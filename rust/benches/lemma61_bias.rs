//! **Lemma 6.1 / Assumption 6** — empirical validation of the gradient-bias
//! bound `E‖b(x)‖² ≤ 4K²η²B²` and the elastic-consistency bound
//! `E‖x̄ − x_i‖² ≤ η²B²` during LayUp-style training.
//!
//! The bench replays LayUp's update rule (local layer-wise SGD + push-sum
//! gossip into a random peer) deterministically round-robin across replicas,
//! measuring at regular intervals:
//!   * the worst consensus distance (LHS of Assumption 6),
//!   * the gradient bias ‖g(x_i) − g(x̄)‖² on a fixed probe batch,
//!   * empirical Lipschitz and gradient-norm constants (K, S) that feed the
//!     bound's RHS.

#[path = "common.rs"]
mod common;

use std::sync::Arc;

use layup::algorithms::PerLayerOpt;
use layup::bias::BiasTracker;
use layup::config::{Algorithm, TrainConfig};
use layup::coordinator::Shared;
use layup::data;
use layup::model::ModelExec;
use layup::runtime::Runtime;
use layup::util::rng::Pcg32;

fn main() {
    let man = common::manifest();
    let steps = common::env_usize("LAYUP_STEPS", 50);
    let m = common::workers();
    let eta = 0.02f32;

    let mut cfg = TrainConfig::new("mlpnet18", Algorithm::LayUp, m, steps);
    cfg.optim = layup::optim::OptimKind::sgd(0.0, 0.0);
    cfg.schedule = layup::optim::Schedule::Constant { lr: eta };
    let shared = Shared::new(&cfg, &man).expect("shared");
    let model = man.model("mlpnet18").unwrap();

    let mut rt = Runtime::new().expect("runtime");
    let mut exec = ModelExec::load(&mut rt, &man, "mlpnet18").expect("load");
    let mut datasets: Vec<_> = (0..m)
        .map(|w| data::build(model, w, m, cfg.seed).expect("dataset"))
        .collect();
    let mut opts: Vec<PerLayerOpt> = (0..m)
        .map(|w| {
            PerLayerOpt::new(
                &cfg.optim,
                &cfg.schedule,
                &exec.manifest,
                w,
                Arc::clone(&shared.update_pool),
            )
        })
        .collect();
    let mut rng = Pcg32::new(99);
    let mut tracker = BiasTracker::default();

    for step in 0..steps {
        for w in 0..m {
            let batch = datasets[w].next_batch();
            let params = &shared.params[w];
            let pass = exec.forward(params, &batch).expect("fwd");
            let peer = rng.peer(w, m);
            let shipped = shared.weights[w].halve();
            let frac = shared.weights[peer].try_accept(shipped);
            if frac.is_none() {
                shared.weights[w].reclaim(shipped);
            }
            // collect (layer, grads) then apply LayUp's per-layer rule
            let mut updates: Vec<(usize, Vec<layup::tensor::Tensor>)> = Vec::new();
            exec.backward(params, &pass, &mut |li, g| updates.push((li, g)))
                .expect("bwd");
            for (li, grads) in updates {
                opts[w].step_layer(params, li, &grads, step);
                if let Some(f) = frac {
                    for (ti, t) in params.layers[li].tensors.iter().enumerate() {
                        let snap = t.snapshot();
                        shared.params[peer].layers[li].tensors[ti].mix_from(1.0 - f, f, &snap.data);
                    }
                    // stamp the peer's staleness clock so its upload cache
                    // sees the gossip write (the clock is the cache key)
                    shared.params[peer].layers[li].clock.record(w, step);
                }
            }
            if frac.is_some() {
                shared.weights[peer].release();
            }
        }
        if step % (steps / 10).max(1) == 0 {
            tracker
                .measure(step, &mut exec, &shared, 0, datasets[0].as_ref())
                .expect("measure");
        }
    }

    let tau_max = 1.0; // gossip lands within one iteration in this replay
    let (bias_worst, bias_bound) = tracker.lemma61_check(eta as f64, m, tau_max);
    let (ec_worst, ec_bound) = tracker.elastic_check(eta as f64, m, tau_max);
    println!("Lemma 6.1:   measured worst ‖b‖² = {bias_worst:.3e}   bound 4K²η²B² = {bias_bound:.3e}");
    println!("Assumption 6: measured worst ‖x̄−x_i‖² = {ec_worst:.3e}   bound η²B² = {ec_bound:.3e}");
    let ok_bias = bias_worst <= bias_bound;
    let ok_ec = ec_worst <= ec_bound * 4.0; // B' is a loose constant; allow 4x slack
    println!("bias bound holds: {ok_bias};   elastic consistency (4x slack): {ok_ec}");
    std::fs::write(common::results_dir().join("lemma61_bias.csv"), tracker.to_csv()).unwrap();
    println!("wrote results/lemma61_bias.csv");
    assert!(ok_bias, "Lemma 6.1 bound violated");
}
