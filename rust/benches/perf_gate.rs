//! **§Perf CI gate** — diffs the kernel rows `perf_hotpath` just wrote to
//! `results/bench_summary.json` against the committed baseline
//! `BENCH_10.json` at the repo root, and exits non-zero when any kernel
//! regressed past the tolerance.
//!
//! The comparison is machine-independent: each kernel's `wall_s` is divided
//! by the same run's `calibration_copy` wall (a plain `f32` memcpy over the
//! same footprint), and those *ratios* — kernel cost in memcpy units — are
//! what gets diffed. A faster or slower runner shifts both sides of every
//! ratio equally; only a real change in kernel efficiency moves it.
//!
//! Knobs:
//!   LAYUP_BENCH_BASELINE  baseline JSON path (default: search for
//!                         BENCH_10.json upward from the current directory)
//!   LAYUP_GATE_TOL        allowed fractional regression (default 0.15)

#[path = "common.rs"]
mod common;

use std::collections::BTreeMap;
use std::path::PathBuf;

use layup::util::json::Json;

const BASELINE_NAME: &str = "BENCH_10.json";
const CALIBRATION: &str = "calibration_copy";

fn baseline_path() -> PathBuf {
    if let Ok(p) = std::env::var("LAYUP_BENCH_BASELINE") {
        return PathBuf::from(p);
    }
    // `cargo bench` runs from the package root (rust/); the baseline lives
    // one level up at the repo root, so walk ancestors
    let mut dir = std::env::current_dir().expect("cwd");
    loop {
        let cand = dir.join(BASELINE_NAME);
        if cand.exists() {
            return cand;
        }
        if !dir.pop() {
            panic!("{BASELINE_NAME} not found in any ancestor of the current directory");
        }
    }
}

/// `label -> wall_s` for every kernel row under `doc["perf_hotpath"]`.
fn kernel_walls(doc: &Json, what: &str) -> BTreeMap<String, f64> {
    let rows = doc
        .get("perf_hotpath")
        .and_then(Json::as_arr)
        .unwrap_or_else(|e| panic!("{what}: missing perf_hotpath section: {e}"));
    assert!(!rows.is_empty(), "{what}: perf_hotpath section is empty");
    rows.iter()
        .map(|row| {
            let label = row.get("label").and_then(Json::as_str).expect("row label");
            let wall = row.get("wall_s").and_then(Json::as_f64).expect("row wall_s");
            assert!(wall > 0.0, "{what}: non-positive wall_s for {label}");
            (label.to_string(), wall)
        })
        .collect()
}

fn load(path: &std::path::Path, what: &str) -> BTreeMap<String, f64> {
    let text = std::fs::read_to_string(path)
        .unwrap_or_else(|e| panic!("{what}: cannot read {}: {e}", path.display()));
    let doc = Json::parse(&text)
        .unwrap_or_else(|e| panic!("{what}: {} is not valid JSON: {e}", path.display()));
    kernel_walls(&doc, what)
}

fn main() {
    let tol = common::env_f64("LAYUP_GATE_TOL", 0.15);
    let current_path = common::results_dir().join("bench_summary.json");
    let current = load(&current_path, "current run");
    let base_path = baseline_path();
    let baseline = load(&base_path, "baseline");

    let cal_cur = *current
        .get(CALIBRATION)
        .unwrap_or_else(|| panic!("current run: no {CALIBRATION} row"));
    let cal_base = *baseline
        .get(CALIBRATION)
        .unwrap_or_else(|| panic!("baseline: no {CALIBRATION} row"));

    println!(
        "perf gate: {} vs {}  (tolerance {:.0}%)",
        current_path.display(),
        base_path.display(),
        100.0 * tol
    );
    println!(
        "{:<28} {:>12} {:>12} {:>9}  verdict",
        "kernel", "base ratio", "now ratio", "delta"
    );

    let mut failures = Vec::new();
    for (label, base_wall) in &baseline {
        if label == CALIBRATION {
            continue;
        }
        let Some(cur_wall) = current.get(label) else {
            // a dropped row is a silent coverage loss, not a perf win
            failures.push(format!("{label}: present in baseline, missing from current run"));
            continue;
        };
        let base_ratio = base_wall / cal_base;
        let cur_ratio = cur_wall / cal_cur;
        let delta = cur_ratio / base_ratio - 1.0;
        let regressed = delta > tol;
        println!(
            "{:<28} {:>12.3} {:>12.3} {:>+8.1}%  {}",
            label,
            base_ratio,
            cur_ratio,
            100.0 * delta,
            if regressed { "REGRESSED" } else { "ok" }
        );
        if regressed {
            failures.push(format!(
                "{label}: {cur_ratio:.3}x memcpy vs baseline {base_ratio:.3}x (+{:.1}%)",
                100.0 * delta
            ));
        }
    }
    for label in current.keys() {
        if !baseline.contains_key(label) {
            println!("{label:<28} (new row — not in baseline, not gated)");
        }
    }

    if !failures.is_empty() {
        eprintln!("\nperf gate FAILED:");
        for f in &failures {
            eprintln!("  {f}");
        }
        std::process::exit(1);
    }
    println!("\nperf gate passed: no kernel regressed more than {:.0}%", 100.0 * tol);
}
