//! **§Perf** — hot-path microbenchmarks backing EXPERIMENTS.md §Perf:
//!   1. per-layer fwd/bwd executable latency (L2/L1 compute path),
//!   2. parameter-upload cost with vs without the version cache,
//!   3. lock-free gossip mix throughput (updater-thread inner loop),
//!   4. full train-step latency per algorithm (1 worker vs M workers).

#[path = "common.rs"]
mod common;

use std::time::Instant;

use layup::config::{Algorithm, TrainConfig};
use layup::coordinator::Shared;
use layup::data;
use layup::model::ModelExec;
use layup::runtime::Runtime;
use layup::tensor::{AtomicTensor, Tensor};

fn time<F: FnMut()>(reps: usize, mut f: F) -> f64 {
    let t0 = Instant::now();
    for _ in 0..reps {
        f();
    }
    t0.elapsed().as_secs_f64() / reps as f64
}

fn main() {
    let man = common::manifest();
    let model_name = "mlpnet18";
    let model = man.model(model_name).unwrap();

    // --- 1. per-layer executable latency -----------------------------------
    let mut rt = Runtime::new().unwrap();
    let mut exec = ModelExec::load(&mut rt, &man, model_name).unwrap();
    let cfg = TrainConfig::new(model_name, Algorithm::LocalSgd, 1, 1);
    let shared = Shared::new(&cfg, &man).unwrap();
    let params = &shared.params[0];
    let mut ds = data::build(model, 0, 1, 7).expect("dataset");
    let batch = ds.next_batch();
    // warmup
    let pass = exec.forward(params, &batch).unwrap();
    exec.backward(params, &pass, &mut |_, _| {}).unwrap();

    let fwd = time(10, || {
        let _ = exec.forward(params, &batch).unwrap();
    });
    let pass = exec.forward(params, &batch).unwrap();
    let bwd = time(10, || {
        exec.backward(params, &pass, &mut |_, _| {}).unwrap();
    });
    println!("fwd  {:.2} ms   bwd {:.2} ms   ({} layers, {:.2e} step FLOPs)",
        1e3 * fwd, 1e3 * bwd, model.layers.len(), model.step_flops() as f64);

    // --- 2. upload cache hit-rate effect ------------------------------------
    exec.upload_hits = 0;
    exec.upload_misses = 0;
    let cached = time(10, || {
        let _ = exec.forward(params, &batch).unwrap();
    });
    let hits_frac = exec.upload_hits as f64 / (exec.upload_hits + exec.upload_misses) as f64;
    // now invalidate every layer every step (simulated gossip storm)
    let uncached = time(10, || {
        for l in &params.layers {
            for t in &l.tensors {
                let snap = t.snapshot();
                t.store_from(&snap.data); // same values
            }
            l.clock.record(0, 0); // stamp the layer clock: cache invalidated
        }
        let _ = exec.forward(params, &batch).unwrap();
    });
    println!(
        "fwd with param-literal cache: {:.2} ms (hit rate {:.0}%)   all-invalidated: {:.2} ms  ({:+.1}%)",
        1e3 * cached,
        100.0 * hits_frac,
        1e3 * uncached,
        100.0 * (uncached / cached - 1.0)
    );

    // --- 3. gossip mix throughput -------------------------------------------
    let n = 1 << 20;
    let at = AtomicTensor::from_tensor(&Tensor::full(&[n], 1.0));
    let src = vec![0.5f32; n];
    let mix = time(20, || at.mix_from(0.5, 0.5, &src));
    println!(
        "gossip mix_from: {:.2} ms for {} elems = {:.2} GB/s effective",
        1e3 * mix,
        n,
        (n * 8) as f64 / mix / 1e9
    );
    let sub = time(20, || at.sub_scaled(0.001, &src));
    println!(
        "optimizer sub_scaled: {:.2} ms = {:.2} GB/s effective",
        1e3 * sub,
        (n * 8) as f64 / sub / 1e9
    );

    // --- 3b. fused updater hot path (§Perf) ---------------------------------
    // LayUp's updater inner loop used to be three passes per layer:
    // sub_scaled (local update) + load_into (snapshot) + mix_from (peer
    // push). The fused sub_scaled_then_mix_into does all of it in one
    // traversal. Same logical work, so both sides report GB/s over the
    // 16 B/elem the update+mix semantically moves.
    let peer = AtomicTensor::from_tensor(&Tensor::full(&[n], 1.0));
    let mut scratch = vec![0.0f32; n];
    let logical_bytes = (n * 16) as f64;
    let three_pass = time(20, || {
        at.sub_scaled(0.001, &src);
        at.load_into(&mut scratch);
        peer.mix_from(0.5, 0.5, &scratch);
    });
    let fused = time(20, || {
        at.sub_scaled_then_mix_into(0.001, &src, &peer, 0.5, 0.5);
    });
    println!(
        "updater three-pass (step+load+mix): {:.2} ms = {:.2} GB/s   fused: {:.2} ms = {:.2} GB/s  ({:.2}x)",
        1e3 * three_pass,
        logical_bytes / three_pass / 1e9,
        1e3 * fused,
        logical_bytes / fused / 1e9,
        three_pass / fused
    );

    // --- 4. end-to-end step latency per algorithm ---------------------------
    let steps = common::env_usize("LAYUP_STEPS", 20);
    println!("\nend-to-end avg step wall time ({} workers, {} steps):", common::workers(), steps);
    for algo in [Algorithm::LayUp, Algorithm::Ddp, Algorithm::GoSgd] {
        let mut cfg = common::vision_cfg(model_name, algo, steps);
        cfg.eval_every = usize::MAX / 2;
        let r = common::run_one(&cfg, &man);
        println!(
            "  {:<12} {:.1} ms/step  occupancy {:.1}%",
            r.algorithm,
            1e3 * r.total_time_s / steps as f64,
            100.0 * r.compute_occupancy
        );
    }
}
