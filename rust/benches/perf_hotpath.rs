//! **§Perf** — hot-path microbenchmarks backing EXPERIMENTS.md §Perf:
//!   1. parameter-kernel throughput, scalar and sharded (`update_threads`
//!      1/2/4): mix, sub_scaled, the fused update+mix, average_with and
//!      delay-compensation — every row lands in
//!      `results/bench_summary.json` and feeds the CI perf gate
//!      (`cargo bench --bench perf_gate` vs the committed `BENCH_10.json`),
//!      alongside the codec wire kernels and the telemetry span recorder,
//!   2. per-layer fwd/bwd executable latency (L2/L1 compute path),
//!   3. parameter-upload cost with vs without the version cache,
//!   4. full train-step latency per algorithm.
//!
//! Sections 2–4 need the XLA artifacts and are skipped on a bare checkout
//! (no `make artifacts`), so the kernel rows — and the regression gate
//! built on them — run anywhere, CI included.

#[path = "common.rs"]
mod common;

use std::hint::black_box;
use std::time::Instant;

use layup::comm::codec::kernels;
use layup::config::{Algorithm, TrainConfig};
use layup::coordinator::Shared;
use layup::data;
use layup::model::ModelExec;
use layup::optim::{LayerOptimizer, OptimKind};
use layup::runtime::Runtime;
use layup::tensor::shard::{ShardPool, CHUNK};
use layup::tensor::{AtomicTensor, Tensor};
use layup::telemetry::{Phase, Telemetry, TelemetryConfig};
use layup::util::json::{num, obj, s, Json};

fn time<F: FnMut()>(reps: usize, mut f: F) -> f64 {
    let t0 = Instant::now();
    for _ in 0..reps {
        f();
    }
    t0.elapsed().as_secs_f64() / reps as f64
}

/// One machine-readable kernel row for the perf gate: stable label,
/// wall-clock per call, and the logical bytes the kernel semantically moves
/// (so effective GB/s can be rederived from the file).
fn kernel_row(label: &str, wall_s: f64, bytes: f64) -> Json {
    obj(vec![
        ("label", s(label)),
        ("wall_s", num(wall_s)),
        ("bytes", num(bytes)),
        ("gbs", num(bytes / wall_s / 1e9)),
    ])
}

/// Section 1: the parameter hot-path kernels, scalar (`t1` — the serial
/// pool, bit-identical to the unsharded code) and sharded at 2 and 4
/// update threads. The `calibration_copy` row is a plain `f32` slice copy:
/// the gate normalises every kernel by it so the comparison tracks
/// *kernel-vs-memcpy* ratios, not absolute runner speed.
fn kernel_section(reps: usize) -> Vec<Json> {
    let n = 1 << 20;
    let mut rows = Vec::new();

    // machine-speed calibration: pure memcpy over the same footprint
    let src = vec![0.5f32; n];
    let mut dst = vec![0.0f32; n];
    let copy = time(reps, || {
        dst.copy_from_slice(&src);
        black_box(&mut dst);
    });
    println!(
        "calibration copy: {:.2} ms = {:.2} GB/s",
        1e3 * copy,
        (n * 8) as f64 / copy / 1e9
    );
    rows.push(kernel_row("calibration_copy", copy, (n * 8) as f64));

    for threads in [1usize, 2, 4] {
        let pool = ShardPool::new(threads);
        let at = AtomicTensor::from_tensor(&Tensor::full(&[n], 1.0));
        let peer = AtomicTensor::from_tensor(&Tensor::full(&[n], 1.0));
        let other = AtomicTensor::from_tensor(&Tensor::full(&[n], 2.0));

        let mix = time(reps, || at.mix_from_sharded(0.5, 0.5, &src, &pool));
        let sub = time(reps, || at.sub_scaled_sharded(0.001, &src, &pool));
        let fused = time(reps, || {
            at.sub_scaled_then_mix_sharded(0.001, &src, &peer, 0.5, 0.5, &pool);
        });
        let avg = time(reps, || at.average_with_sharded(&[&other], &pool));

        // delay compensation (§Perf): grad += λ·g²·(x_now − x_then), the
        // extra traversal DC-ASGD-style updaters pay per step
        let mut opt = LayerOptimizer::with_pool(OptimKind::sgd(0.9, 0.0), &[n], pool);
        let params = [AtomicTensor::from_tensor(&Tensor::full(&[n], 1.0))];
        let mut grads = [Tensor::full(&[n], 0.1)];
        let x_then = [Tensor::full(&[n], 0.9)];
        let comp = time(reps, || opt.compensate(&params, &mut grads, 0.5, &x_then));

        println!(
            "t{threads}: mix {:.2} GB/s   sub_scaled {:.2} GB/s   fused update+mix {:.2} GB/s   average {:.2} GB/s   compensate {:.2} GB/s",
            (n * 8) as f64 / mix / 1e9,
            (n * 8) as f64 / sub / 1e9,
            (n * 16) as f64 / fused / 1e9,
            (n * 12) as f64 / avg / 1e9,
            (n * 16) as f64 / comp / 1e9,
        );
        rows.push(kernel_row(&format!("mix_t{threads}"), mix, (n * 8) as f64));
        rows.push(kernel_row(&format!("sub_scaled_t{threads}"), sub, (n * 8) as f64));
        rows.push(kernel_row(
            &format!("fused_update_mix_t{threads}"),
            fused,
            (n * 16) as f64,
        ));
        rows.push(kernel_row(&format!("average_t{threads}"), avg, (n * 12) as f64));
        rows.push(kernel_row(&format!("compensate_t{threads}"), comp, (n * 16) as f64));

        // codec wire kernels (§Compression): int8 quantize/dequantize and
        // the error-feedback re-add ride the same shard lanes as the
        // parameter kernels, so they regress together
        let mut scales = vec![0.0f32; n.div_ceil(CHUNK)];
        let mut q = vec![0u8; n];
        let enc = time(reps, || {
            kernels::int8_encode(&pool, &src, 0xC0DEC, &mut scales, &mut q);
            black_box(&mut q);
        });
        let mut out = vec![0.0f32; n];
        let dec = time(reps, || {
            kernels::int8_decode(&pool, &scales, &q, &mut out);
            black_box(&mut out);
        });
        let mut y = vec![0.0f32; n];
        let ef = time(reps, || {
            kernels::add_residual(&pool, &src, &dst, &mut y);
            black_box(&mut y);
        });
        println!(
            "t{threads}: int8_encode {:.2} GB/s   int8_decode {:.2} GB/s   ef_add {:.2} GB/s",
            (n * 5) as f64 / enc / 1e9,
            (n * 5) as f64 / dec / 1e9,
            (n * 12) as f64 / ef / 1e9,
        );
        rows.push(kernel_row(&format!("int8_encode_t{threads}"), enc, (n * 5) as f64));
        rows.push(kernel_row(&format!("int8_decode_t{threads}"), dec, (n * 5) as f64));
        rows.push(kernel_row(&format!("ef_add_residual_t{threads}"), ef, (n * 12) as f64));
    }

    // top-k selection: the result is a pure function of the values (identical
    // at every thread count), so one row — timed on the widest pool, which is
    // what the sharded quickselect is built to exploit
    let grad = {
        let mut seed = 0x70_70u64;
        (0..n)
            .map(|_| {
                seed = seed.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                ((seed >> 33) as f32 / (1u64 << 31) as f32) - 0.5
            })
            .collect::<Vec<f32>>()
    };
    let topk_pool = ShardPool::new(4);
    let topk = time(reps, || {
        black_box(kernels::top_k_indices(&topk_pool, &grad, n / 16));
    });
    println!(
        "top_k select (k = n/16): {:.2} ms = {:.2} GB/s",
        1e3 * topk,
        (n * 4) as f64 / topk / 1e9
    );
    rows.push(kernel_row("topk_select_k16", topk, (n * 4) as f64));

    // step-frame coalescing (§Compression): `frame_build` is the per-flush
    // assembly cost — concatenating L per-layer gradients into the single
    // stream a StepFrame ships — and `frame_topk` is the whole-step global
    // selection over that concatenation (ranks compete across layers, the
    // coalesced replacement for L per-layer top-k calls)
    let layers = 16usize;
    let per_layer = n / layers;
    let frame_vals: Vec<&[f32]> = grad.chunks(per_layer).collect();
    let mut concat = vec![0.0f32; n];
    let fb = time(reps, || {
        let mut off = 0;
        for v in &frame_vals {
            concat[off..off + v.len()].copy_from_slice(v);
            off += v.len();
        }
        black_box(&mut concat);
    });
    let ft = time(reps, || {
        black_box(kernels::top_k_indices(&topk_pool, &concat, n / 16));
    });
    println!(
        "frame build ({layers} layers): {:.2} ms = {:.2} GB/s   frame top-k: {:.2} ms",
        1e3 * fb,
        (n * 8) as f64 / fb / 1e9,
        1e3 * ft
    );
    rows.push(kernel_row("frame_build", fb, (n * 8) as f64));
    rows.push(kernel_row("frame_topk", ft, (n * 4) as f64));

    // telemetry span recorder (§Telemetry): guard open + close, two clock
    // reads and one ring-slot publish per span — the full per-span cost an
    // *enabled* run pays at every instrumented site. Logical bytes are the
    // 24-byte ring slot (phase + start + duration + sequence bump).
    let tel = Telemetry::from_config(&TelemetryConfig {
        enabled: true,
        ..TelemetryConfig::default()
    });
    tel.register_thread("bench");
    let spans = 1usize << 17;
    let span_wall = time(reps, || {
        for _ in 0..spans {
            drop(black_box(tel.span(Phase::OptStep)));
        }
    });
    println!("telemetry span record: {:.0} ns/span", 1e9 * span_wall / spans as f64);
    rows.push(kernel_row("span_record", span_wall, (spans * 24) as f64));

    // the pre-shard-pool framing kept for continuity: fused vs the
    // three-pass step + load + mix sequence it replaced
    let at = AtomicTensor::from_tensor(&Tensor::full(&[n], 1.0));
    let peer = AtomicTensor::from_tensor(&Tensor::full(&[n], 1.0));
    let mut scratch = vec![0.0f32; n];
    let logical_bytes = (n * 16) as f64;
    let three_pass = time(reps, || {
        at.sub_scaled(0.001, &src);
        at.load_into(&mut scratch);
        peer.mix_from(0.5, 0.5, &scratch);
    });
    let fused = time(reps, || {
        at.sub_scaled_then_mix_into(0.001, &src, &peer, 0.5, 0.5);
    });
    println!(
        "updater three-pass (step+load+mix): {:.2} ms = {:.2} GB/s   fused: {:.2} ms = {:.2} GB/s  ({:.2}x)",
        1e3 * three_pass,
        logical_bytes / three_pass / 1e9,
        1e3 * fused,
        logical_bytes / fused / 1e9,
        three_pass / fused
    );
    rows.push(kernel_row("three_pass_update_mix", three_pass, logical_bytes));

    rows
}

fn main() {
    // --- 1. parameter hot-path kernels (always runs; feeds the CI gate) -----
    let reps = common::env_usize("LAYUP_REPS", 20);
    let rows = kernel_section(reps);
    common::write_bench_summary("perf_hotpath", rows);

    let Some(man) = common::try_manifest() else {
        println!("artifacts/ missing: skipping fwd/bwd, upload-cache and end-to-end sections");
        return;
    };
    let model_name = "mlpnet18";
    let model = man.model(model_name).unwrap();

    // --- 2. per-layer executable latency -----------------------------------
    let mut rt = Runtime::new().unwrap();
    let mut exec = ModelExec::load(&mut rt, &man, model_name).unwrap();
    let cfg = TrainConfig::new(model_name, Algorithm::LocalSgd, 1, 1);
    let shared = Shared::new(&cfg, &man).unwrap();
    let params = &shared.params[0];
    let mut ds = data::build(model, 0, 1, 7).expect("dataset");
    let batch = ds.next_batch();
    // warmup
    let pass = exec.forward(params, &batch).unwrap();
    exec.backward(params, &pass, &mut |_, _| {}).unwrap();

    let fwd = time(10, || {
        let _ = exec.forward(params, &batch).unwrap();
    });
    let pass = exec.forward(params, &batch).unwrap();
    let bwd = time(10, || {
        exec.backward(params, &pass, &mut |_, _| {}).unwrap();
    });
    println!("fwd  {:.2} ms   bwd {:.2} ms   ({} layers, {:.2e} step FLOPs)",
        1e3 * fwd, 1e3 * bwd, model.layers.len(), model.step_flops() as f64);

    // --- 3. upload cache hit-rate effect ------------------------------------
    exec.upload_hits = 0;
    exec.upload_misses = 0;
    let cached = time(10, || {
        let _ = exec.forward(params, &batch).unwrap();
    });
    let hits_frac = exec.upload_hits as f64 / (exec.upload_hits + exec.upload_misses) as f64;
    // now invalidate every layer every step (simulated gossip storm)
    let uncached = time(10, || {
        for l in &params.layers {
            for t in &l.tensors {
                let snap = t.snapshot();
                t.store_from(&snap.data); // same values
            }
            l.clock.record(0, 0); // stamp the layer clock: cache invalidated
        }
        let _ = exec.forward(params, &batch).unwrap();
    });
    println!(
        "fwd with param-literal cache: {:.2} ms (hit rate {:.0}%)   all-invalidated: {:.2} ms  ({:+.1}%)",
        1e3 * cached,
        100.0 * hits_frac,
        1e3 * uncached,
        100.0 * (uncached / cached - 1.0)
    );

    // --- 4. end-to-end step latency per algorithm ---------------------------
    let steps = common::env_usize("LAYUP_STEPS", 20);
    println!("\nend-to-end avg step wall time ({} workers, {} steps):", common::workers(), steps);
    for algo in [Algorithm::LayUp, Algorithm::Ddp, Algorithm::GoSgd] {
        let mut cfg = common::vision_cfg(model_name, algo, steps);
        cfg.eval_every = usize::MAX / 2;
        let r = common::run_one(&cfg, &man);
        println!(
            "  {:<12} {:.1} ms/step  occupancy {:.1}%",
            r.algorithm,
            1e3 * r.total_time_s / steps as f64,
            100.0 * r.compute_occupancy
        );
    }
}
