//! **Topology protocol** — flat gossip vs the sharded parameter-server
//! family vs hierarchical two-tier gossip, swept over the same simulated
//! link-latency grid as `fig_delay_robustness`.
//!
//! Four configurations share one workload and budget:
//!
//! * `flat`    — LayUp on the flat topology (the repo's default path);
//! * `ps:N`    — ASGD-PS: trainers push per-layer grads to N server shards;
//! * `ps:N+dc` — DC-ASGD-PS: the shards delay-compensate stale gradients
//!               with `λ·g⊙g⊙(x_now − x_then)` before applying;
//! * `hier:G`  — HierGossip: instant intra-group push-sum, leader-to-leader
//!               fabric exchange every `sync_period` steps.
//!
//! Each also runs once on the instant (shared-memory) fabric — the
//! zero-delay reference proving the budget completes on both transports.
//! The table reports wall time, loss at budget, delivered staleness and the
//! PS counters; the paper-relevant separation is DC-ASGD-PS beating ASGD-PS
//! on loss-at-budget once the links carry non-zero delay.
//!
//! Exit is non-zero when any parameter-server run reports `stalled = true`
//! (a shard died and trainers waited out the stall timeout) — the CI
//! topology-smoke job relies on this.
//!
//! Environment knobs:
//!   LAYUP_LATENCIES  one-way seconds (default 0,0.001,0.005)
//!   LAYUP_SHARDS     PS server shards (default 1)
//!   LAYUP_GROUPS     hier groups (default 2)
//!   LAYUP_STEPS / LAYUP_WORKERS / LAYUP_SEEDS as usual

#[path = "common.rs"]
mod common;

use layup::comm::{FabricSpec, LatencyDist};
use layup::config::{Algorithm, TrainConfig};
use layup::metrics::RunSummary;
use layup::topology::roles::TopologySpec;
use layup::util::json::{arr, num, obj, s, Json};

/// One swept configuration: algorithm + topology, labeled for the tables.
struct TopoCase {
    label: &'static str,
    algorithm: Algorithm,
    cluster: TopologySpec,
}

fn cases(shards: usize, groups: usize) -> Vec<TopoCase> {
    vec![
        TopoCase { label: "flat", algorithm: Algorithm::LayUp, cluster: TopologySpec::Flat },
        TopoCase {
            label: "asgd-ps",
            algorithm: Algorithm::AsgdPs,
            cluster: TopologySpec::Ps { shards },
        },
        TopoCase {
            label: "dcasgd-ps",
            algorithm: Algorithm::DcAsgdPs,
            cluster: TopologySpec::Ps { shards },
        },
        TopoCase {
            label: "hier-gossip",
            algorithm: Algorithm::HierGossip,
            cluster: TopologySpec::Hier { groups },
        },
    ]
}

/// The topology row: the stable `summary_row` vocabulary plus the PS/role
/// counters this bench exists to track (append-only, like the base row).
fn topo_row(label: &str, sum: &RunSummary) -> Json {
    let mut row = match common::summary_row(label, sum) {
        Json::Obj(m) => m,
        _ => unreachable!("summary_row returns an object"),
    };
    let ps = &sum.stats.ps;
    row.insert("ps_shards".into(), num(ps.shards as f64));
    row.insert("ps_grad_pushes".into(), num(ps.grad_pushes as f64));
    row.insert("ps_param_pulls".into(), num(ps.param_pulls as f64));
    row.insert("stalled".into(), Json::Bool(sum.stats.recovery.stalled));
    Json::Obj(row)
}

fn main() {
    let man = common::manifest();
    let steps = common::env_usize("LAYUP_STEPS", 48);
    let workers = common::workers();
    let shards = common::env_usize("LAYUP_SHARDS", 1).max(1);
    let groups = common::env_usize("LAYUP_GROUPS", 2).clamp(2, workers);
    let latencies = common::env_latencies("0,0.001,0.005");
    assert!(
        workers > shards + 1,
        "need at least 2 trainers: LAYUP_WORKERS={workers} LAYUP_SHARDS={shards}"
    );

    println!(
        "fig: topology protocol — mlpnet18, {workers} workers, {steps} steps, \
         ps:{shards}, hier:{groups}"
    );
    common::hr();
    println!(
        "{:<12} {:>8} {:>9} {:>10} {:>10} {:>9} {:>8}",
        "topology", "lat (ms)", "wall (s)", "loss@bud", "staleness", "pushes", "stalled"
    );

    let mut summary_rows: Vec<Json> = Vec::new();
    let mut rows: Vec<Json> = Vec::new();
    let mut any_stalled = false;
    // loss-at-budget per (case, latency) for the DC vs plain comparison
    let mut loss_at: Vec<(String, f64, f64)> = Vec::new();

    for case in cases(shards, groups) {
        for (li, fabric) in std::iter::once(None)
            .chain(latencies.iter().copied().map(Some))
            .enumerate()
        {
            let mut cfg: TrainConfig = common::vision_cfg("mlpnet18", case.algorithm, steps);
            cfg.cluster = case.cluster;
            cfg.eval_every = (steps / 6).max(1);
            let (fab_label, lat) = match fabric {
                // the instant fabric run: both-transports acceptance proof
                None => (String::from("instant"), -1.0),
                Some(lat) => {
                    cfg.fabric = FabricSpec::Sim {
                        latency: LatencyDist::Constant(lat),
                        bandwidth_bytes_per_s: 0.0,
                        drop_prob: 0.0,
                    };
                    (format!("{}ms", (1e3 * lat) as u64), lat)
                }
            };
            let sum = common::run_one(&cfg, &man);
            let final_loss = sum.curve.points.last().map(|p| p.loss).unwrap_or(f64::NAN);
            let stalled = sum.stats.recovery.stalled;
            any_stalled |= stalled && case.cluster.n_shards() > 0;
            println!(
                "{:<12} {:>8} {:>9.2} {:>10.4} {:>10.2} {:>9} {:>8}",
                case.label,
                if lat < 0.0 { "inst".into() } else { format!("{:.1}", 1e3 * lat) },
                sum.total_time_s,
                final_loss,
                sum.stats.comm.mean_delivered_staleness(),
                sum.stats.ps.grad_pushes,
                stalled,
            );
            let label = format!("{}-{}", case.label, fab_label);
            rows.push(obj(vec![
                ("topology", s(case.label)),
                ("algorithm", s(&sum.algorithm)),
                ("latency_s", num(lat.max(0.0))),
                ("instant", Json::Bool(lat < 0.0)),
                ("wall_s", num(sum.total_time_s)),
                ("final_loss", num(final_loss)),
                ("mean_staleness", num(sum.stats.comm.mean_delivered_staleness())),
                ("ps_grad_pushes", num(sum.stats.ps.grad_pushes as f64)),
                ("ps_param_pulls", num(sum.stats.ps.param_pulls as f64)),
                ("ps_queue_depth_max", num(sum.stats.ps.queue_depth_max as f64)),
                ("stalled", Json::Bool(stalled)),
            ]));
            summary_rows.push(topo_row(&label, &sum));
            if li > 0 {
                loss_at.push((case.label.to_string(), lat, final_loss));
            }
        }
        common::hr();
    }

    // the paper-relevant separation: shard-side delay compensation recovers
    // loss once the links are slow (DC-ASGD, Zheng et al. 2017)
    for &lat in &latencies {
        if lat <= 0.0 {
            continue;
        }
        let find = |name: &str| {
            loss_at
                .iter()
                .find(|(l, t, _)| l == name && *t == lat)
                .map(|&(_, _, v)| v)
        };
        if let (Some(plain), Some(dc)) = (find("asgd-ps"), find("dcasgd-ps")) {
            println!(
                "delay {:.1} ms: dcasgd-ps loss {:.4} vs asgd-ps {:.4} ({})",
                1e3 * lat,
                dc,
                plain,
                if dc < plain { "compensation wins" } else { "no separation at this budget" }
            );
        }
    }

    let dir = common::results_dir();
    std::fs::write(dir.join("fig_topology.json"), arr(rows).dump()).expect("write json");
    common::write_bench_summary("fig_topology", summary_rows);
    println!("wrote results/fig_topology.json");
    if any_stalled {
        eprintln!("FAIL: a parameter-server run stalled (dead shard waited out the timeout)");
        std::process::exit(1);
    }
}
