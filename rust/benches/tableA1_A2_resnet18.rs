//! **Tables A1/A2** — the ResNet-18 analog (MLPNet-18) on synthetic-100:
//! convergence accuracy + TTC (A2) and TTA to a fixed target (A1).

#[path = "common.rs"]
mod common;

fn main() {
    let man = common::manifest();
    let steps = common::env_usize("LAYUP_STEPS", 140);

    let mut runs = Vec::new();
    for algo in common::paper_algorithms() {
        let cfg = common::vision_cfg("mlpnet18", algo, steps);
        runs.push(common::run_seeds(&cfg, &man));
    }

    println!(
        "Table A2 (measured): mlpnet18 on synthetic-100, {} workers, {} steps",
        common::workers(),
        steps
    );
    println!("{:<14} {:>12} {:>12} {:>8}", "method", "conv acc", "TTC (s)", "epochs");
    common::hr();
    let mut csv = String::from("table,algorithm,metric1,metric2\n");
    for rs in &runs {
        let accs: Vec<f64> = rs.iter().map(|r| r.curve.best_accuracy()).collect();
        let ttcs: Vec<f64> = rs
            .iter()
            .map(|r| r.curve.time_to_convergence(0.01).unwrap_or(r.total_time_s))
            .collect();
        let (am, asd) = common::mean_std(&accs);
        let (tm, _) = common::mean_std(&ttcs);
        println!(
            "{:<14} {:>7.2}±{:<4.2} {:>12.1} {:>8}",
            rs[0].algorithm,
            100.0 * am,
            100.0 * asd,
            tm,
            rs[0].epochs
        );
        csv.push_str(&format!("A2,{},{:.4},{:.2}\n", rs[0].algorithm, am, tm));
    }

    let target = runs
        .iter()
        .map(|rs| common::mean_std(&rs.iter().map(|r| r.curve.best_accuracy()).collect::<Vec<_>>()).0)
        .fold(f64::INFINITY, f64::min)
        * 0.98;
    println!("\nTable A1 (measured): TTA to {:.2}%", 100.0 * target);
    println!("{:<14} {:>12} {:>10}", "method", "TTA (s)", "steps");
    common::hr();
    for rs in &runs {
        let ttas: Vec<f64> = rs
            .iter()
            .map(|r| r.curve.time_to_accuracy(target).unwrap_or(f64::NAN))
            .collect();
        let (tm, tsd) = common::mean_std(&ttas);
        let st = rs[0].curve.step_to_accuracy(target).map(|s| s as f64).unwrap_or(f64::NAN);
        println!("{:<14} {:>7.1}±{:<4.1} {:>10.0}", rs[0].algorithm, tm, tsd, st);
        csv.push_str(&format!("A1,{},{:.2},{:.0}\n", rs[0].algorithm, tm, st));
    }
    std::fs::write(common::results_dir().join("tableA1_A2_resnet18.csv"), csv).unwrap();
    println!("\nwrote results/tableA1_A2_resnet18.csv");
}
