//! **Figure 3** — robustness to stragglers on the vision task.
//!
//! Panel A (accuracy vs delay): measured on the thread cluster — a straggler
//! worker idles `delay x step_time` per iteration; accuracy of the consensus
//! should be roughly flat for all methods.
//! Panel B (training time vs delay): measured on the thread cluster AND at
//! paper scale via the DES (where the barrier vs work-pool distinction shows
//! the paper's separation: DDP/CO2/SlowMo/AD-PSGD degrade, LayUp/GoSGD flat).

#[path = "common.rs"]
mod common;

use layup::config::Algorithm;
use layup::sim::{simulate, Cluster, SimAlgo, Workload};

fn main() {
    let man = common::manifest();
    let steps = common::env_usize("LAYUP_STEPS", 80);
    let delays = [0.0, 2.0, 4.0];
    let algos = [Algorithm::Ddp, Algorithm::GoSgd, Algorithm::Co2, Algorithm::LayUp];

    println!("Fig 3 (measured, thread cluster): mlpnet18, {} workers", common::workers());
    println!(
        "{:<12} {:>8} {:>12} {:>12}",
        "method", "delay", "accuracy", "time (s)"
    );
    common::hr();
    let mut csv = String::from("source,algorithm,delay,accuracy,time_s\n");
    for &algo in &algos {
        for &d in &delays {
            let mut cfg = common::vision_cfg("mlpnet18", algo, steps);
            cfg.straggler = if d > 0.0 { Some((1, d)) } else { None };
            let r = common::run_seeds(&cfg, &man).remove(0);
            let acc = r.curve.best_accuracy();
            println!("{:<12} {:>8.0} {:>11.2}% {:>12.1}", r.algorithm, d, 100.0 * acc, r.total_time_s);
            csv.push_str(&format!(
                "measured,{},{},{:.4},{:.2}\n",
                r.algorithm, d, acc, r.total_time_s
            ));
        }
    }

    println!("\nFig 3B (paper scale, DES): CIFAR-100/ResNet-18 @C1, delay sweep");
    println!("{:<12} {:>8} {:>12}", "method", "delay", "time (s)");
    common::hr();
    for algo in SimAlgo::paper_set(12) {
        for &d in &[0.0, 4.0, 8.0, 16.0, 32.0] {
            let c = Cluster::c1().with_straggler(0, d);
            let w = Workload::resnet18_cifar(c.m);
            let r = simulate(&c, &w, algo, 1);
            println!("{:<12} {:>8.0} {:>12.1}", r.algo, d, r.wall_s);
            csv.push_str(&format!("des,{},{},,{:.2}\n", r.algo, d, r.wall_s));
        }
    }
    std::fs::write(common::results_dir().join("fig3_stragglers.csv"), csv).unwrap();
    println!("\nwrote results/fig3_stragglers.csv");
}
