//! Shared bench harness (criterion is not in the offline crate set, so each
//! bench is a plain binary that prints the paper's table rows and writes
//! CSV/JSON under results/).
//!
//! Environment knobs:
//!   LAYUP_STEPS    steps per run (default per-bench)
//!   LAYUP_WORKERS  simulated devices (default 3 — the paper's C1)
//!   LAYUP_SEEDS    number of seeds to average over (default 1; paper uses 3)
//!   LAYUP_ALGOS    comma-separated algorithm names (registry spellings,
//!                  e.g. "layup,gosgd"); default: the paper's six-algorithm set

#![allow(dead_code)]

use std::path::PathBuf;

use layup::config::{Algorithm, TrainConfig};
use layup::manifest::Manifest;
use layup::metrics::RunSummary;
use layup::optim::{OptimKind, Schedule};
use layup::session::SessionBuilder;
use layup::util::json::{num, obj, s, Json};

pub fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

pub fn workers() -> usize {
    env_usize("LAYUP_WORKERS", 3)
}

pub fn seeds() -> usize {
    env_usize("LAYUP_SEEDS", 1)
}

pub fn results_dir() -> PathBuf {
    // keep results next to the repo root (where artifacts/ lives)
    let dir = layup::artifacts_dir().parent().unwrap().join("results");
    std::fs::create_dir_all(&dir).expect("mkdir results");
    dir
}

pub fn manifest() -> Manifest {
    Manifest::load(&layup::artifacts_dir()).expect("run `make artifacts` first")
}

/// `Some(manifest)` when artifacts/ exists, `None` on a bare checkout —
/// lets kernel-only bench sections (and the CI perf gate fed by them) run
/// without `make artifacts`.
pub fn try_manifest() -> Option<Manifest> {
    Manifest::load(&layup::artifacts_dir()).ok()
}

/// Run one config through the session facade.
pub fn run_one(cfg: &TrainConfig, man: &Manifest) -> RunSummary {
    SessionBuilder::new(cfg.clone())
        .build(man)
        .expect("invalid bench config")
        .run()
        .expect("run failed")
}

/// Baseline config for a vision-table run (paper Table A6 style: SGD with
/// momentum + cosine schedule).
pub fn vision_cfg(model: &str, algorithm: Algorithm, steps: usize) -> TrainConfig {
    let mut cfg = TrainConfig::new(model, algorithm, workers(), steps);
    cfg.optim = OptimKind::sgd(0.9, 5e-4);
    let lr = if matches!(algorithm, Algorithm::LayUp | Algorithm::GoSgd) { 0.035 } else { 0.045 };
    let warmup = if matches!(algorithm, Algorithm::LayUp | Algorithm::GoSgd) { steps / 20 } else { 0 };
    cfg.schedule = Schedule::Cosine {
        lr,
        t_max: steps,
        warmup_steps: warmup,
        warmup_lr: lr / 3.0,
    };
    cfg.sync_period = 12;
    cfg.eval_every = (steps / 15).max(1);
    cfg
}

/// Config for the LM runs (paper Tables A7/A8 style: AdamW + cosine).
pub fn lm_cfg(model: &str, algorithm: Algorithm, steps: usize) -> TrainConfig {
    let mut cfg = TrainConfig::new(model, algorithm, workers(), steps);
    cfg.optim = OptimKind::adamw(0.01);
    let lr = 3e-3f32;
    cfg.schedule = Schedule::Cosine {
        lr,
        t_max: steps,
        warmup_steps: steps / 10,
        warmup_lr: lr / 5.0,
    };
    cfg.sync_period = 12;
    cfg.eval_every = (steps / 12).max(1);
    cfg
}

/// Run `cfg` over `seeds()` seeds; returns all summaries.
pub fn run_seeds(base: &TrainConfig, man: &Manifest) -> Vec<RunSummary> {
    (0..seeds())
        .map(|s| {
            let mut cfg = base.clone();
            cfg.seed = 42 + 1000 * s as u64;
            run_one(&cfg, man)
        })
        .collect()
}

pub fn mean_std(xs: &[f64]) -> (f64, f64) {
    let n = xs.len() as f64;
    let mean = xs.iter().sum::<f64>() / n;
    let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n.max(1.0);
    (mean, var.sqrt())
}

/// The algorithm set under test: `LAYUP_ALGOS` (names resolved through the
/// algorithm registry) or the paper's six-algorithm table order.
pub fn paper_algorithms() -> Vec<Algorithm> {
    match std::env::var("LAYUP_ALGOS") {
        Ok(names) => names
            .split(',')
            .filter(|n| !n.trim().is_empty())
            .map(|n| {
                Algorithm::parse(n.trim())
                    .unwrap_or_else(|e| panic!("LAYUP_ALGOS: {e:#}"))
            })
            .collect(),
        Err(_) => Algorithm::all_paper().to_vec(),
    }
}

pub fn hr() {
    println!("{}", "-".repeat(78));
}

/// One stable machine-readable row of the cross-PR perf trajectory: bench
/// label, wall-clock, final/best loss, and the run's staleness statistics.
/// The key vocabulary is append-only — downstream tooling diffs these files
/// across PRs.
pub fn summary_row(label: &str, sum: &RunSummary) -> Json {
    // a run with no eval points (e.g. fig_fb_ratio's timing window) has no
    // loss to report: emit null, never a sentinel that reads as a metric
    let finite_or_null = |v: Option<f64>| match v {
        Some(x) if x.is_finite() => num(x),
        _ => Json::Null,
    };
    let pts = &sum.curve.points;
    obj(vec![
        ("label", s(label)),
        ("algorithm", s(&sum.algorithm)),
        ("wall_s", num(sum.total_time_s)),
        ("final_loss", finite_or_null(pts.last().map(|p| p.loss))),
        (
            "best_loss",
            finite_or_null((!pts.is_empty()).then(|| sum.curve.best_loss())),
        ),
        (
            "best_accuracy",
            finite_or_null((!pts.is_empty()).then(|| sum.curve.best_accuracy())),
        ),
        ("total_steps", num(sum.total_steps as f64)),
        ("stale_applies", num(sum.stats.staleness.total_applies() as f64)),
        ("stale_tau_mean", num(sum.stats.staleness.mean_tau())),
        ("stale_tau_max", num(sum.stats.staleness.max_tau() as f64)),
        (
            "comm_mean_staleness",
            num(sum.stats.comm.mean_delivered_staleness()),
        ),
    ])
}

/// Merge this bench's rows into `results/bench_summary.json` under the
/// bench's name. Read-modify-write: every bench contributes its section to
/// ONE stable file, so the perf trajectory can be tracked across PRs
/// without scraping per-bench CSVs.
pub fn write_bench_summary(bench: &str, rows: Vec<Json>) {
    let path = results_dir().join("bench_summary.json");
    let mut doc = std::collections::BTreeMap::new();
    if let Ok(text) = std::fs::read_to_string(&path) {
        match Json::parse(&text) {
            Ok(Json::Obj(m)) => doc = m,
            // an unreadable trajectory file is worth a loud warning — the
            // other benches' sections cannot be preserved, only this one's
            // will survive the rewrite
            _ => eprintln!(
                "warning: {} exists but is not a JSON object; rewriting it                  with only the {bench} section",
                path.display()
            ),
        }
    }
    doc.insert(bench.to_string(), Json::Arr(rows));
    // write-then-rename so a killed bench never leaves truncated JSON
    let tmp = path.with_extension("json.tmp");
    std::fs::write(&tmp, Json::Obj(doc).dump()).expect("write bench_summary.json.tmp");
    std::fs::rename(&tmp, &path).expect("commit bench_summary.json");
    println!("bench summary -> {}", path.display());
}

/// `key` as f64 from the environment (bench knob), `default` otherwise.
pub fn env_f64(key: &str, default: f64) -> f64 {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// Parse the `LAYUP_LATENCIES` sweep knob (comma-separated one-way
/// seconds), shared by the delay/staleness benches.
pub fn env_latencies(default: &str) -> Vec<f64> {
    std::env::var("LAYUP_LATENCIES")
        .unwrap_or_else(|_| default.into())
        .split(',')
        .filter(|t| !t.trim().is_empty())
        .map(|t| t.trim().parse().expect("LAYUP_LATENCIES: bad seconds value"))
        .collect()
}
