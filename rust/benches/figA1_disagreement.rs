//! **Figure A1** — model disagreement between workers over training, plus the
//! layer-granularity ablation: LayUp's layer-wise updates vs the same
//! algorithm applying updates only after the full backward pass (the paper's
//! Section 3.2 drift-reduction claim, isolated).

#[path = "common.rs"]
mod common;

use layup::config::Algorithm;

fn main() {
    let man = common::manifest();
    let steps = common::env_usize("LAYUP_STEPS", 100);

    println!("Fig A1 (measured): disagreement ‖x_i − x̄‖/√d during mlpnet18 training");
    println!("{:<14} {:>14} {:>14}", "method", "max drift", "final drift");
    common::hr();
    let mut csv = String::from("algorithm,step,disagreement\n");
    for algo in [
        Algorithm::LayUp,
        Algorithm::LayUpModelGranularity,
        Algorithm::GoSgd,
        Algorithm::Ddp,
    ] {
        let mut cfg = common::vision_cfg("mlpnet18", algo, steps);
        cfg.track_drift_every = (steps / 20).max(1);
        let r = common::run_seeds(&cfg, &man).remove(0);
        println!(
            "{:<14} {:>14.6} {:>14.6}",
            r.algorithm,
            r.stats.max_disagreement,
            r.stats.final_disagreement,
        );
        csv.push_str(&format!(
            "{},max,{:.6}\n{},final,{:.6}\n",
            r.algorithm, r.stats.max_disagreement, r.algorithm, r.stats.final_disagreement
        ));
    }
    println!("\nexpected shape: DDP drift ~0 (lock-step); LayUp bounded and below the");
    println!("model-granularity ablation and GoSGD near the end of training (Fig A1).");
    std::fs::write(common::results_dir().join("figA1_disagreement.csv"), csv).unwrap();
    println!("wrote results/figA1_disagreement.csv");
}
