//! **Table A4** — forward/backward pass timing per architecture, plus the
//! per-layer breakdown LayUp's drift analysis builds on (Section 3.2:
//! gradients become available output-layer-first, D_l grows towards the
//! input). Also prints the paper's C1 constants the DES uses.

#[path = "common.rs"]
mod common;

use std::time::Instant;

use layup::coordinator::Shared;
use layup::data;
use layup::model::ModelExec;
use layup::runtime::Runtime;

fn main() {
    let man = common::manifest();
    let reps = common::env_usize("LAYUP_STEPS", 15);
    println!("Table A4 (measured on this substrate): fwd/bwd wall time per step");
    println!("{:<16} {:>12} {:>12} {:>8}", "architecture", "fwd (ms)", "bwd (ms)", "bwd/fwd");
    common::hr();
    let mut csv = String::from("model,fwd_ms,bwd_ms,ratio\n");

    for model_name in ["mlpnet18", "mlpnet50", "gpt_mini", "rnn_sentiment"] {
        if man.models.get(model_name).is_none() {
            continue;
        }
        let mut rt = Runtime::new().expect("runtime");
        let mut exec = ModelExec::load(&mut rt, &man, model_name).expect("load");
        let model = man.model(model_name).unwrap();
        let mut ds = data::build(model, 0, 1, 7).expect("dataset");
        let cfg = layup::config::TrainConfig::new(
            model_name,
            layup::config::Algorithm::LocalSgd,
            1,
            1,
        );
        let shared = Shared::new(&cfg, &man).expect("shared");
        let params = &shared.params[0];

        // warmup
        let b = ds.next_batch();
        let pass = exec.forward(params, &b).unwrap();
        exec.backward(params, &pass, &mut |_, _| {}).unwrap();

        let (mut fwd_s, mut bwd_s) = (0.0, 0.0);
        for _ in 0..reps {
            let b = ds.next_batch();
            let t0 = Instant::now();
            let pass = exec.forward(params, &b).unwrap();
            fwd_s += t0.elapsed().as_secs_f64();
            let t1 = Instant::now();
            exec.backward(params, &pass, &mut |_, _| {}).unwrap();
            bwd_s += t1.elapsed().as_secs_f64();
        }
        let (f, bw) = (1e3 * fwd_s / reps as f64, 1e3 * bwd_s / reps as f64);
        println!("{:<16} {:>12.2} {:>12.2} {:>8.2}", model_name, f, bw, bw / f);
        csv.push_str(&format!("{},{:.3},{:.3},{:.3}\n", model_name, f, bw, bw / f));
    }

    println!("\npaper constants used by the DES (Table A4, C1):");
    println!("  resnet18: fwd 4.9 ms, bwd 10.2 ms (ratio 2.08)");
    println!("  resnet50: fwd 16.6 ms, bwd 29.9 ms (ratio 1.80)");
    println!("\nSection 3.2 drift check: relative drift D = βT(L+1)/2 grows with depth:");
    for (l, beta_t) in [(8usize, 10.2e-3), (16, 29.9e-3)] {
        println!("  L={l:<3} βT={beta_t:.4}s  ->  D = {:.4}s", beta_t * (l as f64 + 1.0) / 2.0);
    }
    std::fs::write(common::results_dir().join("tableA4_timing.csv"), csv).unwrap();
    println!("\nwrote results/tableA4_timing.csv");
}
