//! **Table 1** — vision: convergence accuracy, TTC and epochs for all six
//! algorithms (paper: ResNet-50 on CIFAR-100/ImageNet-1k; here: the
//! MLPNet-50 analog on synthetic-100 — DESIGN.md substitution table).
//!
//! Two panels are produced:
//!  * measured accuracy/TTC on the live thread cluster (real gradients);
//!  * paper-scale TTC from the DES on C1 (3xA100) for both CIFAR-100 and
//!    ImageNet-1k ResNet-50 workloads.

#[path = "common.rs"]
mod common;

use layup::sim::{simulate, Cluster, SimAlgo, Workload};

fn main() {
    let man = common::manifest();
    let steps = common::env_usize("LAYUP_STEPS", 160);
    let mut csv = String::from("algorithm,accuracy_mean,accuracy_std,ttc_s_mean,ttc_s_std,epochs\n");

    println!("Table 1 (measured, thread cluster): mlpnet50 on synthetic-100, {} workers, {} steps",
             common::workers(), steps);
    println!("{:<14} {:>12} {:>12} {:>8}", "method", "conv acc", "TTC (s)", "epochs");
    common::hr();
    for algo in common::paper_algorithms() {
        let cfg = common::vision_cfg("mlpnet50", algo, steps);
        let runs = common::run_seeds(&cfg, &man);
        let accs: Vec<f64> = runs.iter().map(|r| r.curve.best_accuracy()).collect();
        let ttcs: Vec<f64> = runs
            .iter()
            .map(|r| r.curve.time_to_convergence(0.01).unwrap_or(r.total_time_s))
            .collect();
        let (am, asd) = common::mean_std(&accs);
        let (tm, tsd) = common::mean_std(&ttcs);
        let epochs = runs[0].epochs;
        println!("{:<14} {:>7.2}±{:<4.2} {:>7.1}±{:<4.1} {:>8}",
                 runs[0].algorithm, 100.0 * am, 100.0 * asd, tm, tsd, epochs);
        csv.push_str(&format!("{},{:.4},{:.4},{:.2},{:.2},{}\n",
            runs[0].algorithm, am, asd, tm, tsd, epochs));
    }

    println!("\nTable 1 (paper-scale TTC shape, DES):");
    for (label, cluster, w) in [
        ("CIFAR-100/ResNet-50 @C1", Cluster::c1(), Workload::resnet50_cifar(3)),
        ("ImageNet-1k/ResNet-50 @C1", Cluster::c1(), Workload::resnet50_imagenet(3)),
    ] {
        println!("  {label}");
        println!("  {:<12} {:>12} {:>9}", "method", "TTC (s)", "MFU");
        for algo in SimAlgo::paper_set(12) {
            let r = simulate(&cluster, &w, algo, 1);
            println!("  {:<12} {:>12.0} {:>8.1}%", r.algo, r.wall_s, 100.0 * r.mfu);
        }
    }

    std::fs::write(common::results_dir().join("table1_vision.csv"), csv).unwrap();
    println!("\nwrote results/table1_vision.csv");
}
