//! **Fig FB-ratio** — decoupled forward/backward pools vs the serial loop:
//! sweep fwd:bwd thread ratios {1:1, 2:1, 3:1} and report steps/s, achieved
//! FLOP/s (the MFU numerator — divide by the serial run's peak for MFU) and
//! the per-pool occupancy split. Backs EXPERIMENTS.md §Perf.
//!
//! Knobs: LAYUP_WORKERS (default 3), LAYUP_STEPS (default 40).

#[path = "common.rs"]
mod common;

use layup::config::Algorithm;

fn main() {
    let man = common::manifest();
    let model = "mlpnet18";
    let steps = common::env_usize("LAYUP_STEPS", 40);
    let workers = common::workers();

    println!(
        "fwd:bwd thread-ratio sweep — LayUp, {workers} workers, {steps} steps/worker"
    );
    println!(
        "{:<14} {:>9} {:>12} {:>9} {:>9} {:>8} {:>8}",
        "mode", "steps/s", "FLOP/s", "fwd occ", "bwd occ", "q-depth", "blocked"
    );
    common::hr();
    let mut csv = String::from(
        "mode,fwd_threads,bwd_threads,steps_per_s,achieved_flops_per_s,\
         fwd_occupancy,bwd_occupancy,queue_depth_mean,queue_blocked_frac\n",
    );

    let mut base = common::vision_cfg(model, Algorithm::LayUp, steps);
    base.eval_every = usize::MAX / 2; // measurement window excludes eval

    let mut summary_rows = Vec::new();
    // serial baseline: the original interlocked fwd->bwd loop
    let serial = common::run_one(&base, &man);
    summary_rows.push(common::summary_row("serial", &serial));
    let serial_sps = serial.total_steps as f64 / serial.total_time_s;
    println!(
        "{:<14} {:>9.2} {:>12.3e} {:>8.1}% {:>8.1}% {:>8} {:>8}",
        "serial",
        serial_sps,
        serial.stats.achieved_flops_per_s,
        100.0 * serial.stats.fwd_occupancy,
        100.0 * serial.stats.bwd_occupancy,
        "-",
        "-"
    );
    csv.push_str(&format!(
        "serial,1,1,{:.4},{:.6e},{:.4},{:.4},,\n",
        serial_sps,
        serial.stats.achieved_flops_per_s,
        serial.stats.fwd_occupancy,
        serial.stats.bwd_occupancy,
    ));

    let mut best = (0.0f64, (1usize, 1usize));
    for (f, b) in [(1usize, 1usize), (2, 1), (3, 1)] {
        let mut cfg = base.clone();
        cfg.decoupled = true;
        cfg.fwd_threads = f;
        cfg.bwd_threads = b;
        cfg.queue_depth = 2 * f;
        let r = common::run_one(&cfg, &man);
        summary_rows.push(common::summary_row(&format!("decoupled-{f}-{b}"), &r));
        let sps = r.total_steps as f64 / r.total_time_s;
        if sps > best.0 {
            best = (sps, (f, b));
        }
        let label = format!("decoupled {f}:{b}");
        println!(
            "{:<14} {:>9.2} {:>12.3e} {:>8.1}% {:>8.1}% {:>8.2} {:>7.1}%",
            label,
            sps,
            r.stats.achieved_flops_per_s,
            100.0 * r.stats.fwd_occupancy,
            100.0 * r.stats.bwd_occupancy,
            r.stats.queue.mean_depth(),
            100.0 * r.stats.queue.blocked_frac(),
        );
        csv.push_str(&format!(
            "decoupled,{f},{b},{:.4},{:.6e},{:.4},{:.4},{:.4},{:.4}\n",
            sps,
            r.stats.achieved_flops_per_s,
            r.stats.fwd_occupancy,
            r.stats.bwd_occupancy,
            r.stats.queue.mean_depth(),
            r.stats.queue.blocked_frac(),
        ));
    }

    common::hr();
    let (sps, (f, b)) = best;
    println!(
        "best decoupled ratio {f}:{b} — {:.2}x the serial baseline ({:.2} vs {:.2} steps/s)",
        sps / serial_sps,
        sps,
        serial_sps
    );

    let out = common::results_dir().join("fig_fb_ratio.csv");
    std::fs::write(&out, csv).expect("writing csv");
    common::write_bench_summary("fig_fb_ratio", summary_rows);
    println!("wrote {}", out.display());
}
