//! **Staleness policies under fabric delay** — the per-layer staleness-clock
//! experiment: sweep simulated link delay × update policy
//! {plain, dc, adaptive} for LayUp, with AD-PSGD under {plain, dc} as the
//! symmetric-gossip baseline.
//!
//! Each cell trains the same workload for the same step budget; the table
//! reports loss-at-budget (best eval loss within the budget), the fabric's
//! delivered staleness in steps, and the per-layer observed τ the staleness
//! clocks measured at gradient-apply time. The claim under test: once
//! delivered staleness is large (≥50 steps of delay), the
//! delay-compensated (`dc`) and staleness-adaptive (`adaptive`) arms beat
//! plain LayUp on loss-at-budget.
//!
//! Environment knobs:
//!   LAYUP_LATENCIES  comma-separated one-way seconds (default 0,0.05,0.2)
//!   LAYUP_DC_LAMBDA  DC-ASGD λ (default 0.04)
//!   LAYUP_MIX_BETA   adaptive attenuation β (default 0.5)
//!   LAYUP_STEPS / LAYUP_WORKERS as usual

#[path = "common.rs"]
mod common;

use layup::comm::{FabricSpec, LatencyDist};
use layup::config::{Algorithm, Compensation, Mixing};
use layup::metrics::STALENESS_BUCKET_LABELS;
use layup::util::json::{arr, num, obj, s, Json};

/// One policy arm: how the staleness knobs are set on top of the base run.
struct Arm {
    name: &'static str,
    compensation: Compensation,
    mixing: Mixing,
}

fn main() {
    let man = common::manifest();
    let steps = common::env_usize("LAYUP_STEPS", 48);
    let latencies = common::env_latencies("0,0.05,0.2");
    let dc_lambda = common::env_f64("LAYUP_DC_LAMBDA", 0.04) as f32;
    let mix_beta = common::env_f64("LAYUP_MIX_BETA", 0.5) as f32;

    let layup_arms = [
        Arm { name: "plain", compensation: Compensation::None, mixing: Mixing::Fixed },
        Arm { name: "dc", compensation: Compensation::Dc, mixing: Mixing::Fixed },
        Arm { name: "adaptive", compensation: Compensation::None, mixing: Mixing::Adaptive },
    ];
    let adpsgd_arms = [
        Arm { name: "plain", compensation: Compensation::None, mixing: Mixing::Fixed },
        Arm { name: "dc", compensation: Compensation::Dc, mixing: Mixing::Fixed },
    ];

    println!(
        "fig: staleness policies — mlpnet18, {} workers, {} steps, λ={dc_lambda}, β={mix_beta}",
        common::workers(),
        steps
    );
    common::hr();
    println!(
        "{:<10} {:<9} {:>9} {:>9} {:>11} {:>10} {:>9} {:>8}",
        "algorithm", "policy", "lat (ms)", "wall (s)", "loss@budget", "delivered", "tau mean",
        "tau max"
    );
    let mut rows: Vec<Json> = Vec::new();
    let mut summary_rows: Vec<Json> = Vec::new();
    let mut csv = String::from(
        "algorithm,policy,latency_s,wall_s,loss_at_budget,mean_delivered_staleness,\
         stale_tau_mean,stale_tau_max,hist_labels,hist_total\n",
    );
    for (algo, arms) in [
        (Algorithm::LayUp, &layup_arms[..]),
        (Algorithm::AdPsgd, &adpsgd_arms[..]),
    ] {
        for arm in arms {
            for &lat in &latencies {
                let mut cfg = common::vision_cfg("mlpnet18", algo, steps);
                cfg.eval_every = (steps / 6).max(1);
                cfg.staleness.compensation = arm.compensation;
                cfg.staleness.dc_lambda = dc_lambda;
                cfg.staleness.mixing = arm.mixing;
                cfg.staleness.mix_beta = mix_beta;
                cfg.fabric = FabricSpec::Sim {
                    latency: LatencyDist::Constant(lat),
                    bandwidth_bytes_per_s: 0.0,
                    drop_prob: 0.0,
                };
                let sum = common::run_one(&cfg, &man);
                let stale = &sum.stats.staleness;
                let comm = &sum.stats.comm;
                let loss = sum.curve.best_loss();
                println!(
                    "{:<10} {:<9} {:>9.1} {:>9.2} {:>11.4} {:>10.2} {:>9.2} {:>8}",
                    sum.algorithm,
                    arm.name,
                    1e3 * lat,
                    sum.total_time_s,
                    loss,
                    comm.mean_delivered_staleness(),
                    stale.mean_tau(),
                    stale.max_tau()
                );
                // aggregate τ histogram over layers (stable label order)
                let mut hist = [0u64; layup::metrics::STALENESS_BUCKETS];
                for l in &stale.layers {
                    for (b, &c) in l.hist.iter().enumerate() {
                        hist[b] += c;
                    }
                }
                csv.push_str(&format!(
                    "{},{},{},{:.3},{:.5},{:.3},{:.3},{},{},{}\n",
                    sum.algorithm,
                    arm.name,
                    lat,
                    sum.total_time_s,
                    loss,
                    comm.mean_delivered_staleness(),
                    stale.mean_tau(),
                    stale.max_tau(),
                    STALENESS_BUCKET_LABELS.join(";"),
                    hist.map(|c| c.to_string()).join(";"),
                ));
                rows.push(obj(vec![
                    ("algorithm", s(&sum.algorithm)),
                    ("policy", s(arm.name)),
                    ("latency_s", num(lat)),
                    ("wall_s", num(sum.total_time_s)),
                    ("loss_at_budget", num(loss)),
                    ("mean_delivered_staleness", num(comm.mean_delivered_staleness())),
                    ("stale_tau_mean", num(stale.mean_tau())),
                    ("stale_tau_max", num(stale.max_tau() as f64)),
                    (
                        "tau_hist",
                        arr(hist.iter().map(|&c| num(c as f64)).collect()),
                    ),
                ]));
                summary_rows.push(common::summary_row(
                    &format!("{}-{}-{}ms", sum.algorithm, arm.name, (1e3 * lat) as u64),
                    &sum,
                ));
            }
            common::hr();
        }
    }
    let dir = common::results_dir();
    std::fs::write(dir.join("fig_staleness.csv"), csv).expect("write csv");
    std::fs::write(dir.join("fig_staleness.json"), arr(rows).dump()).expect("write json");
    common::write_bench_summary("fig_staleness", summary_rows);
    println!("wrote results/fig_staleness.csv and .json");
}
