//! **Table 2** — time-to-accuracy (TTA): wall-clock and steps until each
//! algorithm first reaches a fixed target accuracy, chosen (as in the paper)
//! as the best accuracy of the *worst* performing algorithm.

#[path = "common.rs"]
mod common;

fn main() {
    let man = common::manifest();
    let steps = common::env_usize("LAYUP_STEPS", 160);

    // run all algorithms once to find the target, reusing the runs for TTA
    let mut runs = Vec::new();
    for algo in common::paper_algorithms() {
        let cfg = common::vision_cfg("mlpnet50", algo, steps);
        runs.push(common::run_seeds(&cfg, &man));
    }
    let target = runs
        .iter()
        .map(|rs| {
            let accs: Vec<f64> = rs.iter().map(|r| r.curve.best_accuracy()).collect();
            common::mean_std(&accs).0
        })
        .fold(f64::INFINITY, f64::min)
        * 0.98; // slight slack so every algorithm can reach it

    println!(
        "Table 2 (measured): TTA to {:.2}% on mlpnet50/synthetic-100, {} workers",
        100.0 * target,
        common::workers()
    );
    println!("{:<14} {:>12} {:>10}", "method", "TTA (s)", "steps");
    common::hr();
    let mut csv = String::from("algorithm,target,tta_s_mean,tta_s_std,steps\n");
    for rs in &runs {
        let ttas: Vec<f64> = rs
            .iter()
            .map(|r| r.curve.time_to_accuracy(target).unwrap_or(f64::NAN))
            .collect();
        let steps_to: Vec<f64> = rs
            .iter()
            .map(|r| r.curve.step_to_accuracy(target).map(|s| s as f64).unwrap_or(f64::NAN))
            .collect();
        let (tm, tsd) = common::mean_std(&ttas);
        let (sm, _) = common::mean_std(&steps_to);
        println!("{:<14} {:>7.1}±{:<4.1} {:>10.0}", rs[0].algorithm, tm, tsd, sm);
        csv.push_str(&format!("{},{:.4},{:.2},{:.2},{:.0}\n", rs[0].algorithm, target, tm, tsd, sm));
    }
    std::fs::write(common::results_dir().join("table2_tta.csv"), csv).unwrap();
    println!("\nwrote results/table2_tta.csv");
}
