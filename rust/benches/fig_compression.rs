//! **Compression protocol** — the codec sweep: LayUp vs GoSGD vs ASGD-PS
//! under `dense`, `topk:K` and `int8` wire codecs, crossed with a
//! bandwidth-constrained simulated fabric.
//!
//! Every run shares one workload and step budget; the fabric meters
//! **encoded** wire bytes (`Payload::encoded_len`), so a sparsifying codec
//! shows up twice: directly in `comm_bytes`, and indirectly as wall-clock
//! wins once the link bandwidth makes serialization delay the bottleneck.
//! The paper-relevant row is `bytes_reduction_vs_dense` — top-k with
//! error feedback holds the loss curve while cutting wire traffic by
//! roughly `4K/8` (sparse coords cost 8 bytes against 4 dense).
//!
//! Exit is non-zero when any non-dense run fails to reduce bytes at all —
//! the CI compression-smoke job relies on this (and separately asserts the
//! ≥4x top-k floor from bench_summary.json).
//!
//! Environment knobs:
//!   LAYUP_CODECS           comma-separated specs (default dense,topk:16,int8)
//!   LAYUP_BANDWIDTHS_MBPS  link bandwidth sweep (default 40,400)
//!   LAYUP_STEPS / LAYUP_WORKERS / LAYUP_ALGOS as usual

#[path = "common.rs"]
mod common;

use layup::comm::{CodecSpec, FabricSpec, LatencyDist};
use layup::config::{Algorithm, TrainConfig};
use layup::metrics::RunSummary;
use layup::topology::roles::TopologySpec;
use layup::util::json::{arr, num, obj, s, Json};

/// The compression row: the stable `summary_row` vocabulary plus the wire
/// accounting this bench exists to track (append-only, like the base row).
fn codec_row(label: &str, codec: &CodecSpec, mbps: f64, reduction: f64, sum: &RunSummary) -> Json {
    let mut row = match common::summary_row(label, sum) {
        Json::Obj(m) => m,
        _ => unreachable!("summary_row returns an object"),
    };
    row.insert("codec".into(), s(&codec.name()));
    row.insert("bandwidth_mbps".into(), num(mbps));
    row.insert("comm_bytes".into(), num(sum.stats.comm.bytes_sent as f64));
    row.insert("bytes_reduction_vs_dense".into(), num(reduction));
    Json::Obj(row)
}

fn env_codecs() -> Vec<CodecSpec> {
    std::env::var("LAYUP_CODECS")
        .unwrap_or_else(|_| "dense,topk:16,int8".into())
        .split(',')
        .filter(|t| !t.trim().is_empty())
        .map(|t| CodecSpec::parse(t.trim()).unwrap_or_else(|e| panic!("LAYUP_CODECS: {e:#}")))
        .collect()
}

fn env_bandwidths() -> Vec<f64> {
    std::env::var("LAYUP_BANDWIDTHS_MBPS")
        .unwrap_or_else(|_| "40,400".into())
        .split(',')
        .filter(|t| !t.trim().is_empty())
        .map(|t| t.trim().parse().expect("LAYUP_BANDWIDTHS_MBPS: bad Mbit/s value"))
        .collect()
}

fn main() {
    let man = common::manifest();
    let steps = common::env_usize("LAYUP_STEPS", 48);
    let workers = common::workers();
    let codecs = env_codecs();
    let bandwidths = env_bandwidths();
    assert!(workers > 2, "asgd-ps needs at least 2 trainers: LAYUP_WORKERS={workers}");

    let cases: Vec<(&str, Algorithm, TopologySpec)> = vec![
        ("layup", Algorithm::LayUp, TopologySpec::Flat),
        ("gosgd", Algorithm::GoSgd, TopologySpec::Flat),
        ("asgd-ps", Algorithm::AsgdPs, TopologySpec::Ps { shards: 1 }),
    ];

    println!("fig: compression protocol — mlpnet18, {workers} workers, {steps} steps");
    common::hr();
    println!(
        "{:<10} {:<8} {:>8} {:>9} {:>10} {:>12} {:>9}",
        "algorithm", "codec", "bw Mb/s", "wall (s)", "loss@bud", "comm bytes", "vs dense"
    );

    let mut summary_rows: Vec<Json> = Vec::new();
    let mut rows: Vec<Json> = Vec::new();
    let mut csv = String::from("algorithm,codec,bandwidth_mbps,wall_s,final_loss,comm_bytes\n");
    let mut no_reduction = false;

    for (label, algorithm, cluster) in cases {
        // dense baseline bytes per bandwidth point, set by the first codec
        // of each bandwidth loop when the sweep includes "dense"
        let mut dense_bytes: Vec<(u64, u64)> = Vec::new();
        for &mbps in &bandwidths {
            for codec in &codecs {
                let mut cfg: TrainConfig = common::vision_cfg("mlpnet18", algorithm, steps);
                cfg.cluster = cluster;
                cfg.codec = codec.clone();
                cfg.eval_every = (steps / 6).max(1);
                cfg.fabric = FabricSpec::Sim {
                    latency: LatencyDist::Constant(0.002),
                    bandwidth_bytes_per_s: mbps * 125_000.0,
                    drop_prob: 0.01,
                };
                let sum = common::run_one(&cfg, &man);
                let final_loss = sum.curve.points.last().map(|p| p.loss).unwrap_or(f64::NAN);
                let bytes = sum.stats.comm.bytes_sent;
                if codec.is_dense() {
                    dense_bytes.push((mbps.to_bits(), bytes));
                }
                let baseline = dense_bytes
                    .iter()
                    .find(|(b, _)| *b == mbps.to_bits())
                    .map(|&(_, v)| v);
                let reduction = match baseline {
                    Some(d) if bytes > 0 => d as f64 / bytes as f64,
                    _ => f64::NAN,
                };
                if !codec.is_dense() && reduction.is_finite() && reduction < 1.0 {
                    no_reduction = true;
                }
                println!(
                    "{:<10} {:<8} {:>8} {:>9.2} {:>10.4} {:>12} {:>9}",
                    label,
                    codec.name(),
                    mbps,
                    sum.total_time_s,
                    final_loss,
                    bytes,
                    if reduction.is_finite() { format!("{reduction:.2}x") } else { "-".into() },
                );
                csv.push_str(&format!(
                    "{label},{},{mbps},{:.3},{final_loss:.5},{bytes}\n",
                    codec.name(),
                    sum.total_time_s,
                ));
                rows.push(obj(vec![
                    ("algorithm", s(label)),
                    ("codec", s(&codec.name())),
                    ("bandwidth_mbps", num(mbps)),
                    ("wall_s", num(sum.total_time_s)),
                    ("final_loss", num(final_loss)),
                    ("comm_bytes", num(bytes as f64)),
                    (
                        "bytes_reduction_vs_dense",
                        if reduction.is_finite() { num(reduction) } else { Json::Null },
                    ),
                ]));
                let row_label = format!("{label}-{}-bw{mbps}", codec.name().replace(':', ""));
                summary_rows.push(codec_row(&row_label, codec, mbps, reduction, &sum));
            }
        }
        common::hr();
    }

    let dir = common::results_dir();
    std::fs::write(dir.join("fig_compression.json"), arr(rows).dump()).expect("write json");
    std::fs::write(dir.join("fig_compression.csv"), csv).expect("write csv");
    common::write_bench_summary("fig_compression", summary_rows);
    println!("wrote results/fig_compression.json");
    if no_reduction {
        eprintln!("FAIL: a non-dense codec inflated wire bytes over the dense baseline");
        std::process::exit(1);
    }
}
