//! **Compression protocol** — the codec sweep: LayUp vs GoSGD vs ASGD-PS
//! under `dense`, `topk:K` and `int8` wire codecs, crossed with a
//! bandwidth-constrained simulated fabric.
//!
//! Every run shares one workload and step budget; the fabric meters
//! **encoded** wire bytes (`Payload::encoded_len`), so a sparsifying codec
//! shows up twice: directly in `comm_bytes`, and indirectly as wall-clock
//! wins once the link bandwidth makes serialization delay the bottleneck.
//! The paper-relevant row is `bytes_reduction_vs_dense` — top-k with
//! error feedback holds the loss curve while cutting wire traffic by
//! roughly `4K/8` (sparse coords cost 8 bytes against 4 dense).
//!
//! After the base sweep, the LayUp rows are re-run with **step-frame
//! coalescing** on (`[fabric] coalesce = true`): one `StepFrame` per step
//! per link instead of one message per layer, headers amortized, and the
//! top-k codec ranking the step's coordinates globally across layers
//! instead of per layer. Those rows carry `coalesce`, `frames_per_step`,
//! `header_bytes_saved`, `msg_reduction_vs_uncoalesced` and
//! `loss_delta_vs_uncoalesced` (global-vs-per-layer top-k selection).
//!
//! Exit is non-zero when any non-dense run fails to reduce bytes at all,
//! when a coalesced run reduces wire messages by less than half the mean
//! frame width (`L/2` for an `L`-layer model), or when coalescing inflates
//! wire bytes — the CI compression-smoke job relies on this (and
//! separately asserts the ≥4x top-k floor from bench_summary.json).
//!
//! Environment knobs:
//!   LAYUP_CODECS           comma-separated specs (default dense,topk:16,int8)
//!   LAYUP_BANDWIDTHS_MBPS  link bandwidth sweep (default 40,400)
//!   LAYUP_STEPS / LAYUP_WORKERS / LAYUP_ALGOS as usual

#[path = "common.rs"]
mod common;

use layup::comm::{CodecSpec, FabricSpec, LatencyDist};
use layup::config::{Algorithm, TrainConfig};
use layup::metrics::RunSummary;
use layup::topology::roles::TopologySpec;
use layup::util::json::{arr, num, obj, s, Json};

/// The compression row: the stable `summary_row` vocabulary plus the wire
/// accounting this bench exists to track (append-only, like the base row).
fn codec_row(label: &str, codec: &CodecSpec, mbps: f64, reduction: f64, sum: &RunSummary) -> Json {
    let mut row = match common::summary_row(label, sum) {
        Json::Obj(m) => m,
        _ => unreachable!("summary_row returns an object"),
    };
    row.insert("codec".into(), s(&codec.name()));
    row.insert("bandwidth_mbps".into(), num(mbps));
    row.insert("comm_bytes".into(), num(sum.stats.comm.bytes_sent as f64));
    row.insert("bytes_reduction_vs_dense".into(), num(reduction));
    Json::Obj(row)
}

fn env_codecs() -> Vec<CodecSpec> {
    std::env::var("LAYUP_CODECS")
        .unwrap_or_else(|_| "dense,topk:16,int8".into())
        .split(',')
        .filter(|t| !t.trim().is_empty())
        .map(|t| CodecSpec::parse(t.trim()).unwrap_or_else(|e| panic!("LAYUP_CODECS: {e:#}")))
        .collect()
}

fn env_bandwidths() -> Vec<f64> {
    std::env::var("LAYUP_BANDWIDTHS_MBPS")
        .unwrap_or_else(|_| "40,400".into())
        .split(',')
        .filter(|t| !t.trim().is_empty())
        .map(|t| t.trim().parse().expect("LAYUP_BANDWIDTHS_MBPS: bad Mbit/s value"))
        .collect()
}

fn main() {
    let man = common::manifest();
    let steps = common::env_usize("LAYUP_STEPS", 48);
    let workers = common::workers();
    let codecs = env_codecs();
    let bandwidths = env_bandwidths();
    assert!(workers > 2, "asgd-ps needs at least 2 trainers: LAYUP_WORKERS={workers}");

    let cases: Vec<(&str, Algorithm, TopologySpec)> = vec![
        ("layup", Algorithm::LayUp, TopologySpec::Flat),
        ("gosgd", Algorithm::GoSgd, TopologySpec::Flat),
        ("asgd-ps", Algorithm::AsgdPs, TopologySpec::Ps { shards: 1 }),
    ];

    println!("fig: compression protocol — mlpnet18, {workers} workers, {steps} steps");
    common::hr();
    println!(
        "{:<10} {:<8} {:>8} {:>9} {:>10} {:>12} {:>9}",
        "algorithm", "codec", "bw Mb/s", "wall (s)", "loss@bud", "comm bytes", "vs dense"
    );

    let mut summary_rows: Vec<Json> = Vec::new();
    let mut rows: Vec<Json> = Vec::new();
    let mut csv = String::from("algorithm,codec,bandwidth_mbps,wall_s,final_loss,comm_bytes\n");
    let mut no_reduction = false;
    // LayUp base-sweep stats keyed (bandwidth bits, codec name): the
    // uncoalesced side of the coalesce comparison below
    let mut layup_base: Vec<((u64, String), (u64, u64, f64))> = Vec::new();

    for (label, algorithm, cluster) in cases {
        // dense baseline bytes per bandwidth point, set by the first codec
        // of each bandwidth loop when the sweep includes "dense"
        let mut dense_bytes: Vec<(u64, u64)> = Vec::new();
        for &mbps in &bandwidths {
            for codec in &codecs {
                let mut cfg: TrainConfig = common::vision_cfg("mlpnet18", algorithm, steps);
                cfg.cluster = cluster;
                cfg.codec = codec.clone();
                cfg.eval_every = (steps / 6).max(1);
                cfg.fabric = FabricSpec::Sim {
                    latency: LatencyDist::Constant(0.002),
                    bandwidth_bytes_per_s: mbps * 125_000.0,
                    drop_prob: 0.01,
                };
                let sum = common::run_one(&cfg, &man);
                let final_loss = sum.curve.points.last().map(|p| p.loss).unwrap_or(f64::NAN);
                let bytes = sum.stats.comm.bytes_sent;
                if codec.is_dense() {
                    dense_bytes.push((mbps.to_bits(), bytes));
                }
                if label == "layup" {
                    layup_base.push((
                        (mbps.to_bits(), codec.name()),
                        (sum.stats.comm.msgs_sent, bytes, final_loss),
                    ));
                }
                let baseline = dense_bytes
                    .iter()
                    .find(|(b, _)| *b == mbps.to_bits())
                    .map(|&(_, v)| v);
                let reduction = match baseline {
                    Some(d) if bytes > 0 => d as f64 / bytes as f64,
                    _ => f64::NAN,
                };
                if !codec.is_dense() && reduction.is_finite() && reduction < 1.0 {
                    no_reduction = true;
                }
                println!(
                    "{:<10} {:<8} {:>8} {:>9.2} {:>10.4} {:>12} {:>9}",
                    label,
                    codec.name(),
                    mbps,
                    sum.total_time_s,
                    final_loss,
                    bytes,
                    if reduction.is_finite() { format!("{reduction:.2}x") } else { "-".into() },
                );
                csv.push_str(&format!(
                    "{label},{},{mbps},{:.3},{final_loss:.5},{bytes}\n",
                    codec.name(),
                    sum.total_time_s,
                ));
                rows.push(obj(vec![
                    ("algorithm", s(label)),
                    ("codec", s(&codec.name())),
                    ("bandwidth_mbps", num(mbps)),
                    ("wall_s", num(sum.total_time_s)),
                    ("final_loss", num(final_loss)),
                    ("comm_bytes", num(bytes as f64)),
                    (
                        "bytes_reduction_vs_dense",
                        if reduction.is_finite() { num(reduction) } else { Json::Null },
                    ),
                ]));
                let row_label = format!("{label}-{}-bw{mbps}", codec.name().replace(':', ""));
                summary_rows.push(codec_row(&row_label, codec, mbps, reduction, &sum));
            }
        }
        common::hr();
    }

    // --- step-frame coalescing sweep: the LayUp rows again, coalesce on ---
    // one StepFrame per step per link instead of one message per layer;
    // compared against the uncoalesced LayUp runs captured above
    println!("layup + step-frame coalescing ([fabric] coalesce = true)");
    common::hr();
    println!(
        "{:<10} {:<8} {:>8} {:>9} {:>10} {:>12} {:>9} {:>9}",
        "algorithm", "codec", "bw Mb/s", "wall (s)", "loss@bud", "comm bytes", "msgs cut", "frm/step"
    );
    let mut no_coalesce_win = false;
    for &mbps in &bandwidths {
        for codec in &codecs {
            let mut cfg: TrainConfig = common::vision_cfg("mlpnet18", Algorithm::LayUp, steps);
            cfg.cluster = TopologySpec::Flat;
            cfg.codec = codec.clone();
            cfg.coalesce = true;
            cfg.eval_every = (steps / 6).max(1);
            cfg.fabric = FabricSpec::Sim {
                latency: LatencyDist::Constant(0.002),
                bandwidth_bytes_per_s: mbps * 125_000.0,
                drop_prob: 0.01,
            };
            let sum = common::run_one(&cfg, &man);
            let final_loss = sum.curve.points.last().map(|p| p.loss).unwrap_or(f64::NAN);
            let comm = &sum.stats.comm;
            let frames = comm.frames_sent;
            let mean_layers =
                if frames > 0 { comm.frame_layers as f64 / frames as f64 } else { 0.0 };
            // each frame pays one 32-byte wire header plus a 24-byte entry
            // per layer instead of a 32-byte header per layer: 8L - 32 saved
            let header_saved = (8 * comm.frame_layers).saturating_sub(32 * frames);
            let frames_per_step = frames as f64 / sum.total_steps.max(1) as f64;
            let base = layup_base
                .iter()
                .find(|((b, c), _)| *b == mbps.to_bits() && *c == codec.name())
                .map(|&(_, v)| v);
            let (msg_reduction, loss_delta, bytes_ok) = match base {
                Some((m0, b0, l0)) if comm.msgs_sent > 0 => (
                    m0 as f64 / comm.msgs_sent as f64,
                    final_loss - l0,
                    comm.bytes_sent <= b0,
                ),
                _ => (f64::NAN, f64::NAN, false),
            };
            // the coalescing contract: a step's L layer pushes collapse to
            // ~1 frame, so wire messages must shrink by at least L/2, and
            // the frame encoding must never inflate bytes over standalone
            // pushes of the same mass
            if frames == 0
                || !(msg_reduction.is_finite() && msg_reduction >= mean_layers / 2.0)
                || !bytes_ok
            {
                no_coalesce_win = true;
            }
            println!(
                "{:<10} {:<8} {:>8} {:>9.2} {:>10.4} {:>12} {:>9} {:>9.2}",
                "layup",
                codec.name(),
                mbps,
                sum.total_time_s,
                final_loss,
                comm.bytes_sent,
                if msg_reduction.is_finite() {
                    format!("{msg_reduction:.1}x")
                } else {
                    "-".into()
                },
                frames_per_step,
            );
            csv.push_str(&format!(
                "layup+frames,{},{mbps},{:.3},{final_loss:.5},{}\n",
                codec.name(),
                sum.total_time_s,
                comm.bytes_sent,
            ));
            rows.push(obj(vec![
                ("algorithm", s("layup")),
                ("codec", s(&codec.name())),
                ("bandwidth_mbps", num(mbps)),
                ("coalesce", Json::Bool(true)),
                ("wall_s", num(sum.total_time_s)),
                ("final_loss", num(final_loss)),
                ("comm_bytes", num(comm.bytes_sent as f64)),
                ("comm_msgs", num(comm.msgs_sent as f64)),
                ("frames_per_step", num(frames_per_step)),
                ("mean_frame_layers", num(mean_layers)),
                ("header_bytes_saved", num(header_saved as f64)),
                (
                    "msg_reduction_vs_uncoalesced",
                    if msg_reduction.is_finite() { num(msg_reduction) } else { Json::Null },
                ),
                // global top-k (one ranking across the step) vs the
                // uncoalesced per-layer selection at the same budget
                (
                    "loss_delta_vs_uncoalesced",
                    if loss_delta.is_finite() { num(loss_delta) } else { Json::Null },
                ),
            ]));
            // vs-dense reduction for the summary row: against the
            // UNCOALESCED dense LayUp baseline, so the column stays
            // comparable across the whole sweep
            let dense_base = layup_base
                .iter()
                .find(|((b, c), _)| *b == mbps.to_bits() && c.as_str() == "dense")
                .map(|&(_, (_, b0, _))| b0);
            let reduction = match dense_base {
                Some(d) if comm.bytes_sent > 0 => d as f64 / comm.bytes_sent as f64,
                _ => f64::NAN,
            };
            let row_label = format!("layup-frames-{}-bw{mbps}", codec.name().replace(':', ""));
            let mut srow = match codec_row(&row_label, codec, mbps, reduction, &sum) {
                Json::Obj(m) => m,
                _ => unreachable!("codec_row returns an object"),
            };
            srow.insert(
                "bytes_reduction_vs_dense".into(),
                if reduction.is_finite() { num(reduction) } else { Json::Null },
            );
            srow.insert("coalesce".into(), Json::Bool(true));
            srow.insert("frames_per_step".into(), num(frames_per_step));
            srow.insert("mean_frame_layers".into(), num(mean_layers));
            srow.insert("header_bytes_saved".into(), num(header_saved as f64));
            srow.insert("comm_msgs".into(), num(comm.msgs_sent as f64));
            srow.insert(
                "msg_reduction_vs_uncoalesced".into(),
                if msg_reduction.is_finite() { num(msg_reduction) } else { Json::Null },
            );
            summary_rows.push(Json::Obj(srow));
        }
    }
    common::hr();

    let dir = common::results_dir();
    std::fs::write(dir.join("fig_compression.json"), arr(rows).dump()).expect("write json");
    std::fs::write(dir.join("fig_compression.csv"), csv).expect("write csv");
    common::write_bench_summary("fig_compression", summary_rows);
    println!("wrote results/fig_compression.json");
    if no_reduction {
        eprintln!("FAIL: a non-dense codec inflated wire bytes over the dense baseline");
        std::process::exit(1);
    }
    if no_coalesce_win {
        eprintln!(
            "FAIL: step-frame coalescing shipped no frames, cut wire messages by less \
             than L/2, or inflated wire bytes over the uncoalesced run"
        );
        std::process::exit(1);
    }
}
