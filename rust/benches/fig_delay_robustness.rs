//! **Delay robustness** — the paper's headline claim, now measurable: LayUp
//! vs the synchronous (DDP) and symmetric-gossip (AD-PSGD) baselines across
//! simulated link latencies on the `SimFabric` transport.
//!
//! Every configuration runs the same workload; the table reports wall time,
//! slowdown vs that algorithm's zero-extra-latency run, best loss, and the
//! delivered-staleness the fabric measured. DDP pays each link round-trip at
//! every barrier; LayUp's updater threads overlap transit with compute, so
//! its slowdown curve stays flat — the "up to 5.95x faster in the presence
//! of delays" separation.
//!
//! Environment knobs:
//!   LAYUP_LATENCIES  comma-separated one-way seconds (default 0,0.001,0.005,0.02)
//!   LAYUP_DROP       gossip drop probability (default 0; barrier traffic is reliable)
//!   LAYUP_STEPS / LAYUP_WORKERS / LAYUP_ALGOS as usual

#[path = "common.rs"]
mod common;

use layup::comm::{FabricSpec, LatencyDist};
use layup::config::Algorithm;
use layup::util::json::{arr, num, obj, s, Json};

fn main() {
    let man = common::manifest();
    let steps = common::env_usize("LAYUP_STEPS", 48);
    let latencies = common::env_latencies("0,0.001,0.005,0.02");
    let drop_prob: f64 = std::env::var("LAYUP_DROP")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0.0);
    let algos: Vec<Algorithm> = if std::env::var("LAYUP_ALGOS").is_ok() {
        common::paper_algorithms()
    } else {
        vec![Algorithm::LayUp, Algorithm::AdPsgd, Algorithm::Ddp]
    };

    println!(
        "fig: delay robustness — mlpnet18, {} workers, {} steps, drop {:.0}%",
        common::workers(),
        steps,
        100.0 * drop_prob
    );
    common::hr();
    println!(
        "{:<10} {:>9} {:>9} {:>9} {:>10} {:>10} {:>8}",
        "algorithm", "lat (ms)", "wall (s)", "slowdown", "best loss", "staleness", "dropped"
    );
    let mut rows: Vec<Json> = Vec::new();
    let mut summary_rows: Vec<Json> = Vec::new();
    let mut csv = String::from(
        "algorithm,latency_s,wall_s,slowdown,best_loss,mean_staleness,msgs_dropped,bytes_sent\n",
    );
    for algo in algos {
        let mut base_wall: Option<f64> = None;
        for &lat in &latencies {
            let mut cfg = common::vision_cfg("mlpnet18", algo, steps);
            cfg.eval_every = (steps / 6).max(1);
            cfg.fabric = FabricSpec::Sim {
                latency: LatencyDist::Constant(lat),
                bandwidth_bytes_per_s: 0.0,
                // collective (barrier) traffic is reliable by design; the
                // drop knob stresses the gossip algorithms only
                drop_prob: if algo.uses_barrier() { 0.0 } else { drop_prob },
            };
            let sum = common::run_one(&cfg, &man);
            let wall = sum.total_time_s;
            let base = *base_wall.get_or_insert(wall);
            let slowdown = wall / base.max(1e-9);
            let comm = &sum.stats.comm;
            println!(
                "{:<10} {:>9.1} {:>9.2} {:>8.2}x {:>10.4} {:>10.2} {:>8}",
                sum.algorithm,
                1e3 * lat,
                wall,
                slowdown,
                sum.curve.best_loss(),
                comm.mean_delivered_staleness(),
                comm.msgs_dropped
            );
            csv.push_str(&format!(
                "{},{},{:.3},{:.3},{:.5},{:.3},{},{}\n",
                sum.algorithm,
                lat,
                wall,
                slowdown,
                sum.curve.best_loss(),
                comm.mean_delivered_staleness(),
                comm.msgs_dropped,
                comm.bytes_sent
            ));
            rows.push(obj(vec![
                ("algorithm", s(&sum.algorithm)),
                ("latency_s", num(lat)),
                ("wall_s", num(wall)),
                ("slowdown", num(slowdown)),
                ("best_loss", num(sum.curve.best_loss())),
                ("mean_staleness", num(comm.mean_delivered_staleness())),
                ("msgs_dropped", num(comm.msgs_dropped as f64)),
                ("bytes_sent", num(comm.bytes_sent as f64)),
            ]));
            summary_rows.push(common::summary_row(
                &format!("{}-{}ms", sum.algorithm, (1e3 * lat) as u64),
                &sum,
            ));
        }
        common::hr();
    }
    let dir = common::results_dir();
    std::fs::write(dir.join("fig_delay_robustness.csv"), csv).expect("write csv");
    std::fs::write(dir.join("fig_delay_robustness.json"), arr(rows).dump()).expect("write json");
    common::write_bench_summary("fig_delay_robustness", summary_rows);
    println!("wrote results/fig_delay_robustness.csv and .json");
}
