//! **Fault tolerance** — the resilience subsystem's headline figure:
//! loss-vs-wallclock for LayUp vs AD-PSGD vs DDP under a chaos schedule.
//!
//! Three scenarios per algorithm on the same workload and seed:
//!
//! * `baseline`  — no faults;
//! * `restart`   — worker 1 crashes at `LAYUP_CRASH_STEP` and is respawned
//!   after `LAYUP_RESTART_S` seconds of downtime. Gossip algorithms re-enter
//!   from a live peer and barely notice; DDP's barrier holds the whole
//!   collective for the downtime (the Stall policy), which shows up as a
//!   wall-clock plateau in its curve;
//! * `crash`     — the same worker dies permanently. LayUp and AD-PSGD keep
//!   training on the survivors and reach their target loss; DDP waits until
//!   the supervisor reports the stall and stops the run.
//!
//! Output: `results/fig_fault_tolerance.csv` (one row per eval point —
//! the loss-vs-wallclock curves) and `results/fig_fault_tolerance.json`
//! (per-run summaries: wall, best loss, time to the target loss, crash /
//! join / stall accounting).
//!
//! Environment knobs: LAYUP_STEPS (default 60), LAYUP_WORKERS (default 3),
//! LAYUP_CRASH_STEP (default steps/4), LAYUP_RESTART_S (default 2),
//! LAYUP_STALL_TIMEOUT (default 8), LAYUP_TARGET_LOSS (default: 1.05x the
//! algorithm's baseline best).

#[path = "common.rs"]
mod common;

use layup::config::Algorithm;
use layup::metrics::RunSummary;
use layup::resilience::FaultPlan;
use layup::session::SessionBuilder;
use layup::util::json::{arr, num, obj, s, Json};

/// First wall-clock time the curve reaches `target` loss.
fn time_to_loss(summary: &RunSummary, target: f64) -> Option<f64> {
    summary.curve.points.iter().find(|p| p.loss <= target).map(|p| p.time_s)
}

fn main() {
    let man = common::manifest();
    let steps = common::env_usize("LAYUP_STEPS", 60);
    let crash_step = common::env_usize("LAYUP_CRASH_STEP", (steps / 4).max(1));
    let restart_s: f64 = std::env::var("LAYUP_RESTART_S")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(2.0);
    let stall_timeout: f64 = std::env::var("LAYUP_STALL_TIMEOUT")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(8.0);
    let target_override: Option<f64> =
        std::env::var("LAYUP_TARGET_LOSS").ok().and_then(|v| v.parse().ok());

    println!(
        "fig: fault tolerance — mlpnet18, {} workers, {} steps; worker 1 dies at step \
         {crash_step} (restart after {restart_s}s / never)",
        common::workers(),
        steps
    );
    common::hr();
    println!(
        "{:<10} {:<9} {:>9} {:>10} {:>11} {:>7} {:>6} {:>8}",
        "algorithm", "scenario", "wall (s)", "best loss", "t@target", "crashes", "joins", "stalled"
    );

    let scenarios: [(&str, Option<FaultPlan>); 3] = [
        ("baseline", None),
        ("restart", Some(FaultPlan::default().crash_restart(1, crash_step, restart_s))),
        ("crash", Some(FaultPlan::default().crash(1, crash_step))),
    ];
    let mut rows: Vec<Json> = Vec::new();
    let mut csv = String::from("algorithm,scenario,step,time_s,loss,accuracy\n");
    for algo in [Algorithm::LayUp, Algorithm::AdPsgd, Algorithm::Ddp] {
        let mut target = target_override;
        for (scenario, faults) in &scenarios {
            let mut cfg = common::vision_cfg("mlpnet18", algo, steps);
            cfg.eval_every = (steps / 12).max(1);
            cfg.stall_timeout_s = stall_timeout;
            if let Some(plan) = faults {
                cfg.faults = plan.clone();
            }
            let sum = SessionBuilder::new(cfg)
                .build(&man)
                .expect("invalid bench config")
                .run()
                .expect("run failed");
            if target.is_none() {
                // the algorithm's own fault-free best, with 5% slack
                target = Some(sum.curve.best_loss() * 1.05);
            }
            let tgt = target.unwrap();
            let t_at = time_to_loss(&sum, tgt);
            let rec = &sum.stats.recovery;
            println!(
                "{:<10} {:<9} {:>9.2} {:>10.4} {:>11} {:>7} {:>6} {:>8}",
                sum.algorithm,
                scenario,
                sum.total_time_s,
                sum.curve.best_loss(),
                t_at.map(|t| format!("{t:.2}s")).unwrap_or_else(|| "never".into()),
                rec.crashes,
                rec.joins,
                if rec.stalled { "YES" } else { "no" }
            );
            for p in &sum.curve.points {
                csv.push_str(&format!(
                    "{},{},{},{:.3},{:.5},{:.5}\n",
                    sum.algorithm, scenario, p.step, p.time_s, p.loss, p.accuracy
                ));
            }
            rows.push(obj(vec![
                ("algorithm", s(&sum.algorithm)),
                ("scenario", s(scenario)),
                ("wall_s", num(sum.total_time_s)),
                ("best_loss", num(sum.curve.best_loss())),
                ("target_loss", num(tgt)),
                (
                    "time_to_target_s",
                    t_at.map(num).unwrap_or(Json::Null),
                ),
                ("total_steps", num(sum.total_steps as f64)),
                ("crashes", num(rec.crashes as f64)),
                ("joins", num(rec.joins as f64)),
                ("stalled", Json::Bool(rec.stalled)),
                ("membership_epoch", num(rec.membership_epoch as f64)),
            ]));
        }
        common::hr();
    }
    let dir = common::results_dir();
    std::fs::write(dir.join("fig_fault_tolerance.csv"), csv).expect("write csv");
    std::fs::write(dir.join("fig_fault_tolerance.json"), arr(rows).dump()).expect("write json");
    println!("wrote results/fig_fault_tolerance.csv and .json");
}
