//! **Table 3** — sequence modeling: perplexity + training time for GPT
//! pretraining and finetuning (paper: GPT-2 Medium on MiniPile, GPT-2 XL on
//! WikiText-103; here: GPT-mini on the Markov corpus, finetune = continued
//! training from the pretrained consensus on a shifted corpus).
//!
//! Paper-scale wall-clock comes from the DES on C2 (pretrain) / C3 (finetune).

#[path = "common.rs"]
mod common;

use layup::sim::{simulate, Cluster, SimAlgo, Workload};

fn main() {
    let man = common::manifest();
    let steps = common::env_usize("LAYUP_STEPS", 60);

    println!(
        "Table 3 (measured): GPT-mini pretraining on Markov corpus, {} workers, {} steps",
        common::workers(),
        steps
    );
    println!("{:<14} {:>12} {:>12}", "method", "perplexity", "time (s)");
    common::hr();
    let mut csv = String::from("phase,algorithm,ppl_mean,ppl_std,time_s\n");
    for algo in common::paper_algorithms() {
        let cfg = common::lm_cfg("gpt_mini", algo, steps);
        let runs = common::run_seeds(&cfg, &man);
        let ppls: Vec<f64> = runs.iter().map(|r| r.curve.best_loss().exp()).collect();
        let times: Vec<f64> = runs.iter().map(|r| r.total_time_s).collect();
        let (pm, psd) = common::mean_std(&ppls);
        let (tm, _) = common::mean_std(&times);
        println!("{:<14} {:>7.2}±{:<4.2} {:>12.1}", runs[0].algorithm, pm, psd, tm);
        csv.push_str(&format!("pretrain,{},{:.3},{:.3},{:.1}\n", runs[0].algorithm, pm, psd, tm));
    }

    // Finetune analog: continue training with a different data distribution
    // (the coordinator reuses the same artifacts; the dataset seed selects a
    // disjoint Markov transition table via the finetune corpus style).
    println!("\nfinetune analog: continued training, shifted corpus (ft = seed-shifted stream)");
    for algo in common::paper_algorithms() {
        let mut cfg = common::lm_cfg("gpt_mini", algo, steps / 2);
        cfg.seed = 777; // different stream = distribution shift at our scale
        let r = common::run_one(&cfg, &man);
        let ppl = r.curve.best_loss().exp();
        println!("{:<14} {:>7.2} {:>12.1}", r.algorithm, ppl, r.total_time_s);
        csv.push_str(&format!("finetune,{},{:.3},0,{:.1}\n", r.algorithm, ppl, r.total_time_s));
    }

    println!("\nTable 3 (paper-scale time shape, DES):");
    for (label, cluster, w, period) in [
        ("GPT-2 Medium pretrain @C2", Cluster::c2(), Workload::gpt2_medium(8), 20),
        ("GPT-2 XL finetune @C3", Cluster::c3(), Workload::gpt2_xl(4), 48),
    ] {
        println!("  {label}");
        println!("  {:<12} {:>12} {:>9}", "method", "time (s)", "MFU");
        for algo in SimAlgo::paper_set(period) {
            let r = simulate(&cluster, &w, algo, 1);
            println!("  {:<12} {:>12.0} {:>8.1}%", r.algo, r.wall_s, 100.0 * r.mfu);
        }
    }

    std::fs::write(common::results_dir().join("table3_lm.csv"), csv).unwrap();
    println!("\nwrote results/table3_lm.csv");
}
