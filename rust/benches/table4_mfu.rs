//! **Table 4** — Model FLOPs Utilization per algorithm.
//!
//! Measured panel: thread-cluster runs with evaluation disabled; MFU is the
//! achieved FLOPs/s divided by the calibrated single-worker compute-only
//! peak (the "theoretical peak" of this substrate — exactly how Chowdhery et
//! al. define MFU, with our peak standing in for the accelerator datasheet).
//! Paper-scale panel: DES on C2/C3 with the paper's sync periods.

#[path = "common.rs"]
mod common;

use layup::config::Algorithm;
use layup::sim::{simulate, Cluster, SimAlgo, Workload};

fn main() {
    let man = common::manifest();
    let steps = common::env_usize("LAYUP_STEPS", 40);

    // calibrate single-worker peak (no eval, one worker, gossip-free)
    let mut calib = common::lm_cfg("gpt_mini", Algorithm::LocalSgd, steps.min(40));
    calib.workers = 1;
    calib.sync_period = usize::MAX / 2; // never syncs with itself anyway
    calib.eval_every = usize::MAX / 2;
    let peak = common::run_one(&calib, &man).stats.achieved_flops_per_s;
    println!("calibrated single-worker peak: {peak:.3e} FLOP/s\n");

    println!(
        "Table 4 (measured): GPT-mini pretraining MFU, {} workers, {} steps",
        common::workers(),
        steps
    );
    println!("{:<14} {:>10} {:>12}", "method", "MFU", "occupancy");
    common::hr();
    let mut csv = String::from("algorithm,mfu,occupancy\n");
    for algo in common::paper_algorithms() {
        let mut cfg = common::lm_cfg("gpt_mini", algo, steps);
        cfg.eval_every = usize::MAX / 2; // measurement window excludes eval
        let r = common::run_one(&cfg, &man);
        // achieved flops are summed across workers; peak is per worker
        let mfu = r.stats.achieved_flops_per_s / peak / common::workers() as f64;
        println!(
            "{:<14} {:>9.1}% {:>11.1}%",
            r.algorithm,
            100.0 * mfu,
            100.0 * r.compute_occupancy
        );
        csv.push_str(&format!("{},{:.4},{:.4}\n", r.algorithm, mfu, r.compute_occupancy));
    }

    println!("\nTable 4 (paper-scale MFU shape, DES):");
    for (label, cluster, w, period) in [
        ("GPT-2 Medium pretrain @C2", Cluster::c2(), Workload::gpt2_medium(8), 20),
        ("GPT-2 XL finetune @C3", Cluster::c3(), Workload::gpt2_xl(4), 48),
    ] {
        println!("  {label}");
        println!("  {:<12} {:>9}", "method", "MFU");
        for algo in SimAlgo::paper_set(period) {
            let r = simulate(&cluster, &w, algo, 1);
            println!("  {:<12} {:>8.1}%", r.algo, 100.0 * r.mfu);
        }
    }

    std::fs::write(common::results_dir().join("table4_mfu.csv"), csv).unwrap();
    println!("\nwrote results/table4_mfu.csv");
}
