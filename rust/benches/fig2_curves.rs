//! **Figure 2** — learning curves (metric vs wall-clock AND vs steps) for all
//! algorithms on the vision task (2A analog) and GPT pretraining (2B analog).
//! Emits one CSV per (panel, algorithm) under results/fig2/ — the paper's
//! zoomed insets are just re-plots of the same series.

#[path = "common.rs"]
mod common;

fn main() {
    let man = common::manifest();
    let dir = common::results_dir().join("fig2");
    std::fs::create_dir_all(&dir).unwrap();

    for (panel, model, steps, lm) in [
        ("A_vision", "mlpnet50", common::env_usize("LAYUP_STEPS", 160), false),
        ("B_pretrain", "gpt_mini", common::env_usize("LAYUP_STEPS", 50), true),
    ] {
        println!("Fig 2{panel}: {model}");
        for algo in common::paper_algorithms() {
            let cfg = if lm {
                common::lm_cfg(model, algo, steps)
            } else {
                common::vision_cfg(model, algo, steps)
            };
            let r = common::run_seeds(&cfg, &man).remove(0);
            let path = dir.join(format!("{panel}_{}.csv", r.algorithm.replace(['(', ')'], "")));
            std::fs::write(&path, r.curve.to_csv()).unwrap();
            let last = r.curve.points.last().unwrap();
            println!(
                "  {:<12} final loss {:.4} acc {:.3} @ {:.1}s -> {}",
                r.algorithm,
                last.loss,
                last.accuracy,
                last.time_s,
                path.display()
            );
        }
    }
    println!("\nplots: each CSV has (step, time_s, loss, accuracy, perplexity) — the paper's");
    println!("wall-clock panels plot loss vs time_s; the step-insets plot loss vs step.");
}
