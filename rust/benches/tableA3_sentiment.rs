//! **Table A3** — sentiment analysis: the 2-layer RNN classifier (LSTM/IMDb
//! analog), LayUp vs DDP, convergence accuracy + TTC. The paper's finding is
//! parity: the run is too short for the algorithms to separate.

#[path = "common.rs"]
mod common;

use layup::config::Algorithm;
use layup::optim::{OptimKind, Schedule};

fn main() {
    let man = common::manifest();
    let steps = common::env_usize("LAYUP_STEPS", 120);

    println!(
        "Table A3 (measured): rnn_sentiment, {} workers, {} steps",
        common::workers(),
        steps
    );
    println!("{:<14} {:>12} {:>12} {:>8}", "method", "conv acc", "TTC (s)", "epochs");
    common::hr();
    let mut csv = String::from("algorithm,accuracy_mean,accuracy_std,ttc_s\n");
    for algo in [Algorithm::Ddp, Algorithm::LayUp] {
        let mut cfg = common::vision_cfg("rnn_sentiment", algo, steps);
        // paper: Adam @ 1e-3 (A9) — AdamW with no decay is the same here
        cfg.optim = OptimKind::adamw(0.0);
        cfg.schedule = Schedule::Cosine {
            lr: if algo == Algorithm::LayUp { 1.5e-3 } else { 1e-3 },
            t_max: steps,
            warmup_steps: 0,
            warmup_lr: 0.0,
        };
        let runs = common::run_seeds(&cfg, &man);
        let accs: Vec<f64> = runs.iter().map(|r| r.curve.best_accuracy()).collect();
        let ttcs: Vec<f64> = runs
            .iter()
            .map(|r| r.curve.time_to_convergence(0.01).unwrap_or(r.total_time_s))
            .collect();
        let (am, asd) = common::mean_std(&accs);
        let (tm, _) = common::mean_std(&ttcs);
        println!(
            "{:<14} {:>7.2}±{:<4.2} {:>12.1} {:>8}",
            runs[0].algorithm,
            100.0 * am,
            100.0 * asd,
            tm,
            runs[0].epochs
        );
        csv.push_str(&format!("{},{:.4},{:.4},{:.2}\n", runs[0].algorithm, am, asd, tm));
    }
    std::fs::write(common::results_dir().join("tableA3_sentiment.csv"), csv).unwrap();
    println!("\nwrote results/tableA3_sentiment.csv");
}
