//! Resilience-subsystem integration tests: checkpoint resume parity, chaos
//! injection and recovery policies over the full stack. Like
//! `integration.rs`, these need `artifacts/` and self-skip politely when the
//! manifest is missing.

use std::path::PathBuf;
use std::sync::{Arc, Mutex};

use layup::comm::{Codec, CodecSpec, Fabric, InFlight, LatencyDist, Payload, PushOutcome, SimFabric};
use layup::config::{Algorithm, TrainConfig};
use layup::coordinator::Shared;
use layup::manifest::Manifest;
use layup::metrics::RunSummary;
use layup::model::ModelParams;
use layup::optim::OptimKind;
use layup::optim::Schedule;
use layup::resilience::{checkpoint, FaultPlan, RecoveryPolicy};
use layup::session::events::TrainEvent;
use layup::session::SessionBuilder;
use layup::tensor::clock::ClockStamp;
use layup::tensor::{AtomicTensor, LayerParams, Tensor};
use layup::topology::roles::TopologySpec;

fn manifest() -> Option<Manifest> {
    let dir = layup::artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: no artifacts at {} (run `make artifacts`)", dir.display());
        return None;
    }
    Some(Manifest::load(&dir).expect("manifest parses"))
}

fn pick_model(man: &Manifest) -> String {
    if man.models.contains_key("mlpnet18") {
        "mlpnet18".into()
    } else {
        man.models.keys().next().unwrap().clone()
    }
}

fn quick_cfg(model: &str, algo: Algorithm, workers: usize, steps: usize) -> TrainConfig {
    let mut cfg = TrainConfig::new(model, algo, workers, steps);
    cfg.optim = OptimKind::sgd(0.9, 0.0);
    cfg.schedule = Schedule::Constant { lr: 0.03 };
    cfg.eval_every = 3;
    cfg
}

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("layup-resilience-{tag}-{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    dir
}

fn run(cfg: &TrainConfig, man: &Manifest) -> RunSummary {
    SessionBuilder::new(cfg.clone())
        .build(man)
        .expect("config invalid")
        .run()
        .expect("run failed")
}

/// Per-step losses/accuracies must match bit-for-bit (wall times may not).
fn assert_curves_identical(a: &RunSummary, b: &RunSummary, what: &str) {
    assert_eq!(a.curve.points.len(), b.curve.points.len(), "{what}: point counts differ");
    for (pa, pb) in a.curve.points.iter().zip(b.curve.points.iter()) {
        assert_eq!(pa.step, pb.step, "{what}: eval steps differ");
        assert_eq!(
            pa.loss.to_bits(),
            pb.loss.to_bits(),
            "{what}: loss at step {} differs ({} vs {})",
            pa.step,
            pa.loss,
            pb.loss
        );
        assert_eq!(
            pa.accuracy.to_bits(),
            pb.accuracy.to_bits(),
            "{what}: accuracy at step {} differs",
            pa.step
        );
    }
}

/// The tentpole acceptance: a run checkpointed at step k and resumed from
/// that snapshot produces a bit-identical loss curve to the uninterrupted
/// run, on the instant fabric. Gossip algorithms run under the
/// deterministic lockstep driver (the threaded engine's gossip races are
/// scheduler-dependent by design); DDP runs threaded — its barrier already
/// makes it deterministic.
#[test]
fn resume_parity_bit_identical_for_layup_gosgd_adpsgd_and_ddp() {
    let Some(man) = manifest() else { return };
    let model_name = pick_model(&man);
    let cases = [
        (Algorithm::LayUp, true),
        (Algorithm::GoSgd, true),
        (Algorithm::AdPsgd, true),
        (Algorithm::Ddp, false),
    ];
    for (algo, lockstep) in cases {
        let dir = tmp_dir(&format!("parity-{algo:?}"));
        let steps = 12;
        let every = 4;

        // reference: uninterrupted run that also writes checkpoints
        let mut cfg = quick_cfg(&model_name, algo, 2, steps);
        cfg.lockstep = lockstep;
        cfg.checkpoint_every = every;
        cfg.checkpoint_dir = dir.clone();
        let full = run(&cfg, &man);
        assert_eq!(
            full.stats.recovery.checkpoints_saved, 2,
            "{algo:?}: expected snapshots at steps 4 and 8"
        );

        // resumed: fresh session, restore the step-4 snapshot, run to the
        // end — writing its own checkpoints so the step-8 snapshots of both
        // runs can be compared below
        let resumed_dir = tmp_dir(&format!("parity-resumed-{algo:?}"));
        let mut resume_cfg = quick_cfg(&model_name, algo, 2, steps);
        resume_cfg.lockstep = lockstep;
        resume_cfg.checkpoint_every = every;
        resume_cfg.checkpoint_dir = resumed_dir.clone();
        let resumed = SessionBuilder::new(resume_cfg)
            .build(&man)
            .unwrap()
            .resume_from(checkpoint::step_dir(&dir, every))
            .unwrap_or_else(|e| panic!("{algo:?}: resume failed: {e:#}"))
            .run()
            .unwrap_or_else(|e| panic!("{algo:?}: resumed run failed: {e:#}"));

        assert_curves_identical(&full, &resumed, &format!("{algo:?} resume parity"));

        // the step-8 snapshots of the uninterrupted and the resumed run
        // must agree bit-for-bit — parameters AND per-layer staleness
        // clocks (the resume carried LayerClock state exactly)
        let ck_full = checkpoint::load(&checkpoint::step_dir(&dir, 2 * every))
            .unwrap_or_else(|e| panic!("{algo:?}: loading full-run step-8 snapshot: {e:#}"));
        let ck_resumed = checkpoint::load(&checkpoint::step_dir(&resumed_dir, 2 * every))
            .unwrap_or_else(|e| panic!("{algo:?}: loading resumed-run step-8 snapshot: {e:#}"));
        assert_eq!(ck_full.params, ck_resumed.params, "{algo:?}: replica values diverged");
        assert_eq!(
            ck_full.clocks, ck_resumed.clocks,
            "{algo:?}: staleness clocks diverged across resume"
        );

        std::fs::remove_dir_all(&dir).ok();
        std::fs::remove_dir_all(&resumed_dir).ok();
    }
}

/// PS determinism (topology satellite): `asgd-ps` and `dcasgd-ps`
/// checkpoint at step 8 and resume bit-identically under the lockstep
/// driver — the shard's optimizer moments ride the shard wid's checkpoint
/// slot, and the instant fabric's synchronous GradPush/ParamPull round
/// trips replay exactly. `hier-gossip` rides along as the third role
/// topology (leader pushes replay through the same path).
#[test]
fn resume_parity_bit_identical_for_ps_and_hier_topologies() {
    let Some(man) = manifest() else { return };
    let model_name = pick_model(&man);
    let cases = [
        (Algorithm::AsgdPs, TopologySpec::Ps { shards: 1 }),
        (Algorithm::DcAsgdPs, TopologySpec::Ps { shards: 1 }),
        (Algorithm::HierGossip, TopologySpec::Hier { groups: 2 }),
    ];
    for (algo, cluster) in cases {
        let dir = tmp_dir(&format!("parity-{algo:?}"));
        let steps = 12;
        let every = 4;
        let workers = 3; // ps:1 → 2 trainers + 1 shard; hier:2 → groups {0,1}, {2}

        let mut cfg = quick_cfg(&model_name, algo, workers, steps);
        cfg.cluster = cluster;
        cfg.lockstep = true;
        cfg.checkpoint_every = every;
        cfg.checkpoint_dir = dir.clone();
        let full = run(&cfg, &man);
        assert_eq!(
            full.stats.recovery.checkpoints_saved, 2,
            "{algo:?}: expected snapshots at steps 4 and 8"
        );
        if cluster.n_shards() > 0 {
            assert!(full.stats.ps.grad_pushes > 0, "{algo:?}: shards applied no gradients");
            assert!(full.stats.ps.param_pulls > 0, "{algo:?}: shards replied no parameters");
            assert!(!full.stats.recovery.stalled, "{algo:?}: PS run stalled");
            // the shard wid's slot must carry its optimizer moments
            let ck = checkpoint::load(&checkpoint::step_dir(&dir, every)).unwrap();
            assert!(
                ck.workers_state[workers - 1].algo.opt.is_some(),
                "{algo:?}: shard slot missing optimizer state"
            );
        }

        let resumed_dir = tmp_dir(&format!("parity-resumed-{algo:?}"));
        let mut resume_cfg = quick_cfg(&model_name, algo, workers, steps);
        resume_cfg.cluster = cluster;
        resume_cfg.lockstep = true;
        resume_cfg.checkpoint_every = every;
        resume_cfg.checkpoint_dir = resumed_dir.clone();
        let resumed = SessionBuilder::new(resume_cfg)
            .build(&man)
            .unwrap()
            .resume_from(checkpoint::step_dir(&dir, every))
            .unwrap_or_else(|e| panic!("{algo:?}: resume failed: {e:#}"))
            .run()
            .unwrap_or_else(|e| panic!("{algo:?}: resumed run failed: {e:#}"));

        assert_curves_identical(&full, &resumed, &format!("{algo:?} resume parity"));

        // the step-8 snapshots — trainer replicas, shard parameter stacks,
        // staleness clocks — must agree bit-for-bit across the resume
        let ck_full = checkpoint::load(&checkpoint::step_dir(&dir, 2 * every))
            .unwrap_or_else(|e| panic!("{algo:?}: loading full-run step-8 snapshot: {e:#}"));
        let ck_resumed = checkpoint::load(&checkpoint::step_dir(&resumed_dir, 2 * every))
            .unwrap_or_else(|e| panic!("{algo:?}: loading resumed-run step-8 snapshot: {e:#}"));
        assert_eq!(ck_full.params, ck_resumed.params, "{algo:?}: replica values diverged");
        assert_eq!(
            ck_full.clocks, ck_resumed.clocks,
            "{algo:?}: staleness clocks diverged across resume"
        );

        std::fs::remove_dir_all(&dir).ok();
        std::fs::remove_dir_all(&resumed_dir).ok();
    }
}

/// A dead PS shard under the Stall policy stalls the trainers (its layer
/// partition is frozen and the supervisor reports the stall), exactly like
/// a dead barrier peer; under Shrink the surviving shard inherits the whole
/// partition and the run completes.
#[test]
fn dead_shard_stalls_or_repartitions_by_policy() {
    let Some(man) = manifest() else { return };
    let model_name = pick_model(&man);
    let steps = 10;

    // Stall (default): shard wid 3 dies at step 3 → trainers freeze its
    // layers, the supervisor waits out the timeout and stops the run
    let mut cfg = quick_cfg(&model_name, Algorithm::AsgdPs, 4, steps);
    cfg.cluster = TopologySpec::Ps { shards: 2 };
    cfg.faults = FaultPlan::default().crash(3, 3);
    cfg.stall_timeout_s = 1.0;
    let summary = run(&cfg, &man);
    assert!(summary.stats.recovery.stalled, "a dead shard must stall the PS run");
    assert_eq!(summary.stats.recovery.crashes, 1);

    // Shrink: the surviving shard takes over the dead shard's layers (the
    // membership epoch bumps the route cache) and every trainer finishes
    let mut cfg = quick_cfg(&model_name, Algorithm::AsgdPs, 4, steps);
    cfg.cluster = TopologySpec::Ps { shards: 2 };
    cfg.faults = FaultPlan::default().crash(3, 3);
    cfg.recovery = RecoveryPolicy::Shrink;
    let summary = run(&cfg, &man);
    assert!(!summary.stats.recovery.stalled, "shrink re-partitions instead of stalling");
    assert_eq!(summary.total_steps, 2 * steps, "both trainers finish their budgets");
    assert!(summary.stats.ps.repartitions > 0, "route cache never re-partitioned");
    assert!(summary.curve.best_loss().is_finite());
}

/// `resolve` picks the latest snapshot when handed the parent directory, and
/// incompatible sessions are rejected up front.
#[test]
fn resume_resolution_and_compatibility_gates() {
    let Some(man) = manifest() else { return };
    let model_name = pick_model(&man);
    let dir = tmp_dir("resolve");
    let mut cfg = quick_cfg(&model_name, Algorithm::GoSgd, 2, 12);
    cfg.lockstep = true;
    cfg.checkpoint_every = 4;
    cfg.checkpoint_dir = dir.clone();
    let _ = run(&cfg, &man);

    let latest = checkpoint::resolve(&dir).unwrap();
    assert!(latest.ends_with("step-000008"), "latest is step 8, got {}", latest.display());

    // wrong seed: the data streams would diverge — rejected
    let mut bad = quick_cfg(&model_name, Algorithm::GoSgd, 2, 12);
    bad.lockstep = true;
    bad.seed = 7777;
    assert!(SessionBuilder::new(bad).build(&man).unwrap().resume_from(&dir).is_err());
    // wrong algorithm: rejected
    let mut other = quick_cfg(&model_name, Algorithm::AdPsgd, 2, 12);
    other.lockstep = true;
    assert!(SessionBuilder::new(other).build(&man).unwrap().resume_from(&dir).is_err());
    std::fs::remove_dir_all(&dir).ok();
}

/// Crash-injection acceptance: under a permanent worker loss, LayUp keeps
/// training on the survivors and finishes, while DDP's barrier stalls and
/// the run reports it.
#[test]
fn layup_survives_a_permanent_crash_while_ddp_reports_the_stall() {
    let Some(man) = manifest() else { return };
    let model_name = pick_model(&man);
    let steps = 10;

    // LayUp: worker 1 dies at step 3 and never returns; worker 0 finishes
    // its full step budget, gossip pushes to the dead peer become skips.
    let mut cfg = quick_cfg(&model_name, Algorithm::LayUp, 2, steps);
    cfg.faults = FaultPlan::default().crash(1, 3);
    let events: Arc<Mutex<Vec<String>>> = Arc::new(Mutex::new(Vec::new()));
    let sink = {
        let events = Arc::clone(&events);
        move |ev: &TrainEvent| {
            events.lock().unwrap().push(ev.kind().to_string());
        }
    };
    let summary = SessionBuilder::new(cfg)
        .observer(Arc::new(sink))
        .build(&man)
        .unwrap()
        .run()
        .unwrap();
    assert_eq!(summary.total_steps, steps + 3, "survivor finishes, victim stops at 3");
    assert_eq!(summary.stats.recovery.crashes, 1);
    assert_eq!(summary.stats.recovery.joins, 0);
    assert!(!summary.stats.recovery.stalled, "gossip never stalls on a dead peer");
    assert_eq!(summary.stats.recovery.membership_epoch, 1);
    assert!(summary.curve.best_loss().is_finite());
    assert!(events.lock().unwrap().iter().any(|k| k == "worker_crashed"));

    // DDP, same fault, Stall policy: the all-reduce waits for the dead
    // worker until the supervisor reports the stall and stops the run.
    let mut cfg = quick_cfg(&model_name, Algorithm::Ddp, 2, steps);
    cfg.faults = FaultPlan::default().crash(1, 3);
    cfg.stall_timeout_s = 1.0;
    let summary = run(&cfg, &man);
    assert!(summary.stats.recovery.stalled, "DDP must report the stall");
    assert!(
        summary.total_steps < 2 * steps,
        "a stalled DDP run cannot have finished: {} steps",
        summary.total_steps
    );

    // DDP, same fault, Shrink policy: the collective shrinks to the
    // survivor set and the run completes.
    let mut cfg = quick_cfg(&model_name, Algorithm::Ddp, 3, steps);
    cfg.faults = FaultPlan::default().crash(2, 3);
    cfg.recovery = RecoveryPolicy::Shrink;
    let summary = run(&cfg, &man);
    assert!(!summary.stats.recovery.stalled);
    assert_eq!(
        summary.total_steps,
        2 * steps + 3,
        "survivors finish, victim contributed 3 steps"
    );
    assert!(summary.curve.best_loss().is_finite());
}

/// Crash/restart: the worker rejoins from a live peer's parameters, the
/// membership epoch records both transitions, and every scheduled step of
/// the respawned worker still happens.
#[test]
fn crashed_worker_rejoins_and_completes_its_step_budget() {
    let Some(man) = manifest() else { return };
    let model_name = pick_model(&man);
    let steps = 14;
    let mut cfg = quick_cfg(&model_name, Algorithm::LayUp, 2, steps);
    cfg.faults = FaultPlan::default().crash_restart(1, 4, 0.2);
    let events: Arc<Mutex<Vec<String>>> = Arc::new(Mutex::new(Vec::new()));
    let sink = {
        let events = Arc::clone(&events);
        move |ev: &TrainEvent| {
            events.lock().unwrap().push(ev.kind().to_string());
        }
    };
    let summary = SessionBuilder::new(cfg)
        .observer(Arc::new(sink))
        .build(&man)
        .unwrap()
        .run()
        .unwrap();
    assert_eq!(summary.total_steps, 2 * steps, "the rejoined worker finished its budget");
    assert_eq!(summary.stats.recovery.crashes, 1);
    assert_eq!(summary.stats.recovery.joins, 1);
    assert_eq!(summary.stats.recovery.membership_epoch, 2, "dead + alive transitions");
    let kinds = events.lock().unwrap();
    assert!(kinds.iter().any(|k| k == "worker_crashed"));
    assert!(kinds.iter().any(|k| k == "worker_joined"));
}

/// Checkpoint events flow through the observer stream, and the snapshot
/// directories are complete (meta.json present — the commit marker).
#[test]
fn checkpoint_events_and_directories_are_complete() {
    let Some(man) = manifest() else { return };
    let model_name = pick_model(&man);
    let dir = tmp_dir("events");
    let mut cfg = quick_cfg(&model_name, Algorithm::GoSgd, 2, 9);
    cfg.checkpoint_every = 4;
    cfg.checkpoint_dir = dir.clone();
    let saved: Arc<Mutex<Vec<(usize, String)>>> = Arc::new(Mutex::new(Vec::new()));
    let sink = {
        let saved = Arc::clone(&saved);
        move |ev: &TrainEvent| {
            if let TrainEvent::CheckpointSaved { step, path } = ev {
                saved.lock().unwrap().push((*step, path.clone()));
            }
        }
    };
    let summary = SessionBuilder::new(cfg)
        .observer(Arc::new(sink))
        .build(&man)
        .unwrap()
        .run()
        .unwrap();
    let saved = saved.lock().unwrap();
    assert_eq!(saved.len(), 2, "snapshots at steps 4 and 8");
    assert_eq!(summary.stats.recovery.checkpoints_saved, 2);
    for (step, path) in saved.iter() {
        assert!(PathBuf::from(path).join("meta.json").exists(), "step {step} incomplete");
    }
    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------------------------- format v4

/// Fresh two-worker world for the v4 parity test: one layer, two tensors
/// (5 and 7 coords — both off the top-k keep boundary), a `topk:4` codec on
/// a SimFabric whose latency sits far beyond the test horizon so every push
/// stays in flight.
fn v4_world(params: Vec<Arc<ModelParams>>) -> Arc<Shared> {
    let codec = CodecSpec::TopK { k: 4 }.build(2, 0x51ab);
    let fabric = Arc::new(SimFabric::with_codec(
        LatencyDist::Constant(1e6),
        0.0,
        0.0,
        2,
        7,
        codec,
    ));
    Shared::for_tests(params, fabric)
}

fn v4_params(worker: usize) -> Arc<ModelParams> {
    let t = |n: usize, salt: usize| {
        let data = (0..n).map(|i| ((worker * 53 + salt * 19 + i * 11) % 89) as f32 * 0.02 - 0.9);
        AtomicTensor::from_tensor(&Tensor::from_vec(&[n], data.collect()))
    };
    Arc::new(ModelParams { layers: vec![LayerParams::new(vec![t(5, 1), t(7, 2)])] })
}

/// Drive the scripted steps `[a, b)`: each step, each worker applies a
/// deterministic local update, then ships its gradient set (the
/// error-feedback stream) and a layer snapshot to its peer. Nothing is ever
/// delivered — the run's entire comm state lives in the codec residuals and
/// the queued compressed blobs, exactly what FORMAT_VERSION 4 added to the
/// snapshot.
fn v4_segment(shared: &Arc<Shared>, a: usize, b: usize) {
    let grad = |w: usize, s: usize, t: usize, i: usize| {
        ((w * 131 + s * 17 + t * 29 + i * 7) % 97) as f32 * 0.013 - 0.6
    };
    for s in a..b {
        for w in 0..2 {
            let layer = &shared.params[w].layers[0];
            let mut grads = Vec::new();
            for (ti, t) in layer.tensors.iter().enumerate() {
                let g: Vec<f32> = (0..t.numel()).map(|i| grad(w, s, ti, i)).collect();
                t.sub_scaled(0.05, &g);
                grads.push(Tensor::from_vec(&[t.numel()], g));
            }
            let payloads = [
                Payload::GradShare { set: Arc::new(vec![grads]) },
                Payload::LayerPush {
                    layer: 0,
                    open: None,
                    values: Arc::new(layer.tensors.iter().map(|t| t.snapshot().data).collect()),
                    stamp: ClockStamp { worker: w as u32, step: s as u64, version: s as u64 },
                    tau: 0,
                },
            ];
            for p in payloads {
                assert_eq!(
                    shared.fabric.push(shared, w, 1 - w, s, p),
                    PushOutcome::Queued,
                    "scripted pushes never drop (drop_prob 0)"
                );
            }
        }
    }
}

/// `(from, to, step, blob)` signature of every queued message — the
/// wall-clock `remaining_s` is the one field two runs may legitimately
/// disagree on, so it stays out of the comparison.
fn v4_signatures(msgs: &[InFlight]) -> Vec<(usize, usize, usize, Vec<u8>)> {
    msgs.iter()
        .map(|m| {
            let Payload::Compressed(c) = &m.payload else {
                panic!("a non-dense codec wraps every payload");
            };
            (m.from, m.to, m.step, c.blob.to_vec())
        })
        .collect()
}

/// FORMAT_VERSION 4 resume parity: a run checkpointed at step 8 with
/// `topk` messages in flight on a [`SimFabric`] and live error-feedback
/// residuals, saved and reloaded through the on-disk codec, continues to a
/// step-16 state bit-identical to an uninterrupted run — parameters,
/// sender-side residuals, and every queued compressed blob. (The session
/// driver can't host this: lockstep replay rejects the sim fabric, so the
/// schedule is scripted by hand. No artifacts needed.)
#[test]
fn resume_parity_v4_topk_in_flight_bit_identical() {
    assert_eq!(checkpoint::FORMAT_VERSION, 4, "test pins the residual-carrying format");

    // reference: uninterrupted 0..16
    let run_a = v4_world(vec![v4_params(0), v4_params(1)]);
    v4_segment(&run_a, 0, 16);

    // interrupted: 0..8, snapshot through the on-disk codec, resume, 8..16
    let run_b = v4_world(vec![v4_params(0), v4_params(1)]);
    v4_segment(&run_b, 0, 8);
    let mut in_flight = run_b.fabric.drain(0);
    in_flight.extend(run_b.fabric.drain(1));
    assert!(
        in_flight.iter().all(|m| matches!(m.payload, Payload::Compressed(_))),
        "topk wraps every queued payload"
    );
    let residuals = run_b.fabric.core().codec().residual_state();
    assert!(!residuals.is_empty(), "8 sparsified gradient pushes must leave residual mass");
    let ckpt = checkpoint::Checkpoint {
        version: checkpoint::FORMAT_VERSION,
        model: "v4-mini".to_string(),
        algorithm: "Scripted".to_string(),
        workers: 2,
        seed: 7,
        step: 8,
        elapsed_s: 0.0,
        epoch: 0,
        params: vec![run_b.params[0].state_dict(), run_b.params[1].state_dict()],
        clocks: vec![vec![ClockStamp::default()]; 2],
        workers_state: vec![
            checkpoint::WorkerState {
                alive: true,
                steps_done: 8,
                cursor: 0,
                weight: 0.5,
                algo: checkpoint::AlgoState::default(),
            };
            2
        ],
        in_flight,
        residuals,
        curve: Vec::new(),
        drift: Vec::new(),
    };
    let dir = tmp_dir("v4-parity");
    checkpoint::save(&dir, &ckpt).unwrap();
    let loaded = checkpoint::load(&dir).unwrap();
    std::fs::remove_dir_all(&dir).ok();
    assert_eq!(loaded.residuals, ckpt.residuals, "residuals survive the disk round-trip");
    assert_eq!(
        v4_signatures(&loaded.in_flight),
        v4_signatures(&ckpt.in_flight),
        "compressed in-flight blobs survive the disk round-trip"
    );

    // rebuild everything from the loaded snapshot, as resume does
    let restore = |w: usize| {
        let vals: Vec<f32> = loaded.params[w].iter().flatten().flatten().copied().collect();
        let p = v4_params(w);
        let mut at = vals.iter();
        for l in &p.layers {
            for t in &l.tensors {
                let chunk: Vec<f32> = at.by_ref().take(t.numel()).copied().collect();
                t.store_from(&chunk);
            }
        }
        p
    };
    let resumed = v4_world(vec![restore(0), restore(1)]);
    resumed.fabric.core().codec().load_residual_state(&loaded.residuals);
    resumed.fabric.restore(&resumed, loaded.in_flight);
    v4_segment(&resumed, 8, 16);

    // step-16 states must agree bit-for-bit
    let bits = |v: Vec<f32>| v.into_iter().map(f32::to_bits).collect::<Vec<u32>>();
    for w in 0..2 {
        assert_eq!(
            bits(run_a.params[w].flatten()),
            bits(resumed.params[w].flatten()),
            "worker {w} parameters diverged after resume"
        );
    }
    assert_eq!(
        run_a.fabric.core().codec().residual_state(),
        resumed.fabric.core().codec().residual_state(),
        "error-feedback residuals diverged after resume"
    );
    let drain_all = |s: &Arc<Shared>| {
        let mut v = s.fabric.drain(0);
        v.extend(s.fabric.drain(1));
        v4_signatures(&v)
    };
    assert_eq!(drain_all(&run_a), drain_all(&resumed), "in-flight wire bytes diverged");
}
