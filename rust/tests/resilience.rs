//! Resilience-subsystem integration tests: checkpoint resume parity, chaos
//! injection and recovery policies over the full stack. Like
//! `integration.rs`, these need `artifacts/` and self-skip politely when the
//! manifest is missing.

use std::path::PathBuf;
use std::sync::{Arc, Mutex};

use layup::config::{Algorithm, TrainConfig};
use layup::manifest::Manifest;
use layup::metrics::RunSummary;
use layup::optim::OptimKind;
use layup::optim::Schedule;
use layup::resilience::{checkpoint, FaultPlan, RecoveryPolicy};
use layup::session::events::TrainEvent;
use layup::session::SessionBuilder;
use layup::topology::roles::TopologySpec;

fn manifest() -> Option<Manifest> {
    let dir = layup::artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: no artifacts at {} (run `make artifacts`)", dir.display());
        return None;
    }
    Some(Manifest::load(&dir).expect("manifest parses"))
}

fn pick_model(man: &Manifest) -> String {
    if man.models.contains_key("mlpnet18") {
        "mlpnet18".into()
    } else {
        man.models.keys().next().unwrap().clone()
    }
}

fn quick_cfg(model: &str, algo: Algorithm, workers: usize, steps: usize) -> TrainConfig {
    let mut cfg = TrainConfig::new(model, algo, workers, steps);
    cfg.optim = OptimKind::sgd(0.9, 0.0);
    cfg.schedule = Schedule::Constant { lr: 0.03 };
    cfg.eval_every = 3;
    cfg
}

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("layup-resilience-{tag}-{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    dir
}

fn run(cfg: &TrainConfig, man: &Manifest) -> RunSummary {
    SessionBuilder::new(cfg.clone())
        .build(man)
        .expect("config invalid")
        .run()
        .expect("run failed")
}

/// Per-step losses/accuracies must match bit-for-bit (wall times may not).
fn assert_curves_identical(a: &RunSummary, b: &RunSummary, what: &str) {
    assert_eq!(a.curve.points.len(), b.curve.points.len(), "{what}: point counts differ");
    for (pa, pb) in a.curve.points.iter().zip(b.curve.points.iter()) {
        assert_eq!(pa.step, pb.step, "{what}: eval steps differ");
        assert_eq!(
            pa.loss.to_bits(),
            pb.loss.to_bits(),
            "{what}: loss at step {} differs ({} vs {})",
            pa.step,
            pa.loss,
            pb.loss
        );
        assert_eq!(
            pa.accuracy.to_bits(),
            pb.accuracy.to_bits(),
            "{what}: accuracy at step {} differs",
            pa.step
        );
    }
}

/// The tentpole acceptance: a run checkpointed at step k and resumed from
/// that snapshot produces a bit-identical loss curve to the uninterrupted
/// run, on the instant fabric. Gossip algorithms run under the
/// deterministic lockstep driver (the threaded engine's gossip races are
/// scheduler-dependent by design); DDP runs threaded — its barrier already
/// makes it deterministic.
#[test]
fn resume_parity_bit_identical_for_layup_gosgd_adpsgd_and_ddp() {
    let Some(man) = manifest() else { return };
    let model_name = pick_model(&man);
    let cases = [
        (Algorithm::LayUp, true),
        (Algorithm::GoSgd, true),
        (Algorithm::AdPsgd, true),
        (Algorithm::Ddp, false),
    ];
    for (algo, lockstep) in cases {
        let dir = tmp_dir(&format!("parity-{algo:?}"));
        let steps = 12;
        let every = 4;

        // reference: uninterrupted run that also writes checkpoints
        let mut cfg = quick_cfg(&model_name, algo, 2, steps);
        cfg.lockstep = lockstep;
        cfg.checkpoint_every = every;
        cfg.checkpoint_dir = dir.clone();
        let full = run(&cfg, &man);
        assert_eq!(
            full.stats.recovery.checkpoints_saved, 2,
            "{algo:?}: expected snapshots at steps 4 and 8"
        );

        // resumed: fresh session, restore the step-4 snapshot, run to the
        // end — writing its own checkpoints so the step-8 snapshots of both
        // runs can be compared below
        let resumed_dir = tmp_dir(&format!("parity-resumed-{algo:?}"));
        let mut resume_cfg = quick_cfg(&model_name, algo, 2, steps);
        resume_cfg.lockstep = lockstep;
        resume_cfg.checkpoint_every = every;
        resume_cfg.checkpoint_dir = resumed_dir.clone();
        let resumed = SessionBuilder::new(resume_cfg)
            .build(&man)
            .unwrap()
            .resume_from(checkpoint::step_dir(&dir, every))
            .unwrap_or_else(|e| panic!("{algo:?}: resume failed: {e:#}"))
            .run()
            .unwrap_or_else(|e| panic!("{algo:?}: resumed run failed: {e:#}"));

        assert_curves_identical(&full, &resumed, &format!("{algo:?} resume parity"));

        // the step-8 snapshots of the uninterrupted and the resumed run
        // must agree bit-for-bit — parameters AND per-layer staleness
        // clocks (the resume carried LayerClock state exactly)
        let ck_full = checkpoint::load(&checkpoint::step_dir(&dir, 2 * every))
            .unwrap_or_else(|e| panic!("{algo:?}: loading full-run step-8 snapshot: {e:#}"));
        let ck_resumed = checkpoint::load(&checkpoint::step_dir(&resumed_dir, 2 * every))
            .unwrap_or_else(|e| panic!("{algo:?}: loading resumed-run step-8 snapshot: {e:#}"));
        assert_eq!(ck_full.params, ck_resumed.params, "{algo:?}: replica values diverged");
        assert_eq!(
            ck_full.clocks, ck_resumed.clocks,
            "{algo:?}: staleness clocks diverged across resume"
        );

        std::fs::remove_dir_all(&dir).ok();
        std::fs::remove_dir_all(&resumed_dir).ok();
    }
}

/// PS determinism (topology satellite): `asgd-ps` and `dcasgd-ps`
/// checkpoint at step 8 and resume bit-identically under the lockstep
/// driver — the shard's optimizer moments ride the shard wid's checkpoint
/// slot, and the instant fabric's synchronous GradPush/ParamPull round
/// trips replay exactly. `hier-gossip` rides along as the third role
/// topology (leader pushes replay through the same path).
#[test]
fn resume_parity_bit_identical_for_ps_and_hier_topologies() {
    let Some(man) = manifest() else { return };
    let model_name = pick_model(&man);
    let cases = [
        (Algorithm::AsgdPs, TopologySpec::Ps { shards: 1 }),
        (Algorithm::DcAsgdPs, TopologySpec::Ps { shards: 1 }),
        (Algorithm::HierGossip, TopologySpec::Hier { groups: 2 }),
    ];
    for (algo, cluster) in cases {
        let dir = tmp_dir(&format!("parity-{algo:?}"));
        let steps = 12;
        let every = 4;
        let workers = 3; // ps:1 → 2 trainers + 1 shard; hier:2 → groups {0,1}, {2}

        let mut cfg = quick_cfg(&model_name, algo, workers, steps);
        cfg.cluster = cluster;
        cfg.lockstep = true;
        cfg.checkpoint_every = every;
        cfg.checkpoint_dir = dir.clone();
        let full = run(&cfg, &man);
        assert_eq!(
            full.stats.recovery.checkpoints_saved, 2,
            "{algo:?}: expected snapshots at steps 4 and 8"
        );
        if cluster.n_shards() > 0 {
            assert!(full.stats.ps.grad_pushes > 0, "{algo:?}: shards applied no gradients");
            assert!(full.stats.ps.param_pulls > 0, "{algo:?}: shards replied no parameters");
            assert!(!full.stats.recovery.stalled, "{algo:?}: PS run stalled");
            // the shard wid's slot must carry its optimizer moments
            let ck = checkpoint::load(&checkpoint::step_dir(&dir, every)).unwrap();
            assert!(
                ck.workers_state[workers - 1].algo.opt.is_some(),
                "{algo:?}: shard slot missing optimizer state"
            );
        }

        let resumed_dir = tmp_dir(&format!("parity-resumed-{algo:?}"));
        let mut resume_cfg = quick_cfg(&model_name, algo, workers, steps);
        resume_cfg.cluster = cluster;
        resume_cfg.lockstep = true;
        resume_cfg.checkpoint_every = every;
        resume_cfg.checkpoint_dir = resumed_dir.clone();
        let resumed = SessionBuilder::new(resume_cfg)
            .build(&man)
            .unwrap()
            .resume_from(checkpoint::step_dir(&dir, every))
            .unwrap_or_else(|e| panic!("{algo:?}: resume failed: {e:#}"))
            .run()
            .unwrap_or_else(|e| panic!("{algo:?}: resumed run failed: {e:#}"));

        assert_curves_identical(&full, &resumed, &format!("{algo:?} resume parity"));

        // the step-8 snapshots — trainer replicas, shard parameter stacks,
        // staleness clocks — must agree bit-for-bit across the resume
        let ck_full = checkpoint::load(&checkpoint::step_dir(&dir, 2 * every))
            .unwrap_or_else(|e| panic!("{algo:?}: loading full-run step-8 snapshot: {e:#}"));
        let ck_resumed = checkpoint::load(&checkpoint::step_dir(&resumed_dir, 2 * every))
            .unwrap_or_else(|e| panic!("{algo:?}: loading resumed-run step-8 snapshot: {e:#}"));
        assert_eq!(ck_full.params, ck_resumed.params, "{algo:?}: replica values diverged");
        assert_eq!(
            ck_full.clocks, ck_resumed.clocks,
            "{algo:?}: staleness clocks diverged across resume"
        );

        std::fs::remove_dir_all(&dir).ok();
        std::fs::remove_dir_all(&resumed_dir).ok();
    }
}

/// A dead PS shard under the Stall policy stalls the trainers (its layer
/// partition is frozen and the supervisor reports the stall), exactly like
/// a dead barrier peer; under Shrink the surviving shard inherits the whole
/// partition and the run completes.
#[test]
fn dead_shard_stalls_or_repartitions_by_policy() {
    let Some(man) = manifest() else { return };
    let model_name = pick_model(&man);
    let steps = 10;

    // Stall (default): shard wid 3 dies at step 3 → trainers freeze its
    // layers, the supervisor waits out the timeout and stops the run
    let mut cfg = quick_cfg(&model_name, Algorithm::AsgdPs, 4, steps);
    cfg.cluster = TopologySpec::Ps { shards: 2 };
    cfg.faults = FaultPlan::default().crash(3, 3);
    cfg.stall_timeout_s = 1.0;
    let summary = run(&cfg, &man);
    assert!(summary.stats.recovery.stalled, "a dead shard must stall the PS run");
    assert_eq!(summary.stats.recovery.crashes, 1);

    // Shrink: the surviving shard takes over the dead shard's layers (the
    // membership epoch bumps the route cache) and every trainer finishes
    let mut cfg = quick_cfg(&model_name, Algorithm::AsgdPs, 4, steps);
    cfg.cluster = TopologySpec::Ps { shards: 2 };
    cfg.faults = FaultPlan::default().crash(3, 3);
    cfg.recovery = RecoveryPolicy::Shrink;
    let summary = run(&cfg, &man);
    assert!(!summary.stats.recovery.stalled, "shrink re-partitions instead of stalling");
    assert_eq!(summary.total_steps, 2 * steps, "both trainers finish their budgets");
    assert!(summary.stats.ps.repartitions > 0, "route cache never re-partitioned");
    assert!(summary.curve.best_loss().is_finite());
}

/// `resolve` picks the latest snapshot when handed the parent directory, and
/// incompatible sessions are rejected up front.
#[test]
fn resume_resolution_and_compatibility_gates() {
    let Some(man) = manifest() else { return };
    let model_name = pick_model(&man);
    let dir = tmp_dir("resolve");
    let mut cfg = quick_cfg(&model_name, Algorithm::GoSgd, 2, 12);
    cfg.lockstep = true;
    cfg.checkpoint_every = 4;
    cfg.checkpoint_dir = dir.clone();
    let _ = run(&cfg, &man);

    let latest = checkpoint::resolve(&dir).unwrap();
    assert!(latest.ends_with("step-000008"), "latest is step 8, got {}", latest.display());

    // wrong seed: the data streams would diverge — rejected
    let mut bad = quick_cfg(&model_name, Algorithm::GoSgd, 2, 12);
    bad.lockstep = true;
    bad.seed = 7777;
    assert!(SessionBuilder::new(bad).build(&man).unwrap().resume_from(&dir).is_err());
    // wrong algorithm: rejected
    let mut other = quick_cfg(&model_name, Algorithm::AdPsgd, 2, 12);
    other.lockstep = true;
    assert!(SessionBuilder::new(other).build(&man).unwrap().resume_from(&dir).is_err());
    std::fs::remove_dir_all(&dir).ok();
}

/// Crash-injection acceptance: under a permanent worker loss, LayUp keeps
/// training on the survivors and finishes, while DDP's barrier stalls and
/// the run reports it.
#[test]
fn layup_survives_a_permanent_crash_while_ddp_reports_the_stall() {
    let Some(man) = manifest() else { return };
    let model_name = pick_model(&man);
    let steps = 10;

    // LayUp: worker 1 dies at step 3 and never returns; worker 0 finishes
    // its full step budget, gossip pushes to the dead peer become skips.
    let mut cfg = quick_cfg(&model_name, Algorithm::LayUp, 2, steps);
    cfg.faults = FaultPlan::default().crash(1, 3);
    let events: Arc<Mutex<Vec<String>>> = Arc::new(Mutex::new(Vec::new()));
    let sink = {
        let events = Arc::clone(&events);
        move |ev: &TrainEvent| {
            events.lock().unwrap().push(ev.kind().to_string());
        }
    };
    let summary = SessionBuilder::new(cfg)
        .observer(Arc::new(sink))
        .build(&man)
        .unwrap()
        .run()
        .unwrap();
    assert_eq!(summary.total_steps, steps + 3, "survivor finishes, victim stops at 3");
    assert_eq!(summary.stats.recovery.crashes, 1);
    assert_eq!(summary.stats.recovery.joins, 0);
    assert!(!summary.stats.recovery.stalled, "gossip never stalls on a dead peer");
    assert_eq!(summary.stats.recovery.membership_epoch, 1);
    assert!(summary.curve.best_loss().is_finite());
    assert!(events.lock().unwrap().iter().any(|k| k == "worker_crashed"));

    // DDP, same fault, Stall policy: the all-reduce waits for the dead
    // worker until the supervisor reports the stall and stops the run.
    let mut cfg = quick_cfg(&model_name, Algorithm::Ddp, 2, steps);
    cfg.faults = FaultPlan::default().crash(1, 3);
    cfg.stall_timeout_s = 1.0;
    let summary = run(&cfg, &man);
    assert!(summary.stats.recovery.stalled, "DDP must report the stall");
    assert!(
        summary.total_steps < 2 * steps,
        "a stalled DDP run cannot have finished: {} steps",
        summary.total_steps
    );

    // DDP, same fault, Shrink policy: the collective shrinks to the
    // survivor set and the run completes.
    let mut cfg = quick_cfg(&model_name, Algorithm::Ddp, 3, steps);
    cfg.faults = FaultPlan::default().crash(2, 3);
    cfg.recovery = RecoveryPolicy::Shrink;
    let summary = run(&cfg, &man);
    assert!(!summary.stats.recovery.stalled);
    assert_eq!(
        summary.total_steps,
        2 * steps + 3,
        "survivors finish, victim contributed 3 steps"
    );
    assert!(summary.curve.best_loss().is_finite());
}

/// Crash/restart: the worker rejoins from a live peer's parameters, the
/// membership epoch records both transitions, and every scheduled step of
/// the respawned worker still happens.
#[test]
fn crashed_worker_rejoins_and_completes_its_step_budget() {
    let Some(man) = manifest() else { return };
    let model_name = pick_model(&man);
    let steps = 14;
    let mut cfg = quick_cfg(&model_name, Algorithm::LayUp, 2, steps);
    cfg.faults = FaultPlan::default().crash_restart(1, 4, 0.2);
    let events: Arc<Mutex<Vec<String>>> = Arc::new(Mutex::new(Vec::new()));
    let sink = {
        let events = Arc::clone(&events);
        move |ev: &TrainEvent| {
            events.lock().unwrap().push(ev.kind().to_string());
        }
    };
    let summary = SessionBuilder::new(cfg)
        .observer(Arc::new(sink))
        .build(&man)
        .unwrap()
        .run()
        .unwrap();
    assert_eq!(summary.total_steps, 2 * steps, "the rejoined worker finished its budget");
    assert_eq!(summary.stats.recovery.crashes, 1);
    assert_eq!(summary.stats.recovery.joins, 1);
    assert_eq!(summary.stats.recovery.membership_epoch, 2, "dead + alive transitions");
    let kinds = events.lock().unwrap();
    assert!(kinds.iter().any(|k| k == "worker_crashed"));
    assert!(kinds.iter().any(|k| k == "worker_joined"));
}

/// Checkpoint events flow through the observer stream, and the snapshot
/// directories are complete (meta.json present — the commit marker).
#[test]
fn checkpoint_events_and_directories_are_complete() {
    let Some(man) = manifest() else { return };
    let model_name = pick_model(&man);
    let dir = tmp_dir("events");
    let mut cfg = quick_cfg(&model_name, Algorithm::GoSgd, 2, 9);
    cfg.checkpoint_every = 4;
    cfg.checkpoint_dir = dir.clone();
    let saved: Arc<Mutex<Vec<(usize, String)>>> = Arc::new(Mutex::new(Vec::new()));
    let sink = {
        let saved = Arc::clone(&saved);
        move |ev: &TrainEvent| {
            if let TrainEvent::CheckpointSaved { step, path } = ev {
                saved.lock().unwrap().push((*step, path.clone()));
            }
        }
    };
    let summary = SessionBuilder::new(cfg)
        .observer(Arc::new(sink))
        .build(&man)
        .unwrap()
        .run()
        .unwrap();
    let saved = saved.lock().unwrap();
    assert_eq!(saved.len(), 2, "snapshots at steps 4 and 8");
    assert_eq!(summary.stats.recovery.checkpoints_saved, 2);
    for (step, path) in saved.iter() {
        assert!(PathBuf::from(path).join("meta.json").exists(), "step {step} incomplete");
    }
    std::fs::remove_dir_all(&dir).ok();
}
