//! Telemetry integration tests over the full session stack. These need
//! `artifacts/` (run `make artifacts` or `make smoke` first) and auto-skip
//! politely when the manifest is missing, mirroring `integration.rs`.

use layup::config::{Algorithm, TrainConfig};
use layup::manifest::Manifest;
use layup::optim::{OptimKind, Schedule};
use layup::session::SessionBuilder;
use layup::telemetry::TelemetryConfig;
use layup::util::json::Json;

fn manifest() -> Option<Manifest> {
    let dir = layup::artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: no artifacts at {} (run `make artifacts`)", dir.display());
        return None;
    }
    Some(Manifest::load(&dir).expect("manifest parses"))
}

fn pick_model(man: &Manifest) -> String {
    if man.models.contains_key("mlpnet18") {
        "mlpnet18".into()
    } else {
        man.models.keys().next().unwrap().clone()
    }
}

fn quick_cfg(model: &str, algo: Algorithm, workers: usize, steps: usize) -> TrainConfig {
    let mut cfg = TrainConfig::new(model, algo, workers, steps);
    cfg.optim = OptimKind::sgd(0.9, 0.0);
    cfg.schedule = Schedule::Constant { lr: 0.03 };
    cfg.eval_every = (steps / 3).max(1);
    cfg
}

/// Satellite (acceptance): telemetry is off by default and, when switched
/// on, observes without perturbing — a deterministic lockstep run (DDP on
/// the instant fabric is bit-identical run-to-run) produces the exact same
/// loss curve with the recorder enabled, while only the enabled run
/// records spans.
#[test]
fn telemetry_off_is_default_and_enabling_keeps_curves_bit_identical() {
    let Some(man) = manifest() else { return };
    let model_name = pick_model(&man);
    let cfg = quick_cfg(&model_name, Algorithm::Ddp, 2, 10);

    let off = SessionBuilder::new(cfg.clone()).build(&man).unwrap().run().unwrap();
    assert!(!off.stats.telemetry.enabled, "telemetry must be opt-in");
    assert_eq!(off.stats.telemetry.spans, 0, "default run must record nothing");
    assert_eq!(off.stats.telemetry.threads, 0);

    let on = SessionBuilder::new(cfg)
        .telemetry(TelemetryConfig {
            enabled: true,
            sample_every_ms: 5,
            ..TelemetryConfig::default()
        })
        .build(&man)
        .unwrap()
        .run()
        .unwrap();
    assert!(on.stats.telemetry.enabled);
    assert!(on.stats.telemetry.spans > 0, "enabled run must record spans");
    assert!(on.stats.telemetry.threads > 0);
    assert!(on.stats.telemetry.samples > 0, "sampler must take at least the final sample");

    assert_eq!(off.curve.points.len(), on.curve.points.len());
    for (a, b) in off.curve.points.iter().zip(on.curve.points.iter()) {
        assert_eq!(a.step, b.step);
        assert_eq!(a.loss, b.loss, "telemetry must observe, not perturb");
    }

    // the summary JSON carries the new flat keys
    let j = on.to_json().dump();
    for key in ["telemetry_spans", "telemetry_dropped"] {
        assert!(j.contains(&format!("\"{key}\":")), "metrics JSON missing {key}");
    }
}

/// A traced decoupled LayUp run covers the pipeline phases end-to-end and
/// writes a parseable Chrome trace: spans on forward/backward pool tracks,
/// queue waits, optimizer steps and gossip, every span inside a declared
/// thread track.
#[test]
fn traced_decoupled_run_writes_chrome_trace_with_pipeline_phases() {
    let Some(man) = manifest() else { return };
    let model_name = pick_model(&man);
    let dir = std::env::temp_dir().join(format!("layup-telemetry-{}", std::process::id()));
    let trace_path = dir.join("trace.json");

    let mut cfg = quick_cfg(&model_name, Algorithm::LayUp, 2, 12);
    cfg.decoupled = true;
    cfg.fwd_threads = 2;
    cfg.bwd_threads = 1;
    cfg.queue_depth = 2;
    let summary = SessionBuilder::new(cfg)
        .telemetry(TelemetryConfig {
            enabled: true,
            trace_path: Some(trace_path.clone()),
            sample_every_ms: 5,
            ..TelemetryConfig::default()
        })
        .build(&man)
        .unwrap()
        .run()
        .unwrap();
    assert!(summary.stats.telemetry.spans > 0);

    let text = std::fs::read_to_string(&trace_path).expect("trace file written");
    let doc = Json::parse(&text).expect("trace parses as JSON");
    let events = doc.get("traceEvents").unwrap().as_arr().unwrap();

    let mut declared_tids = Vec::new();
    let mut phases = Vec::new();
    let mut counters = Vec::new();
    for e in events {
        let ph = e.get("ph").unwrap().as_str().unwrap();
        let name = e.get("name").unwrap().as_str().unwrap().to_string();
        match ph {
            "M" if name == "thread_name" => {
                declared_tids.push(e.get("tid").unwrap().as_f64().unwrap() as i64);
            }
            "X" => {
                assert!(e.get("dur").unwrap().as_f64().unwrap() >= 0.0);
                // each track's thread_name metadata precedes its spans
                let tid = e.get("tid").unwrap().as_f64().unwrap() as i64;
                assert!(declared_tids.contains(&tid), "span tid {tid} has no track label");
                if !phases.contains(&name) {
                    phases.push(name);
                }
            }
            "C" => {
                if !counters.contains(&name) {
                    counters.push(name);
                }
            }
            _ => {}
        }
    }
    for want in ["forward", "backward", "queue_wait", "opt_step", "gossip"] {
        assert!(phases.iter().any(|p| p == want), "trace missing {want} spans: {phases:?}");
    }
    for want in ["mfu", "queue_depth"] {
        assert!(counters.iter().any(|c| c == want), "trace missing {want} counter");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// A serial checkpointed run covers the checkpoint phase (the decoupled
/// engine rejects checkpointing, so this is the only route to it
/// end-to-end) alongside the compute and gossip phases.
#[test]
fn serial_checkpointed_run_traces_checkpoint_phase() {
    let Some(man) = manifest() else { return };
    let model_name = pick_model(&man);
    let dir = std::env::temp_dir().join(format!("layup-telemetry-ck-{}", std::process::id()));

    let mut cfg = quick_cfg(&model_name, Algorithm::LayUp, 2, 12);
    cfg.checkpoint_every = 6;
    cfg.checkpoint_dir = dir.join("ck");
    let summary = SessionBuilder::new(cfg)
        .telemetry(TelemetryConfig { enabled: true, ..TelemetryConfig::default() })
        .build(&man)
        .unwrap()
        .run()
        .unwrap();
    let phases: Vec<&str> = summary
        .stats
        .telemetry
        .phases
        .iter()
        .filter(|p| p.count > 0)
        .map(|p| p.name)
        .collect();
    for want in ["forward", "backward", "checkpoint", "gossip"] {
        assert!(phases.contains(&want), "missing {want} in {phases:?}");
    }
    let _ = std::fs::remove_dir_all(&dir);
}
