//! Property-based tests on coordinator invariants (routing, push-sum,
//! mixing, scheduling). The offline crate set has no proptest, so this file
//! carries a minimal property harness: seeded random-case generation with
//! failing-seed reporting — rerun a failure with `PROP_SEED=<seed>`.

use std::sync::Arc;

use layup::comm::{
    Codec, CodecSpec, Compressed, Fabric, FrameEntry, LatencyDist, Payload, PushOutcome,
    SimFabric,
};
use layup::coordinator::Shared;
use layup::metrics::{Curve, CurvePoint};
use layup::model::ModelParams;
use layup::optim::Schedule;
use layup::sim::{simulate, Cluster, SimAlgo, Workload};
use layup::tensor::clock::{ClockStamp, LayerClock};
use layup::tensor::{AtomicTensor, LayerParams, Tensor};
use layup::topology::{PushSumWeight, Topology};
use layup::util::rng::Pcg32;

/// Run `f` over `cases` random seeds; panic with the failing seed.
fn prop(name: &str, cases: usize, f: impl Fn(&mut Pcg32)) {
    if let Ok(s) = std::env::var("PROP_SEED") {
        let seed: u64 = s.parse().unwrap();
        f(&mut Pcg32::new(seed));
        return;
    }
    for case in 0..cases {
        let seed = prop_seed_base() ^ (case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            f(&mut Pcg32::new(seed));
        }));
        if result.is_err() {
            panic!("property {name} failed for PROP_SEED={seed}");
        }
    }
}

fn prop_seed_base() -> u64 {
    0xBADC_0FFE
}

#[test]
fn prop_push_sum_weight_conservation() {
    // any interleaving of halve/accept/skip/reclaim conserves total weight
    prop("push_sum_conservation", 50, |rng| {
        let m = 2 + rng.below_usize(6);
        let weights: Vec<PushSumWeight> =
            (0..m).map(|_| PushSumWeight::new(1.0 / m as f32)).collect();
        for _ in 0..200 {
            let i = rng.below_usize(m);
            let j = rng.peer(i, m);
            let shipped = weights[i].halve();
            match weights[j].try_accept(shipped) {
                Some(_) => {
                    // sometimes "forget" to release immediately to provoke skips
                    if rng.next_f32() < 0.8 {
                        weights[j].release();
                    }
                }
                None => weights[i].reclaim(shipped),
            }
        }
        for w in &weights {
            w.release(); // drain any held slots
        }
        let total: f32 = weights.iter().map(|w| w.get()).sum();
        assert!((total - 1.0).abs() < 1e-4, "weight mass drifted: {total}");
    });
}

/// Mirror of `prop_push_sum_weight_conservation` on the simulated fabric:
/// random whole-model push-sum pushes over links with latency and 30% loss.
/// Total weight mass (at the workers + riding the links) stays 1, and the
/// push-sum invariant `sum_i w_i * x_i` (+ in-flight `w_in * x_in`) is
/// conserved: drops reclaim at the sender, deliveries fold at the receiver,
/// in-flight messages merely *delay* — mass is never destroyed.
#[test]
fn prop_sim_fabric_push_sum_mass_delayed_never_destroyed() {
    prop("sim_fabric_mass", 20, |rng| {
        let m = 2 + rng.below_usize(4);
        let dim = 3usize;
        let params: Vec<Arc<ModelParams>> = (0..m)
            .map(|_| {
                let t = Tensor::from_vec(&[dim], (0..dim).map(|_| rng.normal()).collect());
                Arc::new(ModelParams {
                    layers: vec![LayerParams::new(vec![AtomicTensor::from_tensor(&t)])],
                })
            })
            .collect();
        let latency = match rng.below_usize(3) {
            0 => LatencyDist::Constant(0.0),
            1 => LatencyDist::Uniform { lo: 0.0, hi: 0.001 },
            _ => LatencyDist::Pareto { scale: 1e-4, alpha: 2.0 },
        };
        let fabric = Arc::new(SimFabric::new(latency, 0.0, 0.3, m, rng.next_u64()));
        let shared = Shared::for_tests(params, fabric.clone());

        let mass = |shared: &Shared, fabric: &SimFabric| -> (f64, Vec<f64>) {
            let (mut w, mut wx) = fabric.in_flight_push_sum_mass();
            wx.resize(dim, 0.0);
            for i in 0..shared.m {
                let wi = shared.weights[i].get() as f64;
                w += wi;
                for (k, v) in shared.params[i].flatten().iter().enumerate() {
                    wx[k] += wi * *v as f64;
                }
            }
            (w, wx)
        };
        let (w0, p0) = mass(&shared, &fabric);
        assert!((w0 - 1.0).abs() < 1e-4, "initial mass {w0}");

        for round in 0..80 {
            let i = rng.below_usize(m);
            let j = rng.peer(i, m);
            let shipped = shared.weights[i].halve();
            let values: Vec<Vec<Vec<f32>>> = shared.params[i]
                .layers
                .iter()
                .map(|l| l.tensors.iter().map(|t| t.snapshot().data).collect())
                .collect();
            match shared.fabric.push(
                &shared,
                i,
                j,
                round,
                Payload::ModelPush { w_in: shipped, values: Arc::new(values) },
            ) {
                PushOutcome::Dropped | PushOutcome::Busy => {
                    shared.weights[i].reclaim(shipped);
                }
                _ => {}
            }
            if rng.next_f32() < 0.6 {
                shared.fabric.deliver_due(&shared, rng.below_usize(m), round);
            }
            if round % 16 == 0 {
                let (w, p) = mass(&shared, &fabric);
                assert!((w - 1.0).abs() < 1e-3, "weight mass drifted mid-flight: {w}");
                for k in 0..dim {
                    assert!(
                        (p[k] - p0[k]).abs() < 1e-3 * (1.0 + p0[k].abs()),
                        "weighted parameter mass drifted: {} vs {}",
                        p[k],
                        p0[k]
                    );
                }
            }
        }
        // give the links a moment, drain what is due, re-check: whatever
        // was not delivered is still accounted in flight
        std::thread::sleep(std::time::Duration::from_millis(3));
        for w in 0..m {
            shared.fabric.deliver_due(&shared, w, 100);
        }
        let (w1, p1) = mass(&shared, &fabric);
        assert!((w1 - 1.0).abs() < 1e-3, "weight mass destroyed: {w1}");
        for k in 0..dim {
            assert!(
                (p1[k] - p0[k]).abs() < 1e-3 * (1.0 + p0[k].abs()),
                "parameter mass destroyed: {} vs {}",
                p1[k],
                p0[k]
            );
        }
    });
}

/// Checkpoint-quiesce property (resilience subsystem): draining every inbox
/// and restoring the same messages — exactly what a checkpoint does to the
/// links — is invisible to push-sum mass: total weight and the weighted
/// parameter sum are unchanged at every quiesce point and after final
/// delivery.
#[test]
fn prop_sim_fabric_drain_restore_conserves_mass() {
    prop("drain_restore_mass", 20, |rng| {
        let m = 2 + rng.below_usize(4);
        let dim = 3usize;
        let params: Vec<Arc<ModelParams>> = (0..m)
            .map(|_| {
                let t = Tensor::from_vec(&[dim], (0..dim).map(|_| rng.normal()).collect());
                Arc::new(ModelParams {
                    layers: vec![LayerParams::new(vec![AtomicTensor::from_tensor(&t)])],
                })
            })
            .collect();
        let fabric = Arc::new(SimFabric::new(
            LatencyDist::Uniform { lo: 0.0, hi: 0.002 },
            0.0,
            0.3,
            m,
            rng.next_u64(),
        ));
        let shared = Shared::for_tests(params, fabric.clone());

        let mass = |shared: &Shared, fabric: &SimFabric| -> f64 {
            let (mut w, _) = fabric.in_flight_push_sum_mass();
            for i in 0..shared.m {
                w += shared.weights[i].get() as f64;
            }
            w
        };
        assert!((mass(&shared, &fabric) - 1.0).abs() < 1e-4);

        for round in 0..60 {
            let i = rng.below_usize(m);
            let j = rng.peer(i, m);
            let shipped = shared.weights[i].halve();
            let values: Vec<Vec<Vec<f32>>> = shared.params[i]
                .layers
                .iter()
                .map(|l| l.tensors.iter().map(|t| t.snapshot().data).collect())
                .collect();
            match shared.fabric.push(
                &shared,
                i,
                j,
                round,
                Payload::ModelPush { w_in: shipped, values: Arc::new(values) },
            ) {
                PushOutcome::Dropped | PushOutcome::Busy => {
                    shared.weights[i].reclaim(shipped);
                }
                _ => {}
            }
            if round % 10 == 9 {
                // the checkpoint quiesce: pull everything off the links...
                let mut msgs = Vec::new();
                for w in 0..m {
                    msgs.extend(shared.fabric.drain(w));
                }
                let (w_links, _) = fabric.in_flight_push_sum_mass();
                assert_eq!(w_links, 0.0, "drained links hold no mass");
                // ...and put the very same messages back
                shared.fabric.restore(&shared, msgs);
                let w = mass(&shared, &fabric);
                assert!((w - 1.0).abs() < 1e-3, "mass drifted across drain/restore: {w}");
            }
        }
        std::thread::sleep(std::time::Duration::from_millis(4));
        for w in 0..m {
            shared.fabric.deliver_due(&shared, w, 100);
        }
        let w = mass(&shared, &fabric);
        assert!((w - 1.0).abs() < 1e-3, "mass destroyed: {w}");
    });
}

#[test]
fn prop_mix_from_is_convex_and_bounded() {
    prop("mix_convex", 50, |rng| {
        let n = 1 + rng.below_usize(64);
        let a: Vec<f32> = (0..n).map(|_| rng.normal() * 3.0).collect();
        let b: Vec<f32> = (0..n).map(|_| rng.normal() * 3.0).collect();
        let at = AtomicTensor::from_tensor(&Tensor::from_vec(&[n], a.clone()));
        let frac = rng.next_f32();
        at.mix_from(1.0 - frac, frac, &b);
        for (k, v) in at.snapshot().data.iter().enumerate() {
            let (lo, hi) = (a[k].min(b[k]), a[k].max(b[k]));
            assert!(
                *v >= lo - 1e-4 && *v <= hi + 1e-4,
                "mix left the [min,max] interval: {v} not in [{lo},{hi}]"
            );
        }
    });
}

#[test]
fn prop_fused_update_mix_equals_three_pass() {
    // the §Perf fused updater write must be bit-identical to the original
    // sub_scaled + load_into + mix_from sequence for any shape/lr/fraction
    prop("fused_update_mix", 50, |rng| {
        let n = 1 + rng.below_usize(128);
        let init: Vec<f32> = (0..n).map(|_| rng.normal() * 2.0).collect();
        let grad: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let peer_init: Vec<f32> = (0..n).map(|_| rng.normal() * 2.0).collect();
        let lr = rng.next_f32() * 0.2;
        let frac = rng.next_f32();

        let a = AtomicTensor::from_tensor(&Tensor::from_vec(&[n], init.clone()));
        let p = AtomicTensor::from_tensor(&Tensor::from_vec(&[n], peer_init.clone()));
        a.sub_scaled(lr, &grad);
        let mut scratch = vec![0.0f32; n];
        a.load_into(&mut scratch);
        p.mix_from(1.0 - frac, frac, &scratch);

        let af = AtomicTensor::from_tensor(&Tensor::from_vec(&[n], init));
        let pf = AtomicTensor::from_tensor(&Tensor::from_vec(&[n], peer_init));
        af.sub_scaled_then_mix_into(lr, &grad, &pf, 1.0 - frac, frac);

        assert_eq!(af.snapshot().data, a.snapshot().data, "local update differs");
        assert_eq!(pf.snapshot().data, p.snapshot().data, "peer mix differs");
    });
}

#[test]
fn prop_topology_peer_valid_for_all_shapes() {
    prop("topology_valid", 50, |rng| {
        let m = 2 + rng.below_usize(15);
        for topo in [Topology::Random, Topology::Ring, Topology::Groups(1 + rng.below_usize(4))] {
            for me in 0..m {
                for it in 0..20u64 {
                    let j = topo.peer(me, m, it, rng);
                    assert!(j < m && j != me, "{topo:?} produced {j} for me={me}, m={m}");
                }
            }
        }
    });
}

#[test]
fn prop_schedules_are_nonnegative_and_bounded() {
    prop("schedule_bounds", 50, |rng| {
        let lr = rng.next_f32() * 0.5 + 1e-4;
        let t_max = 10 + rng.below_usize(500);
        let warmup = rng.below_usize(t_max / 2);
        for sched in [
            Schedule::Constant { lr },
            Schedule::Cosine { lr, t_max, warmup_steps: warmup, warmup_lr: lr / 10.0 },
            Schedule::Linear { lr, t_max, warmup_steps: warmup, warmup_lr: lr / 10.0 },
        ] {
            for step in 0..t_max + 50 {
                let v = sched.lr_at(step);
                assert!(v >= -1e-7, "negative lr {v} at {step} for {sched:?}");
                assert!(v <= lr * 1.0001, "lr {v} exceeds peak {lr} at {step} for {sched:?}");
            }
        }
    });
}

#[test]
fn prop_curve_tta_monotone_in_target() {
    // a harder target can never be reached *earlier*
    prop("tta_monotone", 50, |rng| {
        let mut pts = Vec::new();
        let mut acc: f64 = 0.0;
        for step in 0..30usize {
            acc = (acc + rng.next_f64() * 0.08).min(1.0);
            pts.push(CurvePoint {
                step,
                time_s: step as f64,
                loss: 1.0 - acc,
                accuracy: acc,
            });
        }
        let curve = Curve { points: pts };
        let (t1, t2) = (0.3, 0.6);
        if let (Some(a), Some(b)) = (curve.time_to_accuracy(t1), curve.time_to_accuracy(t2)) {
            assert!(a <= b, "harder target reached earlier: {a} vs {b}");
        }
    });
}

#[test]
fn prop_sim_occupancy_in_unit_interval_and_layup_never_slower_than_ddp() {
    prop("sim_sane", 30, |rng| {
        let m = 2 + rng.below_usize(7);
        let mut c = Cluster::new("t", m, 1e9 + rng.next_f64() * 4e11, 1e-5, 0.7);
        c.jitter = rng.next_f64() * 0.1;
        if rng.next_f32() < 0.5 {
            c = c.with_straggler(rng.below_usize(m), rng.next_f64() * 16.0);
        }
        let w = Workload::resnet18_cifar(m);
        for algo in SimAlgo::paper_set(1 + rng.below_usize(40)) {
            let r = simulate(&c, &w, algo, rng.next_u64());
            assert!(r.wall_s.is_finite() && r.wall_s > 0.0, "{algo:?} wall {}", r.wall_s);
            assert!(
                (0.0..=1.0 + 1e-9).contains(&r.occupancy),
                "{algo:?} occupancy {}",
                r.occupancy
            );
        }
        let ddp = simulate(&c, &w, SimAlgo::Ddp, 7).wall_s;
        let layup = simulate(&c, &w, SimAlgo::LayUp, 7).wall_s;
        assert!(layup <= ddp * 1.05, "LayUp slower than DDP: {layup} vs {ddp}");
    });
}

#[test]
fn prop_atomic_store_load_roundtrip_any_pattern() {
    prop("atomic_roundtrip", 50, |rng| {
        let n = 1 + rng.below_usize(256);
        let vals: Vec<f32> = (0..n)
            .map(|_| {
                // exercise odd bit patterns too (subnormals, negatives)
                f32::from_bits(rng.next_u32() & 0x7fff_ffff)
            })
            .map(|v| if v.is_nan() { 0.0 } else { v })
            .collect();
        let at = AtomicTensor::zeros(&[n]);
        at.store_from(&vals);
        assert_eq!(at.snapshot().data, vals);
    });
}

/// Staleness-clock property: the version counter is strictly monotone and
/// exact under any interleaving of concurrent writers — every `record` is
/// counted exactly once, so observed τ can never under-count intervening
/// writes — and a sequential tail always leaves the last writer's
/// provenance visible.
#[test]
fn prop_layer_clock_monotone_under_concurrent_writers() {
    prop("clock_monotone", 10, |rng| {
        let clock = Arc::new(LayerClock::new());
        let writers = 2 + rng.below_usize(4);
        let per = 200 + rng.below_usize(300);
        std::thread::scope(|scope| {
            for t in 0..writers {
                let clock = Arc::clone(&clock);
                scope.spawn(move || {
                    let mut last = 0u64;
                    for i in 0..per {
                        clock.record(t, i);
                        let v = clock.version();
                        assert!(v > last, "version went backwards: {v} <= {last}");
                        last = v;
                    }
                });
            }
        });
        assert_eq!(clock.version() as usize, writers * per, "every write counted once");
        // sequential tail: provenance is last-writer-wins
        clock.record(7, 42);
        let s = clock.stamp();
        assert_eq!((s.worker, s.step), (7, 42));
        assert_eq!(s.version as usize, writers * per + 1);
    });
}

/// Clock provenance is conserved by the checkpoint quiesce (`Fabric::drain`
/// / `restore`): a layer-wise push pulled off the links and re-injected
/// still stamps the receiver's clock with the sender's exact `(worker,
/// step)` provenance on delivery, and the mixing it performs is identical.
#[test]
fn prop_drain_restore_conserves_clock_provenance() {
    prop("drain_restore_clocks", 20, |rng| {
        let dim = 2usize;
        let mk = |v: f32| {
            Arc::new(ModelParams {
                layers: vec![LayerParams::new(vec![AtomicTensor::from_tensor(
                    &Tensor::from_vec(&[dim], vec![v; dim]),
                )])],
            })
        };
        let fabric =
            Arc::new(SimFabric::new(LatencyDist::Constant(0.0), 0.0, 0.0, 2, rng.next_u64()));
        let shared = Shared::for_tests(vec![mk(0.0), mk(1.0)], fabric.clone());

        let sender_step = 3 + rng.below_usize(50);
        shared.params[0].layers[0].clock.record(0, sender_step);
        let stamp = shared.params[0].layers[0].clock.stamp();
        let shipped = shared.weights[0].halve();
        let out = shared.fabric.push(
            &shared,
            0,
            1,
            sender_step,
            Payload::LayerPush {
                layer: 0,
                open: Some(shipped),
                values: Arc::new(vec![vec![5.0; dim]]),
                stamp,
                tau: 2,
            },
        );
        assert_eq!(out, PushOutcome::Queued);

        // checkpoint quiesce: drain, then restore the very same messages
        let msgs = shared.fabric.drain(1);
        assert_eq!(msgs.len(), 1);
        shared.fabric.restore(&shared, msgs);

        let receiver_before = shared.params[1].layers[0].clock.version();
        assert_eq!(shared.fabric.deliver_due(&shared, 1, sender_step + 1), 1);
        let got = shared.params[1].layers[0].clock.stamp();
        assert_eq!(
            (got.worker, got.step),
            (stamp.worker, stamp.step),
            "delivered push must carry the sender's provenance through drain/restore"
        );
        assert_eq!(got.version, receiver_before + 1, "exactly one stamped write");
        let total = shared.weights[0].get() + shared.weights[1].get();
        assert!((total - 1.0).abs() < 1e-5, "push-sum mass conserved: {total}");
    });
}

// ---------------------------------------------------------------------------
// comm::codec properties (PR 8): round-trip, error feedback, truncation,
// push-sum composition
// ---------------------------------------------------------------------------

/// A 2-worker Shared with one layer of one `n`-element tensor per replica.
fn codec_shared(
    rng: &mut Pcg32,
    n: usize,
    fabric: Arc<SimFabric>,
) -> (Arc<Shared>, Vec<f32>, Vec<f32>) {
    let mk = |vals: &[f32]| {
        Arc::new(ModelParams {
            layers: vec![LayerParams::new(vec![AtomicTensor::from_tensor(&Tensor::from_vec(
                &[vals.len()],
                vals.to_vec(),
            ))])],
        })
    };
    let a: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
    let b: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
    let shared = Shared::for_tests(vec![mk(&a), mk(&b)], fabric);
    (shared, a, b)
}

fn dense_fabric(rng: &mut Pcg32, m: usize) -> Arc<SimFabric> {
    Arc::new(SimFabric::new(LatencyDist::Constant(0.0), 0.0, 0.0, m, rng.next_u64()))
}

/// Codec round-trip: sparsifiers reproduce every kept coordinate bit-exactly
/// and fill the rest from the receiver's current state; int8 lands within
/// one per-chunk quantization step of the input; dense is the identity.
#[test]
fn prop_codec_roundtrip_within_tolerance() {
    prop("codec_roundtrip", 25, |rng| {
        let n = 1 + rng.below_usize(300);
        let fabric = dense_fabric(rng, 2);
        let (shared, sent, receiver) = codec_shared(rng, n, fabric);
        let payload = Payload::LayerPush {
            layer: 0,
            open: None,
            values: Arc::new(vec![sent.clone()]),
            stamp: ClockStamp { worker: 0, step: 1, version: 1 },
            tau: 0,
        };

        // dense: the identity — no Compressed wrapper at all
        let dense = CodecSpec::Dense.build(2, rng.next_u64());
        match dense.encode(&shared.update_pool, 0, 1, payload.clone()) {
            Payload::LayerPush { values, .. } => assert_eq!(values[0], sent),
            _ => panic!("dense codec must be the identity"),
        }

        for spec_str in ["topk:4", "randk:4"] {
            let spec = CodecSpec::parse(spec_str).unwrap();
            let codec = spec.build(2, rng.next_u64());
            let Payload::Compressed(c) = codec.encode(&shared.update_pool, 0, 1, payload.clone())
            else {
                panic!("{spec_str} must wrap the payload");
            };
            let Payload::LayerPush { values, .. } = c.decode(&shared, 1).unwrap() else {
                panic!("decode changed the payload kind");
            };
            let keep = n.div_ceil(4).max(1);
            let mut from_sender = 0;
            for i in 0..n {
                let got = values[0][i].to_bits();
                if got == sent[i].to_bits() && sent[i].to_bits() != receiver[i].to_bits() {
                    from_sender += 1;
                } else {
                    // unsent state coordinates keep the receiver's value
                    assert_eq!(
                        got,
                        receiver[i].to_bits(),
                        "{spec_str}: coordinate {i} is neither the sender's nor the receiver's"
                    );
                }
            }
            assert_eq!(from_sender, keep, "{spec_str} ships exactly ceil(n/K) coordinates");
        }

        let int8 = CodecSpec::Int8.build(2, rng.next_u64());
        let Payload::Compressed(c) = int8.encode(&shared.update_pool, 0, 1, payload.clone())
        else {
            panic!("int8 must wrap the payload");
        };
        let Payload::LayerPush { values, .. } = c.decode(&shared, 1).unwrap() else {
            panic!("decode changed the payload kind");
        };
        // stochastic rounding moves each value by at most one quantization
        // step of its 1024-element chunk's max-abs scale
        for (chunk_i, chunk) in sent.chunks(1024).enumerate() {
            let scale = chunk.iter().fold(0.0f32, |m, v| m.max(v.abs()));
            let step = scale / 127.0 + 1e-6;
            for (j, &x) in chunk.iter().enumerate() {
                let got = values[0][chunk_i * 1024 + j];
                assert!(
                    (got - x).abs() <= step,
                    "int8 moved {x} to {got} (> one step {step})"
                );
            }
        }
    });
}

/// Error-feedback conservation, bit-exact for top-k: every round, each
/// coordinate of the accumulated gradient `y = x + r_before` ends up either
/// on the wire (kept, residual zeroed) or in the new residual — never both,
/// never neither, never rounded.
#[test]
fn prop_codec_error_feedback_conserves_gradient_mass() {
    prop("codec_error_feedback", 25, |rng| {
        let n = 2 + rng.below_usize(200);
        let fabric = dense_fabric(rng, 2);
        let (shared, _, _) = codec_shared(rng, n, fabric);
        for spec_str in ["topk:4", "randk:4"] {
            let codec = CodecSpec::parse(spec_str).unwrap().build(2, rng.next_u64());
            let mut r_before = vec![0.0f32; n];
            for _round in 0..6 {
                let x: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
                let payload = Payload::GradShare {
                    set: Arc::new(vec![vec![Tensor::from_vec(&[n], x.clone())]]),
                };
                let Payload::Compressed(c) =
                    codec.encode(&shared.update_pool, 0, 1, payload)
                else {
                    panic!("{spec_str} must wrap the payload");
                };
                let Payload::GradShare { set } = c.decode(&shared, 1).unwrap() else {
                    panic!("decode changed the payload kind");
                };
                let delivered = &set[0][0].data;
                let state = codec.residual_state();
                let link = state
                    .iter()
                    .find(|s| s.from == 0 && s.to == 1)
                    .expect("link 0->1 accumulated a residual");
                let (_, r_after) = &link.streams[0];
                for i in 0..n {
                    let y = x[i] + r_before[i];
                    if delivered[i].to_bits() == 0.0f32.to_bits() && r_after[i] != 0.0 {
                        assert_eq!(
                            r_after[i].to_bits(),
                            y.to_bits(),
                            "{spec_str}: unsent coordinate {i} must sit in the residual bit-exactly"
                        );
                    } else {
                        assert_eq!(
                            delivered[i].to_bits(),
                            y.to_bits(),
                            "{spec_str}: sent coordinate {i} must ship the accumulated value"
                        );
                        assert_eq!(r_after[i], 0.0, "sent coordinate {i} must leave the residual");
                    }
                }
                r_before = r_after.clone();
            }
        }
    });
}

/// Truncation-safe decode: every strict prefix of a compressed blob fails to
/// decode (all-or-nothing — no partial apply), and a truncated message on
/// the fabric surfaces as a rejected delivery with the push-sum weight
/// refunded to the sender, the receiver's replica untouched.
#[test]
fn prop_codec_truncated_blob_is_malformed_and_refunds_weight() {
    prop("codec_truncation", 10, |rng| {
        let n = 2 + rng.below_usize(60);
        let codec = CodecSpec::parse("topk:4").unwrap().build(2, rng.next_u64());
        let fabric = Arc::new(SimFabric::with_codec(
            LatencyDist::Constant(0.0),
            0.0,
            0.0,
            2,
            rng.next_u64(),
            Arc::clone(&codec),
        ));
        let (shared, sent, receiver) = codec_shared(rng, n, fabric);
        let payload = Payload::ModelPush {
            w_in: 0.25,
            values: Arc::new(vec![vec![sent.clone()]]),
        };
        let Payload::Compressed(c) = codec.encode(&shared.update_pool, 0, 1, payload) else {
            panic!("topk must wrap the payload");
        };
        // every strict prefix is rejected before any coordinate lands
        for cut in 0..c.blob.len() {
            let trunc = Compressed {
                spec: c.spec.clone(),
                shipped_w: c.shipped_w,
                droppable: c.droppable,
                blob: Arc::new(c.blob[..cut].to_vec()),
            };
            assert!(trunc.decode(&shared, 1).is_err(), "prefix of {cut} bytes decoded");
        }

        // on the fabric: the malformed message is rejected at delivery and
        // the weight it carried is reclaimed by the sender
        let shipped = shared.weights[0].halve();
        let cut = rng.below_usize(c.blob.len());
        let mangled = Payload::Compressed(Compressed {
            spec: c.spec.clone(),
            shipped_w: shipped,
            droppable: c.droppable,
            blob: Arc::new(c.blob[..cut].to_vec()),
        });
        assert_eq!(shared.fabric.push(&shared, 0, 1, 1, mangled), PushOutcome::Queued);
        assert_eq!(shared.fabric.deliver_due(&shared, 1, 2), 0, "malformed must not apply");
        let total = shared.weights[0].get() + shared.weights[1].get();
        assert!((total - 1.0).abs() < 1e-5, "weight not refunded: {total}");
        assert_eq!(
            shared.params[1].flatten(),
            receiver,
            "a malformed message must never partially write the receiver's replica"
        );
    });
}

/// Push-sum weight mass is conserved with a sparsifying codec on lossy
/// links: drops reclaim (outcome-driven at the sender, residuals inside the
/// codec), deliveries fold at the receiver, in-flight compressed messages
/// carry their weight in the clear.
#[test]
fn prop_codec_push_sum_weight_mass_conserved_under_drops() {
    prop("codec_mass_drops", 15, |rng| {
        let m = 2 + rng.below_usize(3);
        let n = 24usize;
        let codec = CodecSpec::parse("topk:8").unwrap().build(m, rng.next_u64());
        let fabric = Arc::new(SimFabric::with_codec(
            LatencyDist::Constant(0.0),
            0.0,
            0.3,
            m,
            rng.next_u64(),
            codec,
        ));
        let params: Vec<Arc<ModelParams>> = (0..m)
            .map(|_| {
                let t = Tensor::from_vec(&[n], (0..n).map(|_| rng.normal()).collect());
                Arc::new(ModelParams {
                    layers: vec![LayerParams::new(vec![AtomicTensor::from_tensor(&t)])],
                })
            })
            .collect();
        let shared = Shared::for_tests(params, fabric.clone());

        let mass = |shared: &Shared, fabric: &SimFabric| -> f64 {
            let (mut w, _) = fabric.in_flight_push_sum_mass();
            for i in 0..shared.m {
                w += shared.weights[i].get() as f64;
            }
            w
        };
        assert!((mass(&shared, &fabric) - 1.0).abs() < 1e-4);

        for round in 0..80 {
            let i = rng.below_usize(m);
            let j = rng.peer(i, m);
            let shipped = shared.weights[i].halve();
            let values: Vec<Vec<Vec<f32>>> = shared.params[i]
                .layers
                .iter()
                .map(|l| l.tensors.iter().map(|t| t.snapshot().data).collect())
                .collect();
            match shared.fabric.push(
                &shared,
                i,
                j,
                round,
                Payload::ModelPush { w_in: shipped, values: Arc::new(values) },
            ) {
                PushOutcome::Dropped | PushOutcome::Busy => {
                    shared.weights[i].reclaim(shipped);
                }
                _ => {}
            }
            if rng.next_f32() < 0.6 {
                shared.fabric.deliver_due(&shared, rng.below_usize(m), round);
            }
            if round % 16 == 0 {
                let w = mass(&shared, &fabric);
                assert!((w - 1.0).abs() < 1e-3, "weight mass drifted mid-flight: {w}");
            }
        }
        for w in 0..m {
            shared.fabric.deliver_due(&shared, w, 100);
        }
        let w = mass(&shared, &fabric);
        assert!((w - 1.0).abs() < 1e-3, "weight mass destroyed under topk + drops: {w}");
    });
}

// ---------------------------------------------------------------------------
// step-frame coalescing properties (PR 10): frame round-trip, truncation,
// drain/restore provenance, gradient-stream isolation
// ---------------------------------------------------------------------------

/// A 2-worker Shared with one single-tensor layer per entry of `sizes`;
/// returns the per-layer sender and receiver values alongside it.
fn frame_shared(
    rng: &mut Pcg32,
    sizes: &[usize],
    fabric: Arc<SimFabric>,
) -> (Arc<Shared>, Vec<Vec<f32>>, Vec<Vec<f32>>) {
    let mk = |layers: &[Vec<f32>]| {
        Arc::new(ModelParams {
            layers: layers
                .iter()
                .map(|vals| {
                    LayerParams::new(vec![AtomicTensor::from_tensor(&Tensor::from_vec(
                        &[vals.len()],
                        vals.clone(),
                    ))])
                })
                .collect(),
        })
    };
    let a: Vec<Vec<f32>> =
        sizes.iter().map(|&n| (0..n).map(|_| rng.normal()).collect()).collect();
    let b: Vec<Vec<f32>> =
        sizes.iter().map(|&n| (0..n).map(|_| rng.normal()).collect()).collect();
    let shared = Shared::for_tests(vec![mk(&a), mk(&b)], fabric);
    (shared, a, b)
}

/// A whole-step frame with one entry per layer, deepest first (the order the
/// backward pass produces), carrying the sender's values.
fn step_frame(open: Option<f32>, sent: &[Vec<f32>], step: u64) -> Payload {
    let entries: Vec<FrameEntry> = (0..sent.len())
        .rev()
        .map(|l| FrameEntry {
            layer: l,
            stamp: ClockStamp { worker: 0, step, version: 1 + l as u64 },
            tau: l as u64,
            values: Arc::new(vec![sent[l].clone()]),
        })
        .collect();
    Payload::StepFrame { open, entries: Arc::new(entries) }
}

/// StepFrame round-trip through every codec: dense is the identity;
/// sparsifiers rank the step's coordinates GLOBALLY — exactly
/// `ceil(total/K)` sender coordinates across all layers, not per layer —
/// with the rest filled from the receiver; int8 stays within one
/// quantization step per 1024-chunk of the concatenated mass. Entry
/// metadata (layer ids, stamps, τ) round-trips exactly.
#[test]
fn prop_step_frame_roundtrip_all_codecs() {
    prop("frame_roundtrip", 20, |rng| {
        let sizes =
            vec![1 + rng.below_usize(80), 1 + rng.below_usize(80), 1 + rng.below_usize(80)];
        let total: usize = sizes.iter().sum();
        let fabric = dense_fabric(rng, 2);
        let (shared, sent, receiver) = frame_shared(rng, &sizes, fabric);
        let payload = step_frame(None, &sent, 1);

        // dense: the identity — no Compressed wrapper at all
        let dense = CodecSpec::Dense.build(2, rng.next_u64());
        match dense.encode(&shared.update_pool, 0, 1, payload.clone()) {
            Payload::StepFrame { entries, .. } => {
                for (l, e) in (0..sizes.len()).rev().zip(entries.iter()) {
                    assert_eq!(e.values[0], sent[l]);
                }
            }
            _ => panic!("dense codec must be the identity"),
        }

        for spec_str in ["topk:4", "randk:4"] {
            let spec = CodecSpec::parse(spec_str).unwrap();
            let codec = spec.build(2, rng.next_u64());
            let Payload::Compressed(c) =
                codec.encode(&shared.update_pool, 0, 1, payload.clone())
            else {
                panic!("{spec_str} must wrap the frame");
            };
            let Payload::StepFrame { open, entries } = c.decode(&shared, 1).unwrap() else {
                panic!("decode changed the payload kind");
            };
            assert!(open.is_none());
            assert_eq!(entries.len(), sizes.len());
            let mut from_sender = 0;
            for (e, l) in entries.iter().zip((0..sizes.len()).rev()) {
                assert_eq!(e.layer, l, "{spec_str}: entry order scrambled");
                assert_eq!((e.stamp.worker, e.stamp.version), (0, 1 + l as u64));
                assert_eq!(e.tau, l as u64);
                for i in 0..sizes[l] {
                    let got = e.values[0][i].to_bits();
                    if got == sent[l][i].to_bits() && sent[l][i].to_bits() != receiver[l][i].to_bits()
                    {
                        from_sender += 1;
                    } else {
                        assert_eq!(
                            got,
                            receiver[l][i].to_bits(),
                            "{spec_str}: layer {l} coord {i} is neither sender's nor receiver's"
                        );
                    }
                }
            }
            assert_eq!(
                from_sender,
                total.div_ceil(4),
                "{spec_str} must ship exactly ceil(total/K) coordinates ranked across the step"
            );
        }

        // int8: one stream over the concatenation, so quantization chunks
        // span layer boundaries — check against the concatenated order
        let int8 = CodecSpec::Int8.build(2, rng.next_u64());
        let Payload::Compressed(c) = int8.encode(&shared.update_pool, 0, 1, payload) else {
            panic!("int8 must wrap the frame");
        };
        let Payload::StepFrame { entries, .. } = c.decode(&shared, 1).unwrap() else {
            panic!("decode changed the payload kind");
        };
        let mut concat_sent: Vec<f32> = Vec::new();
        let mut concat_got: Vec<f32> = Vec::new();
        for (e, l) in entries.iter().zip((0..sizes.len()).rev()) {
            concat_sent.extend_from_slice(&sent[l]);
            concat_got.extend_from_slice(&e.values[0]);
        }
        for (chunk_i, chunk) in concat_sent.chunks(1024).enumerate() {
            let scale = chunk.iter().fold(0.0f32, |m, v| m.max(v.abs()));
            let step = scale / 127.0 + 1e-6;
            for (j, &x) in chunk.iter().enumerate() {
                let got = concat_got[chunk_i * 1024 + j];
                assert!((got - x).abs() <= step, "int8 moved {x} to {got} (> one step {step})");
            }
        }
    });
}

/// All-or-nothing frames: every strict prefix of a compressed StepFrame
/// blob fails to decode, and a truncated frame on the fabric is rejected at
/// delivery with the step's opening push-sum weight refunded to the sender
/// and the receiver's replica untouched — a frame aggregates a whole step,
/// so a partial apply would desynchronize layers within one step.
#[test]
fn prop_step_frame_truncated_blob_rejects_whole_frame_and_refunds() {
    prop("frame_truncation", 10, |rng| {
        let sizes = vec![2 + rng.below_usize(40), 2 + rng.below_usize(40)];
        let codec = CodecSpec::parse("topk:4").unwrap().build(2, rng.next_u64());
        let fabric = Arc::new(SimFabric::with_codec(
            LatencyDist::Constant(0.0),
            0.0,
            0.0,
            2,
            rng.next_u64(),
            Arc::clone(&codec),
        ));
        let (shared, sent, _) = frame_shared(rng, &sizes, fabric);
        let receiver_before = shared.params[1].flatten();

        let shipped = shared.weights[0].halve();
        let Payload::Compressed(c) =
            codec.encode(&shared.update_pool, 0, 1, step_frame(Some(shipped), &sent, 2))
        else {
            panic!("topk must wrap the frame");
        };
        assert_eq!(c.shipped_w, shipped, "opening weight rides the wrapper in the clear");
        // every strict prefix is rejected before any layer lands
        for cut in 0..c.blob.len() {
            let trunc = Compressed {
                spec: c.spec.clone(),
                shipped_w: c.shipped_w,
                droppable: c.droppable,
                blob: Arc::new(c.blob[..cut].to_vec()),
            };
            assert!(trunc.decode(&shared, 1).is_err(), "prefix of {cut} bytes decoded");
        }

        // on the fabric: rejected at delivery, weight refunded, no write
        let cut = rng.below_usize(c.blob.len());
        let mangled = Payload::Compressed(Compressed {
            spec: c.spec.clone(),
            shipped_w: c.shipped_w,
            droppable: c.droppable,
            blob: Arc::new(c.blob[..cut].to_vec()),
        });
        assert_eq!(shared.fabric.push(&shared, 0, 1, 2, mangled), PushOutcome::Queued);
        assert_eq!(shared.fabric.deliver_due(&shared, 1, 3), 0, "truncated frame must not apply");
        let total = shared.weights[0].get() + shared.weights[1].get();
        assert!((total - 1.0).abs() < 1e-5, "opening weight not refunded: {total}");
        assert_eq!(
            shared.params[1].flatten(),
            receiver_before,
            "a truncated frame must never partially write the receiver's replica"
        );
    });
}

/// Checkpoint quiesce with coalescing on: a frame still OPEN in the link's
/// builder drains as one zero-delay in-flight StepFrame (mass conserved,
/// nothing double-counted), and after restore+delivery the receiver carries
/// the sender's clock provenance. The step then RESUMES: its closing
/// layer-0 push flushes as a second frame that must find the mixing
/// fraction the opening frame established — the step mixes whole even when
/// a checkpoint splits it across two frames.
#[test]
fn prop_coalesced_drain_restore_conserves_frame_provenance_and_mass() {
    prop("frame_drain_restore", 15, |rng| {
        let dims = vec![2 + rng.below_usize(6), 2 + rng.below_usize(6)];
        let fabric = Arc::new(SimFabric::with_options(
            LatencyDist::Constant(0.0),
            0.0,
            0.0,
            2,
            rng.next_u64(),
            CodecSpec::Dense.build(2, rng.next_u64()),
            true,
        ));
        let (shared, sent, receiver) = frame_shared(rng, &dims, fabric.clone());
        let step = 4 + rng.below_usize(20);

        // the step opens: its deepest layer buffers in the frame builder
        let shipped = shared.weights[0].halve();
        let out = shared.fabric.push(
            &shared,
            0,
            1,
            step,
            Payload::LayerPush {
                layer: 1,
                open: Some(shipped),
                values: Arc::new(vec![sent[1].clone()]),
                stamp: ClockStamp { worker: 0, step: step as u64, version: 2 },
                tau: 1,
            },
        );
        assert_eq!(out, PushOutcome::Queued);
        assert_eq!(fabric.pending_count(), 0, "builder-held, not yet on the link");
        let (mass, _) = fabric.in_flight_push_sum_mass();
        assert!((mass - shipped as f64).abs() < 1e-9, "builder weight is in flight");

        // checkpoint quiesce mid-step: the open frame leaves the builder
        let msgs = shared.fabric.drain(1);
        assert_eq!(msgs.len(), 1);
        assert_eq!((msgs[0].from, msgs[0].to, msgs[0].step), (0, 1, step));
        assert_eq!(msgs[0].remaining_s, 0.0, "builder frames drain with zero delay left");
        match &msgs[0].payload {
            Payload::StepFrame { open, entries } => {
                assert_eq!(*open, Some(shipped));
                assert_eq!(entries.len(), 1);
                assert_eq!(entries[0].layer, 1);
                assert_eq!((entries[0].stamp.worker, entries[0].stamp.step), (0, step as u64));
            }
            _ => panic!("expected the open StepFrame on the drained link"),
        }
        assert_eq!(fabric.core().frame_open_mass(), 0.0, "drained weight left the builder");

        shared.fabric.restore(&shared, msgs);
        assert_eq!(shared.fabric.deliver_due(&shared, 1, step), 1);
        let frac = shipped / (0.5 + shipped);
        let got = shared.params[1].layers[1].clock.stamp();
        assert_eq!((got.worker, got.step), (0, step as u64), "sender provenance survives");
        for (i, v) in shared.params[1].layers[1].tensors[0].snapshot().data.iter().enumerate() {
            let want = (1.0 - frac) * receiver[1][i] + frac * sent[1][i];
            assert!((v - want).abs() < 1e-6, "layer 1 coord {i}: {v} vs {want}");
        }

        // the step resumes: the closing layer-0 push flushes immediately
        // and must mix with the SAME fraction the opening frame established
        let out = shared.fabric.push(
            &shared,
            0,
            1,
            step,
            Payload::LayerPush {
                layer: 0,
                open: None,
                values: Arc::new(vec![sent[0].clone()]),
                stamp: ClockStamp { worker: 0, step: step as u64, version: 3 },
                tau: 0,
            },
        );
        assert_eq!(out, PushOutcome::Queued);
        assert_eq!(shared.fabric.deliver_due(&shared, 1, step + 1), 1);
        for (i, v) in shared.params[1].layers[0].tensors[0].snapshot().data.iter().enumerate() {
            let want = (1.0 - frac) * receiver[0][i] + frac * sent[0][i];
            assert!((v - want).abs() < 1e-6, "split step must still mix layer 0: {v} vs {want}");
        }
        let got = shared.params[1].layers[0].clock.stamp();
        assert_eq!((got.worker, got.step), (0, step as u64));
        let total = shared.weights[0].get() + shared.weights[1].get();
        assert!((total - 1.0).abs() < 1e-5, "mass conserved across the split step: {total}");
    });
}

/// Frames are State-class streams: interleaving compressed StepFrames on a
/// link must not touch the gradient error-feedback residuals riding the
/// same link — the EF conservation invariant holds exactly as without
/// frames, and every residual stream still belongs to the gradient tag.
#[test]
fn prop_grad_error_feedback_unclobbered_by_interleaved_frames() {
    prop("frame_ef_isolation", 15, |rng| {
        let n = 2 + rng.below_usize(120);
        let fabric = dense_fabric(rng, 2);
        let (shared, _, _) = codec_shared(rng, n, fabric);
        let codec = CodecSpec::parse("topk:4").unwrap().build(2, rng.next_u64());
        let mut r_before = vec![0.0f32; n];
        for _round in 0..6 {
            // a whole-step frame rides the same link between gradient
            // messages — a State-class stream with no residual of its own
            let frame_vals = vec![(0..n).map(|_| rng.normal()).collect::<Vec<f32>>()];
            let Payload::Compressed(c) =
                codec.encode(&shared.update_pool, 0, 1, step_frame(None, &frame_vals, 3))
            else {
                panic!("topk must wrap the frame");
            };
            c.decode(&shared, 1).unwrap();

            let x: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            let payload = Payload::GradShare {
                set: Arc::new(vec![vec![Tensor::from_vec(&[n], x.clone())]]),
            };
            let Payload::Compressed(c) = codec.encode(&shared.update_pool, 0, 1, payload)
            else {
                panic!("topk must wrap the gradient");
            };
            let Payload::GradShare { set } = c.decode(&shared, 1).unwrap() else {
                panic!("decode changed the payload kind");
            };
            let delivered = &set[0][0].data;
            let state = codec.residual_state();
            let link = state
                .iter()
                .find(|s| s.from == 0 && s.to == 1)
                .expect("link 0->1 accumulated a residual");
            let (_, r_after) = &link.streams[0];
            for i in 0..n {
                let y = x[i] + r_before[i];
                if delivered[i].to_bits() == 0.0f32.to_bits() && r_after[i] != 0.0 {
                    assert_eq!(
                        r_after[i].to_bits(),
                        y.to_bits(),
                        "unsent coordinate {i} must sit in the residual bit-exactly"
                    );
                } else {
                    assert_eq!(
                        delivered[i].to_bits(),
                        y.to_bits(),
                        "sent coordinate {i} must ship the accumulated value"
                    );
                }
            }
            r_before = r_after.clone();
        }
        // frames never grew a residual stream: every key is the grad tag
        for link in codec.residual_state() {
            for (key, _) in &link.streams {
                assert_eq!(key.tag, 3, "State-class frame stream leaked into the EF residuals");
            }
        }
    });
}
