//! Integration tests over the full stack: manifest -> PJRT runtime ->
//! layered model -> session/engine -> algorithms. These require `artifacts/`
//! (run `make artifacts` or `make smoke` first); they auto-skip politely if
//! the manifest is missing so `cargo test` stays usable pre-AOT.

use std::sync::{Arc, Mutex};

use layup::comm::{FabricSpec, LatencyDist};
use layup::config::{Algorithm, Compensation, Mixing, TrainConfig};
use layup::coordinator::Shared;
use layup::data::{self, Dataset};
use layup::manifest::Manifest;
use layup::metrics::RunSummary;
use layup::model::ModelExec;
use layup::optim::{OptimKind, Schedule};
use layup::runtime::Runtime;
use layup::session::events::{CurveRecorder, TrainEvent};
use layup::session::SessionBuilder;

fn manifest() -> Option<Manifest> {
    let dir = layup::artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: no artifacts at {} (run `make artifacts`)", dir.display());
        return None;
    }
    Some(Manifest::load(&dir).expect("manifest parses"))
}

fn pick_model(man: &Manifest) -> String {
    // prefer the vision model; fall back to whatever exists
    if man.models.contains_key("mlpnet18") {
        "mlpnet18".into()
    } else {
        man.models.keys().next().unwrap().clone()
    }
}

fn quick_cfg(model: &str, algo: Algorithm, workers: usize, steps: usize) -> TrainConfig {
    let mut cfg = TrainConfig::new(model, algo, workers, steps);
    cfg.optim = OptimKind::sgd(0.9, 0.0);
    cfg.schedule = Schedule::Constant { lr: 0.03 };
    cfg.eval_every = (steps / 3).max(1);
    cfg
}

/// Run one config through the session facade (the tests' single entry).
fn run(cfg: &TrainConfig, man: &Manifest) -> anyhow::Result<RunSummary> {
    SessionBuilder::new(cfg.clone()).build(man)?.run()
}

#[test]
fn artifacts_load_and_execute_forward() {
    let Some(man) = manifest() else { return };
    let model_name = pick_model(&man);
    let mut rt = Runtime::new().unwrap();
    let mut exec = ModelExec::load(&mut rt, &man, &model_name).unwrap();
    let model = man.model(&model_name).unwrap();
    let mut ds = data::build(model, 0, 1, 1).unwrap();
    let cfg = quick_cfg(&model_name, Algorithm::LocalSgd, 1, 1);
    let shared = Shared::new(&cfg, &man).unwrap();
    let pass = exec.forward(&shared.params[0], &ds.next_batch()).unwrap();
    assert!(pass.loss.is_finite());
    assert!(pass.loss > 0.0);
    // untrained accuracy ~ chance
    let (loss, acc) = exec.evaluate(&shared.params[0], ds.as_ref(), 2).unwrap();
    assert!(loss.is_finite());
    assert!((0.0..=1.0).contains(&acc));
}

#[test]
fn backward_emits_every_layer_in_reverse_order() {
    let Some(man) = manifest() else { return };
    let model_name = pick_model(&man);
    let mut rt = Runtime::new().unwrap();
    let mut exec = ModelExec::load(&mut rt, &man, &model_name).unwrap();
    let model = man.model(&model_name).unwrap();
    let mut ds = data::build(model, 0, 1, 2).unwrap();
    let cfg = quick_cfg(&model_name, Algorithm::LocalSgd, 1, 1);
    let shared = Shared::new(&cfg, &man).unwrap();
    let pass = exec.forward(&shared.params[0], &ds.next_batch()).unwrap();

    let mut order = Vec::new();
    exec.backward(&shared.params[0], &pass, &mut |li, grads| {
        // gradient tensor shapes match the manifest
        for (g, spec) in grads.iter().zip(&man.model(&model_name).unwrap().layers[li].params) {
            assert_eq!(g.shape, spec.shape);
            assert!(g.data.iter().all(|v| v.is_finite()));
        }
        order.push(li);
    })
    .unwrap();
    let n = model.layers.len();
    assert_eq!(order, (0..n).rev().collect::<Vec<_>>(), "reverse layer order");
}

#[test]
fn gradient_descent_reduces_loss_single_worker() {
    let Some(man) = manifest() else { return };
    let model_name = pick_model(&man);
    let cfg = quick_cfg(&model_name, Algorithm::LocalSgd, 1, 25);
    let summary = run(&cfg, &man).unwrap();
    let first = summary.curve.points.first().unwrap().loss;
    let best = summary.curve.best_loss();
    assert!(best < first * 0.9, "loss did not improve: {first} -> {best}");
}

#[test]
fn every_algorithm_trains_without_divergence() {
    let Some(man) = manifest() else { return };
    let model_name = pick_model(&man);
    for algo in [
        Algorithm::Ddp,
        Algorithm::LayUp,
        Algorithm::LayUpModelGranularity,
        Algorithm::GoSgd,
        Algorithm::AdPsgd,
        Algorithm::SlowMo,
        Algorithm::Co2,
        Algorithm::LocalSgd,
    ] {
        let cfg = quick_cfg(&model_name, algo, 2, 12);
        let summary = run(&cfg, &man).unwrap_or_else(|e| panic!("{algo:?} failed: {e:#}"));
        assert!(summary.curve.best_loss().is_finite(), "{algo:?} diverged");
        assert_eq!(summary.total_steps, 24);
    }
}

#[test]
fn decoupled_single_worker_tracks_serial_loss_curve() {
    // Loss-parity smoke test: 1 worker, 1:1 ratio, queue_depth 1. The
    // decoupled pipeline overlaps forward(k+1) with backward(k), so curves
    // are not bit-identical (one step of staleness — exactly the regime
    // Lemma 6.1 bounds); both runs must still converge comparably.
    // CO2 is barrier-free and safe at m = 1 (gossip peer selection needs
    // m >= 2), so the same algorithm runs on both sides of the comparison.
    let Some(man) = manifest() else { return };
    let model_name = pick_model(&man);
    let serial_cfg = quick_cfg(&model_name, Algorithm::Co2, 1, 25);
    let serial = run(&serial_cfg, &man).unwrap();

    let mut dec_cfg = quick_cfg(&model_name, Algorithm::Co2, 1, 25);
    dec_cfg.decoupled = true;
    dec_cfg.fwd_threads = 1;
    dec_cfg.bwd_threads = 1;
    dec_cfg.queue_depth = 1;
    let dec = run(&dec_cfg, &man).unwrap();

    let (s_first, s_best) = (serial.curve.points.first().unwrap().loss, serial.curve.best_loss());
    let (d_first, d_best) = (dec.curve.points.first().unwrap().loss, dec.curve.best_loss());
    assert!(s_best < s_first * 0.9, "serial did not learn: {s_first} -> {s_best}");
    assert!(d_best < d_first * 0.9, "decoupled did not learn: {d_first} -> {d_best}");
    assert!(
        d_best < s_best * 1.5 + 0.1,
        "decoupled lost too much vs serial: {d_best} vs {s_best}"
    );
    assert_eq!(dec.total_steps, 25, "every queued pass must complete");
}

#[test]
fn decoupled_pools_train_all_async_algorithms() {
    let Some(man) = manifest() else { return };
    let model_name = pick_model(&man);
    for algo in [Algorithm::LayUp, Algorithm::GoSgd, Algorithm::AdPsgd, Algorithm::Co2] {
        let mut cfg = quick_cfg(&model_name, algo, 2, 12);
        cfg.decoupled = true;
        cfg.fwd_threads = 2;
        cfg.bwd_threads = 1;
        cfg.queue_depth = 3;
        let summary =
            run(&cfg, &man).unwrap_or_else(|e| panic!("decoupled {algo:?} failed: {e:#}"));
        assert!(summary.curve.best_loss().is_finite(), "{algo:?} diverged");
        assert_eq!(summary.total_steps, 24);
        assert!(summary.stats.queue.max_depth <= 3, "queue bound violated");
    }
    // barrier algorithms must be rejected up front, not deadlock
    let mut cfg = quick_cfg(&model_name, Algorithm::Ddp, 2, 6);
    cfg.decoupled = true;
    assert!(run(&cfg, &man).is_err());
}

/// The tentpole end-to-end: every stash-based algorithm now runs with
/// `bwd_threads = 2` (interleaved steps) and must converge comparably to its
/// serial run — the regime `TrainConfig::validate` rejected before the
/// step-keyed `StepState` contract. LayUp rides along to pin its updater's
/// step-keyed push map under the same interleaving.
#[test]
fn interleaved_bwd_threads_match_serial_loss_for_stash_algorithms() {
    let Some(man) = manifest() else { return };
    let model_name = pick_model(&man);
    for algo in [Algorithm::GoSgd, Algorithm::AdPsgd, Algorithm::Co2, Algorithm::LayUp] {
        let serial_cfg = quick_cfg(&model_name, algo, 2, 24);
        let serial = run(&serial_cfg, &man).unwrap_or_else(|e| panic!("serial {algo:?}: {e:#}"));

        let mut dec_cfg = quick_cfg(&model_name, algo, 2, 24);
        dec_cfg.decoupled = true;
        dec_cfg.fwd_threads = 2;
        dec_cfg.bwd_threads = 2;
        dec_cfg.queue_depth = 3;
        let dec = run(&dec_cfg, &man)
            .unwrap_or_else(|e| panic!("decoupled bwd_threads=2 {algo:?}: {e:#}"));

        let (s_first, s_best) =
            (serial.curve.points.first().unwrap().loss, serial.curve.best_loss());
        let (d_first, d_best) = (dec.curve.points.first().unwrap().loss, dec.curve.best_loss());
        assert!(s_best < s_first * 0.9, "{algo:?} serial did not learn: {s_first} -> {s_best}");
        assert!(
            d_best < d_first * 0.9,
            "{algo:?} interleaved did not learn: {d_first} -> {d_best}"
        );
        assert!(
            d_best < s_best * 1.5 + 0.1,
            "{algo:?} interleaved lost too much vs serial: {d_best} vs {s_best}"
        );
        // both backward threads together complete every queued pass
        assert_eq!(dec.total_steps, 48, "{algo:?}: every queued pass must complete");
        assert!(dec.stats.queue.max_depth <= 3, "{algo:?}: queue bound violated");
    }
}

/// The session's typed event stream is consistent with the summary: the
/// curve recorder observes exactly the summary's eval points, and every
/// step completion is reported.
#[test]
fn session_observers_see_steps_and_eval_points() {
    let Some(man) = manifest() else { return };
    let model_name = pick_model(&man);
    let cfg = quick_cfg(&model_name, Algorithm::LocalSgd, 2, 6);

    let recorder = Arc::new(CurveRecorder::new());
    let steps_seen = Arc::new(Mutex::new(Vec::<(usize, usize)>::new()));
    let counter = {
        let steps_seen = Arc::clone(&steps_seen);
        move |ev: &TrainEvent| {
            if let TrainEvent::StepCompleted { worker, step, .. } = ev {
                steps_seen.lock().unwrap().push((*worker, *step));
            }
        }
    };
    let summary = SessionBuilder::new(cfg)
        .observer(recorder.clone())
        .observer(Arc::new(counter))
        .build(&man)
        .unwrap()
        .run()
        .unwrap();

    let recorded = recorder.snapshot();
    assert_eq!(recorded.points.len(), summary.curve.points.len());
    for (a, b) in recorded.points.iter().zip(summary.curve.points.iter()) {
        assert_eq!(a.step, b.step);
        assert!((a.loss - b.loss).abs() < 1e-12);
    }
    let steps_seen = steps_seen.lock().unwrap();
    assert_eq!(steps_seen.len(), summary.total_steps);
    for wid in 0..2 {
        assert_eq!(steps_seen.iter().filter(|(w, _)| *w == wid).count(), 6);
    }
}

#[test]
fn ddp_replicas_stay_bit_identical() {
    let Some(man) = manifest() else { return };
    let model_name = pick_model(&man);
    let mut cfg = quick_cfg(&model_name, Algorithm::Ddp, 2, 6);
    cfg.track_drift_every = 2;
    let summary = run(&cfg, &man).unwrap();
    assert!(
        summary.stats.max_disagreement < 1e-6,
        "DDP drifted: {}",
        summary.stats.max_disagreement
    );
}

#[test]
fn layup_drifts_but_stays_bounded() {
    let Some(man) = manifest() else { return };
    let model_name = pick_model(&man);
    let mut cfg = quick_cfg(&model_name, Algorithm::LayUp, 3, 20);
    cfg.track_drift_every = 2;
    let summary = run(&cfg, &man).unwrap();
    let max_d = summary.stats.max_disagreement;
    assert!(max_d > 0.0, "gossip replicas should differ mid-training");
    assert!(max_d < 1.0, "drift exploded: {max_d}");
    assert!(summary.gossip_applied > 0, "no gossip pushes happened");
}

#[test]
fn layup_straggler_does_not_slow_training_much_but_ddp_does() {
    let Some(man) = manifest() else { return };
    let model_name = pick_model(&man);
    let steps = 10;
    let timed = |algo, delay: f64| {
        let mut cfg = quick_cfg(&model_name, algo, 2, steps);
        cfg.eval_every = steps + 1;
        cfg.straggler = if delay > 0.0 { Some((1, delay)) } else { None };
        run(&cfg, &man).unwrap().total_time_s
    };
    let ddp0 = timed(Algorithm::Ddp, 0.0);
    let ddp4 = timed(Algorithm::Ddp, 4.0);
    assert!(
        ddp4 > ddp0 * 1.5,
        "DDP should slow with a straggler: {ddp0:.2}s -> {ddp4:.2}s"
    );
    // LayUp's non-straggler worker finishes its steps unimpeded; total time
    // is gated by the straggler's own steps, but compute threads never block
    // on each other — with 1 physical core we can only assert it trains fine.
    let lay4 = {
        let mut cfg = quick_cfg(&model_name, Algorithm::LayUp, 2, steps);
        cfg.straggler = Some((1, 4.0));
        run(&cfg, &man).unwrap()
    };
    assert!(lay4.curve.best_loss().is_finite());
}

#[test]
fn push_sum_weights_conserved_within_tolerance() {
    let Some(man) = manifest() else { return };
    let model_name = pick_model(&man);
    let cfg = quick_cfg(&model_name, Algorithm::GoSgd, 3, 15);
    let shared = Shared::new(&cfg, &man).unwrap();
    // run through the public entry to exercise real threads
    let _ = run(&cfg, &man).unwrap();
    // weights in a fresh Shared sum to 1 by construction
    let total: f32 = shared.weights.iter().map(|w| w.get()).sum();
    assert!((total - 1.0).abs() < 1e-5);
}

#[test]
fn eval_batches_are_deterministic_across_workers() {
    let Some(man) = manifest() else { return };
    let model_name = pick_model(&man);
    let model = man.model(&model_name).unwrap();
    let a = data::build(model, 0, 2, 42).unwrap();
    let b = data::build(model, 1, 2, 42).unwrap();
    let ea = a.eval_batch(0);
    let eb = b.eval_batch(0);
    assert_eq!(ea.targets, eb.targets, "eval stream must be shared");
    assert_eq!(ea.x_f32, eb.x_f32);
    assert_eq!(ea.x_i32, eb.x_i32);
}

/// InstantFabric parity (acceptance): the default fabric is Instant, and on
/// it the lockstep algorithms — whose loss curves are fully determined by
/// the seed — reproduce identical curves run-to-run, with fabric traffic
/// accounted at zero staleness. (Gossip algorithms are timing-dependent by
/// design even on the seed-era path, so determinism is asserted where
/// determinism exists.)
#[test]
fn instant_fabric_is_default_and_lockstep_curves_are_identical() {
    let Some(man) = manifest() else { return };
    let model_name = pick_model(&man);
    for algo in [Algorithm::Ddp, Algorithm::LocalSgd, Algorithm::SlowMo] {
        let mut cfg = quick_cfg(&model_name, algo, 2, 10);
        cfg.sync_period = 5; // two outer syncs inside 10 steps
        assert_eq!(cfg.fabric, FabricSpec::Instant);
        let a = run(&cfg, &man).unwrap_or_else(|e| panic!("{algo:?} run a: {e:#}"));
        let b = run(&cfg, &man).unwrap_or_else(|e| panic!("{algo:?} run b: {e:#}"));
        assert_eq!(a.curve.points.len(), b.curve.points.len());
        for (pa, pb) in a.curve.points.iter().zip(b.curve.points.iter()) {
            assert_eq!(pa.step, pb.step);
            assert_eq!(
                pa.loss, pb.loss,
                "{algo:?}: lockstep runs on the instant fabric must be bit-identical"
            );
        }
        let comm = &a.stats.comm;
        assert!(comm.msgs_sent > 0, "{algo:?} must account its fabric traffic");
        assert_eq!(comm.msgs_dropped, 0);
        assert!(
            comm.mean_delivered_staleness().abs() < 1e-9,
            "{algo:?}: instant delivery has zero staleness"
        );
    }
}

/// The SessionBuilder fabric override is just the config knob: explicitly
/// selecting Instant matches the default run bit-for-bit on a lockstep
/// algorithm.
#[test]
fn session_builder_fabric_override_matches_default() {
    let Some(man) = manifest() else { return };
    let model_name = pick_model(&man);
    let cfg = quick_cfg(&model_name, Algorithm::Ddp, 2, 8);
    let a = run(&cfg, &man).unwrap();
    let b = SessionBuilder::new(cfg.clone())
        .fabric(FabricSpec::Instant)
        .build(&man)
        .unwrap()
        .run()
        .unwrap();
    assert_eq!(a.curve.points.len(), b.curve.points.len());
    for (pa, pb) in a.curve.points.iter().zip(b.curve.points.iter()) {
        assert_eq!(pa.loss, pb.loss);
    }
}

/// The SimFabric end-to-end: every algorithm (barrier and gossip alike)
/// trains through queued links with latency — gossip additionally under
/// drops — and the summary carries per-link traffic, delivery and staleness
/// accounting all the way into the metrics JSON.
#[test]
fn sim_fabric_trains_every_algorithm_and_reports_traffic() {
    let Some(man) = manifest() else { return };
    let model_name = pick_model(&man);
    for algo in [
        Algorithm::Ddp,
        Algorithm::LayUp,
        Algorithm::LayUpModelGranularity,
        Algorithm::GoSgd,
        Algorithm::AdPsgd,
        Algorithm::SlowMo,
        Algorithm::Co2,
        Algorithm::LocalSgd,
    ] {
        let mut cfg = quick_cfg(&model_name, algo, 2, 12);
        cfg.sync_period = 4;
        cfg.fabric = FabricSpec::Sim {
            latency: LatencyDist::Constant(0.002),
            bandwidth_bytes_per_s: 0.0,
            drop_prob: if algo.uses_barrier() { 0.0 } else { 0.2 },
        };
        let summary = run(&cfg, &man).unwrap_or_else(|e| panic!("sim fabric {algo:?}: {e:#}"));
        assert!(summary.curve.best_loss().is_finite(), "{algo:?} diverged on the sim fabric");
        assert_eq!(summary.total_steps, 24, "{algo:?}: delayed links must not lose steps");
        let comm = &summary.stats.comm;
        assert!(comm.msgs_sent > 0 && comm.bytes_sent > 0, "{algo:?}: no traffic accounted");
        assert!(comm.msgs_delivered > 0, "{algo:?}: nothing was delivered");
        assert!(!comm.links.is_empty(), "{algo:?}: per-link breakdown missing");
        let j = summary.to_json().dump();
        for key in [
            "comm_msgs_sent",
            "comm_bytes_sent",
            "comm_dropped",
            "comm_delivered",
            "comm_mean_staleness",
            "links",
        ] {
            assert!(j.contains(&format!("\"{key}\":")), "{algo:?}: metrics JSON missing {key}");
        }
    }
}

/// Push-sum weight mass survives a full gossip training run on lossy,
/// delayed links: whatever is not at the workers is still in flight.
#[test]
fn sim_fabric_push_sum_run_conserves_weight_mass() {
    let Some(man) = manifest() else { return };
    let model_name = pick_model(&man);
    for algo in [Algorithm::GoSgd, Algorithm::LayUp] {
        let mut cfg = quick_cfg(&model_name, algo, 3, 15);
        cfg.fabric = FabricSpec::Sim {
            latency: LatencyDist::Uniform { lo: 0.0, hi: 0.003 },
            bandwidth_bytes_per_s: 0.0,
            drop_prob: 0.3,
        };
        // weights live inside the run's own Shared; assert via gossip
        // accounting instead: drops must be visible, and the run must not
        // lose training steps to them
        let summary = run(&cfg, &man).unwrap_or_else(|e| panic!("{algo:?}: {e:#}"));
        assert_eq!(summary.total_steps, 45, "{algo:?}");
        assert!(summary.curve.best_loss().is_finite(), "{algo:?}");
        let comm = &summary.stats.comm;
        assert!(
            comm.msgs_dropped + comm.msgs_delivered <= comm.msgs_sent,
            "{algo:?}: every message is dropped, delivered, or still in flight \
             ({} dropped + {} delivered vs {} sent)",
            comm.msgs_dropped,
            comm.msgs_delivered,
            comm.msgs_sent
        );
        assert!(comm.msgs_dropped > 0, "{algo:?}: 30% drop over 45 steps must drop something");
    }
}

#[test]
fn upload_cache_hits_when_params_unchanged() {
    let Some(man) = manifest() else { return };
    let model_name = pick_model(&man);
    let mut rt = Runtime::new().unwrap();
    let mut exec = ModelExec::load(&mut rt, &man, &model_name).unwrap();
    let model = man.model(&model_name).unwrap();
    let mut ds = data::build(model, 0, 1, 3).unwrap();
    let cfg = quick_cfg(&model_name, Algorithm::LocalSgd, 1, 1);
    let shared = Shared::new(&cfg, &man).unwrap();
    let b = ds.next_batch();
    let _ = exec.forward(&shared.params[0], &b).unwrap();
    let misses_after_first = exec.upload_misses;
    let _ = exec.forward(&shared.params[0], &b).unwrap();
    assert_eq!(exec.upload_misses, misses_after_first, "second fwd must hit the cache");
    assert!(exec.upload_hits > 0);
}

/// Tentpole: per-layer staleness histograms are populated in BOTH serial
/// and decoupled modes, the summary JSON carries the new keys, and the
/// opt-in policies (DC compensation, adaptive mixing) train without
/// divergence on LayUp and AD-PSGD.
#[test]
fn staleness_histograms_populate_and_policies_train() {
    let Some(man) = manifest() else { return };
    let model_name = pick_model(&man);
    let n_layers = man.model(&model_name).unwrap().layers.len();

    // serial: every apply is observed, one histogram per layer
    let cfg = quick_cfg(&model_name, Algorithm::LayUp, 2, 12);
    let summary = run(&cfg, &man).unwrap();
    let stale = &summary.stats.staleness;
    assert!(stale.total_applies() > 0, "serial: no applies observed");
    assert_eq!(stale.layers.len(), n_layers, "one histogram per layer");
    let j = summary.to_json().dump();
    for key in ["stale_applies", "stale_tau_mean", "stale_tau_max", "staleness_layers"] {
        assert!(j.contains(&format!("\"{key}\":")), "metrics JSON missing {key}");
    }

    // decoupled pools: the pipeline's inherent lag shows up as observed τ
    let mut cfg = quick_cfg(&model_name, Algorithm::LayUp, 2, 12);
    cfg.decoupled = true;
    cfg.fwd_threads = 2;
    cfg.bwd_threads = 1;
    cfg.queue_depth = 2;
    let summary = run(&cfg, &man).unwrap();
    assert!(
        summary.stats.staleness.total_applies() > 0,
        "decoupled: no applies observed"
    );

    // DC compensation + adaptive mixing: LayUp still learns
    let mut cfg = quick_cfg(&model_name, Algorithm::LayUp, 2, 20);
    cfg.staleness.compensation = Compensation::Dc;
    cfg.staleness.mixing = Mixing::Adaptive;
    let summary = run(&cfg, &man).unwrap();
    let first = summary.curve.points.first().unwrap().loss;
    assert!(summary.curve.best_loss().is_finite(), "policies-on run diverged");
    assert!(
        summary.curve.best_loss() < first,
        "policies-on run did not improve: {first} -> {}",
        summary.curve.best_loss()
    );

    // DC rides AD-PSGD's apply path too
    let mut cfg = quick_cfg(&model_name, Algorithm::AdPsgd, 2, 12);
    cfg.staleness.compensation = Compensation::Dc;
    let summary = run(&cfg, &man).unwrap();
    assert!(summary.curve.best_loss().is_finite(), "AD-PSGD + dc diverged");
}
