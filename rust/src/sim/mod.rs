//! Discrete-event simulator of the paper's GPU clusters (C1/C2/C3).
//!
//! The thread cluster in [`crate::coordinator`] validates *convergence*
//! (accuracy, perplexity, drift) with real gradients; this module reproduces
//! the paper's *wall-clock* results (TTC/TTA in Tables 1–3, MFU in Table 4,
//! the straggler sweep of Fig 3B) at paper scale, where we obviously cannot
//! run 8×A100. The simulator is parameterized with the paper's own
//! measurements (Table A4 fwd/bwd times), public model sizes, and standard
//! interconnect figures, and simulates each algorithm's *schedule*:
//!
//! * **sync** (DDP, LocalSGD/SlowMo): lock-step steps; every barrier waits
//!   for the slowest device; ring all-reduce cost `2(M−1)/M · bytes/bw`.
//! * **async work-pool** (GoSGD, AD-PSGD, CO2, LayUp): a shared pool of
//!   batches; each device grabs the next batch when free, so a straggler
//!   simply contributes fewer samples instead of stalling the cluster —
//!   this is what makes Fig 3B's flat lines emerge.
//! * **LayUp**: per-layer sends are issued as each layer's backward
//!   completes and overlap with the remaining backward + next forward
//!   (the updater thread); only link saturation leaks into step time.
//! * **AD-PSGD**: symmetric pairwise averaging — the partner must engage,
//!   so pairing with a straggler transfers (some of) its delay; communication
//!   volume is 2x (both directions), as the paper notes.
//! * **GoSGD**: whole-model push after the step; the send serialization sits
//!   on the worker thread (partial overlap only).
//! * **CO2**: averaging is one round stale and fully overlapped; only
//!   overflow beyond the next local window costs time.
//!
//! Everything is deterministic given the seed.

use crate::topology::group_bounds;
use crate::util::rng::Pcg32;

/// Per-layer compute/communication cost on the reference device.
#[derive(Clone, Copy, Debug)]
pub struct LayerCost {
    pub fwd_s: f64,
    pub bwd_s: f64,
    pub bytes: u64,
}

/// A paper workload: model + dataset scale on the reference device.
#[derive(Clone, Debug)]
pub struct Workload {
    pub name: String,
    pub layers: Vec<LayerCost>,
    /// mini-batches in one epoch across the whole cluster
    pub batches_per_epoch: usize,
    pub epochs: usize,
}

impl Workload {
    fn uniform(name: &str, n_layers: usize, fwd_s: f64, bwd_s: f64, param_bytes: u64,
               batches_per_epoch: usize, epochs: usize) -> Workload {
        let lc = LayerCost {
            fwd_s: fwd_s / n_layers as f64,
            bwd_s: bwd_s / n_layers as f64,
            bytes: param_bytes / n_layers as u64,
        };
        Workload {
            name: name.to_string(),
            layers: vec![lc; n_layers],
            batches_per_epoch,
            epochs,
        }
    }

    /// ResNet-18 on CIFAR-100 (Table A4: fwd 4.9 ms, bwd 10.2 ms @ bs 128).
    pub fn resnet18_cifar(m: usize) -> Workload {
        Workload::uniform("resnet18/cifar100", 8, 0.0049, 0.0102,
                          11_700_000 * 4, 50_000 / (128 * m).max(1) * m, 100)
    }

    /// ResNet-50 on CIFAR-100 (Table A4: fwd 16.6 ms, bwd 29.9 ms @ bs 128).
    pub fn resnet50_cifar(m: usize) -> Workload {
        Workload::uniform("resnet50/cifar100", 16, 0.0166, 0.0299,
                          25_600_000 * 4, 50_000 / (128 * m).max(1) * m, 100)
    }

    /// ResNet-50 on ImageNet-1k (bs 256/worker, 90 epochs; C1).
    pub fn resnet50_imagenet(m: usize) -> Workload {
        // fwd/bwd scale ~2x from bs 128 -> 256
        Workload::uniform("resnet50/imagenet", 16, 0.033, 0.060,
                          25_600_000 * 4, 1_281_167 / (256 * m).max(1) * m, 90)
    }

    /// GPT-2 Medium pretraining on MiniPile (C2; ~45.5k steps in the paper).
    pub fn gpt2_medium(m: usize) -> Workload {
        Workload::uniform("gpt2-medium/minipile", 24, 0.28, 0.56,
                          400_000_000 * 4, 45_539 / 8 * m, 8)
    }

    /// GPT-2 XL finetuning on WikiText-103 (C3; ~7.3k steps).
    pub fn gpt2_xl(m: usize) -> Workload {
        Workload::uniform("gpt2-xl/wikitext103", 48, 0.52, 1.04,
                          1_600_000_000 * 4, 7_286 / 4 * m, 4)
    }

    pub fn step_compute_s(&self) -> f64 {
        self.layers.iter().map(|l| l.fwd_s + l.bwd_s).sum()
    }

    pub fn bwd_s(&self) -> f64 {
        self.layers.iter().map(|l| l.bwd_s).sum()
    }

    pub fn model_bytes(&self) -> u64 {
        self.layers.iter().map(|l| l.bytes).sum()
    }

    pub fn total_batches(&self) -> usize {
        self.batches_per_epoch * self.epochs
    }
}

/// Hardware configuration (paper Section 4 "Hardware").
#[derive(Clone, Debug)]
pub struct Cluster {
    pub name: String,
    pub m: usize,
    /// effective point-to-point bandwidth, bytes/s
    pub link_bw: f64,
    /// per-message latency, seconds
    pub link_lat: f64,
    /// per-device speed multipliers (1.0 = reference)
    pub speed: Vec<f64>,
    /// extra idle injected per iteration, in units of one iteration's
    /// compute time (the paper's straggler delay knob, Fig 3)
    pub idle_iters: Vec<f64>,
    /// kernel-level MFU of the dense compute itself (caps device MFU)
    pub kernel_mfu: f64,
    /// per-step compute-time jitter (lognormal sigma); synchronous schedules
    /// pay E[max over M] of this every barrier — a first-order source of the
    /// DDP MFU gap in Table 4
    pub jitter: f64,
    /// host-side processing rate for outer-optimizer steps (SlowMo/CO2 keep
    /// full-precision momentum + buffer copies on the host; calibrated to
    /// the paper's measured SlowMo/CO2 MFU)
    pub host_outer_bw: f64,
}

impl Cluster {
    pub fn new(name: &str, m: usize, link_bw: f64, link_lat: f64, kernel_mfu: f64) -> Cluster {
        Cluster {
            name: name.to_string(),
            m,
            link_bw,
            link_lat,
            speed: vec![1.0; m],
            idle_iters: vec![0.0; m],
            kernel_mfu,
            jitter: 0.05,
            host_outer_bw: 1.0e9,
        }
    }

    /// C1: 3x A100-PCIe 80GB (PCIe gen4 ~ 20 GB/s effective).
    pub fn c1() -> Cluster {
        Cluster::new("C1-3xA100-PCIe", 3, 20e9, 10e-6, 0.74)
    }

    /// C2: 8x A100-SXM4 40GB (NVLink ~ 200 GB/s effective).
    pub fn c2() -> Cluster {
        Cluster::new("C2-8xA100-SXM4", 8, 200e9, 5e-6, 0.74)
    }

    /// C3: 4x H100-SXM5 94GB (NVLink4 ~ 350 GB/s effective).
    pub fn c3() -> Cluster {
        Cluster::new("C3-4xH100-SXM5", 4, 350e9, 5e-6, 0.66)
    }

    pub fn with_straggler(mut self, worker: usize, idle_iters: f64) -> Cluster {
        self.idle_iters[worker] = idle_iters;
        self
    }

    fn xfer(&self, bytes: u64) -> f64 {
        self.link_lat + bytes as f64 / self.link_bw
    }

    /// Ring all-reduce cost for `bytes` over `m` devices.
    fn allreduce(&self, bytes: u64) -> f64 {
        let m = self.m as f64;
        2.0 * (m - 1.0) / m * bytes as f64 / self.link_bw + 2.0 * (m - 1.0) * self.link_lat
    }
}

/// Which schedule to simulate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimAlgo {
    Ddp,
    LayUp,
    GoSgd,
    AdPsgd,
    LocalSgd { period: usize },
    SlowMo { period: usize },
    Co2 { period: usize },
    /// Star/PS topology (`--topology ps:N`): the last `shards` devices are
    /// parameter-server shards (no compute), trainers push per-layer grads
    /// and pull fresh params. `dc` ships `x_then` alongside (DC-ASGD).
    AsgdPs { shards: usize, dc: bool },
    /// Two-tier topology (`--topology hier:G`): intra-group gossip rides
    /// NVLink-class links (free), only leader-to-leader syncs every `period`
    /// steps pay the configured link.
    HierGossip { groups: usize, period: usize },
}

impl SimAlgo {
    pub fn name(&self) -> &'static str {
        match self {
            SimAlgo::Ddp => "DDP",
            SimAlgo::LayUp => "LayUp",
            SimAlgo::GoSgd => "GoSGD",
            SimAlgo::AdPsgd => "AD-PSGD",
            SimAlgo::LocalSgd { .. } => "LocalSGD",
            SimAlgo::SlowMo { .. } => "SlowMo",
            SimAlgo::Co2 { .. } => "CO2",
            SimAlgo::AsgdPs { dc: false, .. } => "ASGD-PS",
            SimAlgo::AsgdPs { dc: true, .. } => "DC-ASGD-PS",
            SimAlgo::HierGossip { .. } => "HierGossip",
        }
    }

    pub fn paper_set(period: usize) -> Vec<SimAlgo> {
        vec![
            SimAlgo::Ddp,
            SimAlgo::Co2 { period },
            SimAlgo::SlowMo { period },
            SimAlgo::GoSgd,
            SimAlgo::AdPsgd,
            SimAlgo::LayUp,
        ]
    }
}

#[derive(Clone, Debug)]
pub struct SimResult {
    pub algo: &'static str,
    pub wall_s: f64,
    /// fraction of device-time spent computing
    pub occupancy: f64,
    /// occupancy x kernel MFU — comparable to Table 4
    pub mfu: f64,
    pub comm_gbytes: f64,
    pub batches: usize,
}

/// Simulate one full training run.
pub fn simulate(cluster: &Cluster, w: &Workload, algo: SimAlgo, seed: u64) -> SimResult {
    match algo {
        SimAlgo::Ddp => sim_sync(cluster, w, 1, algo, seed),
        SimAlgo::LocalSgd { period } | SimAlgo::SlowMo { period } | SimAlgo::Co2 { period } => {
            sim_sync(cluster, w, period, algo, seed)
        }
        SimAlgo::GoSgd | SimAlgo::AdPsgd | SimAlgo::LayUp => {
            sim_async_gossip(cluster, w, algo, seed)
        }
        SimAlgo::AsgdPs { shards, dc } => sim_ps(cluster, w, shards, dc, seed),
        SimAlgo::HierGossip { groups, period } => sim_hier(cluster, w, groups, period, seed),
    }
}

fn busy_time(cluster: &Cluster, w: &Workload, dev: usize) -> f64 {
    w.step_compute_s() / cluster.speed[dev]
}

/// Sample one device's step compute time with lognormal-ish jitter.
fn jittered(cluster: &Cluster, base: f64, rng: &mut Pcg32) -> f64 {
    base * (1.0 + cluster.jitter * rng.normal().abs() as f64)
}

/// Lock-step schedules: DDP (period 1, gradient all-reduce each step) and
/// the LocalSGD family (parameter exchange every `period` steps). Every
/// barrier waits for the slowest device *including* its per-step jitter —
/// the E[max over M] term that erodes DDP's MFU (Table 4) — and for the
/// injected straggler idle (Fig 3B's linear degradation).
fn sim_sync(cluster: &Cluster, w: &Workload, period: usize, algo: SimAlgo, seed: u64) -> SimResult {
    let m = cluster.m;
    let mut rng = Pcg32::new(seed ^ 0x5bc0);
    let global_steps = w.total_batches() / m;
    let period = period.max(1);
    let bytes = w.model_bytes();

    // per-sync extra costs by flavour
    let allreduce = cluster.allreduce(bytes);
    let (sync_every_step, per_sync): (f64, f64) = match algo {
        SimAlgo::Ddp => (allreduce, 0.0),
        SimAlgo::LocalSgd { .. } => (0.0, allreduce),
        // SlowMo: all-reduce + host-side outer momentum (3 model-size buffers)
        SimAlgo::SlowMo { .. } => (0.0, allreduce + 3.0 * bytes as f64 / cluster.host_outer_bw),
        // CO2: the all-reduce overlaps with the next window (one-round-stale
        // averaging); only the host-side outer step stalls the device.
        SimAlgo::Co2 { .. } => (0.0, 3.0 * bytes as f64 / cluster.host_outer_bw),
        _ => unreachable!(),
    };

    let mut wall = 0.0f64;
    let mut busy = vec![0.0f64; m];
    for step in 0..global_steps {
        // barrier: slowest jittered device (straggler idles (1+d)x)
        let mut slowest = 0.0f64;
        for d in 0..m {
            let c = jittered(cluster, busy_time(cluster, w, d), &mut rng);
            busy[d] += c;
            slowest = slowest.max(c * (1.0 + cluster.idle_iters[d]));
        }
        wall += slowest + sync_every_step;
        if (step + 1) % period == 0 {
            wall += per_sync;
        }
    }
    let n_syncs = (global_steps / period) as f64;
    let comm_rounds = match algo {
        SimAlgo::Ddp => global_steps as f64,
        _ => n_syncs,
    };
    let total_busy: f64 = busy.iter().sum();
    let occupancy = total_busy / (wall * m as f64);
    SimResult {
        algo: algo.name(),
        wall_s: wall,
        occupancy,
        mfu: occupancy * cluster.kernel_mfu,
        comm_gbytes: comm_rounds * m as f64 * bytes as f64 * 2.0 * (m as f64 - 1.0)
            / m as f64
            / 1e9,
        batches: global_steps * m,
    }
}

/// Asynchronous schedules (GoSGD / AD-PSGD / LayUp): every device trains on
/// its own shard with NO barrier; a straggler simply falls behind (it keeps
/// receiving gossip, so consensus is maintained — validated on the thread
/// cluster) and the run completes when the healthy devices finish their
/// shards. This is exactly why Fig 3B's LayUp/GoSGD lines are flat.
fn sim_async_gossip(cluster: &Cluster, w: &Workload, algo: SimAlgo, seed: u64) -> SimResult {
    let m = cluster.m;
    let quota = w.total_batches() / m;
    let mut rng = Pcg32::new(seed ^ 0x5130);
    let mut free = vec![0.0f64; m];
    let mut remaining = vec![quota; m];
    let mut busy = vec![0.0f64; m];
    let mut link_free = vec![0.0f64; m];
    let mut comm_bytes = 0u64;
    let mut batches_done = 0usize;

    loop {
        // healthy devices done? then stop (stragglers are cut off — their
        // contribution is redundant data the consensus no longer needs)
        let healthy_done = (0..m)
            .filter(|&d| cluster.idle_iters[d] == 0.0)
            .all(|d| remaining[d] == 0);
        if healthy_done {
            break;
        }
        // earliest-free device with work left takes the next batch.
        // total_cmp: a NaN free time (e.g. a degenerate jitter draw) must
        // not panic the simulator mid-run — NaN sorts last and the run
        // proceeds on the healthy devices.
        let Some(dev) = (0..m)
            .filter(|&d| remaining[d] > 0)
            .min_by(|&a, &b| free[a].total_cmp(&free[b]))
        else {
            break;
        };
        let t0 = free[dev];
        let compute = jittered(cluster, busy_time(cluster, w, dev), &mut rng);
        let idle = compute * cluster.idle_iters[dev];
        let mut t_end = t0 + idle + compute;
        busy[dev] += compute;

        match algo {
            SimAlgo::LayUp => {
                // Per-layer sends issued as each layer's backward finishes;
                // the updater thread overlaps them with the remaining
                // backward and the next forward. Only link backlog beyond a
                // full step leaks into the compute timeline.
                let send = cluster.xfer(w.model_bytes());
                comm_bytes += w.model_bytes();
                let first_grad_at = t_end - w.bwd_s() / cluster.speed[dev];
                let link_end = link_free[dev].max(first_grad_at) + send;
                link_free[dev] = link_end;
                let backlog = link_end - (t_end + compute);
                if backlog > 0.0 {
                    t_end += backlog;
                }
            }
            SimAlgo::GoSgd => {
                // whole-model push after the step: the send is initiated on
                // the worker thread and received updates are applied there
                // too (queue drain) — partial overlap only.
                let send = cluster.xfer(w.model_bytes());
                let apply = w.model_bytes() as f64 / cluster.host_outer_bw * 0.02;
                comm_bytes += w.model_bytes();
                t_end += 0.5 * send + apply;
                link_free[dev] = t_end + 0.5 * send;
            }
            SimAlgo::AdPsgd => {
                // symmetric averaging: rendezvous with a random peer — if
                // the peer is behind (e.g. the straggler), we wait for it.
                let peer = rng.peer(dev, m);
                let xfer = 2.0 * cluster.xfer(w.model_bytes());
                comm_bytes += 2 * w.model_bytes();
                let peer_ready = if remaining[peer] > 0 { free[peer] } else { t_end };
                t_end = t_end.max(peer_ready) + xfer;
            }
            _ => unreachable!(),
        }
        free[dev] = t_end;
        remaining[dev] -= 1;
        batches_done += 1;
    }

    // wall clock: when the healthy devices finished
    let wall = (0..m)
        .filter(|&d| cluster.idle_iters[d] == 0.0)
        .map(|d| free[d])
        .fold(0.0, f64::max)
        .max(1e-9);
    let total_busy: f64 = (0..m)
        .filter(|&d| cluster.idle_iters[d] == 0.0)
        .map(|d| busy[d].min(wall))
        .sum();
    let healthy = (0..m).filter(|&d| cluster.idle_iters[d] == 0.0).count();
    let occupancy = total_busy / (wall * healthy.max(1) as f64);
    SimResult {
        algo: algo.name(),
        wall_s: wall,
        occupancy,
        mfu: occupancy * cluster.kernel_mfu,
        comm_gbytes: comm_bytes as f64 / 1e9,
        batches: batches_done,
    }
}

/// Star/PS schedule (`asgd-ps` / `dcasgd-ps`): the last `shards` devices run
/// no compute — they own a layer partition each and serialize the trainers'
/// round trips on their links. A trainer's push is issued layer-wise as the
/// backward produces gradients (LayUp-style overlap) and the parameter pull
/// lands asynchronously; only shard-link backlog beyond a full step leaks
/// into the trainer's timeline. `dc` doubles the push volume (`x_then`
/// rides along for the shard-side delay compensation).
fn sim_ps(cluster: &Cluster, w: &Workload, shards: usize, dc: bool, seed: u64) -> SimResult {
    let m = cluster.m;
    let shards = shards.clamp(1, m - 1);
    let trainers = m - shards;
    let quota = w.total_batches() / trainers;
    let mut rng = Pcg32::new(seed ^ 0x9057);
    let mut free = vec![0.0f64; trainers];
    let mut remaining = vec![quota; trainers];
    let mut busy = vec![0.0f64; trainers];
    let mut shard_free = vec![0.0f64; shards];
    let mut comm_bytes = 0u64;
    let mut batches_done = 0usize;

    // per trainer-step traffic through ONE shard: its slice of the grads
    // (x2 when x_then rides along) out, its slice of the params back
    let push_bytes = w.model_bytes() * if dc { 2 } else { 1 };
    let slice_xfer =
        |bytes: u64| cluster.link_lat + (bytes / shards as u64) as f64 / cluster.link_bw;
    let per_shard_rt = slice_xfer(push_bytes) + slice_xfer(w.model_bytes());

    loop {
        let healthy_done = (0..trainers)
            .filter(|&d| cluster.idle_iters[d] == 0.0)
            .all(|d| remaining[d] == 0);
        if healthy_done {
            break;
        }
        let Some(dev) = (0..trainers)
            .filter(|&d| remaining[d] > 0)
            .min_by(|&a, &b| free[a].total_cmp(&free[b]))
        else {
            break;
        };
        let t0 = free[dev];
        let compute = jittered(cluster, busy_time(cluster, w, dev), &mut rng);
        let idle = compute * cluster.idle_iters[dev];
        let mut t_end = t0 + idle + compute;
        busy[dev] += compute;
        comm_bytes += push_bytes + w.model_bytes();

        // the first grads exist once the backward starts producing; every
        // shard serializes the round trips of all trainers on its link
        let first_grad_at = t_end - w.bwd_s() / cluster.speed[dev];
        let mut slowest_shard = 0.0f64;
        for sf in shard_free.iter_mut() {
            *sf = sf.max(first_grad_at) + per_shard_rt;
            slowest_shard = slowest_shard.max(*sf);
        }
        // backlog beyond one fully-overlapped step throttles the trainer
        let backlog = slowest_shard - (t_end + compute);
        if backlog > 0.0 {
            t_end += backlog;
        }
        free[dev] = t_end;
        remaining[dev] -= 1;
        batches_done += 1;
    }

    let wall = (0..trainers)
        .filter(|&d| cluster.idle_iters[d] == 0.0)
        .map(|d| free[d])
        .fold(0.0, f64::max)
        .max(1e-9);
    let total_busy: f64 = (0..trainers)
        .filter(|&d| cluster.idle_iters[d] == 0.0)
        .map(|d| busy[d].min(wall))
        .sum();
    let healthy = (0..trainers).filter(|&d| cluster.idle_iters[d] == 0.0).count();
    // occupancy over the trainer devices only — the shards run no compute,
    // mirroring the thread cluster's per-role denominators
    let occupancy = total_busy / (wall * healthy.max(1) as f64);
    SimResult {
        algo: if dc { "DC-ASGD-PS" } else { "ASGD-PS" },
        wall_s: wall,
        occupancy,
        mfu: occupancy * cluster.kernel_mfu,
        comm_gbytes: comm_bytes as f64 / 1e9,
        batches: batches_done,
    }
}

/// Two-tier schedule (`hier-gossip`): intra-group push-sum rides the
/// intra-node links (instant, free — the group models one NVLink domain);
/// only the group leaders' whole-model exchanges every `period` steps pay
/// the configured inter-node link, GoSGD-style (half-overlapped send).
fn sim_hier(cluster: &Cluster, w: &Workload, groups: usize, period: usize, seed: u64) -> SimResult {
    let m = cluster.m;
    let groups = groups.clamp(1, m);
    let period = period.max(1);
    let quota = w.total_batches() / m;
    let mut rng = Pcg32::new(seed ^ 0x416e);
    let mut free = vec![0.0f64; m];
    let mut remaining = vec![quota; m];
    let mut busy = vec![0.0f64; m];
    let mut comm_bytes = 0u64;
    let mut batches_done = 0usize;
    let leader: Vec<bool> = (0..m)
        .map(|d| (0..groups).any(|k| group_bounds(k, m, groups).0 == d))
        .collect();

    loop {
        let healthy_done = (0..m)
            .filter(|&d| cluster.idle_iters[d] == 0.0)
            .all(|d| remaining[d] == 0);
        if healthy_done {
            break;
        }
        let Some(dev) = (0..m)
            .filter(|&d| remaining[d] > 0)
            .min_by(|&a, &b| free[a].total_cmp(&free[b]))
        else {
            break;
        };
        let t0 = free[dev];
        let compute = jittered(cluster, busy_time(cluster, w, dev), &mut rng);
        let idle = compute * cluster.idle_iters[dev];
        let mut t_end = t0 + idle + compute;
        busy[dev] += compute;

        // tier 2 only: the leader ships its model to the next group's
        // leader at the period boundary (tier-1 intra-group mixes are free)
        let step_done = quota - remaining[dev];
        if groups > 1 && leader[dev] && (step_done + 1) % period == 0 {
            let send = cluster.xfer(w.model_bytes());
            comm_bytes += w.model_bytes();
            t_end += 0.5 * send; // half-overlapped, like GoSGD's push
        }
        free[dev] = t_end;
        remaining[dev] -= 1;
        batches_done += 1;
    }

    let wall = (0..m)
        .filter(|&d| cluster.idle_iters[d] == 0.0)
        .map(|d| free[d])
        .fold(0.0, f64::max)
        .max(1e-9);
    let total_busy: f64 = (0..m)
        .filter(|&d| cluster.idle_iters[d] == 0.0)
        .map(|d| busy[d].min(wall))
        .sum();
    let healthy = (0..m).filter(|&d| cluster.idle_iters[d] == 0.0).count();
    let occupancy = total_busy / (wall * healthy.max(1) as f64);
    SimResult {
        algo: "HierGossip",
        wall_s: wall,
        occupancy,
        mfu: occupancy * cluster.kernel_mfu,
        comm_gbytes: comm_bytes as f64 / 1e9,
        batches: batches_done,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base() -> (Cluster, Workload) {
        let c = Cluster::c1();
        let w = Workload::resnet50_cifar(c.m);
        (c, w)
    }

    #[test]
    fn ddp_pays_allreduce_every_step() {
        let (c, w) = base();
        let ddp = simulate(&c, &w, SimAlgo::Ddp, 1);
        let local = simulate(&c, &w, SimAlgo::LocalSgd { period: 12 }, 1);
        assert!(ddp.wall_s > local.wall_s, "DDP {} vs LocalSGD {}", ddp.wall_s, local.wall_s);
        assert!(ddp.occupancy < local.occupancy);
    }

    #[test]
    fn layup_faster_than_ddp_and_high_mfu() {
        let (c, w) = base();
        let ddp = simulate(&c, &w, SimAlgo::Ddp, 1);
        let layup = simulate(&c, &w, SimAlgo::LayUp, 1);
        assert!(layup.wall_s < ddp.wall_s);
        assert!(layup.mfu > ddp.mfu);
        // LayUp overlaps fully on this cluster: occupancy ~ 1
        assert!(layup.occupancy > 0.95, "occupancy {}", layup.occupancy);
    }

    #[test]
    fn straggler_hurts_ddp_not_layup() {
        let (c, w) = base();
        let delays = [0.0, 8.0, 32.0];
        let mut ddp_times = Vec::new();
        let mut layup_times = Vec::new();
        for &d in &delays {
            let cs = c.clone().with_straggler(0, d);
            ddp_times.push(simulate(&cs, &w, SimAlgo::Ddp, 1).wall_s);
            layup_times.push(simulate(&cs, &w, SimAlgo::LayUp, 1).wall_s);
        }
        // DDP degrades ~linearly
        assert!(ddp_times[2] > 10.0 * ddp_times[0]);
        // LayUp stays within ~25% (straggler just does fewer batches)
        assert!(layup_times[2] < 1.25 * layup_times[0],
            "layup {:?}", layup_times);
    }

    #[test]
    fn adpsgd_degrades_under_straggler_more_than_gosgd() {
        let (c, w) = base();
        let cs = c.clone().with_straggler(0, 16.0);
        let go0 = simulate(&c, &w, SimAlgo::GoSgd, 1).wall_s;
        let go1 = simulate(&cs, &w, SimAlgo::GoSgd, 1).wall_s;
        let ad0 = simulate(&c, &w, SimAlgo::AdPsgd, 1).wall_s;
        let ad1 = simulate(&cs, &w, SimAlgo::AdPsgd, 1).wall_s;
        assert!(go1 / go0 < 1.3, "gosgd ratio {}", go1 / go0);
        assert!(ad1 / ad0 > go1 / go0, "adpsgd should degrade more");
    }

    #[test]
    fn adpsgd_doubles_comm_volume_vs_gosgd() {
        let (c, w) = base();
        let go = simulate(&c, &w, SimAlgo::GoSgd, 1);
        let ad = simulate(&c, &w, SimAlgo::AdPsgd, 1);
        assert!((ad.comm_gbytes / go.comm_gbytes - 2.0).abs() < 0.01);
    }

    #[test]
    fn co2_overlap_beats_slowmo_wallclock() {
        let c = Cluster::c2();
        let w = Workload::gpt2_medium(c.m);
        let co2 = simulate(&c, &w, SimAlgo::Co2 { period: 12 }, 1);
        let slowmo = simulate(&c, &w, SimAlgo::SlowMo { period: 12 }, 1);
        assert!(co2.wall_s <= slowmo.wall_s);
    }

    #[test]
    fn dc_asgd_ps_ships_more_and_hier_ships_less() {
        let c = Cluster::c2();
        let w = Workload::resnet50_cifar(c.m);
        let ps = simulate(&c, &w, SimAlgo::AsgdPs { shards: 2, dc: false }, 1);
        let dc = simulate(&c, &w, SimAlgo::AsgdPs { shards: 2, dc: true }, 1);
        // x_then rides along: (2+1)/(1+1) = 1.5x the PS volume
        assert!((dc.comm_gbytes / ps.comm_gbytes - 1.5).abs() < 0.01, "{} vs {}", dc.comm_gbytes, ps.comm_gbytes);
        // only leader syncs pay the link: far below whole-model gossip
        let go = simulate(&c, &w, SimAlgo::GoSgd, 1);
        let hier = simulate(&c, &w, SimAlgo::HierGossip { groups: 2, period: 12 }, 1);
        assert!(hier.comm_gbytes < 0.5 * go.comm_gbytes, "{} vs {}", hier.comm_gbytes, go.comm_gbytes);
        assert!(hier.occupancy > 0.9, "occupancy {}", hier.occupancy);
    }

    #[test]
    fn ps_trainer_occupancy_counts_trainers_only() {
        let c = Cluster::c1();
        let w = Workload::resnet18_cifar(c.m);
        let r = simulate(&c, &w, SimAlgo::AsgdPs { shards: 1, dc: false }, 1);
        // 2 trainers push through a fat intra-node link: near-full overlap
        assert!(r.occupancy > 0.8, "occupancy {}", r.occupancy);
        assert!(r.batches > 0);
    }

    #[test]
    fn mfu_ordering_matches_table4_pretraining() {
        // Table 4 (GPT-2 Medium): AD-PSGD ~ LayUp > DDP ~ GoSGD > CO2/SlowMo
        let c = Cluster::c2();
        let w = Workload::gpt2_medium(c.m);
        let r: std::collections::HashMap<_, _> = SimAlgo::paper_set(12)
            .into_iter()
            .map(|a| {
                let s = simulate(&c, &w, a, 1);
                (s.algo, s.mfu)
            })
            .collect();
        assert!(r["LayUp"] > r["DDP"], "{r:?}");
        assert!(r["AD-PSGD"] > r["DDP"], "{r:?}");
    }
}
