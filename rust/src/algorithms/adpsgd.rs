//! **AD-PSGD** baseline (Lian et al., 2018): asynchronous decentralized
//! parallel SGD with *symmetric* pairwise averaging.
//!
//! After each local SGD step the worker picks a random peer and both models
//! are set to their elementwise average. The symmetry is what distinguishes
//! it from push-style gossip (GoSGD/LayUp) — and what doubles communication
//! volume, as the paper notes. Our lock-free implementation mirrors the
//! paper's atomics: the average is computed from a snapshot and written to
//! both replicas; concurrent writers may interleave (races lose updates,
//! never safety).
//!
//! Gradients accumulate in the engine-owned [`StepState`], so interleaved
//! steps (`bwd_threads > 1`) are safe: each in-flight pass has its own stash.

use std::sync::Arc;

use anyhow::Result;

use crate::algorithms::{
    comm_delay, maybe_compensate, observe_apply, PerLayerOpt, StepState, WorkerAlgo,
};
use crate::comm::{wire_bytes, Fabric, Payload};
use crate::config::TrainConfig;
use crate::coordinator::Shared;
use crate::manifest::ModelManifest;
use crate::resilience::AlgoState;
use crate::session::events::TrainEvent;
use crate::tensor::Tensor;
use crate::topology::Topology;
use crate::util::rng::Pcg32;

pub struct AdPsgd {
    wid: usize,
    shared: Arc<Shared>,
    opt: PerLayerOpt,
    topology: Topology,
    rng: Pcg32,
    comm_latency_s: f64,
}

impl AdPsgd {
    pub fn new(
        cfg: &TrainConfig,
        wid: usize,
        shared: Arc<Shared>,
        manifest: &ModelManifest,
    ) -> AdPsgd {
        let pool = Arc::clone(&shared.update_pool);
        AdPsgd {
            wid,
            shared,
            opt: PerLayerOpt::new(&cfg.optim, &cfg.schedule, manifest, wid, pool),
            topology: cfg.topology.clone(),
            rng: Pcg32::new(cfg.seed ^ 0xadb5d ^ ((wid as u64) << 24)),
            comm_latency_s: cfg.comm_latency_s,
        }
    }
}

impl WorkerAlgo for AdPsgd {
    fn on_layer_grads(
        &mut self,
        ctx: &mut StepState,
        layer: usize,
        grads: Vec<Tensor>,
    ) -> Result<()> {
        ctx.stash(layer, grads);
        Ok(())
    }

    fn on_step_end(&mut self, mut ctx: StepState) -> Result<()> {
        let step = ctx.step();
        let mut grads = ctx.take_grads();
        for (li, g) in grads.iter_mut().enumerate() {
            observe_apply(&self.shared, self.wid, ctx.stamp(li), li, step);
            let xt = ctx.take_x_then(li);
            maybe_compensate(&mut self.opt, &self.shared, self.wid, li, g, xt.as_ref());
            self.opt.step_layer(&self.shared.params[self.wid], li, g, step);
        }
        let my = &self.shared.params[self.wid];

        // symmetric pairwise averaging — two transfers (there and back),
        // hence 2x the communication volume of a push-only scheme
        let peer = self
            .topology
            .peer(self.wid, self.shared.m, step as u64, &mut self.rng);
        if !self.shared.membership.alive(peer) {
            // dead peer (chaos injection): skip the exchange this step —
            // AD-PSGD ships no weight, so nothing needs reclaiming
            self.shared
                .events
                .emit(TrainEvent::GossipSkipped { worker: self.wid, peer, step });
            return Ok(());
        }
        if self.shared.fabric.fused_gossip() {
            // shared-memory fast path: the seed-era synchronous swap
            let peer_params = &self.shared.params[peer];
            comm_delay(2.0 * self.comm_latency_s);
            let pool = &self.shared.update_pool;
            for (li, layer) in my.layers.iter().enumerate() {
                for (ti, t) in layer.tensors.iter().enumerate() {
                    let mine = t.snapshot();
                    // peer = (peer + mine)/2
                    peer_params.layers[li].tensors[ti].mix_from_sharded(0.5, 0.5, &mine.data, pool);
                    // mine = the freshly averaged peer value (symmetric result)
                    let avg = peer_params.layers[li].tensors[ti].snapshot();
                    t.store_from_sharded(&avg.data, pool);
                }
                // both halves of the swap were written: stamp both clocks
                peer_params.layers[li].clock.record(self.wid, step);
                my.layers[li].clock.record(peer, step);
            }
            let bytes = wire_bytes(my.numel());
            self.shared
                .fabric
                .core()
                .record_instant(&self.shared, self.wid, peer, step, bytes);
            self.shared
                .fabric
                .core()
                .record_instant(&self.shared, peer, self.wid, step, bytes);
            self.shared
                .events
                .emit(TrainEvent::GossipApplied { worker: self.wid, peer, step });
        } else {
            // delayed symmetric averaging: the peer mixes the snapshot on
            // delivery and ships its pre-mix snapshot back — both halves
            // ride the links, and a straggling link shows up as staleness
            // instead of a stall (the DaSGD-style relaxation)
            let flat = Arc::new(my.flatten());
            let _ = self.shared.fabric.push(
                &self.shared,
                self.wid,
                peer,
                step,
                Payload::PairAverage { flat, reply: false },
            );
        }
        Ok(())
    }

    fn state_dict(&mut self) -> Result<AlgoState> {
        Ok(AlgoState {
            opt: Some(self.opt.state_dict()),
            rng: Some(self.rng.state()),
            outer: None,
        })
    }

    fn load_state_dict(&mut self, state: AlgoState) -> Result<()> {
        if let Some(opt) = &state.opt {
            self.opt.load_state_dict(opt)?;
        }
        if let Some(rng) = state.rng {
            self.rng = Pcg32::from_state(rng);
        }
        Ok(())
    }
}
