//! **LayUp** (paper Algorithm 1): asynchronous decentralized SGD with
//! lock-free, layer-wise, randomized-gossip push-sum updates.
//!
//! Per worker there are two threads:
//!
//! * the **computation thread** (the engine's training loop) runs
//!   forward + backward and, as each layer's gradient pops out of the
//!   backward pass, notifies the updater (`on_layer_grads` -> mpsc send —
//!   the "Notify: updater thread i" line of Algorithm 1);
//! * the **updater thread** (spawned here) receives those notifications and,
//!   for each layer: applies the local SGD update to its own shared store
//!   (`x^{i,l} <- x̃^{i,l} - η ∇L`), then pushes the freshly updated layer
//!   into the chosen peer's store with the push-sum mixing fraction.
//!
//! Push-sum bookkeeping per iteration: at the first layer of an iteration the
//! updater picks a uniform random peer j, halves its own weight, and tries to
//! claim j's accept slot. If j is busy (another updater is mid-push — the
//! contention case of Section 3.1) the whole iteration's peer updates are
//! *skipped* and the shipped weight reclaimed; the local updates still apply,
//! so no gradient information is lost, only its propagation is delayed. At
//! the last layer (layer 0 — backward runs output->input) the slot is
//! released and `w_j += w_i` has already been folded in by `try_accept`.
//!
//! The updater keys its per-iteration push state by the step carried in each
//! message, so interleaved steps from several backward threads are safe by
//! construction — LayUp was the existence proof for the [`StepState`]
//! contract the other algorithms now share.
//!
//! The `model_granularity` flag turns off the paper's core idea (updates are
//! buffered in the engine-owned [`StepState`] and applied/pushed only after
//! the full backward pass) — this is the GoSGD-like ablation used to isolate
//! the contribution of layer-wise updates.

use std::collections::HashMap;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::{Context, Result};

use crate::algorithms::{
    attenuate_frac, comm_delay, maybe_compensate, observe_apply, PerLayerOpt, StepState,
    WorkerAlgo,
};
use crate::comm::{wire_bytes, Fabric, Payload, PushOutcome};
use crate::config::{Mixing, TrainConfig};
use crate::coordinator::Shared;
use crate::manifest::ModelManifest;
use crate::optim::OptState;
use crate::resilience::AlgoState;
use crate::session::events::TrainEvent;
use crate::telemetry::Phase as TelPhase;
use crate::tensor::clock::ClockStamp;
use crate::tensor::Tensor;
use crate::topology::Topology;
use crate::util::rng::Pcg32;

enum Msg {
    Layer {
        step: usize,
        layer: usize,
        grads: Vec<Tensor>,
        /// the pass's read-time clock snapshot of this layer (None when the
        /// engine captured no snapshot — unit tests)
        stamp: Option<ClockStamp>,
        /// forward-time parameter values (DC compensation; None when off)
        x_then: Option<Vec<Tensor>>,
    },
    Done,
    /// Checkpoint/lockstep sync point: every message sent before this one
    /// has been applied when the ack fires (the channel is FIFO).
    Quiesce(Sender<()>),
    /// Snapshot the updater-owned optimizer moments + gossip RNG.
    StateDict(Sender<(OptState, (u64, u64))>),
    /// Restore a snapshot (checkpoint resume); acks the load result.
    Load(OptState, (u64, u64), Sender<Result<()>>),
}

pub struct LayUp {
    tx: Sender<Msg>,
    updater: Option<JoinHandle<Result<()>>>,
    model_granularity: bool,
}

impl LayUp {
    pub fn new(
        cfg: &TrainConfig,
        wid: usize,
        shared: Arc<Shared>,
        manifest: &ModelManifest,
        model_granularity: bool,
    ) -> LayUp {
        let (tx, rx) = channel();
        let opt = PerLayerOpt::new(
            &cfg.optim,
            &cfg.schedule,
            manifest,
            wid,
            Arc::clone(&shared.update_pool),
        );
        let updater = UpdaterThread {
            wid,
            shared,
            opt,
            topology: cfg.topology.clone(),
            rng: Pcg32::new(cfg.seed ^ (0x1a1a << 8) ^ wid as u64),
            comm_latency_s: cfg.comm_latency_s,
            n_layers: manifest.layers.len(),
            scratch: Vec::new(),
        };
        let handle = std::thread::Builder::new()
            .name(format!("updater-{wid}"))
            .spawn(move || updater.run(rx))
            .expect("spawning updater thread");
        LayUp { tx, updater: Some(handle), model_granularity }
    }
}

impl WorkerAlgo for LayUp {
    fn on_layer_grads(
        &mut self,
        ctx: &mut StepState,
        layer: usize,
        grads: Vec<Tensor>,
    ) -> Result<()> {
        if self.model_granularity {
            // ablation: buffer until the backward pass completes
            ctx.stash(layer, grads);
            return Ok(());
        }
        let stamp = ctx.stamp(layer);
        let x_then = ctx.take_x_then(layer);
        self.tx
            .send(Msg::Layer { step: ctx.step(), layer, grads, stamp, x_then })
            .context("updater thread gone")
    }

    fn on_step_end(&mut self, mut ctx: StepState) -> Result<()> {
        if self.model_granularity {
            let step = ctx.step();
            // replay in arrival (reverse layer) order so the updater's
            // iteration bookkeeping — open at the deepest layer, close at
            // layer 0 — matches the streaming path
            for (layer, grads) in ctx.take_grads().into_iter().enumerate().rev() {
                let stamp = ctx.stamp(layer);
                let x_then = ctx.take_x_then(layer);
                self.tx
                    .send(Msg::Layer { step, layer, grads, stamp, x_then })
                    .context("updater thread gone")?;
            }
        }
        Ok(())
    }

    fn finish(&mut self) -> Result<()> {
        let _ = self.tx.send(Msg::Done);
        if let Some(h) = self.updater.take() {
            h.join().expect("updater panicked")?;
        }
        Ok(())
    }

    /// Block until the updater thread applied everything sent so far. The
    /// channel is FIFO, so an acked ping proves all prior layer messages
    /// (local updates AND peer pushes) landed in the shared stores.
    fn quiesce(&mut self) -> Result<()> {
        let (ack, done) = channel();
        self.tx.send(Msg::Quiesce(ack)).context("updater thread gone")?;
        done.recv().context("updater thread gone (quiesce)")
    }

    fn state_dict(&mut self) -> Result<AlgoState> {
        let (ack, reply) = channel();
        self.tx.send(Msg::StateDict(ack)).context("updater thread gone")?;
        let (opt, rng) = reply.recv().context("updater thread gone (state_dict)")?;
        Ok(AlgoState { opt: Some(opt), rng: Some(rng), outer: None })
    }

    fn load_state_dict(&mut self, state: AlgoState) -> Result<()> {
        let (Some(opt), Some(rng)) = (state.opt, state.rng) else {
            return Ok(());
        };
        let (ack, reply) = channel();
        self.tx.send(Msg::Load(opt, rng, ack)).context("updater thread gone")?;
        reply.recv().context("updater thread gone (load_state_dict)")?
    }
}

/// The paper's "Updater Thread i".
struct UpdaterThread {
    wid: usize,
    shared: Arc<Shared>,
    opt: PerLayerOpt,
    topology: Topology,
    rng: Pcg32,
    comm_latency_s: f64,
    n_layers: usize,
    /// reusable send buffer (§Perf: allocation-free updater inner loop)
    scratch: Vec<f32>,
}

/// Per-iteration push state (keyed by step in the updater's in-flight map).
struct PushState {
    peer: usize,
    /// mixing fraction w_i/(w_i+w_j); None => skipped on contention
    frac: Option<f32>,
    shipped_w: f32,
}

/// Per-iteration push state on a queued (simulated) fabric.
struct SimPush {
    peer: usize,
    /// weight to ride the step's opening message (taken on first send)
    open: Option<f32>,
    /// true once the opening message was dropped: remaining layers skip
    skipped: bool,
}

impl UpdaterThread {
    fn run(self, rx: Receiver<Msg>) -> Result<()> {
        self.shared.telemetry.register_thread(&format!("updater-{}", self.wid));
        // The transport decides the push mechanics: the instant fabric keeps
        // the seed-era in-place handshake + fused mix (bit-for-bit), a
        // queued fabric ships each layer as a message the peer applies at
        // its own step boundaries.
        if self.shared.fabric.fused_gossip() {
            self.run_instant(rx)
        } else {
            self.run_sim(rx)
        }
    }

    fn run_instant(mut self, rx: Receiver<Msg>) -> Result<()> {
        // Push state keyed by step: with `bwd_threads > 1` the backward pool
        // interleaves layer messages of different steps, so several
        // iterations are in flight at once. Each keeps its own peer/fraction
        // from first layer to layer 0 (one halve + one peer per iteration,
        // exactly as in the serial stream); the map holds at most
        // `bwd_threads` entries.
        let mut pushes: HashMap<usize, PushState> = HashMap::new();
        loop {
            let msg = match rx.recv() {
                Ok(m) => m,
                Err(_) => break, // sender dropped (worker errored out)
            };
            match msg {
                Msg::Done => break,
                Msg::Quiesce(ack) => {
                    let _ = ack.send(()); // FIFO: everything before us applied
                }
                Msg::StateDict(ack) => {
                    let _ = ack.send((self.opt.state_dict(), self.rng.state()));
                }
                Msg::Load(opt, rng, ack) => {
                    let r = self.opt.load_state_dict(&opt);
                    if r.is_ok() {
                        self.rng = Pcg32::from_state(rng);
                    }
                    let _ = ack.send(r);
                }
                Msg::Layer { step, layer, mut grads, stamp, x_then } => {
                    if !pushes.contains_key(&step) {
                        let p = self.open_iteration(step);
                        pushes.insert(step, p);
                    }
                    let (frac, peer) = {
                        let p = &pushes[&step];
                        (p.frac, p.peer)
                    };

                    // Staleness observation + opt-in update policies: τ is
                    // the writes that landed on this layer between the
                    // pass's read and this apply (clock snapshot delta).
                    let tau = observe_apply(&self.shared, self.wid, stamp, layer, step);
                    maybe_compensate(
                        &mut self.opt,
                        &self.shared,
                        self.wid,
                        layer,
                        &mut grads,
                        x_then.as_ref(),
                    );
                    // Adaptive mixing attenuates the per-layer mixing
                    // fraction by observed τ (identity when fixed / τ = 0).
                    let pol = self.shared.staleness_cfg;
                    let eff = |frac: f32| match pol.mixing {
                        Mixing::Adaptive => attenuate_frac(frac, tau, pol.mix_beta),
                        Mixing::Fixed => frac,
                    };
                    let my = &self.shared.params[self.wid];

                    // Local Update + Communication + Peer Update.
                    match frac {
                        // §Perf fused hot path: local update and peer push in
                        // ONE traversal of the layer's data (the step + load
                        // + mix sequence walked it three times).
                        Some(frac) if self.comm_latency_s <= 0.0 => {
                            let _sp = self.shared.telemetry.span(TelPhase::Gossip);
                            let frac = eff(frac);
                            let peer_params = &self.shared.params[peer];
                            self.opt.step_layer_mix(
                                my,
                                peer_params,
                                layer,
                                &grads,
                                step,
                                1.0 - frac,
                                frac,
                            );
                            self.shared.fabric.core().record_instant(
                                &self.shared,
                                self.wid,
                                peer,
                                step,
                                wire_bytes(my.layers[layer].numel()),
                            );
                        }
                        // Simulated link latency: the local update must land
                        // *before* the transit sleep (the device does not wait
                        // on the network), so the push stays a separate pass.
                        Some(frac) => {
                            let frac = eff(frac);
                            {
                                let _sp = self.shared.telemetry.span(TelPhase::OptStep);
                                self.opt.step_layer(my, layer, &grads, step);
                            }
                            let _sp = self.shared.telemetry.span(TelPhase::Gossip);
                            comm_delay(self.comm_latency_s);
                            let peer_params = &self.shared.params[peer];
                            let pool = &self.shared.update_pool;
                            for (ti, t) in my.layers[layer].tensors.iter().enumerate() {
                                self.scratch.resize(t.numel(), 0.0);
                                t.load_into_sharded(&mut self.scratch, pool);
                                peer_params.layers[layer].tensors[ti].mix_from_sharded(
                                    1.0 - frac,
                                    frac,
                                    &self.scratch,
                                    pool,
                                );
                            }
                            peer_params.layers[layer].clock.record(self.wid, step);
                            self.shared.fabric.core().record_instant(
                                &self.shared,
                                self.wid,
                                peer,
                                step,
                                wire_bytes(my.layers[layer].numel()),
                            );
                        }
                        // Skipped push (contention): local update only.
                        None => {
                            let _sp = self.shared.telemetry.span(TelPhase::OptStep);
                            self.opt.step_layer(my, layer, &grads, step);
                        }
                    }

                    // layer 0 is the last gradient of the backward pass
                    if layer == 0 {
                        if let Some(p) = pushes.remove(&step) {
                            self.close_iteration(p);
                        }
                    }
                }
            }
        }
        // don't leak busy slots of iterations that never reached layer 0
        // (only possible when the run is winding down on an error)
        for (_, p) in pushes.drain() {
            self.close_iteration(p);
        }
        Ok(())
    }

    /// Queued-fabric updater: the local update applies immediately (the
    /// device never waits on the network); each layer then ships as its own
    /// message, the step's first (deepest) layer carrying the halved
    /// push-sum weight. The *receiver* performs the weight handshake when
    /// that opening message arrives and mixes follower layers as they land —
    /// layer-wise propagation over real (simulated) links. A dropped opening
    /// message reclaims the weight and skips the step's remaining sends,
    /// exactly the contention-skip semantics of the instant path.
    fn run_sim(mut self, rx: Receiver<Msg>) -> Result<()> {
        let mut pushes: HashMap<usize, SimPush> = HashMap::new();
        loop {
            let msg = match rx.recv() {
                Ok(m) => m,
                Err(_) => break, // sender dropped (worker errored out)
            };
            match msg {
                Msg::Done => break,
                Msg::Quiesce(ack) => {
                    let _ = ack.send(()); // FIFO: everything before us applied
                }
                Msg::StateDict(ack) => {
                    let _ = ack.send((self.opt.state_dict(), self.rng.state()));
                }
                Msg::Load(opt, rng, ack) => {
                    let r = self.opt.load_state_dict(&opt);
                    if r.is_ok() {
                        self.rng = Pcg32::from_state(rng);
                    }
                    let _ = ack.send(r);
                }
                Msg::Layer { step, layer, mut grads, stamp, x_then } => {
                    if !pushes.contains_key(&step) {
                        let m = self.shared.m;
                        let peer = self.topology.peer(self.wid, m, step as u64, &mut self.rng);
                        if self.shared.membership.alive(peer) {
                            let shipped = self.shared.weights[self.wid].halve();
                            pushes
                                .insert(step, SimPush { peer, open: Some(shipped), skipped: false });
                        } else {
                            // dead peer (chaos injection): the step's pushes
                            // are skipped, the weight never leaves home
                            self.shared.weights[self.wid]
                                .skipped
                                .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                            self.shared.events.emit(TrainEvent::GossipSkipped {
                                worker: self.wid,
                                peer,
                                step,
                            });
                            pushes.insert(step, SimPush { peer, open: None, skipped: true });
                        }
                    }
                    // Staleness observation + optional DC compensation (τ is
                    // computed BEFORE the local apply below lands).
                    let tau = observe_apply(&self.shared, self.wid, stamp, layer, step);
                    maybe_compensate(
                        &mut self.opt,
                        &self.shared,
                        self.wid,
                        layer,
                        &mut grads,
                        x_then.as_ref(),
                    );
                    // local update first — Algorithm 1's
                    // `x^{i,l} <- x̃^{i,l} - η ∇L` never waits on a link
                    {
                        let _sp = self.shared.telemetry.span(TelPhase::OptStep);
                        self.opt
                            .step_layer(&self.shared.params[self.wid], layer, &grads, step);
                    }

                    let p = pushes.get_mut(&step).expect("push state opened above");
                    if !p.skipped {
                        let _sp = self.shared.telemetry.span(TelPhase::Gossip);
                        let tensors = &self.shared.params[self.wid].layers[layer].tensors;
                        let mut vals: Vec<Vec<f32>> = Vec::with_capacity(tensors.len());
                        for t in tensors {
                            let mut v = vec![0.0f32; t.numel()];
                            t.load_into_sharded(&mut v, &self.shared.update_pool);
                            vals.push(v);
                        }
                        let open_w = p.open.take();
                        // the payload header carries the pushed layer's
                        // post-update clock stamp and the sender-observed τ
                        // (the receiver's adaptive mixing attenuates on it)
                        let sent_stamp = self.shared.params[self.wid].layers[layer].clock.stamp();
                        let outcome = self.shared.fabric.push(
                            &self.shared,
                            self.wid,
                            p.peer,
                            step,
                            Payload::LayerPush {
                                layer,
                                open: open_w,
                                values: Arc::new(vals),
                                stamp: sent_stamp,
                                tau,
                            },
                        );
                        if matches!(outcome, PushOutcome::Dropped | PushOutcome::Busy) {
                            if let Some(w) = open_w {
                                // the opening message never left: reclaim the
                                // weight and skip this step's remaining
                                // layers — information is delayed, not lost.
                                // Counted as a skip so the summary's
                                // gossip_skipped agrees with the event stream.
                                self.shared.weights[self.wid].reclaim(w);
                                self.shared.weights[self.wid]
                                    .skipped
                                    .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                                p.skipped = true;
                                self.shared.events.emit(TrainEvent::GossipSkipped {
                                    worker: self.wid,
                                    peer: p.peer,
                                    step,
                                });
                            }
                            // a dropped follower only delays that layer's mix
                        }
                    }
                    if layer == 0 {
                        pushes.remove(&step);
                    }
                }
            }
        }
        // reclaim opening weights of steps that never sent (wind-down on
        // error before their first layer message went out)
        for (_, p) in pushes.drain() {
            if let Some(w) = p.open {
                self.shared.weights[self.wid].reclaim(w);
            }
        }
        Ok(())
    }

    /// Start of an iteration: pick a peer, halve own weight, claim the
    /// peer's accept slot (skip on contention or a dead peer).
    fn open_iteration(&mut self, step: usize) -> PushState {
        let m = self.shared.m;
        let peer = self
            .topology
            .peer(self.wid, m, step as u64, &mut self.rng);
        if !self.shared.membership.alive(peer) {
            // dead peer (chaos injection): same semantics as a contention
            // skip — the weight stays home, propagation is delayed
            self.shared.weights[self.wid]
                .skipped
                .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            self.shared
                .events
                .emit(TrainEvent::GossipSkipped { worker: self.wid, peer, step });
            return PushState { peer, frac: None, shipped_w: 0.0 };
        }
        let shipped_w = self.shared.weights[self.wid].halve();
        let frac = self.shared.weights[peer].try_accept(shipped_w);
        if frac.is_none() {
            // contention: reclaim the weight — the paper's "no information
            // is really lost", the push is simply retried next iteration.
            self.shared.weights[self.wid].reclaim(shipped_w);
            self.shared
                .events
                .emit(TrainEvent::GossipSkipped { worker: self.wid, peer, step });
        } else {
            self.shared
                .events
                .emit(TrainEvent::GossipApplied { worker: self.wid, peer, step });
        }
        PushState { peer, frac, shipped_w }
    }

    fn close_iteration(&mut self, p: PushState) {
        if p.frac.is_some() {
            self.shared.weights[p.peer].release();
        }
        let _ = p.shipped_w;
        let _ = self.n_layers;
    }
}
