//! **CO2** baseline (Sun et al., 2024): Local SGD with *overlapped*
//! communication and an outer momentum step.
//!
//! CO2's point is that the global average need not stall the inner loop: the
//! averaging runs concurrently with the next round of local steps, at the
//! cost of using one-round-*stale* snapshots. We implement exactly that
//! semantics without a barrier: at each sync point a worker (1) ships its
//! current parameters to every peer over the communication fabric, (2)
//! averages whatever peer snapshots have *arrived* in its fabric mailboxes
//! (possibly from the previous round — that is the overlap; on a delayed
//! fabric they are older still), and (3) applies the SlowMo-style outer
//! momentum step. No worker ever waits, so a straggler cannot stall the
//! others — but the staleness adds drift, which is why CO2 trails LayUp on
//! task metrics in the paper.
//!
//! Being barrier-free and stash-free (gradients live in the engine-owned
//! [`StepState`]), CO2 runs on the decoupled pools at any `bwd_threads`.
//!
//! Following the paper (footnote 3), the penalty-gap correction of the CO2
//! paper is not implemented — the published CO2 code omits it too.

use std::sync::Arc;

use anyhow::Result;

use crate::algorithms::{comm_delay, localsgd::LocalSgd, slowmo::SlowMo, StepState, WorkerAlgo};
use crate::comm::{Fabric, Payload};
use crate::config::TrainConfig;
use crate::coordinator::Shared;
use crate::manifest::ModelManifest;
use crate::resilience::{AlgoState, OuterState};
use crate::tensor::Tensor;

pub struct Co2 {
    inner: LocalSgd,
    outer_momentum: f32,
    outer_lr: f32,
    u: Vec<f32>,
    x_prev: Vec<f32>,
}

impl Co2 {
    pub fn new(cfg: &TrainConfig, wid: usize, shared: Arc<Shared>, manifest: &ModelManifest) -> Co2 {
        let x_prev = shared.params[wid].flatten();
        // seed every peer's mailbox with the initial snapshot so the first
        // stale averages see all replicas (the seed-era code pre-published
        // its own slot the same way)
        let init = Arc::new(x_prev.clone());
        for peer in 0..shared.m {
            if peer != wid {
                let _ = shared.fabric.push(
                    &shared,
                    wid,
                    peer,
                    0,
                    Payload::ParamShare { flat: Arc::clone(&init) },
                );
            }
        }
        Co2 {
            inner: LocalSgd::new(cfg, wid, shared, manifest),
            outer_momentum: cfg.outer_momentum,
            outer_lr: cfg.outer_lr,
            u: vec![0.0; x_prev.len()],
            x_prev,
        }
    }

    /// Barrier-free average over the snapshots that have arrived: the own
    /// fresh snapshot at its own index plus each peer's latest mailbox
    /// entry, summed in sender order (bit-identical to the seed-era slot
    /// sweep on the instant fabric).
    fn stale_average(&self, mine: &Arc<Vec<f32>>) -> Vec<f32> {
        let shared = &self.inner.shared;
        let mut acc: Option<Vec<f32>> = None;
        let mut count = 0usize;
        for from in 0..shared.m {
            let snap: Option<Arc<Vec<f32>>> = if from == self.inner.wid {
                Some(Arc::clone(mine))
            } else {
                shared
                    .fabric
                    .core()
                    .latest_params(self.inner.wid, from)
                    .map(|(_, flat)| flat)
            };
            if let Some(v) = snap {
                match &mut acc {
                    None => acc = Some(v.as_ref().clone()),
                    Some(a) => {
                        for (x, &y) in a.iter_mut().zip(v.iter()) {
                            *x += y;
                        }
                    }
                }
                count += 1;
            }
        }
        let mut a = acc.expect("own snapshot always present");
        for x in &mut a {
            *x /= count as f32;
        }
        a
    }
}

impl WorkerAlgo for Co2 {
    fn on_layer_grads(
        &mut self,
        ctx: &mut StepState,
        layer: usize,
        grads: Vec<Tensor>,
    ) -> Result<()> {
        ctx.stash(layer, grads);
        Ok(())
    }

    fn on_step_end(&mut self, mut ctx: StepState) -> Result<()> {
        let step = ctx.step();
        self.inner.local_step(&mut ctx);
        if (step + 1) % self.inner.sync_period == 0 {
            let shared = Arc::clone(&self.inner.shared);
            let wid = self.inner.wid;
            // ship a fresh snapshot to every peer (starts the overlapped
            // "all-reduce"; on a delayed fabric it arrives late — staler
            // averages, never a stall)
            let mine = Arc::new(shared.params[wid].flatten());
            for peer in 0..shared.m {
                if peer != wid {
                    let _ = shared.fabric.push(
                        &shared,
                        wid,
                        peer,
                        step,
                        Payload::ParamShare { flat: Arc::clone(&mine) },
                    );
                }
            }
            comm_delay(self.inner.comm_latency_s);
            // pump the own inbox, then average whatever has arrived — NO
            // barrier (the overlap)
            shared.fabric.deliver_due(&shared, wid, step);
            let avg = self.stale_average(&mine);
            let x_new = SlowMo::outer_step(
                &mut self.u,
                &mut self.x_prev,
                &avg,
                self.outer_momentum,
                self.outer_lr,
            );
            shared.params[wid].store_flat_sharded(&x_new, wid, step, &shared.update_pool);
        }
        Ok(())
    }

    fn state_dict(&mut self) -> Result<AlgoState> {
        Ok(AlgoState {
            opt: Some(self.inner.opt.state_dict()),
            rng: None,
            outer: Some(OuterState { u: self.u.clone(), x_prev: self.x_prev.clone() }),
        })
    }

    fn load_state_dict(&mut self, state: AlgoState) -> Result<()> {
        if let Some(opt) = &state.opt {
            self.inner.opt.load_state_dict(opt)?;
        }
        if let Some(outer) = state.outer {
            if outer.u.len() != self.u.len() || outer.x_prev.len() != self.x_prev.len() {
                anyhow::bail!("outer-momentum state_dict length mismatch");
            }
            self.u = outer.u;
            self.x_prev = outer.x_prev;
        }
        Ok(())
    }
}
