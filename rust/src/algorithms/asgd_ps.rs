//! **ASGD-PS** / **DC-ASGD-PS**: asynchronous SGD against sharded parameter
//! servers (the `ps:N` role topology).
//!
//! The last `N` worker ids of the cluster run no model at all — they are
//! server shards, each owning a contiguous partition of the layers (see
//! [`crate::topology::roles`]). Trainers never step an optimizer: the moment
//! a layer's gradient exists, [`AsgdPs::on_layer_grads`] ships it to the
//! layer's owning shard as a [`Payload::GradPush`], layer-wise and
//! overlapping the rest of the backward pass exactly like LayUp's updater
//! dispatch. The shard applies it with its own optimizer stack
//! ([`crate::coordinator::PsState`]) and replies with the fresh layer values
//! (`Payload::ParamPull`), which land in the trainer's replica at its next
//! step boundary (instantly on the shared-memory transport).
//!
//! **DC-ASGD-PS** additionally ships the trainer's forward-time parameter
//! values `x_then` inside the push, and the *shard* compensates the stale
//! gradient with `λ·g⊙g⊙(x_now − x_then)` (Zheng et al., 2017) before
//! applying — the staleness provenance is the [`ClockStamp`] the trainer
//! captured when its forward pass read the layer.
//!
//! The gradient-apply and reply logic lives in `crate::comm`'s `GradPush` /
//! `ParamPull` arms so both transports share it; this file holds only the
//! trainer-side sender and the shard-side checkpoint proxy.

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::algorithms::{StepState, WorkerAlgo};
use crate::comm::{Fabric, Payload};
use crate::config::TrainConfig;
use crate::coordinator::Shared;
use crate::manifest::ModelManifest;
use crate::resilience::AlgoState;
use crate::tensor::Tensor;

/// Trainer side of the PS protocol: push gradients, pull parameters.
pub struct AsgdPs {
    wid: usize,
    shared: Arc<Shared>,
    /// ship `x_then` so the shard can delay-compensate (DC-ASGD-PS)
    dc: bool,
}

impl AsgdPs {
    pub fn new(
        _cfg: &TrainConfig,
        wid: usize,
        shared: Arc<Shared>,
        _manifest: &ModelManifest,
        dc: bool,
    ) -> AsgdPs {
        AsgdPs { wid, shared, dc }
    }
}

impl WorkerAlgo for AsgdPs {
    fn on_layer_grads(
        &mut self,
        ctx: &mut StepState,
        layer: usize,
        grads: Vec<Tensor>,
    ) -> Result<()> {
        let owner = loop {
            match self.shared.fabric.core().route_layer(&self.shared, layer) {
                Some(o) => break o,
                None => {
                    // the layer's shard is down under the Stall policy: the
                    // trainer cannot make progress without it, so it genuinely
                    // stalls here until the supervisor times the run out
                    // (under Shrink, route_layer re-partitions and heals)
                    if self.shared.should_stop() {
                        return Ok(());
                    }
                    std::thread::sleep(std::time::Duration::from_millis(1));
                }
            }
        };
        let flats: Vec<Vec<f32>> = grads.into_iter().map(|t| t.data).collect();
        let x_then = if self.dc {
            ctx.take_x_then(layer)
                .map(|xt| Arc::new(xt.into_iter().map(|t| t.data).collect::<Vec<Vec<f32>>>()))
        } else {
            None
        };
        // provenance: the clock snapshot the forward pass read — the shard
        // measures τ against its own clock version at apply time
        let stamp = ctx
            .stamp(layer)
            .unwrap_or_else(|| self.shared.params[self.wid].layers[layer].clock.stamp());
        // GradPush is reliable (never dropped, never Busy): the outcome is
        // Queued or Delivered, nothing to reclaim
        let _ = self.shared.fabric.push(
            &self.shared,
            self.wid,
            owner,
            ctx.step(),
            Payload::GradPush { layer, grads: Arc::new(flats), x_then, stamp },
        );
        Ok(())
    }

    fn on_step_end(&mut self, _ctx: StepState) -> Result<()> {
        // nothing local to apply: parameters arrive as ParamPull replies at
        // the engine's per-step deliver_due (synchronously on the instant
        // transport). No trainer-side optimizer, no trainer-side state.
        Ok(())
    }
}

/// Shard side: the apply path lives in the fabric (`GradPush` arm); this
/// proxy only exposes the shard's optimizer moments to the checkpoint
/// machinery through the standard [`WorkerAlgo`] state hooks.
pub struct PsShardAlgo {
    wid: usize,
    shared: Arc<Shared>,
}

impl PsShardAlgo {
    pub fn new(wid: usize, shared: Arc<Shared>) -> PsShardAlgo {
        PsShardAlgo { wid, shared }
    }
}

impl WorkerAlgo for PsShardAlgo {
    fn on_layer_grads(
        &mut self,
        _ctx: &mut StepState,
        _layer: usize,
        _grads: Vec<Tensor>,
    ) -> Result<()> {
        bail!("a PS shard runs no backward pass (worker {})", self.wid)
    }

    fn on_step_end(&mut self, _ctx: StepState) -> Result<()> {
        bail!("a PS shard runs no training steps (worker {})", self.wid)
    }

    fn state_dict(&mut self) -> Result<AlgoState> {
        let Some(ps) = self.shared.ps.as_ref() else {
            bail!("PsShardAlgo on a run without a PS topology");
        };
        let Some(k) = ps.shard_of(self.wid) else {
            bail!("worker {} is not a PS shard", self.wid);
        };
        Ok(AlgoState {
            opt: Some(ps.shards[k].lock().unwrap().state_dict()),
            rng: None,
            outer: None,
        })
    }

    fn load_state_dict(&mut self, state: AlgoState) -> Result<()> {
        let Some(ps) = self.shared.ps.as_ref() else {
            bail!("PsShardAlgo on a run without a PS topology");
        };
        let Some(k) = ps.shard_of(self.wid) else {
            bail!("worker {} is not a PS shard", self.wid);
        };
        if let Some(opt) = &state.opt {
            ps.shards[k].lock().unwrap().load_state_dict(opt)?;
        }
        Ok(())
    }
}
