//! **Local SGD** baseline (Stich, 2019): run `sync_period` purely local SGD
//! steps, then synchronize by global parameter averaging.
//!
//! This file also hosts the shared periodic-averaging machinery reused by
//! SlowMo and CO2 (both are Local SGD plus an outer optimizer step).

use std::sync::Arc;

use anyhow::Result;

use crate::algorithms::{comm_delay, observe_apply, PerLayerOpt, StepState, WorkerAlgo};
use crate::comm::{self, Fabric, Payload};
use crate::config::TrainConfig;
use crate::coordinator::Shared;
use crate::manifest::ModelManifest;
use crate::resilience::AlgoState;
use crate::tensor::Tensor;

pub struct LocalSgd {
    pub(crate) wid: usize,
    pub(crate) shared: Arc<Shared>,
    pub(crate) opt: PerLayerOpt,
    pub(crate) sync_period: usize,
    pub(crate) comm_latency_s: f64,
}

impl LocalSgd {
    pub fn new(
        cfg: &TrainConfig,
        wid: usize,
        shared: Arc<Shared>,
        manifest: &ModelManifest,
    ) -> LocalSgd {
        let pool = Arc::clone(&shared.update_pool);
        LocalSgd {
            wid,
            shared,
            opt: PerLayerOpt::new(&cfg.optim, &cfg.schedule, manifest, wid, pool),
            sync_period: cfg.sync_period.max(1),
            comm_latency_s: cfg.comm_latency_s,
        }
    }

    /// Apply one step's full gradient set locally (inner loop), recording
    /// each layer's observed staleness against the pass's clock snapshot.
    pub(crate) fn local_step(&mut self, ctx: &mut StepState) {
        let step = ctx.step();
        let grads = ctx.take_grads();
        let my = &self.shared.params[self.wid];
        for (li, g) in grads.iter().enumerate() {
            observe_apply(&self.shared, self.wid, ctx.stamp(li), li, step);
            self.opt.step_layer(my, li, g, step);
        }
    }

    /// Barrier-synchronized global parameter average (the "outer" sync),
    /// exchanged over the communication fabric: each worker ships its
    /// snapshot to every peer, then collects the step-tagged set (own
    /// snapshot at its own index, so the summation order — and the averaged
    /// floats — are bit-identical to the seed-era slot exchange). On a
    /// delayed fabric the collect blocks until every snapshot arrives.
    /// Returns `None` when the run is stopping, otherwise the averaged flat
    /// parameter vector (callers may post-process it, e.g. SlowMo momentum).
    pub(crate) fn global_average(&mut self, step: usize) -> Result<Option<Vec<f32>>> {
        let mine = Arc::new(self.shared.params[self.wid].flatten());
        for peer in 0..self.shared.m {
            if peer != self.wid {
                let _ = self.shared.fabric.push(
                    &self.shared,
                    self.wid,
                    peer,
                    step,
                    Payload::ParamShare { flat: Arc::clone(&mine) },
                );
            }
        }
        comm_delay(self.comm_latency_s);
        if !self.shared.barrier.wait(&self.shared.stop) {
            return Ok(None);
        }
        let Some(flats) = comm::collect_params(&self.shared, self.wid, step, mine) else {
            return Ok(None);
        };
        let avg = {
            let mut acc: Vec<f32> = flats[0].as_ref().clone();
            for f in &flats[1..] {
                for (a, &b) in acc.iter_mut().zip(f.iter()) {
                    *a += b;
                }
            }
            // under the Shrink recovery policy the collect skips dead
            // workers, so the denominator is the contributors actually
            // collected (== m on a fault-free run: bit-identical averages)
            let m = flats.len() as f32;
            for a in &mut acc {
                *a /= m;
            }
            acc
        };
        if !self.shared.barrier.wait(&self.shared.stop) {
            return Ok(None);
        }
        Ok(Some(avg))
    }
}

impl WorkerAlgo for LocalSgd {
    fn on_layer_grads(
        &mut self,
        ctx: &mut StepState,
        layer: usize,
        grads: Vec<Tensor>,
    ) -> Result<()> {
        ctx.stash(layer, grads);
        Ok(())
    }

    fn on_step_end(&mut self, mut ctx: StepState) -> Result<()> {
        let step = ctx.step();
        self.local_step(&mut ctx);
        if (step + 1) % self.sync_period == 0 {
            if let Some(avg) = self.global_average(step)? {
                self.shared.params[self.wid].store_flat_sharded(
                    &avg,
                    self.wid,
                    step,
                    &self.shared.update_pool,
                );
            }
        }
        Ok(())
    }

    fn state_dict(&mut self) -> Result<AlgoState> {
        Ok(AlgoState { opt: Some(self.opt.state_dict()), ..AlgoState::default() })
    }

    fn load_state_dict(&mut self, state: AlgoState) -> Result<()> {
        if let Some(opt) = &state.opt {
            self.opt.load_state_dict(opt)?;
        }
        Ok(())
    }
}
