//! **GoSGD** baseline (Blot et al., 2019): asynchronous push-sum gossip SGD
//! at *whole-model* granularity.
//!
//! Each worker performs a local SGD step, then pushes its entire parameter
//! vector to one uniformly random peer using the same push-sum weight
//! protocol as LayUp. The difference from LayUp is exactly the paper's
//! contribution in negative: updates are exchanged only after the complete
//! backward pass, from the worker thread itself — no per-layer overlap —
//! so information mixes less frequently and the communication sits on the
//! critical path of the step.
//!
//! Gradients accumulate in the engine-owned [`StepState`], so this algorithm
//! is safe under interleaved steps (`bwd_threads > 1`): each in-flight pass
//! carries its own stash, and the whole-model push at `on_step_end` runs
//! under the engine's per-worker hook mutex.

use std::sync::Arc;

use anyhow::Result;

use crate::algorithms::{
    comm_delay, maybe_compensate, observe_apply, PerLayerOpt, StepState, WorkerAlgo,
};
use crate::comm::{wire_bytes, Fabric, Payload, PushOutcome};
use crate::config::TrainConfig;
use crate::coordinator::Shared;
use crate::manifest::ModelManifest;
use crate::resilience::AlgoState;
use crate::session::events::TrainEvent;
use crate::tensor::Tensor;
use crate::topology::Topology;
use crate::util::rng::Pcg32;

pub struct GoSgd {
    wid: usize,
    shared: Arc<Shared>,
    opt: PerLayerOpt,
    topology: Topology,
    rng: Pcg32,
    comm_latency_s: f64,
}

impl GoSgd {
    pub fn new(
        cfg: &TrainConfig,
        wid: usize,
        shared: Arc<Shared>,
        manifest: &ModelManifest,
    ) -> GoSgd {
        let pool = Arc::clone(&shared.update_pool);
        GoSgd {
            wid,
            shared,
            opt: PerLayerOpt::new(&cfg.optim, &cfg.schedule, manifest, wid, pool),
            topology: cfg.topology.clone(),
            rng: Pcg32::new(cfg.seed ^ 0x60560d ^ ((wid as u64) << 32)),
            comm_latency_s: cfg.comm_latency_s,
        }
    }
}

impl WorkerAlgo for GoSgd {
    fn on_layer_grads(
        &mut self,
        ctx: &mut StepState,
        layer: usize,
        grads: Vec<Tensor>,
    ) -> Result<()> {
        ctx.stash(layer, grads);
        Ok(())
    }

    fn on_step_end(&mut self, mut ctx: StepState) -> Result<()> {
        let step = ctx.step();
        // local SGD step over all layers at once, each apply observed
        // against the pass's clock snapshot (+ optional DC compensation)
        let mut grads = ctx.take_grads();
        for (li, g) in grads.iter_mut().enumerate() {
            observe_apply(&self.shared, self.wid, ctx.stamp(li), li, step);
            let xt = ctx.take_x_then(li);
            maybe_compensate(&mut self.opt, &self.shared, self.wid, li, g, xt.as_ref());
            self.opt.step_layer(&self.shared.params[self.wid], li, g, step);
        }
        let my = &self.shared.params[self.wid];

        // push-sum gossip of the whole model
        let peer = self
            .topology
            .peer(self.wid, self.shared.m, step as u64, &mut self.rng);
        if !self.shared.membership.alive(peer) {
            // the chosen peer's device is down (chaos injection): a push to
            // it would vanish, so treat it exactly like a contention skip —
            // the weight stays home and propagation is retried next step
            self.shared.weights[self.wid]
                .skipped
                .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            self.shared
                .events
                .emit(TrainEvent::GossipSkipped { worker: self.wid, peer, step });
            return Ok(());
        }
        let shipped = self.shared.weights[self.wid].halve();
        if self.shared.fabric.fused_gossip() {
            // shared-memory fast path: the seed-era in-place push-sum mix
            match self.shared.weights[peer].try_accept(shipped) {
                None => {
                    self.shared.weights[self.wid].reclaim(shipped);
                    self.shared
                        .events
                        .emit(TrainEvent::GossipSkipped { worker: self.wid, peer, step });
                }
                Some(frac) => {
                    comm_delay(self.comm_latency_s);
                    let peer_params = &self.shared.params[peer];
                    let pool = &self.shared.update_pool;
                    for (li, layer) in my.layers.iter().enumerate() {
                        for (ti, t) in layer.tensors.iter().enumerate() {
                            let snap = t.snapshot();
                            peer_params.layers[li].tensors[ti].mix_from_sharded(
                                1.0 - frac,
                                frac,
                                &snap.data,
                                pool,
                            );
                        }
                        peer_params.layers[li].clock.record(self.wid, step);
                    }
                    self.shared.weights[peer].release();
                    self.shared.fabric.core().record_instant(
                        &self.shared,
                        self.wid,
                        peer,
                        step,
                        wire_bytes(my.numel()),
                    );
                    self.shared
                        .events
                        .emit(TrainEvent::GossipApplied { worker: self.wid, peer, step });
                }
            }
        } else {
            // queued transport: ship the whole model; the receiver performs
            // the weight handshake and mixes at its next step boundary
            let mut values: Vec<Vec<Vec<f32>>> = Vec::with_capacity(my.layers.len());
            for layer in &my.layers {
                let mut lv: Vec<Vec<f32>> = Vec::with_capacity(layer.tensors.len());
                for t in &layer.tensors {
                    lv.push(t.snapshot().data);
                }
                values.push(lv);
            }
            let outcome = self.shared.fabric.push(
                &self.shared,
                self.wid,
                peer,
                step,
                Payload::ModelPush { w_in: shipped, values: Arc::new(values) },
            );
            if matches!(outcome, PushOutcome::Dropped | PushOutcome::Busy) {
                // the link lost it: reclaim — mass is never destroyed. Count
                // the skip on the sender's weight so the summary's
                // gossip_skipped agrees with the emitted events.
                self.shared.weights[self.wid].reclaim(shipped);
                self.shared.weights[self.wid]
                    .skipped
                    .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                self.shared
                    .events
                    .emit(TrainEvent::GossipSkipped { worker: self.wid, peer, step });
            }
        }
        Ok(())
    }

    fn state_dict(&mut self) -> Result<AlgoState> {
        Ok(AlgoState {
            opt: Some(self.opt.state_dict()),
            rng: Some(self.rng.state()),
            outer: None,
        })
    }

    fn load_state_dict(&mut self, state: AlgoState) -> Result<()> {
        if let Some(opt) = &state.opt {
            self.opt.load_state_dict(opt)?;
        }
        if let Some(rng) = state.rng {
            self.rng = Pcg32::from_state(rng);
        }
        Ok(())
    }
}
