//! **DDP** baseline: synchronous data-parallel SGD (Li et al., 2020).
//!
//! Every step: each worker stashes its full gradient set during backward,
//! then all workers meet at a barrier, all-reduce (average) the gradients,
//! and apply the identical averaged update with identical optimizer state —
//! so replicas stay bit-identical, exactly like torch DDP with NCCL
//! all-reduce. The two barriers bracket the exchange so no worker can
//! overwrite a slot that another worker has not read yet.
//!
//! The synchronization barrier is DDP's weakness the paper targets: a
//! straggler (Section 5.4) stalls *everyone*, and the serial
//! backward -> all-reduce -> step dependency caps MFU (Table 4).

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::algorithms::{average_grad_sets, comm_delay, PerLayerOpt, StepState, WorkerAlgo};
use crate::config::TrainConfig;
use crate::coordinator::Shared;
use crate::manifest::ModelManifest;
use crate::tensor::Tensor;

pub struct Ddp {
    wid: usize,
    shared: Arc<Shared>,
    opt: PerLayerOpt,
    comm_latency_s: f64,
}

impl Ddp {
    pub fn new(cfg: &TrainConfig, wid: usize, shared: Arc<Shared>, manifest: &ModelManifest) -> Ddp {
        Ddp {
            wid,
            shared,
            opt: PerLayerOpt::new(&cfg.optim, &cfg.schedule, manifest),
            comm_latency_s: cfg.comm_latency_s,
        }
    }
}

impl WorkerAlgo for Ddp {
    fn on_layer_grads(
        &mut self,
        ctx: &mut StepState,
        layer: usize,
        grads: Vec<Tensor>,
    ) -> Result<()> {
        // synchronous DDP can only buffer: updates wait for the barrier
        ctx.stash(layer, grads);
        Ok(())
    }

    fn on_step_end(&mut self, mut ctx: StepState) -> Result<()> {
        let step = ctx.step();
        // publish my gradients
        *self.shared.grad_slots[self.wid].lock().unwrap() = Some(ctx.take_grads());

        // all-reduce: barrier, average everyone's grads, barrier
        comm_delay(self.comm_latency_s);
        if !self.shared.barrier.wait(&self.shared.stop) {
            return Ok(()); // run is stopping
        }
        let avg = {
            let guards: Vec<_> = self
                .shared
                .grad_slots
                .iter()
                .map(|s| s.lock().unwrap())
                .collect();
            let sets: Vec<&crate::algorithms::GradSet> = guards
                .iter()
                .map(|g| g.as_ref().expect("worker missed grad publish"))
                .collect();
            if sets.len() != self.shared.m {
                bail!("ddp: incomplete gradient exchange");
            }
            average_grad_sets(&sets)
        };
        if !self.shared.barrier.wait(&self.shared.stop) {
            return Ok(());
        }

        // identical update on every worker keeps replicas in lock-step
        let my = &self.shared.params[self.wid];
        for (li, grads) in avg.iter().enumerate() {
            self.opt.step_layer(my, li, grads, step);
        }
        Ok(())
    }
}
