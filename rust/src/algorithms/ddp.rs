//! **DDP** baseline: synchronous data-parallel SGD (Li et al., 2020).
//!
//! Every step: each worker stashes its full gradient set during backward,
//! then all workers meet at a barrier, all-reduce (average) the gradients,
//! and apply the identical averaged update with identical optimizer state —
//! so replicas stay bit-identical, exactly like torch DDP with NCCL
//! all-reduce. The two barriers bracket the exchange so no worker can
//! overwrite a slot that another worker has not read yet.
//!
//! The synchronization barrier is DDP's weakness the paper targets: a
//! straggler (Section 5.4) stalls *everyone*, the serial
//! backward -> all-reduce -> step dependency caps MFU (Table 4), and on a
//! delayed fabric every round-trip pays the link latency — the comparison
//! `benches/fig_delay_robustness.rs` sweeps.
//!
//! Gradient exchange rides the communication fabric: each worker pushes its
//! `GradShare` to every peer, then collects the full step-tagged set (own
//! set at its own index, so the averaging order — and the averaged floats —
//! are bit-identical to the seed-era slot exchange).

use std::sync::Arc;

use anyhow::Result;

use crate::algorithms::{
    average_grad_sets, comm_delay, observe_apply, GradSet, PerLayerOpt, StepState, WorkerAlgo,
};
use crate::comm::{self, Fabric, Payload};
use crate::config::TrainConfig;
use crate::coordinator::Shared;
use crate::manifest::ModelManifest;
use crate::resilience::AlgoState;
use crate::tensor::Tensor;

pub struct Ddp {
    wid: usize,
    shared: Arc<Shared>,
    opt: PerLayerOpt,
    comm_latency_s: f64,
}

impl Ddp {
    pub fn new(cfg: &TrainConfig, wid: usize, shared: Arc<Shared>, manifest: &ModelManifest) -> Ddp {
        let pool = Arc::clone(&shared.update_pool);
        Ddp {
            wid,
            shared,
            opt: PerLayerOpt::new(&cfg.optim, &cfg.schedule, manifest, wid, pool),
            comm_latency_s: cfg.comm_latency_s,
        }
    }
}

impl WorkerAlgo for Ddp {
    fn on_layer_grads(
        &mut self,
        ctx: &mut StepState,
        layer: usize,
        grads: Vec<Tensor>,
    ) -> Result<()> {
        // synchronous DDP can only buffer: updates wait for the barrier
        ctx.stash(layer, grads);
        Ok(())
    }

    fn on_step_end(&mut self, mut ctx: StepState) -> Result<()> {
        let step = ctx.step();
        // ship my gradients to every peer (the fabric accounts the naive
        // all-gather volume: grad bytes x (m-1) per worker per step)
        let mine: Arc<GradSet> = Arc::new(ctx.take_grads());
        for peer in 0..self.shared.m {
            if peer != self.wid {
                let _ = self.shared.fabric.push(
                    &self.shared,
                    self.wid,
                    peer,
                    step,
                    Payload::GradShare { set: Arc::clone(&mine) },
                );
            }
        }

        // all-reduce: barrier, average everyone's grads, barrier. On a
        // delayed fabric the collect blocks until every share arrives — the
        // latency lands on DDP's critical path, as it does on real links.
        comm_delay(self.comm_latency_s);
        if !self.shared.barrier.wait(&self.shared.stop) {
            return Ok(()); // run is stopping
        }
        let Some(sets) = comm::collect_grads(&self.shared, self.wid, step, mine) else {
            return Ok(()); // run is stopping
        };
        let avg = {
            let refs: Vec<&GradSet> = sets.iter().map(|s| s.as_ref()).collect();
            average_grad_sets(&refs)
        };
        if !self.shared.barrier.wait(&self.shared.stop) {
            return Ok(());
        }

        // identical update on every worker keeps replicas in lock-step
        let my = &self.shared.params[self.wid];
        for (li, grads) in avg.iter().enumerate() {
            observe_apply(&self.shared, self.wid, ctx.stamp(li), li, step);
            self.opt.step_layer(my, li, grads, step);
        }
        Ok(())
    }

    fn state_dict(&mut self) -> Result<AlgoState> {
        Ok(AlgoState { opt: Some(self.opt.state_dict()), ..AlgoState::default() })
    }

    fn load_state_dict(&mut self, state: AlgoState) -> Result<()> {
        if let Some(opt) = &state.opt {
            self.opt.load_state_dict(opt)?;
        }
        Ok(())
    }
}
