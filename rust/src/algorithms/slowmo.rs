//! **SlowMo** baseline (Wang et al.): Local SGD with a slow outer momentum
//! step at every synchronization point.
//!
//! At sync `t`, with `x_prev` the (identical) post-sync parameters of the
//! previous sync and `x_avg` the fresh global average:
//!
//! ```text
//! u <- β u + (x_prev − x_avg)          (slow momentum buffer)
//! x <- x_prev − α u                    (outer step, α = outer_lr)
//! ```
//!
//! With β=0, α=1 this reduces exactly to Local SGD (property-tested). The
//! momentum buffer costs one extra model-size buffer — the memory overhead
//! the paper contrasts with LayUp's buffer-free design.

use std::sync::Arc;

use anyhow::Result;

use crate::algorithms::{localsgd::LocalSgd, StepState, WorkerAlgo};
use crate::config::TrainConfig;
use crate::coordinator::Shared;
use crate::manifest::ModelManifest;
use crate::resilience::{AlgoState, OuterState};
use crate::tensor::Tensor;

pub struct SlowMo {
    inner: LocalSgd,
    outer_momentum: f32,
    outer_lr: f32,
    /// slow momentum buffer u (model-size)
    u: Vec<f32>,
    /// parameters right after the previous outer step
    x_prev: Vec<f32>,
}

impl SlowMo {
    pub fn new(
        cfg: &TrainConfig,
        wid: usize,
        shared: Arc<Shared>,
        manifest: &ModelManifest,
    ) -> SlowMo {
        let x_prev = shared.params[wid].flatten();
        SlowMo {
            inner: LocalSgd::new(cfg, wid, shared, manifest),
            outer_momentum: cfg.outer_momentum,
            outer_lr: cfg.outer_lr,
            u: vec![0.0; x_prev.len()],
            x_prev,
        }
    }

    /// The outer step; shared with CO2.
    pub(crate) fn outer_step(
        u: &mut [f32],
        x_prev: &mut [f32],
        avg: &[f32],
        beta: f32,
        alpha: f32,
    ) -> Vec<f32> {
        let mut x_new = vec![0.0f32; avg.len()];
        for i in 0..avg.len() {
            u[i] = beta * u[i] + (x_prev[i] - avg[i]);
            x_new[i] = x_prev[i] - alpha * u[i];
        }
        x_prev.copy_from_slice(&x_new);
        x_new
    }
}

impl WorkerAlgo for SlowMo {
    fn on_layer_grads(
        &mut self,
        ctx: &mut StepState,
        layer: usize,
        grads: Vec<Tensor>,
    ) -> Result<()> {
        ctx.stash(layer, grads);
        Ok(())
    }

    fn on_step_end(&mut self, mut ctx: StepState) -> Result<()> {
        let step = ctx.step();
        self.inner.local_step(&mut ctx);
        if (step + 1) % self.inner.sync_period == 0 {
            if let Some(avg) = self.inner.global_average(step)? {
                let x_new = Self::outer_step(
                    &mut self.u,
                    &mut self.x_prev,
                    &avg,
                    self.outer_momentum,
                    self.outer_lr,
                );
                self.inner.shared.params[self.inner.wid].store_flat_sharded(
                    &x_new,
                    self.inner.wid,
                    step,
                    &self.inner.shared.update_pool,
                );
            }
        }
        Ok(())
    }

    fn state_dict(&mut self) -> Result<AlgoState> {
        Ok(AlgoState {
            opt: Some(self.inner.opt.state_dict()),
            rng: None,
            outer: Some(OuterState { u: self.u.clone(), x_prev: self.x_prev.clone() }),
        })
    }

    fn load_state_dict(&mut self, state: AlgoState) -> Result<()> {
        if let Some(opt) = &state.opt {
            self.inner.opt.load_state_dict(opt)?;
        }
        if let Some(outer) = state.outer {
            if outer.u.len() != self.u.len() || outer.x_prev.len() != self.x_prev.len() {
                anyhow::bail!("outer-momentum state_dict length mismatch");
            }
            self.u = outer.u;
            self.x_prev = outer.x_prev;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn beta_zero_alpha_one_reduces_to_plain_averaging() {
        let mut u = vec![0.0; 3];
        let mut x_prev = vec![1.0, 2.0, 3.0];
        let avg = vec![0.5, 1.5, 2.5];
        let x_new = SlowMo::outer_step(&mut u, &mut x_prev, &avg, 0.0, 1.0);
        assert_eq!(x_new, avg);
    }

    #[test]
    fn momentum_accumulates_drift_direction() {
        let mut u = vec![0.0];
        let mut x_prev = vec![1.0];
        // two syncs that each pull x down by 0.1
        let x1 = SlowMo::outer_step(&mut u, &mut x_prev, &[0.9], 0.5, 1.0);
        assert!((x1[0] - 0.9).abs() < 1e-6); // u = 0.1
        let x2 = SlowMo::outer_step(&mut u, &mut x_prev, &[0.8], 0.5, 1.0);
        // u = 0.5*0.1 + (0.9-0.8) = 0.15; x = 0.9 - 0.15 = 0.75 (overshoots avg)
        assert!((x2[0] - 0.75).abs() < 1e-6);
    }
}
