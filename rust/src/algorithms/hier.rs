//! **HierGossip**: hierarchical two-tier push-sum gossip (the `hier:G` role
//! topology).
//!
//! The cluster is split into `G` contiguous groups (the same ceil-split as
//! [`crate::topology::group_bounds`], so every group is non-empty). Two
//! tiers of mixing:
//!
//! * **intra-group, every step**: LayUp-style push-sum to a uniformly random
//!   peer *within the worker's own group*, applied through the in-place
//!   shared-memory path regardless of the run's fabric — group members model
//!   co-located devices (one node, NVLink-class links), so their exchanges
//!   are instant and free of the simulated WAN latency;
//! * **inter-group, every `sync_period` steps**: the group's *leader* (its
//!   lowest live wid) ships its full model to the next group's leader as a
//!   [`Payload::ModelPush`] over the fabric — this is the only traffic that
//!   pays the configured link latency/bandwidth, exactly the hierarchy that
//!   makes gossip viable across slow inter-node links.
//!
//! Push-sum weight bookkeeping is identical to GoSGD/LayUp: halve on send,
//! reclaim on any drop/contention — mass is delayed, never destroyed.

use std::sync::atomic::Ordering;
use std::sync::Arc;

use anyhow::Result;

use crate::algorithms::{maybe_compensate, observe_apply, PerLayerOpt, StepState, WorkerAlgo};
use crate::comm::{wire_bytes, Fabric, Payload, PushOutcome};
use crate::config::TrainConfig;
use crate::coordinator::Shared;
use crate::manifest::ModelManifest;
use crate::resilience::AlgoState;
use crate::session::events::TrainEvent;
use crate::tensor::Tensor;
use crate::topology::roles::TopologySpec;
use crate::topology::{group_bounds, group_of};
use crate::util::rng::Pcg32;

pub struct HierGossip {
    wid: usize,
    shared: Arc<Shared>,
    opt: PerLayerOpt,
    /// number of groups (validated `2..=workers`)
    groups: usize,
    /// inter-group leader exchange period (steps)
    sync_period: usize,
    rng: Pcg32,
}

impl HierGossip {
    pub fn new(
        cfg: &TrainConfig,
        wid: usize,
        shared: Arc<Shared>,
        manifest: &ModelManifest,
    ) -> HierGossip {
        let groups = match cfg.cluster {
            TopologySpec::Hier { groups } => groups,
            // degenerate fallback (unit tests building the algo directly):
            // one group = plain intra-group gossip, no leader tier
            _ => 1,
        };
        let pool = Arc::clone(&shared.update_pool);
        HierGossip {
            wid,
            shared,
            opt: PerLayerOpt::new(&cfg.optim, &cfg.schedule, manifest, wid, pool),
            groups,
            sync_period: cfg.sync_period.max(1),
            rng: Pcg32::new(cfg.seed ^ 0x41e72a ^ ((wid as u64) << 32)),
        }
    }

    /// Lowest live wid of group `k` (the group's leader), if any survive.
    fn leader_of(&self, k: usize) -> Option<usize> {
        let (lo, hi) = group_bounds(k, self.shared.m, self.groups);
        (lo..hi).find(|&w| self.shared.membership.alive(w))
    }

    fn skip(&self, peer: usize, step: usize) {
        self.shared.weights[self.wid].skipped.fetch_add(1, Ordering::Relaxed);
        self.shared
            .events
            .emit(TrainEvent::GossipSkipped { worker: self.wid, peer, step });
    }
}

impl WorkerAlgo for HierGossip {
    fn on_layer_grads(
        &mut self,
        ctx: &mut StepState,
        layer: usize,
        grads: Vec<Tensor>,
    ) -> Result<()> {
        ctx.stash(layer, grads);
        Ok(())
    }

    fn on_step_end(&mut self, mut ctx: StepState) -> Result<()> {
        let step = ctx.step();
        let mut grads = ctx.take_grads();
        for (li, g) in grads.iter_mut().enumerate() {
            observe_apply(&self.shared, self.wid, ctx.stamp(li), li, step);
            let xt = ctx.take_x_then(li);
            maybe_compensate(&mut self.opt, &self.shared, self.wid, li, g, xt.as_ref());
            self.opt.step_layer(&self.shared.params[self.wid], li, g, step);
        }
        let m = self.shared.m;
        let mine = group_of(self.wid, m, self.groups);
        let (lo, hi) = group_bounds(mine, m, self.groups);

        // tier 1: intra-group push-sum, in place (instant semantics — the
        // group models one node, whatever the run's fabric)
        if hi - lo > 1 {
            let span = (hi - lo - 1) as u64;
            let mut peer = lo + (self.rng.next_u64() % span) as usize;
            if peer >= self.wid {
                peer += 1; // uniform over the group minus self
            }
            if !self.shared.membership.alive(peer) {
                self.skip(peer, step);
            } else {
                let shipped = self.shared.weights[self.wid].halve();
                match self.shared.weights[peer].try_accept(shipped) {
                    None => {
                        self.shared.weights[self.wid].reclaim(shipped);
                        self.skip(peer, step);
                    }
                    Some(frac) => {
                        let my = &self.shared.params[self.wid];
                        let peer_params = &self.shared.params[peer];
                        let pool = &self.shared.update_pool;
                        for (li, layer) in my.layers.iter().enumerate() {
                            for (ti, t) in layer.tensors.iter().enumerate() {
                                let snap = t.snapshot();
                                peer_params.layers[li].tensors[ti].mix_from_sharded(
                                    1.0 - frac,
                                    frac,
                                    &snap.data,
                                    pool,
                                );
                            }
                            peer_params.layers[li].clock.record(self.wid, step);
                        }
                        self.shared.weights[peer].release();
                        self.shared.fabric.core().record_instant(
                            &self.shared,
                            self.wid,
                            peer,
                            step,
                            wire_bytes(my.numel()),
                        );
                        self.shared
                            .events
                            .emit(TrainEvent::GossipApplied { worker: self.wid, peer, step });
                    }
                }
            }
        }

        // tier 2: the group leader ships its model to the next group's
        // leader over the fabric (the only traffic paying link latency)
        if self.groups > 1
            && step % self.sync_period == self.sync_period - 1
            && self.leader_of(mine) == Some(self.wid)
        {
            let Some(peer) = self.leader_of((mine + 1) % self.groups) else {
                return Ok(()); // the whole next group is down
            };
            if peer == self.wid {
                return Ok(());
            }
            let my = &self.shared.params[self.wid];
            let shipped = self.shared.weights[self.wid].halve();
            let values: Vec<Vec<Vec<f32>>> = my
                .layers
                .iter()
                .map(|layer| layer.tensors.iter().map(|t| t.snapshot().data).collect())
                .collect();
            let outcome = self.shared.fabric.push(
                &self.shared,
                self.wid,
                peer,
                step,
                Payload::ModelPush { w_in: shipped, values: Arc::new(values) },
            );
            if matches!(outcome, PushOutcome::Dropped | PushOutcome::Busy) {
                self.shared.weights[self.wid].reclaim(shipped);
                self.skip(peer, step);
            }
        }
        Ok(())
    }

    fn state_dict(&mut self) -> Result<AlgoState> {
        Ok(AlgoState {
            opt: Some(self.opt.state_dict()),
            rng: Some(self.rng.state()),
            outer: None,
        })
    }

    fn load_state_dict(&mut self, state: AlgoState) -> Result<()> {
        if let Some(opt) = &state.opt {
            self.opt.load_state_dict(opt)?;
        }
        if let Some(rng) = state.rng {
            self.rng = Pcg32::from_state(rng);
        }
        Ok(())
    }
}
