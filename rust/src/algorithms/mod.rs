//! Distributed training algorithms (the paper's Section 4 "Baseline" set).
//!
//! Every algorithm implements [`WorkerAlgo`], driven by the per-worker
//! training engine in [`crate::coordinator`]:
//!
//! ```text
//! for step {
//!     forward();
//!     let mut ctx = StepState::new(step, n_layers);       // engine-owned
//!     backward(|layer, grads| algo.on_layer_grads(&mut ctx, layer, grads));
//!     algo.on_step_end(ctx);                              // ctx consumed
//! }
//! ```
//!
//! `on_layer_grads` fires the moment a layer's gradient exists — LayUp hands
//! it straight to its updater thread (overlapping the rest of the backward
//! pass); synchronous baselines merely stash it in the [`StepState`] until
//! `on_step_end`.
//!
//! # Threading contract
//!
//! In the serial loop the hooks run on the worker's single compute thread
//! and steps arrive strictly in order. In **decoupled** mode
//! (`TrainConfig::decoupled`) they run on the worker's *backward-pool*
//! threads instead, serialized by a per-worker mutex held across each
//! individual call:
//!
//! * One step's backward pass runs entirely on one backward thread, so its
//!   `on_layer_grads` calls still arrive in reverse layer order — but when
//!   `bwd_threads > 1` calls belonging to *different* steps interleave, and
//!   `on_step_end` is invoked by whichever thread finished that pass, not
//!   necessarily in step order.
//! * All per-iteration gradient state lives in the engine-owned
//!   [`StepState`]: the engine opens one per forward pass and threads it
//!   through that pass's hook calls, so interleaved steps can never
//!   cross-contaminate (each pass has its own stash). Algorithm structs may
//!   only hold *cross-step* state (optimizer moments, RNG, topology), which
//!   the per-worker mutex serializes.
//! * Because steps can complete out of order, anything step-dependent inside
//!   a hook (e.g. the LR schedule) must use the context's step, never an
//!   assumed-monotonic counter.
//! * Barrier-synchronized algorithms (DDP / LocalSGD / SlowMo) require
//!   lock-step in-order steps and are rejected for decoupled runs by
//!   `TrainConfig::validate`.

pub mod adpsgd;
pub mod asgd_ps;
pub mod co2;
pub mod ddp;
pub mod gosgd;
pub mod hier;
pub mod layup;
pub mod localsgd;
pub mod slowmo;

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::config::{Algorithm, Compensation, TrainConfig};
use crate::coordinator::Shared;
use crate::manifest::ModelManifest;
use crate::model::ModelParams;
use crate::optim::{LayerOptimizer, OptState, OptimKind, Schedule};
use crate::resilience::AlgoState;
use crate::session::events::TrainEvent;
use crate::sim::SimAlgo;
use crate::tensor::clock::ClockStamp;
use crate::tensor::shard::ShardPool;
use crate::tensor::Tensor;

/// Per-pass step context, owned by the training engine.
///
/// The engine opens one `StepState` per forward pass and hands it (by
/// mutable reference during backward, by value at step end) to the
/// [`WorkerAlgo`] hooks of that pass. Keeping the per-iteration gradient
/// stash *here* — instead of inside the algorithm struct — is what makes
/// stash-based algorithms (GoSGD, AD-PSGD, CO2) safe when several backward
/// threads interleave steps: two in-flight steps each carry their own state,
/// so out-of-order `on_step_end` delivery cannot mix their gradients.
pub struct StepState {
    step: usize,
    stash: GradStash,
    /// per-layer staleness-clock snapshot taken when the pass read its
    /// parameters (empty when the engine did not capture one — unit tests)
    clocks: Vec<ClockStamp>,
    /// forward-time parameter values per layer for DC-ASGD compensation
    /// (empty when `compensation = "none"`); taken per layer by the apply
    /// site, exactly once
    x_then: Vec<Option<Vec<Tensor>>>,
}

impl StepState {
    /// Open the context for `step` on a model with `n_layers` layers.
    pub fn new(step: usize, n_layers: usize) -> StepState {
        StepState {
            step,
            stash: GradStash::new(n_layers),
            clocks: Vec::new(),
            x_then: Vec::new(),
        }
    }

    /// Attach the pass's parameter-clock snapshot (builder style; the
    /// engine calls this right before the forward pass reads the stores).
    pub fn with_clocks(mut self, clocks: Vec<ClockStamp>) -> StepState {
        self.clocks = clocks;
        self
    }

    /// Attach the forward-time parameter values (`x_then[layer][param]`)
    /// for DC-ASGD delay compensation.
    pub fn with_x_then(mut self, x_then: Vec<Vec<Tensor>>) -> StepState {
        self.x_then = x_then.into_iter().map(Some).collect();
        self
    }

    /// The clock snapshot of `layer` at parameter-read time, when captured.
    pub fn stamp(&self, layer: usize) -> Option<ClockStamp> {
        self.clocks.get(layer).copied()
    }

    /// The full clock snapshot (empty when not captured).
    pub fn clocks(&self) -> &[ClockStamp] {
        &self.clocks
    }

    /// Take `layer`'s forward-time parameter values (DC compensation);
    /// `None` when compensation is off or the layer was already taken.
    pub fn take_x_then(&mut self, layer: usize) -> Option<Vec<Tensor>> {
        self.x_then.get_mut(layer).and_then(Option::take)
    }

    /// The training step this context belongs to.
    pub fn step(&self) -> usize {
        self.step
    }

    /// Stash one layer's gradients until `on_step_end`.
    pub fn stash(&mut self, layer: usize, grads: Vec<Tensor>) {
        self.stash.put(layer, grads);
    }

    /// Take the complete gradient set (panics if a layer is missing — the
    /// engine guarantees a full backward pass before `on_step_end`).
    pub fn take_grads(&mut self) -> GradSet {
        self.stash.take()
    }
}

/// Per-worker hook object. See the module docs for the threading contract.
pub trait WorkerAlgo: Send {
    /// Called during backward, in reverse layer order, as each layer's
    /// gradient becomes available. `ctx` is the engine-owned context of the
    /// pass this gradient belongs to.
    fn on_layer_grads(&mut self, ctx: &mut StepState, layer: usize, grads: Vec<Tensor>)
        -> Result<()>;

    /// Called after the backward pass of `ctx.step()` completed; consumes
    /// the step's context (and with it any stashed gradients).
    fn on_step_end(&mut self, ctx: StepState) -> Result<()>;

    /// Called once after the last step (join helper threads, flush state).
    fn finish(&mut self) -> Result<()> {
        Ok(())
    }

    /// Block until every asynchronously dispatched update (e.g. LayUp's
    /// updater-thread queue) has been applied to the shared stores. The
    /// checkpoint rendezvous calls this on every worker before snapshotting,
    /// and the deterministic lockstep driver calls it after every hook so
    /// replays are bit-exact. Synchronous algorithms have nothing in flight.
    fn quiesce(&mut self) -> Result<()> {
        Ok(())
    }

    /// Snapshot the algorithm's cross-step state (optimizer moments, gossip
    /// RNG, outer momentum) for a `resilience::checkpoint`. Must be called
    /// quiesced, at a step boundary.
    fn state_dict(&mut self) -> Result<AlgoState> {
        Ok(AlgoState::default())
    }

    /// Restore a [`WorkerAlgo::state_dict`] snapshot (checkpoint resume).
    /// Called before the first step runs.
    fn load_state_dict(&mut self, state: AlgoState) -> Result<()> {
        let _ = state;
        Ok(())
    }
}

/// Constructor signature of a thread-cluster algorithm.
pub type BuildFn = fn(&TrainConfig, usize, Arc<Shared>, &ModelManifest) -> Box<dyn WorkerAlgo>;

/// One entry of the algorithm registry: the single source of truth tying an
/// [`Algorithm`] to its display name, CLI spellings, thread-cluster
/// constructor and discrete-event-simulator counterpart. `main`, the bench
/// harness and the config parser all resolve algorithms through this table
/// instead of keeping divergent match arms.
pub struct AlgoSpec {
    pub algo: Algorithm,
    /// canonical display name (as the paper's tables print it)
    pub name: &'static str,
    /// accepted CLI / config spellings (lowercase)
    pub aliases: &'static [&'static str],
    /// thread-cluster constructor
    pub build: BuildFn,
    /// DES counterpart given the outer sync period (`None`: no DES model)
    pub sim: Option<fn(usize) -> SimAlgo>,
}

static REGISTRY: [AlgoSpec; 11] = [
    AlgoSpec {
        algo: Algorithm::Ddp,
        name: "DDP",
        aliases: &["ddp"],
        build: |c, w, s, m| Box::new(ddp::Ddp::new(c, w, s, m)),
        sim: Some(|_| SimAlgo::Ddp),
    },
    AlgoSpec {
        algo: Algorithm::LayUp,
        name: "LayUp",
        aliases: &["layup"],
        build: |c, w, s, m| Box::new(layup::LayUp::new(c, w, s, m, false)),
        sim: Some(|_| SimAlgo::LayUp),
    },
    AlgoSpec {
        algo: Algorithm::GoSgd,
        name: "GoSGD",
        aliases: &["gosgd"],
        build: |c, w, s, m| Box::new(gosgd::GoSgd::new(c, w, s, m)),
        sim: Some(|_| SimAlgo::GoSgd),
    },
    AlgoSpec {
        algo: Algorithm::AdPsgd,
        name: "AD-PSGD",
        aliases: &["adpsgd", "ad-psgd"],
        build: |c, w, s, m| Box::new(adpsgd::AdPsgd::new(c, w, s, m)),
        sim: Some(|_| SimAlgo::AdPsgd),
    },
    AlgoSpec {
        algo: Algorithm::SlowMo,
        name: "SlowMo",
        aliases: &["slowmo"],
        build: |c, w, s, m| Box::new(slowmo::SlowMo::new(c, w, s, m)),
        sim: Some(|period| SimAlgo::SlowMo { period }),
    },
    AlgoSpec {
        algo: Algorithm::Co2,
        name: "CO2",
        aliases: &["co2"],
        build: |c, w, s, m| Box::new(co2::Co2::new(c, w, s, m)),
        sim: Some(|period| SimAlgo::Co2 { period }),
    },
    AlgoSpec {
        algo: Algorithm::LocalSgd,
        name: "LocalSGD",
        aliases: &["localsgd", "local-sgd"],
        build: |c, w, s, m| Box::new(localsgd::LocalSgd::new(c, w, s, m)),
        sim: Some(|period| SimAlgo::LocalSgd { period }),
    },
    AlgoSpec {
        algo: Algorithm::LayUpModelGranularity,
        name: "LayUp(model)",
        aliases: &["layup-model", "layup_model"],
        build: |c, w, s, m| Box::new(layup::LayUp::new(c, w, s, m, true)),
        sim: None,
    },
    AlgoSpec {
        algo: Algorithm::AsgdPs,
        name: "ASGD-PS",
        aliases: &["asgd-ps", "asgd_ps"],
        build: |c, w, s, m| Box::new(asgd_ps::AsgdPs::new(c, w, s, m, false)),
        sim: None,
    },
    AlgoSpec {
        algo: Algorithm::DcAsgdPs,
        name: "DC-ASGD-PS",
        aliases: &["dcasgd-ps", "dc-asgd-ps"],
        build: |c, w, s, m| Box::new(asgd_ps::AsgdPs::new(c, w, s, m, true)),
        sim: None,
    },
    AlgoSpec {
        algo: Algorithm::HierGossip,
        name: "HierGossip",
        aliases: &["hier-gossip", "hiergossip"],
        build: |c, w, s, m| Box::new(hier::HierGossip::new(c, w, s, m)),
        sim: None,
    },
];

/// The full algorithm registry (paper set + ablations).
pub fn registry() -> &'static [AlgoSpec] {
    &REGISTRY
}

/// The registry entry for `algo` (every variant is registered).
pub fn spec(algo: Algorithm) -> &'static AlgoSpec {
    registry()
        .iter()
        .find(|s| s.algo == algo)
        .expect("every Algorithm variant is registered")
}

/// Resolve a CLI / config spelling to its algorithm.
pub fn parse_name(name: &str) -> Result<Algorithm> {
    let lower = name.to_ascii_lowercase();
    for s in registry() {
        if s.aliases.contains(&lower.as_str()) {
            return Ok(s.algo);
        }
    }
    let known: Vec<&str> = registry().iter().map(|s| s.aliases[0]).collect();
    bail!("unknown algorithm {name:?} (expected one of: {})", known.join(" "))
}

/// Instantiate the configured algorithm for worker `wid`.
pub fn build(
    cfg: &TrainConfig,
    wid: usize,
    shared: Arc<Shared>,
    manifest: &ModelManifest,
) -> Result<Box<dyn WorkerAlgo>> {
    if cfg.cluster.is_shard(wid, cfg.workers) {
        // role topologies: the last wids are parameter-server shards — no
        // training hooks, just the checkpoint proxy onto `Shared::ps`
        return Ok(Box::new(asgd_ps::PsShardAlgo::new(wid, shared)));
    }
    Ok((spec(cfg.algorithm).build)(cfg, wid, shared, manifest))
}

/// One optimizer per layer — the granularity LayUp steps at. Owns the
/// worker id so every apply stamps `(worker, step)` provenance into the
/// written layer's staleness clock.
pub struct PerLayerOpt {
    pub opts: Vec<LayerOptimizer>,
    pub schedule: Schedule,
    /// the worker whose replica this optimizer stack updates (clock stamps)
    pub wid: usize,
}

impl PerLayerOpt {
    /// One [`LayerOptimizer`] per manifest layer, all sharing `pool` for
    /// their parameter traversals (§Perf). Algorithm constructors pass the
    /// run's `Shared::update_pool`; pass `ShardPool::serial()` where
    /// sharding is not wired (tests, standalone benches).
    pub fn new(
        kind: &OptimKind,
        schedule: &Schedule,
        manifest: &ModelManifest,
        wid: usize,
        pool: Arc<ShardPool>,
    ) -> Self {
        let opts = manifest
            .layers
            .iter()
            .map(|lm| {
                let sizes: Vec<usize> = lm.params.iter().map(|p| p.numel()).collect();
                LayerOptimizer::with_pool(kind.clone(), &sizes, Arc::clone(&pool))
            })
            .collect();
        PerLayerOpt { opts, schedule: schedule.clone(), wid }
    }

    /// Apply one layer's gradient to the shared store at `step`'s LR and
    /// stamp the layer's staleness clock.
    pub fn step_layer(&mut self, params: &ModelParams, li: usize, grads: &[Tensor], step: usize) {
        let lr = self.schedule.lr_at(step);
        self.opts[li].step(&params.layers[li].tensors, grads, lr);
        params.layers[li].clock.record(self.wid, step);
    }

    /// DC-ASGD delay compensation for one layer (mutates `grads` in place;
    /// see [`LayerOptimizer::compensate`]). A separate pre-pass so it
    /// composes with both the plain and the fused apply below.
    pub fn compensate_layer(
        &mut self,
        params: &ModelParams,
        li: usize,
        grads: &mut [Tensor],
        lambda: f32,
        x_then: &[Tensor],
    ) {
        self.opts[li].compensate(&params.layers[li].tensors, grads, lambda, x_then);
    }

    /// Checkpoint view of every layer's optimizer moments.
    pub fn state_dict(&self) -> OptState {
        OptState { layers: self.opts.iter().map(LayerOptimizer::state_dict).collect() }
    }

    /// Restore a [`PerLayerOpt::state_dict`] snapshot.
    pub fn load_state_dict(&mut self, state: &OptState) -> Result<()> {
        if state.layers.len() != self.opts.len() {
            bail!(
                "optimizer state_dict has {} layers, model has {}",
                state.layers.len(),
                self.opts.len()
            );
        }
        for (opt, st) in self.opts.iter_mut().zip(&state.layers) {
            opt.load_state_dict(st)?;
        }
        Ok(())
    }

    /// Fused updater hot path (§Perf): apply one layer's gradient *and* push
    /// the freshly updated layer into `peer`'s store with the push-sum mixing
    /// fractions, in one traversal per parameter instead of the three passes
    /// of step + load + mix. Numerically identical to `step_layer` followed
    /// by mixing (absent concurrent writers).
    pub fn step_layer_mix(
        &mut self,
        params: &ModelParams,
        peer: &ModelParams,
        li: usize,
        grads: &[Tensor],
        step: usize,
        keep_frac: f32,
        push_frac: f32,
    ) {
        let lr = self.schedule.lr_at(step);
        self.opts[li].step_mix(
            &params.layers[li].tensors,
            grads,
            lr,
            &peer.layers[li].tensors,
            keep_frac,
            push_frac,
        );
        params.layers[li].clock.record(self.wid, step);
        peer.layers[li].clock.record(self.wid, step);
    }
}

/// Observe one gradient apply against the pass's clock snapshot: compute
/// the layer's observed delay τ (writes that landed on the layer between
/// the pass's parameter read and this apply), record it in the run's
/// per-layer staleness histogram, and emit a [`TrainEvent::StaleApply`]
/// when someone is listening. Returns τ (0 when no snapshot was captured).
pub fn observe_apply(
    shared: &Shared,
    wid: usize,
    stamp: Option<ClockStamp>,
    layer: usize,
    step: usize,
) -> u64 {
    let Some(snap) = stamp else {
        return 0;
    };
    let tau = shared.params[wid].layers[layer].clock.observed_tau(&snap);
    shared.staleness.record(layer, tau);
    if tau > 0 && shared.events.has_observers() {
        shared
            .events
            .emit(TrainEvent::StaleApply { worker: wid, layer, step, tau });
    }
    tau
}

/// Apply the run's DC compensation policy to one layer's gradients (in
/// place): identity unless `compensation = "dc"` AND the pass captured a
/// forward-time snapshot for this layer. One definition for every
/// gradient-apply site (LayUp's two updater loops, GoSGD, AD-PSGD).
pub(crate) fn maybe_compensate(
    opt: &mut PerLayerOpt,
    shared: &Shared,
    wid: usize,
    li: usize,
    grads: &mut [Tensor],
    x_then: Option<&Vec<Tensor>>,
) {
    if shared.staleness_cfg.compensation == Compensation::Dc {
        if let Some(xt) = x_then {
            opt.compensate_layer(
                &shared.params[wid],
                li,
                grads,
                shared.staleness_cfg.dc_lambda,
                xt,
            );
        }
    }
}

/// Staleness-adaptive mixing attenuation: `frac / (1 + β·τ)` — the more
/// writes a pushed layer missed, the less of it the receiver mixes in.
/// Identity at τ = 0 or β = 0 (the `mixing = "fixed"` numerics).
pub fn attenuate_frac(frac: f32, tau: u64, beta: f32) -> f32 {
    frac / (1.0 + beta * tau as f32)
}

/// A full gradient set: grads[layer][param].
pub type GradSet = Vec<Vec<Tensor>>;

/// Stash used by step-granularity algorithms: collects layer grads during
/// backward, hands the complete set to `on_step_end`. Lives inside the
/// engine-owned [`StepState`], one per in-flight pass.
#[derive(Default)]
pub struct GradStash {
    slots: Vec<Option<Vec<Tensor>>>,
}

impl GradStash {
    pub fn new(n_layers: usize) -> Self {
        GradStash { slots: (0..n_layers).map(|_| None).collect() }
    }

    pub fn put(&mut self, layer: usize, grads: Vec<Tensor>) {
        self.slots[layer] = Some(grads);
    }

    /// Take the complete gradient set (panics if any layer is missing —
    /// that would be a coordinator bug).
    pub fn take(&mut self) -> GradSet {
        self.slots
            .iter_mut()
            .map(|s| s.take().expect("missing layer grads"))
            .collect()
    }
}

/// Average `sets` elementwise into a fresh GradSet.
pub fn average_grad_sets(sets: &[&GradSet]) -> GradSet {
    let n = sets.len() as f32;
    let first = sets[0];
    first
        .iter()
        .enumerate()
        .map(|(li, layer)| {
            layer
                .iter()
                .enumerate()
                .map(|(pi, t)| {
                    let mut acc = t.clone();
                    for other in &sets[1..] {
                        acc.axpy(1.0, &other[li][pi]);
                    }
                    acc.scale(1.0 / n);
                    acc
                })
                .collect()
        })
        .collect()
}

/// Legacy sender-side communication sleep (`TrainConfig::comm_latency_s`).
/// Link-level delay, bandwidth and loss now live in the communication
/// fabric (`crate::comm`, `TrainConfig::fabric`); this knob survives as a
/// crude stall-the-sender model the older benches sweep.
pub fn comm_delay(seconds: f64) {
    if seconds > 0.0 {
        std::thread::sleep(std::time::Duration::from_secs_f64(seconds));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grad_stash_roundtrip() {
        let mut s = GradStash::new(2);
        s.put(1, vec![Tensor::from_vec(&[1], vec![2.0])]);
        s.put(0, vec![Tensor::from_vec(&[1], vec![1.0])]);
        let set = s.take();
        assert_eq!(set[0][0].data, vec![1.0]);
        assert_eq!(set[1][0].data, vec![2.0]);
    }

    #[test]
    #[should_panic(expected = "missing layer grads")]
    fn grad_stash_incomplete_panics() {
        let mut s = GradStash::new(2);
        s.put(0, vec![]);
        let _ = s.take();
    }

    #[test]
    fn average_grad_sets_means() {
        let a: GradSet = vec![vec![Tensor::from_vec(&[2], vec![0.0, 2.0])]];
        let b: GradSet = vec![vec![Tensor::from_vec(&[2], vec![4.0, 0.0])]];
        let avg = average_grad_sets(&[&a, &b]);
        assert_eq!(avg[0][0].data, vec![2.0, 1.0]);
    }

    #[test]
    fn registry_covers_every_algorithm_and_alias_roundtrips() {
        for algo in [
            Algorithm::Ddp,
            Algorithm::LayUp,
            Algorithm::GoSgd,
            Algorithm::AdPsgd,
            Algorithm::SlowMo,
            Algorithm::Co2,
            Algorithm::LocalSgd,
            Algorithm::LayUpModelGranularity,
            Algorithm::AsgdPs,
            Algorithm::DcAsgdPs,
            Algorithm::HierGossip,
        ] {
            let s = spec(algo);
            assert_eq!(s.algo, algo);
            for alias in s.aliases {
                assert_eq!(parse_name(alias).unwrap(), algo, "alias {alias}");
            }
        }
        assert!(parse_name("sgd??").is_err());
        // every paper algorithm has a DES counterpart
        for algo in Algorithm::all_paper() {
            assert!(spec(*algo).sim.is_some(), "{algo:?} needs a DES model");
        }
    }

    /// The tentpole invariant: two interleaved in-flight steps each keep
    /// their own engine-owned state, so layer gradients delivered while the
    /// other step is mid-backward — and step ends arriving out of order —
    /// can never cross-contaminate.
    #[test]
    fn step_states_isolate_interleaved_steps() {
        let mut a = StepState::new(7, 2);
        let mut b = StepState::new(8, 2);
        assert_eq!(a.step(), 7);
        assert_eq!(b.step(), 8);
        // interleaved reverse-layer-order delivery, as two backward threads
        // would produce it: b's layer 1, a's layer 1, a's layer 0, b's layer 0
        b.stash(1, vec![Tensor::from_vec(&[1], vec![81.0])]);
        a.stash(1, vec![Tensor::from_vec(&[1], vec![71.0])]);
        a.stash(0, vec![Tensor::from_vec(&[1], vec![70.0])]);
        b.stash(0, vec![Tensor::from_vec(&[1], vec![80.0])]);
        // out-of-order completion: step 8 ends before step 7
        let gb = b.take_grads();
        let ga = a.take_grads();
        assert_eq!(gb[0][0].data, vec![80.0]);
        assert_eq!(gb[1][0].data, vec![81.0]);
        assert_eq!(ga[0][0].data, vec![70.0]);
        assert_eq!(ga[1][0].data, vec![71.0]);
    }

    /// Same invariant through the trait: a stash-consuming algorithm sees
    /// exactly its own step's gradient set at `on_step_end`, whatever the
    /// delivery interleaving.
    #[test]
    fn out_of_order_step_end_delivers_uncontaminated_grad_sets() {
        struct Recorder {
            seen: Vec<(usize, Vec<f32>)>,
        }
        impl WorkerAlgo for Recorder {
            fn on_layer_grads(
                &mut self,
                ctx: &mut StepState,
                layer: usize,
                grads: Vec<Tensor>,
            ) -> Result<()> {
                ctx.stash(layer, grads);
                Ok(())
            }

            fn on_step_end(&mut self, mut ctx: StepState) -> Result<()> {
                let step = ctx.step();
                let flat: Vec<f32> = ctx
                    .take_grads()
                    .into_iter()
                    .flatten()
                    .flat_map(|t| t.data)
                    .collect();
                self.seen.push((step, flat));
                Ok(())
            }
        }

        let mut algo = Recorder { seen: Vec::new() };
        let mut s3 = StepState::new(3, 2);
        let mut s4 = StepState::new(4, 2);
        // two "backward threads" interleaving their reverse-order layers
        algo.on_layer_grads(&mut s3, 1, vec![Tensor::from_vec(&[1], vec![31.0])]).unwrap();
        algo.on_layer_grads(&mut s4, 1, vec![Tensor::from_vec(&[1], vec![41.0])]).unwrap();
        algo.on_layer_grads(&mut s4, 0, vec![Tensor::from_vec(&[1], vec![40.0])]).unwrap();
        algo.on_layer_grads(&mut s3, 0, vec![Tensor::from_vec(&[1], vec![30.0])]).unwrap();
        // step 4 completes before step 3
        algo.on_step_end(s4).unwrap();
        algo.on_step_end(s3).unwrap();
        assert_eq!(algo.seen[0], (4, vec![40.0, 41.0]));
        assert_eq!(algo.seen[1], (3, vec![30.0, 31.0]));
    }
}
