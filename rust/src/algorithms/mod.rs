//! Distributed training algorithms (the paper's Section 4 "Baseline" set).
//!
//! Every algorithm implements [`WorkerAlgo`], driven by the per-worker
//! training loop in [`crate::coordinator`]:
//!
//! ```text
//! for step {
//!     forward();
//!     backward(|layer, grads| algo.on_layer_grads(step, layer, grads));  // reverse layer order
//!     algo.on_step_end(step);
//! }
//! ```
//!
//! `on_layer_grads` fires the moment a layer's gradient exists — LayUp hands
//! it straight to its updater thread (overlapping the rest of the backward
//! pass); synchronous baselines merely stash it until `on_step_end`.

pub mod adpsgd;
pub mod co2;
pub mod ddp;
pub mod gosgd;
pub mod layup;
pub mod localsgd;
pub mod slowmo;

use std::sync::Arc;

use anyhow::Result;

use crate::config::{Algorithm, TrainConfig};
use crate::coordinator::Shared;
use crate::manifest::ModelManifest;
use crate::model::ModelParams;
use crate::optim::{LayerOptimizer, OptimKind, Schedule};
use crate::tensor::Tensor;

/// Per-worker hook object.
///
/// # Threading contract
///
/// In the serial loop the hooks run on the worker's single compute thread.
/// In **decoupled** mode (`TrainConfig::decoupled`) they run on the worker's
/// *backward-pool* threads instead, serialized by a per-worker mutex held
/// across each individual call:
///
/// * `on_layer_grads` calls for one `step` still arrive in reverse layer
///   order, but when `bwd_threads > 1` calls belonging to *different* steps
///   may interleave, and steps may complete out of order. Algorithms must
///   key any per-iteration state by `step` to opt into that
///   (`Algorithm::supports_interleaved_steps` — LayUp's updater qualifies;
///   the `GradStash`-based algorithms are limited to `bwd_threads = 1` by
///   `TrainConfig::validate`).
/// * `on_step_end(step)` is invoked by whichever backward thread finished
///   that pass — not necessarily in step order.
/// * Barrier-synchronized algorithms (DDP / LocalSGD / SlowMo) require
///   lock-step in-order steps and are rejected for decoupled runs by
///   `TrainConfig::validate`.
pub trait WorkerAlgo: Send {
    /// Called during backward, in reverse layer order, as each layer's
    /// gradient becomes available.
    fn on_layer_grads(&mut self, step: usize, layer: usize, grads: Vec<Tensor>) -> Result<()>;

    /// Called after the backward pass of `step` completed.
    fn on_step_end(&mut self, step: usize) -> Result<()>;

    /// Called once after the last step (join helper threads, flush state).
    fn finish(&mut self) -> Result<()> {
        Ok(())
    }
}

/// Instantiate the algorithm for worker `wid`.
pub fn build(
    cfg: &TrainConfig,
    wid: usize,
    shared: Arc<Shared>,
    manifest: &ModelManifest,
) -> Result<Box<dyn WorkerAlgo>> {
    Ok(match cfg.algorithm {
        Algorithm::Ddp => Box::new(ddp::Ddp::new(cfg, wid, shared, manifest)),
        Algorithm::LayUp => Box::new(layup::LayUp::new(cfg, wid, shared, manifest, false)),
        Algorithm::LayUpModelGranularity => {
            Box::new(layup::LayUp::new(cfg, wid, shared, manifest, true))
        }
        Algorithm::GoSgd => Box::new(gosgd::GoSgd::new(cfg, wid, shared, manifest)),
        Algorithm::AdPsgd => Box::new(adpsgd::AdPsgd::new(cfg, wid, shared, manifest)),
        Algorithm::LocalSgd => Box::new(localsgd::LocalSgd::new(cfg, wid, shared, manifest)),
        Algorithm::SlowMo => Box::new(slowmo::SlowMo::new(cfg, wid, shared, manifest)),
        Algorithm::Co2 => Box::new(co2::Co2::new(cfg, wid, shared, manifest)),
    })
}

/// One optimizer per layer — the granularity LayUp steps at.
pub struct PerLayerOpt {
    pub opts: Vec<LayerOptimizer>,
    pub schedule: Schedule,
}

impl PerLayerOpt {
    pub fn new(kind: &OptimKind, schedule: &Schedule, manifest: &ModelManifest) -> Self {
        let opts = manifest
            .layers
            .iter()
            .map(|lm| {
                let sizes: Vec<usize> = lm.params.iter().map(|p| p.numel()).collect();
                LayerOptimizer::new(kind.clone(), &sizes)
            })
            .collect();
        PerLayerOpt { opts, schedule: schedule.clone() }
    }

    /// Apply one layer's gradient to the shared store at `step`'s LR.
    pub fn step_layer(&mut self, params: &ModelParams, li: usize, grads: &[Tensor], step: usize) {
        let lr = self.schedule.lr_at(step);
        self.opts[li].step(&params.layers[li].tensors, grads, lr);
    }

    /// Fused updater hot path (§Perf): apply one layer's gradient *and* push
    /// the freshly updated layer into `peer`'s store with the push-sum mixing
    /// fractions, in one traversal per parameter instead of the three passes
    /// of step + load + mix. Numerically identical to `step_layer` followed
    /// by mixing (absent concurrent writers).
    pub fn step_layer_mix(
        &mut self,
        params: &ModelParams,
        peer: &ModelParams,
        li: usize,
        grads: &[Tensor],
        step: usize,
        keep_frac: f32,
        push_frac: f32,
    ) {
        let lr = self.schedule.lr_at(step);
        self.opts[li].step_mix(
            &params.layers[li].tensors,
            grads,
            lr,
            &peer.layers[li].tensors,
            keep_frac,
            push_frac,
        );
    }
}

/// A full gradient set: grads[layer][param].
pub type GradSet = Vec<Vec<Tensor>>;

/// Stash used by step-granularity algorithms: collects layer grads during
/// backward, hands the complete set to `on_step_end`.
#[derive(Default)]
pub struct GradStash {
    slots: Vec<Option<Vec<Tensor>>>,
}

impl GradStash {
    pub fn new(n_layers: usize) -> Self {
        GradStash { slots: (0..n_layers).map(|_| None).collect() }
    }

    pub fn put(&mut self, layer: usize, grads: Vec<Tensor>) {
        self.slots[layer] = Some(grads);
    }

    /// Take the complete gradient set (panics if any layer is missing —
    /// that would be a coordinator bug).
    pub fn take(&mut self) -> GradSet {
        self.slots
            .iter_mut()
            .map(|s| s.take().expect("missing layer grads"))
            .collect()
    }
}

/// Average `sets` elementwise into a fresh GradSet.
pub fn average_grad_sets(sets: &[&GradSet]) -> GradSet {
    let n = sets.len() as f32;
    let first = sets[0];
    first
        .iter()
        .enumerate()
        .map(|(li, layer)| {
            layer
                .iter()
                .enumerate()
                .map(|(pi, t)| {
                    let mut acc = t.clone();
                    for other in &sets[1..] {
                        acc.axpy(1.0, &other[li][pi]);
                    }
                    acc.scale(1.0 / n);
                    acc
                })
                .collect()
        })
        .collect()
}

/// Simulated communication latency: sleep if configured (thread cluster has
/// no real network; the DES models paper-scale links instead).
pub fn comm_delay(seconds: f64) {
    if seconds > 0.0 {
        std::thread::sleep(std::time::Duration::from_secs_f64(seconds));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grad_stash_roundtrip() {
        let mut s = GradStash::new(2);
        s.put(1, vec![Tensor::from_vec(&[1], vec![2.0])]);
        s.put(0, vec![Tensor::from_vec(&[1], vec![1.0])]);
        let set = s.take();
        assert_eq!(set[0][0].data, vec![1.0]);
        assert_eq!(set[1][0].data, vec![2.0]);
    }

    #[test]
    #[should_panic(expected = "missing layer grads")]
    fn grad_stash_incomplete_panics() {
        let mut s = GradStash::new(2);
        s.put(0, vec![]);
        let _ = s.take();
    }

    #[test]
    fn average_grad_sets_means() {
        let a: GradSet = vec![vec![Tensor::from_vec(&[2], vec![0.0, 2.0])]];
        let b: GradSet = vec![vec![Tensor::from_vec(&[2], vec![4.0, 0.0])]];
        let avg = average_grad_sets(&[&a, &b]);
        assert_eq!(avg[0][0].data, vec![2.0, 1.0]);
    }
}
