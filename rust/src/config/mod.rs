//! Experiment configuration: a TOML-subset parser plus typed experiment
//! presets mirroring the paper's hyper-parameter tables (A5–A9).
//!
//! Supported TOML subset: `[section]` headers, `key = value` with string,
//! bool, integer, float and homogeneous-array values, `#` comments. That is
//! everything our experiment files use; exotic TOML (dates, inline tables,
//! multiline strings) is intentionally rejected.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use crate::comm::{FabricSpec, LatencyDist};
use crate::optim::{OptimKind, Schedule};
use crate::resilience::{FaultPlan, RecoveryPolicy};
use crate::topology::roles::TopologySpec;
use crate::topology::Topology;

/// Parsed TOML-subset document: section -> key -> value.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Toml {
    pub sections: BTreeMap<String, BTreeMap<String, TomlValue>>,
}

#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Str(String),
    Bool(bool),
    Int(i64),
    Float(f64),
    Arr(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Int(i) => Some(*i as f64),
            TomlValue::Float(f) => Some(*f),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            TomlValue::Int(i) if *i >= 0 => Some(*i as usize),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

impl Toml {
    pub fn parse(text: &str) -> Result<Toml> {
        let mut doc = Toml::default();
        let mut section = String::new();
        doc.sections.entry(section.clone()).or_default();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|l| l.strip_suffix(']')) {
                section = name.trim().to_string();
                doc.sections.entry(section.clone()).or_default();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .with_context(|| format!("line {}: expected key = value", lineno + 1))?;
            let value = parse_value(v.trim())
                .with_context(|| format!("line {}: bad value {:?}", lineno + 1, v.trim()))?;
            doc.sections
                .get_mut(&section)
                .unwrap()
                .insert(k.trim().to_string(), value);
        }
        Ok(doc)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&TomlValue> {
        self.sections.get(section)?.get(key)
    }

    pub fn f64_or(&self, section: &str, key: &str, default: f64) -> f64 {
        self.get(section, key).and_then(|v| v.as_f64()).unwrap_or(default)
    }

    pub fn usize_or(&self, section: &str, key: &str, default: usize) -> usize {
        self.get(section, key).and_then(|v| v.as_usize()).unwrap_or(default)
    }

    pub fn str_or<'a>(&'a self, section: &str, key: &str, default: &'a str) -> &'a str {
        self.get(section, key).and_then(|v| v.as_str()).unwrap_or(default)
    }

    pub fn bool_or(&self, section: &str, key: &str, default: bool) -> bool {
        self.get(section, key).and_then(|v| v.as_bool()).unwrap_or(default)
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' inside quotes is preserved
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(v: &str) -> Result<TomlValue> {
    if let Some(stripped) = v.strip_prefix('"') {
        let Some(inner) = stripped.strip_suffix('"') else {
            bail!("unterminated string");
        };
        return Ok(TomlValue::Str(inner.to_string()));
    }
    if v == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if v == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(inner) = v.strip_prefix('[').and_then(|x| x.strip_suffix(']')) {
        let items = inner.trim();
        if items.is_empty() {
            return Ok(TomlValue::Arr(Vec::new()));
        }
        let vals: Result<Vec<_>> = items.split(',').map(|x| parse_value(x.trim())).collect();
        return Ok(TomlValue::Arr(vals?));
    }
    if let Ok(i) = v.parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = v.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    bail!("unrecognized value")
}

/// Stale-gradient correction policy (`[staleness] compensation`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Compensation {
    /// Apply gradients as computed (the default; numerics-neutral).
    None,
    /// DC-ASGD delay compensation (Zheng et al.): correct each applied
    /// gradient with `λ·g⊙g⊙(x_now − x_then)` against the forward-time
    /// parameter snapshot.
    Dc,
}

/// Gossip mixing policy under observed staleness (`[staleness] mixing`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mixing {
    /// The push-sum fraction as the weight handshake produced it (default).
    Fixed,
    /// Attenuate LayUp's per-layer mixing fraction by the observed per-layer
    /// delay: `frac / (1 + β·τ)` — a stale push mixes in less.
    Adaptive,
}

/// Staleness policy knobs (`[staleness]` config section, `--compensation` /
/// `--adaptive-mix` CLI flags, `SessionBuilder::staleness`). The defaults
/// (`compensation = "none"`, `mixing = "fixed"`) are numerics-neutral: runs
/// are bit-identical to a build without the staleness machinery.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct StalenessConfig {
    pub compensation: Compensation,
    /// DC-ASGD correction strength λ (the paper uses 0.04–0.1)
    pub dc_lambda: f32,
    pub mixing: Mixing,
    /// adaptive-mixing attenuation strength β in `frac / (1 + β·τ)`
    pub mix_beta: f32,
}

impl Default for StalenessConfig {
    fn default() -> Self {
        StalenessConfig {
            compensation: Compensation::None,
            dc_lambda: 0.04,
            mixing: Mixing::Fixed,
            mix_beta: 0.5,
        }
    }
}

impl StalenessConfig {
    /// Reject nonsensical knobs and policy/algorithm combinations. The
    /// policies act where gradients are applied against possibly-stale
    /// parameters: compensation needs the gossip algorithms' per-worker
    /// apply path, adaptive mixing needs LayUp's push-sum fractions.
    pub fn validate(&self, algorithm: Algorithm) -> Result<()> {
        if !self.dc_lambda.is_finite() || self.dc_lambda < 0.0 {
            bail!("staleness.lambda must be a finite nonnegative number, got {}", self.dc_lambda);
        }
        if !self.mix_beta.is_finite() || self.mix_beta < 0.0 {
            bail!("staleness.beta must be a finite nonnegative number, got {}", self.mix_beta);
        }
        let gossip = matches!(
            algorithm,
            Algorithm::LayUp
                | Algorithm::LayUpModelGranularity
                | Algorithm::GoSgd
                | Algorithm::AdPsgd
        );
        if self.compensation == Compensation::Dc && !gossip {
            bail!(
                "compensation = \"dc\" corrects stale asynchronous applies and is \
                 supported for layup/layup-model/gosgd/adpsgd; {} applies synchronously",
                algorithm.name()
            );
        }
        let layup = matches!(algorithm, Algorithm::LayUp | Algorithm::LayUpModelGranularity);
        if self.mixing == Mixing::Adaptive && !layup {
            bail!(
                "mixing = \"adaptive\" attenuates LayUp's push-sum mixing fractions; \
                 {} does not use them",
                algorithm.name()
            );
        }
        Ok(())
    }
}

/// Which distributed algorithm a run uses (Section 4 "Baseline").
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algorithm {
    Ddp,
    LayUp,
    GoSgd,
    AdPsgd,
    SlowMo,
    Co2,
    LocalSgd,
    /// Ablation: LayUp with model-granularity (whole-model) updates.
    LayUpModelGranularity,
    /// Classic asynchronous SGD against a sharded parameter server
    /// (`ps:N` topology): trainers push per-layer gradients, shards apply
    /// and reply with fresh parameters.
    AsgdPs,
    /// DC-ASGD (Zheng et al.): ASGD-PS where shards compensate each stale
    /// gradient with `λ·g⊙g⊙(x_now − x_then)` against the trainer's
    /// push-time parameter snapshot.
    DcAsgdPs,
    /// Hierarchical two-tier gossip (`hier:G` topology): LayUp push-sum
    /// inside groups, periodic leader-level model exchange across groups.
    HierGossip,
}

impl Algorithm {
    /// Resolve a CLI / config spelling via the algorithm registry
    /// ([`crate::algorithms::registry`] — the single source of truth).
    pub fn parse(s: &str) -> Result<Algorithm> {
        crate::algorithms::parse_name(s)
    }

    /// Canonical display name (as the paper's tables print it), from the
    /// algorithm registry.
    pub fn name(&self) -> &'static str {
        crate::algorithms::spec(*self).name
    }

    /// Algorithms that synchronize workers step-for-step at a barrier.
    /// They require lock-step in-order steps and cannot run on the decoupled
    /// forward/backward pools (passes complete out of order there).
    ///
    /// Every non-barrier algorithm runs decoupled at ANY `bwd_threads`: the
    /// engine-owned per-pass `StepState` keys gradient state by step, so
    /// interleaved steps cannot cross-contaminate.
    pub fn uses_barrier(&self) -> bool {
        matches!(self, Algorithm::Ddp | Algorithm::LocalSgd | Algorithm::SlowMo)
    }

    pub fn all_paper() -> &'static [Algorithm] {
        &[
            Algorithm::Ddp,
            Algorithm::Co2,
            Algorithm::SlowMo,
            Algorithm::GoSgd,
            Algorithm::AdPsgd,
            Algorithm::LayUp,
        ]
    }
}

/// Full configuration of one training run on the thread cluster.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub model: String,
    pub algorithm: Algorithm,
    pub workers: usize,
    pub steps: usize,
    pub eval_every: usize,
    pub seed: u64,
    pub optim: OptimKind,
    pub schedule: Schedule,
    pub topology: Topology,
    /// cluster role/routing topology (`--topology {flat,ps:N,hier:G}`):
    /// flat peer-to-peer (default, seed-era behavior), star/parameter-server
    /// with N layer-partitioning shards, or hierarchical two-tier groups
    pub cluster: TopologySpec,
    /// outer-loop period for LocalSGD/SlowMo/CO2 (paper's `out_freq`)
    pub sync_period: usize,
    /// outer (slow) momentum for SlowMo/CO2
    pub outer_momentum: f32,
    pub outer_lr: f32,
    /// injected straggler: (worker id, extra iterations of delay per step)
    pub straggler: Option<(usize, f64)>,
    /// simulated per-message communication latency (seconds, thread cluster)
    pub comm_latency_s: f64,
    /// track drift/bias every k steps (0 = off; it is expensive)
    pub track_drift_every: usize,
    /// run each worker as decoupled forward/backward thread pools connected
    /// by a bounded pass queue (PD-ASGD style). `false` keeps the serial
    /// fwd->bwd loop, step-for-step identical to the original — every
    /// existing bench stays comparable.
    pub decoupled: bool,
    /// forward-pool threads per worker (decoupled mode; ratio sweepable)
    pub fwd_threads: usize,
    /// backward-pool threads per worker (decoupled mode)
    pub bwd_threads: usize,
    /// shard-pool lanes for the parameter hot path (§Perf): traversals of
    /// the lock-free stores (optimizer steps, gossip mixes, collective
    /// write-backs) split across this many threads. 1 (default) keeps the
    /// serial path — bit-identical to the unsharded behavior.
    pub update_threads: usize,
    /// bounded pass-queue capacity per worker: the forward pool blocks
    /// (backpressure) once this many passes await backward
    pub queue_depth: usize,
    /// communication fabric: `Instant` (seed-era shared-memory semantics,
    /// default) or `Sim` (per-link latency, bandwidth and loss — the
    /// delay-robustness experiments)
    pub fabric: FabricSpec,
    /// fabric-boundary compression codec: `Dense` (identity, default),
    /// `TopK`/`RandK` sparsification with error feedback, or `Int8`
    /// stochastic quantization — every payload kind and every algorithm
    /// inherits it without per-algorithm changes
    pub codec: crate::comm::CodecSpec,
    /// step-frame coalescing at the fabric boundary (`[fabric] coalesce`,
    /// `--coalesce`): buffer one step's consecutive `LayerPush`es per link
    /// and ship them as a single `StepFrame` — one wire header, one codec
    /// pass over the whole step (global top-k), one delivery event. Default
    /// off: bit-identical seed curves
    pub coalesce: bool,
    /// write a `resilience::checkpoint` every k steps (0 = off)
    pub checkpoint_every: usize,
    /// parent directory for periodic checkpoints (`step-XXXXXX` subdirs)
    pub checkpoint_dir: std::path::PathBuf,
    /// chaos fault schedule (empty = no injected failures)
    pub faults: FaultPlan,
    /// how collective (barrier) algorithms react to a dead peer
    pub recovery: RecoveryPolicy,
    /// Stall policy: seconds a permanently lost worker may block the
    /// collective before the run is reported stalled and stopped
    pub stall_timeout_s: f64,
    /// deterministic lockstep driver: one thread runs every worker
    /// round-robin with quiesced updates — same seed, same floats, every
    /// run (resume-parity testing, replay debugging). Rejected for barrier
    /// algorithms, decoupled pools, chaos and stragglers.
    pub lockstep: bool,
    /// staleness update policies: delay compensation and adaptive mixing
    /// (defaults off — numerics-neutral)
    pub staleness: StalenessConfig,
    /// telemetry: span tracing, time-series sampling and trace export
    /// (default off — bit-identical, zero hot-path allocations)
    pub telemetry: crate::telemetry::TelemetryConfig,
}

impl TrainConfig {
    pub fn new(model: &str, algorithm: Algorithm, workers: usize, steps: usize) -> Self {
        TrainConfig {
            model: model.to_string(),
            algorithm,
            workers,
            steps,
            eval_every: (steps / 20).max(1),
            seed: 42,
            optim: OptimKind::sgd(0.9, 0.0),
            schedule: Schedule::Cosine { lr: 0.05, t_max: steps, warmup_steps: 0, warmup_lr: 0.0 },
            topology: Topology::Random,
            cluster: TopologySpec::Flat,
            sync_period: 12,
            outer_momentum: 0.5,
            outer_lr: 1.0,
            straggler: None,
            comm_latency_s: 0.0,
            track_drift_every: 0,
            decoupled: false,
            fwd_threads: 1,
            bwd_threads: 1,
            update_threads: 1,
            queue_depth: 2,
            fabric: FabricSpec::Instant,
            codec: crate::comm::CodecSpec::Dense,
            coalesce: false,
            checkpoint_every: 0,
            checkpoint_dir: std::path::PathBuf::from("checkpoints"),
            faults: FaultPlan::default(),
            recovery: RecoveryPolicy::Stall,
            stall_timeout_s: 60.0,
            lockstep: false,
            staleness: StalenessConfig::default(),
            telemetry: crate::telemetry::TelemetryConfig::default(),
        }
    }

    /// Check cross-field invariants before a run. Called by
    /// `session::SessionBuilder::build`; surfaced here so configs can be
    /// rejected at parse time too.
    pub fn validate(&self) -> Result<()> {
        if self.workers == 0 {
            bail!("workers must be >= 1");
        }
        if self.steps == 0 {
            bail!("steps must be >= 1");
        }
        if self.eval_every == 0 {
            bail!("eval_every must be >= 1 (use a huge value to disable eval)");
        }
        if self.fwd_threads == 0 || self.bwd_threads == 0 {
            bail!(
                "fwd_threads/bwd_threads must be >= 1 (got {}:{})",
                self.fwd_threads,
                self.bwd_threads
            );
        }
        if self.update_threads == 0 {
            bail!("update_threads must be >= 1 (1 = the serial parameter hot path)");
        }
        if self.queue_depth == 0 {
            bail!("queue_depth must be >= 1 (the pass queue is bounded but not empty)");
        }
        if self.decoupled && self.algorithm.uses_barrier() {
            bail!(
                "{} synchronizes workers step-for-step at a barrier and cannot run \
                 decoupled (backward passes complete out of order); set decoupled = false",
                self.algorithm.name()
            );
        }
        if let Topology::Groups(g) = self.topology {
            if g == 0 {
                bail!("gossip topology groups must be >= 1");
            }
            if g > self.workers {
                bail!(
                    "gossip topology has {g} groups but only {} workers — groups \
                     cannot exceed the worker count",
                    self.workers
                );
            }
        }
        self.cluster.validate(self.workers)?;
        let ps_algo = matches!(self.algorithm, Algorithm::AsgdPs | Algorithm::DcAsgdPs);
        match self.cluster {
            TopologySpec::Ps { .. } if !ps_algo => bail!(
                "a ps:N topology routes gradients to parameter-server shards, which \
                 only asgd-ps/dcasgd-ps speak; {} is peer-to-peer",
                self.algorithm.name()
            ),
            TopologySpec::Hier { .. } if self.algorithm != Algorithm::HierGossip => bail!(
                "a hier:G topology needs the hier-gossip algorithm (intra-group \
                 push-sum + leader exchange); {} ignores groups",
                self.algorithm.name()
            ),
            TopologySpec::Flat if ps_algo => bail!(
                "{} needs parameter-server shards; pick a ps:N topology \
                 (e.g. --topology ps:1)",
                self.algorithm.name()
            ),
            TopologySpec::Flat if self.algorithm == Algorithm::HierGossip => bail!(
                "hier-gossip needs trainer groups; pick a hier:G topology \
                 (e.g. --topology hier:2)"
            ),
            _ => {}
        }
        if self.cluster != TopologySpec::Flat {
            if self.decoupled {
                bail!(
                    "role topologies drive the serial per-worker loop; decoupled \
                     forward/backward pools are flat-only (set decoupled = false)"
                );
            }
            if self.checkpoint_every > 0 && !self.lockstep {
                bail!(
                    "threaded checkpoint rendezvous counts every live worker at a step \
                     boundary, which parameter-server shards never reach; checkpoint \
                     role topologies under lockstep = true"
                );
            }
            if self.faults.faults.iter().any(|f| f.restart_after_s.is_some()) {
                bail!(
                    "crash/restart faults are flat-only for now: a respawned worker's \
                     gossip rejoin (donor copy + weight halving) does not describe a \
                     parameter-server shard or group leader; make the fault permanent"
                );
            }
        }
        self.fabric.validate()?;
        self.codec.validate()?;
        self.staleness.validate(self.algorithm)?;
        self.faults.validate(self.workers, self.steps)?;
        if !self.faults.is_empty() && self.decoupled {
            bail!(
                "chaos injection drives the serial per-worker loop; it cannot tear down \
                 decoupled forward/backward pools (set decoupled = false or drop the faults)"
            );
        }
        if self.checkpoint_every > 0 && self.decoupled {
            bail!(
                "checkpointing quiesces workers at a common step boundary, which decoupled \
                 pools (out-of-order passes) do not have; set decoupled = false"
            );
        }
        let has_restart_fault = self.faults.faults.iter().any(|f| f.restart_after_s.is_some());
        if self.checkpoint_every > 0 && has_restart_fault {
            bail!(
                "periodic checkpoints cannot be combined with crash/restart faults: a \
                 rejoined worker runs several steps behind the survivors, so it would hit \
                 checkpoint boundaries the others have already passed (tearing or hanging \
                 the rendezvous); checkpoint alongside permanent faults, or run the \
                 restart schedule without checkpointing"
            );
        }
        if self.recovery == RecoveryPolicy::Shrink
            && self.algorithm.uses_barrier()
            && has_restart_fault
        {
            bail!(
                "{}: a worker cannot rejoin a SHRUNKEN collective — the survivors advance \
                 past its step-tagged exchanges during the downtime and neither side's \
                 collect can complete; use the stall policy for crash/restart faults, or \
                 make the loss permanent",
                self.algorithm.name()
            );
        }
        if self.stall_timeout_s <= 0.0 || !self.stall_timeout_s.is_finite() {
            bail!("stall_timeout_s must be a finite positive number of seconds");
        }
        self.telemetry.validate()?;
        if self.lockstep {
            if self.algorithm.uses_barrier() {
                bail!(
                    "{} blocks at a collective barrier and would deadlock the single \
                     lockstep driver thread; run it on the threaded engine (its \
                     step-tagged exchanges are deterministic there already)",
                    self.algorithm.name()
                );
            }
            if self.decoupled {
                bail!("lockstep is a serial driver; it cannot run decoupled pools");
            }
            if !self.faults.is_empty() {
                bail!("chaos injection requires the threaded engine; drop lockstep");
            }
            if self.straggler.is_some() {
                bail!("straggler injection (wall-clock sleeps) is meaningless under lockstep");
            }
            if !matches!(self.fabric, FabricSpec::Instant) {
                bail!(
                    "lockstep's same-seed-same-floats guarantee holds on the instant \
                     fabric only: simulated links deliver on wall-clock time, which the \
                     deterministic driver cannot control; use the instant fabric"
                );
            }
        }
        Ok(())
    }

    /// Load from a TOML-subset file (see configs/ for examples).
    pub fn from_toml(doc: &Toml) -> Result<TrainConfig> {
        let model = doc.str_or("run", "model", "mlpnet18").to_string();
        let algorithm = Algorithm::parse(doc.str_or("run", "algorithm", "layup"))?;
        let workers = doc.usize_or("run", "workers", 4);
        let steps = doc.usize_or("run", "steps", 200);
        let mut cfg = TrainConfig::new(&model, algorithm, workers, steps);
        cfg.eval_every = doc.usize_or("run", "eval_every", cfg.eval_every);
        cfg.seed = doc.usize_or("run", "seed", 42) as u64;
        cfg.sync_period = doc.usize_or("run", "sync_period", cfg.sync_period);
        cfg.outer_momentum = doc.f64_or("run", "outer_momentum", 0.5) as f32;
        cfg.outer_lr = doc.f64_or("run", "outer_lr", 1.0) as f32;
        cfg.comm_latency_s = doc.f64_or("run", "comm_latency_s", 0.0);
        cfg.track_drift_every = doc.usize_or("run", "track_drift_every", 0);
        cfg.decoupled = doc.bool_or("run", "decoupled", false);
        cfg.fwd_threads = doc.usize_or("run", "fwd_threads", 1);
        cfg.bwd_threads = doc.usize_or("run", "bwd_threads", 1);
        cfg.update_threads = doc.usize_or("run", "update_threads", 1);
        cfg.queue_depth = doc.usize_or("run", "queue_depth", 2);

        // [fabric] section: kind = "instant" | "sim", plus the sim link knobs
        cfg.fabric = match doc.str_or("fabric", "kind", "instant") {
            "instant" => FabricSpec::Instant,
            "sim" => {
                let latency = match doc.get("fabric", "latency") {
                    None => LatencyDist::Constant(0.0),
                    Some(TomlValue::Str(spec)) => LatencyDist::parse(spec)?,
                    Some(v) => match v.as_f64() {
                        Some(s) => LatencyDist::Constant(s),
                        None => bail!("fabric.latency must be seconds or a latency spec string"),
                    },
                };
                FabricSpec::Sim {
                    latency,
                    // Mbit/s in the file, bytes/s internally
                    bandwidth_bytes_per_s: doc.f64_or("fabric", "bandwidth_mbps", 0.0) * 125_000.0,
                    drop_prob: doc.f64_or("fabric", "drop_prob", 0.0),
                }
            }
            other => bail!("fabric.kind: expected \"instant\" or \"sim\", got {other:?}"),
        };
        // fabric-boundary compression: "dense" | "topk:K" | "randk:K" | "int8"
        cfg.codec = crate::comm::CodecSpec::parse(doc.str_or("fabric", "codec", "dense"))?;
        // step-frame coalescing of LayUp's per-layer pushes (default off)
        cfg.coalesce = doc.bool_or("fabric", "coalesce", false);

        // [topology]: cluster roles/routing (flat | ps:N | hier:G)
        cfg.cluster = TopologySpec::parse(doc.str_or("topology", "kind", "flat"))?;

        let lr = doc.f64_or("optim", "lr", 0.05) as f32;
        let wd = doc.f64_or("optim", "weight_decay", 0.0) as f32;
        cfg.optim = match doc.str_or("optim", "optimizer", "sgd") {
            "adamw" => OptimKind::adamw(wd),
            _ => OptimKind::sgd(doc.f64_or("optim", "momentum", 0.9) as f32, wd),
        };
        let warmup = doc.usize_or("optim", "warmup_steps", 0);
        let warmup_lr = doc.f64_or("optim", "warmup_lr", 0.0) as f32;
        let t_max = doc.usize_or("optim", "t_max", steps);
        cfg.schedule = match doc.str_or("optim", "schedule", "cosine") {
            "linear" => Schedule::Linear { lr, t_max, warmup_steps: warmup, warmup_lr },
            "constant" => Schedule::Constant { lr },
            _ => Schedule::Cosine { lr, t_max, warmup_steps: warmup, warmup_lr },
        };
        if let Some(w) = doc.get("straggler", "worker").and_then(|v| v.as_usize()) {
            let delay = doc.f64_or("straggler", "delay_iterations", 1.0);
            cfg.straggler = Some((w, delay));
        }

        // [checkpoint]: periodic snapshots (resilience subsystem)
        cfg.checkpoint_every = doc.usize_or("checkpoint", "every", 0);
        cfg.checkpoint_dir =
            std::path::PathBuf::from(doc.str_or("checkpoint", "dir", "checkpoints"));

        // [chaos]: seeded fault schedule + recovery knobs
        if let Some(spec) = doc.get("chaos", "faults").and_then(|v| v.as_str()) {
            cfg.faults = FaultPlan::parse(spec)?;
        }
        cfg.recovery = RecoveryPolicy::parse(doc.str_or("chaos", "policy", "stall"))?;
        cfg.stall_timeout_s = doc.f64_or("chaos", "stall_timeout_s", cfg.stall_timeout_s);

        cfg.lockstep = doc.bool_or("run", "lockstep", false);

        // [staleness]: delay-compensated and staleness-adaptive updates
        cfg.staleness.compensation = match doc.str_or("staleness", "compensation", "none") {
            "none" => Compensation::None,
            "dc" => Compensation::Dc,
            other => bail!("staleness.compensation: expected \"none\" or \"dc\", got {other:?}"),
        };
        cfg.staleness.dc_lambda =
            doc.f64_or("staleness", "lambda", cfg.staleness.dc_lambda as f64) as f32;
        cfg.staleness.mixing = match doc.str_or("staleness", "mixing", "fixed") {
            "fixed" => Mixing::Fixed,
            "adaptive" => Mixing::Adaptive,
            other => {
                bail!("staleness.mixing: expected \"fixed\" or \"adaptive\", got {other:?}")
            }
        };
        cfg.staleness.mix_beta =
            doc.f64_or("staleness", "beta", cfg.staleness.mix_beta as f64) as f32;

        // [telemetry]: span tracing + sampler; setting a trace path implies
        // enabled (a trace you asked for should never come back empty)
        cfg.telemetry.enabled = doc.bool_or("telemetry", "enabled", false);
        if let Some(path) = doc.get("telemetry", "trace").and_then(|v| v.as_str()) {
            cfg.telemetry.trace_path = Some(std::path::PathBuf::from(path));
            cfg.telemetry.enabled = true;
        }
        cfg.telemetry.sample_every_ms =
            doc.usize_or("telemetry", "sample_every_ms", cfg.telemetry.sample_every_ms as usize)
                as u64;
        cfg.telemetry.ring_capacity =
            doc.usize_or("telemetry", "ring_capacity", cfg.telemetry.ring_capacity);

        cfg.validate()?;
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_toml_subset() {
        let doc = Toml::parse(
            r#"
            # an experiment
            [run]
            model = "gpt_mini"   # the model
            algorithm = "layup"
            workers = 4
            steps = 300
            [optim]
            optimizer = "adamw"
            lr = 3e-4
            flags = [1, 2, 3]
            on = true
            "#,
        )
        .unwrap();
        assert_eq!(doc.str_or("run", "model", ""), "gpt_mini");
        assert_eq!(doc.usize_or("run", "workers", 0), 4);
        assert_eq!(doc.f64_or("optim", "lr", 0.0), 3e-4);
        assert!(doc.bool_or("optim", "on", false));
        assert_eq!(
            doc.get("optim", "flags"),
            Some(&TomlValue::Arr(vec![
                TomlValue::Int(1),
                TomlValue::Int(2),
                TomlValue::Int(3)
            ]))
        );
    }

    #[test]
    fn train_config_from_toml() {
        let doc = Toml::parse(
            r#"
            [run]
            model = "mlpnet18"
            algorithm = "slowmo"
            workers = 3
            steps = 100
            sync_period = 48
            [optim]
            optimizer = "sgd"
            lr = 0.045
            momentum = 0.9
            schedule = "cosine"
            [straggler]
            worker = 1
            delay_iterations = 4.0
            "#,
        )
        .unwrap();
        let cfg = TrainConfig::from_toml(&doc).unwrap();
        assert_eq!(cfg.algorithm, Algorithm::SlowMo);
        assert_eq!(cfg.workers, 3);
        assert_eq!(cfg.sync_period, 48);
        assert_eq!(cfg.straggler, Some((1, 4.0)));
    }

    #[test]
    fn decoupled_knobs_parse_with_safe_defaults() {
        let doc = Toml::parse(
            r#"
            [run]
            algorithm = "layup"
            decoupled = true
            fwd_threads = 3
            bwd_threads = 1
            update_threads = 4
            queue_depth = 6
            "#,
        )
        .unwrap();
        let cfg = TrainConfig::from_toml(&doc).unwrap();
        assert!(cfg.decoupled);
        assert_eq!((cfg.fwd_threads, cfg.bwd_threads, cfg.queue_depth), (3, 1, 6));
        assert_eq!(cfg.update_threads, 4);
        // defaults preserve serial semantics
        let d = TrainConfig::new("mlpnet18", Algorithm::LayUp, 2, 10);
        assert!(!d.decoupled);
        assert_eq!((d.fwd_threads, d.bwd_threads), (1, 1));
        assert_eq!(d.update_threads, 1, "default must keep the serial hot path");
        d.validate().unwrap();
    }

    #[test]
    fn validate_rejects_bad_pool_configs() {
        let mut cfg = TrainConfig::new("mlpnet18", Algorithm::LayUp, 2, 10);
        cfg.eval_every = 0;
        assert!(cfg.validate().is_err(), "eval_every = 0 would panic at step % 0");
        cfg.eval_every = 1;
        cfg.fwd_threads = 0;
        assert!(cfg.validate().is_err());
        cfg.fwd_threads = 2;
        cfg.update_threads = 0;
        assert!(cfg.validate().is_err(), "update_threads = 0 has no lane to run on");
        cfg.update_threads = 4;
        cfg.queue_depth = 0;
        assert!(cfg.validate().is_err());
        cfg.queue_depth = 2;
        cfg.validate().unwrap();
        // barrier algorithms cannot run decoupled
        for algo in [Algorithm::Ddp, Algorithm::LocalSgd, Algorithm::SlowMo] {
            let mut cfg = TrainConfig::new("mlpnet18", algo, 2, 10);
            cfg.decoupled = true;
            assert!(cfg.validate().is_err(), "{algo:?} must be rejected");
            assert!(algo.uses_barrier());
        }
        // every non-barrier algorithm runs decoupled at ANY bwd_threads:
        // the engine-owned per-pass StepState makes interleaved steps safe
        for algo in [Algorithm::LayUp, Algorithm::GoSgd, Algorithm::AdPsgd, Algorithm::Co2] {
            for bwd_threads in [1, 2, 4] {
                let mut cfg = TrainConfig::new("mlpnet18", algo, 2, 10);
                cfg.decoupled = true;
                cfg.bwd_threads = bwd_threads;
                cfg.validate().unwrap_or_else(|e| {
                    panic!("{algo:?} with bwd_threads={bwd_threads} should be allowed: {e}")
                });
                assert!(!algo.uses_barrier());
            }
        }
    }

    #[test]
    fn fabric_section_parses_and_validates() {
        // default: the instant shared-memory transport
        let d = TrainConfig::new("mlpnet18", Algorithm::LayUp, 2, 10);
        assert_eq!(d.fabric, FabricSpec::Instant);

        let doc = Toml::parse(
            r#"
            [run]
            algorithm = "layup"
            [fabric]
            kind = "sim"
            latency = "uniform:0.001..0.01"
            bandwidth_mbps = 100
            drop_prob = 0.05
            "#,
        )
        .unwrap();
        let cfg = TrainConfig::from_toml(&doc).unwrap();
        match cfg.fabric {
            FabricSpec::Sim { latency, bandwidth_bytes_per_s, drop_prob } => {
                assert_eq!(latency, LatencyDist::Uniform { lo: 0.001, hi: 0.01 });
                assert!((bandwidth_bytes_per_s - 12_500_000.0).abs() < 1e-6);
                assert!((drop_prob - 0.05).abs() < 1e-12);
            }
            other => panic!("expected a sim fabric, got {other:?}"),
        }

        // bare number = constant seconds
        let doc = Toml::parse("[fabric]\nkind = \"sim\"\nlatency = 0.002\n").unwrap();
        let cfg = TrainConfig::from_toml(&doc).unwrap();
        assert!(matches!(
            cfg.fabric,
            FabricSpec::Sim { latency: LatencyDist::Constant(s), .. } if (s - 0.002).abs() < 1e-12
        ));

        // invalid knobs are rejected at parse time (validate runs in from_toml)
        let doc = Toml::parse("[fabric]\nkind = \"sim\"\ndrop_prob = 1.5\n").unwrap();
        assert!(TrainConfig::from_toml(&doc).is_err());
        let doc = Toml::parse("[fabric]\nkind = \"carrier-pigeon\"\n").unwrap();
        assert!(TrainConfig::from_toml(&doc).is_err());

        // codec knob: default dense, spec strings parse, junk is rejected
        assert_eq!(d.codec, crate::comm::CodecSpec::Dense);
        let doc = Toml::parse("[fabric]\ncodec = \"topk:8\"\n").unwrap();
        let cfg = TrainConfig::from_toml(&doc).unwrap();
        assert_eq!(cfg.codec, crate::comm::CodecSpec::TopK { k: 8 });
        let doc = Toml::parse("[fabric]\ncodec = \"int8\"\n").unwrap();
        assert_eq!(
            TrainConfig::from_toml(&doc).unwrap().codec,
            crate::comm::CodecSpec::Int8
        );
        // K = 1 would grow every message; rejected at parse time
        let doc = Toml::parse("[fabric]\ncodec = \"topk:1\"\n").unwrap();
        assert!(TrainConfig::from_toml(&doc).is_err());
        let doc = Toml::parse("[fabric]\ncodec = \"gzip\"\n").unwrap();
        assert!(TrainConfig::from_toml(&doc).is_err());

        // coalesce knob: default off (bit-identical seed path), bool parses,
        // and it composes with a codec in the same [fabric] section
        assert!(!d.coalesce);
        let doc = Toml::parse("[fabric]\ncoalesce = true\n").unwrap();
        assert!(TrainConfig::from_toml(&doc).unwrap().coalesce);
        let doc = Toml::parse("[fabric]\ncodec = \"topk:8\"\ncoalesce = true\n").unwrap();
        let cfg = TrainConfig::from_toml(&doc).unwrap();
        assert!(cfg.coalesce);
        assert_eq!(cfg.codec, crate::comm::CodecSpec::TopK { k: 8 });
    }

    #[test]
    fn checkpoint_and_chaos_sections_parse_and_validate() {
        let doc = Toml::parse(
            r#"
            [run]
            algorithm = "layup"
            workers = 3
            steps = 100
            [checkpoint]
            every = 25
            dir = "snaps"
            [chaos]
            faults = "1@20+0.5, 2@40"
            policy = "shrink"
            stall_timeout_s = 5.0
            "#,
        )
        .unwrap();
        let cfg = TrainConfig::from_toml(&doc).unwrap();
        assert_eq!(cfg.checkpoint_every, 25);
        assert_eq!(cfg.checkpoint_dir, std::path::PathBuf::from("snaps"));
        assert_eq!(cfg.faults.faults.len(), 2);
        assert_eq!(cfg.recovery, RecoveryPolicy::Shrink);
        assert!((cfg.stall_timeout_s - 5.0).abs() < 1e-12);

        // defaults: no checkpointing, no chaos, stall policy
        let d = TrainConfig::new("mlpnet18", Algorithm::LayUp, 2, 10);
        assert_eq!(d.checkpoint_every, 0);
        assert!(d.faults.is_empty());
        assert_eq!(d.recovery, RecoveryPolicy::Stall);
        assert!(!d.lockstep);
        d.validate().unwrap();

        // fault schedules are validated against the run shape at parse time
        let doc = Toml::parse("[run]\nworkers = 2\nsteps = 10\n[chaos]\nfaults = \"5@3\"\n")
            .unwrap();
        assert!(TrainConfig::from_toml(&doc).is_err(), "fault targets worker 5 of 2");
    }

    #[test]
    fn lockstep_and_resilience_validation_rules() {
        // lockstep runs any non-barrier algorithm
        for algo in [Algorithm::LayUp, Algorithm::GoSgd, Algorithm::AdPsgd, Algorithm::Co2] {
            let mut cfg = TrainConfig::new("mlpnet18", algo, 2, 10);
            cfg.lockstep = true;
            cfg.validate().unwrap();
        }
        // ...but not the barrier family (single driver thread would deadlock)
        for algo in [Algorithm::Ddp, Algorithm::LocalSgd, Algorithm::SlowMo] {
            let mut cfg = TrainConfig::new("mlpnet18", algo, 2, 10);
            cfg.lockstep = true;
            assert!(cfg.validate().is_err(), "{algo:?}");
        }
        // lockstep excludes decoupled pools, chaos and stragglers
        let mut cfg = TrainConfig::new("mlpnet18", Algorithm::LayUp, 2, 10);
        cfg.lockstep = true;
        cfg.decoupled = true;
        assert!(cfg.validate().is_err());
        let mut cfg = TrainConfig::new("mlpnet18", Algorithm::LayUp, 2, 10);
        cfg.lockstep = true;
        cfg.faults = FaultPlan::default().crash(1, 5);
        assert!(cfg.validate().is_err());
        let mut cfg = TrainConfig::new("mlpnet18", Algorithm::LayUp, 2, 10);
        cfg.lockstep = true;
        cfg.straggler = Some((1, 2.0));
        assert!(cfg.validate().is_err());
        // ...and the sim fabric (wall-clock deliveries break determinism)
        let mut cfg = TrainConfig::new("mlpnet18", Algorithm::LayUp, 2, 10);
        cfg.lockstep = true;
        cfg.fabric = FabricSpec::sim_default();
        assert!(cfg.validate().is_err());
        // chaos + decoupled and checkpoint + decoupled are rejected
        let mut cfg = TrainConfig::new("mlpnet18", Algorithm::LayUp, 2, 10);
        cfg.decoupled = true;
        cfg.faults = FaultPlan::default().crash_restart(1, 5, 0.1);
        assert!(cfg.validate().is_err());
        let mut cfg = TrainConfig::new("mlpnet18", Algorithm::LayUp, 2, 10);
        cfg.decoupled = true;
        cfg.checkpoint_every = 5;
        assert!(cfg.validate().is_err());
        // a bad stall timeout is rejected
        let mut cfg = TrainConfig::new("mlpnet18", Algorithm::Ddp, 2, 10);
        cfg.stall_timeout_s = 0.0;
        assert!(cfg.validate().is_err());
        // restart faults tear the checkpoint rendezvous (rejoiner runs
        // behind); permanent faults checkpoint fine
        let mut cfg = TrainConfig::new("mlpnet18", Algorithm::LayUp, 2, 10);
        cfg.checkpoint_every = 4;
        cfg.faults = FaultPlan::default().crash_restart(1, 5, 0.1);
        assert!(cfg.validate().is_err());
        cfg.faults = FaultPlan::default().crash(1, 5);
        cfg.validate().unwrap();
        // a worker cannot rejoin a SHRUNKEN barrier collective...
        let mut cfg = TrainConfig::new("mlpnet18", Algorithm::Ddp, 3, 10);
        cfg.recovery = RecoveryPolicy::Shrink;
        cfg.faults = FaultPlan::default().crash_restart(1, 5, 0.1);
        assert!(cfg.validate().is_err());
        // ...but stall-and-rejoin supports the restart, and gossip
        // algorithms rejoin a shrink-policy run fine (no collectives)
        cfg.recovery = RecoveryPolicy::Stall;
        cfg.validate().unwrap();
        let mut cfg = TrainConfig::new("mlpnet18", Algorithm::LayUp, 3, 10);
        cfg.recovery = RecoveryPolicy::Shrink;
        cfg.faults = FaultPlan::default().crash_restart(1, 5, 0.1);
        cfg.validate().unwrap();
    }

    #[test]
    fn staleness_section_parses_and_validates() {
        // defaults are off (numerics-neutral)
        let d = TrainConfig::new("mlpnet18", Algorithm::LayUp, 2, 10);
        assert_eq!(d.staleness.compensation, Compensation::None);
        assert_eq!(d.staleness.mixing, Mixing::Fixed);
        d.validate().unwrap();

        let doc = Toml::parse(
            r#"
            [run]
            algorithm = "layup"
            [staleness]
            compensation = "dc"
            lambda = 0.1
            mixing = "adaptive"
            beta = 0.25
            "#,
        )
        .unwrap();
        let cfg = TrainConfig::from_toml(&doc).unwrap();
        assert_eq!(cfg.staleness.compensation, Compensation::Dc);
        assert!((cfg.staleness.dc_lambda - 0.1).abs() < 1e-7);
        assert_eq!(cfg.staleness.mixing, Mixing::Adaptive);
        assert!((cfg.staleness.mix_beta - 0.25).abs() < 1e-7);

        // unknown spellings are rejected at parse time
        let doc = Toml::parse("[staleness]\ncompensation = \"hessian\"\n").unwrap();
        assert!(TrainConfig::from_toml(&doc).is_err());
        let doc = Toml::parse("[staleness]\nmixing = \"sticky\"\n").unwrap();
        assert!(TrainConfig::from_toml(&doc).is_err());

        // dc rides the asynchronous gossip apply path only
        for algo in [Algorithm::LayUp, Algorithm::GoSgd, Algorithm::AdPsgd] {
            let mut cfg = TrainConfig::new("mlpnet18", algo, 2, 10);
            cfg.staleness.compensation = Compensation::Dc;
            cfg.validate().unwrap_or_else(|e| panic!("{algo:?}: {e}"));
        }
        for algo in [Algorithm::Ddp, Algorithm::LocalSgd, Algorithm::SlowMo, Algorithm::Co2] {
            let mut cfg = TrainConfig::new("mlpnet18", algo, 2, 10);
            cfg.staleness.compensation = Compensation::Dc;
            assert!(cfg.validate().is_err(), "{algo:?} has no stale apply path");
        }
        // adaptive mixing attenuates LayUp's push-sum fractions only
        let mut cfg = TrainConfig::new("mlpnet18", Algorithm::LayUp, 2, 10);
        cfg.staleness.mixing = Mixing::Adaptive;
        cfg.validate().unwrap();
        let mut cfg = TrainConfig::new("mlpnet18", Algorithm::GoSgd, 2, 10);
        cfg.staleness.mixing = Mixing::Adaptive;
        assert!(cfg.validate().is_err());
        // knob ranges
        let mut cfg = TrainConfig::new("mlpnet18", Algorithm::LayUp, 2, 10);
        cfg.staleness.dc_lambda = f32::NAN;
        assert!(cfg.validate().is_err());
        let mut cfg = TrainConfig::new("mlpnet18", Algorithm::LayUp, 2, 10);
        cfg.staleness.mix_beta = -1.0;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn topology_section_parses_and_validates() {
        // default is flat — bit-identical to the pre-topology era
        let d = TrainConfig::new("mlpnet18", Algorithm::LayUp, 2, 10);
        assert_eq!(d.cluster, TopologySpec::Flat);
        d.validate().unwrap();

        let doc = Toml::parse(
            r#"
            [run]
            algorithm = "asgd-ps"
            workers = 4
            steps = 20
            [topology]
            kind = "ps:2"
            "#,
        )
        .unwrap();
        let cfg = TrainConfig::from_toml(&doc).unwrap();
        assert_eq!(cfg.algorithm, Algorithm::AsgdPs);
        assert_eq!(cfg.cluster, TopologySpec::Ps { shards: 2 });

        let doc = Toml::parse(
            "[run]\nalgorithm = \"hier-gossip\"\nworkers = 6\n[topology]\nkind = \"hier:3\"\n",
        )
        .unwrap();
        let cfg = TrainConfig::from_toml(&doc).unwrap();
        assert_eq!(cfg.cluster, TopologySpec::Hier { groups: 3 });

        // algorithm/topology pairing is enforced in both directions
        let mut cfg = TrainConfig::new("mlpnet18", Algorithm::AsgdPs, 4, 10);
        assert!(cfg.validate().is_err(), "asgd-ps needs ps:N");
        cfg.cluster = TopologySpec::Ps { shards: 1 };
        cfg.validate().unwrap();
        let mut cfg = TrainConfig::new("mlpnet18", Algorithm::DcAsgdPs, 4, 10);
        cfg.cluster = TopologySpec::Ps { shards: 2 };
        cfg.validate().unwrap();
        let mut cfg = TrainConfig::new("mlpnet18", Algorithm::LayUp, 4, 10);
        cfg.cluster = TopologySpec::Ps { shards: 1 };
        assert!(cfg.validate().is_err(), "layup does not speak PS");
        let mut cfg = TrainConfig::new("mlpnet18", Algorithm::HierGossip, 4, 10);
        assert!(cfg.validate().is_err(), "hier-gossip needs hier:G");
        cfg.cluster = TopologySpec::Hier { groups: 2 };
        cfg.validate().unwrap();
        cfg.cluster = TopologySpec::Ps { shards: 1 };
        assert!(cfg.validate().is_err(), "hier-gossip is not a PS algorithm");

        // shard/group bounds
        let mut cfg = TrainConfig::new("mlpnet18", Algorithm::AsgdPs, 2, 10);
        cfg.cluster = TopologySpec::Ps { shards: 2 };
        assert!(cfg.validate().is_err(), "no trainers left");
        let mut cfg = TrainConfig::new("mlpnet18", Algorithm::HierGossip, 3, 10);
        cfg.cluster = TopologySpec::Hier { groups: 4 };
        assert!(cfg.validate().is_err(), "groups > workers");

        // gossip Groups(g) with g > workers is rejected (exact-bounds rule)
        let mut cfg = TrainConfig::new("mlpnet18", Algorithm::LayUp, 3, 10);
        cfg.topology = Topology::Groups(5);
        assert!(cfg.validate().is_err());
        cfg.topology = Topology::Groups(0);
        assert!(cfg.validate().is_err());
        cfg.topology = Topology::Groups(3);
        cfg.validate().unwrap();

        // decoupled pools, threaded checkpoints and restart faults are
        // flat-only; lockstep checkpoints are the supported PS combination
        let mut cfg = TrainConfig::new("mlpnet18", Algorithm::AsgdPs, 4, 10);
        cfg.cluster = TopologySpec::Ps { shards: 1 };
        cfg.decoupled = true;
        assert!(cfg.validate().is_err());
        let mut cfg = TrainConfig::new("mlpnet18", Algorithm::AsgdPs, 4, 10);
        cfg.cluster = TopologySpec::Ps { shards: 1 };
        cfg.checkpoint_every = 4;
        assert!(cfg.validate().is_err(), "threaded rendezvous never counts shards");
        cfg.lockstep = true;
        cfg.validate().unwrap();
        let mut cfg = TrainConfig::new("mlpnet18", Algorithm::AsgdPs, 4, 10);
        cfg.cluster = TopologySpec::Ps { shards: 1 };
        cfg.faults = FaultPlan::default().crash_restart(3, 5, 0.1);
        assert!(cfg.validate().is_err(), "restart faults are flat-only");
        cfg.faults = FaultPlan::default().crash(3, 5);
        cfg.validate().unwrap();

        // bad spellings are rejected at parse time
        let doc = Toml::parse("[topology]\nkind = \"star:2\"\n").unwrap();
        assert!(TrainConfig::from_toml(&doc).is_err());
    }

    #[test]
    fn rejects_bad_input() {
        assert!(Toml::parse("[run]\nkey value").is_err());
        assert!(Toml::parse("[run]\nkey = @@").is_err());
        assert!(Algorithm::parse("sgd??").is_err());
    }

    #[test]
    fn algorithm_roundtrip() {
        for a in Algorithm::all_paper() {
            let parsed = Algorithm::parse(&a.name().to_ascii_lowercase().replace("(model)", "-model"));
            assert!(parsed.is_ok(), "{a:?}");
        }
    }
}
