//! The crate's public training facade: build a [`Session`] from a
//! [`TrainConfig`] + [`Manifest`], attach typed-event observers, run, get a
//! [`RunSummary`].
//!
//! ```no_run
//! use std::sync::Arc;
//! use layup::config::{Algorithm, TrainConfig};
//! use layup::manifest::Manifest;
//! use layup::session::{events::ProgressPrinter, SessionBuilder};
//!
//! # fn main() -> anyhow::Result<()> {
//! let manifest = Manifest::load(&layup::artifacts_dir())?;
//! let cfg = TrainConfig::new("mlpnet18", Algorithm::LayUp, 2, 60);
//! let summary = SessionBuilder::new(cfg)
//!     .observer(Arc::new(ProgressPrinter::new()))
//!     .build(&manifest)?
//!     .run()?;
//! println!("best accuracy {:.3}", summary.curve.best_accuracy());
//! # Ok(())
//! # }
//! ```
//!
//! The facade replaces the seed-era `coordinator::run` free function.
//! Construction is two-phase on purpose: `build`
//! validates the config and binds the manifest, so configuration errors
//! surface before any thread spawns; `run` consumes the session — one run
//! per session, matching the engine's single-use shared state.
//!
//! The communication fabric is selected the same way as every other knob:
//! through the config, or the [`SessionBuilder::fabric`] override:
//!
//! ```no_run
//! use layup::comm::{FabricSpec, LatencyDist};
//! use layup::config::{Algorithm, TrainConfig};
//! use layup::manifest::Manifest;
//! use layup::session::SessionBuilder;
//!
//! # fn main() -> anyhow::Result<()> {
//! let manifest = Manifest::load(&layup::artifacts_dir())?;
//! let cfg = TrainConfig::new("mlpnet18", Algorithm::LayUp, 4, 200);
//! let summary = SessionBuilder::new(cfg)
//!     .fabric(FabricSpec::Sim {
//!         latency: LatencyDist::Constant(0.005), // 5 ms links
//!         bandwidth_bytes_per_s: 12.5e6,         // 100 Mbit/s
//!         drop_prob: 0.01,
//!     })
//!     .build(&manifest)?
//!     .run()?;
//! println!("mean delivered staleness: {:.2} steps",
//!          summary.stats.comm.mean_delivered_staleness());
//! # Ok(())
//! # }
//! ```

pub mod events;

use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::comm::{Fabric, FabricSpec};
use crate::config::{Algorithm, Compensation, Mixing, StalenessConfig, TrainConfig};
use crate::coordinator::{engine, Shared};
use crate::data;
use crate::manifest::Manifest;
use crate::metrics::{QueueStats, RecoveryStats, RunStats, RunSummary};
use crate::resilience::{checkpoint, Checkpoint, FaultPlan, RecoveryPolicy};
use self::events::{EventBus, Observer, TrainEvent};

/// Configures a training session: config + observers.
pub struct SessionBuilder {
    cfg: TrainConfig,
    events: EventBus,
}

impl SessionBuilder {
    pub fn new(cfg: TrainConfig) -> SessionBuilder {
        SessionBuilder { cfg, events: EventBus::new() }
    }

    /// Attach a typed-event observer (may be called repeatedly).
    pub fn observer(mut self, observer: Arc<dyn Observer>) -> SessionBuilder {
        self.events.attach(observer);
        self
    }

    /// Convenience: attach the stdout progress printer.
    pub fn progress(self) -> SessionBuilder {
        self.observer(Arc::new(events::ProgressPrinter::new()))
    }

    /// Select the communication fabric (overrides the config's choice):
    /// `FabricSpec::Instant` for seed-era shared-memory semantics,
    /// `FabricSpec::Sim { .. }` for links with latency, bandwidth and loss.
    pub fn fabric(mut self, spec: FabricSpec) -> SessionBuilder {
        self.cfg.fabric = spec;
        self
    }

    /// Install a compression codec at the fabric boundary (`[fabric] codec`
    /// equivalent). Every payload kind and every algorithm inherits it; the
    /// default `CodecSpec::Dense` is bit-identical to no codec at all.
    ///
    /// ```no_run
    /// use layup::comm::{CodecSpec, FabricSpec};
    /// use layup::config::{Algorithm, TrainConfig};
    /// use layup::manifest::Manifest;
    /// use layup::session::SessionBuilder;
    ///
    /// # fn main() -> anyhow::Result<()> {
    /// let manifest = Manifest::load(&layup::artifacts_dir())?;
    /// let cfg = TrainConfig::new("mlpnet18", Algorithm::LayUp, 8, 500);
    /// let summary = SessionBuilder::new(cfg)
    ///     .fabric(FabricSpec::sim_default())
    ///     .codec(CodecSpec::parse("topk:16")?)
    ///     .build(&manifest)?
    ///     .run()?;
    /// println!("wire bytes: {}", summary.stats.comm.bytes_sent);
    /// # Ok(())
    /// # }
    /// ```
    pub fn codec(mut self, spec: crate::comm::CodecSpec) -> SessionBuilder {
        self.cfg.codec = spec;
        self
    }

    /// Enable step-frame coalescing at the fabric boundary (`[fabric]
    /// coalesce` / `--coalesce` equivalent): LayUp's consecutive per-layer
    /// pushes on a link buffer in a `FrameBuilder` and ship as one
    /// `StepFrame` — one wire header, one codec pass over the whole step's
    /// gradient mass (so `topk:K` ranks coordinates globally across
    /// layers), one delivery event. The default (`false`) keeps per-layer
    /// pushes and is bit-identical to earlier releases.
    ///
    /// ```no_run
    /// use layup::comm::{CodecSpec, FabricSpec};
    /// use layup::config::{Algorithm, TrainConfig};
    /// use layup::manifest::Manifest;
    /// use layup::session::SessionBuilder;
    ///
    /// # fn main() -> anyhow::Result<()> {
    /// let manifest = Manifest::load(&layup::artifacts_dir())?;
    /// let cfg = TrainConfig::new("mlpnet18", Algorithm::LayUp, 8, 500);
    /// let summary = SessionBuilder::new(cfg)
    ///     .fabric(FabricSpec::sim_default())
    ///     .codec(CodecSpec::parse("topk:16")?)
    ///     .coalesce(true)
    ///     .build(&manifest)?
    ///     .run()?;
    /// println!("wire messages: {}", summary.stats.comm.msgs_sent);
    /// # Ok(())
    /// # }
    /// ```
    pub fn coalesce(mut self, on: bool) -> SessionBuilder {
        self.cfg.coalesce = on;
        self
    }

    /// Select the cluster topology (`[topology]` config section
    /// equivalent): `TopologySpec::Flat` (default) for homogeneous gossip,
    /// `TopologySpec::Ps { shards }` to turn the last `shards` worker ids
    /// into parameter-server shards, `TopologySpec::Hier { groups }` for
    /// two-tier gossip. Validation pairs the topology with the algorithm.
    ///
    /// ```no_run
    /// use layup::config::{Algorithm, TrainConfig};
    /// use layup::manifest::Manifest;
    /// use layup::session::SessionBuilder;
    /// use layup::topology::roles::TopologySpec;
    ///
    /// # fn main() -> anyhow::Result<()> {
    /// let manifest = Manifest::load(&layup::artifacts_dir())?;
    /// // 6 workers: 4 trainers pushing gradients to 2 server shards
    /// let cfg = TrainConfig::new("mlpnet18", Algorithm::AsgdPs, 6, 60);
    /// let summary = SessionBuilder::new(cfg)
    ///     .topology(TopologySpec::Ps { shards: 2 })
    ///     .build(&manifest)?
    ///     .run()?;
    /// println!("grad pushes: {}", summary.stats.ps.grad_pushes);
    /// # Ok(())
    /// # }
    /// ```
    pub fn topology(mut self, spec: crate::topology::roles::TopologySpec) -> SessionBuilder {
        self.cfg.cluster = spec;
        self
    }

    /// Shard-pool lanes for the parameter hot path (§Perf): optimizer
    /// steps, gossip mixes and collective write-backs split their store
    /// traversals across `n` threads. `1` (the default) keeps the serial
    /// path, bit-identical to the unsharded behavior; validation rejects 0.
    pub fn update_threads(mut self, n: usize) -> SessionBuilder {
        self.cfg.update_threads = n;
        self
    }

    /// Write a `resilience::checkpoint` every `every` steps (0 disables).
    /// Snapshots land in `step-XXXXXX` subdirectories of the checkpoint dir
    /// (see [`SessionBuilder::checkpoint_dir`]); resume one with
    /// [`Session::resume_from`].
    pub fn checkpoint_every(mut self, every: usize) -> SessionBuilder {
        self.cfg.checkpoint_every = every;
        self
    }

    /// Parent directory for periodic checkpoints (default `checkpoints/`).
    pub fn checkpoint_dir<P: Into<std::path::PathBuf>>(mut self, dir: P) -> SessionBuilder {
        self.cfg.checkpoint_dir = dir.into();
        self
    }

    /// Install a chaos fault schedule (`resilience::chaos`): the engine
    /// tears the scheduled workers down and respawns them per the plan.
    pub fn chaos(mut self, plan: FaultPlan) -> SessionBuilder {
        self.cfg.faults = plan;
        self
    }

    /// How collective (barrier) algorithms react to a dead peer:
    /// stall-and-rejoin (default) or shrink to the survivors.
    pub fn recovery(mut self, policy: RecoveryPolicy) -> SessionBuilder {
        self.cfg.recovery = policy;
        self
    }

    /// Replace the run's staleness policy knobs wholesale
    /// (`[staleness]` config section equivalent).
    pub fn staleness(mut self, cfg: StalenessConfig) -> SessionBuilder {
        self.cfg.staleness = cfg;
        self
    }

    /// Replace the run's telemetry knobs wholesale (`[telemetry]` config
    /// section equivalent): span tracing, the background sampler period and
    /// an optional Chrome-trace output path. The default config keeps
    /// telemetry off — bit-identical hot paths, zero allocations.
    ///
    /// ```no_run
    /// use layup::config::{Algorithm, TrainConfig};
    /// use layup::manifest::Manifest;
    /// use layup::session::SessionBuilder;
    /// use layup::telemetry::TelemetryConfig;
    ///
    /// # fn main() -> anyhow::Result<()> {
    /// let manifest = Manifest::load(&layup::artifacts_dir())?;
    /// let cfg = TrainConfig::new("mlpnet18", Algorithm::LayUp, 4, 200);
    /// let summary = SessionBuilder::new(cfg)
    ///     .telemetry(TelemetryConfig {
    ///         enabled: true,
    ///         trace_path: Some("trace.json".into()),
    ///         ..TelemetryConfig::default()
    ///     })
    ///     .build(&manifest)?
    ///     .run()?;
    /// println!("spans recorded: {}", summary.stats.telemetry.spans);
    /// # Ok(())
    /// # }
    /// ```
    pub fn telemetry(mut self, cfg: crate::telemetry::TelemetryConfig) -> SessionBuilder {
        self.cfg.telemetry = cfg;
        self
    }

    /// Select the stale-gradient correction policy:
    /// `Compensation::Dc` applies the DC-ASGD `λ·g⊙g⊙(x_now − x_then)`
    /// correction at every asynchronous gradient apply.
    pub fn compensation(mut self, policy: Compensation) -> SessionBuilder {
        self.cfg.staleness.compensation = policy;
        self
    }

    /// Toggle staleness-adaptive gossip mixing: LayUp's per-layer push-sum
    /// mixing fraction is attenuated by the observed per-layer delay τ
    /// (`frac / (1 + β·τ)`).
    pub fn adaptive_mix(mut self, on: bool) -> SessionBuilder {
        self.cfg.staleness.mixing = if on { Mixing::Adaptive } else { Mixing::Fixed };
        self
    }

    /// Convenience: stream every event to a JSONL file at `path`.
    ///
    /// The file is created (truncated) HERE, before `build` validates the
    /// config — validate first (or call `build` before attaching) when the
    /// path may hold a previous run's log you care about.
    pub fn jsonl_sink<P: AsRef<std::path::Path>>(self, path: P) -> Result<SessionBuilder> {
        let sink = events::JsonlSink::create(path)?;
        Ok(self.observer(Arc::new(sink)))
    }

    /// Validate the config and bind the artifact manifest. Configuration
    /// errors surface here, before any thread spawns.
    pub fn build(self, manifest: &Manifest) -> Result<Session<'_>> {
        self.cfg.validate()?;
        manifest.model(&self.cfg.model)?; // unknown models fail at build too
        Ok(Session { cfg: self.cfg, manifest, events: self.events, resume: None })
    }
}

/// A validated, ready-to-run training session.
pub struct Session<'m> {
    cfg: TrainConfig,
    manifest: &'m Manifest,
    events: EventBus,
    resume: Option<Checkpoint>,
}

impl Session<'_> {
    pub fn config(&self) -> &TrainConfig {
        &self.cfg
    }

    /// Restore a `resilience::checkpoint` so [`Session::run`] continues the
    /// snapshotted run instead of starting fresh. `dir` is either a
    /// checkpoint directory itself or a parent holding `step-XXXXXX`
    /// snapshots (the latest is picked). The checkpoint must match the
    /// session's model, algorithm, worker count and seed; resuming into
    /// decoupled pools is rejected (snapshots are taken at the serial
    /// engines' step boundaries).
    pub fn resume_from<P: AsRef<Path>>(mut self, dir: P) -> Result<Self> {
        if self.cfg.decoupled {
            anyhow::bail!(
                "checkpoints are taken at serial step boundaries; resume with \
                 decoupled = false"
            );
        }
        let dir = checkpoint::resolve(dir.as_ref())?;
        let ck = checkpoint::load(&dir)?;
        ck.check_compatible(
            &self.cfg.model,
            self.cfg.algorithm.name(),
            self.cfg.workers,
            self.cfg.seed,
        )?;
        if ck.step >= self.cfg.steps {
            anyhow::bail!(
                "checkpoint is at step {} but the session runs only {} steps — \
                 nothing left to do",
                ck.step,
                self.cfg.steps
            );
        }
        self.events.emit(TrainEvent::Resumed { step: ck.step, path: dir.display().to_string() });
        self.resume = Some(ck);
        Ok(self)
    }

    /// Run the full training job on the thread cluster. Returns the learning
    /// curve, MFU/occupancy, drift samples, gossip counters and the typed
    /// [`RunStats`].
    pub fn run(self) -> Result<RunSummary> {
        let Session { cfg, manifest, events, resume } = self;
        let shared = Shared::with_events(&cfg, manifest, events, resume.as_ref())?;
        shared.events.emit(TrainEvent::RunStarted {
            algorithm: cfg.algorithm.name(),
            workers: cfg.workers,
            steps: cfg.steps,
            decoupled: cfg.decoupled,
        });
        let t0 = Instant::now();

        // Compute lanes mirror the occupancy denominator below: one per
        // trainer serially, fwd + bwd pool threads per trainer decoupled.
        // The sampler normalises MFU against this count.
        let lanes = (cfg.cluster.n_trainers(cfg.workers)
            * if cfg.decoupled { cfg.fwd_threads + cfg.bwd_threads } else { 1 })
            as f64;
        let sampler = crate::telemetry::sampler::spawn(
            &shared.telemetry,
            &shared,
            cfg.telemetry.sample_every_ms,
            lanes,
        );
        let result = engine::execute(&cfg, manifest, &shared, resume.as_ref());
        if let Some(s) = sampler {
            s.stop(); // joins; takes one final sample so short runs still chart
        }
        let stats = result?;

        let wall = t0.elapsed().as_secs_f64();
        let total_compute: f64 = stats.iter().map(|s| s.compute_s).sum();
        let total_flops: u64 = stats.iter().map(|s| s.flops).sum();
        let total_steps: usize = stats.iter().map(|s| s.steps).sum();
        // Occupancy denominators count the threads that could have computed:
        // one per worker serially, fwd_threads + bwd_threads per worker
        // decoupled.
        let (fwd_pool, bwd_pool) = if cfg.decoupled {
            (cfg.fwd_threads, cfg.bwd_threads)
        } else {
            (1, 1)
        };
        let threads = if cfg.decoupled { fwd_pool + bwd_pool } else { 1 };
        // Role topologies: PS shards run no compute, so occupancy counts
        // trainer wids only (n_trainers == workers for flat/hier).
        let trainers = cfg.cluster.n_trainers(cfg.workers);
        let occupancy = (total_compute / (wall * (trainers * threads) as f64)).min(1.0);
        let (applied, skipped) = shared.gossip_counts();

        let model = manifest.model(&cfg.model)?;
        let data0 = data::build(model, 0, cfg.workers, cfg.seed)?;
        let batches_per_epoch = data0.batches_per_epoch();

        let mut curve = shared.curve.lock().unwrap().clone();
        curve.sort_by_step(); // decoupled passes complete out of step order
        let mut drift = shared.drift.lock().unwrap().clone();
        drift.sort_by_step();
        let mut queue = QueueStats::default();
        for s in &stats {
            queue.merge(&s.queue);
        }
        let upload_hits: u64 = stats.iter().map(|s| s.upload_hits).sum();
        let upload_total: u64 = stats.iter().map(|s| s.upload_hits + s.upload_misses).sum();
        let run_stats = RunStats {
            achieved_flops_per_s: total_flops as f64 / wall,
            max_disagreement: drift.max_disagreement(),
            final_disagreement: drift.final_disagreement(),
            upload_hit_rate: upload_hits as f64 / (upload_total as f64).max(1.0),
            // Per-pool occupancy split (§Perf): fwd- or bwd-bound pipeline?
            fwd_occupancy: (stats.iter().map(|s| s.fwd_compute_s).sum::<f64>()
                / (wall * (trainers * fwd_pool) as f64))
                .min(1.0),
            bwd_occupancy: (stats.iter().map(|s| s.bwd_compute_s).sum::<f64>()
                / (wall * (trainers * bwd_pool) as f64))
                .min(1.0),
            queue,
            comm: shared.fabric.core().snapshot(),
            staleness: shared.staleness.snapshot(),
            recovery: RecoveryStats {
                crashes: shared.membership.crash_count(),
                joins: shared.membership.join_count(),
                checkpoints_saved: shared
                    .ckpt
                    .as_ref()
                    .map(|c| c.saved.load(std::sync::atomic::Ordering::Relaxed))
                    .unwrap_or(0),
                membership_epoch: shared.membership.epoch(),
                stalled: shared.membership.stalled(),
            },
            ps: {
                use std::sync::atomic::Ordering::Relaxed;
                crate::metrics::PsStats {
                    shards: cfg.cluster.n_shards() as u64,
                    grad_pushes: shared.ps.as_ref().map(|p| p.grad_pushes.load(Relaxed)).unwrap_or(0),
                    param_pulls: shared.ps.as_ref().map(|p| p.param_pulls.load(Relaxed)).unwrap_or(0),
                    repartitions: shared
                        .fabric
                        .core()
                        .role_table()
                        .map(|t| t.repartitions.load(Relaxed))
                        .unwrap_or(0),
                    queue_depth_max: shared
                        .ps
                        .as_ref()
                        .map(|p| p.queue_depth_max.load(Relaxed))
                        .unwrap_or(0),
                }
            },
            telemetry: shared.telemetry.stats(),
        };

        if let Some(path) = cfg.telemetry.trace_path.as_ref() {
            crate::telemetry::export::write_chrome_trace(&shared.telemetry, path)?;
        }

        shared.events.emit(TrainEvent::RunCompleted { total_steps, wall_s: wall });

        Ok(RunSummary {
            algorithm: cfg.algorithm.name().to_string(),
            curve,
            mfu: occupancy, // benches calibrate against single-worker peak
            compute_occupancy: occupancy,
            total_time_s: wall,
            total_steps,
            epochs: stats.first().map(|s| s.steps).unwrap_or(0) / batches_per_epoch.max(1),
            gossip_skipped: skipped,
            gossip_applied: applied,
            stats: run_stats,
        })
    }
}

/// Convenience: run every paper algorithm on the same base config, returning
/// summaries in paper-table order (used by the bench harness).
pub fn run_paper_set(base: &TrainConfig, manifest: &Manifest) -> Result<Vec<RunSummary>> {
    Algorithm::all_paper()
        .iter()
        .map(|&a| {
            let mut cfg = base.clone();
            cfg.algorithm = a;
            SessionBuilder::new(cfg).build(manifest)?.run()
        })
        .collect()
}
