//! Typed training-event stream and pluggable observers.
//!
//! The engine and the algorithms emit [`TrainEvent`]s through the
//! [`EventBus`] a [`crate::session::SessionBuilder`] assembles. Observers are
//! shared (`Arc<dyn Observer>`), may be called from any worker / pool /
//! updater thread, and must therefore be `Send + Sync` and use interior
//! mutability for any state. Emission is synchronous and in-line: keep
//! observers cheap (the built-in ones buffer or lock briefly) — a run with
//! no observers pays one empty-slice iteration per event.

use std::collections::BTreeMap;
use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use crate::metrics::{Curve, CurvePoint};
use crate::util::json::{num, obj, s, Json};

/// One typed event from a training run, in rough emission order.
#[derive(Clone, Debug, PartialEq)]
pub enum TrainEvent {
    /// The run is about to spawn its workers.
    RunStarted { algorithm: &'static str, workers: usize, steps: usize, decoupled: bool },
    /// One worker finished one training step (decoupled runs may report
    /// steps out of order; `loss` is the step's training loss).
    StepCompleted { worker: usize, step: usize, loss: f64 },
    /// Worker 0 evaluated its replica on the held-out stream.
    EvalPoint { step: usize, time_s: f64, loss: f64, accuracy: f64 },
    /// A gossip exchange landed in a peer's parameter store.
    GossipApplied { worker: usize, peer: usize, step: usize },
    /// A gossip exchange was skipped on contention (push-sum busy slot).
    GossipSkipped { worker: usize, peer: usize, step: usize },
    /// Pass-queue depth right after a forward-pool push (decoupled mode).
    QueueDepth { worker: usize, step: usize, depth: usize },
    /// Periodic per-lane compute gauge (eval cadence): cumulative busy
    /// seconds and retired FLOPs for one compute lane — `lane` indexes the
    /// thread within a worker (always 0 serially; forward threads then
    /// backward threads decoupled). Feeds live-MFU displays.
    Utilization { worker: usize, lane: usize, step: usize, compute_s: f64, flops: u64 },
    /// A message left `from` toward `to` on the communication fabric
    /// (emitted only when observers are attached — this is per-message).
    CommSent { from: usize, to: usize, step: usize, bytes: u64 },
    /// The link dropped a message (simulated fabric; the sender reclaims
    /// any shipped push-sum weight).
    CommDropped { from: usize, to: usize, step: usize },
    /// A message was applied at its receiver; `staleness` is the receiver's
    /// step minus the sender's step at send time.
    CommDelivered { from: usize, to: usize, step: usize, staleness: i64 },
    /// A gradient was applied against parameters that had moved since the
    /// pass read them: `tau` is the number of intervening writes observed
    /// on that layer's staleness clock (emitted only when τ > 0 and
    /// observers are attached — this is per-(apply, layer)).
    StaleApply { worker: usize, layer: usize, step: usize, tau: u64 },
    /// The configured straggler idled before this step.
    StragglerInjected { worker: usize, step: usize, delay_s: f64 },
    /// A chaos fault tore this worker down before it ran `step`
    /// (resilience subsystem; the membership epoch bumped).
    WorkerCrashed { worker: usize, step: usize },
    /// A crashed worker was respawned and rejoined the run at `step`;
    /// `epoch` is the membership version after the join.
    WorkerJoined { worker: usize, step: usize, epoch: u64 },
    /// A periodic checkpoint was written; resume with
    /// `Session::resume_from(path)` (or `layup train --resume <path>`).
    CheckpointSaved { step: usize, path: String },
    /// The session restored a checkpoint and will continue from `step`.
    Resumed { step: usize, path: String },
    /// All workers joined; the summary is being assembled.
    RunCompleted { total_steps: usize, wall_s: f64 },
}

impl TrainEvent {
    /// Stable snake_case tag (the `"event"` field of the JSONL sink).
    pub fn kind(&self) -> &'static str {
        match self {
            TrainEvent::RunStarted { .. } => "run_started",
            TrainEvent::StepCompleted { .. } => "step_completed",
            TrainEvent::EvalPoint { .. } => "eval_point",
            TrainEvent::GossipApplied { .. } => "gossip_applied",
            TrainEvent::GossipSkipped { .. } => "gossip_skipped",
            TrainEvent::QueueDepth { .. } => "queue_depth",
            TrainEvent::Utilization { .. } => "utilization",
            TrainEvent::CommSent { .. } => "comm_sent",
            TrainEvent::CommDropped { .. } => "comm_dropped",
            TrainEvent::CommDelivered { .. } => "comm_delivered",
            TrainEvent::StaleApply { .. } => "stale_apply",
            TrainEvent::StragglerInjected { .. } => "straggler_injected",
            TrainEvent::WorkerCrashed { .. } => "worker_crashed",
            TrainEvent::WorkerJoined { .. } => "worker_joined",
            TrainEvent::CheckpointSaved { .. } => "checkpoint_saved",
            TrainEvent::Resumed { .. } => "resumed",
            TrainEvent::RunCompleted { .. } => "run_completed",
        }
    }

    /// One flat JSON object per event (the JSONL record shape).
    pub fn to_json(&self) -> Json {
        let mut fields = vec![("event", s(self.kind()))];
        match self {
            TrainEvent::RunStarted { algorithm, workers, steps, decoupled } => {
                fields.push(("algorithm", s(algorithm)));
                fields.push(("workers", num(*workers as f64)));
                fields.push(("steps", num(*steps as f64)));
                fields.push(("decoupled", Json::Bool(*decoupled)));
            }
            TrainEvent::StepCompleted { worker, step, loss } => {
                fields.push(("worker", num(*worker as f64)));
                fields.push(("step", num(*step as f64)));
                fields.push(("loss", num(*loss)));
            }
            TrainEvent::EvalPoint { step, time_s, loss, accuracy } => {
                fields.push(("step", num(*step as f64)));
                fields.push(("time_s", num(*time_s)));
                fields.push(("loss", num(*loss)));
                fields.push(("accuracy", num(*accuracy)));
            }
            TrainEvent::GossipApplied { worker, peer, step }
            | TrainEvent::GossipSkipped { worker, peer, step } => {
                fields.push(("worker", num(*worker as f64)));
                fields.push(("peer", num(*peer as f64)));
                fields.push(("step", num(*step as f64)));
            }
            TrainEvent::QueueDepth { worker, step, depth } => {
                fields.push(("worker", num(*worker as f64)));
                fields.push(("step", num(*step as f64)));
                fields.push(("depth", num(*depth as f64)));
            }
            TrainEvent::Utilization { worker, lane, step, compute_s, flops } => {
                fields.push(("worker", num(*worker as f64)));
                fields.push(("lane", num(*lane as f64)));
                fields.push(("step", num(*step as f64)));
                fields.push(("compute_s", num(*compute_s)));
                fields.push(("flops", num(*flops as f64)));
            }
            TrainEvent::CommSent { from, to, step, bytes } => {
                fields.push(("from", num(*from as f64)));
                fields.push(("to", num(*to as f64)));
                fields.push(("step", num(*step as f64)));
                fields.push(("bytes", num(*bytes as f64)));
            }
            TrainEvent::CommDropped { from, to, step } => {
                fields.push(("from", num(*from as f64)));
                fields.push(("to", num(*to as f64)));
                fields.push(("step", num(*step as f64)));
            }
            TrainEvent::CommDelivered { from, to, step, staleness } => {
                fields.push(("from", num(*from as f64)));
                fields.push(("to", num(*to as f64)));
                fields.push(("step", num(*step as f64)));
                fields.push(("staleness", num(*staleness as f64)));
            }
            TrainEvent::StaleApply { worker, layer, step, tau } => {
                fields.push(("worker", num(*worker as f64)));
                fields.push(("layer", num(*layer as f64)));
                fields.push(("step", num(*step as f64)));
                fields.push(("tau", num(*tau as f64)));
            }
            TrainEvent::StragglerInjected { worker, step, delay_s } => {
                fields.push(("worker", num(*worker as f64)));
                fields.push(("step", num(*step as f64)));
                fields.push(("delay_s", num(*delay_s)));
            }
            TrainEvent::WorkerCrashed { worker, step } => {
                fields.push(("worker", num(*worker as f64)));
                fields.push(("step", num(*step as f64)));
            }
            TrainEvent::WorkerJoined { worker, step, epoch } => {
                fields.push(("worker", num(*worker as f64)));
                fields.push(("step", num(*step as f64)));
                fields.push(("epoch", num(*epoch as f64)));
            }
            TrainEvent::CheckpointSaved { step, path } => {
                fields.push(("step", num(*step as f64)));
                fields.push(("path", s(path)));
            }
            TrainEvent::Resumed { step, path } => {
                fields.push(("step", num(*step as f64)));
                fields.push(("path", s(path)));
            }
            TrainEvent::RunCompleted { total_steps, wall_s } => {
                fields.push(("total_steps", num(*total_steps as f64)));
                fields.push(("wall_s", num(*wall_s)));
            }
        }
        obj(fields)
    }
}

/// A training-run observer. Called synchronously from worker threads.
pub trait Observer: Send + Sync {
    fn on_event(&self, event: &TrainEvent);
}

/// Closures observe directly: `.observer(Arc::new(|ev: &TrainEvent| ...))`.
impl<F> Observer for F
where
    F: Fn(&TrainEvent) + Send + Sync,
{
    fn on_event(&self, event: &TrainEvent) {
        self(event)
    }
}

/// The fan-out point: every emit is forwarded to each attached observer.
#[derive(Clone, Default)]
pub struct EventBus {
    observers: Vec<Arc<dyn Observer>>,
}

impl EventBus {
    pub fn new() -> EventBus {
        EventBus::default()
    }

    pub fn attach(&mut self, observer: Arc<dyn Observer>) {
        self.observers.push(observer);
    }

    pub fn has_observers(&self) -> bool {
        !self.observers.is_empty()
    }

    pub fn emit(&self, event: TrainEvent) {
        for o in &self.observers {
            o.on_event(&event);
        }
    }
}

/// Prints run lifecycle and evaluation points to stdout — the typed
/// replacement for the ad-hoc `println!` progress lines. Accumulates the
/// per-lane [`TrainEvent::Utilization`] gauges and the per-message
/// [`TrainEvent::CommSent`] bytes so eval lines carry a live MFU estimate
/// and the cumulative wire traffic.
#[derive(Default)]
pub struct ProgressPrinter {
    state: Mutex<ProgressState>,
}

#[derive(Default)]
struct ProgressState {
    /// (worker, lane) -> latest cumulative (busy seconds, retired FLOPs).
    lanes: BTreeMap<(usize, usize), (f64, u64)>,
    /// Cumulative fabric bytes (every `CommSent`).
    comm_bytes: u64,
}

impl ProgressPrinter {
    pub fn new() -> ProgressPrinter {
        ProgressPrinter::default()
    }
}

impl Observer for ProgressPrinter {
    fn on_event(&self, event: &TrainEvent) {
        match event {
            TrainEvent::RunStarted { algorithm, workers, steps, decoupled } => {
                let mode = if *decoupled { "decoupled" } else { "serial" };
                println!("[{algorithm}] {workers} workers x {steps} steps ({mode})");
            }
            TrainEvent::Utilization { worker, lane, compute_s, flops, .. } => {
                let mut st = self.state.lock().unwrap();
                st.lanes.insert((*worker, *lane), (*compute_s, *flops));
            }
            TrainEvent::CommSent { bytes, .. } => {
                self.state.lock().unwrap().comm_bytes += bytes;
            }
            TrainEvent::EvalPoint { step, time_s, loss, accuracy } => {
                let st = self.state.lock().unwrap();
                let mut line = format!(
                    "[eval] step {step:>6}  t={time_s:>7.1}s  loss {loss:.4}  acc {:.1}%",
                    100.0 * accuracy
                );
                if !st.lanes.is_empty() && *time_s > 0.0 {
                    let busy: f64 = st.lanes.values().map(|(busy_s, _)| *busy_s).sum();
                    let mfu = (busy / (time_s * st.lanes.len() as f64)).min(1.0);
                    line.push_str(&format!("  mfu {:.1}%", 100.0 * mfu));
                }
                if st.comm_bytes > 0 {
                    line.push_str(&format!(
                        "  comm {:.1} MiB",
                        st.comm_bytes as f64 / (1024.0 * 1024.0)
                    ));
                }
                println!("{line}");
            }
            TrainEvent::WorkerCrashed { worker, step } => {
                println!("[chaos] worker {worker} crashed at step {step}");
            }
            TrainEvent::WorkerJoined { worker, step, epoch } => {
                println!("[chaos] worker {worker} rejoined at step {step} (epoch {epoch})");
            }
            TrainEvent::CheckpointSaved { step, path } => {
                println!("[ckpt] step {step} -> {path}");
            }
            TrainEvent::Resumed { step, path } => {
                println!("[ckpt] resumed from {path} at step {step}");
            }
            TrainEvent::RunCompleted { total_steps, wall_s } => {
                println!("[done] {total_steps} steps in {wall_s:.1}s");
            }
            _ => {}
        }
    }
}

/// Streams every event as one JSON object per line (JSONL), suitable for
/// offline analysis; see EXPERIMENTS.md §Events.
pub struct JsonlSink {
    out: Mutex<Box<dyn Write + Send>>,
}

impl JsonlSink {
    /// Create (truncate) `path` and stream events into it, buffered.
    pub fn create<P: AsRef<Path>>(path: P) -> Result<JsonlSink> {
        let file = File::create(path.as_ref())
            .with_context(|| format!("creating event sink {}", path.as_ref().display()))?;
        Ok(JsonlSink::new(Box::new(BufWriter::new(file))))
    }

    /// Stream events into an arbitrary writer (tests use a shared buffer).
    pub fn new(out: Box<dyn Write + Send>) -> JsonlSink {
        JsonlSink { out: Mutex::new(out) }
    }
}

impl Observer for JsonlSink {
    fn on_event(&self, event: &TrainEvent) {
        let mut out = self.out.lock().unwrap();
        // an unwritable sink must not kill the training run
        let _ = writeln!(out, "{}", event.to_json().dump());
        if matches!(event, TrainEvent::RunCompleted { .. }) {
            let _ = out.flush();
        }
    }
}

impl Drop for JsonlSink {
    fn drop(&mut self) {
        if let Ok(mut out) = self.out.lock() {
            let _ = out.flush();
        }
    }
}

/// Records [`TrainEvent::EvalPoint`]s into an in-memory [`Curve`] — handy
/// when a caller wants live curve access without waiting for the summary.
/// The buffer is step-sorted in place when `RunCompleted` arrives (decoupled
/// runs evaluate out of order), so post-run [`CurveRecorder::snapshot`]
/// calls see the final, flushed curve without re-sorting.
#[derive(Default)]
pub struct CurveRecorder {
    curve: Mutex<Curve>,
}

impl CurveRecorder {
    pub fn new() -> CurveRecorder {
        CurveRecorder::default()
    }

    /// The step-sorted curve recorded so far.
    pub fn snapshot(&self) -> Curve {
        let mut c = self.curve.lock().unwrap().clone();
        c.sort_by_step();
        c
    }
}

impl Observer for CurveRecorder {
    fn on_event(&self, event: &TrainEvent) {
        match event {
            TrainEvent::EvalPoint { step, time_s, loss, accuracy } => {
                self.curve.lock().unwrap().push(CurvePoint {
                    step: *step,
                    time_s: *time_s,
                    loss: *loss,
                    accuracy: *accuracy,
                });
            }
            TrainEvent::RunCompleted { .. } => {
                // run-end flush: settle the ordering once
                self.curve.lock().unwrap().sort_by_step();
            }
            _ => {}
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn event_kinds_and_json_tags_agree() {
        let ev = TrainEvent::EvalPoint { step: 3, time_s: 1.5, loss: 0.7, accuracy: 0.25 };
        assert_eq!(ev.kind(), "eval_point");
        let j = ev.to_json().dump();
        assert!(j.contains("\"event\":\"eval_point\""), "{j}");
        assert!(j.contains("\"accuracy\":0.25"), "{j}");
    }

    #[test]
    fn utilization_serializes_lane_and_flops() {
        let ev =
            TrainEvent::Utilization { worker: 1, lane: 2, step: 30, compute_s: 0.5, flops: 1000 };
        assert_eq!(ev.kind(), "utilization");
        let j = ev.to_json().dump();
        assert!(j.contains("\"lane\":2"), "{j}");
        assert!(j.contains("\"compute_s\":0.5"), "{j}");
        assert!(j.contains("\"flops\":1000"), "{j}");
    }

    #[test]
    fn comm_events_serialize_with_link_and_staleness_fields() {
        let sent = TrainEvent::CommSent { from: 0, to: 2, step: 5, bytes: 128 };
        assert_eq!(sent.kind(), "comm_sent");
        let j = sent.to_json().dump();
        assert!(j.contains("\"from\":0"), "{j}");
        assert!(j.contains("\"to\":2"), "{j}");
        assert!(j.contains("\"bytes\":128"), "{j}");

        let dropped = TrainEvent::CommDropped { from: 1, to: 0, step: 7 };
        assert_eq!(dropped.kind(), "comm_dropped");
        assert!(dropped.to_json().dump().contains("\"step\":7"));

        let delivered = TrainEvent::CommDelivered { from: 1, to: 0, step: 7, staleness: -2 };
        assert_eq!(delivered.kind(), "comm_delivered");
        assert!(delivered.to_json().dump().contains("\"staleness\":-2"));
    }

    #[test]
    fn stale_apply_serializes_layer_and_tau() {
        let ev = TrainEvent::StaleApply { worker: 2, layer: 5, step: 40, tau: 7 };
        assert_eq!(ev.kind(), "stale_apply");
        let j = ev.to_json().dump();
        assert!(j.contains("\"layer\":5"), "{j}");
        assert!(j.contains("\"tau\":7"), "{j}");
    }

    #[test]
    fn resilience_events_serialize_the_fault_timeline() {
        let crash = TrainEvent::WorkerCrashed { worker: 1, step: 20 };
        assert_eq!(crash.kind(), "worker_crashed");
        assert!(crash.to_json().dump().contains("\"worker\":1"));

        let join = TrainEvent::WorkerJoined { worker: 1, step: 20, epoch: 2 };
        assert_eq!(join.kind(), "worker_joined");
        assert!(join.to_json().dump().contains("\"epoch\":2"));

        let saved =
            TrainEvent::CheckpointSaved { step: 25, path: "ck/step-000025".into() };
        assert_eq!(saved.kind(), "checkpoint_saved");
        let j = saved.to_json().dump();
        assert!(j.contains("\"step\":25"), "{j}");
        assert!(j.contains("\"path\":\"ck/step-000025\""), "{j}");

        let resumed = TrainEvent::Resumed { step: 25, path: "ck/step-000025".into() };
        assert_eq!(resumed.kind(), "resumed");
        assert!(resumed.to_json().dump().contains("\"event\":\"resumed\""));
    }

    #[test]
    fn bus_fans_out_to_all_observers() {
        let seen = Arc::new(Mutex::new(Vec::new()));
        let mut bus = EventBus::new();
        for _ in 0..2 {
            let seen = Arc::clone(&seen);
            bus.attach(Arc::new(move |ev: &TrainEvent| {
                seen.lock().unwrap().push(ev.kind());
            }));
        }
        assert!(bus.has_observers());
        bus.emit(TrainEvent::RunCompleted { total_steps: 1, wall_s: 0.1 });
        assert_eq!(*seen.lock().unwrap(), vec!["run_completed", "run_completed"]);
    }

    #[test]
    fn curve_recorder_collects_sorted_eval_points() {
        let rec = CurveRecorder::new();
        rec.on_event(&TrainEvent::EvalPoint { step: 10, time_s: 2.0, loss: 0.5, accuracy: 0.6 });
        rec.on_event(&TrainEvent::EvalPoint { step: 0, time_s: 1.0, loss: 1.0, accuracy: 0.1 });
        rec.on_event(&TrainEvent::RunCompleted { total_steps: 2, wall_s: 2.0 });
        let c = rec.snapshot();
        assert_eq!(c.points.len(), 2);
        assert_eq!(c.points[0].step, 0);
        assert_eq!(c.points[1].step, 10);
    }

    #[test]
    fn jsonl_sink_writes_one_line_per_event() {
        #[derive(Clone, Default)]
        struct SharedBuf(Arc<Mutex<Vec<u8>>>);
        impl Write for SharedBuf {
            fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
                self.0.lock().unwrap().extend_from_slice(buf);
                Ok(buf.len())
            }
            fn flush(&mut self) -> std::io::Result<()> {
                Ok(())
            }
        }
        let buf = SharedBuf::default();
        let sink = JsonlSink::new(Box::new(buf.clone()));
        sink.on_event(&TrainEvent::GossipSkipped { worker: 1, peer: 2, step: 5 });
        sink.on_event(&TrainEvent::RunCompleted { total_steps: 5, wall_s: 1.0 });
        drop(sink);
        let text = String::from_utf8(buf.0.lock().unwrap().clone()).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        assert!(lines[0].contains("\"event\":\"gossip_skipped\""));
        assert!(lines[0].contains("\"peer\":2"));
        assert!(lines[1].contains("\"event\":\"run_completed\""));
    }
}
