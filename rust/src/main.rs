//! `layup` CLI — the launcher for the L3 session facade.
//!
//! Subcommands (hand-rolled parsing; the offline crate set has no clap):
//!
//! ```text
//! layup train  [--config cfg.toml] [--model M] [--algorithm A] [--workers N]
//!              [--steps S] [--eval-every K] [--lr F] [--seed K]
//!              [--straggler W:D] [--drift-every K] [--decoupled true]
//!              [--fwd-threads N] [--bwd-threads N] [--update-threads N] [--queue-depth N]
//!              [--events events.jsonl] [--out results.json] [--curve out.csv]
//! layup sim    [--cluster c1|c2|c3] [--workload W] [--algorithm A|all]
//!              [--sync-period K] [--straggler W:D] [--seed K]
//! layup inspect            # print the artifact manifest summary
//! layup bench-peak [--model M] [--steps S]   # calibrate single-worker peak
//! ```
//!
//! Each subcommand accepts exactly the flags it documents: an unknown flag
//! (e.g. the `--step 100` typo for `--steps`) is an error, not silently
//! ignored.

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use layup::comm::{FabricSpec, LatencyDist};
use layup::config::{Algorithm, Compensation, Mixing, Toml, TrainConfig};
use layup::manifest::Manifest;
use layup::optim::Schedule;
use layup::resilience::{FaultPlan, RecoveryPolicy};
use layup::session::events::JsonlSink;
use layup::session::SessionBuilder;
use layup::sim::{simulate, Cluster, SimAlgo, Workload};
use layup::topology::roles::TopologySpec;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Flags accepted by `layup train`.
const TRAIN_FLAGS: &[&str] = &[
    "config",
    "model",
    "algorithm",
    "workers",
    "steps",
    "eval-every",
    "lr",
    "seed",
    "straggler",
    "drift-every",
    "decoupled",
    "fwd-threads",
    "bwd-threads",
    "update-threads",
    "queue-depth",
    "topology",
    "fabric",
    "codec",
    "coalesce",
    "link-latency",
    "link-drop",
    "link-bandwidth",
    "ckpt-every",
    "ckpt-dir",
    "resume",
    "crash",
    "recovery",
    "stall-timeout",
    "lockstep",
    "compensation",
    "dc-lambda",
    "adaptive-mix",
    "mix-beta",
    "events",
    "out",
    "curve",
    "trace",
    "sample-every-ms",
];

/// Flags accepted by `layup sim`.
const SIM_FLAGS: &[&str] =
    &["cluster", "workload", "algorithm", "topology", "sync-period", "straggler", "seed"];

/// Flags accepted by `layup bench-peak`.
const BENCH_PEAK_FLAGS: &[&str] = &["model", "steps"];

/// Tiny flag parser: `--key value` pairs after the subcommand, checked
/// against the subcommand's allowed set.
struct Args {
    flags: HashMap<String, String>,
}

impl Args {
    fn parse(argv: &[String], allowed: &[&str]) -> Result<Args> {
        let mut flags = HashMap::new();
        let mut i = 0;
        while i < argv.len() {
            let k = argv[i]
                .strip_prefix("--")
                .with_context(|| format!("expected --flag, got {:?}", argv[i]))?;
            if !allowed.contains(&k) {
                if allowed.is_empty() {
                    bail!("unknown flag --{k}: this subcommand takes no flags");
                }
                let known: Vec<String> = allowed.iter().map(|a| format!("--{a}")).collect();
                bail!(
                    "unknown flag --{k} for this subcommand (accepted: {})",
                    known.join(" ")
                );
            }
            let v = argv
                .get(i + 1)
                .with_context(|| format!("--{k} needs a value"))?;
            flags.insert(k.to_string(), v.clone());
            i += 2;
        }
        Ok(Args { flags })
    }

    fn get(&self, k: &str) -> Option<&str> {
        self.flags.get(k).map(|s| s.as_str())
    }

    /// `--k`'s value as usize, `d` when absent; a present-but-unparseable
    /// value is an error (no silent defaulting over typos).
    fn usize_or(&self, k: &str, d: usize) -> Result<usize> {
        match self.get(k) {
            None => Ok(d),
            Some(v) => v
                .parse()
                .with_context(|| format!("--{k}: expected an integer, got {v:?}")),
        }
    }

    /// `--k`'s value as bool (`true`/`false`), `d` when absent.
    fn bool_or(&self, k: &str, d: bool) -> Result<bool> {
        match self.get(k) {
            None => Ok(d),
            Some("true") => Ok(true),
            Some("false") => Ok(false),
            Some(v) => bail!("--{k}: expected true or false, got {v:?}"),
        }
    }
}

fn run() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first() else {
        print_usage();
        return Ok(());
    };
    match cmd.as_str() {
        "train" => cmd_train(&Args::parse(&argv[1..], TRAIN_FLAGS)?),
        "sim" => cmd_sim(&Args::parse(&argv[1..], SIM_FLAGS)?),
        "inspect" => {
            Args::parse(&argv[1..], &[])?;
            cmd_inspect()
        }
        "bench-peak" => cmd_bench_peak(&Args::parse(&argv[1..], BENCH_PEAK_FLAGS)?),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => bail!("unknown subcommand {other:?} (try `layup help`)"),
    }
}

fn print_usage() {
    let algorithms: Vec<&str> = layup::algorithms::registry().iter().map(|s| s.aliases[0]).collect();
    println!(
        "layup — asynchronous decentralized SGD with layer-wise updates\n\n\
         usage:\n\
         \x20 layup train   [--config f.toml] [--model M] [--algorithm A] [--workers N]\n\
         \x20               [--steps S] [--eval-every K] [--lr F] [--seed K]\n\
         \x20               [--straggler W:D] [--drift-every K] [--decoupled true]\n\
         \x20               [--fwd-threads N] [--bwd-threads N] [--update-threads N]\n\
         \x20               [--queue-depth N] [--topology flat|ps:N|hier:G]\n\
         \x20               [--fabric instant|sim] [--link-latency SPEC] [--link-drop P]\n\
         \x20               [--link-bandwidth MBPS] [--codec dense|topk:K|randk:K|int8]\n\
         \x20               [--coalesce true]\n\
         \x20               [--compensation none|dc] [--dc-lambda F]\n\
         \x20               [--adaptive-mix true] [--mix-beta F]\n\
         \x20               [--ckpt-every K] [--ckpt-dir DIR] [--resume DIR]\n\
         \x20               [--crash W@STEP[+SECS],..] [--recovery stall|shrink]\n\
         \x20               [--stall-timeout S] [--lockstep true]\n\
         \x20               [--events events.jsonl] [--out results.json] [--curve curve.csv]\n\
         \x20               [--trace trace.json] [--sample-every-ms MS]\n\
         \x20               (latency SPEC: seconds | constant:S | uniform:LO..HI |\n\
         \x20               pareto:SCALE,ALPHA; --link-* flags imply --fabric sim;\n\
         \x20               --crash schedules chaos faults, --resume continues a\n\
         \x20               checkpoint dir or its latest step-XXXXXX snapshot)\n\
         \x20 layup sim     [--cluster c1|c2|c3] [--workload resnet18_cifar|resnet50_cifar|\n\
         \x20               resnet50_imagenet|gpt2_medium|gpt2_xl] [--algorithm A|all]\n\
         \x20               [--topology flat|ps:N|hier:G] [--sync-period K]\n\
         \x20               [--straggler W:D] [--seed K]\n\
         \x20 layup inspect\n\
         \x20 layup bench-peak [--model M] [--steps S]\n\n\
         algorithms: {}",
        algorithms.join(" ")
    );
}

fn build_train_config(args: &Args) -> Result<TrainConfig> {
    let mut cfg = if let Some(path) = args.get("config") {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {path}"))?;
        TrainConfig::from_toml(&Toml::parse(&text)?)?
    } else {
        TrainConfig::new("mlpnet18", Algorithm::LayUp, 4, 200)
    };
    if let Some(m) = args.get("model") {
        cfg.model = m.to_string();
    }
    if let Some(a) = args.get("algorithm") {
        cfg.algorithm = Algorithm::parse(a)?;
    }
    cfg.workers = args.usize_or("workers", cfg.workers)?;
    cfg.steps = args.usize_or("steps", cfg.steps)?;
    // --eval-every wins; otherwise a config file's cadence is honored, and
    // without a config file the default follows the (possibly overridden)
    // step count
    let eval_default = if args.get("config").is_none() {
        (cfg.steps / 20).max(1)
    } else {
        cfg.eval_every
    };
    cfg.eval_every = args.usize_or("eval-every", eval_default)?;
    cfg.seed = args.usize_or("seed", cfg.seed as usize)? as u64;
    cfg.track_drift_every = args.usize_or("drift-every", cfg.track_drift_every)?;
    cfg.decoupled = args.bool_or("decoupled", cfg.decoupled)?;
    cfg.fwd_threads = args.usize_or("fwd-threads", cfg.fwd_threads)?;
    cfg.bwd_threads = args.usize_or("bwd-threads", cfg.bwd_threads)?;
    cfg.update_threads = args.usize_or("update-threads", cfg.update_threads)?;
    cfg.queue_depth = args.usize_or("queue-depth", cfg.queue_depth)?;
    if let Some(v) = args.get("topology") {
        cfg.cluster = TopologySpec::parse(v).with_context(|| format!("--topology {v:?}"))?;
    }
    if let Some(v) = args.get("lr") {
        let lr: f32 = v
            .parse()
            .with_context(|| format!("--lr: expected a number, got {v:?}"))?;
        cfg.schedule = Schedule::Cosine { lr, t_max: cfg.steps, warmup_steps: 0, warmup_lr: 0.0 };
    }
    if let Some(s) = args.get("straggler") {
        let (w, d) = s.split_once(':').context("--straggler wants WORKER:DELAY")?;
        cfg.straggler = Some((w.parse()?, d.parse()?));
    }

    // Resilience: periodic checkpoints, chaos schedule, recovery knobs.
    cfg.checkpoint_every = args.usize_or("ckpt-every", cfg.checkpoint_every)?;
    if let Some(dir) = args.get("ckpt-dir") {
        cfg.checkpoint_dir = dir.into();
    }
    if let Some(spec) = args.get("crash") {
        cfg.faults = FaultPlan::parse(spec).with_context(|| format!("--crash {spec:?}"))?;
    }
    if let Some(p) = args.get("recovery") {
        cfg.recovery = RecoveryPolicy::parse(p)?;
    }
    if let Some(v) = args.get("stall-timeout") {
        cfg.stall_timeout_s = v
            .parse()
            .with_context(|| format!("--stall-timeout: expected seconds, got {v:?}"))?;
    }
    cfg.lockstep = args.bool_or("lockstep", cfg.lockstep)?;

    // Staleness policies: DC-ASGD delay compensation + adaptive mixing.
    if let Some(v) = args.get("compensation") {
        cfg.staleness.compensation = match v {
            "none" => Compensation::None,
            "dc" => Compensation::Dc,
            other => bail!("--compensation: expected none or dc, got {other:?}"),
        };
    }
    if let Some(v) = args.get("dc-lambda") {
        cfg.staleness.dc_lambda = v
            .parse()
            .with_context(|| format!("--dc-lambda: expected a number, got {v:?}"))?;
    }
    if args.bool_or("adaptive-mix", cfg.staleness.mixing == Mixing::Adaptive)? {
        cfg.staleness.mixing = Mixing::Adaptive;
    } else {
        cfg.staleness.mixing = Mixing::Fixed;
    }
    if let Some(v) = args.get("mix-beta") {
        cfg.staleness.mix_beta = v
            .parse()
            .with_context(|| format!("--mix-beta: expected a number, got {v:?}"))?;
    }

    // Communication fabric. The --link-* knobs describe simulated links, so
    // they imply --fabric sim; naming --fabric instant alongside them is a
    // contradiction, not a silent override.
    let fabric_flag = args.get("fabric");
    if let Some(v) = fabric_flag {
        cfg.fabric = match v {
            "instant" => FabricSpec::Instant,
            "sim" => match cfg.fabric.clone() {
                sim @ FabricSpec::Sim { .. } => sim, // keep config-file link knobs
                FabricSpec::Instant => FabricSpec::sim_default(),
            },
            other => bail!("--fabric: expected instant or sim, got {other:?}"),
        };
    }
    let have_link_flags = ["link-latency", "link-drop", "link-bandwidth"]
        .into_iter()
        .any(|k| args.get(k).is_some());
    if have_link_flags {
        if fabric_flag == Some("instant") {
            bail!(
                "--link-latency/--link-drop/--link-bandwidth describe simulated \
                 links; drop them or use --fabric sim"
            );
        }
        let (mut latency, mut bandwidth_bytes_per_s, mut drop_prob) = match cfg.fabric.clone() {
            FabricSpec::Sim { latency, bandwidth_bytes_per_s, drop_prob } => {
                (latency, bandwidth_bytes_per_s, drop_prob)
            }
            FabricSpec::Instant => (LatencyDist::Constant(0.0), 0.0, 0.0),
        };
        if let Some(v) = args.get("link-latency") {
            latency = LatencyDist::parse(v).with_context(|| format!("--link-latency {v:?}"))?;
        }
        if let Some(v) = args.get("link-bandwidth") {
            let mbps: f64 = v
                .parse()
                .with_context(|| format!("--link-bandwidth: expected Mbit/s, got {v:?}"))?;
            bandwidth_bytes_per_s = mbps * 125_000.0;
        }
        if let Some(v) = args.get("link-drop") {
            drop_prob = v
                .parse()
                .with_context(|| format!("--link-drop: expected a probability, got {v:?}"))?;
        }
        cfg.fabric = FabricSpec::Sim { latency, bandwidth_bytes_per_s, drop_prob };
    }
    // Fabric-boundary compression (works on both transports).
    if let Some(v) = args.get("codec") {
        cfg.codec = layup::comm::CodecSpec::parse(v)?;
    }
    // Step-frame coalescing of LayUp's per-layer pushes (default off).
    cfg.coalesce = args.bool_or("coalesce", cfg.coalesce)?;
    // Telemetry: a trace path implies enabling the recorder.
    if let Some(path) = args.get("trace") {
        cfg.telemetry.trace_path = Some(path.into());
        cfg.telemetry.enabled = true;
    }
    cfg.telemetry.sample_every_ms =
        args.usize_or("sample-every-ms", cfg.telemetry.sample_every_ms as usize)? as u64;
    Ok(cfg)
}

fn cmd_train(args: &Args) -> Result<()> {
    let cfg = build_train_config(args)?;
    let manifest = Manifest::load(&layup::artifacts_dir())?;
    // reject bad configs BEFORE touching the --events file: JsonlSink::create
    // truncates, and an invalid run must not wipe a previous run's event log
    cfg.validate()?;
    manifest.model(&cfg.model)?;
    println!(
        "training {} with {} on {} workers for {} steps (seed {}, {} fabric)",
        cfg.model,
        cfg.algorithm.name(),
        cfg.workers,
        cfg.steps,
        cfg.seed,
        cfg.fabric.name()
    );
    let t0 = std::time::Instant::now();
    let trace_path = cfg.telemetry.trace_path.clone();
    let mut builder = SessionBuilder::new(cfg);
    if let Some(path) = args.get("events") {
        builder = builder.observer(Arc::new(JsonlSink::create(path)?));
        println!("typed event stream -> {path}");
    }
    let mut session = builder.build(&manifest)?;
    if let Some(dir) = args.get("resume") {
        session = session.resume_from(dir)?;
        println!("resuming from checkpoint {dir}");
    }
    let summary = session.run()?;
    println!(
        "done in {:.1}s: best_acc={:.4} best_loss={:.4} (ppl {:.2}) occupancy={:.1}% gossip applied/skipped={}/{}",
        t0.elapsed().as_secs_f64(),
        summary.curve.best_accuracy(),
        summary.curve.best_loss(),
        summary.curve.best_loss().exp(),
        100.0 * summary.compute_occupancy,
        summary.gossip_applied,
        summary.gossip_skipped,
    );
    let comm = &summary.stats.comm;
    if comm.msgs_sent > 0 {
        println!(
            "comm: {} msgs / {} bytes sent, {} delivered, {} dropped, mean staleness {:.2} steps",
            comm.msgs_sent,
            comm.bytes_sent,
            comm.msgs_delivered,
            comm.msgs_dropped,
            comm.mean_delivered_staleness(),
        );
    }
    let stale = &summary.stats.staleness;
    if stale.total_applies() > 0 {
        println!(
            "staleness: {} applies observed, mean tau {:.2} writes, max {}",
            stale.total_applies(),
            stale.mean_tau(),
            stale.max_tau(),
        );
    }
    let rec = &summary.stats.recovery;
    if rec.crashes > 0 || rec.checkpoints_saved > 0 || rec.stalled {
        println!(
            "resilience: {} crashes, {} rejoins, {} checkpoints (membership epoch {}){}",
            rec.crashes,
            rec.joins,
            rec.checkpoints_saved,
            rec.membership_epoch,
            if rec.stalled { " — RUN STALLED" } else { "" }
        );
    }
    let tel = &summary.stats.telemetry;
    if tel.enabled {
        println!(
            "telemetry: {} spans on {} threads ({} dropped), {} samples",
            tel.spans, tel.threads, tel.dropped, tel.samples
        );
        if let Some(path) = trace_path.as_ref() {
            println!("chrome trace -> {}", path.display());
        }
    }
    if let Some(path) = args.get("curve") {
        std::fs::write(path, summary.curve.to_csv())?;
        println!("learning curve -> {path}");
    }
    if let Some(path) = args.get("out") {
        std::fs::write(path, summary.to_json().dump())?;
        println!("summary -> {path}");
    }
    Ok(())
}

fn cmd_sim(args: &Args) -> Result<()> {
    let cluster_name = args.get("cluster").unwrap_or("c1");
    let mut cluster = match cluster_name {
        "c1" => Cluster::c1(),
        "c2" => Cluster::c2(),
        "c3" => Cluster::c3(),
        other => bail!("unknown cluster {other:?}"),
    };
    if let Some(s) = args.get("straggler") {
        let (w, d) = s.split_once(':').context("--straggler wants WORKER:DELAY")?;
        cluster = cluster.with_straggler(w.parse()?, d.parse()?);
    }
    let workload_name = args.get("workload").unwrap_or("resnet50_cifar");
    let w = match workload_name {
        "resnet18_cifar" => Workload::resnet18_cifar(cluster.m),
        "resnet50_cifar" => Workload::resnet50_cifar(cluster.m),
        "resnet50_imagenet" => Workload::resnet50_imagenet(cluster.m),
        "gpt2_medium" => Workload::gpt2_medium(cluster.m),
        "gpt2_xl" => Workload::gpt2_xl(cluster.m),
        other => bail!("unknown workload {other:?}"),
    };
    let period = args.usize_or("sync-period", 12)?;
    let topo = match args.get("topology") {
        Some(v) => {
            let t = TopologySpec::parse(v).with_context(|| format!("--topology {v:?}"))?;
            t.validate(cluster.m)
                .with_context(|| format!("--topology {v:?} on {} devices", cluster.m))?;
            t
        }
        None => TopologySpec::Flat,
    };
    let algos: Vec<SimAlgo> = match (args.get("algorithm").unwrap_or("all"), topo) {
        // the topology picks the schedule family when no algorithm is named
        ("all", TopologySpec::Flat) => SimAlgo::paper_set(period),
        ("all", TopologySpec::Ps { shards }) => vec![
            SimAlgo::AsgdPs { shards, dc: false },
            SimAlgo::AsgdPs { shards, dc: true },
        ],
        ("all", TopologySpec::Hier { groups }) => {
            vec![SimAlgo::HierGossip { groups, period }]
        }
        (name, topo) => {
            let algo = Algorithm::parse(name)?;
            match (algo, topo) {
                (Algorithm::AsgdPs, TopologySpec::Ps { shards }) => {
                    vec![SimAlgo::AsgdPs { shards, dc: false }]
                }
                (Algorithm::DcAsgdPs, TopologySpec::Ps { shards }) => {
                    vec![SimAlgo::AsgdPs { shards, dc: true }]
                }
                (Algorithm::HierGossip, TopologySpec::Hier { groups }) => {
                    vec![SimAlgo::HierGossip { groups, period }]
                }
                (Algorithm::AsgdPs | Algorithm::DcAsgdPs, _) => {
                    bail!("{name} needs --topology ps:N (server shards)")
                }
                (Algorithm::HierGossip, _) => bail!("{name} needs --topology hier:G (groups)"),
                (_, TopologySpec::Ps { .. } | TopologySpec::Hier { .. }) => {
                    bail!("{name} runs the flat topology; drop --topology or use all")
                }
                (_, TopologySpec::Flat) => {
                    // one registry lookup instead of a divergent name match
                    let spec = layup::algorithms::spec(algo);
                    let Some(sim) = spec.sim else {
                        bail!("{} has no discrete-event-simulator model", spec.name);
                    };
                    vec![sim(period)]
                }
            }
        }
    };
    println!(
        "simulating {} on {} ({} devices)",
        w.name, cluster.name, cluster.m
    );
    println!(
        "{:<10} {:>12} {:>10} {:>8} {:>12}",
        "algorithm", "wall (s)", "occup.", "MFU", "comm (GB)"
    );
    let seed = args.usize_or("seed", 1)? as u64;
    for a in algos {
        let r = simulate(&cluster, &w, a, seed);
        println!(
            "{:<10} {:>12.1} {:>9.1}% {:>7.1}% {:>12.1}",
            r.algo,
            r.wall_s,
            100.0 * r.occupancy,
            100.0 * r.mfu,
            r.comm_gbytes
        );
    }
    Ok(())
}

fn cmd_inspect() -> Result<()> {
    let dir = layup::artifacts_dir();
    let manifest = Manifest::load(&dir)?;
    println!("artifacts: {} (scale: {})", dir.display(), manifest.scale);
    for (name, m) in &manifest.models {
        println!(
            "model {name}: task={} batch={} params={} step_flops={:.2e}",
            m.task,
            m.batch,
            m.param_count,
            m.step_flops() as f64
        );
        for l in &m.layers {
            println!(
                "  {:<12} {:?}  params={:<9} fwd={} bwd={}",
                l.name,
                l.kind,
                l.param_numel(),
                l.fwd_file,
                l.bwd_file
            );
        }
    }
    Ok(())
}

/// Calibrate the single-worker compute-only peak (the "theoretical peak" the
/// MFU of Table 4 is measured against on this substrate).
fn cmd_bench_peak(args: &Args) -> Result<()> {
    let model = args.get("model").unwrap_or("mlpnet18");
    let steps = args.usize_or("steps", 20)?;
    let manifest = Manifest::load(&layup::artifacts_dir())?;
    let mut cfg = TrainConfig::new(model, Algorithm::GoSgd, 1, steps);
    cfg.eval_every = steps + 1; // no eval in the timing window
    let summary = SessionBuilder::new(cfg).build(&manifest)?.run()?;
    let peak = summary.stats.achieved_flops_per_s;
    println!(
        "single-worker peak on {model}: {:.3e} FLOP/s (occupancy {:.1}%)",
        peak,
        100.0 * summary.compute_occupancy
    );
    Ok(())
}
