//! Versioned, self-describing training snapshots.
//!
//! A checkpoint directory holds two files:
//!
//! * `meta.json` — human-readable inventory: format name + version, run
//!   identity (model, algorithm, workers, seed), the resume step, and counts
//!   of everything the binary blob carries. Written *last*, so a directory
//!   with a `meta.json` is a complete checkpoint (commit marker).
//! * `state.bin` — the full training state in a little-endian binary layout
//!   (exact f32/f64 bits, no decimal round-tripping): every worker's model
//!   replica ([`crate::model::ModelParams::state_dict`]), optimizer moments
//!   and gossip RNG streams ([`AlgoState`]), data-loader cursors, push-sum
//!   weights, membership flags, the quiesced in-flight fabric messages
//!   ([`crate::comm::InFlight`]), the codec's sender-side error-feedback
//!   residuals ([`crate::comm::codec::ResidualState`]) and the learning
//!   curve so far.
//!
//! The invariant the round-trip tests pin: **save → load → continue is
//! bit-identical to an uninterrupted run** (on the instant fabric, under a
//! deterministic driver — see the engine's lockstep mode and the parity
//! tests in `tests/resilience.rs`).

use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::comm::codec::{CodecSpec, Compressed, ResidualState, StreamKey};
use crate::comm::{InFlight, Payload};
use crate::metrics::CurvePoint;
use crate::optim::{LayerOptState, OptState};
use crate::tensor::clock::ClockStamp;
use crate::tensor::Tensor;
use crate::util::json::{num, obj, s, Json};

/// Bump on any layout change; `load` rejects unknown versions.
/// v2: per-layer staleness clocks (`Checkpoint::clocks`) + provenance
/// headers (`stamp`, `tau`) on `Payload::LayerPush`.
/// v3: parameter-server payload tags (`Payload::GradPush` = 5,
/// `Payload::ParamPull` = 6) so a `ps:N` run's in-flight traffic survives
/// the drain/restore round trip.
/// v4: fabric codec state — `Payload::Compressed` in-flight messages
/// (tag 7) and per-link error-feedback residuals
/// (`Checkpoint::residuals`), so a `topk`/`randk` run resumes without
/// destroying the gradient mass the sparsifier was still holding.
/// v5: step-frame coalescing — `Payload::StepFrame` in-flight messages
/// (tag 8), including partially built frames the fabric's per-link
/// `FrameBuilder`s still held at the quiesce (drained as zero-delay
/// in-flight traffic, conserving clock provenance and push-sum mass).
pub const FORMAT_VERSION: u32 = 5;

/// Format name written to `meta.json` (self-description).
pub const FORMAT_NAME: &str = "layup-checkpoint";

const MAGIC: &[u8; 8] = b"LAYUPCKP";
const META_FILE: &str = "meta.json";
const STATE_FILE: &str = "state.bin";

/// Cross-step state of one worker's algorithm object, as captured by
/// [`crate::algorithms::WorkerAlgo::state_dict`]. Which fields are present
/// depends on the algorithm (DDP: optimizer only; GoSGD: optimizer + peer
/// RNG; SlowMo/CO2: optimizer + outer momentum; ...).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct AlgoState {
    /// per-layer optimizer moments
    pub opt: Option<OptState>,
    /// gossip peer-selection RNG stream (`Pcg32::state`)
    pub rng: Option<(u64, u64)>,
    /// SlowMo/CO2 outer-momentum state
    pub outer: Option<OuterState>,
}

/// SlowMo/CO2 slow-momentum buffers.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct OuterState {
    /// slow momentum buffer u (model-size)
    pub u: Vec<f32>,
    /// parameters right after the previous outer step
    pub x_prev: Vec<f32>,
}

/// Everything worker-local a resume needs.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct WorkerState {
    /// was the slot alive at snapshot time (a chaos-killed worker is saved
    /// dead; resume revives every slot, like restarting the job)
    pub alive: bool,
    /// completed-step counter at snapshot time
    pub steps_done: u64,
    /// data-loader cursor (training batches drawn)
    pub cursor: u64,
    /// push-sum weight
    pub weight: f32,
    /// algorithm state (optimizer moments, gossip RNG, outer momentum)
    pub algo: AlgoState,
}

/// One full training snapshot (see module docs for the on-disk layout).
/// (No `Debug`/`PartialEq`: [`InFlight`] payloads intentionally don't
/// implement them — compare fields, as the codec tests do.)
#[derive(Clone)]
pub struct Checkpoint {
    pub version: u32,
    pub model: String,
    /// canonical algorithm display name
    pub algorithm: String,
    pub workers: usize,
    pub seed: u64,
    /// every worker completed steps `< step`; resume starts here
    pub step: usize,
    /// wall seconds of training before the snapshot (curve continuity)
    pub elapsed_s: f64,
    /// membership epoch at snapshot time
    pub epoch: u64,
    /// per-worker model replicas (`params[w][layer][tensor]`)
    pub params: Vec<Vec<Vec<Vec<f32>>>>,
    /// per-worker, per-layer staleness-clock state (`clocks[w][layer]`),
    /// restored bit-identically on resume
    pub clocks: Vec<Vec<ClockStamp>>,
    pub workers_state: Vec<WorkerState>,
    /// quiesced fabric messages still riding the links
    pub in_flight: Vec<InFlight>,
    /// codec error-feedback residuals per directed link (empty for the
    /// dense codec) — the gradient mass the sparsifier still holds
    /// sender-side, without which a resume would silently destroy it
    pub residuals: Vec<ResidualState>,
    /// eval curve recorded before the snapshot
    pub curve: Vec<CurvePoint>,
    /// drift samples recorded before the snapshot
    pub drift: Vec<(u64, f64)>,
}

impl Checkpoint {
    /// Reject a resume into a session whose config does not match the run
    /// that produced the snapshot.
    pub fn check_compatible(
        &self,
        model: &str,
        algorithm: &str,
        workers: usize,
        seed: u64,
    ) -> Result<()> {
        if self.version != FORMAT_VERSION {
            bail!(
                "checkpoint format v{} is not supported (this build reads v{FORMAT_VERSION})",
                self.version
            );
        }
        if self.model != model || self.algorithm != algorithm {
            bail!(
                "checkpoint was taken from {}/{}, the session runs {model}/{algorithm}",
                self.model,
                self.algorithm
            );
        }
        if self.workers != workers {
            bail!(
                "checkpoint has {} workers, the session runs {workers}",
                self.workers
            );
        }
        if self.seed != seed {
            bail!(
                "checkpoint was taken at seed {}, the session runs seed {seed} \
                 (data streams would diverge; resume with the original seed)",
                self.seed
            );
        }
        Ok(())
    }
}

/// The subdirectory a periodic checkpoint at `step` is written to.
pub fn step_dir(dir: &Path, step: usize) -> std::path::PathBuf {
    dir.join(format!("step-{step:06}"))
}

/// Resolve a user-supplied resume path: either a checkpoint directory
/// itself (holds `meta.json`) or a parent directory of `step-XXXXXX`
/// checkpoints, in which case the latest one is picked.
pub fn resolve(dir: &Path) -> Result<std::path::PathBuf> {
    if dir.join(META_FILE).exists() {
        return Ok(dir.to_path_buf());
    }
    let mut best: Option<(u64, std::path::PathBuf)> = None;
    let entries = std::fs::read_dir(dir)
        .with_context(|| format!("reading checkpoint dir {}", dir.display()))?;
    for entry in entries {
        let path = entry?.path();
        let Some(name) = path.file_name().and_then(|n| n.to_str()) else {
            continue;
        };
        // compare the parsed step number, not the name: lexicographic order
        // misfiles steps past the zero-padding width (step-1000000 sorts
        // before step-999999)
        let Some(step) = name.strip_prefix("step-").and_then(|s| s.parse::<u64>().ok()) else {
            continue;
        };
        if path.join(META_FILE).exists()
            && best.as_ref().map(|&(b, _)| step > b).unwrap_or(true)
        {
            best = Some((step, path));
        }
    }
    best.map(|(_, p)| p).ok_or_else(|| {
        anyhow::anyhow!(
            "{} holds no checkpoint (no meta.json, no step-* subdirectory)",
            dir.display()
        )
    })
}

/// Write `ckpt` into `dir` (created if missing): `state.bin` first, then the
/// self-describing `meta.json` commit marker.
pub fn save(dir: &Path, ckpt: &Checkpoint) -> Result<()> {
    std::fs::create_dir_all(dir)
        .with_context(|| format!("creating checkpoint dir {}", dir.display()))?;
    let mut enc = Enc::default();
    encode(ckpt, &mut enc);
    std::fs::write(dir.join(STATE_FILE), &enc.buf)
        .with_context(|| format!("writing {}", dir.join(STATE_FILE).display()))?;
    let live = ckpt.workers_state.iter().filter(|w| w.alive).count();
    let meta = obj(vec![
        ("format", s(FORMAT_NAME)),
        ("format_version", num(ckpt.version as f64)),
        ("state_file", s(STATE_FILE)),
        ("model", s(&ckpt.model)),
        ("algorithm", s(&ckpt.algorithm)),
        ("workers", num(ckpt.workers as f64)),
        ("live_workers", num(live as f64)),
        ("seed", num(ckpt.seed as f64)),
        ("step", num(ckpt.step as f64)),
        ("elapsed_s", num(ckpt.elapsed_s)),
        ("membership_epoch", num(ckpt.epoch as f64)),
        ("in_flight_msgs", num(ckpt.in_flight.len() as f64)),
        // wire bytes of the quiesced traffic, through the same
        // Payload::encoded_len() that CommStats meters and SimFabric
        // serializes against — one byte-accounting source of truth
        (
            "in_flight_bytes",
            num(ckpt.in_flight.iter().map(|m| m.payload.encoded_len() as f64).sum()),
        ),
        ("codec_residual_links", num(ckpt.residuals.len() as f64)),
        ("curve_points", num(ckpt.curve.len() as f64)),
        ("drift_samples", num(ckpt.drift.len() as f64)),
    ]);
    std::fs::write(dir.join(META_FILE), meta.dump())
        .with_context(|| format!("writing {}", dir.join(META_FILE).display()))?;
    Ok(())
}

/// Load a checkpoint directory written by [`save`].
pub fn load(dir: &Path) -> Result<Checkpoint> {
    let meta_path = dir.join(META_FILE);
    let meta_text = std::fs::read_to_string(&meta_path)
        .with_context(|| format!("reading {} (incomplete checkpoint?)", meta_path.display()))?;
    let meta = Json::parse(&meta_text).context("parsing checkpoint meta.json")?;
    let format = meta.get("format")?.as_str()?.to_string();
    if format != FORMAT_NAME {
        bail!("{} is not a layup checkpoint (format {format:?})", dir.display());
    }
    let version = meta.get("format_version")?.as_usize()? as u32;
    if version != FORMAT_VERSION {
        bail!("checkpoint format v{version} is not supported (this build reads v{FORMAT_VERSION})");
    }
    let state_file = meta.get("state_file")?.as_str()?.to_string();
    let bytes = std::fs::read(dir.join(&state_file))
        .with_context(|| format!("reading {}", dir.join(&state_file).display()))?;
    let ckpt = decode(&bytes).context("decoding checkpoint state.bin")?;
    // the meta header must agree with the binary payload (self-description
    // is only useful if it is truthful)
    if ckpt.step != meta.get("step")?.as_usize()? || ckpt.workers != meta.get("workers")?.as_usize()?
    {
        bail!("checkpoint meta.json disagrees with state.bin (corrupt checkpoint)");
    }
    Ok(ckpt)
}

// ---------------------------------------------------------------------------
// binary codec
// ---------------------------------------------------------------------------

#[derive(Default)]
struct Enc {
    buf: Vec<u8>,
}

impl Enc {
    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }
    fn bool(&mut self, v: bool) {
        self.u8(u8::from(v));
    }
    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn str(&mut self, v: &str) {
        self.u64(v.len() as u64);
        self.buf.extend_from_slice(v.as_bytes());
    }
    fn f32s(&mut self, v: &[f32]) {
        self.u64(v.len() as u64);
        for &x in v {
            self.f32(x);
        }
    }
    fn usizes(&mut self, v: &[usize]) {
        self.u64(v.len() as u64);
        for &x in v {
            self.u64(x as u64);
        }
    }
}

struct Dec<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Dec<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.i + n > self.b.len() {
            bail!("checkpoint truncated at byte {} (wanted {n} more)", self.i);
        }
        let out = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(out)
    }
    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }
    fn bool(&mut self) -> Result<bool> {
        Ok(self.u8()? != 0)
    }
    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn len(&mut self) -> Result<usize> {
        let n = self.u64()?;
        // a corrupt length must error, not OOM the process
        if n > (self.b.len() - self.i) as u64 {
            bail!("checkpoint declares {n} elements but only {} bytes remain", self.b.len() - self.i);
        }
        Ok(n as usize)
    }
    fn str(&mut self) -> Result<String> {
        let n = self.len()?;
        String::from_utf8(self.take(n)?.to_vec()).context("checkpoint string not UTF-8")
    }
    fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.len()?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.f32()?);
        }
        Ok(out)
    }
    fn usizes(&mut self) -> Result<Vec<usize>> {
        let n = self.len()?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.u64()? as usize);
        }
        Ok(out)
    }
}

fn encode(ckpt: &Checkpoint, e: &mut Enc) {
    e.buf.extend_from_slice(MAGIC);
    e.u32(ckpt.version);
    e.str(&ckpt.model);
    e.str(&ckpt.algorithm);
    e.u64(ckpt.workers as u64);
    e.u64(ckpt.seed);
    e.u64(ckpt.step as u64);
    e.f64(ckpt.elapsed_s);
    e.u64(ckpt.epoch);
    e.u64(ckpt.params.len() as u64);
    for worker in &ckpt.params {
        e.u64(worker.len() as u64);
        for layer in worker {
            e.u64(layer.len() as u64);
            for tensor in layer {
                e.f32s(tensor);
            }
        }
    }
    e.u64(ckpt.clocks.len() as u64);
    for worker in &ckpt.clocks {
        e.u64(worker.len() as u64);
        for st in worker {
            encode_stamp(st, e);
        }
    }
    e.u64(ckpt.workers_state.len() as u64);
    for w in &ckpt.workers_state {
        e.bool(w.alive);
        e.u64(w.steps_done);
        e.u64(w.cursor);
        e.f32(w.weight);
        encode_algo(&w.algo, e);
    }
    e.u64(ckpt.in_flight.len() as u64);
    for m in &ckpt.in_flight {
        e.u64(m.from as u64);
        e.u64(m.to as u64);
        e.u64(m.step as u64);
        e.f64(m.remaining_s);
        encode_payload(&m.payload, e);
    }
    e.u64(ckpt.residuals.len() as u64);
    for r in &ckpt.residuals {
        e.u64(r.from as u64);
        e.u64(r.to as u64);
        e.u64(r.streams.len() as u64);
        for (key, vals) in &r.streams {
            e.u8(key.tag);
            e.u32(key.layer);
            e.u32(key.tensor);
            e.f32s(vals);
        }
    }
    e.u64(ckpt.curve.len() as u64);
    for p in &ckpt.curve {
        e.u64(p.step as u64);
        e.f64(p.time_s);
        e.f64(p.loss);
        e.f64(p.accuracy);
    }
    e.u64(ckpt.drift.len() as u64);
    for &(step, v) in &ckpt.drift {
        e.u64(step);
        e.f64(v);
    }
}

fn decode(bytes: &[u8]) -> Result<Checkpoint> {
    let mut d = Dec { b: bytes, i: 0 };
    if d.take(MAGIC.len())? != MAGIC {
        bail!("bad checkpoint magic (not a layup state.bin)");
    }
    let version = d.u32()?;
    if version != FORMAT_VERSION {
        bail!("checkpoint format v{version} is not supported (this build reads v{FORMAT_VERSION})");
    }
    let model = d.str()?;
    let algorithm = d.str()?;
    let workers = d.u64()? as usize;
    let seed = d.u64()?;
    let step = d.u64()? as usize;
    let elapsed_s = d.f64()?;
    let epoch = d.u64()?;
    let n_workers_params = d.len()?;
    let mut params = Vec::with_capacity(n_workers_params);
    for _ in 0..n_workers_params {
        let n_layers = d.len()?;
        let mut worker = Vec::with_capacity(n_layers);
        for _ in 0..n_layers {
            let n_tensors = d.len()?;
            let mut layer = Vec::with_capacity(n_tensors);
            for _ in 0..n_tensors {
                layer.push(d.f32s()?);
            }
            worker.push(layer);
        }
        params.push(worker);
    }
    let n_clock_workers = d.len()?;
    let mut clocks = Vec::with_capacity(n_clock_workers);
    for _ in 0..n_clock_workers {
        let n_layers = d.len()?;
        let mut worker = Vec::with_capacity(n_layers);
        for _ in 0..n_layers {
            worker.push(decode_stamp(&mut d)?);
        }
        clocks.push(worker);
    }
    let n_states = d.len()?;
    let mut workers_state = Vec::with_capacity(n_states);
    for _ in 0..n_states {
        workers_state.push(WorkerState {
            alive: d.bool()?,
            steps_done: d.u64()?,
            cursor: d.u64()?,
            weight: d.f32()?,
            algo: decode_algo(&mut d)?,
        });
    }
    let n_in_flight = d.len()?;
    let mut in_flight = Vec::with_capacity(n_in_flight);
    for _ in 0..n_in_flight {
        in_flight.push(InFlight {
            from: d.u64()? as usize,
            to: d.u64()? as usize,
            step: d.u64()? as usize,
            remaining_s: d.f64()?,
            payload: decode_payload(&mut d)?,
        });
    }
    let n_residuals = d.len()?;
    let mut residuals = Vec::with_capacity(n_residuals);
    for _ in 0..n_residuals {
        let from = d.u64()? as usize;
        let to = d.u64()? as usize;
        let n_streams = d.len()?;
        let mut streams = Vec::with_capacity(n_streams);
        for _ in 0..n_streams {
            let key = StreamKey { tag: d.u8()?, layer: d.u32()?, tensor: d.u32()? };
            streams.push((key, d.f32s()?));
        }
        residuals.push(ResidualState { from, to, streams });
    }
    let n_curve = d.len()?;
    let mut curve = Vec::with_capacity(n_curve);
    for _ in 0..n_curve {
        curve.push(CurvePoint {
            step: d.u64()? as usize,
            time_s: d.f64()?,
            loss: d.f64()?,
            accuracy: d.f64()?,
        });
    }
    let n_drift = d.len()?;
    let mut drift = Vec::with_capacity(n_drift);
    for _ in 0..n_drift {
        drift.push((d.u64()?, d.f64()?));
    }
    if d.i != d.b.len() {
        bail!("checkpoint has {} trailing bytes", d.b.len() - d.i);
    }
    // the per-worker arrays must match the declared worker count — a
    // mismatch would otherwise surface as an engine panic or, worse, a
    // silently partial restore (zip stopping at the shorter side)
    if params.len() != workers || workers_state.len() != workers || clocks.len() != workers {
        bail!(
            "checkpoint declares {workers} workers but carries {} replicas, {} clock sets \
             and {} worker states",
            params.len(),
            clocks.len(),
            workers_state.len()
        );
    }
    // each worker's clock list must cover exactly its replica's layers — a
    // shorter list would otherwise restore partially (zip stops early)
    for (w, (p, c)) in params.iter().zip(&clocks).enumerate() {
        if p.len() != c.len() {
            bail!(
                "checkpoint worker {w} carries {} layers but {} layer clocks",
                p.len(),
                c.len()
            );
        }
    }
    Ok(Checkpoint {
        version,
        model,
        algorithm,
        workers,
        seed,
        step,
        elapsed_s,
        epoch,
        params,
        clocks,
        workers_state,
        in_flight,
        residuals,
        curve,
        drift,
    })
}

fn encode_stamp(st: &ClockStamp, e: &mut Enc) {
    e.u32(st.worker);
    e.u64(st.step);
    e.u64(st.version);
}

fn decode_stamp(d: &mut Dec) -> Result<ClockStamp> {
    Ok(ClockStamp { worker: d.u32()?, step: d.u64()?, version: d.u64()? })
}

fn encode_algo(a: &AlgoState, e: &mut Enc) {
    match &a.opt {
        None => e.bool(false),
        Some(opt) => {
            e.bool(true);
            e.u64(opt.layers.len() as u64);
            for l in &opt.layers {
                e.u64(l.m.len() as u64);
                for buf in &l.m {
                    e.f32s(buf);
                }
                e.u64(l.v.len() as u64);
                for buf in &l.v {
                    e.f32s(buf);
                }
                e.u64(l.t);
            }
        }
    }
    match a.rng {
        None => e.bool(false),
        Some((state, inc)) => {
            e.bool(true);
            e.u64(state);
            e.u64(inc);
        }
    }
    match &a.outer {
        None => e.bool(false),
        Some(o) => {
            e.bool(true);
            e.f32s(&o.u);
            e.f32s(&o.x_prev);
        }
    }
}

fn decode_algo(d: &mut Dec) -> Result<AlgoState> {
    let opt = if d.bool()? {
        let n_layers = d.len()?;
        let mut layers = Vec::with_capacity(n_layers);
        for _ in 0..n_layers {
            let n_m = d.len()?;
            let mut m = Vec::with_capacity(n_m);
            for _ in 0..n_m {
                m.push(d.f32s()?);
            }
            let n_v = d.len()?;
            let mut v = Vec::with_capacity(n_v);
            for _ in 0..n_v {
                v.push(d.f32s()?);
            }
            layers.push(LayerOptState { m, v, t: d.u64()? });
        }
        Some(OptState { layers })
    } else {
        None
    };
    let rng = if d.bool()? { Some((d.u64()?, d.u64()?)) } else { None };
    let outer = if d.bool()? {
        Some(OuterState { u: d.f32s()?, x_prev: d.f32s()? })
    } else {
        None
    };
    Ok(AlgoState { opt, rng, outer })
}

fn encode_payload(p: &Payload, e: &mut Enc) {
    match p {
        Payload::LayerPush { layer, open, values, stamp, tau } => {
            e.u8(0);
            e.u64(*layer as u64);
            match open {
                None => e.bool(false),
                Some(w) => {
                    e.bool(true);
                    e.f32(*w);
                }
            }
            e.u64(values.len() as u64);
            for v in values.iter() {
                e.f32s(v);
            }
            encode_stamp(stamp, e);
            e.u64(*tau);
        }
        Payload::ModelPush { w_in, values } => {
            e.u8(1);
            e.f32(*w_in);
            e.u64(values.len() as u64);
            for layer in values.iter() {
                e.u64(layer.len() as u64);
                for v in layer {
                    e.f32s(v);
                }
            }
        }
        Payload::PairAverage { flat, reply } => {
            e.u8(2);
            e.bool(*reply);
            e.f32s(flat);
        }
        Payload::GradShare { set } => {
            e.u8(3);
            e.u64(set.len() as u64);
            for layer in set.iter() {
                e.u64(layer.len() as u64);
                for t in layer {
                    e.usizes(&t.shape);
                    e.f32s(&t.data);
                }
            }
        }
        Payload::ParamShare { flat } => {
            e.u8(4);
            e.f32s(flat);
        }
        Payload::GradPush { layer, grads, x_then, stamp } => {
            e.u8(5);
            e.u64(*layer as u64);
            e.u64(grads.len() as u64);
            for g in grads.iter() {
                e.f32s(g);
            }
            match x_then {
                None => e.bool(false),
                Some(xt) => {
                    e.bool(true);
                    e.u64(xt.len() as u64);
                    for v in xt.iter() {
                        e.f32s(v);
                    }
                }
            }
            encode_stamp(stamp, e);
        }
        Payload::ParamPull { layer, values, stamp } => {
            e.u8(6);
            e.u64(*layer as u64);
            e.u64(values.len() as u64);
            for v in values.iter() {
                e.f32s(v);
            }
            encode_stamp(stamp, e);
        }
        Payload::Compressed(c) => {
            e.u8(7);
            let (tag, k) = c.spec.wire_tag();
            e.u8(tag);
            e.u32(k);
            e.f32(c.shipped_w);
            e.bool(c.droppable);
            e.u64(c.blob.len() as u64);
            e.buf.extend_from_slice(&c.blob);
        }
        Payload::StepFrame { open, entries } => {
            e.u8(8);
            match open {
                None => e.bool(false),
                Some(w) => {
                    e.bool(true);
                    e.f32(*w);
                }
            }
            e.u64(entries.len() as u64);
            for entry in entries.iter() {
                e.u64(entry.layer as u64);
                encode_stamp(&entry.stamp, e);
                e.u64(entry.tau);
                e.u64(entry.values.len() as u64);
                for v in entry.values.iter() {
                    e.f32s(v);
                }
            }
        }
    }
}

fn decode_payload(d: &mut Dec) -> Result<Payload> {
    Ok(match d.u8()? {
        0 => {
            let layer = d.u64()? as usize;
            let open = if d.bool()? { Some(d.f32()?) } else { None };
            let n = d.len()?;
            let mut values = Vec::with_capacity(n);
            for _ in 0..n {
                values.push(d.f32s()?);
            }
            let stamp = decode_stamp(d)?;
            let tau = d.u64()?;
            Payload::LayerPush { layer, open, values: Arc::new(values), stamp, tau }
        }
        1 => {
            let w_in = d.f32()?;
            let n_layers = d.len()?;
            let mut values = Vec::with_capacity(n_layers);
            for _ in 0..n_layers {
                let n_tensors = d.len()?;
                let mut layer = Vec::with_capacity(n_tensors);
                for _ in 0..n_tensors {
                    layer.push(d.f32s()?);
                }
                values.push(layer);
            }
            Payload::ModelPush { w_in, values: Arc::new(values) }
        }
        2 => {
            let reply = d.bool()?;
            Payload::PairAverage { flat: Arc::new(d.f32s()?), reply }
        }
        3 => {
            let n_layers = d.len()?;
            let mut set = Vec::with_capacity(n_layers);
            for _ in 0..n_layers {
                let n_params = d.len()?;
                let mut layer = Vec::with_capacity(n_params);
                for _ in 0..n_params {
                    let shape = d.usizes()?;
                    let data = d.f32s()?;
                    if shape.iter().product::<usize>() != data.len() {
                        bail!("checkpoint GradShare tensor shape/data mismatch");
                    }
                    layer.push(Tensor::from_vec(&shape, data));
                }
                set.push(layer);
            }
            Payload::GradShare { set: Arc::new(set) }
        }
        4 => Payload::ParamShare { flat: Arc::new(d.f32s()?) },
        5 => {
            let layer = d.u64()? as usize;
            let n = d.len()?;
            let mut grads = Vec::with_capacity(n);
            for _ in 0..n {
                grads.push(d.f32s()?);
            }
            let x_then = if d.bool()? {
                let n = d.len()?;
                let mut xt = Vec::with_capacity(n);
                for _ in 0..n {
                    xt.push(d.f32s()?);
                }
                Some(Arc::new(xt))
            } else {
                None
            };
            let stamp = decode_stamp(d)?;
            Payload::GradPush { layer, grads: Arc::new(grads), x_then, stamp }
        }
        6 => {
            let layer = d.u64()? as usize;
            let n = d.len()?;
            let mut values = Vec::with_capacity(n);
            for _ in 0..n {
                values.push(d.f32s()?);
            }
            let stamp = decode_stamp(d)?;
            Payload::ParamPull { layer, values: Arc::new(values), stamp }
        }
        7 => {
            let spec = CodecSpec::from_wire(d.u8()?, d.u32()?)?;
            let shipped_w = d.f32()?;
            let droppable = d.bool()?;
            let n = d.len()?;
            let blob = Arc::new(d.take(n)?.to_vec());
            Payload::Compressed(Compressed { spec, shipped_w, droppable, blob })
        }
        8 => {
            let open = if d.bool()? { Some(d.f32()?) } else { None };
            let ne = d.len()?;
            let mut entries = Vec::with_capacity(ne);
            for _ in 0..ne {
                let layer = d.u64()? as usize;
                let stamp = decode_stamp(d)?;
                let tau = d.u64()?;
                let nt = d.len()?;
                let mut values = Vec::with_capacity(nt);
                for _ in 0..nt {
                    values.push(d.f32s()?);
                }
                entries.push(crate::comm::FrameEntry {
                    layer,
                    stamp,
                    tau,
                    values: Arc::new(values),
                });
            }
            Payload::StepFrame { open, entries: Arc::new(entries) }
        }
        tag => bail!("unknown checkpoint payload tag {tag}"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Checkpoint {
        Checkpoint {
            version: FORMAT_VERSION,
            model: "mlpnet18".into(),
            algorithm: "LayUp".into(),
            workers: 2,
            seed: 42,
            step: 10,
            elapsed_s: 1.5,
            epoch: 3,
            params: vec![
                vec![vec![vec![1.0, -2.5], vec![0.125]], vec![vec![3.0]]],
                vec![vec![vec![0.5, 0.5], vec![-1.0]], vec![vec![f32::MIN_POSITIVE]]],
            ],
            clocks: vec![
                vec![
                    ClockStamp { worker: 0, step: 9, version: 40 },
                    ClockStamp { worker: 1, step: 8, version: 12 },
                ],
                vec![
                    ClockStamp { worker: 1, step: 7, version: 33 },
                    ClockStamp { worker: 0, step: 9, version: 41 },
                ],
            ],
            workers_state: vec![
                WorkerState {
                    alive: true,
                    steps_done: 10,
                    cursor: 10,
                    weight: 0.5,
                    algo: AlgoState {
                        opt: Some(OptState {
                            layers: vec![LayerOptState {
                                m: vec![vec![0.1, 0.2], vec![0.3]],
                                v: Vec::new(),
                                t: 10,
                            }],
                        }),
                        rng: Some((123, 457)),
                        outer: None,
                    },
                },
                WorkerState {
                    alive: false,
                    steps_done: 7,
                    cursor: 7,
                    weight: 0.0,
                    algo: AlgoState {
                        opt: None,
                        rng: None,
                        outer: Some(OuterState { u: vec![1.0], x_prev: vec![2.0] }),
                    },
                },
            ],
            in_flight: vec![
                InFlight {
                    from: 0,
                    to: 1,
                    step: 9,
                    remaining_s: 0.004,
                    payload: Payload::LayerPush {
                        layer: 1,
                        open: Some(0.25),
                        values: Arc::new(vec![vec![9.0, 8.0]]),
                        stamp: ClockStamp { worker: 0, step: 9, version: 40 },
                        tau: 3,
                    },
                },
                InFlight {
                    from: 1,
                    to: 0,
                    step: 8,
                    remaining_s: 0.0,
                    payload: Payload::GradShare {
                        set: Arc::new(vec![vec![Tensor::from_vec(&[2, 1], vec![1.0, 2.0])]]),
                    },
                },
                InFlight {
                    from: 0,
                    to: 1,
                    step: 9,
                    remaining_s: 0.001,
                    payload: Payload::GradPush {
                        layer: 0,
                        grads: Arc::new(vec![vec![0.5, -0.5], vec![2.0]]),
                        x_then: Some(Arc::new(vec![vec![1.0, 1.0], vec![-1.0]])),
                        stamp: ClockStamp { worker: 0, step: 9, version: 40 },
                    },
                },
                InFlight {
                    from: 1,
                    to: 0,
                    step: 9,
                    remaining_s: 0.002,
                    payload: Payload::ParamPull {
                        layer: 1,
                        values: Arc::new(vec![vec![4.0]]),
                        stamp: ClockStamp { worker: 1, step: 9, version: 44 },
                    },
                },
                InFlight {
                    from: 0,
                    to: 1,
                    step: 10,
                    remaining_s: 0.003,
                    payload: Payload::Compressed(Compressed {
                        spec: CodecSpec::TopK { k: 4 },
                        shipped_w: 0.125,
                        droppable: true,
                        blob: Arc::new(vec![3, 0, 0, 0, 0, 7, 255]),
                    }),
                },
                InFlight {
                    from: 1,
                    to: 0,
                    step: 10,
                    // a partial frame drained out of a FrameBuilder at the
                    // quiesce (v5): zero remaining delay, per-entry stamps
                    remaining_s: 0.0,
                    payload: Payload::StepFrame {
                        open: Some(0.0625),
                        entries: Arc::new(vec![
                            crate::comm::FrameEntry {
                                layer: 1,
                                stamp: ClockStamp { worker: 1, step: 10, version: 45 },
                                tau: 2,
                                values: Arc::new(vec![vec![6.0]]),
                            },
                            crate::comm::FrameEntry {
                                layer: 0,
                                stamp: ClockStamp { worker: 1, step: 10, version: 46 },
                                tau: 0,
                                values: Arc::new(vec![vec![1.5, -1.5], vec![0.25]]),
                            },
                        ]),
                    },
                },
            ],
            residuals: vec![ResidualState {
                from: 0,
                to: 1,
                streams: vec![
                    (StreamKey { tag: 3, layer: 0, tensor: 0 }, vec![0.5, -0.25]),
                    (StreamKey { tag: 5, layer: 1, tensor: 0 }, vec![1.5]),
                ],
            }],
            curve: vec![CurvePoint { step: 5, time_s: 0.7, loss: 1.25, accuracy: 0.5 }],
            drift: vec![(4, 0.125)],
        }
    }

    fn payloads_eq(a: &Payload, b: &Payload) -> bool {
        match (a, b) {
            (
                Payload::LayerPush { layer: la, open: oa, values: va, stamp: sa, tau: ta },
                Payload::LayerPush { layer: lb, open: ob, values: vb, stamp: sb, tau: tb },
            ) => la == lb && oa == ob && va == vb && sa == sb && ta == tb,
            (
                Payload::ModelPush { w_in: wa, values: va },
                Payload::ModelPush { w_in: wb, values: vb },
            ) => wa == wb && va == vb,
            (
                Payload::PairAverage { flat: fa, reply: ra },
                Payload::PairAverage { flat: fb, reply: rb },
            ) => fa == fb && ra == rb,
            (Payload::GradShare { set: sa }, Payload::GradShare { set: sb }) => sa == sb,
            (Payload::ParamShare { flat: fa }, Payload::ParamShare { flat: fb }) => fa == fb,
            (
                Payload::GradPush { layer: la, grads: ga, x_then: xa, stamp: sa },
                Payload::GradPush { layer: lb, grads: gb, x_then: xb, stamp: sb },
            ) => la == lb && ga == gb && xa == xb && sa == sb,
            (
                Payload::ParamPull { layer: la, values: va, stamp: sa },
                Payload::ParamPull { layer: lb, values: vb, stamp: sb },
            ) => la == lb && va == vb && sa == sb,
            (Payload::Compressed(ca), Payload::Compressed(cb)) => {
                ca.spec == cb.spec
                    && ca.shipped_w.to_bits() == cb.shipped_w.to_bits()
                    && ca.droppable == cb.droppable
                    && ca.blob == cb.blob
            }
            (
                Payload::StepFrame { open: oa, entries: ea },
                Payload::StepFrame { open: ob, entries: eb },
            ) => {
                oa == ob
                    && ea.len() == eb.len()
                    && ea.iter().zip(eb.iter()).all(|(a, b)| {
                        a.layer == b.layer
                            && a.stamp == b.stamp
                            && a.tau == b.tau
                            && a.values == b.values
                    })
            }
            _ => false,
        }
    }

    #[test]
    fn save_load_roundtrip_is_bit_exact() {
        let dir = std::env::temp_dir().join(format!("layup-ckpt-test-{}", std::process::id()));
        let ckpt = sample();
        save(&dir, &ckpt).unwrap();
        let back = load(&dir).unwrap();
        assert_eq!(back.model, ckpt.model);
        assert_eq!(back.algorithm, ckpt.algorithm);
        assert_eq!(back.workers, ckpt.workers);
        assert_eq!(back.seed, ckpt.seed);
        assert_eq!(back.step, ckpt.step);
        assert_eq!(back.elapsed_s.to_bits(), ckpt.elapsed_s.to_bits());
        assert_eq!(back.epoch, ckpt.epoch);
        assert_eq!(back.params, ckpt.params);
        assert_eq!(back.clocks, ckpt.clocks, "LayerClock state survives bit-identically");
        assert_eq!(back.workers_state, ckpt.workers_state);
        assert_eq!(back.in_flight.len(), ckpt.in_flight.len());
        for (a, b) in back.in_flight.iter().zip(&ckpt.in_flight) {
            assert_eq!((a.from, a.to, a.step), (b.from, b.to, b.step));
            assert_eq!(a.remaining_s.to_bits(), b.remaining_s.to_bits());
            assert!(payloads_eq(&a.payload, &b.payload));
        }
        assert_eq!(back.residuals, ckpt.residuals, "codec residuals survive bit-identically");
        assert_eq!(back.curve.len(), 1);
        assert_eq!(back.curve[0].loss.to_bits(), ckpt.curve[0].loss.to_bits());
        assert_eq!(back.drift, ckpt.drift);
        // meta.json is a truthful self-description
        let meta =
            Json::parse(&std::fs::read_to_string(dir.join(META_FILE)).unwrap()).unwrap();
        assert_eq!(meta.get("format").unwrap().as_str().unwrap(), FORMAT_NAME);
        assert_eq!(meta.get("step").unwrap().as_usize().unwrap(), 10);
        assert_eq!(meta.get("live_workers").unwrap().as_usize().unwrap(), 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corrupt_or_foreign_inputs_are_rejected() {
        assert!(decode(b"not a checkpoint").is_err());
        let mut enc = Enc::default();
        encode(&sample(), &mut enc);
        // truncation anywhere must surface as an error, not a panic
        for cut in [8, 12, 40, enc.buf.len() - 1] {
            assert!(decode(&enc.buf[..cut]).is_err(), "cut at {cut}");
        }
        // trailing garbage is rejected too
        let mut long = enc.buf.clone();
        long.push(0);
        assert!(decode(&long).is_err());
        // a bad version is rejected up front
        let mut bad = enc.buf.clone();
        bad[8] = 99;
        assert!(decode(&bad).is_err());
    }

    #[test]
    fn compatibility_gate_matches_run_identity() {
        let ckpt = sample();
        ckpt.check_compatible("mlpnet18", "LayUp", 2, 42).unwrap();
        assert!(ckpt.check_compatible("gpt_mini", "LayUp", 2, 42).is_err());
        assert!(ckpt.check_compatible("mlpnet18", "DDP", 2, 42).is_err());
        assert!(ckpt.check_compatible("mlpnet18", "LayUp", 3, 42).is_err());
        assert!(ckpt.check_compatible("mlpnet18", "LayUp", 2, 7).is_err());
    }
}
