//! Versioned worker membership: which of a run's worker slots are alive.
//!
//! The seed-era cluster had a fixed worker count for the life of a run; the
//! resilience subsystem makes membership *elastic*: a slot transitions
//! dead/alive as the chaos supervisor tears workers down and respawns them,
//! and every transition bumps a monotone **epoch** so long-running readers
//! (barriers, collect loops, gossip peer pickers) can cheaply detect that
//! the world changed. Capacity is bounded by the initial worker count — a
//! "join" re-activates a slot (the TorchElastic max-world-size model), it
//! does not grow the parameter-store vectors mid-run.
//!
//! One `Membership` is shared by [`crate::coordinator::Shared`] and the
//! communication fabric's [`crate::comm::FabricCore`], so transports and
//! algorithms agree on liveness.

use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};

/// How collective (barrier) algorithms react to a dead peer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RecoveryPolicy {
    /// Hold the collective at the barrier until the worker rejoins (the
    /// DDP-stalls-on-failure behaviour the fault-tolerance figure shows);
    /// the supervisor reports a stall if the worker never comes back.
    Stall,
    /// Shrink the collective to the live workers: barriers count live slots
    /// and all-reduces average over live contributors only.
    Shrink,
}

impl RecoveryPolicy {
    /// Parse a CLI / TOML spelling.
    pub fn parse(s: &str) -> anyhow::Result<RecoveryPolicy> {
        match s.to_ascii_lowercase().as_str() {
            "stall" | "stall-and-rejoin" => Ok(RecoveryPolicy::Stall),
            "shrink" => Ok(RecoveryPolicy::Shrink),
            other => anyhow::bail!("unknown recovery policy {other:?} (expected stall or shrink)"),
        }
    }

    /// Short name for logs and summaries.
    pub fn name(&self) -> &'static str {
        match self {
            RecoveryPolicy::Stall => "stall",
            RecoveryPolicy::Shrink => "shrink",
        }
    }
}

/// Shared, lock-free membership table (see module docs).
pub struct Membership {
    /// bumped on every alive/dead transition
    epoch: AtomicU64,
    alive: Vec<AtomicBool>,
    /// 0 = Stall, 1 = Shrink (fixed per run, set before workers spawn)
    policy: AtomicU32,
    /// set by the supervisor when a Stall-policy collective waited past the
    /// stall timeout for a worker that is never coming back
    stalled: AtomicBool,
    crashes: AtomicU64,
    joins: AtomicU64,
}

impl Membership {
    /// Fresh membership: all `m` slots alive, epoch 0, Stall policy.
    pub fn new(m: usize) -> Membership {
        Membership {
            epoch: AtomicU64::new(0),
            alive: (0..m).map(|_| AtomicBool::new(true)).collect(),
            policy: AtomicU32::new(0),
            stalled: AtomicBool::new(false),
            crashes: AtomicU64::new(0),
            joins: AtomicU64::new(0),
        }
    }

    /// Slot capacity (the run's initial worker count).
    pub fn workers(&self) -> usize {
        self.alive.len()
    }

    pub fn alive(&self, w: usize) -> bool {
        self.alive[w].load(Ordering::Acquire)
    }

    pub fn live_count(&self) -> usize {
        self.alive.iter().filter(|a| a.load(Ordering::Acquire)).count()
    }

    /// Lowest-id live worker, if any (checkpoint writer / respawn donor).
    pub fn first_live(&self) -> Option<usize> {
        (0..self.workers()).find(|&w| self.alive(w))
    }

    /// Monotone membership version; any change bumps it.
    pub fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::Acquire)
    }

    /// Mark `w` dead. Returns `false` (and does nothing) if it already was.
    pub fn mark_dead(&self, w: usize) -> bool {
        if self.alive[w].swap(false, Ordering::AcqRel) {
            self.epoch.fetch_add(1, Ordering::AcqRel);
            self.crashes.fetch_add(1, Ordering::Relaxed);
            true
        } else {
            false
        }
    }

    /// Mark `w` alive again (a respawned worker rejoining). Returns `false`
    /// if it already was.
    pub fn mark_alive(&self, w: usize) -> bool {
        if !self.alive[w].swap(true, Ordering::AcqRel) {
            self.epoch.fetch_add(1, Ordering::AcqRel);
            self.joins.fetch_add(1, Ordering::Relaxed);
            true
        } else {
            false
        }
    }

    pub fn policy(&self) -> RecoveryPolicy {
        if self.policy.load(Ordering::Relaxed) == 1 {
            RecoveryPolicy::Shrink
        } else {
            RecoveryPolicy::Stall
        }
    }

    /// Select the run's recovery policy (called once, before workers spawn).
    pub fn set_policy(&self, policy: RecoveryPolicy) {
        let v = match policy {
            RecoveryPolicy::Stall => 0,
            RecoveryPolicy::Shrink => 1,
        };
        self.policy.store(v, Ordering::Relaxed);
    }

    pub fn stalled(&self) -> bool {
        self.stalled.load(Ordering::Relaxed)
    }

    pub fn mark_stalled(&self) {
        self.stalled.store(true, Ordering::Relaxed);
    }

    /// Total dead transitions (summary stats).
    pub fn crash_count(&self) -> u64 {
        self.crashes.load(Ordering::Relaxed)
    }

    /// Total rejoin transitions (summary stats).
    pub fn join_count(&self) -> u64 {
        self.joins.load(Ordering::Relaxed)
    }

    /// Checkpoint view of the alive flags.
    pub fn alive_flags(&self) -> Vec<bool> {
        (0..self.workers()).map(|w| self.alive(w)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transitions_bump_the_epoch_once_each() {
        let m = Membership::new(3);
        assert_eq!(m.live_count(), 3);
        assert_eq!(m.epoch(), 0);
        assert!(m.mark_dead(1));
        assert!(!m.mark_dead(1), "double-kill is a no-op");
        assert_eq!(m.epoch(), 1);
        assert_eq!(m.live_count(), 2);
        assert!(!m.alive(1));
        assert_eq!(m.first_live(), Some(0));
        assert!(m.mark_alive(1));
        assert!(!m.mark_alive(1));
        assert_eq!(m.epoch(), 2);
        assert_eq!((m.crash_count(), m.join_count()), (1, 1));
        assert_eq!(m.alive_flags(), vec![true, true, true]);
    }

    #[test]
    fn policy_parse_and_roundtrip() {
        let m = Membership::new(2);
        assert_eq!(m.policy(), RecoveryPolicy::Stall);
        m.set_policy(RecoveryPolicy::Shrink);
        assert_eq!(m.policy(), RecoveryPolicy::Shrink);
        assert_eq!(RecoveryPolicy::parse("stall").unwrap(), RecoveryPolicy::Stall);
        assert_eq!(RecoveryPolicy::parse("Shrink").unwrap(), RecoveryPolicy::Shrink);
        assert!(RecoveryPolicy::parse("panic").is_err());
        assert_eq!(RecoveryPolicy::Shrink.name(), "shrink");
    }

    #[test]
    fn stall_flag_latches() {
        let m = Membership::new(2);
        assert!(!m.stalled());
        m.mark_stalled();
        assert!(m.stalled());
    }
}
