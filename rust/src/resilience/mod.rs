//! Resilience subsystem: checkpoint/restore, chaos injection and elastic
//! worker membership.
//!
//! The paper's central claim is robustness: layer-wise partial updates
//! tolerate delays and throughput differences that stall synchronous DDP.
//! This subsystem extends that robustness from *slow* workers to *dead* and
//! *joining* workers, and makes it measurable:
//!
//! * [`checkpoint`] — versioned, self-describing snapshots of full training
//!   state (model replicas, optimizer moments, RNG streams, data cursors,
//!   push-sum weights, quiesced in-flight fabric traffic, membership and the
//!   learning curve), with the save→load→continue ≡ uninterrupted invariant
//!   pinned by the resume-parity tests. Wired in via
//!   `SessionBuilder::checkpoint_every(..)` / `Session::resume_from(..)`,
//!   the `[checkpoint]` config section and the `layup train --resume` /
//!   `--ckpt-every` CLI flags.
//! * [`chaos`] — seeded crash/restart schedules ([`chaos::FaultPlan`]) the
//!   coordinator engine executes by tearing down and respawning worker
//!   threads, with per-algorithm recovery: gossip algorithms re-enter from a
//!   live peer's current parameters (push-sum weight donated by the peer so
//!   mass is conserved), collective algorithms either stall-and-rejoin or
//!   shrink the collective ([`membership::RecoveryPolicy`]).
//! * [`membership`] — the versioned-epoch membership table `Shared` and the
//!   communication fabric consult, making worker count elastic within the
//!   run's slot capacity.
//!
//! Fault timelines surface as typed events
//! (`TrainEvent::{WorkerCrashed, WorkerJoined, CheckpointSaved, Resumed}`)
//! and in `RunStats::recovery`; `benches/fig_fault_tolerance.rs` turns them
//! into the loss-vs-wallclock fault-tolerance figure.

pub mod chaos;
pub mod checkpoint;
pub mod membership;

pub use chaos::{ChaosRuntime, Fault, FaultPlan};
pub use checkpoint::{AlgoState, Checkpoint, OuterState, WorkerState};
pub use membership::{Membership, RecoveryPolicy};
