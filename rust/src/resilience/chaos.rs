//! Chaos injection: a seeded fault schedule the coordinator engine executes.
//!
//! A [`FaultPlan`] lists worker crashes (`crash-at-step`), each optionally
//! followed by a restart after a fixed downtime (`restart-after`); a fault
//! with no restart is a **permanent loss**. The engine's supervisor tears the
//! worker's thread down when its crash step arrives (the worker exits its
//! loop cleanly — we simulate a dead *device*, the harness itself is not
//! `kill -9`'d), reclaims the dead worker's push-sum weight so gossip mass
//! is conserved, and respawns the worker after the downtime under the
//! algorithm's recovery policy (see [`super::membership::RecoveryPolicy`]
//! and the engine docs).
//!
//! Schedules are deterministic: build one explicitly with the builder
//! methods, parse one from the CLI `--crash` spec, or draw a seeded random
//! schedule with [`FaultPlan::random`].

use anyhow::{bail, Context, Result};
use std::sync::atomic::{AtomicBool, Ordering};

use crate::util::rng::Pcg32;

/// One scheduled worker failure.
#[derive(Clone, Debug, PartialEq)]
pub struct Fault {
    /// which worker slot dies
    pub worker: usize,
    /// the step at which it dies (checked at the top of that step, before
    /// any compute for it happens)
    pub at_step: usize,
    /// downtime before the supervisor respawns it; `None` = permanent loss
    pub restart_after_s: Option<f64>,
}

/// A deterministic crash/restart schedule (empty by default: no chaos).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultPlan {
    pub faults: Vec<Fault>,
}

impl FaultPlan {
    pub fn is_empty(&self) -> bool {
        self.faults.is_empty()
    }

    /// Add a permanent crash: `worker` dies at `at_step` and never returns.
    pub fn crash(mut self, worker: usize, at_step: usize) -> FaultPlan {
        self.faults.push(Fault { worker, at_step, restart_after_s: None });
        self
    }

    /// Add a crash/restart: `worker` dies at `at_step` and is respawned
    /// after `restart_after_s` seconds of downtime.
    pub fn crash_restart(mut self, worker: usize, at_step: usize, restart_after_s: f64) -> FaultPlan {
        self.faults
            .push(Fault { worker, at_step, restart_after_s: Some(restart_after_s) });
        self
    }

    /// A seeded random schedule: `n_faults` crashes at uniform steps in
    /// `[1, steps)`, spread over workers `1..m` (worker 0 is spared so the
    /// eval stream keeps flowing), each with the given downtime.
    pub fn random(
        seed: u64,
        workers: usize,
        steps: usize,
        n_faults: usize,
        restart_after_s: Option<f64>,
    ) -> FaultPlan {
        let mut rng = Pcg32::new(seed ^ 0xc4a05);
        let mut plan = FaultPlan::default();
        if workers < 2 || steps < 2 {
            return plan;
        }
        for _ in 0..n_faults {
            let worker = 1 + rng.below_usize(workers - 1);
            let at_step = 1 + rng.below_usize(steps - 1);
            plan.faults.push(Fault { worker, at_step, restart_after_s });
        }
        plan
    }

    /// Parse a CLI spec: comma-separated `WORKER@STEP` (permanent) or
    /// `WORKER@STEP+SECONDS` (restart after a downtime), e.g. `1@20+0.5,2@40`.
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let mut plan = FaultPlan::default();
        for part in spec.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (worker, rest) = part
                .split_once('@')
                .with_context(|| format!("fault {part:?}: expected WORKER@STEP[+SECONDS]"))?;
            let worker: usize = worker
                .trim()
                .parse()
                .with_context(|| format!("fault {part:?}: bad worker id"))?;
            let (step, restart) = match rest.split_once('+') {
                Some((s, r)) => {
                    let secs: f64 = r
                        .trim()
                        .parse()
                        .with_context(|| format!("fault {part:?}: bad restart seconds"))?;
                    (s, Some(secs))
                }
                None => (rest, None),
            };
            let at_step: usize = step
                .trim()
                .parse()
                .with_context(|| format!("fault {part:?}: bad crash step"))?;
            plan.faults.push(Fault { worker, at_step, restart_after_s: restart });
        }
        Ok(plan)
    }

    /// Reject schedules that cannot execute on an `(m, steps)` run.
    pub fn validate(&self, workers: usize, steps: usize) -> Result<()> {
        if self.is_empty() {
            return Ok(());
        }
        if workers < 2 {
            bail!("chaos injection needs at least 2 workers (a donor must survive)");
        }
        for f in &self.faults {
            if f.worker >= workers {
                bail!("fault targets worker {} but the run has {workers}", f.worker);
            }
            if f.at_step >= steps {
                bail!(
                    "fault at step {} is beyond the run's {steps} steps",
                    f.at_step
                );
            }
            if let Some(s) = f.restart_after_s {
                if s < 0.0 || !s.is_finite() {
                    bail!("fault restart downtime must be finite and >= 0, got {s}");
                }
            }
        }
        let mut by_worker: Vec<Vec<&Fault>> = vec![Vec::new(); workers];
        for f in &self.faults {
            by_worker[f.worker].push(f);
        }
        for (w, faults) in by_worker.iter().enumerate() {
            for (i, a) in faults.iter().enumerate() {
                for b in &faults[i + 1..] {
                    if a.at_step == b.at_step {
                        bail!("worker {w} has two faults at step {}", a.at_step);
                    }
                }
                if a.restart_after_s.is_none() && faults.len() > 1 {
                    bail!("worker {w}: a permanent fault cannot be combined with others");
                }
            }
        }
        Ok(())
    }

    /// The fault that fires for `(worker, step)`, if any.
    pub fn fault_at(&self, worker: usize, step: usize) -> Option<(usize, &Fault)> {
        self.faults
            .iter()
            .enumerate()
            .find(|(_, f)| f.worker == worker && f.at_step == step)
    }
}

/// Runtime state of a plan: which faults already fired. A respawned worker
/// restarts *at* its crash step, so without this latch the same fault would
/// kill it again immediately.
pub struct ChaosRuntime {
    pub plan: FaultPlan,
    fired: Vec<AtomicBool>,
}

impl ChaosRuntime {
    pub fn new(plan: FaultPlan) -> ChaosRuntime {
        let fired = (0..plan.faults.len()).map(|_| AtomicBool::new(false)).collect();
        ChaosRuntime { plan, fired }
    }

    /// Fire-once check: `true` exactly the first time `(worker, step)`
    /// matches an unfired fault.
    pub fn due(&self, worker: usize, step: usize) -> bool {
        match self.plan.fault_at(worker, step) {
            Some((idx, _)) => !self.fired[idx].swap(true, Ordering::AcqRel),
            None => false,
        }
    }

    /// The scheduled downtime of the fault that killed `worker` at `step`
    /// (`None` = permanent).
    pub fn restart_after(&self, worker: usize, step: usize) -> Option<f64> {
        self.plan
            .fault_at(worker, step)
            .and_then(|(_, f)| f.restart_after_s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_parse_roundtrip() {
        let built = FaultPlan::default().crash_restart(1, 20, 0.5).crash(2, 40);
        let parsed = FaultPlan::parse("1@20+0.5, 2@40").unwrap();
        assert_eq!(built, parsed);
        assert!(FaultPlan::parse("").unwrap().is_empty());
        assert!(FaultPlan::parse("nope").is_err());
        assert!(FaultPlan::parse("1@x").is_err());
        assert!(FaultPlan::parse("1@5+abc").is_err());
    }

    #[test]
    fn validation_rejects_impossible_schedules() {
        let plan = FaultPlan::default().crash(1, 5);
        plan.validate(3, 10).unwrap();
        assert!(plan.validate(1, 10).is_err(), "needs a surviving donor");
        assert!(plan.validate(3, 5).is_err(), "crash step beyond the run");
        assert!(FaultPlan::default().crash(7, 1).validate(3, 10).is_err());
        let dup = FaultPlan::default().crash_restart(1, 5, 0.1).crash_restart(1, 5, 0.2);
        assert!(dup.validate(3, 10).is_err());
        let after_permanent = FaultPlan::default().crash(1, 5).crash_restart(1, 8, 0.1);
        assert!(after_permanent.validate(3, 10).is_err());
        let neg = FaultPlan::default().crash_restart(1, 5, -1.0);
        assert!(neg.validate(3, 10).is_err());
        // empty plans validate against anything
        FaultPlan::default().validate(1, 1).unwrap();
    }

    #[test]
    fn random_schedules_are_seed_deterministic_and_spare_worker_zero() {
        let a = FaultPlan::random(9, 4, 100, 6, Some(0.25));
        let b = FaultPlan::random(9, 4, 100, 6, Some(0.25));
        assert_eq!(a, b);
        assert_eq!(a.faults.len(), 6);
        for f in &a.faults {
            assert!(f.worker >= 1 && f.worker < 4);
            assert!(f.at_step >= 1 && f.at_step < 100);
            assert_eq!(f.restart_after_s, Some(0.25));
        }
        let c = FaultPlan::random(10, 4, 100, 6, Some(0.25));
        assert_ne!(a, c, "different seeds draw different schedules");
        assert!(FaultPlan::random(1, 1, 100, 3, None).is_empty());
    }

    #[test]
    fn runtime_fires_each_fault_exactly_once() {
        let rt = ChaosRuntime::new(FaultPlan::default().crash_restart(1, 3, 0.1));
        assert!(!rt.due(0, 3), "wrong worker");
        assert!(!rt.due(1, 2), "wrong step");
        assert!(rt.due(1, 3), "first match fires");
        assert!(!rt.due(1, 3), "a respawned worker passing its crash step survives");
        assert_eq!(rt.restart_after(1, 3), Some(0.1));
        assert_eq!(rt.restart_after(1, 4), None);
    }
}
