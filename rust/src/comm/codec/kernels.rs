//! Codec hot loops, sharded across the update lanes (§Perf, PR 6 pattern).
//!
//! Every kernel here is **bit-identical at any `update_threads`**: the shard
//! ranges [`ShardPool::run`] hands out are contiguous but *not* chunk-
//! aligned, so the int8 kernels key their per-chunk scales to **absolute**
//! chunk indices — a shard that starts mid-chunk recomputes that chunk's
//! scale from the full chunk (reads of the shared input are free) and only
//! *writes* the scale slot when it owns the chunk's first element. Element
//! outputs are a pure function of `(x[i], scale[i/CHUNK], seed, i)`, so the
//! thread count can never leak into the wire bytes.

use crate::tensor::shard::{DisjointMut, ShardPool, CHUNK};

/// Quantized values are scaled into `[-QMAX, QMAX]` (symmetric, no zero
/// point): `i8::MIN` is never emitted, so negation round-trips.
pub const QMAX: f32 = 127.0;

/// splitmix64 finalizer — the stateless per-element hash behind stochastic
/// rounding and rand-k index draws. Counter-based (no sequential RNG state),
/// so element `i`'s randomness is independent of which shard visits it.
pub fn mix64(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Uniform in `[0, 1)` for element `i` under `seed` (24 explicit bits, the
/// f32 mantissa width — every representable outcome is exact).
pub fn unit_f32(seed: u64, i: usize) -> f32 {
    let h = mix64(seed ^ (i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
    ((h >> 40) as f32) * (1.0 / (1u64 << 24) as f32)
}

/// Error-feedback re-add: `y[i] = x[i] + r[i]`, sharded. One plain f32 add
/// per element — the same float the serial loop would produce, so the
/// conservation property (`sent + residual == x + old residual`) stays
/// bit-exact at any thread count.
pub fn add_residual(pool: &ShardPool, x: &[f32], r: &[f32], y: &mut [f32]) {
    assert_eq!(x.len(), r.len());
    assert_eq!(x.len(), y.len());
    let yd = DisjointMut::new(y);
    pool.run(x.len(), |range| {
        let ys = unsafe { yd.slice(range.clone()) };
        for (i, yi) in range.zip(ys.iter_mut()) {
            *yi = x[i] + r[i];
        }
    });
}

/// Int8 stochastic quantization with per-chunk (`CHUNK` = 1024 element)
/// max-abs scales. `scales` must hold `x.len().div_ceil(CHUNK)` slots and
/// `q` one byte per element (two's-complement i8 in `[-127, 127]`).
///
/// Rounding is stochastic and *unbiased*: `v = x/scale·127` rounds up with
/// probability `frac(v)`, drawn from the counter-based hash — never from a
/// link RNG, so quantization noise cannot perturb drop dice or latency
/// draws.
pub fn int8_encode(pool: &ShardPool, x: &[f32], seed: u64, scales: &mut [f32], q: &mut [u8]) {
    let n = x.len();
    assert_eq!(scales.len(), n.div_ceil(CHUNK));
    assert_eq!(q.len(), n);
    let sd = DisjointMut::new(scales);
    let qd = DisjointMut::new(q);
    pool.run(n, |range| {
        let first_chunk = range.start / CHUNK;
        let last_chunk = range.end.div_ceil(CHUNK);
        for c in first_chunk..last_chunk {
            let cs = c * CHUNK;
            let ce = (cs + CHUNK).min(n);
            // scale over the FULL chunk, even when this shard only covers a
            // tail of it — reading the shared input outside the shard range
            // is free, and it keeps the scale independent of the sharding
            let mut m = 0.0f32;
            for &v in &x[cs..ce] {
                m = m.max(v.abs());
            }
            // the shard that owns the chunk's first element writes the slot
            if cs >= range.start {
                unsafe { sd.slice(c..c + 1) }[0] = m;
            }
            let lo = cs.max(range.start);
            let hi = ce.min(range.end);
            let qs = unsafe { qd.slice(lo..hi) };
            if m == 0.0 || !m.is_finite() {
                // an all-zero (or non-finite) chunk quantizes to zeros; the
                // decoder multiplies by the stored scale, reproducing zeros
                // (resp. leaving the poisoned chunk zeroed rather than
                // spraying NaN into every coordinate)
                qs.fill(0);
                continue;
            }
            for (i, qi) in (lo..hi).zip(qs.iter_mut()) {
                let v = (x[i] / m * QMAX).clamp(-QMAX, QMAX);
                let f = v.floor();
                let up = unit_f32(seed, i) < (v - f);
                let quantized = (f as i32 + i32::from(up)).clamp(-127, 127);
                *qi = quantized as i8 as u8;
            }
        }
    });
}

/// Dequantize: `out[i] = q[i]/127 · scales[i/CHUNK]`, sharded. Pure per-
/// element arithmetic — bit-identical at any thread count.
pub fn int8_decode(pool: &ShardPool, scales: &[f32], q: &[u8], out: &mut [f32]) {
    let n = q.len();
    assert_eq!(scales.len(), n.div_ceil(CHUNK));
    assert_eq!(out.len(), n);
    let od = DisjointMut::new(out);
    pool.run(n, |range| {
        let os = unsafe { od.slice(range.clone()) };
        for (i, oi) in range.zip(os.iter_mut()) {
            *oi = (q[i] as i8 as f32) * (1.0 / QMAX) * scales[i / CHUNK];
        }
    });
}

/// The `k` indices of largest `|y|`, deterministically tie-broken by the
/// lower index, returned in ascending index order.
///
/// **§Perf rewrite** (the old quickselect over `u32` indices ran at
/// 0.21 GB/s — every comparison chased two random `y` loads): each element
/// packs into one `u64` key, `(|y[i]|.to_bits() << 32) | !i`. For the
/// non-negative magnitudes `total_cmp` *is* the integer order of the bits
/// (NaN above every finite magnitude included), and the complemented index
/// breaks magnitude ties toward the lower index — so one branchless integer
/// compare replaces the float/index comparator exactly. Selection shards on
/// the pool: each lane partial-selects its range's top-`min(k, len)`
/// candidates (the global top-k is a subset of the per-shard top-k's by the
/// total order), then one exact select over the candidate union. The result
/// is a pure function of `(y, k)` — bit-identical at any `update_threads`
/// and any shard partition.
pub fn top_k_indices(pool: &ShardPool, y: &[f32], k: usize) -> Vec<u32> {
    let n = y.len();
    let k = k.min(n);
    if k == 0 {
        return Vec::new();
    }
    let key = |i: usize| ((y[i].abs().to_bits() as u64) << 32) | (!(i as u32)) as u64;
    let candidates = std::sync::Mutex::new(Vec::<u64>::with_capacity(k));
    pool.run(n, |range| {
        let mut keys: Vec<u64> = range.map(key).collect();
        if k < keys.len() {
            keys.select_nth_unstable_by(k - 1, |a, b| b.cmp(a));
            keys.truncate(k);
        }
        candidates.lock().unwrap().append(&mut keys);
    });
    let mut keys = candidates.into_inner().unwrap();
    if k < keys.len() {
        keys.select_nth_unstable_by(k - 1, |a, b| b.cmp(a));
        keys.truncate(k);
    }
    let mut idx: Vec<u32> = keys.iter().map(|&kb| !(kb as u32)).collect();
    idx.sort_unstable();
    idx
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Deterministic pseudo-data (the LCG pattern the tensor tests use).
    fn lcg_data(n: usize, mut seed: u64) -> Vec<f32> {
        (0..n)
            .map(|_| {
                seed = seed.wrapping_mul(1664525).wrapping_add(1013904223);
                ((seed >> 8) as u32 % (1 << 24)) as f32 / (1 << 24) as f32 - 0.5
            })
            .collect()
    }

    /// §Small fix acceptance: the codec kernels must be bit-identical across
    /// thread counts on sizes that straddle chunk boundaries — one chunk
    /// minus a remainder, exactly one chunk, and a prime well past 4 chunks
    /// (5003 = 4·CHUNK + 907, so shard ranges split chunks mid-way).
    #[test]
    fn kernels_bit_identical_across_thread_counts_at_chunk_boundaries() {
        let serial = ShardPool::serial();
        for n in [CHUNK - 3, CHUNK, 5003] {
            let x = lcg_data(n, 7 + n as u64);
            let r = lcg_data(n, 99 + n as u64);
            let mut scales0 = vec![0.0f32; n.div_ceil(CHUNK)];
            let mut q0 = vec![0u8; n];
            int8_encode(&serial, &x, 0xC0DEC, &mut scales0, &mut q0);
            let mut out0 = vec![0.0f32; n];
            int8_decode(&serial, &scales0, &q0, &mut out0);
            let mut y0 = vec![0.0f32; n];
            add_residual(&serial, &x, &r, &mut y0);
            for threads in [2, 3, 4] {
                let pool = ShardPool::new(threads);
                let mut scales = vec![0.0f32; n.div_ceil(CHUNK)];
                let mut q = vec![0u8; n];
                int8_encode(&pool, &x, 0xC0DEC, &mut scales, &mut q);
                assert_eq!(q, q0, "n={n} t={threads}: quantized bytes drifted");
                let bits = |v: &[f32]| v.iter().map(|f| f.to_bits()).collect::<Vec<_>>();
                assert_eq!(bits(&scales), bits(&scales0), "n={n} t={threads}: scales drifted");
                let mut out = vec![0.0f32; n];
                int8_decode(&pool, &scales, &q, &mut out);
                assert_eq!(bits(&out), bits(&out0), "n={n} t={threads}: decode drifted");
                let mut y = vec![0.0f32; n];
                add_residual(&pool, &x, &r, &mut y);
                assert_eq!(bits(&y), bits(&y0), "n={n} t={threads}: EF re-add drifted");
                for k in [1, 7, n / 16 + 1, n - 1, n] {
                    assert_eq!(
                        top_k_indices(&pool, &x, k),
                        top_k_indices(&serial, &x, k),
                        "n={n} t={threads} k={k}: top-k selection drifted"
                    );
                }
            }
        }
    }

    #[test]
    fn int8_roundtrip_is_within_one_scale_step() {
        let pool = ShardPool::serial();
        let x = lcg_data(3000, 3);
        let mut scales = vec![0.0f32; x.len().div_ceil(CHUNK)];
        let mut q = vec![0u8; x.len()];
        int8_encode(&pool, &x, 1, &mut scales, &mut q);
        let mut out = vec![0.0f32; x.len()];
        int8_decode(&pool, &scales, &q, &mut out);
        for (i, (&a, &b)) in x.iter().zip(&out).enumerate() {
            let tol = scales[i / CHUNK] / QMAX + 1e-7;
            assert!((a - b).abs() <= tol, "elem {i}: |{a} - {b}| > {tol}");
        }
    }

    #[test]
    fn int8_stochastic_rounding_is_unbiased() {
        // a constant 0.5 between two quantization steps must round up about
        // half the time under the counter-based hash
        let pool = ShardPool::serial();
        let n = 4096;
        // a 1.0 anchor at each chunk head pins every scale to exactly 1.0;
        // the probe value then maps to exactly 63.5 quantization steps
        let mut x = vec![63.5f32 / QMAX; n];
        for c in 0..n.div_ceil(CHUNK) {
            x[c * CHUNK] = 1.0;
        }
        let mut scales = vec![0.0f32; n.div_ceil(CHUNK)];
        let mut q = vec![0u8; n];
        int8_encode(&pool, &x, 42, &mut scales, &mut q);
        let probes: Vec<i8> = (0..n).filter(|i| i % CHUNK != 0).map(|i| q[i] as i8).collect();
        let ups = probes.iter().filter(|&&v| v == 64).count();
        let downs = probes.iter().filter(|&&v| v == 63).count();
        assert_eq!(ups + downs, probes.len(), "probe must land on one of the two steps");
        let frac = ups as f64 / probes.len() as f64;
        assert!((frac - 0.5).abs() < 0.05, "rounding bias: up-fraction {frac}");
    }

    #[test]
    fn zero_and_nonfinite_chunks_quantize_to_zeros() {
        let pool = ShardPool::serial();
        let mut x = vec![0.0f32; CHUNK + 10];
        for v in x.iter_mut().skip(CHUNK) {
            *v = f32::INFINITY;
        }
        let mut scales = vec![0.0f32; 2];
        let mut q = vec![1u8; x.len()];
        int8_encode(&pool, &x, 5, &mut scales, &mut q);
        assert!(q.iter().all(|&b| b == 0));
    }

    #[test]
    fn top_k_selects_largest_magnitudes_with_index_tiebreak() {
        let pool = ShardPool::serial();
        let y = [0.5, -3.0, 0.25, 3.0, -0.5, 0.0];
        assert_eq!(top_k_indices(&pool, &y, 2), vec![1, 3]);
        // |0.5| ties at indices 0 and 4: the lower index wins the last slot
        assert_eq!(top_k_indices(&pool, &y, 3), vec![0, 1, 3]);
        assert_eq!(top_k_indices(&pool, &y, 0), Vec::<u32>::new());
        assert_eq!(top_k_indices(&pool, &y, 99), vec![0, 1, 2, 3, 4, 5]);
        assert_eq!(top_k_indices(&pool, &[], 3), Vec::<u32>::new());
    }

    /// The packed-key rewrite must match the reference float comparator
    /// (`|y| desc via total_cmp, then index asc`) on adversarial inputs:
    /// NaN (sorts above every magnitude), ±0 ties, ±inf, subnormals, and
    /// exact ± pairs that tie on magnitude.
    #[test]
    fn top_k_packed_keys_match_reference_comparator_on_edge_values() {
        let pool = ShardPool::new(3);
        let y: Vec<f32> = vec![
            0.0,
            -0.0,
            f32::NAN,
            f32::INFINITY,
            f32::NEG_INFINITY,
            1.0e-40, // subnormal
            -1.0e-40,
            2.5,
            -2.5,
            f32::from_bits(0xFFC0_0001), // -NaN with payload
            1.0,
        ];
        let reference = |y: &[f32], k: usize| -> Vec<u32> {
            let mut idx: Vec<u32> = (0..y.len() as u32).collect();
            idx.sort_by(|&a, &b| {
                y[b as usize]
                    .abs()
                    .total_cmp(&y[a as usize].abs())
                    .then_with(|| a.cmp(&b))
            });
            idx.truncate(k.min(y.len()));
            idx.sort_unstable();
            idx
        };
        for k in 0..=y.len() {
            assert_eq!(top_k_indices(&pool, &y, k), reference(&y, k), "k={k}");
        }
    }
}
