//! `comm::codec` — pluggable gradient/parameter compression at the fabric
//! boundary (ROADMAP item 3).
//!
//! Every message a worker pushes crosses one chokepoint
//! ([`crate::comm::FabricCore`]), so compression installs there once and
//! every payload kind — `LayerPush`, `ModelPush`, `PairAverage`,
//! `GradShare`/`ParamShare`, `GradPush`/`ParamPull` — and every registry
//! algorithm inherits it without per-algorithm changes. The fabric encodes
//! at `push` time (before the link's drop dice and bandwidth accounting, so
//! serialization delay and [`crate::metrics::CommStats`] meter the **encoded
//! wire size**) and decodes at apply time (a malformed blob is
//! `ApplyResult::Malformed`: rejected with a push-sum weight refund, never a
//! partial write).
//!
//! Codecs:
//!
//! * **`dense`** (default) — the identity. Payloads are passed through
//!   untouched, so default runs stay bit-identical to a build without the
//!   codec subsystem: same floats, same link-RNG draws, same byte counts.
//! * **`topk:K` / `randk:K`** — sparsification. `K` is the *divisor*: each
//!   tensor ships its `ceil(n/K)` largest-magnitude (resp. uniformly drawn)
//!   coordinates as `(u32 index, f32 value)` pairs, an `8/4K` compression of
//!   the dense 4-byte/coordinate stream (`topk:16` ≈ 8× fewer bytes).
//!   **Gradient** streams (`GradShare`, `GradPush.grads`) carry per-link
//!   [error-feedback] residuals: dropped coordinates accumulate sender-side
//!   and are re-added before the next encode, and a message the link loses
//!   folds its shipped coordinates back into the residual — composing with
//!   push-sum weight reclaim, so no gradient mass is ever silently
//!   destroyed. **State** streams (parameter pushes) sparsify without a
//!   residual (stale parameter corrections would diverge); the receiver
//!   fills unsent coordinates from its *own* current values, making a
//!   sparse push a partial mix rather than a zero-smearing overwrite.
//! * **`int8`** — stochastic quantization with per-chunk
//!   ([`crate::tensor::shard::CHUNK`]-element) max-abs scales, ~4× fewer
//!   bytes. Rounding is unbiased and drawn from a counter-based hash (never
//!   a link RNG), keyed by a per-link message sequence number.
//!
//! Determinism: `dense` and `topk` are RNG-free, so same seeds → same
//! curves, and a `topk` checkpoint resumes bit-identically (residuals ride
//! `FORMAT_VERSION` 4 snapshots). `randk`/`int8` draw from the codec seed
//! and per-link sequence counters, which are deterministic within a run but
//! not checkpointed — resume bit-parity is promised for `dense`/`topk`.
//!
//! [error-feedback]: https://arxiv.org/abs/1809.07599

pub mod kernels;
pub mod wire;

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{bail, Context, Result};

use crate::comm::Payload;
use crate::coordinator::Shared;
use crate::tensor::clock::ClockStamp;
use crate::tensor::shard::{ShardPool, CHUNK};
use crate::tensor::Tensor;
use crate::util::rng::Pcg32;

use self::kernels::{add_residual, int8_decode, int8_encode, mix64, top_k_indices};
use self::wire::{Reader, Writer};

/// Which codec a run installs at the fabric boundary
/// (`[fabric] codec = "dense|topk:K|randk:K|int8"`, `--codec`,
/// [`crate::session::SessionBuilder::codec`]).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum CodecSpec {
    /// Identity (the default): dense f32 payloads, bit-identical to a build
    /// without the codec subsystem.
    Dense,
    /// Keep each tensor's `ceil(n/k)` largest-magnitude coordinates
    /// (deterministic, index-tie-broken), with error feedback on gradients.
    TopK { k: u32 },
    /// Keep `ceil(n/k)` uniformly drawn coordinates, with error feedback on
    /// gradients (the unbiased sparsifier baseline).
    RandK { k: u32 },
    /// Stochastic 8-bit quantization with per-chunk max-abs scales.
    Int8,
}

impl Default for CodecSpec {
    fn default() -> Self {
        CodecSpec::Dense
    }
}

impl CodecSpec {
    /// Parse a config/CLI spelling: `dense`, `topk:K`, `randk:K`, `int8`.
    pub fn parse(spec: &str) -> Result<CodecSpec> {
        let t = spec.trim();
        if t == "dense" {
            return Ok(CodecSpec::Dense);
        }
        if t == "int8" {
            return Ok(CodecSpec::Int8);
        }
        for (prefix, rand) in [("topk:", false), ("randk:", true)] {
            if let Some(rest) = t.strip_prefix(prefix) {
                let k: u32 = rest
                    .parse()
                    .with_context(|| format!("codec {spec:?}: K must be an integer"))?;
                let out = if rand { CodecSpec::RandK { k } } else { CodecSpec::TopK { k } };
                out.validate()?;
                return Ok(out);
            }
        }
        bail!("codec: expected \"dense\", \"topk:K\", \"randk:K\" or \"int8\", got {spec:?}")
    }

    /// Canonical spelling (round-trips through [`CodecSpec::parse`]).
    pub fn name(&self) -> String {
        match self {
            CodecSpec::Dense => "dense".into(),
            CodecSpec::TopK { k } => format!("topk:{k}"),
            CodecSpec::RandK { k } => format!("randk:{k}"),
            CodecSpec::Int8 => "int8".into(),
        }
    }

    /// Reject nonsensical knobs. `K` is the sparsification *divisor* (keep
    /// `ceil(n/K)` coordinates), and each kept coordinate costs 8 wire bytes
    /// vs 4 dense — `K = 1` would *grow* every message.
    pub fn validate(&self) -> Result<()> {
        match self {
            CodecSpec::TopK { k } | CodecSpec::RandK { k } if *k < 2 => bail!(
                "codec {}: K is the sparsification divisor (keep ~n/K coordinates at \
                 8 bytes each); K must be >= 2 — use \"dense\" for no compression",
                self.name()
            ),
            _ => Ok(()),
        }
    }

    pub fn is_dense(&self) -> bool {
        matches!(self, CodecSpec::Dense)
    }

    /// Build the runtime codec for an `m`-slot cluster. `seed` feeds the
    /// rand-k index draws and int8 stochastic rounding only — dense and
    /// top-k are RNG-free.
    pub fn build(&self, m: usize, seed: u64) -> Arc<dyn Codec> {
        match self {
            CodecSpec::Dense => Arc::new(DenseCodec),
            CodecSpec::TopK { k } => Arc::new(SparsifyCodec::new(*k, false, m, seed)),
            CodecSpec::RandK { k } => Arc::new(SparsifyCodec::new(*k, true, m, seed)),
            CodecSpec::Int8 => Arc::new(Int8Codec { seed }),
        }
    }

    /// Stable `(tag, k)` pair for the checkpoint codec (payload tag 7).
    pub fn wire_tag(&self) -> (u8, u32) {
        match self {
            CodecSpec::Dense => (0, 0),
            CodecSpec::TopK { k } => (1, *k),
            CodecSpec::RandK { k } => (2, *k),
            CodecSpec::Int8 => (3, 0),
        }
    }

    /// Inverse of [`CodecSpec::wire_tag`].
    pub fn from_wire(tag: u8, k: u32) -> Result<CodecSpec> {
        let spec = match tag {
            0 => CodecSpec::Dense,
            1 => CodecSpec::TopK { k },
            2 => CodecSpec::RandK { k },
            3 => CodecSpec::Int8,
            other => bail!("unknown codec wire tag {other}"),
        };
        spec.validate()?;
        Ok(spec)
    }
}

/// Identity of one compressed stream within a directed link: the payload
/// tag plus the (layer, tensor) coordinates. Error-feedback residuals are
/// keyed by this, so e.g. layer-3 gradients never contaminate layer-5's
/// residual.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct StreamKey {
    /// payload wire tag (0..=7, the blob numbering; 7 is the coalesced
    /// step frame, whose single concatenated stream uses layer 0/tensor 0)
    pub tag: u8,
    pub layer: u32,
    pub tensor: u32,
}

/// One directed link's error-feedback residuals, in checkpointable form
/// (`FORMAT_VERSION` 4). Streams are ordered by [`StreamKey`], so snapshots
/// are deterministic byte-for-byte.
#[derive(Clone, Debug, PartialEq)]
pub struct ResidualState {
    pub from: usize,
    pub to: usize,
    pub streams: Vec<(StreamKey, Vec<f32>)>,
}

/// A codec-encoded message riding a link. The push-sum metadata
/// (`shipped_w`, `droppable`) travels in the clear so the fabric can meter,
/// drop-dice and refund without decoding; everything else lives in the
/// codec's wire blob.
#[derive(Clone)]
pub struct Compressed {
    /// the codec that produced `blob` (decode dispatches on it)
    pub spec: CodecSpec,
    /// push-sum weight riding the message (refunded on drop/reject)
    pub shipped_w: f32,
    /// whether the inner payload tolerates link loss
    pub droppable: bool,
    /// the encoded wire stream ([`wire`] framing)
    pub blob: Arc<Vec<u8>>,
}

/// Pluggable compression at the fabric boundary. One codec instance is
/// shared by every link of a fabric; implementations hold their own
/// per-link state (error-feedback residuals, message sequence counters).
pub trait Codec: Send + Sync {
    /// The spec this codec was built from.
    fn spec(&self) -> &CodecSpec;

    /// Encode one outgoing message for the directed link `from → to`.
    /// Identity for `dense`; already-compressed payloads (the checkpoint
    /// restore path) pass through unchanged.
    fn encode(&self, pool: &ShardPool, from: usize, to: usize, payload: Payload) -> Payload;

    /// The link lost `payload` (drop dice): fold its shipped gradient
    /// coordinates back into the sender-side residual, so lossy links shed
    /// latency, not gradient mass. No-op for codecs without residuals.
    fn on_drop(&self, _from: usize, _to: usize, _payload: &Payload) {}

    /// Snapshot per-link error-feedback residuals (checkpoint capture).
    fn residual_state(&self) -> Vec<ResidualState> {
        Vec::new()
    }

    /// Restore residuals from a checkpoint snapshot (resume).
    fn load_residual_state(&self, _states: &[ResidualState]) {}
}

/// The identity codec: `encode` returns the payload untouched, so default
/// runs carry dense f32 payloads with seed-era byte accounting.
pub struct DenseCodec;

impl Codec for DenseCodec {
    fn spec(&self) -> &CodecSpec {
        &CodecSpec::Dense
    }

    fn encode(&self, _pool: &ShardPool, _from: usize, _to: usize, payload: Payload) -> Payload {
        payload
    }
}

// ---------------------------------------------------------------------------
// payload structure walk (shared by every compressing codec)
// ---------------------------------------------------------------------------

/// Whether a stream carries gradient mass (error-feedback eligible,
/// zero-filled at decode) or parameter state (no residual, receiver-filled
/// at decode).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum StreamClass {
    Grad,
    State,
}

/// Per-stream context handed to a codec's stream encoder.
struct StreamCtx {
    link: usize,
    key: StreamKey,
    /// per-stream seed (rand-k draws, int8 stochastic rounding)
    seed: u64,
}

/// Serialize `payload`'s header fields and hand each f32 stream to
/// `stream` in a fixed walk order (the decode side mirrors it exactly).
/// `GradPush.x_then` ships dense inside the blob: it is a *parameter
/// snapshot for delay compensation* — sparsifying it would corrupt the
/// DC-ASGD correction term, and it is absent unless compensation is on.
fn build_blob(
    payload: &Payload,
    mut stream: impl FnMut(&mut Writer, StreamKey, StreamClass, &[f32]),
) -> Vec<u8> {
    let mut w = Writer::with_capacity(256);
    let write_stamp = |w: &mut Writer, st: &ClockStamp| {
        w.u32(st.worker);
        w.u64(st.step);
        w.u64(st.version);
    };
    match payload {
        Payload::LayerPush { layer, open, values, stamp, tau } => {
            w.u8(0);
            w.u32(*layer as u32);
            match open {
                None => w.bool(false),
                Some(f) => {
                    w.bool(true);
                    w.f32(*f);
                }
            }
            write_stamp(&mut w, stamp);
            w.u64(*tau);
            w.u32(values.len() as u32);
            for (ti, v) in values.iter().enumerate() {
                let key = StreamKey { tag: 0, layer: *layer as u32, tensor: ti as u32 };
                stream(&mut w, key, StreamClass::State, v);
            }
        }
        Payload::ModelPush { w_in, values } => {
            w.u8(1);
            w.f32(*w_in);
            w.u32(values.len() as u32);
            for (li, layer) in values.iter().enumerate() {
                w.u32(layer.len() as u32);
                for (ti, v) in layer.iter().enumerate() {
                    let key = StreamKey { tag: 1, layer: li as u32, tensor: ti as u32 };
                    stream(&mut w, key, StreamClass::State, v);
                }
            }
        }
        Payload::PairAverage { flat, reply } => {
            w.u8(2);
            w.bool(*reply);
            stream(&mut w, StreamKey { tag: 2, layer: 0, tensor: 0 }, StreamClass::State, flat);
        }
        Payload::GradShare { set } => {
            w.u8(3);
            w.u32(set.len() as u32);
            for (li, layer) in set.iter().enumerate() {
                w.u32(layer.len() as u32);
                for (ti, t) in layer.iter().enumerate() {
                    let key = StreamKey { tag: 3, layer: li as u32, tensor: ti as u32 };
                    stream(&mut w, key, StreamClass::Grad, &t.data);
                }
            }
        }
        Payload::ParamShare { flat } => {
            w.u8(4);
            stream(&mut w, StreamKey { tag: 4, layer: 0, tensor: 0 }, StreamClass::State, flat);
        }
        Payload::GradPush { layer, grads, x_then, stamp } => {
            w.u8(5);
            w.u32(*layer as u32);
            write_stamp(&mut w, stamp);
            w.u32(grads.len() as u32);
            for (ti, g) in grads.iter().enumerate() {
                let key = StreamKey { tag: 5, layer: *layer as u32, tensor: ti as u32 };
                stream(&mut w, key, StreamClass::Grad, g);
            }
            match x_then {
                None => w.bool(false),
                Some(xt) => {
                    w.bool(true);
                    for v in xt.iter() {
                        w.u32(v.len() as u32);
                        w.f32s(v);
                    }
                }
            }
        }
        Payload::ParamPull { layer, values, stamp } => {
            w.u8(6);
            w.u32(*layer as u32);
            write_stamp(&mut w, stamp);
            w.u32(values.len() as u32);
            for (ti, v) in values.iter().enumerate() {
                let key = StreamKey { tag: 6, layer: *layer as u32, tensor: ti as u32 };
                stream(&mut w, key, StreamClass::State, v);
            }
        }
        Payload::StepFrame { open, entries } => {
            w.u8(7);
            match open {
                None => w.bool(false),
                Some(f) => {
                    w.bool(true);
                    w.f32(*f);
                }
            }
            w.u32(entries.len() as u32);
            let mut concat: Vec<f32> = Vec::new();
            for e in entries.iter() {
                w.u32(e.layer as u32);
                write_stamp(&mut w, &e.stamp);
                w.u64(e.tau);
                w.u32(e.values.len() as u32);
                for v in e.values.iter() {
                    concat.extend_from_slice(v);
                }
            }
            // ONE stream over the whole step's concatenated gradient mass:
            // top-k ranks coordinates globally across layers, and the codec
            // pays its per-message setup once instead of once per layer
            let key = StreamKey { tag: 7, layer: 0, tensor: 0 };
            stream(&mut w, key, StreamClass::State, &concat);
        }
        // the restore path short-circuits in `Codec::encode`; a nested
        // Compressed here is a framing bug
        Payload::Compressed(_) => unreachable!("cannot re-encode a compressed payload"),
    }
    w.finish()
}

fn read_stamp(r: &mut Reader) -> Result<ClockStamp> {
    Ok(ClockStamp { worker: r.u32()?, step: r.u64()?, version: r.u64()? })
}

/// What a decoded stream's unsent coordinates reconstruct to.
enum Base<'a> {
    /// gradient streams: unsent mass is zero here (it lives in the sender's
    /// residual and arrives with a later message)
    Zeros,
    /// state streams: unsent coordinates keep the receiver's current value,
    /// so a sparse parameter push is a partial mix, not a zero overwrite
    Fill(&'a [f32]),
}

/// Decode one stream written by a compressing codec. Validates the declared
/// length against the receiver's tensor (`expected`) and every index bound
/// *before* any value lands — malformed input errors out with nothing
/// written.
fn read_stream(
    r: &mut Reader,
    spec: &CodecSpec,
    pool: &ShardPool,
    expected: usize,
    base: Base,
) -> Result<Vec<f32>> {
    let n = r.u32()? as usize;
    if n != expected {
        bail!("stream declares {n} coordinates, the receiver tensor holds {expected}");
    }
    match spec {
        CodecSpec::TopK { .. } | CodecSpec::RandK { .. } => {
            let k = r.u32()? as usize;
            if k > n {
                bail!("sparse stream keeps {k} of {n} coordinates");
            }
            let idxs = r.u32s(k)?;
            let vals = r.f32s(k)?;
            let mut out = match base {
                Base::Zeros => vec![0.0; n],
                Base::Fill(b) => b.to_vec(),
            };
            let mut prev = None;
            for (&i, &v) in idxs.iter().zip(&vals) {
                if i as usize >= n || prev.is_some_and(|p| i <= p) {
                    bail!("sparse indices must be strictly ascending and < {n}");
                }
                prev = Some(i);
                out[i as usize] = v;
            }
            Ok(out)
        }
        CodecSpec::Int8 => {
            let scales = r.f32s(n.div_ceil(CHUNK))?;
            let q = r.take(n)?;
            let mut out = vec![0.0; n];
            int8_decode(pool, &scales, q, &mut out);
            Ok(out)
        }
        CodecSpec::Dense => bail!("dense payloads ride uncompressed"),
    }
}

impl Compressed {
    /// Wire size of this message: the fixed header the dense payloads also
    /// pay, plus the codec blob.
    pub fn encoded_len(&self) -> u64 {
        crate::comm::wire_bytes(0) + self.blob.len() as u64
    }

    /// Decode at the receiver (`wid`) into the dense payload `apply`
    /// dispatches on. Validation is all-or-nothing: any framing, bound or
    /// shape violation errors out before a single coordinate is
    /// constructed, so a truncated blob can never partially apply.
    pub fn decode(&self, shared: &Shared, wid: usize) -> Result<Payload> {
        let pool = &shared.update_pool;
        let params = shared.params.get(wid).context("receiver id out of range")?;
        let spec = &self.spec;
        let mut r = Reader::new(&self.blob);
        let payload = match r.u8()? {
            0 => {
                let layer = r.u32()? as usize;
                let open = if r.bool()? { Some(r.f32()?) } else { None };
                let stamp = read_stamp(&mut r)?;
                let tau = r.u64()?;
                let nt = r.u32()? as usize;
                let lp = params.layers.get(layer).context("LayerPush layer out of range")?;
                let held = lp.tensors.len();
                if nt != held {
                    bail!("LayerPush carries {nt} tensors, layer {layer} holds {held}");
                }
                let mut values = Vec::with_capacity(nt);
                for t in &lp.tensors {
                    let b = t.state_dict();
                    values.push(read_stream(&mut r, spec, pool, b.len(), Base::Fill(&b))?);
                }
                Payload::LayerPush { layer, open, values: Arc::new(values), stamp, tau }
            }
            1 => {
                let w_in = r.f32()?;
                let nl = r.u32()? as usize;
                if nl != params.layers.len() {
                    bail!("ModelPush carries {nl} layers, the model holds {}", params.layers.len());
                }
                let mut values = Vec::with_capacity(nl);
                for lp in &params.layers {
                    let nt = r.u32()? as usize;
                    if nt != lp.tensors.len() {
                        bail!("ModelPush layer tensor count mismatch");
                    }
                    let mut layer = Vec::with_capacity(nt);
                    for t in &lp.tensors {
                        let b = t.state_dict();
                        layer.push(read_stream(&mut r, spec, pool, b.len(), Base::Fill(&b))?);
                    }
                    values.push(layer);
                }
                Payload::ModelPush { w_in, values: Arc::new(values) }
            }
            2 => {
                let reply = r.bool()?;
                let b = params.flatten();
                let flat = read_stream(&mut r, spec, pool, b.len(), Base::Fill(&b))?;
                Payload::PairAverage { flat: Arc::new(flat), reply }
            }
            3 => {
                let nl = r.u32()? as usize;
                if nl != params.layers.len() {
                    bail!("GradShare carries {nl} layers, the model holds {}", params.layers.len());
                }
                let mut set = Vec::with_capacity(nl);
                for lp in &params.layers {
                    let nt = r.u32()? as usize;
                    if nt != lp.tensors.len() {
                        bail!("GradShare layer tensor count mismatch");
                    }
                    let mut layer = Vec::with_capacity(nt);
                    for t in &lp.tensors {
                        let data = read_stream(&mut r, spec, pool, t.numel(), Base::Zeros)?;
                        layer.push(Tensor::from_vec(t.shape(), data));
                    }
                    set.push(layer);
                }
                Payload::GradShare { set: Arc::new(set) }
            }
            4 => {
                let b = params.flatten();
                let flat = read_stream(&mut r, spec, pool, b.len(), Base::Fill(&b))?;
                Payload::ParamShare { flat: Arc::new(flat) }
            }
            5 => {
                let layer = r.u32()? as usize;
                let stamp = read_stamp(&mut r)?;
                let ng = r.u32()? as usize;
                let lp = params.layers.get(layer).context("GradPush layer out of range")?;
                let held = lp.tensors.len();
                if ng != held {
                    bail!("GradPush carries {ng} tensors, layer {layer} holds {held}");
                }
                let mut grads = Vec::with_capacity(ng);
                for t in &lp.tensors {
                    grads.push(read_stream(&mut r, spec, pool, t.numel(), Base::Zeros)?);
                }
                let x_then = if r.bool()? {
                    let mut xt = Vec::with_capacity(ng);
                    for t in &lp.tensors {
                        let n = r.u32()? as usize;
                        if n != t.numel() {
                            bail!("GradPush x_then length mismatch");
                        }
                        xt.push(r.f32s(n)?);
                    }
                    Some(Arc::new(xt))
                } else {
                    None
                };
                Payload::GradPush { layer, grads: Arc::new(grads), x_then, stamp }
            }
            6 => {
                let layer = r.u32()? as usize;
                let stamp = read_stamp(&mut r)?;
                let nt = r.u32()? as usize;
                let lp = params.layers.get(layer).context("ParamPull layer out of range")?;
                let held = lp.tensors.len();
                if nt != held {
                    bail!("ParamPull carries {nt} tensors, layer {layer} holds {held}");
                }
                let mut values = Vec::with_capacity(nt);
                for t in &lp.tensors {
                    let b = t.state_dict();
                    values.push(read_stream(&mut r, spec, pool, b.len(), Base::Fill(&b))?);
                }
                Payload::ParamPull { layer, values: Arc::new(values), stamp }
            }
            7 => {
                let open = if r.bool()? { Some(r.f32()?) } else { None };
                let ne = r.u32()? as usize;
                if ne == 0 {
                    bail!("StepFrame carries no entries");
                }
                // entry index table first (all-or-nothing: every layer id
                // and tensor count validates before any value decodes)
                let mut meta = Vec::with_capacity(ne);
                let mut fill: Vec<f32> = Vec::new();
                for _ in 0..ne {
                    let layer = r.u32()? as usize;
                    let stamp = read_stamp(&mut r)?;
                    let tau = r.u64()?;
                    let nt = r.u32()? as usize;
                    let lp = params.layers.get(layer).context("StepFrame layer out of range")?;
                    let held = lp.tensors.len();
                    if nt != held {
                        bail!("StepFrame entry carries {nt} tensors, layer {layer} holds {held}");
                    }
                    for t in &lp.tensors {
                        fill.extend_from_slice(&t.state_dict());
                    }
                    meta.push((layer, stamp, tau));
                }
                // one stream over the step's concatenation, unsent
                // coordinates filled from the receiver's own values
                let flat = read_stream(&mut r, spec, pool, fill.len(), Base::Fill(&fill))?;
                let mut off = 0usize;
                let mut entries = Vec::with_capacity(ne);
                for (layer, stamp, tau) in meta {
                    let lp = &params.layers[layer];
                    let mut values = Vec::with_capacity(lp.tensors.len());
                    for t in &lp.tensors {
                        let n = t.numel();
                        values.push(flat[off..off + n].to_vec());
                        off += n;
                    }
                    entries.push(crate::comm::FrameEntry {
                        layer,
                        stamp,
                        tau,
                        values: Arc::new(values),
                    });
                }
                Payload::StepFrame { open, entries: Arc::new(entries) }
            }
            tag => bail!("unknown compressed payload tag {tag}"),
        };
        r.done()?;
        Ok(payload)
    }
}

// ---------------------------------------------------------------------------
// top-k / rand-k sparsification with error feedback
// ---------------------------------------------------------------------------

/// `topk:K` / `randk:K`: ship `ceil(n/K)` coordinates per tensor, with
/// per-link per-stream error-feedback residuals on gradient streams.
pub struct SparsifyCodec {
    spec: CodecSpec,
    rand: bool,
    k: u32,
    m: usize,
    seed: u64,
    /// per directed link (`from * m + to`): residual per gradient stream,
    /// ordered by key so snapshots are deterministic
    residuals: Vec<Mutex<BTreeMap<StreamKey, Vec<f32>>>>,
    /// per-link message counters (rand-k index draws)
    seqs: Vec<AtomicU64>,
}

impl SparsifyCodec {
    pub fn new(k: u32, rand: bool, m: usize, seed: u64) -> SparsifyCodec {
        let spec = if rand { CodecSpec::RandK { k } } else { CodecSpec::TopK { k } };
        SparsifyCodec {
            spec,
            rand,
            k: k.max(2),
            m,
            seed,
            residuals: (0..m * m).map(|_| Mutex::new(BTreeMap::new())).collect(),
            seqs: (0..m * m).map(|_| AtomicU64::new(0)).collect(),
        }
    }

    /// Coordinates kept for an `n`-element tensor (at least one, so every
    /// stream makes progress).
    fn keep(&self, n: usize) -> usize {
        n.div_ceil(self.k as usize).clamp(1, n)
    }

    fn select(&self, pool: &ShardPool, y: &[f32], k: usize, seed: u64) -> Vec<u32> {
        if !self.rand {
            return top_k_indices(pool, y, k);
        }
        // Floyd's k-of-n sample: deterministic under the stream seed, and
        // drawn from the codec's own RNG — link dice are untouched
        let n = y.len();
        let mut rng = Pcg32::new(seed);
        let mut picked = std::collections::BTreeSet::new();
        for j in (n - k)..n {
            let t = rng.below_usize(j + 1) as u32;
            if !picked.insert(t) {
                picked.insert(j as u32);
            }
        }
        picked.into_iter().collect()
    }

    /// Encode one stream. Gradient streams run error feedback: the residual
    /// is re-added (`y = x + r`), the kept coordinates of `y` ship exactly
    /// and leave the residual, everything else *is* the new residual —
    /// `sent + residual == x + old residual`, coordinate-wise bit-exact.
    fn stream(
        &self,
        w: &mut Writer,
        pool: &ShardPool,
        ctx: &StreamCtx,
        class: StreamClass,
        x: &[f32],
    ) {
        let n = x.len();
        if n == 0 {
            w.u32(0);
            w.u32(0);
            return;
        }
        let k = self.keep(n);
        match class {
            StreamClass::Grad => {
                let mut link = self.residuals[ctx.link].lock().unwrap();
                let r = link.entry(ctx.key).or_default();
                if r.len() != n {
                    // a shape change (new run phase) invalidates the residual
                    r.clear();
                    r.resize(n, 0.0);
                }
                let mut y = vec![0.0f32; n];
                add_residual(pool, x, r, &mut y);
                let idxs = self.select(pool, &y, k, ctx.seed);
                w.u32(n as u32);
                w.u32(idxs.len() as u32);
                w.u32s(&idxs);
                r.copy_from_slice(&y);
                for &i in &idxs {
                    w.f32(y[i as usize]);
                    r[i as usize] = 0.0;
                }
            }
            StreamClass::State => {
                let idxs = self.select(pool, x, k, ctx.seed);
                w.u32(n as u32);
                w.u32(idxs.len() as u32);
                w.u32s(&idxs);
                for &i in &idxs {
                    w.f32(x[i as usize]);
                }
            }
        }
    }

    /// Walk a blob this codec produced and fold every gradient stream's
    /// shipped coordinates back into the link residual (the kept slots were
    /// zeroed at encode, so the residual returns to the full accumulated
    /// gradient — drop-composable with push-sum weight reclaim).
    fn reclaim_from_blob(&self, link: usize, blob: &[u8]) -> Result<()> {
        let mut r = Reader::new(blob);
        let mut sparse = |r: &mut Reader, key: Option<StreamKey>| -> Result<()> {
            let n = r.u32()? as usize;
            let k = r.u32()? as usize;
            if k > n {
                bail!("bad sparse framing");
            }
            let idxs = r.u32s(k)?;
            let vals = r.f32s(k)?;
            if let Some(key) = key {
                let mut map = self.residuals[link].lock().unwrap();
                let res = map.entry(key).or_default();
                if res.len() != n {
                    res.clear();
                    res.resize(n, 0.0);
                }
                for (&i, &v) in idxs.iter().zip(&vals) {
                    if (i as usize) < n {
                        res[i as usize] += v;
                    }
                }
            }
            Ok(())
        };
        match r.u8()? {
            3 => {
                let nl = r.u32()? as usize;
                for li in 0..nl {
                    let nt = r.u32()? as usize;
                    for ti in 0..nt {
                        let key = StreamKey { tag: 3, layer: li as u32, tensor: ti as u32 };
                        sparse(&mut r, Some(key))?;
                    }
                }
            }
            5 => {
                let layer = r.u32()?;
                read_stamp(&mut r)?;
                let ng = r.u32()? as usize;
                for ti in 0..ng {
                    let key = StreamKey { tag: 5, layer, tensor: ti as u32 };
                    sparse(&mut r, Some(key))?;
                }
                // x_then (dense) carries no gradient mass — nothing to reclaim
            }
            // state-only payloads carry no gradient mass
            _ => {}
        }
        Ok(())
    }

    fn msg_seed(&self, link: usize) -> u64 {
        let seq = self.seqs[link].fetch_add(1, Ordering::Relaxed);
        mix64(
            self.seed
                ^ (link as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15)
                ^ seq.wrapping_mul(0xD1B5_4A32_D192_ED03),
        )
    }
}

impl Codec for SparsifyCodec {
    fn spec(&self) -> &CodecSpec {
        &self.spec
    }

    fn encode(&self, pool: &ShardPool, from: usize, to: usize, payload: Payload) -> Payload {
        if matches!(payload, Payload::Compressed(_)) {
            return payload; // checkpoint restore: already on the wire
        }
        let link = from * self.m + to;
        let msg_seed = self.msg_seed(link);
        let shipped_w = payload.shipped_weight();
        let droppable = payload.droppable();
        let mut ix = 0u64;
        let blob = build_blob(&payload, |w, key, class, x| {
            let ctx = StreamCtx { link, key, seed: mix64(msg_seed ^ (ix + 1)) };
            ix += 1;
            self.stream(w, pool, &ctx, class, x);
        });
        Payload::Compressed(Compressed {
            spec: self.spec.clone(),
            shipped_w,
            droppable,
            blob: Arc::new(blob),
        })
    }

    fn on_drop(&self, from: usize, to: usize, payload: &Payload) {
        let Payload::Compressed(c) = payload else { return };
        if c.spec != self.spec {
            return;
        }
        // a blob this codec produced always parses; a restore-path blob from
        // a different run shape at worst reclaims nothing
        let reclaimed = self.reclaim_from_blob(from * self.m + to, &c.blob);
        debug_assert!(reclaimed.is_ok(), "residual reclaim failed: {reclaimed:?}");
    }

    fn residual_state(&self) -> Vec<ResidualState> {
        let mut out = Vec::new();
        for (link, slot) in self.residuals.iter().enumerate() {
            let map = slot.lock().unwrap();
            if map.is_empty() {
                continue;
            }
            out.push(ResidualState {
                from: link / self.m,
                to: link % self.m,
                streams: map.iter().map(|(k, v)| (*k, v.clone())).collect(),
            });
        }
        out
    }

    fn load_residual_state(&self, states: &[ResidualState]) {
        for slot in &self.residuals {
            slot.lock().unwrap().clear();
        }
        for rs in states {
            let link = rs.from * self.m + rs.to;
            if let Some(slot) = self.residuals.get(link) {
                let mut map = slot.lock().unwrap();
                for (key, vals) in &rs.streams {
                    map.insert(*key, vals.clone());
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// int8 stochastic quantization
// ---------------------------------------------------------------------------

/// `int8`: per-chunk max-abs scales + unbiased stochastic rounding (~4×
/// fewer wire bytes). Lossy but dense — every coordinate arrives, so no
/// error feedback is needed; the quantization error is zero-mean and
/// bounded by one scale step per element.
pub struct Int8Codec {
    seed: u64,
}

impl Codec for Int8Codec {
    fn spec(&self) -> &CodecSpec {
        &CodecSpec::Int8
    }

    fn encode(&self, pool: &ShardPool, from: usize, to: usize, payload: Payload) -> Payload {
        if matches!(payload, Payload::Compressed(_)) {
            return payload;
        }
        let shipped_w = payload.shipped_weight();
        let droppable = payload.droppable();
        // stateless per-message seed: both endpoints of a link share the
        // stream, keyed off a global counter so repeated pushes of the same
        // tensor draw fresh rounding noise
        static MSG: AtomicU64 = AtomicU64::new(0);
        let msg_seed = mix64(
            self.seed
                ^ ((from * 31 + to) as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15)
                ^ MSG.fetch_add(1, Ordering::Relaxed).wrapping_mul(0xD1B5_4A32_D192_ED03),
        );
        let mut ix = 0u64;
        let blob = build_blob(&payload, |w, _key, _class, x| {
            let seed = mix64(msg_seed ^ (ix + 1));
            ix += 1;
            let n = x.len();
            w.u32(n as u32);
            let mut scales = vec![0.0f32; n.div_ceil(CHUNK)];
            let mut q = vec![0u8; n];
            int8_encode(pool, x, seed, &mut scales, &mut q);
            w.f32s(&scales);
            w.bytes(&q);
        });
        Payload::Compressed(Compressed {
            spec: CodecSpec::Int8,
            shipped_w,
            droppable,
            blob: Arc::new(blob),
        })
    }
}
