//! Minimal little-endian reader/writer for compressed payload blobs.
//!
//! The same framing discipline as the checkpoint codec (exact f32 bits, no
//! decimal round-tripping), but scoped to one message: a blob is built once
//! at encode time and parsed once at apply time. Every `Reader` accessor
//! bounds-checks before it allocates, so a truncated or hostile blob can
//! never partially apply or OOM the process — decode errors surface as
//! `ApplyResult::Malformed` at the fabric boundary.

use anyhow::{bail, Result};

/// Append-only little-endian byte sink.
#[derive(Default)]
pub struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    pub fn with_capacity(cap: usize) -> Writer {
        Writer { buf: Vec::with_capacity(cap) }
    }

    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn bool(&mut self, v: bool) {
        self.u8(u8::from(v));
    }

    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Raw values, no length prefix (the caller frames counts explicitly).
    pub fn f32s(&mut self, vs: &[f32]) {
        self.buf.reserve(4 * vs.len());
        for &v in vs {
            self.f32(v);
        }
    }

    /// Raw index values, no length prefix.
    pub fn u32s(&mut self, vs: &[u32]) {
        self.buf.reserve(4 * vs.len());
        for &v in vs {
            self.u32(v);
        }
    }

    /// Raw bytes, no length prefix.
    pub fn bytes(&mut self, bs: &[u8]) {
        self.buf.extend_from_slice(bs);
    }

    pub fn finish(self) -> Vec<u8> {
        self.buf
    }
}

/// Bounds-checked little-endian cursor over an encoded blob.
pub struct Reader<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Reader<'a> {
    pub fn new(b: &'a [u8]) -> Reader<'a> {
        Reader { b, i: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.b.len() - self.i
    }

    pub fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if n > self.remaining() {
            bail!("compressed blob truncated at byte {} (wanted {n} more)", self.i);
        }
        let out = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(out)
    }

    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn bool(&mut self) -> Result<bool> {
        Ok(self.u8()? != 0)
    }

    pub fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    /// `n` values, validated against the remaining length *before* the
    /// allocation (a corrupt count must error, not OOM).
    pub fn f32s(&mut self, n: usize) -> Result<Vec<f32>> {
        let bytes = self.take(n.checked_mul(4).unwrap_or(usize::MAX))?;
        Ok(bytes.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect())
    }

    /// `n` index values, same bounds discipline as [`Reader::f32s`].
    pub fn u32s(&mut self, n: usize) -> Result<Vec<u32>> {
        let bytes = self.take(n.checked_mul(4).unwrap_or(usize::MAX))?;
        Ok(bytes.chunks_exact(4).map(|c| u32::from_le_bytes(c.try_into().unwrap())).collect())
    }

    /// The blob must be fully consumed — trailing bytes mean a framing bug
    /// or tampering, and either way the message is malformed.
    pub fn done(&self) -> Result<()> {
        if self.i != self.b.len() {
            bail!("compressed blob has {} trailing bytes", self.remaining());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_is_bit_exact() {
        let mut w = Writer::with_capacity(64);
        w.u8(7);
        w.bool(true);
        w.u32(0xDEAD_BEEF);
        w.u64(u64::MAX - 1);
        w.f32(f32::MIN_POSITIVE);
        w.f32s(&[1.5, -0.0, f32::NAN]);
        w.u32s(&[0, 3, u32::MAX]);
        w.bytes(&[9, 8]);
        let buf = w.finish();
        let mut r = Reader::new(&buf);
        assert_eq!(r.u8().unwrap(), 7);
        assert!(r.bool().unwrap());
        assert_eq!(r.u32().unwrap(), 0xDEAD_BEEF);
        assert_eq!(r.u64().unwrap(), u64::MAX - 1);
        assert_eq!(r.f32().unwrap().to_bits(), f32::MIN_POSITIVE.to_bits());
        let fs = r.f32s(3).unwrap();
        assert_eq!(fs[0].to_bits(), 1.5f32.to_bits());
        assert_eq!(fs[1].to_bits(), (-0.0f32).to_bits());
        assert!(fs[2].is_nan());
        assert_eq!(r.u32s(3).unwrap(), vec![0, 3, u32::MAX]);
        assert_eq!(r.take(2).unwrap(), &[9, 8]);
        r.done().unwrap();
    }

    #[test]
    fn truncation_and_trailing_bytes_error() {
        let mut w = Writer::default();
        w.u32(5);
        let buf = w.finish();
        let mut r = Reader::new(&buf[..3]);
        assert!(r.u32().is_err());
        // a huge declared count must error before allocating
        let mut r = Reader::new(&buf);
        assert!(r.f32s(usize::MAX / 2).is_err());
        let mut r = Reader::new(&buf);
        r.u32().unwrap();
        r.done().unwrap();
        let mut r = Reader::new(&buf);
        r.u8().unwrap();
        assert!(r.done().is_err(), "3 unread bytes must be rejected");
    }
}
