//! The simulated transport: per-link FIFO queues with seeded latency,
//! bandwidth-derived serialization delay and drop probability. Queued
//! messages are applied by the *receiving* worker at its step boundaries
//! (`Fabric::deliver_due`), so a delayed link shows up exactly where it does
//! on real hardware: synchronous algorithms stall on it, asynchronous ones
//! absorb it as staleness.
//!
//! Link model, per message: the transmitter serializes at `bytes/bandwidth`
//! (links are half-duplex per direction, so back-to-back messages queue
//! behind each other), then the sampled propagation latency applies, and
//! delivery order on a link is clamped to FIFO (in-order, TCP-like).
//! Droppable payloads are lost at *send* time with probability `drop_prob`
//! so the sender can reclaim shipped push-sum weight — mass is delayed or
//! returned, never destroyed.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use std::sync::Arc;

use crate::comm::{
    apply, ApplyResult, Codec, Fabric, FabricCore, InFlight, LatencyDist, Payload, PushOutcome,
};
use crate::coordinator::Shared;
use crate::util::rng::Pcg32;

/// One message queued on a link.
struct Queued {
    seq: u64,
    ready_at: f64,
    from: usize,
    step: usize,
    payload: Payload,
}

/// Sender-side state of one directed link.
struct Link {
    /// when the transmitter frees up (bandwidth serialization)
    next_free: f64,
    /// last scheduled arrival (enforces per-link FIFO delivery)
    last_ready: f64,
    /// seeded per-link randomness (latency samples, drop decisions)
    rng: Pcg32,
}

/// See the module docs: queued per-link channels with delay, bandwidth and
/// loss. Construct via `crate::comm::build_fabric` or directly in tests.
pub struct SimFabric {
    core: FabricCore,
    latency: LatencyDist,
    bandwidth_bytes_per_s: f64,
    drop_prob: f64,
    epoch: Instant,
    seq: AtomicU64,
    /// indexed `from * m + to`
    links: Vec<Mutex<Link>>,
    /// per receiver
    inboxes: Vec<Mutex<Vec<Queued>>>,
}

impl SimFabric {
    /// A simulated fabric connecting `m` workers; all link randomness is
    /// derived from `seed`. Dense (identity) codec.
    pub fn new(
        latency: LatencyDist,
        bandwidth_bytes_per_s: f64,
        drop_prob: f64,
        m: usize,
        seed: u64,
    ) -> SimFabric {
        SimFabric::with_codec(
            latency,
            bandwidth_bytes_per_s,
            drop_prob,
            m,
            seed,
            Arc::new(crate::comm::codec::DenseCodec),
        )
    }

    /// A simulated fabric with a compression codec installed at the push
    /// boundary: serialization delay and byte metering see encoded sizes.
    pub fn with_codec(
        latency: LatencyDist,
        bandwidth_bytes_per_s: f64,
        drop_prob: f64,
        m: usize,
        seed: u64,
        codec: Arc<dyn Codec>,
    ) -> SimFabric {
        SimFabric::with_options(latency, bandwidth_bytes_per_s, drop_prob, m, seed, codec, false)
    }

    /// A simulated fabric with a codec **and** the step-frame coalescing
    /// switch: with `coalesce` on, consecutive `LayerPush`es on a link
    /// buffer in its `FrameBuilder` and hit the wire as one `StepFrame` —
    /// one header, one codec pass, one serialization/delivery event.
    #[allow(clippy::too_many_arguments)]
    pub fn with_options(
        latency: LatencyDist,
        bandwidth_bytes_per_s: f64,
        drop_prob: f64,
        m: usize,
        seed: u64,
        codec: Arc<dyn Codec>,
        coalesce: bool,
    ) -> SimFabric {
        SimFabric {
            core: FabricCore::with_options(m, codec, coalesce),
            latency,
            bandwidth_bytes_per_s,
            drop_prob,
            epoch: Instant::now(),
            seq: AtomicU64::new(0),
            links: (0..m * m)
                .map(|i| {
                    Mutex::new(Link {
                        next_free: 0.0,
                        last_ready: 0.0,
                        rng: Pcg32::new(
                            seed ^ 0xfab2 ^ (i as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15),
                        ),
                    })
                })
                .collect(),
            inboxes: (0..m).map(|_| Mutex::new(Vec::new())).collect(),
        }
    }

    fn now(&self) -> f64 {
        self.epoch.elapsed().as_secs_f64()
    }

    /// Messages queued on the links (sent but not yet applied).
    pub fn pending_count(&self) -> usize {
        self.inboxes.iter().map(|b| b.lock().unwrap().len()).sum()
    }

    /// Push-sum mass currently riding the links, as `(weight, weighted
    /// parameter vector)` — whole-model pushes contribute `w_in * x`
    /// flattened. Diagnostic accessor for the conservation property: mass in
    /// flight is delayed, never destroyed. Compressed messages contribute
    /// their shipped weight (carried in the clear); the `w·x` ledger skips
    /// them — it would need a receiver-context decode — so codec-enabled
    /// property tests assert on the weight column only.
    pub fn in_flight_push_sum_mass(&self) -> (f64, Vec<f64>) {
        // weight held by open (unflushed) coalescing frame builders is in
        // flight too: the sender shipped it, no receiver has folded it in
        let mut w_total = self.core.frame_open_mass();
        let mut wx: Vec<f64> = Vec::new();
        for inbox in &self.inboxes {
            for q in inbox.lock().unwrap().iter() {
                w_total += q.payload.shipped_weight() as f64;
                if let Payload::ModelPush { w_in, values } = &q.payload {
                    let mut k = 0usize;
                    for layer in values.iter() {
                        for vals in layer {
                            for &v in vals {
                                if wx.len() <= k {
                                    wx.resize(k + 1, 0.0);
                                }
                                wx[k] += *w_in as f64 * v as f64;
                                k += 1;
                            }
                        }
                    }
                }
            }
        }
        (w_total, wx)
    }

    /// Queue one message on the link: encode, roll the drop dice, schedule
    /// serialization + latency, enqueue. Both the public `push` (after
    /// coalescing) and delivery-generated replies land here.
    fn push_wire(
        &self,
        shared: &Shared,
        from: usize,
        to: usize,
        step: usize,
        payload: Payload,
    ) -> PushOutcome {
        let _sp = shared.telemetry.span(crate::telemetry::Phase::FabricPush);
        // codec boundary: everything downstream — serialization delay, drop
        // dice, byte metering, the queue — sees the encoded message
        let payload = {
            let _enc = (!self.core.codec().spec().is_dense())
                .then(|| shared.telemetry.span(crate::telemetry::Phase::CodecEncode));
            self.core.codec().encode(&shared.update_pool, from, to, payload)
        };
        let bytes = payload.encoded_len();
        let m = self.core.workers();
        let ready_at = {
            let mut link = self.links[from * m + to].lock().unwrap();
            if payload.droppable() && self.drop_prob > 0.0 && link.rng.next_f64() < self.drop_prob
            {
                drop(link);
                // the link lost the message: shipped gradient coordinates
                // fold back into the sender-side error-feedback residual
                // (composing with the caller's push-sum weight reclaim)
                self.core.codec().on_drop(from, to, &payload);
                self.core.record_drop(shared, from, to, step, bytes);
                return PushOutcome::Dropped;
            }
            let now = self.now();
            let tx_start = now.max(link.next_free);
            let ser = if self.bandwidth_bytes_per_s > 0.0 {
                bytes as f64 / self.bandwidth_bytes_per_s
            } else {
                0.0
            };
            link.next_free = tx_start + ser;
            let lat = self.latency.sample(&mut link.rng);
            let ready = (link.next_free + lat).max(link.last_ready);
            link.last_ready = ready;
            ready
        };
        self.core.record_send(shared, from, to, step, bytes);
        let seq = self.seq.fetch_add(1, Ordering::Relaxed);
        self.inboxes[to]
            .lock()
            .unwrap()
            .push(Queued { seq, ready_at, from, step, payload });
        PushOutcome::Queued
    }
}

impl Fabric for SimFabric {
    fn core(&self) -> &FabricCore {
        &self.core
    }

    fn is_instant(&self) -> bool {
        false
    }

    fn push(
        &self,
        shared: &Shared,
        from: usize,
        to: usize,
        step: usize,
        payload: Payload,
    ) -> PushOutcome {
        if self.core.coalesce() && matches!(payload, Payload::LayerPush { .. }) {
            // step-frame coalescing: buffer this layer in the link's frame
            // builder; an intermediate push reports Queued, the layer-0
            // close (and any stale-step flush) ships as one StepFrame
            let mut last = PushOutcome::Queued;
            for (fstep, frame) in self.core.coalesce_layer_push(from, to, step, payload) {
                let open = frame.shipped_weight();
                let out = self.push_wire(shared, from, to, fstep, frame);
                if matches!(out, PushOutcome::Dropped) && open > 0.0 {
                    // the frame owns the step's opening weight — hoisted out
                    // of a push the caller already saw Queued for — so the
                    // fabric must refund it; the caller cannot
                    shared.weights[from].reclaim(open);
                }
                last = out;
            }
            return last;
        }
        self.push_wire(shared, from, to, step, payload)
    }

    fn deliver_due(&self, shared: &Shared, wid: usize, recv_step: usize) -> usize {
        let now = self.now();
        let mut due: Vec<Queued> = Vec::new();
        {
            let mut inbox = self.inboxes[wid].lock().unwrap();
            if inbox.is_empty() {
                return 0;
            }
            let mut keep = Vec::with_capacity(inbox.len());
            for q in inbox.drain(..) {
                if q.ready_at <= now {
                    due.push(q);
                } else {
                    keep.push(q);
                }
            }
            *inbox = keep;
        }
        if due.is_empty() {
            return 0;
        }
        let _sp = shared.telemetry.span(crate::telemetry::Phase::FabricDeliver);
        // total_cmp: a NaN ready time (impossible by construction, but this
        // is the same class of bug as the simulator's device pick) must not
        // scramble FIFO order silently
        due.sort_by(|a, b| a.ready_at.total_cmp(&b.ready_at).then(a.seq.cmp(&b.seq)));
        let mut applied = 0usize;
        let mut replies: Vec<(usize, Payload)> = Vec::new();
        let mut leftover: Vec<Queued> = Vec::new();
        let mut it = due.into_iter();
        while let Some(q) = it.next() {
            match apply(&self.core, shared, wid, q.from, q.step, &q.payload) {
                ApplyResult::Busy => {
                    // busy accept slot: delay, never destroy — put this and
                    // everything after it back (preserving order) and retry
                    // at the next boundary
                    leftover.push(q);
                    leftover.extend(it);
                    break;
                }
                ApplyResult::Malformed => {
                    // truncated/corrupt payload: count it as a drop and
                    // refund any shipped push-sum weight to the sender —
                    // never a partial write, mass never destroyed
                    self.core.record_rejected(shared, q.from, wid, q.step);
                    let w = q.payload.shipped_weight();
                    if w > 0.0 {
                        shared.weights[q.from].reclaim(w);
                    }
                }
                ApplyResult::Applied { reply } => {
                    self.core.record_delivered(shared, q.from, wid, q.step, recv_step);
                    if let Some((dest, p)) = reply {
                        replies.push((dest, p));
                    }
                    applied += 1;
                }
            }
        }
        if !leftover.is_empty() {
            let mut inbox = self.inboxes[wid].lock().unwrap();
            leftover.extend(inbox.drain(..));
            *inbox = leftover;
        }
        for (dest, p) in replies {
            // delivery-generated traffic (AD-PSGD's return half) ships from
            // the receiver at its current step
            let _ = self.push(shared, wid, dest, recv_step, p);
        }
        applied
    }

    fn pending_to(&self, wid: usize) -> usize {
        self.inboxes[wid].lock().unwrap().len()
    }

    fn drain(&self, wid: usize) -> Vec<InFlight> {
        let now = self.now();
        let mut queued: Vec<Queued> = self.inboxes[wid].lock().unwrap().drain(..).collect();
        // keep the link's delivery order (ready time, then send sequence)
        queued.sort_by(|a, b| {
            a.ready_at
                .total_cmp(&b.ready_at)
                .then(a.seq.cmp(&b.seq))
        });
        let mut out: Vec<InFlight> = queued
            .into_iter()
            .map(|q| InFlight {
                from: q.from,
                to: wid,
                step: q.step,
                remaining_s: (q.ready_at - now).max(0.0),
                payload: q.payload,
            })
            .collect();
        // open frame builders hold not-yet-wired pushes (coalescing runs):
        // flush them as zero-delay in-flight frames so checkpoints conserve
        // their clock provenance and push-sum mass. They were buffered after
        // everything already queued, so they restore last.
        out.extend(self.core.drain_frames_to(wid));
        out
    }

    fn restore(&self, _shared: &Shared, msgs: Vec<InFlight>) {
        // These messages already paid their send-time dice (drop decision,
        // latency sample, serialization delay) — re-queue them with the
        // remaining delay, in order, without touching the link RNGs.
        let now = self.now();
        for m in msgs {
            let seq = self.seq.fetch_add(1, Ordering::Relaxed);
            self.inboxes[m.to].lock().unwrap().push(Queued {
                seq,
                ready_at: now + m.remaining_s,
                from: m.from,
                step: m.step,
                payload: m.payload,
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use std::sync::Arc;

    use super::*;

    use crate::coordinator::Shared;
    use crate::model::ModelParams;
    use crate::tensor::{AtomicTensor, LayerParams, Tensor};

    fn two_worker_shared(fabric: Arc<dyn Fabric>) -> Arc<Shared> {
        let params = (0..2)
            .map(|w| {
                Arc::new(ModelParams {
                    layers: vec![LayerParams::new(vec![AtomicTensor::from_tensor(
                        &Tensor::from_vec(&[2], vec![w as f32, w as f32]),
                    )])],
                })
            })
            .collect();
        Shared::for_tests(params, fabric)
    }

    #[test]
    fn model_push_queues_then_mixes_at_the_boundary() {
        let sim = Arc::new(SimFabric::new(LatencyDist::Constant(0.0), 0.0, 0.0, 2, 1));
        let fabric: Arc<dyn Fabric> = sim.clone();
        let shared = two_worker_shared(Arc::clone(&fabric));

        let shipped = shared.weights[0].halve(); // 0.5 -> ships 0.25
        let values = Arc::new(vec![vec![vec![5.0f32, 5.0]]]);
        let out = fabric.push(&shared, 0, 1, 3, Payload::ModelPush { w_in: shipped, values });
        assert_eq!(out, PushOutcome::Queued);
        assert_eq!(sim.pending_count(), 1);
        // nothing mutated until the receiver's step boundary
        assert_eq!(shared.params[1].flatten(), vec![1.0, 1.0]);

        assert_eq!(fabric.deliver_due(&shared, 1, 5), 1);
        assert_eq!(sim.pending_count(), 0);
        let frac = 0.25 / 0.75; // w_in / (w_self + w_in)
        let want = (1.0 - frac) * 1.0 + frac * 5.0;
        for v in shared.params[1].flatten() {
            assert!((v - want).abs() < 1e-6, "{v} vs {want}");
        }
        // weight mass folded into the receiver, total conserved
        let total = shared.weights[0].get() + shared.weights[1].get();
        assert!((total - 1.0).abs() < 1e-6);
        let stats = fabric.core().snapshot();
        assert_eq!((stats.msgs_sent, stats.msgs_delivered), (1, 1));
        assert_eq!(stats.staleness_sum, 2, "sent at step 3, delivered at step 5");
    }

    #[test]
    fn busy_slot_requeues_instead_of_destroying() {
        let sim = Arc::new(SimFabric::new(LatencyDist::Constant(0.0), 0.0, 0.0, 2, 2));
        let fabric: Arc<dyn Fabric> = sim.clone();
        let shared = two_worker_shared(Arc::clone(&fabric));

        // claim worker 1's accept slot so the delivery finds it busy
        assert!(shared.weights[1].try_accept(0.0).is_some());
        let shipped = shared.weights[0].halve();
        let values = Arc::new(vec![vec![vec![2.0f32, 2.0]]]);
        let _ = fabric.push(&shared, 0, 1, 0, Payload::ModelPush { w_in: shipped, values });
        assert_eq!(fabric.deliver_due(&shared, 1, 0), 0);
        assert_eq!(sim.pending_count(), 1, "busy delivery is re-queued, not lost");

        shared.weights[1].release();
        assert_eq!(fabric.deliver_due(&shared, 1, 1), 1);
        assert_eq!(sim.pending_count(), 0);
    }

    #[test]
    fn drops_are_counted_and_the_sender_reclaims() {
        // probability > 1 (config validation forbids it, the raw constructor
        // does not): every draw of next_f64() in [0,1) hits, deterministically
        let sim = Arc::new(SimFabric::new(LatencyDist::Constant(0.0), 0.0, 2.0, 2, 9));
        let fabric: Arc<dyn Fabric> = sim.clone();
        let shared = two_worker_shared(Arc::clone(&fabric));

        let before = shared.weights[0].get();
        let shipped = shared.weights[0].halve();
        let values = Arc::new(vec![vec![vec![1.0f32, 1.0]]]);
        let out = fabric.push(&shared, 0, 1, 0, Payload::ModelPush { w_in: shipped, values });
        assert_eq!(out, PushOutcome::Dropped);
        shared.weights[0].reclaim(shipped);
        assert!((shared.weights[0].get() - before).abs() < 1e-7);
        assert_eq!(sim.pending_count(), 0);

        let stats = fabric.core().snapshot();
        assert_eq!(stats.msgs_dropped, 1);
        assert_eq!(stats.msgs_delivered, 0);
        // reliable payloads are never dropped
        let out = fabric.push(
            &shared,
            0,
            1,
            0,
            Payload::ParamShare { flat: Arc::new(vec![0.0; 4]) },
        );
        assert_eq!(out, PushOutcome::Queued);
    }

    /// Checkpoint quiesce contract: drain removes queued traffic without
    /// applying it, restore re-queues it with its remaining delay, and the
    /// push-sum mass riding the links survives the round trip.
    #[test]
    fn drain_restore_roundtrip_conserves_in_flight_mass() {
        let sim = Arc::new(SimFabric::new(LatencyDist::Constant(0.0), 0.0, 0.0, 2, 4));
        let fabric: Arc<dyn Fabric> = sim.clone();
        let shared = two_worker_shared(Arc::clone(&fabric));

        let shipped = shared.weights[0].halve();
        let values = Arc::new(vec![vec![vec![5.0f32, 5.0]]]);
        let _ = fabric.push(&shared, 0, 1, 3, Payload::ModelPush { w_in: shipped, values });
        let (mass_before, _) = sim.in_flight_push_sum_mass();
        assert!((mass_before - shipped as f64).abs() < 1e-9);

        let msgs = fabric.drain(1);
        assert_eq!(msgs.len(), 1);
        assert_eq!((msgs[0].from, msgs[0].to, msgs[0].step), (0, 1, 3));
        assert_eq!(sim.pending_count(), 0, "drained, nothing queued");
        let (mass_drained, _) = sim.in_flight_push_sum_mass();
        assert_eq!(mass_drained, 0.0);
        // nothing was applied: the receiver is untouched
        assert_eq!(shared.params[1].flatten(), vec![1.0, 1.0]);

        fabric.restore(&shared, msgs);
        assert_eq!(sim.pending_count(), 1);
        let (mass_restored, _) = sim.in_flight_push_sum_mass();
        assert!((mass_restored - shipped as f64).abs() < 1e-9, "mass back on the links");

        assert_eq!(fabric.deliver_due(&shared, 1, 5), 1);
        let total = shared.weights[0].get() + shared.weights[1].get();
        assert!((total - 1.0).abs() < 1e-6, "total mass conserved end-to-end");
    }

    /// PS payloads ride the drain/restore checkpoint path like any other
    /// traffic: a queued `GradPush` and `ParamPull` survive the round trip
    /// with gradients, `x_then` provenance and remaining delay intact. They
    /// carry no push-sum weight, so the in-flight mass ledger stays empty.
    #[test]
    fn ps_payloads_survive_drain_restore() {
        let sim = Arc::new(SimFabric::new(LatencyDist::Constant(10.0), 0.0, 0.0, 2, 6));
        let fabric: Arc<dyn Fabric> = sim.clone();
        let shared = two_worker_shared(Arc::clone(&fabric));

        let stamp = shared.params[0].layers[0].clock.stamp();
        let _ = fabric.push(
            &shared,
            0,
            1,
            2,
            Payload::GradPush {
                layer: 0,
                grads: Arc::new(vec![vec![0.5, -0.5]]),
                x_then: Some(Arc::new(vec![vec![1.0, 1.0]])),
                stamp,
            },
        );
        let _ = fabric.push(
            &shared,
            1,
            0,
            2,
            Payload::ParamPull { layer: 0, values: Arc::new(vec![vec![4.0, 4.0]]), stamp },
        );
        let (mass, _) = sim.in_flight_push_sum_mass();
        assert_eq!(mass, 0.0, "PS traffic carries no push-sum weight");

        let to1 = fabric.drain(1);
        let to0 = fabric.drain(0);
        assert_eq!((to1.len(), to0.len()), (1, 1));
        assert!(matches!(
            &to1[0].payload,
            Payload::GradPush { layer: 0, x_then: Some(_), .. }
        ));
        assert!(matches!(&to0[0].payload, Payload::ParamPull { layer: 0, .. }));
        assert!(to1[0].remaining_s > 5.0, "remaining {}", to1[0].remaining_s);

        fabric.restore(&shared, to1);
        fabric.restore(&shared, to0);
        assert_eq!(sim.pending_count(), 2);
        // the restored delay still gates delivery, exactly as before drain
        assert_eq!(fabric.deliver_due(&shared, 1, 10), 0);
    }

    /// Drained messages carry their remaining delay: restoring a not-yet-due
    /// message keeps it undeliverable until that delay passes.
    #[test]
    fn drain_preserves_remaining_latency() {
        let sim = Arc::new(SimFabric::new(LatencyDist::Constant(30.0), 0.0, 0.0, 2, 5));
        let fabric: Arc<dyn Fabric> = sim.clone();
        let shared = two_worker_shared(Arc::clone(&fabric));
        let _ = fabric.push(
            &shared,
            0,
            1,
            0,
            Payload::ParamShare { flat: Arc::new(vec![1.0, 1.0]) },
        );
        let msgs = fabric.drain(1);
        assert_eq!(msgs.len(), 1);
        assert!(
            msgs[0].remaining_s > 25.0 && msgs[0].remaining_s <= 30.0,
            "remaining {}",
            msgs[0].remaining_s
        );
        fabric.restore(&shared, msgs);
        assert_eq!(fabric.deliver_due(&shared, 1, 0), 0, "still not due after restore");
        assert_eq!(sim.pending_count(), 1);
    }

    /// Satellite: a truncated payload is rejected at delivery in RELEASE
    /// builds too — counted as a drop, never a partial write, shipped
    /// push-sum weight refunded to the sender.
    #[test]
    fn malformed_payload_counts_as_drop_never_partial_write() {
        let sim = Arc::new(SimFabric::new(LatencyDist::Constant(0.0), 0.0, 0.0, 2, 8));
        let fabric: Arc<dyn Fabric> = sim.clone();
        let shared = two_worker_shared(Arc::clone(&fabric));

        let before = shared.params[1].flatten();
        let w_before: f32 = shared.weights.iter().map(|w| w.get()).sum();
        let shipped = shared.weights[0].halve();
        // receiver tensors hold 2 values; this push carries only 1
        let _ = fabric.push(
            &shared,
            0,
            1,
            0,
            Payload::ModelPush { w_in: shipped, values: Arc::new(vec![vec![vec![9.0]]]) },
        );
        assert_eq!(fabric.deliver_due(&shared, 1, 1), 0, "malformed is not applied");
        assert_eq!(shared.params[1].flatten(), before, "no partial write");
        let stats = fabric.core().snapshot();
        assert_eq!(stats.msgs_dropped, 1, "counted as a drop");
        assert_eq!(stats.msgs_delivered, 0);
        let w_after: f32 = shared.weights.iter().map(|w| w.get()).sum();
        assert!((w_after - w_before).abs() < 1e-6, "shipped weight refunded to the sender");

        // a truncated LayerPush is rejected the same way
        let _ = fabric.push(
            &shared,
            0,
            1,
            1,
            Payload::LayerPush {
                layer: 0,
                open: None,
                values: Arc::new(vec![vec![1.0]]), // store holds 2 values
                stamp: crate::tensor::clock::ClockStamp::default(),
                tau: 0,
            },
        );
        assert_eq!(fabric.deliver_due(&shared, 1, 2), 0);
        assert_eq!(shared.params[1].flatten(), before);
        // an out-of-range layer index is rejected too (no panic)
        let _ = fabric.push(
            &shared,
            0,
            1,
            2,
            Payload::LayerPush {
                layer: 7,
                open: None,
                values: Arc::new(vec![vec![1.0, 1.0]]),
                stamp: crate::tensor::clock::ClockStamp::default(),
                tau: 0,
            },
        );
        assert_eq!(fabric.deliver_due(&shared, 1, 3), 0);
        assert_eq!(fabric.core().snapshot().msgs_dropped, 3);
    }

    #[test]
    fn latency_holds_messages_until_due() {
        let sim = Arc::new(SimFabric::new(LatencyDist::Constant(30.0), 0.0, 0.0, 2, 3));
        let fabric: Arc<dyn Fabric> = sim.clone();
        let shared = two_worker_shared(Arc::clone(&fabric));
        let _ = fabric.push(
            &shared,
            0,
            1,
            0,
            Payload::ParamShare { flat: Arc::new(vec![1.0, 1.0]) },
        );
        assert_eq!(fabric.deliver_due(&shared, 1, 0), 0, "30s latency: not due yet");
        assert_eq!(sim.pending_count(), 1);
        assert!(fabric.core().latest_params(1, 0).is_none());
    }

    /// A 2-worker Shared with `layers` single-tensor layers of `dim` values
    /// each (worker w starts at `w`), for the coalescing tests.
    fn layered_shared(fabric: Arc<dyn Fabric>, layers: usize, dim: usize) -> Arc<Shared> {
        let params = (0..2)
            .map(|w| {
                Arc::new(ModelParams {
                    layers: (0..layers)
                        .map(|_| {
                            LayerParams::new(vec![AtomicTensor::from_tensor(&Tensor::from_vec(
                                &[dim],
                                vec![w as f32; dim],
                            ))])
                        })
                        .collect(),
                })
            })
            .collect();
        Shared::for_tests(params, fabric)
    }

    fn lp(layer: usize, open: Option<f32>, dim: usize) -> Payload {
        Payload::LayerPush {
            layer,
            open,
            values: Arc::new(vec![vec![3.0; dim]]),
            stamp: crate::tensor::clock::ClockStamp { worker: 0, step: 0, version: 1 },
            tau: 0,
        }
    }

    /// Satellite: with coalescing on, an L-layer step hits the link as ONE
    /// serialization event instead of L — fewer, larger messages, and
    /// strictly fewer wire bytes (per-push headers amortize into 24-byte
    /// frame entries, a net win once L > 4).
    #[test]
    fn coalescing_ships_fewer_larger_messages() {
        use crate::comm::{wire_bytes, FRAME_ENTRY_BYTES};
        const LAYERS: usize = 8;
        const DIM: usize = 4;
        let mut queued = Vec::new();
        let mut stats = Vec::new();
        for coalesce in [false, true] {
            let sim = Arc::new(SimFabric::with_options(
                LatencyDist::Constant(0.0),
                1e6,
                0.0,
                2,
                11,
                Arc::new(crate::comm::codec::DenseCodec),
                coalesce,
            ));
            let fabric: Arc<dyn Fabric> = sim.clone();
            let shared = layered_shared(Arc::clone(&fabric), LAYERS, DIM);
            let shipped = shared.weights[0].halve();
            for layer in (0..LAYERS).rev() {
                let open = (layer == LAYERS - 1).then_some(shipped);
                let out = fabric.push(&shared, 0, 1, 0, lp(layer, open, DIM));
                assert_eq!(out, PushOutcome::Queued);
            }
            queued.push(sim.pending_count());
            stats.push(fabric.core().snapshot());
        }
        assert_eq!(queued[0], LAYERS, "uncoalesced: one wire event per layer");
        assert_eq!(queued[1], 1, "coalesced: the whole step is one event");
        assert_eq!(stats[0].msgs_sent as usize, LAYERS);
        assert_eq!(stats[1].msgs_sent, 1);
        assert_eq!(stats[0].bytes_sent, LAYERS as u64 * wire_bytes(DIM));
        assert_eq!(
            stats[1].bytes_sent,
            wire_bytes(LAYERS * DIM) + LAYERS as u64 * FRAME_ENTRY_BYTES
        );
        assert!(stats[1].bytes_sent < stats[0].bytes_sent, "headers amortized");
    }

    /// The step's opening weight is hoisted out of a push the caller
    /// already saw `Queued` for; when the closing flush then rolls a drop,
    /// the FABRIC refunds it — the caller cannot, and must not.
    #[test]
    fn dropped_frame_refunds_the_hoisted_opening_weight() {
        let sim = Arc::new(SimFabric::with_options(
            LatencyDist::Constant(0.0),
            0.0,
            2.0, // every drop-dice roll hits, deterministically
            2,
            13,
            Arc::new(crate::comm::codec::DenseCodec),
            true,
        ));
        let fabric: Arc<dyn Fabric> = sim.clone();
        let shared = layered_shared(Arc::clone(&fabric), 2, 2);

        let shipped = shared.weights[0].halve(); // 0.5 -> ships 0.25
        let out = fabric.push(&shared, 0, 1, 0, lp(1, Some(shipped), 2));
        assert_eq!(out, PushOutcome::Queued, "buffered in the frame builder");
        assert!((sim.core().frame_open_mass() - shipped as f64).abs() < 1e-9);
        let (mass, _) = sim.in_flight_push_sum_mass();
        assert!((mass - shipped as f64).abs() < 1e-9, "builder-held weight is in flight");

        // the layer-0 close flushes the frame; the drop dice eat it
        let out = fabric.push(&shared, 0, 1, 0, lp(0, None, 2));
        assert_eq!(out, PushOutcome::Dropped);
        assert_eq!(sim.pending_count(), 0);
        assert_eq!(fabric.core().snapshot().msgs_dropped, 1);
        assert_eq!(sim.core().frame_open_mass(), 0.0);
        // the caller took `open` at the deepest layer and saw Queued: it
        // holds nothing to reclaim. The fabric refunded the hoisted weight.
        let total: f32 = shared.weights.iter().map(|w| w.get()).sum();
        assert!((total - 1.0).abs() < 1e-6, "mass conserved without caller action");
    }
}
