//! The shared-memory transport: `push` applies the payload to the receiver
//! synchronously on the sender's thread — bit-for-bit the seed-era direct
//! `Shared` mutation semantics, now with per-link accounting.
//!
//! Gossip algorithms additionally keep their fused in-place hot paths when
//! `Fabric::is_instant` (LayUp's `step_layer_mix` single traversal, GoSGD's
//! snapshot-and-mix, AD-PSGD's synchronous symmetric swap) and account that
//! traffic through `FabricCore::record_instant`; only the collective shares
//! (DDP gradients, LocalSGD/SlowMo/CO2 snapshots) route through `push`.

use std::sync::Arc;

use crate::comm::{apply, ApplyResult, Codec, Fabric, FabricCore, InFlight, Payload, PushOutcome};
use crate::coordinator::Shared;

/// See the module docs: zero-delay, loss-free, in-process links.
pub struct InstantFabric {
    core: FabricCore,
}

impl InstantFabric {
    /// An instant fabric connecting `m` workers (dense codec).
    pub fn new(m: usize) -> InstantFabric {
        InstantFabric { core: FabricCore::new(m) }
    }

    /// An instant fabric with a compression codec installed: the links are
    /// free, but byte metering still reports encoded sizes (and the
    /// encode/decode numerics apply), so codec behavior is testable without
    /// a simulated clock.
    pub fn with_codec(m: usize, codec: Arc<dyn Codec>) -> InstantFabric {
        InstantFabric::with_options(m, codec, false)
    }

    /// An instant fabric with a codec and step-frame coalescing switch:
    /// with `coalesce` on, consecutive `LayerPush`es buffer in the per-link
    /// `FrameBuilder` and apply as one `StepFrame` when layer 0 closes the
    /// step — the zero-delay way to test coalescing numerics.
    pub fn with_options(m: usize, codec: Arc<dyn Codec>, coalesce: bool) -> InstantFabric {
        InstantFabric { core: FabricCore::with_options(m, codec, coalesce) }
    }

    /// The seed-era synchronous push: encode, meter, apply on the sender's
    /// thread. Both the public `push` (after coalescing) and `restore` land
    /// here.
    fn push_wire(
        &self,
        shared: &Shared,
        from: usize,
        to: usize,
        step: usize,
        payload: Payload,
    ) -> PushOutcome {
        let _sp = shared.telemetry.span(crate::telemetry::Phase::FabricPush);
        // codec boundary: meter and apply the encoded message (identity for
        // the default dense codec — bit-for-bit the seed-era path)
        let payload = {
            let _enc = (!self.core.codec().spec().is_dense())
                .then(|| shared.telemetry.span(crate::telemetry::Phase::CodecEncode));
            self.core.codec().encode(&shared.update_pool, from, to, payload)
        };
        self.core.record_send(shared, from, to, step, payload.encoded_len());
        match apply(&self.core, shared, to, from, step, &payload) {
            ApplyResult::Busy => PushOutcome::Busy,
            ApplyResult::Malformed => {
                // truncated/corrupt payload: counted as a drop, never a
                // partial write; the Dropped outcome makes the sender
                // reclaim any shipped push-sum weight
                self.core.record_rejected(shared, from, to, step);
                PushOutcome::Dropped
            }
            ApplyResult::Applied { reply } => {
                // applied at send time: zero staleness by definition
                self.core.record_delivered(shared, from, to, step, step);
                if let Some((dest, p)) = reply {
                    // e.g. AD-PSGD's return half on the generic payload path
                    // (the fused instant path swaps in place instead)
                    let _ = self.push(shared, to, dest, step, p);
                }
                PushOutcome::Delivered
            }
        }
    }
}

impl Fabric for InstantFabric {
    fn core(&self) -> &FabricCore {
        &self.core
    }

    fn is_instant(&self) -> bool {
        true
    }

    fn push(
        &self,
        shared: &Shared,
        from: usize,
        to: usize,
        step: usize,
        payload: Payload,
    ) -> PushOutcome {
        if self.core.coalesce() && matches!(payload, Payload::LayerPush { .. }) {
            // step-frame coalescing: buffer this layer in the link's frame
            // builder; an intermediate push reports Queued, the layer-0
            // close (and any stale-step flush) ships as one StepFrame
            let mut last = PushOutcome::Queued;
            for (fstep, frame) in self.core.coalesce_layer_push(from, to, step, payload) {
                let open = frame.shipped_weight();
                let out = self.push_wire(shared, from, to, fstep, frame);
                if matches!(out, PushOutcome::Dropped | PushOutcome::Busy) && open > 0.0 {
                    // the frame owns the step's opening weight — hoisted out
                    // of a push the caller already saw Queued for — so the
                    // fabric must refund it; the caller cannot
                    shared.weights[from].reclaim(open);
                }
                last = out;
            }
            return last;
        }
        self.push_wire(shared, from, to, step, payload)
    }

    fn deliver_due(&self, _shared: &Shared, _wid: usize, _recv_step: usize) -> usize {
        0 // nothing is ever queued
    }

    fn drain(&self, wid: usize) -> Vec<InFlight> {
        // nothing ever queues on the links; only open frame builders hold
        // not-yet-shipped state (coalescing runs only)
        self.core.drain_frames_to(wid)
    }

    fn restore(&self, shared: &Shared, msgs: Vec<InFlight>) {
        // Restoring (e.g. a checkpoint taken on a simulated fabric) onto the
        // zero-delay transport applies the messages immediately — the
        // instant-fabric semantics of "no time passes on the link". A busy
        // push-sum accept slot cannot happen here (restore runs before any
        // worker thread spawns), but reclaim defensively so weight mass can
        // never be destroyed.
        for m in msgs {
            let shipped = m.payload.shipped_weight();
            if matches!(
                self.push(shared, m.from, m.to, m.step, m.payload),
                PushOutcome::Busy | PushOutcome::Dropped
            ) && shipped > 0.0
            {
                shared.weights[m.from].reclaim(shipped);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use std::sync::Arc;

    use super::*;
    use crate::algorithms::GradSet;
    use crate::comm::wire_bytes;
    use crate::coordinator::Shared;
    use crate::model::ModelParams;
    use crate::tensor::{AtomicTensor, LayerParams, Tensor};

    fn two_worker_shared(fabric: Arc<dyn Fabric>) -> Arc<Shared> {
        let params = (0..2)
            .map(|w| {
                Arc::new(ModelParams {
                    layers: vec![LayerParams::new(vec![AtomicTensor::from_tensor(
                        &Tensor::from_vec(&[2], vec![w as f32, w as f32]),
                    )])],
                })
            })
            .collect();
        Shared::for_tests(params, fabric)
    }

    #[test]
    fn grad_share_lands_in_mailbox_step_tagged() {
        let fabric: Arc<dyn Fabric> = Arc::new(InstantFabric::new(2));
        let shared = two_worker_shared(Arc::clone(&fabric));
        let set: GradSet = vec![vec![Tensor::from_vec(&[1], vec![2.0])]];
        let out = fabric.push(&shared, 0, 1, 7, Payload::GradShare { set: Arc::new(set) });
        assert_eq!(out, PushOutcome::Delivered);
        let (step, got) = fabric.core().latest_grads(1, 0).unwrap();
        assert_eq!(step, 7);
        assert_eq!(got[0][0].data, vec![2.0]);
        let stats = fabric.core().snapshot();
        assert_eq!(stats.msgs_sent, 1);
        assert_eq!(stats.msgs_delivered, 1);
        assert_eq!(stats.bytes_sent, wire_bytes(1));
        assert_eq!(stats.staleness_sum, 0, "instant delivery has zero staleness");
    }

    /// The instant transport never queues, so drain is empty; restoring
    /// (e.g. a sim-fabric checkpoint) applies the messages immediately.
    #[test]
    fn drain_is_empty_and_restore_applies_immediately() {
        let fabric: Arc<dyn Fabric> = Arc::new(InstantFabric::new(2));
        let shared = two_worker_shared(Arc::clone(&fabric));
        assert!(fabric.drain(0).is_empty());
        assert!(fabric.drain(1).is_empty());
        fabric.restore(
            &shared,
            vec![crate::comm::InFlight {
                from: 0,
                to: 1,
                step: 4,
                remaining_s: 0.25, // remaining delay collapses to zero here
                payload: Payload::ParamShare { flat: Arc::new(vec![7.0, 7.0]) },
            }],
        );
        let (step, flat) = fabric.core().latest_params(1, 0).unwrap();
        assert_eq!(step, 4);
        assert_eq!(*flat, vec![7.0, 7.0]);
    }

    /// Satellite: the instant transport rejects malformed payloads at push
    /// time — the sender sees `Dropped` (and reclaims any shipped weight),
    /// the receiver's store is untouched.
    #[test]
    fn malformed_payload_is_dropped_not_partially_applied() {
        let fabric: Arc<dyn Fabric> = Arc::new(InstantFabric::new(2));
        let shared = two_worker_shared(Arc::clone(&fabric));
        let before = shared.params[1].flatten();
        // receiver's flat size is 2; ship 3 values
        let out = fabric.push(
            &shared,
            0,
            1,
            0,
            Payload::PairAverage { flat: Arc::new(vec![1.0, 2.0, 3.0]), reply: false },
        );
        assert_eq!(out, PushOutcome::Dropped);
        assert_eq!(shared.params[1].flatten(), before, "no partial write");
        let stats = fabric.core().snapshot();
        assert_eq!(stats.msgs_dropped, 1);
        assert_eq!(stats.msgs_delivered, 0);
        // a short GradShare never lands in the mailbox
        let set: GradSet = vec![]; // zero layers, model has one
        let out = fabric.push(&shared, 0, 1, 1, Payload::GradShare { set: Arc::new(set) });
        assert_eq!(out, PushOutcome::Dropped);
        assert!(fabric.core().latest_grads(1, 0).is_none());
    }

    /// A 2-worker Shared with `layers` single-tensor layers of `dim` values
    /// each (worker w starts at `w`), for the coalescing tests.
    fn layered_shared(fabric: Arc<dyn Fabric>, layers: usize, dim: usize) -> Arc<Shared> {
        let params = (0..2)
            .map(|w| {
                Arc::new(ModelParams {
                    layers: (0..layers)
                        .map(|_| {
                            LayerParams::new(vec![AtomicTensor::from_tensor(&Tensor::from_vec(
                                &[dim],
                                vec![w as f32; dim],
                            ))])
                        })
                        .collect(),
                })
            })
            .collect();
        Shared::for_tests(params, fabric)
    }

    fn layer_push(layer: usize, open: Option<f32>, dim: usize) -> Payload {
        Payload::LayerPush {
            layer,
            open,
            values: Arc::new(vec![vec![4.0; dim]]),
            stamp: crate::tensor::clock::ClockStamp { worker: 0, step: 9, version: 1 },
            tau: 0,
        }
    }

    /// Tentpole semantics on the zero-delay transport: with coalescing on,
    /// a step's layer pushes buffer (Queued, receiver untouched) until the
    /// layer-0 close applies them all as ONE wire message whose size is the
    /// frame arithmetic (one header + 24 bytes per layer), with a single
    /// push-sum handshake for the step.
    #[test]
    fn coalesced_step_applies_as_one_frame() {
        let fabric: Arc<dyn Fabric> = Arc::new(InstantFabric::with_options(
            2,
            Arc::new(crate::comm::codec::DenseCodec),
            true,
        ));
        assert!(!fabric.fused_gossip(), "--coalesce must never be a silent no-op");
        let shared = layered_shared(Arc::clone(&fabric), 3, 2);
        let shipped = shared.weights[0].halve(); // 0.5 -> ships 0.25
        assert_eq!(
            fabric.push(&shared, 0, 1, 9, layer_push(2, Some(shipped), 2)),
            PushOutcome::Queued
        );
        assert_eq!(fabric.push(&shared, 0, 1, 9, layer_push(1, None, 2)), PushOutcome::Queued);
        assert_eq!(shared.params[1].flatten(), vec![1.0; 6], "nothing applied while buffering");
        assert_eq!(fabric.core().snapshot().msgs_sent, 0);

        assert_eq!(fabric.push(&shared, 0, 1, 9, layer_push(0, None, 2)), PushOutcome::Delivered);
        // one handshake: frac = 0.25 / (0.5 + 0.25), every layer mixed by it
        let frac = 0.25f32 / 0.75;
        let want = (1.0 - frac) * 1.0 + frac * 4.0;
        for v in shared.params[1].flatten() {
            assert!((v - want).abs() < 1e-6, "{v} vs {want}");
        }
        // every layer carries the sender's provenance stamp
        for l in &shared.params[1].layers {
            let s = l.clock.stamp();
            assert_eq!((s.worker, s.step), (0, 9));
        }
        let stats = fabric.core().snapshot();
        assert_eq!(stats.msgs_sent, 1, "three pushes, ONE wire message");
        assert_eq!(stats.bytes_sent, wire_bytes(6) + 3 * crate::comm::FRAME_ENTRY_BYTES);
        assert_eq!(fabric.core().frame_counters(), (1, 3));
        let total = shared.weights[0].get() + shared.weights[1].get();
        assert!((total - 1.0).abs() < 1e-6, "push-sum mass conserved: {total}");
    }

    /// A busy receiver rejects the frame at its one handshake; the fabric —
    /// not the caller, who saw only Queued outcomes — must refund the
    /// opening weight it hoisted into the frame.
    #[test]
    fn coalesced_busy_frame_refunds_hoisted_weight() {
        let fabric: Arc<dyn Fabric> = Arc::new(InstantFabric::with_options(
            2,
            Arc::new(crate::comm::codec::DenseCodec),
            true,
        ));
        let shared = layered_shared(Arc::clone(&fabric), 2, 2);
        // claim worker 1's accept slot so the frame's handshake finds it busy
        assert!(shared.weights[1].try_accept(0.0).is_some());
        let shipped = shared.weights[0].halve();
        assert_eq!(
            fabric.push(&shared, 0, 1, 3, layer_push(1, Some(shipped), 2)),
            PushOutcome::Queued
        );
        assert_eq!(fabric.push(&shared, 0, 1, 3, layer_push(0, None, 2)), PushOutcome::Busy);
        shared.weights[1].release();
        let total = shared.weights[0].get() + shared.weights[1].get();
        assert!((total - 1.0).abs() < 1e-6, "hoisted weight refunded: {total}");
        assert_eq!(shared.params[1].flatten(), vec![1.0; 4], "busy frame never applies");
    }

    /// Parity pin for the default: a `coalesce = false` fabric is the seed
    /// path bit-for-bit — same outcome, same byte accounting, same receiver
    /// values as the pre-coalescing constructor, frames never engaged, and
    /// the fused instant gossip fast path stays on.
    #[test]
    fn coalesce_off_is_bit_identical_to_the_seed_path() {
        let old: Arc<dyn Fabric> =
            Arc::new(InstantFabric::with_codec(2, Arc::new(crate::comm::codec::DenseCodec)));
        let new: Arc<dyn Fabric> = Arc::new(InstantFabric::with_options(
            2,
            Arc::new(crate::comm::codec::DenseCodec),
            false,
        ));
        assert!(old.fused_gossip() && new.fused_gossip());
        let mut results: Vec<Vec<u32>> = Vec::new();
        for fabric in [&old, &new] {
            let shared = layered_shared(Arc::clone(fabric), 2, 2);
            let shipped = shared.weights[0].halve();
            assert_eq!(
                fabric.push(&shared, 0, 1, 2, layer_push(1, Some(shipped), 2)),
                PushOutcome::Delivered,
                "without coalescing every push applies immediately"
            );
            assert_eq!(fabric.push(&shared, 0, 1, 2, layer_push(0, None, 2)), PushOutcome::Delivered);
            assert_eq!(fabric.core().frame_counters(), (0, 0), "builders never engaged");
            let stats = fabric.core().snapshot();
            assert_eq!(stats.msgs_sent, 2);
            assert_eq!(stats.bytes_sent, 2 * wire_bytes(2));
            results.push(shared.params[1].flatten().iter().map(|v| v.to_bits()).collect());
        }
        assert_eq!(results[0], results[1], "coalesce=false must be the seed path bit-for-bit");
    }

    #[test]
    fn pair_average_applies_both_halves_synchronously() {
        let fabric: Arc<dyn Fabric> = Arc::new(InstantFabric::new(2));
        let shared = two_worker_shared(Arc::clone(&fabric));
        let flat = Arc::new(shared.params[0].flatten());
        let out = fabric.push(&shared, 0, 1, 0, Payload::PairAverage { flat, reply: false });
        assert_eq!(out, PushOutcome::Delivered);
        // worker 1 mixed 0.5/0.5 with worker 0's [0,0]; the reply mixed
        // worker 0 with worker 1's pre-mix [1,1] — both end at 0.5
        assert_eq!(shared.params[1].flatten(), vec![0.5, 0.5]);
        assert_eq!(shared.params[0].flatten(), vec![0.5, 0.5]);
        // both directions accounted
        assert_eq!(fabric.core().snapshot().msgs_sent, 2);
    }
}
