//! The shared-memory transport: `push` applies the payload to the receiver
//! synchronously on the sender's thread — bit-for-bit the seed-era direct
//! `Shared` mutation semantics, now with per-link accounting.
//!
//! Gossip algorithms additionally keep their fused in-place hot paths when
//! `Fabric::is_instant` (LayUp's `step_layer_mix` single traversal, GoSGD's
//! snapshot-and-mix, AD-PSGD's synchronous symmetric swap) and account that
//! traffic through `FabricCore::record_instant`; only the collective shares
//! (DDP gradients, LocalSGD/SlowMo/CO2 snapshots) route through `push`.

use std::sync::Arc;

use crate::comm::{apply, ApplyResult, Codec, Fabric, FabricCore, InFlight, Payload, PushOutcome};
use crate::coordinator::Shared;

/// See the module docs: zero-delay, loss-free, in-process links.
pub struct InstantFabric {
    core: FabricCore,
}

impl InstantFabric {
    /// An instant fabric connecting `m` workers (dense codec).
    pub fn new(m: usize) -> InstantFabric {
        InstantFabric { core: FabricCore::new(m) }
    }

    /// An instant fabric with a compression codec installed: the links are
    /// free, but byte metering still reports encoded sizes (and the
    /// encode/decode numerics apply), so codec behavior is testable without
    /// a simulated clock.
    pub fn with_codec(m: usize, codec: Arc<dyn Codec>) -> InstantFabric {
        InstantFabric { core: FabricCore::with_codec(m, codec) }
    }
}

impl Fabric for InstantFabric {
    fn core(&self) -> &FabricCore {
        &self.core
    }

    fn is_instant(&self) -> bool {
        true
    }

    fn push(
        &self,
        shared: &Shared,
        from: usize,
        to: usize,
        step: usize,
        payload: Payload,
    ) -> PushOutcome {
        let _sp = shared.telemetry.span(crate::telemetry::Phase::FabricPush);
        // codec boundary: meter and apply the encoded message (identity for
        // the default dense codec — bit-for-bit the seed-era path)
        let payload = {
            let _enc = (!self.core.codec().spec().is_dense())
                .then(|| shared.telemetry.span(crate::telemetry::Phase::CodecEncode));
            self.core.codec().encode(&shared.update_pool, from, to, payload)
        };
        self.core.record_send(shared, from, to, step, payload.encoded_len());
        match apply(&self.core, shared, to, from, step, &payload) {
            ApplyResult::Busy => PushOutcome::Busy,
            ApplyResult::Malformed => {
                // truncated/corrupt payload: counted as a drop, never a
                // partial write; the Dropped outcome makes the sender
                // reclaim any shipped push-sum weight
                self.core.record_rejected(shared, from, to, step);
                PushOutcome::Dropped
            }
            ApplyResult::Applied { reply } => {
                // applied at send time: zero staleness by definition
                self.core.record_delivered(shared, from, to, step, step);
                if let Some((dest, p)) = reply {
                    // e.g. AD-PSGD's return half on the generic payload path
                    // (the fused instant path swaps in place instead)
                    let _ = self.push(shared, to, dest, step, p);
                }
                PushOutcome::Delivered
            }
        }
    }

    fn deliver_due(&self, _shared: &Shared, _wid: usize, _recv_step: usize) -> usize {
        0 // nothing is ever queued
    }

    fn drain(&self, _wid: usize) -> Vec<InFlight> {
        Vec::new() // nothing is ever in flight
    }

    fn restore(&self, shared: &Shared, msgs: Vec<InFlight>) {
        // Restoring (e.g. a checkpoint taken on a simulated fabric) onto the
        // zero-delay transport applies the messages immediately — the
        // instant-fabric semantics of "no time passes on the link". A busy
        // push-sum accept slot cannot happen here (restore runs before any
        // worker thread spawns), but reclaim defensively so weight mass can
        // never be destroyed.
        for m in msgs {
            let shipped = m.payload.shipped_weight();
            if matches!(
                self.push(shared, m.from, m.to, m.step, m.payload),
                PushOutcome::Busy | PushOutcome::Dropped
            ) && shipped > 0.0
            {
                shared.weights[m.from].reclaim(shipped);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use std::sync::Arc;

    use super::*;
    use crate::algorithms::GradSet;
    use crate::comm::wire_bytes;
    use crate::coordinator::Shared;
    use crate::model::ModelParams;
    use crate::tensor::{AtomicTensor, LayerParams, Tensor};

    fn two_worker_shared(fabric: Arc<dyn Fabric>) -> Arc<Shared> {
        let params = (0..2)
            .map(|w| {
                Arc::new(ModelParams {
                    layers: vec![LayerParams::new(vec![AtomicTensor::from_tensor(
                        &Tensor::from_vec(&[2], vec![w as f32, w as f32]),
                    )])],
                })
            })
            .collect();
        Shared::for_tests(params, fabric)
    }

    #[test]
    fn grad_share_lands_in_mailbox_step_tagged() {
        let fabric: Arc<dyn Fabric> = Arc::new(InstantFabric::new(2));
        let shared = two_worker_shared(Arc::clone(&fabric));
        let set: GradSet = vec![vec![Tensor::from_vec(&[1], vec![2.0])]];
        let out = fabric.push(&shared, 0, 1, 7, Payload::GradShare { set: Arc::new(set) });
        assert_eq!(out, PushOutcome::Delivered);
        let (step, got) = fabric.core().latest_grads(1, 0).unwrap();
        assert_eq!(step, 7);
        assert_eq!(got[0][0].data, vec![2.0]);
        let stats = fabric.core().snapshot();
        assert_eq!(stats.msgs_sent, 1);
        assert_eq!(stats.msgs_delivered, 1);
        assert_eq!(stats.bytes_sent, wire_bytes(1));
        assert_eq!(stats.staleness_sum, 0, "instant delivery has zero staleness");
    }

    /// The instant transport never queues, so drain is empty; restoring
    /// (e.g. a sim-fabric checkpoint) applies the messages immediately.
    #[test]
    fn drain_is_empty_and_restore_applies_immediately() {
        let fabric: Arc<dyn Fabric> = Arc::new(InstantFabric::new(2));
        let shared = two_worker_shared(Arc::clone(&fabric));
        assert!(fabric.drain(0).is_empty());
        assert!(fabric.drain(1).is_empty());
        fabric.restore(
            &shared,
            vec![crate::comm::InFlight {
                from: 0,
                to: 1,
                step: 4,
                remaining_s: 0.25, // remaining delay collapses to zero here
                payload: Payload::ParamShare { flat: Arc::new(vec![7.0, 7.0]) },
            }],
        );
        let (step, flat) = fabric.core().latest_params(1, 0).unwrap();
        assert_eq!(step, 4);
        assert_eq!(*flat, vec![7.0, 7.0]);
    }

    /// Satellite: the instant transport rejects malformed payloads at push
    /// time — the sender sees `Dropped` (and reclaims any shipped weight),
    /// the receiver's store is untouched.
    #[test]
    fn malformed_payload_is_dropped_not_partially_applied() {
        let fabric: Arc<dyn Fabric> = Arc::new(InstantFabric::new(2));
        let shared = two_worker_shared(Arc::clone(&fabric));
        let before = shared.params[1].flatten();
        // receiver's flat size is 2; ship 3 values
        let out = fabric.push(
            &shared,
            0,
            1,
            0,
            Payload::PairAverage { flat: Arc::new(vec![1.0, 2.0, 3.0]), reply: false },
        );
        assert_eq!(out, PushOutcome::Dropped);
        assert_eq!(shared.params[1].flatten(), before, "no partial write");
        let stats = fabric.core().snapshot();
        assert_eq!(stats.msgs_dropped, 1);
        assert_eq!(stats.msgs_delivered, 0);
        // a short GradShare never lands in the mailbox
        let set: GradSet = vec![]; // zero layers, model has one
        let out = fabric.push(&shared, 0, 1, 1, Payload::GradShare { set: Arc::new(set) });
        assert_eq!(out, PushOutcome::Dropped);
        assert!(fabric.core().latest_grads(1, 0).is_none());
    }

    #[test]
    fn pair_average_applies_both_halves_synchronously() {
        let fabric: Arc<dyn Fabric> = Arc::new(InstantFabric::new(2));
        let shared = two_worker_shared(Arc::clone(&fabric));
        let flat = Arc::new(shared.params[0].flatten());
        let out = fabric.push(&shared, 0, 1, 0, Payload::PairAverage { flat, reply: false });
        assert_eq!(out, PushOutcome::Delivered);
        // worker 1 mixed 0.5/0.5 with worker 0's [0,0]; the reply mixed
        // worker 0 with worker 1's pre-mix [1,1] — both end at 0.5
        assert_eq!(shared.params[1].flatten(), vec![0.5, 0.5]);
        assert_eq!(shared.params[0].flatten(), vec![0.5, 0.5]);
        // both directions accounted
        assert_eq!(fabric.core().snapshot().msgs_sent, 2);
    }
}
