//! Communication fabric: pluggable transports for **all** inter-worker
//! parameter traffic.
//!
//! The paper's headline claim is robustness to *delays*, yet the seed-era
//! algorithms communicated by instantaneously mutating the peer's
//! [`crate::tensor::AtomicTensor`] store, so delayed or lossy links could not
//! be modeled at all. This module is the API seam that fixes that: every
//! algorithm ships its traffic as a [`Payload`] through the run's [`Fabric`],
//! and the fabric decides what a "link" means:
//!
//! * [`InstantFabric`] — the shared-memory transport. `push` applies the
//!   payload to the receiver synchronously on the sender's thread, exactly
//!   the seed-era semantics (the gossip algorithms additionally keep their
//!   fused in-place hot paths when [`Fabric::is_instant`] — numerics are
//!   bit-for-bit unchanged, now with per-link accounting).
//! * [`SimFabric`] — queued per-link channels with seeded latency
//!   distributions ([`LatencyDist`]), bandwidth-derived serialization delay
//!   and drop probability; queued messages are applied by the *receiving*
//!   worker at its step boundaries ([`Fabric::deliver_due`]). This is what
//!   the delay-robustness sweep (`benches/fig_delay_robustness.rs`) runs on.
//!
//! # Protocol invariants
//!
//! * **Push-sum mass is delayed, never destroyed.** A gossip message carries
//!   its shipped weight ([`Payload::shipped_weight`]); a drop is decided at
//!   *send* time so the sender can reclaim (exactly the seed-era
//!   contention-skip semantics), a busy receiver slot re-queues the message
//!   instead of discarding it, and weight in flight is accounted by
//!   [`SimFabric::in_flight_push_sum_mass`]. The property test in
//!   `tests/properties.rs` pins this.
//! * **Per-link FIFO.** Deliveries on one link happen in send order, so a
//!   layer-wise push's opening message (which establishes the mixing
//!   fraction) always precedes its followers.
//! * **Collective shares are reliable.** [`Payload::GradShare`] and
//!   [`Payload::ParamShare`] are never dropped (TCP-like), only delayed —
//!   barrier rounds slow down under latency but cannot deadlock.

pub mod codec;
pub mod instant;
pub mod sim;

use std::collections::HashMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::algorithms::GradSet;
use crate::config::Mixing;
use crate::coordinator::Shared;
use crate::metrics::{CommStats, LinkTraffic};
use crate::tensor::clock::ClockStamp;
use crate::tensor::Tensor;
use crate::resilience::membership::{Membership, RecoveryPolicy};
use crate::session::events::TrainEvent;
use crate::topology::roles::RoleTable;
use crate::util::rng::Pcg32;

pub use codec::{Codec, CodecSpec, Compressed};
pub use instant::InstantFabric;
pub use sim::SimFabric;

/// Serialized wire size of a message carrying `floats` f32 values (4 bytes
/// each plus a fixed header).
pub fn wire_bytes(floats: usize) -> u64 {
    32 + 4 * floats as u64
}

/// Per-layer index cost inside a coalesced [`Payload::StepFrame`]: layer id,
/// provenance stamp and τ packed into 24 bytes. The amortization win of
/// coalescing is exactly `32 − 24 = 8` bytes per layer plus the `L − 1`
/// saved headers' worth of per-message fixed costs (codec setup, one
/// delivery event instead of `L`).
pub const FRAME_ENTRY_BYTES: u64 = 24;

/// One layer's slot in a coalesced [`Payload::StepFrame`]: the fields a
/// standalone [`Payload::LayerPush`] would carry, minus the per-message
/// header (`open` is hoisted to the frame — at most one opening per step).
#[derive(Clone)]
pub struct FrameEntry {
    /// layer index in the receiver's store
    pub layer: usize,
    /// the sender's post-update staleness-clock stamp of this layer
    pub stamp: ClockStamp,
    /// sender-observed delay τ of the gradient behind this layer's push
    pub tau: u64,
    /// the layer's parameter tensors, flattened per parameter
    pub values: Arc<Vec<Vec<f32>>>,
}

/// One unit of inter-worker traffic. Gossip payloads mutate the receiver's
/// parameter store on delivery; share payloads land in per-link mailboxes
/// read by the collective algorithms.
#[derive(Clone)]
pub enum Payload {
    /// LayUp: one layer of a push-sum step push. `open` carries the shipped
    /// push-sum weight on the step's first (deepest) layer; followers of the
    /// same step reuse the mixing fraction established when the opening
    /// message was delivered. `values[param]` are the layer's tensors.
    LayerPush {
        /// layer index in the receiver's store
        layer: usize,
        /// shipped push-sum weight (opening message of the step only)
        open: Option<f32>,
        /// the layer's parameter tensors, flattened per parameter
        values: Arc<Vec<Vec<f32>>>,
        /// the sender's post-update staleness-clock stamp of this layer
        /// (provenance header: who produced these values, at which step)
        stamp: ClockStamp,
        /// sender-observed delay τ of the gradient behind this push; the
        /// receiver's `mixing = "adaptive"` policy attenuates on it
        tau: u64,
    },
    /// GoSGD: whole-model push-sum push (`values[layer][param]`).
    ModelPush {
        /// shipped push-sum weight
        w_in: f32,
        /// every layer's parameter tensors
        values: Arc<Vec<Vec<Vec<f32>>>>,
    },
    /// AD-PSGD: symmetric pairwise averaging. The receiver mixes the
    /// incoming snapshot into its own store (0.5/0.5) and — unless this
    /// already *is* the reply — ships its pre-mix snapshot back, so both
    /// halves of the exchange ride the links (2x communication volume, as
    /// the paper notes).
    PairAverage {
        /// the sender's flattened parameters
        flat: Arc<Vec<f32>>,
        /// true for the return half (stops the ping-pong)
        reply: bool,
    },
    /// DDP: one worker's gradient contribution to the all-reduce round
    /// (mailbox payload, consumed by [`collect_grads`]).
    GradShare {
        /// the sender's full gradient set for this step
        set: Arc<GradSet>,
    },
    /// LocalSGD / SlowMo / CO2: a flat parameter snapshot (mailbox payload;
    /// barrier algorithms collect it with [`collect_params`], CO2 reads the
    /// latest arrival without waiting).
    ParamShare {
        /// the sender's flattened parameters
        flat: Arc<Vec<f32>>,
    },
    /// ASGD-PS (`ps:N` topology): one layer's gradient pushed from a trainer
    /// to the parameter-server shard owning that layer. The shard applies it
    /// with its own optimizer and replies with a [`Payload::ParamPull`].
    /// Reliable (never dropped) — a lost gradient would silently skip an
    /// optimizer step.
    GradPush {
        /// model layer the gradient belongs to
        layer: usize,
        /// the layer's gradient tensors, flattened per parameter
        grads: Arc<Vec<Vec<f32>>>,
        /// the trainer's forward-time parameter values (dcasgd-ps only):
        /// the `x_then` of the DC-ASGD correction
        /// `g + λ·g⊙g⊙(x_now − x_then)` the shard applies before stepping
        x_then: Option<Arc<Vec<Vec<f32>>>>,
        /// the trainer's forward-time clock stamp of this layer — mirrors
        /// the shard's clock as of the trainer's last pull, so the shard's
        /// observed τ counts exactly the shard writes the gradient missed
        stamp: ClockStamp,
    },
    /// ASGD-PS: fresh layer parameters a shard sends back to a trainer in
    /// response to a [`Payload::GradPush`]. Reliable.
    ParamPull {
        /// model layer the parameters belong to
        layer: usize,
        /// the layer's parameter tensors, flattened per parameter
        values: Arc<Vec<Vec<f32>>>,
        /// the shard's layer-clock stamp after the apply; the trainer loads
        /// it into its replica clock so the next push carries exact
        /// shard-version provenance
        stamp: ClockStamp,
    },
    /// LayUp with `[fabric] coalesce = true`: one worker's **whole step** of
    /// layer pushes on one link, coalesced by the fabric's per-link
    /// [`FrameBuilder`](FabricCore) into a single wire message. Pays one
    /// header plus a 24-byte index slot per layer (instead of one 32-byte
    /// header per layer), crosses the codec **once** over the concatenated
    /// gradient mass (so `topk:K` ranks coordinates globally across layers),
    /// and lands as one delivery event. `open` is the step's push-sum
    /// opening weight, hoisted out of the first (deepest) entry.
    StepFrame {
        /// shipped push-sum weight for the whole step (one handshake)
        open: Option<f32>,
        /// per-layer slots in push order (deepest first, layer 0 closes)
        entries: Arc<Vec<FrameEntry>>,
    },
    /// A codec-encoded message (`[fabric] codec != "dense"`): the installed
    /// [`codec::Codec`] wraps every outgoing payload at the fabric boundary,
    /// and `apply` decodes it back before dispatching. Push-sum metadata
    /// rides in the clear so drop/refund accounting never needs a decode.
    Compressed(Compressed),
}

impl Payload {
    /// Serialized wire size of this message — the single source of truth for
    /// byte accounting: [`CommStats`] meters it, [`SimFabric`] derives
    /// serialization delay from it, and the checkpoint codec sizes in-flight
    /// buffers with it. A compressed payload reports its **encoded** size,
    /// which is how compression shows up as wall-clock wins on
    /// bandwidth-constrained links.
    pub fn encoded_len(&self) -> u64 {
        let floats: usize = match self {
            Payload::LayerPush { values, .. } => values.iter().map(|v| v.len()).sum(),
            Payload::ModelPush { values, .. } => values
                .iter()
                .map(|l| l.iter().map(|v| v.len()).sum::<usize>())
                .sum(),
            Payload::PairAverage { flat, .. } | Payload::ParamShare { flat } => flat.len(),
            Payload::GradShare { set } => set
                .iter()
                .map(|l| l.iter().map(|t| t.data.len()).sum::<usize>())
                .sum(),
            Payload::GradPush { grads, x_then, .. } => {
                grads.iter().map(|v| v.len()).sum::<usize>()
                    + x_then
                        .as_ref()
                        .map(|x| x.iter().map(|v| v.len()).sum::<usize>())
                        .unwrap_or(0)
            }
            Payload::ParamPull { values, .. } => values.iter().map(|v| v.len()).sum(),
            Payload::StepFrame { entries, .. } => {
                // one header for the frame + a 24-byte index slot per layer —
                // the header-amortization arithmetic the coalescing tests pin
                let floats: usize = entries
                    .iter()
                    .map(|e| e.values.iter().map(|v| v.len()).sum::<usize>())
                    .sum();
                return wire_bytes(floats) + FRAME_ENTRY_BYTES * entries.len() as u64;
            }
            Payload::Compressed(c) => return c.encoded_len(),
        };
        wire_bytes(floats)
    }

    /// May the transport drop this message? Gossip traffic tolerates loss
    /// (the information is delayed to a later exchange); collective shares
    /// and parameter-server traffic are modeled as reliable so barrier
    /// rounds cannot deadlock and optimizer steps are never silently lost.
    /// A compressed payload inherits its inner payload's answer (captured at
    /// encode time).
    pub fn droppable(&self) -> bool {
        match self {
            Payload::LayerPush { .. }
            | Payload::StepFrame { .. }
            | Payload::ModelPush { .. }
            | Payload::PairAverage { .. } => true,
            Payload::Compressed(c) => c.droppable,
            _ => false,
        }
    }

    /// Push-sum weight mass this message carries while in flight.
    pub fn shipped_weight(&self) -> f32 {
        match self {
            Payload::LayerPush { open, .. } | Payload::StepFrame { open, .. } => {
                open.unwrap_or(0.0)
            }
            Payload::ModelPush { w_in, .. } => *w_in,
            Payload::Compressed(c) => c.shipped_w,
            _ => 0.0,
        }
    }
}

/// One queued message pulled off a transport by [`Fabric::drain`] — the
/// checkpoint/crash view of traffic still riding the links. Restorable via
/// [`Fabric::restore`]; serialized by `resilience::checkpoint`.
#[derive(Clone)]
pub struct InFlight {
    /// sending worker
    pub from: usize,
    /// receiving worker
    pub to: usize,
    /// sender's step at send time
    pub step: usize,
    /// link delay left when drained (0 on instant transports; a restored
    /// message becomes due this many seconds after the restore)
    pub remaining_s: f64,
    pub payload: Payload,
}

/// What [`Fabric::push`] did with the message.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PushOutcome {
    /// Applied synchronously to the receiver (instant transports).
    Delivered,
    /// Queued on the link for later delivery (simulated transports).
    Queued,
    /// Dropped by the link. The sender must reclaim any shipped weight —
    /// mass is never destroyed in flight.
    Dropped,
    /// The receiver's push-sum accept slot was busy (instant transports
    /// only; a simulated transport re-queues instead). Semantics match a
    /// contention skip: reclaim and retry at a later exchange.
    Busy,
}

/// Seeded one-way link latency distributions for the simulated fabric.
#[derive(Clone, Debug, PartialEq)]
pub enum LatencyDist {
    /// Fixed delay in seconds.
    Constant(f64),
    /// Uniform in `[lo, hi]` seconds.
    Uniform {
        /// lower bound (seconds)
        lo: f64,
        /// upper bound (seconds)
        hi: f64,
    },
    /// Pareto-tailed: `scale * u^(-1/alpha)` — heavy-tailed link stragglers.
    Pareto {
        /// minimum delay (seconds)
        scale: f64,
        /// tail index (mean is finite for `alpha > 1`)
        alpha: f64,
    },
}

impl LatencyDist {
    /// Draw one delay in seconds.
    pub fn sample(&self, rng: &mut Pcg32) -> f64 {
        match self {
            LatencyDist::Constant(s) => *s,
            LatencyDist::Uniform { lo, hi } => lo + (hi - lo) * rng.next_f64(),
            LatencyDist::Pareto { scale, alpha } => {
                let u = (1.0 - rng.next_f64()).max(1e-12);
                scale * u.powf(-1.0 / alpha)
            }
        }
    }

    /// Expected delay (infinite for a Pareto tail with `alpha <= 1`).
    pub fn mean(&self) -> f64 {
        match self {
            LatencyDist::Constant(s) => *s,
            LatencyDist::Uniform { lo, hi } => 0.5 * (lo + hi),
            LatencyDist::Pareto { scale, alpha } => {
                if *alpha > 1.0 {
                    scale * alpha / (alpha - 1.0)
                } else {
                    f64::INFINITY
                }
            }
        }
    }

    /// Reject nonsensical parameterizations.
    pub fn validate(&self) -> Result<()> {
        match self {
            LatencyDist::Constant(s) => {
                if *s < 0.0 || !s.is_finite() {
                    bail!("link latency must be a finite nonnegative number of seconds, got {s}");
                }
            }
            LatencyDist::Uniform { lo, hi } => {
                if *lo < 0.0 || hi < lo || !hi.is_finite() {
                    bail!("uniform link latency wants 0 <= lo <= hi, got {lo}..{hi}");
                }
            }
            LatencyDist::Pareto { scale, alpha } => {
                if *scale <= 0.0 || *alpha <= 0.0 || !scale.is_finite() || !alpha.is_finite() {
                    bail!("pareto link latency wants scale > 0 and alpha > 0, got {scale},{alpha}");
                }
            }
        }
        Ok(())
    }

    /// Parse a CLI/TOML latency spec: a plain number of seconds,
    /// `constant:S`, `uniform:LO..HI` or `pareto:SCALE,ALPHA`.
    pub fn parse(spec: &str) -> Result<LatencyDist> {
        let spec = spec.trim();
        if let Ok(v) = spec.parse::<f64>() {
            return Ok(LatencyDist::Constant(v));
        }
        if let Some(rest) = spec.strip_prefix("constant:") {
            let s: f64 = rest.trim().parse().context("constant latency wants seconds")?;
            return Ok(LatencyDist::Constant(s));
        }
        if let Some(rest) = spec.strip_prefix("uniform:") {
            let (lo, hi) = rest
                .split_once("..")
                .context("uniform latency wants LO..HI seconds")?;
            return Ok(LatencyDist::Uniform {
                lo: lo.trim().parse().context("uniform latency lower bound")?,
                hi: hi.trim().parse().context("uniform latency upper bound")?,
            });
        }
        if let Some(rest) = spec.strip_prefix("pareto:") {
            let (scale, alpha) = rest
                .split_once(',')
                .context("pareto latency wants SCALE,ALPHA")?;
            return Ok(LatencyDist::Pareto {
                scale: scale.trim().parse().context("pareto latency scale")?,
                alpha: alpha.trim().parse().context("pareto latency alpha")?,
            });
        }
        bail!(
            "unrecognized latency spec {spec:?} (expected SECONDS, constant:S, \
             uniform:LO..HI or pareto:SCALE,ALPHA)"
        )
    }
}

/// Which transport a run uses (`TrainConfig::fabric`, CLI `--fabric`).
#[derive(Clone, Debug, PartialEq)]
pub enum FabricSpec {
    /// Shared-memory transport: pushes mutate the peer synchronously —
    /// bit-for-bit the seed-era semantics. The default.
    Instant,
    /// Queued per-link transport with seeded latency, bandwidth-derived
    /// serialization delay and drop probability.
    Sim {
        /// one-way link latency distribution
        latency: LatencyDist,
        /// link bandwidth in bytes/s (0 = infinite: no serialization delay)
        bandwidth_bytes_per_s: f64,
        /// per-message drop probability for droppable (gossip) payloads
        drop_prob: f64,
    },
}

impl FabricSpec {
    /// A simulated fabric with ideal links (zero latency, no loss) — the
    /// starting point the `--link-*` CLI flags refine.
    pub fn sim_default() -> FabricSpec {
        FabricSpec::Sim {
            latency: LatencyDist::Constant(0.0),
            bandwidth_bytes_per_s: 0.0,
            drop_prob: 0.0,
        }
    }

    /// Short name for logs and the CLI.
    pub fn name(&self) -> &'static str {
        match self {
            FabricSpec::Instant => "instant",
            FabricSpec::Sim { .. } => "sim",
        }
    }

    /// Reject nonsensical link parameters (called by `TrainConfig::validate`).
    pub fn validate(&self) -> Result<()> {
        if let FabricSpec::Sim { latency, bandwidth_bytes_per_s, drop_prob } = self {
            latency.validate()?;
            if *bandwidth_bytes_per_s < 0.0 || !bandwidth_bytes_per_s.is_finite() {
                bail!("link bandwidth must be >= 0 bytes/s (0 = infinite)");
            }
            if !(0.0..1.0).contains(drop_prob) {
                bail!("link drop probability must be in [0, 1), got {drop_prob}");
            }
        }
        Ok(())
    }
}

/// Construct the configured transport for an `m`-worker run, with `codec`
/// installed at the boundary (identity for [`CodecSpec::Dense`]) and
/// step-frame `coalesce`ing on or off (`[fabric] coalesce`, default off).
pub fn build_fabric(
    spec: &FabricSpec,
    codec_spec: &CodecSpec,
    coalesce: bool,
    m: usize,
    seed: u64,
) -> Arc<dyn Fabric> {
    // the codec draws from its own seed lane: installing `randk`/`int8`
    // must not perturb the link dice (latency, drops) of the run
    let codec = codec_spec.build(m, seed ^ 0xc0dec);
    match spec {
        FabricSpec::Instant => Arc::new(InstantFabric::with_options(m, codec, coalesce)),
        FabricSpec::Sim { latency, bandwidth_bytes_per_s, drop_prob } => {
            Arc::new(SimFabric::with_options(
                latency.clone(),
                *bandwidth_bytes_per_s,
                *drop_prob,
                m,
                seed,
                codec,
                coalesce,
            ))
        }
    }
}

/// A pluggable transport for inter-worker traffic. One fabric per run;
/// workers address each other by worker id (a worker's "endpoint" is the
/// `(fabric, wid)` pair every engine thread already holds via `Shared`).
pub trait Fabric: Send + Sync {
    /// Shared accounting and mailboxes (per-link traffic, collective shares).
    fn core(&self) -> &FabricCore;

    /// True when `push` mutates the receiver synchronously in shared memory.
    /// Gossip algorithms then keep their fused in-place hot paths and account
    /// the traffic through [`FabricCore::record_instant`].
    fn is_instant(&self) -> bool;

    /// True when gossip algorithms may take their fused in-place hot paths:
    /// the transport is instant AND the codec is the dense identity. A
    /// non-dense codec must see every payload at the push boundary, so it
    /// forces even instant runs onto the generic payload path (intra-node
    /// shared-memory traffic — hierarchical tier 1 — stays fused: it models
    /// one node's internal bus, which no wire codec touches). Step-frame
    /// coalescing likewise lives at the push boundary, so enabling it also
    /// routes instant runs through payloads — `--coalesce` is never a
    /// silent no-op.
    fn fused_gossip(&self) -> bool {
        self.is_instant() && self.core().codec().spec().is_dense() && !self.core().coalesce()
    }

    /// Ship one message from worker `from` to worker `to`. `step` is the
    /// sender's current step (staleness accounting).
    fn push(
        &self,
        shared: &Shared,
        from: usize,
        to: usize,
        step: usize,
        payload: Payload,
    ) -> PushOutcome;

    /// Apply every message currently due for `wid` (no-op on instant
    /// transports); returns how many were applied. Called by the receiving
    /// worker at its step boundaries — `recv_step` is its current step.
    fn deliver_due(&self, shared: &Shared, wid: usize, recv_step: usize) -> usize;

    /// Remove every message queued toward `wid` without applying it
    /// (checkpoint quiesce, crash reclaim). Instant transports queue
    /// nothing, so they return an empty vec. Deliveries on the drained link
    /// keep their send order.
    fn drain(&self, wid: usize) -> Vec<InFlight>;

    /// Re-inject messages taken by [`Fabric::drain`] (or loaded from a
    /// checkpoint): queued transports re-queue them with their remaining
    /// delay, instant transports apply them on the spot. Send-time dice
    /// (drop, latency) were already rolled — restoring must not re-roll.
    fn restore(&self, shared: &Shared, msgs: Vec<InFlight>);

    /// Messages currently queued toward `wid` (due or not). Instant
    /// transports queue nothing. Parameter-server shards poll this to know
    /// when the trainers' last gradients have all drained.
    fn pending_to(&self, wid: usize) -> usize {
        let _ = wid;
        0
    }
}

/// Per-link traffic counters (lock-free; snapshot via [`FabricCore::snapshot`]).
#[derive(Default)]
struct LinkCounters {
    msgs: AtomicU64,
    bytes: AtomicU64,
    drops: AtomicU64,
    delivered: AtomicU64,
    staleness_sum: AtomicI64,
}

/// Latest collective share received on one link (mailbox slot).
#[derive(Default)]
struct ShareSlot {
    grads: Option<(usize, Arc<GradSet>)>,
    params: Option<(usize, Arc<Vec<f32>>)>,
}

/// One link's open coalescing frame: the [`Payload::LayerPush`]es of one
/// (sender, step) accumulated at the fabric boundary, waiting for the
/// step's closing layer-0 push to flush as a single [`Payload::StepFrame`].
struct FrameBuilder {
    /// sender step every buffered entry belongs to
    step: usize,
    /// push-sum weight taken from the step's opening push
    open: Option<f32>,
    /// buffered layers in push order (deepest first)
    entries: Vec<FrameEntry>,
}

impl FrameBuilder {
    fn into_payload(self) -> (usize, Payload) {
        (self.step, Payload::StepFrame { open: self.open, entries: Arc::new(self.entries) })
    }
}

/// State shared by every fabric implementation: per-link traffic counters,
/// collective-share mailboxes, and the per-receiver mixing-fraction table
/// that multi-message (layer-wise) pushes key by `(sender, step)`.
pub struct FabricCore {
    m: usize,
    /// indexed `from * m + to`
    links: Vec<LinkCounters>,
    /// indexed `to * m + from`
    shares: Vec<Mutex<ShareSlot>>,
    /// per receiver: `(from, step) -> mixing fraction` for in-flight
    /// layer-wise pushes
    pending_frac: Vec<Mutex<HashMap<(usize, usize), f32>>>,
    /// elastic worker membership (shared with `Shared` so transports and
    /// algorithms agree on liveness; see `crate::resilience::membership`)
    membership: Arc<Membership>,
    /// layer→shard routing table for role topologies (`ps:N`); absent on
    /// flat clusters — installed once by the coordinator at session build
    roles: OnceLock<RoleTable>,
    /// the compression codec every push crosses ([`codec::DenseCodec`] is
    /// the identity default)
    codec: Arc<dyn Codec>,
    /// step-frame coalescing enabled (`[fabric] coalesce = true`)
    coalesce: bool,
    /// per-link open frames, indexed `from * m + to`; only engaged when
    /// `coalesce` is set (the default-off path never touches these locks)
    frames: Vec<Mutex<Option<FrameBuilder>>>,
    /// coalesced frames flushed to the wire
    frames_sent: AtomicU64,
    /// layer pushes absorbed into those frames (for `frames_per_step` /
    /// `header_bytes_saved` reporting)
    frame_layers: AtomicU64,
}

impl FabricCore {
    /// Fresh core for an `m`-worker fabric (all slots alive, dense codec).
    pub fn new(m: usize) -> FabricCore {
        FabricCore::with_codec(m, Arc::new(codec::DenseCodec))
    }

    /// Fresh core with a compression codec installed at the boundary.
    pub fn with_codec(m: usize, codec: Arc<dyn Codec>) -> FabricCore {
        FabricCore::with_options(m, codec, false)
    }

    /// Fresh core with a codec and the step-frame coalescing switch.
    pub fn with_options(m: usize, codec: Arc<dyn Codec>, coalesce: bool) -> FabricCore {
        FabricCore {
            m,
            links: (0..m * m).map(|_| LinkCounters::default()).collect(),
            shares: (0..m * m).map(|_| Mutex::new(ShareSlot::default())).collect(),
            pending_frac: (0..m).map(|_| Mutex::new(HashMap::new())).collect(),
            membership: Arc::new(Membership::new(m)),
            roles: OnceLock::new(),
            codec,
            coalesce,
            frames: (0..m * m).map(|_| Mutex::new(None)).collect(),
            frames_sent: AtomicU64::new(0),
            frame_layers: AtomicU64::new(0),
        }
    }

    /// The installed compression codec.
    pub fn codec(&self) -> &Arc<dyn Codec> {
        &self.codec
    }

    /// Is step-frame coalescing enabled on this fabric?
    pub fn coalesce(&self) -> bool {
        self.coalesce
    }

    /// Feed one [`Payload::LayerPush`] into the link's frame builder and
    /// return the frames that must ship **now** as `(step, payload)` pairs:
    /// a stale frame flushed because the sender moved to a new step (crash
    /// or skip left the old step open), and/or the frame this layer-0 push
    /// just closed. An absorbed intermediate push returns an empty vec —
    /// the transport reports [`PushOutcome::Queued`] for it. Non-LayerPush
    /// payloads are handed back unchanged.
    pub(crate) fn coalesce_layer_push(
        &self,
        from: usize,
        to: usize,
        step: usize,
        payload: Payload,
    ) -> Vec<(usize, Payload)> {
        let Payload::LayerPush { layer, open, values, stamp, tau } = payload else {
            return vec![(step, payload)];
        };
        let mut slot = self.frames[from * self.m + to].lock().unwrap();
        let mut out = Vec::new();
        if slot.as_ref().is_some_and(|fb| fb.step != step) {
            out.push(self.flush_frame(slot.take().unwrap()));
        }
        let fb = slot.get_or_insert_with(|| FrameBuilder { step, open: None, entries: Vec::new() });
        if let Some(w) = open {
            // at most one opening per step in practice; summing is the
            // mass-conserving answer if a sender ever opens twice
            fb.open = Some(fb.open.unwrap_or(0.0) + w);
        }
        fb.entries.push(FrameEntry { layer, stamp, tau, values });
        if layer == 0 {
            out.push(self.flush_frame(slot.take().unwrap()));
        }
        out
    }

    fn flush_frame(&self, fb: FrameBuilder) -> (usize, Payload) {
        self.frames_sent.fetch_add(1, Ordering::Relaxed);
        self.frame_layers.fetch_add(fb.entries.len() as u64, Ordering::Relaxed);
        fb.into_payload()
    }

    /// Flush every open frame headed to `wid` out of the builders (checkpoint
    /// quiesce / crash reclaim — the companion of [`Fabric::drain`]). The
    /// partial frames become ordinary in-flight messages with zero remaining
    /// delay, so drain/restore conserves their clock provenance and push-sum
    /// mass exactly like queued traffic.
    pub(crate) fn drain_frames_to(&self, wid: usize) -> Vec<InFlight> {
        if !self.coalesce {
            return Vec::new();
        }
        let mut out = Vec::new();
        for from in 0..self.m {
            let mut slot = self.frames[from * self.m + wid].lock().unwrap();
            if let Some(fb) = slot.take() {
                // no counter bump: the frame never reached the wire — it is
                // checkpoint state, and restore re-injects it as traffic
                let (step, payload) = fb.into_payload();
                out.push(InFlight { from, to: wid, step, remaining_s: 0.0, payload });
            }
        }
        out
    }

    /// Push-sum weight currently held by open (unflushed) frame builders —
    /// part of the conserved in-flight mass alongside queued messages.
    pub fn frame_open_mass(&self) -> f64 {
        if !self.coalesce {
            return 0.0;
        }
        self.frames
            .iter()
            .map(|s| {
                s.lock()
                    .unwrap()
                    .as_ref()
                    .and_then(|fb| fb.open)
                    .unwrap_or(0.0) as f64
            })
            .sum()
    }

    /// `(frames flushed, layer pushes absorbed into them)` so far — feeds
    /// the `frames_per_step` / `header_bytes_saved` bench columns.
    pub fn frame_counters(&self) -> (u64, u64) {
        (
            self.frames_sent.load(Ordering::Relaxed),
            self.frame_layers.load(Ordering::Relaxed),
        )
    }

    /// Number of workers this fabric connects.
    pub fn workers(&self) -> usize {
        self.m
    }

    /// The fabric's membership table (versioned epoch; shared with the run's
    /// `Shared` state).
    pub fn membership(&self) -> &Arc<Membership> {
        &self.membership
    }

    /// Install the role/routing table of a role topology (`ps:N`). Called
    /// once by the coordinator at session build; a second install is a no-op.
    pub fn install_roles(&self, table: RoleTable) {
        let _ = self.roles.set(table);
    }

    /// The installed role table, if this is a role-topology run.
    pub fn role_table(&self) -> Option<&RoleTable> {
        self.roles.get()
    }

    /// Worker id of the parameter-server shard owning `layer` under the
    /// current membership epoch, or `None` when the run is flat or the
    /// owner is dead under the Stall policy (the trainer freezes the layer).
    ///
    /// Elastic path: on an epoch change under the Shrink policy the role
    /// table re-partitions layers across surviving shards and reports
    /// handovers, which are applied here — the dead shard's replica still
    /// holds the freshest values, so they are copied (parameters, clock and
    /// per-layer optimizer moments) into the new owner before routing
    /// resumes. Trainer pushes racing the handover land on whichever owner
    /// their route call resolved — acceptable on this non-deterministic
    /// crash-recovery path, and mass-free (PS traffic ships no weight).
    pub fn route_layer(&self, shared: &Shared, layer: usize) -> Option<usize> {
        let table = self.roles.get()?;
        let epoch = self.membership.epoch();
        let alive = self.membership.alive_flags();
        let shrink = self.membership.policy() == RecoveryPolicy::Shrink;
        let (owner, handovers) = table.route(epoch, &alive, shrink, layer);
        for h in handovers {
            let src = &shared.params[h.from_wid].layers[h.layer];
            let dst = &shared.params[h.to_wid].layers[h.layer];
            for (ti, t) in src.tensors.iter().enumerate() {
                dst.tensors[ti].store_from_sharded(&t.state_dict(), &shared.update_pool);
            }
            dst.clock.load(src.clock.stamp());
            if let Some(ps) = shared.ps.as_ref() {
                if let (Some(a), Some(b)) = (ps.shard_of(h.from_wid), ps.shard_of(h.to_wid)) {
                    let st = ps.shards[a].lock().unwrap().opts[h.layer].state_dict();
                    let _ = ps.shards[b].lock().unwrap().opts[h.layer].load_state_dict(&st);
                }
            }
        }
        owner
    }

    fn link(&self, from: usize, to: usize) -> &LinkCounters {
        &self.links[from * self.m + to]
    }

    fn share(&self, to: usize, from: usize) -> &Mutex<ShareSlot> {
        &self.shares[to * self.m + from]
    }

    /// Count one message leaving `from` toward `to`.
    pub fn record_send(&self, shared: &Shared, from: usize, to: usize, step: usize, bytes: u64) {
        let l = self.link(from, to);
        l.msgs.fetch_add(1, Ordering::Relaxed);
        l.bytes.fetch_add(bytes, Ordering::Relaxed);
        if shared.events.has_observers() {
            shared.events.emit(TrainEvent::CommSent { from, to, step, bytes });
        }
    }

    /// Count one message the link dropped (also counts as sent).
    pub fn record_drop(&self, shared: &Shared, from: usize, to: usize, step: usize, bytes: u64) {
        let l = self.link(from, to);
        l.msgs.fetch_add(1, Ordering::Relaxed);
        l.bytes.fetch_add(bytes, Ordering::Relaxed);
        l.drops.fetch_add(1, Ordering::Relaxed);
        if shared.events.has_observers() {
            shared.events.emit(TrainEvent::CommDropped { from, to, step });
        }
    }

    /// Count a message rejected at delivery time (malformed payload): it
    /// was already counted as sent at push time, so only the drop counter
    /// bumps; the drop event still fires so the stream shows the loss.
    pub fn record_rejected(&self, shared: &Shared, from: usize, to: usize, step: usize) {
        self.link(from, to).drops.fetch_add(1, Ordering::Relaxed);
        if shared.events.has_observers() {
            shared.events.emit(TrainEvent::CommDropped { from, to, step });
        }
    }

    /// Count one delivery into `to`; staleness is `recv_step - sent_step`.
    pub fn record_delivered(
        &self,
        shared: &Shared,
        from: usize,
        to: usize,
        sent_step: usize,
        recv_step: usize,
    ) {
        let l = self.link(from, to);
        l.delivered.fetch_add(1, Ordering::Relaxed);
        let staleness = recv_step as i64 - sent_step as i64;
        l.staleness_sum.fetch_add(staleness, Ordering::Relaxed);
        if shared.events.has_observers() {
            shared
                .events
                .emit(TrainEvent::CommDelivered { from, to, step: sent_step, staleness });
        }
    }

    /// Instant-transport accounting for a push the sender applied in place
    /// (the fused gossip hot paths): one send plus one zero-staleness
    /// delivery.
    pub fn record_instant(&self, shared: &Shared, from: usize, to: usize, step: usize, bytes: u64) {
        self.record_send(shared, from, to, step, bytes);
        self.record_delivered(shared, from, to, step, step);
    }

    /// Deposit a gradient share from `from` into `to`'s mailbox.
    pub fn put_grads(&self, to: usize, from: usize, step: usize, set: Arc<GradSet>) {
        self.share(to, from).lock().unwrap().grads = Some((step, set));
    }

    /// Latest step-tagged gradient share `wid` received from `from`.
    pub fn latest_grads(&self, wid: usize, from: usize) -> Option<(usize, Arc<GradSet>)> {
        self.share(wid, from).lock().unwrap().grads.clone()
    }

    /// Deposit a parameter share from `from` into `to`'s mailbox.
    pub fn put_params(&self, to: usize, from: usize, step: usize, flat: Arc<Vec<f32>>) {
        self.share(to, from).lock().unwrap().params = Some((step, flat));
    }

    /// Latest step-tagged parameter share `wid` received from `from`.
    pub fn latest_params(&self, wid: usize, from: usize) -> Option<(usize, Arc<Vec<f32>>)> {
        self.share(wid, from).lock().unwrap().params.clone()
    }

    fn set_frac(&self, wid: usize, from: usize, step: usize, frac: f32) {
        let mut map = self.pending_frac[wid].lock().unwrap();
        // prune stale entries from the same sender (a lost layer-0 close
        // would otherwise leak the entry forever)
        map.retain(|&(f, s), _| f != from || s + 64 > step);
        map.insert((from, step), frac);
    }

    fn get_frac(&self, wid: usize, from: usize, step: usize) -> Option<f32> {
        self.pending_frac[wid].lock().unwrap().get(&(from, step)).copied()
    }

    fn clear_frac(&self, wid: usize, from: usize, step: usize) {
        self.pending_frac[wid].lock().unwrap().remove(&(from, step));
    }

    /// Aggregate the per-link counters into a [`CommStats`] snapshot.
    pub fn snapshot(&self) -> CommStats {
        let (frames_sent, frame_layers) = self.frame_counters();
        let mut stats = CommStats { frames_sent, frame_layers, ..CommStats::default() };
        for from in 0..self.m {
            for to in 0..self.m {
                let l = self.link(from, to);
                let msgs = l.msgs.load(Ordering::Relaxed);
                let bytes = l.bytes.load(Ordering::Relaxed);
                let drops = l.drops.load(Ordering::Relaxed);
                let delivered = l.delivered.load(Ordering::Relaxed);
                if msgs == 0 && delivered == 0 {
                    continue;
                }
                stats.msgs_sent += msgs;
                stats.bytes_sent += bytes;
                stats.msgs_dropped += drops;
                stats.msgs_delivered += delivered;
                stats.staleness_sum += l.staleness_sum.load(Ordering::Relaxed);
                stats.links.push(LinkTraffic { from, to, msgs, bytes, drops, delivered });
            }
        }
        stats
    }
}

/// Result of applying one delivered message to the receiver's state.
pub(crate) enum ApplyResult {
    /// Applied. `reply` is traffic the delivery itself produced (AD-PSGD's
    /// return half) for the fabric to ship.
    Applied {
        /// `(destination, payload)` to push on behalf of the receiver
        reply: Option<(usize, Payload)>,
    },
    /// The receiver's push-sum accept slot was busy; redeliver later
    /// (delayed, never destroyed).
    Busy,
    /// The payload's tensor lengths do not match the receiver's stores
    /// (truncated or corrupt message). Counted as a drop — NEVER partially
    /// applied; any shipped push-sum weight is refunded to the sender.
    Malformed,
}

/// Release-build shape validation of a delivered payload against the
/// receiver's stores. The mutating paths below rely on `debug_assert!`s in
/// `Tensor::axpy`/`AtomicTensor::mix_from`, so without this gate a
/// truncated message would silently mis-apply (or partially write) in
/// release builds. A malformed message counts as a drop, never a partial
/// write.
fn payload_shape_ok(shared: &Shared, wid: usize, payload: &Payload) -> bool {
    let model = &shared.params[wid];
    match payload {
        Payload::LayerPush { layer, values, .. } => {
            let Some(lp) = model.layers.get(*layer) else {
                return false;
            };
            values.len() == lp.tensors.len()
                && values.iter().zip(&lp.tensors).all(|(v, t)| v.len() == t.numel())
        }
        Payload::ModelPush { values, .. } => {
            values.len() == model.layers.len()
                && values.iter().zip(&model.layers).all(|(lv, lp)| {
                    lv.len() == lp.tensors.len()
                        && lv.iter().zip(&lp.tensors).all(|(v, t)| v.len() == t.numel())
                })
        }
        Payload::PairAverage { flat, .. } | Payload::ParamShare { flat } => {
            flat.len() == model.numel()
        }
        Payload::GradShare { set } => {
            set.len() == model.layers.len()
                && set.iter().zip(&model.layers).all(|(lv, lp)| {
                    lv.len() == lp.tensors.len()
                        && lv.iter().zip(&lp.tensors).all(|(g, t)| g.data.len() == t.numel())
                })
        }
        Payload::GradPush { layer, grads, x_then, .. } => {
            let Some(lp) = model.layers.get(*layer) else {
                return false;
            };
            let fits = |vals: &Vec<Vec<f32>>| {
                vals.len() == lp.tensors.len()
                    && vals.iter().zip(&lp.tensors).all(|(v, t)| v.len() == t.numel())
            };
            fits(grads) && x_then.as_ref().map(|x| fits(x)).unwrap_or(true)
        }
        Payload::ParamPull { layer, values, .. } => {
            let Some(lp) = model.layers.get(*layer) else {
                return false;
            };
            values.len() == lp.tensors.len()
                && values.iter().zip(&lp.tensors).all(|(v, t)| v.len() == t.numel())
        }
        Payload::StepFrame { entries, .. } => {
            !entries.is_empty()
                && entries.iter().all(|e| {
                    model.layers.get(e.layer).is_some_and(|lp| {
                        e.values.len() == lp.tensors.len()
                            && e.values.iter().zip(&lp.tensors).all(|(v, t)| v.len() == t.numel())
                    })
                })
        }
        // compressed payloads decode (with their own all-or-nothing
        // validation) before this gate; one reaching it is a framing bug
        Payload::Compressed(_) => false,
    }
}

/// Apply `payload` (sent by `from` at `step`) to worker `wid`'s state:
/// gossip payloads mix into the parameter store with push-sum bookkeeping,
/// collective shares land in the mailboxes. Shared by both transports — the
/// instant fabric calls it from `push`, the simulated one from
/// `deliver_due`.
pub(crate) fn apply(
    core: &FabricCore,
    shared: &Shared,
    wid: usize,
    from: usize,
    step: usize,
    payload: &Payload,
) -> ApplyResult {
    // codec boundary: a compressed message decodes to its dense payload
    // first. Decode is all-or-nothing — a truncated or corrupt blob returns
    // Malformed here (reject + push-sum weight refund), never a partial
    // write. A Busy outcome re-queues the original compressed message, so
    // the retry decodes again against the then-current receiver state.
    let decoded;
    let payload = match payload {
        Payload::Compressed(c) => {
            let _dec = shared.telemetry.span(crate::telemetry::Phase::CodecDecode);
            match c.decode(shared, wid) {
                Ok(p) => {
                    decoded = p;
                    &decoded
                }
                Err(_) => return ApplyResult::Malformed,
            }
        }
        p => p,
    };
    if !payload_shape_ok(shared, wid, payload) {
        return ApplyResult::Malformed;
    }
    match payload {
        Payload::LayerPush { layer, open, values, stamp, tau } => {
            let frac = match open {
                Some(w_in) => match shared.weights[wid].try_accept(*w_in) {
                    None => return ApplyResult::Busy,
                    Some(frac) => {
                        shared.weights[wid].release();
                        core.set_frac(wid, from, step, frac);
                        shared
                            .events
                            .emit(TrainEvent::GossipApplied { worker: from, peer: wid, step });
                        frac
                    }
                },
                None => match core.get_frac(wid, from, step) {
                    Some(f) => f,
                    // the opening message never arrived: this layer's mix is
                    // delayed to a later push (parameters, not weight mass)
                    None => return ApplyResult::Applied { reply: None },
                },
            };
            // staleness-adaptive mixing: a push whose gradient was computed
            // against τ-stale parameters mixes in attenuated (per layer)
            let frac = match shared.staleness_cfg.mixing {
                Mixing::Adaptive => {
                    crate::algorithms::attenuate_frac(frac, *tau, shared.staleness_cfg.mix_beta)
                }
                Mixing::Fixed => frac,
            };
            for (ti, vals) in values.iter().enumerate() {
                shared.params[wid].layers[*layer].tensors[ti].mix_from_sharded(
                    1.0 - frac,
                    frac,
                    vals,
                    &shared.update_pool,
                );
            }
            // provenance: this layer now carries the sender's stamped write
            shared.params[wid].layers[*layer]
                .clock
                .record(stamp.worker as usize, stamp.step as usize);
            if *layer == 0 {
                core.clear_frac(wid, from, step);
            }
            ApplyResult::Applied { reply: None }
        }
        Payload::StepFrame { open, entries } => {
            // one push-sum handshake for the whole step
            let frac = match open {
                Some(w_in) => match shared.weights[wid].try_accept(*w_in) {
                    None => return ApplyResult::Busy,
                    Some(frac) => {
                        shared.weights[wid].release();
                        // a frame normally carries the whole step, but a
                        // mid-step drain/restore can split one step across
                        // two frames — record the fraction so the closing
                        // half still mixes (cleared below when layer 0 lands)
                        core.set_frac(wid, from, step, frac);
                        shared
                            .events
                            .emit(TrainEvent::GossipApplied { worker: from, peer: wid, step });
                        frac
                    }
                },
                // a weightless frame (opening mass reclaimed sender-side, or
                // the closing half of a split step): fall back to an
                // established fraction, else defer — same semantics as a
                // follower LayerPush without its opener
                None => match core.get_frac(wid, from, step) {
                    Some(f) => f,
                    None => return ApplyResult::Applied { reply: None },
                },
            };
            for e in entries.iter() {
                let f = match shared.staleness_cfg.mixing {
                    Mixing::Adaptive => {
                        crate::algorithms::attenuate_frac(frac, e.tau, shared.staleness_cfg.mix_beta)
                    }
                    Mixing::Fixed => frac,
                };
                for (ti, vals) in e.values.iter().enumerate() {
                    shared.params[wid].layers[e.layer].tensors[ti].mix_from_sharded(
                        1.0 - f,
                        f,
                        vals,
                        &shared.update_pool,
                    );
                }
                shared.params[wid].layers[e.layer]
                    .clock
                    .record(e.stamp.worker as usize, e.stamp.step as usize);
            }
            // layer 0 closes the step (exactly like a standalone LayerPush):
            // only then does the fraction-table entry retire — a split
            // step's closing frame can still find it
            if entries.iter().any(|e| e.layer == 0) {
                core.clear_frac(wid, from, step);
            }
            ApplyResult::Applied { reply: None }
        }
        Payload::ModelPush { w_in, values } => match shared.weights[wid].try_accept(*w_in) {
            None => ApplyResult::Busy,
            Some(frac) => {
                for (li, layer) in values.iter().enumerate() {
                    for (ti, vals) in layer.iter().enumerate() {
                        shared.params[wid].layers[li].tensors[ti].mix_from_sharded(
                            1.0 - frac,
                            frac,
                            vals,
                            &shared.update_pool,
                        );
                    }
                    shared.params[wid].layers[li].clock.record(from, step);
                }
                shared.weights[wid].release();
                shared
                    .events
                    .emit(TrainEvent::GossipApplied { worker: from, peer: wid, step });
                ApplyResult::Applied { reply: None }
            }
        },
        Payload::PairAverage { flat, reply } => {
            let back = if *reply {
                None
            } else {
                Some((
                    from,
                    Payload::PairAverage {
                        flat: Arc::new(shared.params[wid].flatten()),
                        reply: true,
                    },
                ))
            };
            let mut off = 0usize;
            for layer in &shared.params[wid].layers {
                for t in &layer.tensors {
                    let n = t.numel();
                    t.mix_from_sharded(0.5, 0.5, &flat[off..off + n], &shared.update_pool);
                    off += n;
                }
                layer.clock.record(from, step);
            }
            shared
                .events
                .emit(TrainEvent::GossipApplied { worker: from, peer: wid, step });
            ApplyResult::Applied { reply: back }
        }
        Payload::GradShare { set } => {
            core.put_grads(wid, from, step, Arc::clone(set));
            ApplyResult::Applied { reply: None }
        }
        Payload::ParamShare { flat } => {
            core.put_params(wid, from, step, Arc::clone(flat));
            ApplyResult::Applied { reply: None }
        }
        Payload::GradPush { layer, grads, x_then, stamp } => {
            // only a parameter-server shard may receive gradient pushes; a
            // GradPush routed to a trainer is a corrupt/misrouted message
            let Some(ps) = shared.ps.as_ref() else {
                return ApplyResult::Malformed;
            };
            let Some(shard) = ps.shard_of(wid) else {
                return ApplyResult::Malformed;
            };
            // τ: shard writes this gradient missed (the trainer's stamp
            // mirrors the shard clock as of its last pull)
            crate::algorithms::observe_apply(shared, wid, Some(*stamp), *layer, step);
            let _sp = shared.telemetry.span(crate::telemetry::Phase::OptStep);
            let store = &shared.params[wid].layers[*layer];
            let mut gt: Vec<Tensor> = grads
                .iter()
                .zip(&store.tensors)
                .map(|(g, t)| Tensor::from_vec(t.shape(), g.clone()))
                .collect();
            let mut opt = ps.shards[shard].lock().unwrap();
            if let Some(xt) = x_then {
                let xt: Vec<Tensor> = xt
                    .iter()
                    .zip(&store.tensors)
                    .map(|(v, t)| Tensor::from_vec(t.shape(), v.clone()))
                    .collect();
                opt.compensate_layer(
                    &shared.params[wid],
                    *layer,
                    &mut gt,
                    shared.staleness_cfg.dc_lambda,
                    &xt,
                );
            }
            // the sender's step drives the LR schedule, as in flat async SGD
            opt.step_layer(&shared.params[wid], *layer, &gt, step);
            drop(opt);
            ps.grad_pushes.fetch_add(1, Ordering::Relaxed);
            ps.param_pulls.fetch_add(1, Ordering::Relaxed);
            let values: Vec<Vec<f32>> = store.tensors.iter().map(|t| t.state_dict()).collect();
            let reply = Payload::ParamPull {
                layer: *layer,
                values: Arc::new(values),
                stamp: store.clock.stamp(),
            };
            ApplyResult::Applied { reply: Some((from, reply)) }
        }
        Payload::ParamPull { layer, values, stamp } => {
            let store = &shared.params[wid].layers[*layer];
            for (ti, vals) in values.iter().enumerate() {
                store.tensors[ti].store_from_sharded(vals, &shared.update_pool);
            }
            // mirror the shard's clock: the next GradPush from this replica
            // carries exact shard-version provenance
            store.clock.load(*stamp);
            ApplyResult::Applied { reply: None }
        }
    }
}

/// Block (pumping deliveries) until every peer's gradient share for `step`
/// arrived at `wid`. `mine` fills the own-worker position so the result is
/// ordered by sender id — the all-reduce averaging order the seed code used,
/// kept for bit-identical averages. Returns `None` when the run is stopping.
///
/// Membership-aware: under the `Shrink` recovery policy a dead sender is
/// skipped (the collective averages over live contributors); under `Stall`
/// the collect keeps waiting — the worker rejoins, or the chaos supervisor
/// reports the stall and stops the run. Liveness is re-read every pass, so a
/// mid-collect membership change unblocks waiters.
pub fn collect_grads(
    shared: &Shared,
    wid: usize,
    step: usize,
    mine: Arc<GradSet>,
) -> Option<Vec<Arc<GradSet>>> {
    let shrink = shared.membership.policy() == RecoveryPolicy::Shrink;
    loop {
        shared.fabric.deliver_due(shared, wid, step);
        let mut out: Vec<Arc<GradSet>> = Vec::with_capacity(shared.m);
        let mut complete = true;
        for from in 0..shared.m {
            if from == wid {
                out.push(Arc::clone(&mine));
                continue;
            }
            if shrink && !shared.membership.alive(from) {
                continue;
            }
            match shared.fabric.core().latest_grads(wid, from) {
                Some((s, set)) if s == step => out.push(set),
                _ => {
                    complete = false;
                    break;
                }
            }
        }
        if complete {
            return Some(out);
        }
        if shared.should_stop() {
            return None;
        }
        std::thread::sleep(Duration::from_micros(200));
    }
}

/// Block (pumping deliveries) until every peer's parameter share for `step`
/// arrived at `wid`; ordering and membership semantics as in
/// [`collect_grads`]. `None` when stopping.
pub fn collect_params(
    shared: &Shared,
    wid: usize,
    step: usize,
    mine: Arc<Vec<f32>>,
) -> Option<Vec<Arc<Vec<f32>>>> {
    let shrink = shared.membership.policy() == RecoveryPolicy::Shrink;
    loop {
        shared.fabric.deliver_due(shared, wid, step);
        let mut out: Vec<Arc<Vec<f32>>> = Vec::with_capacity(shared.m);
        let mut complete = true;
        for from in 0..shared.m {
            if from == wid {
                out.push(Arc::clone(&mine));
                continue;
            }
            if shrink && !shared.membership.alive(from) {
                continue;
            }
            match shared.fabric.core().latest_params(wid, from) {
                Some((s, flat)) if s == step => out.push(flat),
                _ => {
                    complete = false;
                    break;
                }
            }
        }
        if complete {
            return Some(out);
        }
        if shared.should_stop() {
            return None;
        }
        std::thread::sleep(Duration::from_micros(200));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_specs_parse_and_validate() {
        assert_eq!(LatencyDist::parse("0.01").unwrap(), LatencyDist::Constant(0.01));
        assert_eq!(LatencyDist::parse("constant:0.5").unwrap(), LatencyDist::Constant(0.5));
        assert_eq!(
            LatencyDist::parse("uniform:0.001..0.02").unwrap(),
            LatencyDist::Uniform { lo: 0.001, hi: 0.02 }
        );
        assert_eq!(
            LatencyDist::parse("pareto:0.003,1.5").unwrap(),
            LatencyDist::Pareto { scale: 0.003, alpha: 1.5 }
        );
        assert!(LatencyDist::parse("gamma:1").is_err());
        assert!(LatencyDist::parse("uniform:5").is_err());
        assert!(LatencyDist::Uniform { lo: 0.2, hi: 0.1 }.validate().is_err());
        assert!(LatencyDist::Constant(-1.0).validate().is_err());
        assert!(LatencyDist::Pareto { scale: 0.0, alpha: 1.0 }.validate().is_err());
    }

    #[test]
    fn latency_samples_respect_bounds_and_mean() {
        let mut rng = Pcg32::new(5);
        let u = LatencyDist::Uniform { lo: 0.001, hi: 0.002 };
        for _ in 0..1000 {
            let v = u.sample(&mut rng);
            assert!((0.001..=0.002).contains(&v), "{v}");
        }
        let p = LatencyDist::Pareto { scale: 1e-3, alpha: 2.0 };
        for _ in 0..1000 {
            assert!(p.sample(&mut rng) >= 1e-3);
        }
        assert!((p.mean() - 2e-3).abs() < 1e-12);
        assert_eq!(LatencyDist::Constant(0.7).mean(), 0.7);
        assert!(LatencyDist::Pareto { scale: 1.0, alpha: 0.5 }.mean().is_infinite());
    }

    #[test]
    fn fabric_spec_validation_and_names() {
        assert_eq!(FabricSpec::Instant.name(), "instant");
        assert_eq!(FabricSpec::sim_default().name(), "sim");
        FabricSpec::Instant.validate().unwrap();
        FabricSpec::sim_default().validate().unwrap();
        let bad = FabricSpec::Sim {
            latency: LatencyDist::Constant(0.0),
            bandwidth_bytes_per_s: 0.0,
            drop_prob: 1.0,
        };
        assert!(bad.validate().is_err(), "drop probability 1.0 would drop everything");
    }

    #[test]
    fn payload_bytes_and_droppability() {
        let layer = Payload::LayerPush {
            layer: 0,
            open: Some(0.25),
            values: Arc::new(vec![vec![0.0; 10], vec![0.0; 2]]),
            stamp: crate::tensor::clock::ClockStamp::default(),
            tau: 0,
        };
        assert_eq!(layer.encoded_len(), wire_bytes(12));
        assert!(layer.droppable());
        assert_eq!(layer.shipped_weight(), 0.25);

        let share = Payload::ParamShare { flat: Arc::new(vec![0.0; 7]) };
        assert_eq!(share.encoded_len(), wire_bytes(7));
        assert!(!share.droppable(), "collective shares are reliable");
        assert_eq!(share.shipped_weight(), 0.0);

        // a coalesced step frame pays ONE header plus a 24-byte index slot
        // per layer — not one 32-byte header per layer. Three layers of 12
        // floats: 32 + 4·36 + 3·24 on the wire, vs 3·(32 + 4·12) uncoalesced.
        let entry = |layer: usize| FrameEntry {
            layer,
            stamp: crate::tensor::clock::ClockStamp::default(),
            tau: 0,
            values: Arc::new(vec![vec![0.0; 10], vec![0.0; 2]]),
        };
        let frame = Payload::StepFrame {
            open: Some(0.25),
            entries: Arc::new(vec![entry(2), entry(1), entry(0)]),
        };
        assert_eq!(frame.encoded_len(), wire_bytes(36) + 3 * FRAME_ENTRY_BYTES);
        // header amortization arithmetic: the saving is 32 − 24 = 8 bytes
        // per layer minus the frame's own 32-byte header — net positive once
        // a step spans more than 4 layers (3 layers still pay 8 bytes extra)
        assert_eq!(frame.encoded_len() - 3 * wire_bytes(12), 32 - 3 * 8);
        let wide = Payload::StepFrame {
            open: None,
            entries: Arc::new((0..8).rev().map(entry).collect()),
        };
        assert_eq!(wide.encoded_len(), wire_bytes(96) + 8 * FRAME_ENTRY_BYTES);
        assert!(
            wide.encoded_len() < 8 * wire_bytes(12),
            "an 8-layer frame must beat 8 standalone headers"
        );
        assert!(frame.droppable(), "frames inherit LayerPush's droppability");
        assert_eq!(frame.shipped_weight(), 0.25);
        assert_eq!(wide.shipped_weight(), 0.0);

        // a compressed payload meters its encoded size and carries the
        // inner payload's drop/weight metadata in the clear
        let packed = Payload::Compressed(Compressed {
            spec: CodecSpec::TopK { k: 8 },
            shipped_w: 0.25,
            droppable: true,
            blob: Arc::new(vec![0u8; 11]),
        });
        assert_eq!(packed.encoded_len(), wire_bytes(0) + 11);
        assert!(packed.droppable());
        assert_eq!(packed.shipped_weight(), 0.25);

        let push = Payload::GradPush {
            layer: 1,
            grads: Arc::new(vec![vec![0.0; 5], vec![0.0; 3]]),
            x_then: Some(Arc::new(vec![vec![0.0; 5], vec![0.0; 3]])),
            stamp: crate::tensor::clock::ClockStamp::default(),
        };
        assert_eq!(push.encoded_len(), wire_bytes(16), "x_then rides the wire too");
        assert!(!push.droppable(), "a lost gradient would skip an optimizer step");
        assert_eq!(push.shipped_weight(), 0.0, "PS traffic carries no push-sum mass");

        let pull = Payload::ParamPull {
            layer: 1,
            values: Arc::new(vec![vec![0.0; 5], vec![0.0; 3]]),
            stamp: crate::tensor::clock::ClockStamp::default(),
        };
        assert_eq!(pull.encoded_len(), wire_bytes(8));
        assert!(!pull.droppable());
        assert_eq!(pull.shipped_weight(), 0.0);
    }

    #[test]
    fn core_mailboxes_and_snapshot() {
        use crate::tensor::Tensor;

        let core = FabricCore::new(2);
        let set: GradSet = vec![vec![Tensor::from_vec(&[1], vec![3.0])]];
        core.put_grads(1, 0, 4, Arc::new(set));
        let (s, got) = core.latest_grads(1, 0).unwrap();
        assert_eq!(s, 4);
        assert_eq!(got[0][0].data, vec![3.0]);
        assert!(core.latest_grads(0, 1).is_none());

        core.put_params(0, 1, 9, Arc::new(vec![1.0, 2.0]));
        let (s, flat) = core.latest_params(0, 1).unwrap();
        assert_eq!(s, 9);
        assert_eq!(*flat, vec![1.0, 2.0]);

        // fraction table prunes per sender
        core.set_frac(0, 1, 10, 0.5);
        assert_eq!(core.get_frac(0, 1, 10), Some(0.5));
        core.set_frac(0, 1, 100, 0.25);
        assert_eq!(core.get_frac(0, 1, 10), None, "stale entry pruned");
        core.clear_frac(0, 1, 100);
        assert_eq!(core.get_frac(0, 1, 100), None);

        assert_eq!(core.snapshot().msgs_sent, 0);
    }

    fn lp(layer: usize, step: usize, open: Option<f32>) -> Payload {
        Payload::LayerPush {
            layer,
            open,
            values: Arc::new(vec![vec![layer as f32; 2]]),
            stamp: ClockStamp { worker: 0, step: step as u64, version: 1 + layer as u64 },
            tau: 0,
        }
    }

    /// The frame builder's whole lifecycle: intermediate pushes absorb
    /// (empty flush list), the layer-0 close ships one `StepFrame` holding
    /// every buffered layer in push order with the opening weight hoisted,
    /// and the counters meter exactly what reached the wire.
    #[test]
    fn frame_builder_buffers_until_layer_zero_closes() {
        let core = FabricCore::with_options(2, Arc::new(codec::DenseCodec), true);
        assert!(core.coalesce());
        assert!(core.coalesce_layer_push(0, 1, 5, lp(2, 5, Some(0.25))).is_empty());
        assert!(core.coalesce_layer_push(0, 1, 5, lp(1, 5, None)).is_empty());
        assert!((core.frame_open_mass() - 0.25).abs() < 1e-9, "builder holds the opening mass");
        assert_eq!(core.frame_counters(), (0, 0), "nothing reached the wire yet");
        let mut out = core.coalesce_layer_push(0, 1, 5, lp(0, 5, None));
        assert_eq!(out.len(), 1);
        let (step, payload) = out.pop().unwrap();
        assert_eq!(step, 5);
        let Payload::StepFrame { open, entries } = payload else {
            panic!("layer 0 must close the frame");
        };
        assert_eq!(open, Some(0.25));
        let layers: Vec<usize> = entries.iter().map(|e| e.layer).collect();
        assert_eq!(layers, vec![2, 1, 0], "push order (deepest first) preserved");
        assert_eq!(entries[0].stamp.version, 3, "entry stamps ride unchanged");
        assert_eq!(core.frame_open_mass(), 0.0);
        assert_eq!(core.frame_counters(), (1, 3));
        // non-LayerPush traffic passes through untouched
        let thru = core.coalesce_layer_push(0, 1, 6, Payload::ParamShare { flat: Arc::new(vec![]) });
        assert_eq!(thru.len(), 1);
        assert!(matches!(thru[0].1, Payload::ParamShare { .. }));
    }

    /// A sender that moved to a new step with the old step's frame still
    /// open (crash, skip, lost close) flushes the stale frame first — its
    /// mass and layers ship late rather than leaking in the builder.
    #[test]
    fn frame_builder_flushes_stale_step_before_starting_the_next() {
        let core = FabricCore::with_options(2, Arc::new(codec::DenseCodec), true);
        assert!(core.coalesce_layer_push(0, 1, 5, lp(2, 5, Some(0.25))).is_empty());
        let out = core.coalesce_layer_push(0, 1, 6, lp(2, 6, Some(0.125)));
        assert_eq!(out.len(), 1, "the stale step-5 frame flushes");
        assert_eq!(out[0].0, 5);
        assert!((out[0].1.shipped_weight() - 0.25).abs() < 1e-9);
        assert!((core.frame_open_mass() - 0.125).abs() < 1e-9, "step 6 is building");
        // closing step 6 ships the second frame
        let out = core.coalesce_layer_push(0, 1, 6, lp(0, 6, None));
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].0, 6);
        assert_eq!(core.frame_counters(), (2, 3));
    }

    /// The checkpoint companion: `drain_frames_to` empties every builder
    /// aimed at the worker into zero-delay in-flight frames (conserving the
    /// open mass) without bumping the wire counters — builder state is
    /// checkpoint state, not traffic.
    #[test]
    fn drain_frames_to_conserves_builder_state_without_counting_traffic() {
        let core = FabricCore::with_options(3, Arc::new(codec::DenseCodec), true);
        assert!(core.coalesce_layer_push(0, 2, 7, lp(1, 7, Some(0.5))).is_empty());
        assert!(core.coalesce_layer_push(1, 2, 3, lp(2, 3, None)).is_empty());
        assert!(core.drain_frames_to(0).is_empty(), "no builder aims at worker 0");
        let drained = core.drain_frames_to(2);
        assert_eq!(drained.len(), 2);
        for f in &drained {
            assert_eq!(f.to, 2);
            assert_eq!(f.remaining_s, 0.0);
            assert!(matches!(f.payload, Payload::StepFrame { .. }));
        }
        let total: f32 = drained.iter().map(|f| f.payload.shipped_weight()).sum();
        assert!((total - 0.5).abs() < 1e-9, "drained frames carry the open mass");
        assert_eq!(core.frame_open_mass(), 0.0, "builders emptied");
        assert_eq!(core.frame_counters(), (0, 0), "drain is not wire traffic");
    }
}
