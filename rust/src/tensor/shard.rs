//! **§Perf** — the shard pool behind the sharded parameter hot path.
//!
//! Every parameter-store traversal ([`AtomicTensor`](super::AtomicTensor)
//! update/mix/average kernels, `LayerOptimizer::step_with`/`compensate`) used
//! to be one sequential scalar loop over per-element relaxed atomics. The
//! [`ShardPool`] splits any traversal above a size threshold into disjoint
//! index ranges and runs them on a small set of persistent helper threads —
//! race-free *by construction*: the shards never overlap, and the underlying
//! stores are already lock-free `AtomicU32` slices, so concurrent writers
//! from other pools keep the usual Hogwild overwrite semantics.
//!
//! The pool is hand-rolled on `std::thread` + `std::sync::mpsc` (the repo's
//! zero-dependency style — no rayon): one persistent helper thread per extra
//! `update_threads`, a channel per helper, and a per-call ack channel the
//! caller blocks on. Shard 0 always runs on the calling thread, so
//! `update_threads = 1` (the default) is *exactly* the old single-threaded
//! behavior — same arithmetic per element in the same order, bit-identical.
//!
//! Sizing: work is split at [`CHUNK`]-element granularity (the same chunk the
//! kernels copy through stack scratch so LLVM can autovectorize the f32
//! arithmetic), and the pool only engages once a traversal spans at least two
//! chunks — below that the dispatch overhead dwarfs the loop.

use std::ops::Range;
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, OnceLock};
use std::thread::JoinHandle;

/// Elements per shard-kernel chunk: 4 KiB of f32 stack scratch, small enough
/// to stay cache-hot, large enough for the autovectorized inner loops to
/// amortize the copy in/out of the atomic store.
pub const CHUNK: usize = 1024;

/// A unit of sharded work shipped to a helper thread: an erased closure
/// (`call` reconstructs the concrete `&F` from `ctx`) plus the index range it
/// owns and the ack channel the dispatching caller blocks on.
struct Job {
    call: unsafe fn(*const (), Range<usize>),
    ctx: *const (),
    range: Range<usize>,
    done: Sender<()>,
}

// SAFETY: `ctx` points at an `F: Sync` on the dispatching caller's stack
// (enforced by the `ShardPool::run` bound), and the caller blocks until every
// job acked or died — the pointee outlives every use and may be shared.
unsafe impl Send for Job {}

/// Reconstruct the concrete closure behind a [`Job`]'s erased context pointer
/// and run it over the job's range.
///
/// # Safety
/// `ctx` must point at a live `F` (guaranteed by `run` blocking on the acks).
unsafe fn call_thunk<F: Fn(Range<usize>) + Sync>(ctx: *const (), range: Range<usize>) {
    (*ctx.cast::<F>())(range);
}

/// Persistent shard pool (see module docs). One pool is shared per
/// [`Shared`](crate::coordinator::Shared) coordinator — concurrent `run`
/// calls from different worker/updater threads interleave their jobs on the
/// helpers; each call only waits for its own acks.
pub struct ShardPool {
    /// one channel per persistent helper thread (empty ⇒ serial pool)
    senders: Vec<Sender<Job>>,
    handles: Vec<JoinHandle<()>>,
    /// run telemetry, installed by `Shared` after construction; sharded
    /// traversals record a `ShardKernel` span on the dispatching caller
    telemetry: OnceLock<Arc<crate::telemetry::Telemetry>>,
}

impl ShardPool {
    /// A pool with `update_threads` total lanes: the calling thread plus
    /// `update_threads − 1` persistent helpers. `new(1)` spawns nothing and
    /// behaves exactly like [`ShardPool::serial`].
    pub fn new(update_threads: usize) -> Arc<ShardPool> {
        let helpers = update_threads.saturating_sub(1);
        let mut senders = Vec::with_capacity(helpers);
        let mut handles = Vec::with_capacity(helpers);
        for i in 0..helpers {
            let (tx, rx) = channel::<Job>();
            let handle = std::thread::Builder::new()
                .name(format!("shard-{i}"))
                .spawn(move || {
                    while let Ok(Job { call, ctx, range, done }) = rx.recv() {
                        // SAFETY: the dispatching caller blocks until this
                        // job acks (or its sender drops on panic), so `ctx`
                        // is live for the duration of the call.
                        unsafe { call(ctx, range) };
                        let _ = done.send(());
                    }
                })
                .expect("spawn shard-pool helper thread");
            senders.push(tx);
            handles.push(handle);
        }
        Arc::new(ShardPool { senders, handles, telemetry: OnceLock::new() })
    }

    /// The zero-helper pool: every `run` executes inline on the caller.
    /// Used wherever sharding is not wired up (tests, default constructors).
    pub fn serial() -> Arc<ShardPool> {
        ShardPool::new(1)
    }

    /// Total lanes (caller + helpers) — the effective `update_threads`.
    pub fn threads(&self) -> usize {
        self.senders.len() + 1
    }

    /// Install the run's telemetry recorder (called once by `Shared` right
    /// after construction; later calls are no-ops).
    pub fn install_telemetry(&self, tel: &Arc<crate::telemetry::Telemetry>) {
        let _ = self.telemetry.set(Arc::clone(tel));
    }

    /// How many shards an `n`-element traversal splits into: 1 below the
    /// 2·[`CHUNK`] engage threshold, otherwise capped by both the lane count
    /// and the number of whole chunks (so no shard is smaller than a chunk).
    fn shards_for(&self, n: usize) -> usize {
        if self.senders.is_empty() || n < 2 * CHUNK {
            return 1;
        }
        self.threads().min(n / CHUNK)
    }

    /// Run `f` over `0..n`, split into disjoint contiguous ranges across the
    /// pool's lanes. Shard 0 runs on the calling thread; the call returns
    /// only after every shard finished, so `f` may borrow from the caller's
    /// stack. Serial pools (and traversals below the engage threshold) run
    /// `f(0..n)` inline — bit-identical to the unsharded loop.
    ///
    /// Panics if a helper died mid-job (a shard closure panicked): the
    /// traversal may be partially applied, which is indistinguishable from a
    /// lost lock-free update but must not pass silently.
    pub fn run<F: Fn(Range<usize>) + Sync>(&self, n: usize, f: F) {
        let shards = self.shards_for(n);
        if shards <= 1 {
            f(0..n);
            return;
        }
        // actually-sharded traversal: record it on the dispatching caller
        let _sp = self
            .telemetry
            .get()
            .map(|tel| tel.span(crate::telemetry::Phase::ShardKernel));
        let per = n.div_ceil(shards);
        let (ack_tx, ack_rx) = channel();
        let ctx: *const () = (&f as *const F).cast();
        let mut pending = 0usize;
        for s in 1..shards {
            let range = (s * per)..((s + 1) * per).min(n);
            let job = Job { call: call_thunk::<F>, ctx, range, done: ack_tx.clone() };
            match self.senders[(s - 1) % self.senders.len()].send(job) {
                Ok(()) => pending += 1,
                // helper already gone (its receiver dropped): its shard must
                // still execute exactly once — run it inline
                Err(dead) => f(dead.0.range),
            }
        }
        drop(ack_tx);
        f(0..per.min(n));
        for _ in 0..pending {
            if ack_rx.recv().is_err() {
                panic!("shard-pool helper died mid-traversal (shard closure panicked)");
            }
        }
    }
}

impl Drop for ShardPool {
    fn drop(&mut self) {
        // closing the channels ends each helper's recv loop
        self.senders.clear();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// A `&mut [T]` that can be carved into **disjoint** sub-slices from inside
/// the `Fn` shard closures (a plain `&mut` capture would make the closure
/// `FnMut` and un-sharable). The shard ranges handed out by
/// [`ShardPool::run`] never overlap, which is exactly the aliasing guarantee
/// [`DisjointMut::slice`] needs — see its safety contract.
pub struct DisjointMut<'a, T> {
    ptr: *mut T,
    len: usize,
    _marker: std::marker::PhantomData<&'a mut [T]>,
}

// SAFETY: shard closures on different threads only ever touch disjoint
// ranges (the `slice` contract), so sharing the wrapper is as safe as
// handing each thread its own split_at_mut half.
unsafe impl<T: Send> Sync for DisjointMut<'_, T> {}

impl<'a, T> DisjointMut<'a, T> {
    /// Wrap a mutable slice for disjoint-range access from shard closures.
    pub fn new(slice: &'a mut [T]) -> Self {
        DisjointMut {
            ptr: slice.as_mut_ptr(),
            len: slice.len(),
            _marker: std::marker::PhantomData,
        }
    }

    /// The wrapped slice's length.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when the wrapped slice is empty.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Reborrow `range` of the wrapped slice as `&mut`.
    ///
    /// # Safety
    /// Concurrent callers must pass **non-overlapping** in-bounds ranges —
    /// the pool's shard ranges satisfy this by construction. Two overlapping
    /// `slice` calls alias a `&mut` and are undefined behavior.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn slice(&self, range: Range<usize>) -> &mut [T] {
        debug_assert!(range.end <= self.len, "shard range out of bounds");
        std::slice::from_raw_parts_mut(self.ptr.add(range.start), range.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    /// Collect the ranges a run hands out and check they tile 0..n exactly.
    fn cover(pool: &ShardPool, n: usize) -> Vec<Range<usize>> {
        let ranges = std::sync::Mutex::new(Vec::new());
        pool.run(n, |r| ranges.lock().unwrap().push(r));
        let mut out = ranges.into_inner().unwrap();
        out.sort_by_key(|r| r.start);
        out
    }

    #[test]
    fn serial_pool_runs_inline_in_one_range() {
        let pool = ShardPool::serial();
        assert_eq!(pool.threads(), 1);
        assert_eq!(cover(&pool, 5000), vec![0..5000]);
    }

    #[test]
    fn shards_tile_the_range_exactly_once() {
        let pool = ShardPool::new(4);
        assert_eq!(pool.threads(), 4);
        for n in [0, 1, CHUNK - 1, CHUNK, 2 * CHUNK - 1, 2 * CHUNK, 5003, 4 * CHUNK + 7] {
            let ranges = cover(&pool, n);
            let mut next = 0;
            for r in &ranges {
                assert_eq!(r.start, next, "gap/overlap at n={n}");
                assert!(r.end > r.start, "empty shard at n={n}");
                next = r.end;
            }
            assert_eq!(next, n, "ranges must cover 0..{n}");
            if n < 2 * CHUNK {
                assert_eq!(ranges.len(), 1, "below threshold must stay serial (n={n})");
            }
        }
    }

    #[test]
    fn every_element_visited_exactly_once() {
        let pool = ShardPool::new(3);
        let n = 3 * CHUNK + 41;
        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        pool.run(n, |r| {
            for i in r {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn disjoint_mut_writes_land_per_shard() {
        let pool = ShardPool::new(4);
        let n = 4 * CHUNK;
        let mut data = vec![0u32; n];
        let dm = DisjointMut::new(&mut data);
        pool.run(n, |r| {
            // SAFETY: pool shards are disjoint
            let chunk = unsafe { dm.slice(r.clone()) };
            for (j, x) in chunk.iter_mut().enumerate() {
                *x = (r.start + j) as u32;
            }
        });
        assert!(data.iter().enumerate().all(|(i, &x)| x == i as u32));
    }

    #[test]
    fn pool_survives_many_concurrent_callers() {
        // several "updater threads" share one pool, like Shared does
        let pool = ShardPool::new(2);
        let n = 4 * CHUNK;
        std::thread::scope(|s| {
            for _ in 0..4 {
                let pool = &pool;
                s.spawn(move || {
                    for _ in 0..50 {
                        let total = AtomicUsize::new(0);
                        pool.run(n, |r| {
                            total.fetch_add(r.len(), Ordering::Relaxed);
                        });
                        assert_eq!(total.load(Ordering::Relaxed), n);
                    }
                });
            }
        });
    }
}
