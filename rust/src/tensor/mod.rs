//! Tensors for the L3 coordinator.
//!
//! Two flavours:
//!
//! * [`Tensor`] — plain host tensor (`Vec<f32>` + shape). Used for gradients,
//!   optimizer state, activations and anything thread-local.
//! * [`AtomicTensor`] — the **lock-free shared parameter store** at the heart
//!   of LayUp. Parameters are `[AtomicU32]` bit-cast f32, written with
//!   `Ordering::Relaxed`. Updater threads from *other* devices write directly
//!   into a worker's `AtomicTensor`s while that worker's compute thread reads
//!   them mid-forward — exactly the Hogwild-style overwrite semantics of the
//!   paper (Section 3.1: "multiple updater threads can update the same
//!   parameters simultaneously (lock-free) leading to the updates being
//!   overwritten"), but expressed in safe Rust: races lose *updates*, never
//!   memory safety.
//!
//! Write tracking lives one level up: every [`LayerParams`] carries a
//! [`clock::LayerClock`] stamped with `(worker, step)` provenance by each
//! writer. The runtime keys its XLA `Literal` upload cache on the clock's
//! monotone version (DESIGN.md §Perf), and the staleness machinery derives
//! the observed per-layer delay τ from clock snapshots — see
//! [`clock`] for the contract. (The seed-era per-tensor `version` counter
//! was folded into the layer clock.)
//!
//! **§Perf** — every [`AtomicTensor`] traversal is structured as
//! chunk-into-scratch → plain-f32 kernel → store-back (LLVM autovectorizes
//! the arithmetic on the stack scratch; it never vectorizes per-element
//! atomic ops), and each op has a `*_sharded` twin that splits the traversal
//! into disjoint index ranges on a [`shard::ShardPool`]. Disjoint shards over
//! lock-free stores are race-free by construction; with a serial pool (or
//! below the engage threshold) the sharded twins are bit-identical to the
//! scalar ops.

pub mod clock;
pub mod shard;

use std::ops::Range;
use std::sync::atomic::{AtomicU32, Ordering};

use clock::LayerClock;
use shard::{DisjointMut, ShardPool, CHUNK};

/// Plain host tensor: row-major f32 data plus shape.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Tensor { shape: shape.to_vec(), data }
    }

    pub fn full(shape: &[usize], v: f32) -> Self {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![v; n] }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    /// self += alpha * other
    pub fn axpy(&mut self, alpha: f32, other: &Tensor) {
        debug_assert_eq!(self.data.len(), other.data.len());
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += alpha * b;
        }
    }

    pub fn scale(&mut self, alpha: f32) {
        for a in &mut self.data {
            *a *= alpha;
        }
    }

    pub fn fill(&mut self, v: f32) {
        self.data.fill(v);
    }

    /// L2 norm squared.
    pub fn sq_norm(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum()
    }

    /// Squared L2 distance to another tensor.
    pub fn sq_dist(&self, other: &Tensor) -> f64 {
        debug_assert_eq!(self.data.len(), other.data.len());
        self.data
            .iter()
            .zip(&other.data)
            .map(|(&a, &b)| {
                let d = (a - b) as f64;
                d * d
            })
            .sum()
    }
}

/// Lock-free shared parameter tensor (see module docs). Write tracking
/// (upload-cache invalidation, staleness provenance) lives on the owning
/// layer's [`clock::LayerClock`], not here — writers stamp the layer clock
/// after their data stores.
pub struct AtomicTensor {
    shape: Vec<usize>,
    data: Box<[AtomicU32]>,
}

impl AtomicTensor {
    pub fn zeros(shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        let data: Box<[AtomicU32]> = (0..n).map(|_| AtomicU32::new(0f32.to_bits())).collect();
        AtomicTensor { shape: shape.to_vec(), data }
    }

    pub fn from_tensor(t: &Tensor) -> Self {
        let data: Box<[AtomicU32]> = t.data.iter().map(|&x| AtomicU32::new(x.to_bits())).collect();
        AtomicTensor { shape: t.shape.clone(), data }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    /// Relaxed-read `range` of the tensor into `out` (`out[j]` gets element
    /// `range.start + j`). The copy stays per-element atomic loads; the
    /// arithmetic kernels below do their math on the plain-f32 copy.
    pub(crate) fn load_range(&self, range: Range<usize>, out: &mut [f32]) {
        debug_assert_eq!(out.len(), range.len());
        for (o, a) in out.iter_mut().zip(&self.data[range]) {
            *o = f32::from_bits(a.load(Ordering::Relaxed));
        }
    }

    /// Relaxed-write `src` over `range` of the tensor (inverse of
    /// [`AtomicTensor::load_range`]).
    pub(crate) fn store_range(&self, range: Range<usize>, src: &[f32]) {
        debug_assert_eq!(src.len(), range.len());
        for (a, &s) in self.data[range].iter().zip(src.iter()) {
            a.store(s.to_bits(), Ordering::Relaxed);
        }
    }

    /// Relaxed-read the whole tensor into `out`. A concurrent writer may be
    /// interleaved — the result can mix old and new elements. That tearing is
    /// the *intended* semantics (the forward pass "might use those updates
    /// directly", Section 3).
    pub fn load_into(&self, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.data.len());
        self.load_range(0..self.data.len(), out);
    }

    /// [`AtomicTensor::load_into`] with the copy sharded across `pool`.
    pub fn load_into_sharded(&self, out: &mut [f32], pool: &ShardPool) {
        debug_assert_eq!(out.len(), self.data.len());
        let dst = DisjointMut::new(out);
        pool.run(self.data.len(), |r| {
            // SAFETY: pool shards are disjoint ranges
            self.load_range(r.clone(), unsafe { dst.slice(r) });
        });
    }

    pub fn snapshot(&self) -> Tensor {
        Tensor { shape: self.shape.clone(), data: self.state_dict() }
    }

    /// Relaxed-overwrite the whole tensor from `src`.
    pub fn store_from(&self, src: &[f32]) {
        debug_assert_eq!(src.len(), self.data.len());
        self.store_range(0..self.data.len(), src);
    }

    /// [`AtomicTensor::store_from`] with the copy sharded across `pool`.
    pub fn store_from_sharded(&self, src: &[f32], pool: &ShardPool) {
        debug_assert_eq!(src.len(), self.data.len());
        pool.run(self.data.len(), |r| self.store_range(r.clone(), &src[r]));
    }

    /// `p -= lr * g` over `range`; `grad` is range-aligned
    /// (`grad[j]` pairs with element `range.start + j`).
    pub(crate) fn sub_scaled_range(&self, range: Range<usize>, lr: f32, grad: &[f32]) {
        debug_assert_eq!(grad.len(), range.len());
        let mut buf = [0.0f32; CHUNK];
        let (start, end) = (range.start, range.end);
        let mut i = start;
        while i < end {
            let len = CHUNK.min(end - i);
            let b = &mut buf[..len];
            self.load_range(i..i + len, b);
            for (x, &g) in b.iter_mut().zip(&grad[i - start..i - start + len]) {
                *x -= lr * g;
            }
            self.store_range(i..i + len, b);
            i += len;
        }
    }

    /// Lock-free SGD-style update: `p -= lr * g` elementwise.
    /// Load-modify-store without CAS — concurrent writers may overwrite each
    /// other (the paper's explicit design choice).
    pub fn sub_scaled(&self, lr: f32, grad: &[f32]) {
        debug_assert_eq!(grad.len(), self.data.len());
        self.sub_scaled_range(0..self.data.len(), lr, grad);
    }

    /// [`AtomicTensor::sub_scaled`] with the traversal sharded across `pool`.
    pub fn sub_scaled_sharded(&self, lr: f32, grad: &[f32], pool: &ShardPool) {
        debug_assert_eq!(grad.len(), self.data.len());
        pool.run(self.data.len(), |r| self.sub_scaled_range(r.clone(), lr, &grad[r]));
    }

    /// `p = self_frac * p + peer_frac * incoming` over `range`; `incoming`
    /// is range-aligned.
    pub(crate) fn mix_range(
        &self,
        range: Range<usize>,
        self_frac: f32,
        peer_frac: f32,
        incoming: &[f32],
    ) {
        debug_assert_eq!(incoming.len(), range.len());
        let mut buf = [0.0f32; CHUNK];
        let (start, end) = (range.start, range.end);
        let mut i = start;
        while i < end {
            let len = CHUNK.min(end - i);
            let b = &mut buf[..len];
            self.load_range(i..i + len, b);
            for (x, &inc) in b.iter_mut().zip(&incoming[i - start..i - start + len]) {
                *x = self_frac * *x + peer_frac * inc;
            }
            self.store_range(i..i + len, b);
            i += len;
        }
    }

    /// Lock-free push-sum mix used by the gossip updater threads:
    /// `p = self_frac * p + peer_frac * incoming` elementwise.
    pub fn mix_from(&self, self_frac: f32, peer_frac: f32, incoming: &[f32]) {
        debug_assert_eq!(incoming.len(), self.data.len());
        self.mix_range(0..self.data.len(), self_frac, peer_frac, incoming);
    }

    /// [`AtomicTensor::mix_from`] with the traversal sharded across `pool`.
    pub fn mix_from_sharded(
        &self,
        self_frac: f32,
        peer_frac: f32,
        incoming: &[f32],
        pool: &ShardPool,
    ) {
        debug_assert_eq!(incoming.len(), self.data.len());
        pool.run(self.data.len(), |r| {
            self.mix_range(r.clone(), self_frac, peer_frac, &incoming[r]);
        });
    }

    /// Fused update+mix over `range` (see
    /// [`AtomicTensor::sub_scaled_then_mix_into`]); `update` is
    /// range-aligned.
    pub(crate) fn sub_scaled_then_mix_range(
        &self,
        range: Range<usize>,
        lr: f32,
        update: &[f32],
        peer: &AtomicTensor,
        keep_frac: f32,
        push_frac: f32,
    ) {
        debug_assert_eq!(update.len(), range.len());
        debug_assert_eq!(peer.data.len(), self.data.len());
        let mut buf = [0.0f32; CHUNK];
        let mut pbuf = [0.0f32; CHUNK];
        let (start, end) = (range.start, range.end);
        let mut i = start;
        while i < end {
            let len = CHUNK.min(end - i);
            let (b, pb) = (&mut buf[..len], &mut pbuf[..len]);
            self.load_range(i..i + len, b);
            for (x, &u) in b.iter_mut().zip(&update[i - start..i - start + len]) {
                *x -= lr * u;
            }
            self.store_range(i..i + len, b);
            peer.load_range(i..i + len, pb);
            for (p, &new) in pb.iter_mut().zip(b.iter()) {
                *p = keep_frac * *p + push_frac * new;
            }
            peer.store_range(i..i + len, pb);
            i += len;
        }
    }

    /// Fused updater hot path (§Perf): apply the local update `p -= lr * u`
    /// **and** push the freshly updated value into `peer`
    /// (`peer = keep_frac * peer + push_frac * p_new`) in one traversal.
    ///
    /// Numerically identical to `sub_scaled(lr, update)` followed by
    /// `load_into(scratch)` + `peer.mix_from(keep_frac, push_frac, scratch)`
    /// — which walks the layer's data three times — absent concurrent
    /// writers; under races the usual lock-free overwrite semantics apply.
    pub fn sub_scaled_then_mix_into(
        &self,
        lr: f32,
        update: &[f32],
        peer: &AtomicTensor,
        keep_frac: f32,
        push_frac: f32,
    ) {
        debug_assert_eq!(update.len(), self.data.len());
        self.sub_scaled_then_mix_range(
            0..self.data.len(),
            lr,
            update,
            peer,
            keep_frac,
            push_frac,
        );
    }

    /// [`AtomicTensor::sub_scaled_then_mix_into`] with the traversal sharded
    /// across `pool`.
    pub fn sub_scaled_then_mix_sharded(
        &self,
        lr: f32,
        update: &[f32],
        peer: &AtomicTensor,
        keep_frac: f32,
        push_frac: f32,
        pool: &ShardPool,
    ) {
        debug_assert_eq!(update.len(), self.data.len());
        pool.run(self.data.len(), |r| {
            self.sub_scaled_then_mix_range(
                r.clone(),
                lr,
                &update[r],
                peer,
                keep_frac,
                push_frac,
            );
        });
    }

    /// Checkpoint view of the store: the current values as a plain host
    /// vector (a relaxed snapshot, like [`AtomicTensor::snapshot`] without
    /// the shape). Collected directly from the relaxed loads — no
    /// zero-fill-then-overwrite double write.
    pub fn state_dict(&self) -> Vec<f32> {
        self.data.iter().map(|a| f32::from_bits(a.load(Ordering::Relaxed))).collect()
    }

    /// Restore from a [`AtomicTensor::state_dict`] snapshot. Like every
    /// other write, the caller stamps the owning layer's clock so upload
    /// caches invalidate.
    pub fn load_state_dict(&self, values: &[f32]) {
        self.store_from(values);
    }

    /// Element-wise average with the other stores over `range`.
    pub(crate) fn average_range(&self, range: Range<usize>, others: &[&AtomicTensor]) {
        let denom = (others.len() + 1) as f32;
        let mut acc = [0.0f32; CHUNK];
        let mut tmp = [0.0f32; CHUNK];
        let (start, end) = (range.start, range.end);
        let mut i = start;
        while i < end {
            let len = CHUNK.min(end - i);
            let (a, t) = (&mut acc[..len], &mut tmp[..len]);
            self.load_range(i..i + len, a);
            for o in others {
                o.load_range(i..i + len, t);
                for (x, &y) in a.iter_mut().zip(t.iter()) {
                    *x += y;
                }
            }
            for x in a.iter_mut() {
                *x /= denom;
            }
            self.store_range(i..i + len, a);
            i += len;
        }
    }

    /// Element-wise average with `k` other parameter stores (DDP all-reduce
    /// endpoint; AD-PSGD pairwise averaging uses the 2-way case).
    pub fn average_with(&self, others: &[&AtomicTensor]) {
        debug_assert!(others.iter().all(|o| o.data.len() == self.data.len()));
        self.average_range(0..self.data.len(), others);
    }

    /// [`AtomicTensor::average_with`] with the traversal sharded across
    /// `pool`.
    pub fn average_with_sharded(&self, others: &[&AtomicTensor], pool: &ShardPool) {
        debug_assert!(others.iter().all(|o| o.data.len() == self.data.len()));
        pool.run(self.data.len(), |r| self.average_range(r, others));
    }
}

/// One model layer's named parameter tensors (shared store) plus the
/// layer's staleness clock. Writers stamp the clock after their data
/// stores; readers snapshot it (see [`clock`]).
pub struct LayerParams {
    pub tensors: Vec<AtomicTensor>,
    /// per-layer write clock: provenance-stamped, monotone-versioned
    pub clock: LayerClock,
}

impl LayerParams {
    /// A layer store with a fresh clock.
    pub fn new(tensors: Vec<AtomicTensor>) -> LayerParams {
        LayerParams { tensors, clock: LayerClock::new() }
    }

    pub fn numel(&self) -> usize {
        self.tensors.iter().map(|t| t.numel()).sum()
    }

    /// The layer's write-version (upload-cache key) — the clock's counter.
    pub fn version(&self) -> u64 {
        self.clock.version()
    }

    pub fn snapshot(&self) -> Vec<Tensor> {
        self.tensors.iter().map(|t| t.snapshot()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn tensor_axpy_scale() {
        let mut a = Tensor::from_vec(&[3], vec![1.0, 2.0, 3.0]);
        let b = Tensor::from_vec(&[3], vec![1.0, 1.0, 1.0]);
        a.axpy(0.5, &b);
        assert_eq!(a.data, vec![1.5, 2.5, 3.5]);
        a.scale(2.0);
        assert_eq!(a.data, vec![3.0, 5.0, 7.0]);
    }

    #[test]
    fn tensor_sq_dist() {
        let a = Tensor::from_vec(&[2], vec![0.0, 3.0]);
        let b = Tensor::from_vec(&[2], vec![4.0, 0.0]);
        assert_eq!(a.sq_dist(&b), 25.0);
    }

    #[test]
    fn atomic_roundtrip() {
        let at = AtomicTensor::zeros(&[4]);
        at.store_from(&[1.0, -2.0, 3.5, 0.25]);
        assert_eq!(at.snapshot().data, vec![1.0, -2.0, 3.5, 0.25]);
    }

    #[test]
    fn layer_params_version_tracks_the_clock() {
        let lp = LayerParams::new(vec![AtomicTensor::zeros(&[2]), AtomicTensor::zeros(&[3])]);
        assert_eq!(lp.numel(), 5);
        assert_eq!(lp.version(), 0);
        lp.tensors[0].store_from(&[1.0, 2.0]);
        lp.clock.record(1, 7);
        assert_eq!(lp.version(), 1, "a stamped write invalidates the upload cache");
        let s = lp.clock.stamp();
        assert_eq!((s.worker, s.step), (1, 7));
    }

    #[test]
    fn atomic_sub_scaled() {
        let at = AtomicTensor::from_tensor(&Tensor::from_vec(&[3], vec![1.0, 1.0, 1.0]));
        at.sub_scaled(0.1, &[1.0, 2.0, 3.0]);
        let s = at.snapshot().data;
        assert!((s[0] - 0.9).abs() < 1e-6);
        assert!((s[1] - 0.8).abs() < 1e-6);
        assert!((s[2] - 0.7).abs() < 1e-6);
    }

    #[test]
    fn atomic_mix_is_convex_combination() {
        let at = AtomicTensor::from_tensor(&Tensor::from_vec(&[2], vec![0.0, 10.0]));
        at.mix_from(0.25, 0.75, &[4.0, 2.0]);
        let s = at.snapshot().data;
        assert!((s[0] - 3.0).abs() < 1e-6);
        assert!((s[1] - 4.0).abs() < 1e-6);
    }

    #[test]
    fn fused_update_mix_matches_three_pass_path() {
        let init = vec![1.0, -2.0, 0.5, 3.0];
        let grad = vec![0.4, -1.0, 2.0, 0.0];
        let peer_init = vec![10.0, 0.0, -4.0, 1.0];
        let (lr, keep, push) = (0.1f32, 0.75f32, 0.25f32);

        // reference: the original three-pass sequence
        let a = AtomicTensor::from_tensor(&Tensor::from_vec(&[4], init.clone()));
        let p = AtomicTensor::from_tensor(&Tensor::from_vec(&[4], peer_init.clone()));
        a.sub_scaled(lr, &grad);
        let mut scratch = vec![0.0; 4];
        a.load_into(&mut scratch);
        p.mix_from(keep, push, &scratch);

        // fused single traversal
        let af = AtomicTensor::from_tensor(&Tensor::from_vec(&[4], init));
        let pf = AtomicTensor::from_tensor(&Tensor::from_vec(&[4], peer_init));
        af.sub_scaled_then_mix_into(lr, &grad, &pf, keep, push);

        assert_eq!(af.snapshot().data, a.snapshot().data);
        assert_eq!(pf.snapshot().data, p.snapshot().data);
    }

    #[test]
    fn atomic_average_with() {
        let a = AtomicTensor::from_tensor(&Tensor::from_vec(&[2], vec![0.0, 3.0]));
        let b = AtomicTensor::from_tensor(&Tensor::from_vec(&[2], vec![6.0, 3.0]));
        let c = AtomicTensor::from_tensor(&Tensor::from_vec(&[2], vec![3.0, 3.0]));
        a.average_with(&[&b, &c]);
        assert_eq!(a.snapshot().data, vec![3.0, 3.0]);
    }

    /// Deterministic pseudo-random fill (no rand crate in the offline set).
    fn lcg_data(n: usize, mut seed: u32) -> Vec<f32> {
        (0..n)
            .map(|_| {
                seed = seed.wrapping_mul(1664525).wrapping_add(1013904223);
                (seed >> 8) as f32 / (1 << 24) as f32 - 0.5
            })
            .collect()
    }

    /// The sharded twins must be **bit-identical** to the scalar ops for
    /// every traversal, exercised at the chunk boundaries: below one chunk,
    /// exactly one chunk, and a prime above threads·chunk (so the last shard
    /// is ragged). Elementwise math is independent per element, so chunking
    /// and sharding may not change a single bit.
    #[test]
    fn sharded_ops_bit_identical_to_scalar_at_chunk_boundaries() {
        let pool = shard::ShardPool::new(4);
        for n in [shard::CHUNK - 3, shard::CHUNK, 5003] {
            let init = lcg_data(n, 1);
            let grad = lcg_data(n, 2);
            let peer_init = lcg_data(n, 3);
            let pair = || {
                (
                    AtomicTensor::from_tensor(&Tensor::from_vec(&[n], init.clone())),
                    AtomicTensor::from_tensor(&Tensor::from_vec(&[n], init.clone())),
                )
            };
            let bits = |t: &AtomicTensor| -> Vec<u32> {
                t.state_dict().iter().map(|v| v.to_bits()).collect()
            };

            let (a, b) = pair();
            a.sub_scaled(0.1, &grad);
            b.sub_scaled_sharded(0.1, &grad, &pool);
            assert_eq!(bits(&a), bits(&b), "sub_scaled n={n}");

            let (a, b) = pair();
            a.mix_from(0.75, 0.25, &grad);
            b.mix_from_sharded(0.75, 0.25, &grad, &pool);
            assert_eq!(bits(&a), bits(&b), "mix_from n={n}");

            let (a, b) = pair();
            let pa = AtomicTensor::from_tensor(&Tensor::from_vec(&[n], peer_init.clone()));
            let pb = AtomicTensor::from_tensor(&Tensor::from_vec(&[n], peer_init.clone()));
            a.sub_scaled_then_mix_into(0.1, &grad, &pa, 0.6, 0.4);
            b.sub_scaled_then_mix_sharded(0.1, &grad, &pb, 0.6, 0.4, &pool);
            assert_eq!(bits(&a), bits(&b), "fused self n={n}");
            assert_eq!(bits(&pa), bits(&pb), "fused peer n={n}");

            let (a, b) = pair();
            let o1 = AtomicTensor::from_tensor(&Tensor::from_vec(&[n], grad.clone()));
            let o2 = AtomicTensor::from_tensor(&Tensor::from_vec(&[n], peer_init.clone()));
            a.average_with(&[&o1, &o2]);
            b.average_with_sharded(&[&o1, &o2], &pool);
            assert_eq!(bits(&a), bits(&b), "average_with n={n}");

            let (a, b) = pair();
            a.store_from(&grad);
            b.store_from_sharded(&grad, &pool);
            assert_eq!(bits(&a), bits(&b), "store_from n={n}");

            let mut out_a = vec![0.0f32; n];
            let mut out_b = vec![0.0f32; n];
            a.load_into(&mut out_a);
            b.load_into_sharded(&mut out_b, &pool);
            assert_eq!(out_a, out_b, "load_into n={n}");
        }
    }

    /// Sharding lives strictly *below* the clock protocol: concurrent
    /// writers driving sharded stores still stamp the layer clock exactly
    /// once per logical write, so the version count equals the write count.
    #[test]
    fn sharded_concurrent_writers_stamp_clock_once_per_write() {
        let n = 4 * shard::CHUNK + 7;
        let lp = Arc::new(LayerParams::new(vec![AtomicTensor::zeros(&[n])]));
        let pool = shard::ShardPool::new(3);
        let writes_per_thread = 25;
        std::thread::scope(|s| {
            for w in 0..4usize {
                let lp = Arc::clone(&lp);
                let pool = Arc::clone(&pool);
                s.spawn(move || {
                    let vals = vec![w as f32 + 1.0; n];
                    for step in 0..writes_per_thread {
                        lp.tensors[0].store_from_sharded(&vals, &pool);
                        lp.clock.record(w, step);
                    }
                });
            }
        });
        assert_eq!(
            lp.version(),
            4 * writes_per_thread as u64,
            "one stamp per logical write, no extra stamps from sharding"
        );
        for v in lp.tensors[0].state_dict() {
            assert!((1.0..=4.0).contains(&v), "v={v}");
        }
    }

    #[test]
    fn concurrent_lockfree_writes_stay_safe() {
        // Hammer one tensor from several threads; we assert only memory
        // safety and that the final value is one of the written values
        // per element (updates may be lost — by design).
        let at = Arc::new(AtomicTensor::zeros(&[64]));
        let threads: Vec<_> = (0..4)
            .map(|t| {
                let at = Arc::clone(&at);
                std::thread::spawn(move || {
                    let vals = vec![t as f32 + 1.0; 64];
                    for _ in 0..1000 {
                        at.store_from(&vals);
                        at.sub_scaled(0.0, &vals); // no-op math, real traffic
                    }
                })
            })
            .collect();
        for th in threads {
            th.join().unwrap();
        }
        for v in at.snapshot().data {
            assert!((1.0..=4.0).contains(&v), "v={v}");
        }
    }
}
