//! Tensors for the L3 coordinator.
//!
//! Two flavours:
//!
//! * [`Tensor`] — plain host tensor (`Vec<f32>` + shape). Used for gradients,
//!   optimizer state, activations and anything thread-local.
//! * [`AtomicTensor`] — the **lock-free shared parameter store** at the heart
//!   of LayUp. Parameters are `[AtomicU32]` bit-cast f32, written with
//!   `Ordering::Relaxed`. Updater threads from *other* devices write directly
//!   into a worker's `AtomicTensor`s while that worker's compute thread reads
//!   them mid-forward — exactly the Hogwild-style overwrite semantics of the
//!   paper (Section 3.1: "multiple updater threads can update the same
//!   parameters simultaneously (lock-free) leading to the updates being
//!   overwritten"), but expressed in safe Rust: races lose *updates*, never
//!   memory safety.
//!
//! Write tracking lives one level up: every [`LayerParams`] carries a
//! [`clock::LayerClock`] stamped with `(worker, step)` provenance by each
//! writer. The runtime keys its XLA `Literal` upload cache on the clock's
//! monotone version (DESIGN.md §Perf), and the staleness machinery derives
//! the observed per-layer delay τ from clock snapshots — see
//! [`clock`] for the contract. (The seed-era per-tensor `version` counter
//! was folded into the layer clock.)

pub mod clock;

use std::sync::atomic::{AtomicU32, Ordering};

use clock::LayerClock;

/// Plain host tensor: row-major f32 data plus shape.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Tensor { shape: shape.to_vec(), data }
    }

    pub fn full(shape: &[usize], v: f32) -> Self {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![v; n] }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    /// self += alpha * other
    pub fn axpy(&mut self, alpha: f32, other: &Tensor) {
        debug_assert_eq!(self.data.len(), other.data.len());
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += alpha * b;
        }
    }

    pub fn scale(&mut self, alpha: f32) {
        for a in &mut self.data {
            *a *= alpha;
        }
    }

    pub fn fill(&mut self, v: f32) {
        self.data.fill(v);
    }

    /// L2 norm squared.
    pub fn sq_norm(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum()
    }

    /// Squared L2 distance to another tensor.
    pub fn sq_dist(&self, other: &Tensor) -> f64 {
        debug_assert_eq!(self.data.len(), other.data.len());
        self.data
            .iter()
            .zip(&other.data)
            .map(|(&a, &b)| {
                let d = (a - b) as f64;
                d * d
            })
            .sum()
    }
}

/// Lock-free shared parameter tensor (see module docs). Write tracking
/// (upload-cache invalidation, staleness provenance) lives on the owning
/// layer's [`clock::LayerClock`], not here — writers stamp the layer clock
/// after their data stores.
pub struct AtomicTensor {
    shape: Vec<usize>,
    data: Box<[AtomicU32]>,
}

impl AtomicTensor {
    pub fn zeros(shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        let data: Box<[AtomicU32]> = (0..n).map(|_| AtomicU32::new(0f32.to_bits())).collect();
        AtomicTensor { shape: shape.to_vec(), data }
    }

    pub fn from_tensor(t: &Tensor) -> Self {
        let data: Box<[AtomicU32]> = t.data.iter().map(|&x| AtomicU32::new(x.to_bits())).collect();
        AtomicTensor { shape: t.shape.clone(), data }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    /// Relaxed-read the whole tensor into `out`. A concurrent writer may be
    /// interleaved — the result can mix old and new elements. That tearing is
    /// the *intended* semantics (the forward pass "might use those updates
    /// directly", Section 3).
    pub fn load_into(&self, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.data.len());
        for (o, a) in out.iter_mut().zip(self.data.iter()) {
            *o = f32::from_bits(a.load(Ordering::Relaxed));
        }
    }

    pub fn snapshot(&self) -> Tensor {
        let mut t = Tensor::zeros(&self.shape);
        self.load_into(&mut t.data);
        t
    }

    /// Relaxed-overwrite the whole tensor from `src`.
    pub fn store_from(&self, src: &[f32]) {
        debug_assert_eq!(src.len(), self.data.len());
        for (a, &s) in self.data.iter().zip(src.iter()) {
            a.store(s.to_bits(), Ordering::Relaxed);
        }
    }

    /// Lock-free SGD-style update: `p -= lr * g` elementwise.
    /// Load-modify-store without CAS — concurrent writers may overwrite each
    /// other (the paper's explicit design choice).
    pub fn sub_scaled(&self, lr: f32, grad: &[f32]) {
        debug_assert_eq!(grad.len(), self.data.len());
        for (a, &g) in self.data.iter().zip(grad.iter()) {
            let cur = f32::from_bits(a.load(Ordering::Relaxed));
            a.store((cur - lr * g).to_bits(), Ordering::Relaxed);
        }
    }

    /// Lock-free push-sum mix used by the gossip updater threads:
    /// `p = self_frac * p + peer_frac * incoming` elementwise.
    pub fn mix_from(&self, self_frac: f32, peer_frac: f32, incoming: &[f32]) {
        debug_assert_eq!(incoming.len(), self.data.len());
        for (a, &inc) in self.data.iter().zip(incoming.iter()) {
            let cur = f32::from_bits(a.load(Ordering::Relaxed));
            a.store((self_frac * cur + peer_frac * inc).to_bits(), Ordering::Relaxed);
        }
    }

    /// Fused updater hot path (§Perf): apply the local update `p -= lr * u`
    /// **and** push the freshly updated value into `peer`
    /// (`peer = keep_frac * peer + push_frac * p_new`) in one traversal.
    ///
    /// Numerically identical to `sub_scaled(lr, update)` followed by
    /// `load_into(scratch)` + `peer.mix_from(keep_frac, push_frac, scratch)`
    /// — which walks the layer's data three times — absent concurrent
    /// writers; under races the usual lock-free overwrite semantics apply.
    pub fn sub_scaled_then_mix_into(
        &self,
        lr: f32,
        update: &[f32],
        peer: &AtomicTensor,
        keep_frac: f32,
        push_frac: f32,
    ) {
        debug_assert_eq!(update.len(), self.data.len());
        debug_assert_eq!(peer.data.len(), self.data.len());
        for ((a, &u), pa) in self.data.iter().zip(update.iter()).zip(peer.data.iter()) {
            let new = f32::from_bits(a.load(Ordering::Relaxed)) - lr * u;
            a.store(new.to_bits(), Ordering::Relaxed);
            let pcur = f32::from_bits(pa.load(Ordering::Relaxed));
            pa.store((keep_frac * pcur + push_frac * new).to_bits(), Ordering::Relaxed);
        }
    }

    /// Checkpoint view of the store: the current values as a plain host
    /// vector (a relaxed snapshot, like [`AtomicTensor::snapshot`] without
    /// the shape).
    pub fn state_dict(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.data.len()];
        self.load_into(&mut out);
        out
    }

    /// Restore from a [`AtomicTensor::state_dict`] snapshot. Like every
    /// other write, the caller stamps the owning layer's clock so upload
    /// caches invalidate.
    pub fn load_state_dict(&self, values: &[f32]) {
        self.store_from(values);
    }

    /// Element-wise average with `k` other parameter stores (DDP all-reduce
    /// endpoint; AD-PSGD pairwise averaging uses the 2-way case).
    pub fn average_with(&self, others: &[&AtomicTensor]) {
        let n = self.data.len();
        let denom = (others.len() + 1) as f32;
        for i in 0..n {
            let mut acc = f32::from_bits(self.data[i].load(Ordering::Relaxed));
            for o in others {
                acc += f32::from_bits(o.data[i].load(Ordering::Relaxed));
            }
            self.data[i].store((acc / denom).to_bits(), Ordering::Relaxed);
        }
    }
}

/// One model layer's named parameter tensors (shared store) plus the
/// layer's staleness clock. Writers stamp the clock after their data
/// stores; readers snapshot it (see [`clock`]).
pub struct LayerParams {
    pub tensors: Vec<AtomicTensor>,
    /// per-layer write clock: provenance-stamped, monotone-versioned
    pub clock: LayerClock,
}

impl LayerParams {
    /// A layer store with a fresh clock.
    pub fn new(tensors: Vec<AtomicTensor>) -> LayerParams {
        LayerParams { tensors, clock: LayerClock::new() }
    }

    pub fn numel(&self) -> usize {
        self.tensors.iter().map(|t| t.numel()).sum()
    }

    /// The layer's write-version (upload-cache key) — the clock's counter.
    pub fn version(&self) -> u64 {
        self.clock.version()
    }

    pub fn snapshot(&self) -> Vec<Tensor> {
        self.tensors.iter().map(|t| t.snapshot()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn tensor_axpy_scale() {
        let mut a = Tensor::from_vec(&[3], vec![1.0, 2.0, 3.0]);
        let b = Tensor::from_vec(&[3], vec![1.0, 1.0, 1.0]);
        a.axpy(0.5, &b);
        assert_eq!(a.data, vec![1.5, 2.5, 3.5]);
        a.scale(2.0);
        assert_eq!(a.data, vec![3.0, 5.0, 7.0]);
    }

    #[test]
    fn tensor_sq_dist() {
        let a = Tensor::from_vec(&[2], vec![0.0, 3.0]);
        let b = Tensor::from_vec(&[2], vec![4.0, 0.0]);
        assert_eq!(a.sq_dist(&b), 25.0);
    }

    #[test]
    fn atomic_roundtrip() {
        let at = AtomicTensor::zeros(&[4]);
        at.store_from(&[1.0, -2.0, 3.5, 0.25]);
        assert_eq!(at.snapshot().data, vec![1.0, -2.0, 3.5, 0.25]);
    }

    #[test]
    fn layer_params_version_tracks_the_clock() {
        let lp = LayerParams::new(vec![AtomicTensor::zeros(&[2]), AtomicTensor::zeros(&[3])]);
        assert_eq!(lp.numel(), 5);
        assert_eq!(lp.version(), 0);
        lp.tensors[0].store_from(&[1.0, 2.0]);
        lp.clock.record(1, 7);
        assert_eq!(lp.version(), 1, "a stamped write invalidates the upload cache");
        let s = lp.clock.stamp();
        assert_eq!((s.worker, s.step), (1, 7));
    }

    #[test]
    fn atomic_sub_scaled() {
        let at = AtomicTensor::from_tensor(&Tensor::from_vec(&[3], vec![1.0, 1.0, 1.0]));
        at.sub_scaled(0.1, &[1.0, 2.0, 3.0]);
        let s = at.snapshot().data;
        assert!((s[0] - 0.9).abs() < 1e-6);
        assert!((s[1] - 0.8).abs() < 1e-6);
        assert!((s[2] - 0.7).abs() < 1e-6);
    }

    #[test]
    fn atomic_mix_is_convex_combination() {
        let at = AtomicTensor::from_tensor(&Tensor::from_vec(&[2], vec![0.0, 10.0]));
        at.mix_from(0.25, 0.75, &[4.0, 2.0]);
        let s = at.snapshot().data;
        assert!((s[0] - 3.0).abs() < 1e-6);
        assert!((s[1] - 4.0).abs() < 1e-6);
    }

    #[test]
    fn fused_update_mix_matches_three_pass_path() {
        let init = vec![1.0, -2.0, 0.5, 3.0];
        let grad = vec![0.4, -1.0, 2.0, 0.0];
        let peer_init = vec![10.0, 0.0, -4.0, 1.0];
        let (lr, keep, push) = (0.1f32, 0.75f32, 0.25f32);

        // reference: the original three-pass sequence
        let a = AtomicTensor::from_tensor(&Tensor::from_vec(&[4], init.clone()));
        let p = AtomicTensor::from_tensor(&Tensor::from_vec(&[4], peer_init.clone()));
        a.sub_scaled(lr, &grad);
        let mut scratch = vec![0.0; 4];
        a.load_into(&mut scratch);
        p.mix_from(keep, push, &scratch);

        // fused single traversal
        let af = AtomicTensor::from_tensor(&Tensor::from_vec(&[4], init));
        let pf = AtomicTensor::from_tensor(&Tensor::from_vec(&[4], peer_init));
        af.sub_scaled_then_mix_into(lr, &grad, &pf, keep, push);

        assert_eq!(af.snapshot().data, a.snapshot().data);
        assert_eq!(pf.snapshot().data, p.snapshot().data);
    }

    #[test]
    fn atomic_average_with() {
        let a = AtomicTensor::from_tensor(&Tensor::from_vec(&[2], vec![0.0, 3.0]));
        let b = AtomicTensor::from_tensor(&Tensor::from_vec(&[2], vec![6.0, 3.0]));
        let c = AtomicTensor::from_tensor(&Tensor::from_vec(&[2], vec![3.0, 3.0]));
        a.average_with(&[&b, &c]);
        assert_eq!(a.snapshot().data, vec![3.0, 3.0]);
    }

    #[test]
    fn concurrent_lockfree_writes_stay_safe() {
        // Hammer one tensor from several threads; we assert only memory
        // safety and that the final value is one of the written values
        // per element (updates may be lost — by design).
        let at = Arc::new(AtomicTensor::zeros(&[64]));
        let threads: Vec<_> = (0..4)
            .map(|t| {
                let at = Arc::clone(&at);
                std::thread::spawn(move || {
                    let vals = vec![t as f32 + 1.0; 64];
                    for _ in 0..1000 {
                        at.store_from(&vals);
                        at.sub_scaled(0.0, &vals); // no-op math, real traffic
                    }
                })
            })
            .collect();
        for th in threads {
            th.join().unwrap();
        }
        for v in at.snapshot().data {
            assert!((1.0..=4.0).contains(&v), "v={v}");
        }
    }
}
