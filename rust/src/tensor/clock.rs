//! Per-layer staleness clocks: versioned write provenance for the shared
//! parameter stores.
//!
//! The seed-era code counted writes per *tensor* (`AtomicTensor.version`)
//! purely as an upload-cache key; nothing recorded *who* wrote or *when*, so
//! the staleness the paper reasons about — "the gradient was computed
//! against parameters that have since been overwritten k times" — was not
//! observable. A [`LayerClock`] makes it first-class:
//!
//! * every **writer** (optimizer step, gossip mix, checkpoint restore)
//!   stamps `(worker, step)` provenance and bumps a monotone version
//!   counter via [`LayerClock::record`];
//! * every **reader** (forward upload, backward, fabric send) takes a
//!   [`ClockStamp`] snapshot via [`LayerClock::stamp`];
//! * at gradient-apply time the observed per-layer delay is
//!   `τ = version_now − snapshot.version` — the number of writes that landed
//!   on the layer between the pass's parameter read and this apply
//!   ([`observed_tau`]). On a serial 1-worker instant-fabric run τ is 0; the
//!   decoupled pools and delayed fabrics make it positive, which is exactly
//!   what the delay-compensated (`dc`) and staleness-adaptive update
//!   policies act on.
//!
//! Like the parameter stores themselves, clocks are lock-free: the version
//! counter is strictly monotone (`fetch_add`), while the packed provenance
//! word is a racy last-writer-wins store — a concurrent [`stamp`] may pair a
//! version with the provenance of a neighbouring write. That tearing only
//! blurs *who* wrote (diagnostics); τ, the upload-cache key and the
//! histogram counts all derive from the monotone version alone.
//!
//! [`stamp`]: LayerClock::stamp
//! [`observed_tau`]: LayerClock::observed_tau

use std::sync::atomic::{AtomicU64, Ordering};

/// Snapshot of one layer's clock: the last writer's provenance plus the
/// monotone write-version at snapshot time.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ClockStamp {
    /// worker id of the last writer (0 for the initializer)
    pub worker: u32,
    /// the last writer's training step
    pub step: u64,
    /// monotone write counter at snapshot time
    pub version: u64,
}

// The provenance word packs the full 32-bit worker id with the low 32 bits
// of the step into one atomic u64 (so a stamp can never pair one writer's
// worker with another's step). Steps are recorded modulo 2^32 — ~4 billion
// steps, far beyond any run this system drives — so `load` round-trips
// every checkpoint exactly.
const STEP_BITS: u32 = 32;
const STEP_MASK: u64 = (1 << STEP_BITS) - 1;

fn pack(worker: u32, step: u64) -> u64 {
    ((worker as u64) << STEP_BITS) | (step & STEP_MASK)
}

fn unpack(packed: u64) -> (u32, u64) {
    ((packed >> STEP_BITS) as u32, packed & STEP_MASK)
}

/// One layer's staleness clock (see module docs). Owned by
/// [`crate::tensor::LayerParams`]; the runtime's upload cache keys on
/// [`LayerClock::version`], replacing the seed-era per-tensor counters.
#[derive(Debug, Default)]
pub struct LayerClock {
    /// strictly monotone write counter (the upload-cache key)
    version: AtomicU64,
    /// `(worker, step)` of the last writer, packed (racy vs `version`)
    packed: AtomicU64,
}

impl LayerClock {
    /// A fresh clock: version 0, provenance "worker 0 at step 0".
    pub fn new() -> LayerClock {
        LayerClock::default()
    }

    /// Monotone write counter; readers use it to invalidate upload caches
    /// and to compute observed staleness.
    pub fn version(&self) -> u64 {
        self.version.load(Ordering::Acquire)
    }

    /// Stamp one write: record `(worker, step)` provenance and bump the
    /// version. Called by every parameter writer *after* its data stores.
    pub fn record(&self, worker: usize, step: usize) {
        self.packed.store(pack(worker as u32, step as u64), Ordering::Relaxed);
        self.version.fetch_add(1, Ordering::Release);
    }

    /// Reader snapshot: the last writer's provenance + current version.
    pub fn stamp(&self) -> ClockStamp {
        let version = self.version.load(Ordering::Acquire);
        let (worker, step) = unpack(self.packed.load(Ordering::Relaxed));
        ClockStamp { worker, step, version }
    }

    /// Observed delay of a gradient apply against a read-time snapshot: the
    /// number of writes that landed on this layer since `snap` was taken.
    pub fn observed_tau(&self, snap: &ClockStamp) -> u64 {
        self.version().saturating_sub(snap.version)
    }

    /// Restore an exact clock state (checkpoint resume). Unlike
    /// [`LayerClock::record`] this sets the version rather than bumping it,
    /// so a resumed run carries the snapshot's clocks bit-identically.
    pub fn load(&self, stamp: ClockStamp) {
        self.packed.store(pack(stamp.worker, stamp.step), Ordering::Relaxed);
        self.version.store(stamp.version, Ordering::Release);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn record_stamps_provenance_and_bumps_version() {
        let c = LayerClock::new();
        assert_eq!(c.stamp(), ClockStamp { worker: 0, step: 0, version: 0 });
        c.record(3, 17);
        let s = c.stamp();
        assert_eq!((s.worker, s.step, s.version), (3, 17, 1));
        c.record(1, 18);
        let s2 = c.stamp();
        assert_eq!((s2.worker, s2.step, s2.version), (1, 18, 2));
        assert_eq!(c.observed_tau(&s), 1, "one write landed since the snapshot");
        assert_eq!(c.observed_tau(&s2), 0);
    }

    #[test]
    fn load_restores_exact_state_for_resume() {
        let c = LayerClock::new();
        c.record(0, 1);
        c.record(2, 5);
        let snap = c.stamp();
        let restored = LayerClock::new();
        restored.load(snap);
        assert_eq!(restored.stamp(), snap, "resume carries clocks bit-identically");
        // a later snapshot from the past never yields negative τ
        let old = ClockStamp { worker: 0, step: 0, version: snap.version + 10 };
        assert_eq!(restored.observed_tau(&old), 0);
    }

    #[test]
    fn wide_worker_and_step_values_round_trip() {
        // the full u32 worker range survives (the provenance word gives the
        // worker all 32 bits; steps carry their low 32 bits)
        let c = LayerClock::new();
        c.record(u32::MAX as usize, (u32::MAX - 1) as usize);
        let s = c.stamp();
        assert_eq!(s.worker, u32::MAX);
        assert_eq!(s.step, (u32::MAX - 1) as u64);
        let restored = LayerClock::new();
        restored.load(s);
        assert_eq!(restored.stamp(), s, "load round-trips wide ids exactly");
    }

    /// The tentpole invariant: the version counter is strictly monotone
    /// under concurrent writers — every record is counted exactly once, so
    /// τ can never under-report intervening writes.
    #[test]
    fn version_is_monotone_and_exact_under_concurrent_writers() {
        let c = Arc::new(LayerClock::new());
        let threads: Vec<_> = (0..4)
            .map(|t| {
                let c = Arc::clone(&c);
                std::thread::spawn(move || {
                    let mut last = 0u64;
                    for i in 0..1000 {
                        c.record(t, i);
                        let v = c.version();
                        assert!(v > last, "monotone per observer");
                        last = v;
                    }
                })
            })
            .collect();
        for th in threads {
            th.join().unwrap();
        }
        assert_eq!(c.version(), 4000, "every write counted exactly once");
    }
}
