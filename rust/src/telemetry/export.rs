//! Telemetry export: Chrome-trace JSON (opens in Perfetto /
//! `chrome://tracing`) and a plain-text metrics exposition dump.
//!
//! The trace layout: one `pid 0` process named `layup`, one thread track per
//! registered [`ThreadTrack`] (metadata `M` events carry the track labels),
//! every retained span as a complete `X` event (microsecond `ts`/`dur`, the
//! phase's snake_case name), and the sampler's series as counter `C` events
//! (`mfu`, `queue_depth`, `flops_per_s`, `wire_bytes_per_s`, `push_weight`,
//! `tau_mean`) sharing the same time origin as the spans.

use std::path::Path;

use anyhow::{Context, Result};

use crate::telemetry::Telemetry;
use crate::util::json::{arr, num, obj, s, Json};

/// Counter-track names emitted from the sampled series, paired with an
/// extractor. Split out so the exporter and its invariant tests agree on
/// the set.
const COUNTERS: [&str; 6] =
    ["mfu", "queue_depth", "flops_per_s", "wire_bytes_per_s", "push_weight", "tau_mean"];

fn counter_value(name: &str, smp: &crate::telemetry::sampler::Sample) -> f64 {
    match name {
        "mfu" => smp.mfu,
        "queue_depth" => smp.queue_depth as f64,
        "flops_per_s" => smp.flops_per_s,
        "wire_bytes_per_s" => smp.bytes_per_s,
        "push_weight" => smp.push_weight,
        _ => smp.tau_mean,
    }
}

/// Render the recorder as a Chrome-trace document
/// (`{"traceEvents": [...]}`).
pub fn chrome_trace(tel: &Telemetry) -> Json {
    let mut events: Vec<Json> = Vec::new();
    events.push(obj(vec![
        ("ph", s("M")),
        ("name", s("process_name")),
        ("pid", num(0.0)),
        ("tid", num(0.0)),
        ("args", obj(vec![("name", s("layup"))])),
    ]));

    for track in tel.tracks() {
        let tid = track.tid() as f64;
        events.push(obj(vec![
            ("ph", s("M")),
            ("name", s("thread_name")),
            ("pid", num(0.0)),
            ("tid", num(tid)),
            ("args", obj(vec![("name", s(track.name()))])),
        ]));
        for span in track.spans() {
            events.push(obj(vec![
                ("ph", s("X")),
                ("pid", num(0.0)),
                ("tid", num(tid)),
                ("name", s(span.phase.name())),
                ("cat", s("layup")),
                ("ts", num(span.start_ns as f64 / 1e3)),
                ("dur", num(span.dur_ns as f64 / 1e3)),
            ]));
        }
    }

    for smp in tel.samples() {
        let ts = smp.t_s * 1e6;
        for name in COUNTERS {
            events.push(obj(vec![
                ("ph", s("C")),
                ("pid", num(0.0)),
                ("tid", num(0.0)),
                ("name", s(name)),
                ("ts", num(ts)),
                ("args", obj(vec![("value", num(counter_value(name, &smp)))])),
            ]));
        }
        for link in &smp.links {
            events.push(obj(vec![
                ("ph", s("C")),
                ("pid", num(0.0)),
                ("tid", num(0.0)),
                ("name", s(&format!("link_{}_{}_bytes_per_s", link.from, link.to))),
                ("ts", num(ts)),
                ("args", obj(vec![("value", num(link.bytes_per_s))])),
            ]));
        }
    }

    obj(vec![("traceEvents", arr(events))])
}

/// Write the Chrome-trace JSON to `path` (parent directories are created).
pub fn write_chrome_trace(tel: &Telemetry, path: &Path) -> Result<()> {
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)
                .with_context(|| format!("creating trace directory {}", dir.display()))?;
        }
    }
    std::fs::write(path, chrome_trace(tel).dump())
        .with_context(|| format!("writing trace {}", path.display()))
}

/// Plain-text metrics exposition: one `name value` line per counter, the
/// per-phase aggregate table, and the last sampled gauge values.
pub fn metrics_text(tel: &Telemetry) -> String {
    use std::fmt::Write as _;
    let st = tel.stats();
    let mut out = String::new();
    let _ = writeln!(out, "telemetry_enabled {}", u8::from(st.enabled));
    let _ = writeln!(out, "telemetry_spans {}", st.spans);
    let _ = writeln!(out, "telemetry_dropped {}", st.dropped);
    let _ = writeln!(out, "telemetry_threads {}", st.threads);
    let _ = writeln!(out, "telemetry_samples {}", st.samples);
    for p in &st.phases {
        let _ = writeln!(out, "phase_{}_count {}", p.name, p.count);
        let _ = writeln!(out, "phase_{}_total_s {:.9}", p.name, p.total_s);
        let _ = writeln!(out, "phase_{}_self_s {:.9}", p.name, p.self_s);
    }
    if let Some(last) = tel.samples().last() {
        let _ = writeln!(out, "last_sample_t_s {:.6}", last.t_s);
        for name in COUNTERS {
            let _ = writeln!(out, "last_{} {:.6}", name, counter_value(name, last));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::{sampler::Sample, Phase, TelemetryConfig};

    fn recording_telemetry() -> std::sync::Arc<Telemetry> {
        let tel = Telemetry::from_config(&TelemetryConfig {
            enabled: true,
            ring_capacity: 64,
            ..TelemetryConfig::default()
        });
        tel.register_thread("export-test");
        {
            let _outer = tel.span(Phase::Forward);
            let _inner = tel.span(Phase::CodecEncode);
        }
        {
            let _sp = tel.span(Phase::Backward);
        }
        tel.push_sample(Sample { t_s: 0.1, mfu: 0.5, queue_depth: 2, ..Sample::default() });
        tel
    }

    /// Satellite: trace-export invariants — the document parses as JSON,
    /// every span event has a non-negative duration, and every span's `tid`
    /// belongs to a declared thread track.
    #[test]
    fn trace_is_valid_json_with_declared_tracks_and_nonnegative_durations() {
        let tel = recording_telemetry();
        let text = chrome_trace(&tel).dump();
        let doc = Json::parse(&text).expect("trace must parse as JSON");
        let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
        assert!(!events.is_empty());

        let mut declared_tids = Vec::new();
        for e in events {
            if e.get("ph").unwrap().as_str().unwrap() == "M"
                && e.get("name").unwrap().as_str().unwrap() == "thread_name"
            {
                declared_tids.push(e.get("tid").unwrap().as_f64().unwrap() as i64);
            }
        }
        assert!(!declared_tids.is_empty(), "at least one thread track declared");

        let mut span_events = 0usize;
        for e in events {
            if e.get("ph").unwrap().as_str().unwrap() != "X" {
                continue;
            }
            span_events += 1;
            let dur = e.get("dur").unwrap().as_f64().unwrap();
            let ts = e.get("ts").unwrap().as_f64().unwrap();
            assert!(dur >= 0.0, "span durations are non-negative");
            assert!(ts >= 0.0, "span timestamps are non-negative");
            let tid = e.get("tid").unwrap().as_f64().unwrap() as i64;
            assert!(
                declared_tids.contains(&tid),
                "span tid {tid} nested within a declared thread track"
            );
            let name = e.get("name").unwrap().as_str().unwrap();
            assert!(
                crate::telemetry::PHASES.iter().any(|p| p.name() == name),
                "span name {name} is in the phase taxonomy"
            );
        }
        assert_eq!(span_events, 3, "all recorded spans exported");
    }

    #[test]
    fn counter_tracks_cover_mfu_and_queue_depth() {
        let tel = recording_telemetry();
        let doc = chrome_trace(&tel);
        let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
        let mut counters = Vec::new();
        for e in events {
            if e.get("ph").unwrap().as_str().unwrap() == "C" {
                counters.push(e.get("name").unwrap().as_str().unwrap().to_string());
                // counter payload is a single numeric value
                let v = e.get("args").unwrap().get("value").unwrap().as_f64().unwrap();
                assert!(v.is_finite());
            }
        }
        assert!(counters.iter().any(|c| c == "mfu"));
        assert!(counters.iter().any(|c| c == "queue_depth"));
    }

    #[test]
    fn disabled_recorder_exports_an_empty_trace() {
        let tel = Telemetry::disabled();
        let doc = chrome_trace(&tel);
        let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
        // only the process_name metadata event: no tracks, no spans
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].get("ph").unwrap().as_str().unwrap(), "M");
    }

    #[test]
    fn metrics_text_lists_every_phase() {
        let tel = recording_telemetry();
        let text = metrics_text(&tel);
        assert!(text.contains("telemetry_enabled 1"));
        assert!(text.contains("telemetry_spans 3"));
        for p in crate::telemetry::PHASES {
            assert!(text.contains(&format!("phase_{}_count", p.name())));
        }
        assert!(text.contains("last_mfu 0.500000"));
    }

    #[test]
    fn trace_file_roundtrips_from_disk() {
        let tel = recording_telemetry();
        let dir = std::env::temp_dir().join(format!("layup-trace-{}", std::process::id()));
        let path = dir.join("trace.json");
        write_chrome_trace(&tel, &path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(Json::parse(&text).is_ok());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
