//! Run telemetry: low-overhead span tracing, a background time-series
//! sampler and Chrome-trace export (EXPERIMENTS.md §Telemetry).
//!
//! The paper's headline claims are throughput claims — higher model flops
//! utilization from decoupled backprop — and end-of-run aggregates cannot
//! show *where* a step's time goes (forward vs. queue wait vs. optimizer
//! apply vs. codec encode vs. fabric delivery). This module makes the
//! timeline first-class, in three zero-dependency parts:
//!
//! * **Span tracing** — every instrumented section records a [`Phase`]-tagged
//!   span into a per-thread fixed-capacity ring ([`ThreadTrack`]: drop-oldest
//!   with a dropped counter, lock-free single-writer). Recording costs two
//!   monotonic-clock reads plus relaxed atomic stores (~tens of ns); when
//!   telemetry is disabled — the default — every site pays one relaxed
//!   atomic load, allocates nothing, and runs are bit-identical to
//!   pre-telemetry builds.
//! * **Time-series sampler** — [`sampler`] runs a background thread that
//!   snapshots queue depth, compute occupancy (live MFU), FLOP/s, τ means,
//!   push-sum weight and wire bytes/s into a bounded in-memory series at a
//!   configurable period.
//! * **Export** — [`export`] renders the rings and the sampled series as
//!   Chrome-trace JSON (one track per OS thread plus counter tracks; opens
//!   in Perfetto / `chrome://tracing`) or a plain-text metrics dump, and
//!   [`Telemetry::stats`] summarizes span/drop counts and per-phase
//!   total/self time into the `telemetry` section of
//!   [`crate::metrics::RunStats`].
//!
//! Wired as `[telemetry]` config, `--trace <path>` / `--sample-every-ms`
//! CLI flags and `SessionBuilder::telemetry(...)`.

pub mod export;
pub mod sampler;

use std::cell::RefCell;
use std::collections::VecDeque;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicI64, AtomicU32, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{bail, Result};

/// The closed phase taxonomy. Every instrumented hot-path section is one of
/// these; the set is deliberately small and stable so traces from different
/// runs (and the CI smoke assertions) compare phase-for-phase.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(usize)]
pub enum Phase {
    /// Forward pass (serial loop, forward pool, lockstep).
    Forward = 0,
    /// Blocking on the bounded pass queue (decoupled push/pop).
    QueueWait = 1,
    /// Backward pass (serial loop, backward pool, lockstep).
    Backward = 2,
    /// Optimizer apply: LayUp updater `step_layer`, PS shard-side step.
    OptStep = 3,
    /// Wire-codec encode at the fabric push boundary (non-dense codecs).
    CodecEncode = 4,
    /// Wire-codec decode at the fabric apply boundary.
    CodecDecode = 5,
    /// `Fabric::push` — metering, drop dice, queueing or instant apply.
    FabricPush = 6,
    /// `Fabric::deliver_due` applying queued messages at a step boundary.
    FabricDeliver = 7,
    /// Gossip mixing: LayUp peer push / fused update+mix sections.
    Gossip = 8,
    /// Checkpoint rendezvous write.
    Checkpoint = 9,
    /// A sharded `ShardPool` tensor traversal (only when actually sharded).
    ShardKernel = 10,
}

/// All phases, in `repr` order (index == discriminant).
pub const PHASES: [Phase; Phase::COUNT] = [
    Phase::Forward,
    Phase::QueueWait,
    Phase::Backward,
    Phase::OptStep,
    Phase::CodecEncode,
    Phase::CodecDecode,
    Phase::FabricPush,
    Phase::FabricDeliver,
    Phase::Gossip,
    Phase::Checkpoint,
    Phase::ShardKernel,
];

impl Phase {
    /// Number of phases in the taxonomy.
    pub const COUNT: usize = 11;

    /// Stable snake_case name — used as the Chrome-trace event name and in
    /// the metrics exposition dump.
    pub fn name(self) -> &'static str {
        match self {
            Phase::Forward => "forward",
            Phase::QueueWait => "queue_wait",
            Phase::Backward => "backward",
            Phase::OptStep => "opt_step",
            Phase::CodecEncode => "codec_encode",
            Phase::CodecDecode => "codec_decode",
            Phase::FabricPush => "fabric_push",
            Phase::FabricDeliver => "fabric_deliver",
            Phase::Gossip => "gossip",
            Phase::Checkpoint => "checkpoint",
            Phase::ShardKernel => "shard_kernel",
        }
    }

    /// Inverse of the `repr` discriminant (ring slots store it as `u32`).
    pub fn from_index(i: usize) -> Option<Phase> {
        PHASES.get(i).copied()
    }
}

/// `[telemetry]` section of the train config. Defaults keep telemetry OFF:
/// no recorder threads, no spans, bit-identical hot paths.
#[derive(Clone, Debug)]
pub struct TelemetryConfig {
    /// Master switch. Setting `trace` (config) or `--trace` (CLI) implies it.
    pub enabled: bool,
    /// Where to write the Chrome-trace JSON at run end (`None` = don't).
    pub trace_path: Option<PathBuf>,
    /// Background sampler period in milliseconds (`0` disables the sampler
    /// thread while keeping span tracing on).
    pub sample_every_ms: u64,
    /// Per-thread span ring capacity (drop-oldest beyond it).
    pub ring_capacity: usize,
}

impl Default for TelemetryConfig {
    fn default() -> TelemetryConfig {
        TelemetryConfig {
            enabled: false,
            trace_path: None,
            sample_every_ms: 100,
            ring_capacity: 16384,
        }
    }
}

impl TelemetryConfig {
    /// Validate the knobs (called from `TrainConfig::validate`).
    pub fn validate(&self) -> Result<()> {
        if self.enabled && self.ring_capacity == 0 {
            bail!("telemetry: ring_capacity must be >= 1 when telemetry is enabled");
        }
        Ok(())
    }
}

/// One OS thread's fixed-capacity span ring. Single-writer (the owning
/// thread), many-reader (export/stats): the writer stores the record columns
/// relaxed, then publishes by bumping `total` with `Release`; readers load
/// `total` with `Acquire` and only trust slots at least one full lap old or
/// below the published count. Capacity overflow drops the *oldest* span —
/// `total` keeps counting, so the dropped count is exact.
pub struct ThreadTrack {
    name: String,
    tid: usize,
    cap: usize,
    /// Spans ever recorded on this track (slot = `total % cap`).
    total: AtomicUsize,
    phase: Vec<AtomicU32>,
    start_ns: Vec<AtomicU64>,
    dur_ns: Vec<AtomicU64>,
}

impl ThreadTrack {
    fn new(name: String, tid: usize, cap: usize) -> ThreadTrack {
        let cap = cap.max(1);
        ThreadTrack {
            name,
            tid,
            cap,
            total: AtomicUsize::new(0),
            phase: (0..cap).map(|_| AtomicU32::new(0)).collect(),
            start_ns: (0..cap).map(|_| AtomicU64::new(0)).collect(),
            dur_ns: (0..cap).map(|_| AtomicU64::new(0)).collect(),
        }
    }

    /// Track label (thread name or an explicit driver label).
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Stable per-run track id (Chrome-trace `tid`).
    pub fn tid(&self) -> usize {
        self.tid
    }

    /// Ring capacity.
    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Spans ever recorded (retained + dropped).
    pub fn recorded(&self) -> u64 {
        self.total.load(Ordering::Acquire) as u64
    }

    /// Spans evicted by ring wraparound.
    pub fn dropped(&self) -> u64 {
        self.recorded().saturating_sub(self.cap as u64)
    }

    /// Record one finished span (owning thread only).
    fn record(&self, phase: Phase, start_ns: u64, dur_ns: u64) {
        let total = self.total.load(Ordering::Relaxed);
        let slot = total % self.cap;
        self.phase[slot].store(phase as u32, Ordering::Relaxed);
        self.start_ns[slot].store(start_ns, Ordering::Relaxed);
        self.dur_ns[slot].store(dur_ns, Ordering::Relaxed);
        self.total.store(total + 1, Ordering::Release);
    }

    /// Retained spans, oldest first. Exact once the owning thread has
    /// quiesced (export runs after the engine joins its workers);
    /// best-effort under concurrent recording.
    pub fn spans(&self) -> Vec<SpanSnap> {
        let total = self.total.load(Ordering::Acquire);
        let kept = total.min(self.cap);
        let first = total - kept; // oldest retained span's sequence number
        (first..total)
            .filter_map(|seq| {
                let slot = seq % self.cap;
                let phase = Phase::from_index(self.phase[slot].load(Ordering::Relaxed) as usize)?;
                Some(SpanSnap {
                    phase,
                    start_ns: self.start_ns[slot].load(Ordering::Relaxed),
                    dur_ns: self.dur_ns[slot].load(Ordering::Relaxed),
                })
            })
            .collect()
    }
}

/// One retained span, snapshot out of a [`ThreadTrack`] ring.
#[derive(Clone, Copy, Debug)]
pub struct SpanSnap {
    /// Phase tag.
    pub phase: Phase,
    /// Start offset from the recorder's epoch, nanoseconds.
    pub start_ns: u64,
    /// Wall duration, nanoseconds.
    pub dur_ns: u64,
}

/// Per-phase running aggregates (count / total wall / self wall), updated at
/// span end with relaxed atomics.
#[derive(Default)]
struct PhaseAgg {
    count: AtomicU64,
    total_ns: AtomicU64,
    self_ns: AtomicU64,
}

/// Per-thread recorder state, keyed by the owning [`Telemetry`]'s run id so
/// a thread reused across sessions re-registers cleanly.
struct Local {
    run: u64,
    track: Arc<ThreadTrack>,
    /// Child-duration accumulator stack: one slot per open span; a closing
    /// span folds its duration into its parent's slot, making self time an
    /// exact subtraction (no extra clock reads).
    stack: Vec<u64>,
}

thread_local! {
    static LOCAL: RefCell<Option<Local>> = const { RefCell::new(None) };
}

static NEXT_RUN: AtomicU64 = AtomicU64::new(1);

/// The per-run telemetry recorder, shared by every worker/pool/updater
/// thread through `Shared`. Construct with [`Telemetry::from_config`] (or
/// [`Telemetry::disabled`] for the default-off instance).
pub struct Telemetry {
    on: AtomicBool,
    run: u64,
    epoch: Instant,
    ring_capacity: usize,
    tracks: Mutex<Vec<Arc<ThreadTrack>>>,
    aggs: [PhaseAgg; Phase::COUNT],
    queue_depth: AtomicI64,
    flops: AtomicU64,
    samples: Mutex<VecDeque<sampler::Sample>>,
}

/// Cap on the sampler's in-memory series (drop-oldest beyond it): 8192
/// samples ≈ 13 minutes at the default 100 ms period.
const MAX_SAMPLES: usize = 8192;

impl Telemetry {
    /// Build a recorder from config. A disabled config yields a recorder
    /// whose every call is a single relaxed load + early return.
    pub fn from_config(cfg: &TelemetryConfig) -> Arc<Telemetry> {
        Arc::new(Telemetry {
            on: AtomicBool::new(cfg.enabled),
            run: NEXT_RUN.fetch_add(1, Ordering::Relaxed),
            epoch: Instant::now(),
            ring_capacity: cfg.ring_capacity.max(1),
            tracks: Mutex::new(Vec::new()),
            aggs: std::array::from_fn(|_| PhaseAgg::default()),
            queue_depth: AtomicI64::new(0),
            flops: AtomicU64::new(0),
            samples: Mutex::new(VecDeque::new()),
        })
    }

    /// The default-off recorder (tests, `Shared::for_tests`).
    pub fn disabled() -> Arc<Telemetry> {
        Telemetry::from_config(&TelemetryConfig::default())
    }

    /// The disabled-path fast check: one relaxed atomic load.
    #[inline]
    pub fn enabled(&self) -> bool {
        self.on.load(Ordering::Relaxed)
    }

    /// Seconds since this recorder's epoch (the trace's time origin).
    pub fn elapsed_s(&self) -> f64 {
        self.epoch.elapsed().as_secs_f64()
    }

    fn now_ns(&self) -> u64 {
        self.epoch.elapsed().as_nanos() as u64
    }

    /// Label the calling thread's track (worker/pool/updater drivers call
    /// this once at entry). A later unlabeled [`Telemetry::span`] on a fresh
    /// thread auto-registers with the OS thread name instead.
    pub fn register_thread(&self, label: &str) {
        if !self.enabled() {
            return;
        }
        LOCAL.with(|cell| {
            let mut cell = cell.borrow_mut();
            let current = matches!(cell.as_ref(), Some(l) if l.run == self.run);
            if !current {
                *cell = Some(Local {
                    run: self.run,
                    track: self.new_track(Some(label)),
                    stack: Vec::new(),
                });
            }
        });
    }

    fn new_track(&self, label: Option<&str>) -> Arc<ThreadTrack> {
        let mut reg = self.tracks.lock().unwrap();
        let tid = reg.len();
        let name = match label {
            Some(l) => l.to_string(),
            None => std::thread::current()
                .name()
                .map(str::to_string)
                .unwrap_or_else(|| format!("thread-{tid}")),
        };
        let track = Arc::new(ThreadTrack::new(name, tid, self.ring_capacity));
        reg.push(Arc::clone(&track));
        track
    }

    /// Open a span; it records into the calling thread's ring when the
    /// returned guard drops. Disabled: one relaxed load, a `None` guard,
    /// zero allocations.
    #[must_use = "the span measures until the guard drops"]
    pub fn span(&self, phase: Phase) -> SpanGuard<'_> {
        if !self.enabled() {
            return SpanGuard { active: None };
        }
        let start_ns = self.now_ns();
        self.with_local(|local| local.stack.push(0));
        SpanGuard { active: Some(Active { tel: self, phase, start_ns }) }
    }

    fn with_local(&self, f: impl FnOnce(&mut Local)) {
        LOCAL.with(|cell| {
            let mut cell = cell.borrow_mut();
            let current = matches!(cell.as_ref(), Some(l) if l.run == self.run);
            if !current {
                *cell = Some(Local {
                    run: self.run,
                    track: self.new_track(None),
                    stack: Vec::new(),
                });
            }
            f(cell.as_mut().expect("local state installed above"));
        });
    }

    fn end_span(&self, phase: Phase, start_ns: u64) {
        let dur_ns = self.now_ns().saturating_sub(start_ns);
        let mut child_ns = 0u64;
        self.with_local(|local| {
            child_ns = local.stack.pop().unwrap_or(0);
            if let Some(parent) = local.stack.last_mut() {
                *parent += dur_ns;
            }
            local.track.record(phase, start_ns, dur_ns);
        });
        let agg = &self.aggs[phase as usize];
        agg.count.fetch_add(1, Ordering::Relaxed);
        agg.total_ns.fetch_add(dur_ns, Ordering::Relaxed);
        agg.self_ns
            .fetch_add(dur_ns.saturating_sub(child_ns), Ordering::Relaxed);
    }

    /// Queue-depth gauge: a pass entered the bounded queue.
    pub fn queue_push(&self) {
        if self.enabled() {
            self.queue_depth.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Queue-depth gauge: a pass left the bounded queue.
    pub fn queue_pop(&self) {
        if self.enabled() {
            self.queue_depth.fetch_sub(1, Ordering::Relaxed);
        }
    }

    /// Current queue-depth gauge value (sampler / tests).
    pub fn queue_depth(&self) -> i64 {
        self.queue_depth.load(Ordering::Relaxed)
    }

    /// FLOPs gauge: a worker thread retired `flops` more model FLOPs.
    pub fn add_flops(&self, flops: u64) {
        if self.enabled() {
            self.flops.fetch_add(flops, Ordering::Relaxed);
        }
    }

    /// Cumulative retired FLOPs across all reporting threads.
    pub fn flops_total(&self) -> u64 {
        self.flops.load(Ordering::Relaxed)
    }

    /// Total wall nanoseconds recorded for `phase` so far (sampler reads
    /// `Forward + Backward` as live compute time).
    pub fn phase_total_ns(&self, phase: Phase) -> u64 {
        self.aggs[phase as usize].total_ns.load(Ordering::Relaxed)
    }

    /// Append one sampler reading (bounded drop-oldest series).
    pub fn push_sample(&self, s: sampler::Sample) {
        let mut q = self.samples.lock().unwrap();
        if q.len() >= MAX_SAMPLES {
            q.pop_front();
        }
        q.push_back(s);
    }

    /// The sampled time series, oldest first.
    pub fn samples(&self) -> Vec<sampler::Sample> {
        self.samples.lock().unwrap().iter().cloned().collect()
    }

    /// Snapshot every registered thread track (export, tests).
    pub fn tracks(&self) -> Vec<Arc<ThreadTrack>> {
        self.tracks.lock().unwrap().clone()
    }

    /// Summarize into the `RunStats.telemetry` section.
    pub fn stats(&self) -> TelemetryStats {
        let tracks = self.tracks.lock().unwrap();
        let mut spans = 0u64;
        let mut dropped = 0u64;
        for t in tracks.iter() {
            spans += t.recorded();
            dropped += t.dropped();
        }
        TelemetryStats {
            enabled: self.enabled(),
            spans,
            dropped,
            threads: tracks.len(),
            samples: self.samples.lock().unwrap().len(),
            phases: PHASES
                .iter()
                .map(|&p| {
                    let agg = &self.aggs[p as usize];
                    PhaseStat {
                        name: p.name(),
                        count: agg.count.load(Ordering::Relaxed),
                        total_s: agg.total_ns.load(Ordering::Relaxed) as f64 * 1e-9,
                        self_s: agg.self_ns.load(Ordering::Relaxed) as f64 * 1e-9,
                    }
                })
                .collect(),
        }
    }
}

struct Active<'a> {
    tel: &'a Telemetry,
    phase: Phase,
    start_ns: u64,
}

/// RAII span: records `[open .. drop]` into the calling thread's ring.
/// Obtained from [`Telemetry::span`]; a disabled recorder hands out inert
/// guards.
#[must_use = "the span measures until the guard drops"]
pub struct SpanGuard<'a> {
    active: Option<Active<'a>>,
}

impl Drop for SpanGuard<'_> {
    fn drop(&mut self) {
        if let Some(a) = self.active.take() {
            a.tel.end_span(a.phase, a.start_ns);
        }
    }
}

/// One phase's row in [`TelemetryStats`].
#[derive(Clone, Debug)]
pub struct PhaseStat {
    /// Phase name ([`Phase::name`]).
    pub name: &'static str,
    /// Spans recorded for this phase.
    pub count: u64,
    /// Total wall time inside the phase, seconds.
    pub total_s: f64,
    /// Self time (total minus time inside nested child spans), seconds.
    pub self_s: f64,
}

/// The `telemetry` section of [`crate::metrics::RunStats`]: span/drop counts
/// and per-phase total/self wall time. `Default` is the all-zero disabled
/// summary.
#[derive(Clone, Debug, Default)]
pub struct TelemetryStats {
    /// Whether the recorder was enabled for the run.
    pub enabled: bool,
    /// Spans recorded across all threads (retained + dropped).
    pub spans: u64,
    /// Spans evicted by ring wraparound.
    pub dropped: u64,
    /// Thread tracks registered.
    pub threads: usize,
    /// Sampler readings retained.
    pub samples: usize,
    /// Per-phase aggregates, in taxonomy order.
    pub phases: Vec<PhaseStat>,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn enabled_cfg(cap: usize) -> TelemetryConfig {
        TelemetryConfig { enabled: true, ring_capacity: cap, ..TelemetryConfig::default() }
    }

    #[test]
    fn disabled_recorder_records_nothing() {
        let tel = Telemetry::disabled();
        for _ in 0..100 {
            let _sp = tel.span(Phase::Forward);
        }
        tel.queue_push();
        tel.add_flops(1_000_000);
        let st = tel.stats();
        assert!(!st.enabled);
        assert_eq!(st.spans, 0);
        assert_eq!(st.threads, 0, "no track is ever registered when disabled");
        assert_eq!(tel.queue_depth(), 0);
        assert_eq!(tel.flops_total(), 0);
    }

    #[test]
    fn spans_land_in_the_callers_track() {
        let tel = Telemetry::from_config(&enabled_cfg(64));
        tel.register_thread("unit-test");
        {
            let _sp = tel.span(Phase::Forward);
        }
        {
            let _sp = tel.span(Phase::Backward);
        }
        let tracks = tel.tracks();
        assert_eq!(tracks.len(), 1);
        assert_eq!(tracks[0].name(), "unit-test");
        let spans = tracks[0].spans();
        assert_eq!(spans.len(), 2);
        assert_eq!(spans[0].phase, Phase::Forward);
        assert_eq!(spans[1].phase, Phase::Backward);
        // recorded at end time: the ring keeps chronological end order
        assert!(spans[0].start_ns <= spans[1].start_ns);
        let st = tel.stats();
        assert_eq!(st.spans, 2);
        assert_eq!(st.dropped, 0);
    }

    /// Satellite: ring wraparound drops the OLDEST spans and counts them.
    #[test]
    fn ring_wraparound_drops_oldest_and_counts() {
        let tel = Telemetry::from_config(&enabled_cfg(4));
        tel.register_thread("wrap");
        for i in 0..7 {
            let phase = if i < 3 { Phase::Forward } else { Phase::OptStep };
            let _sp = tel.span(phase);
        }
        let tracks = tel.tracks();
        assert_eq!(tracks[0].recorded(), 7);
        assert_eq!(tracks[0].dropped(), 3);
        let spans = tracks[0].spans();
        assert_eq!(spans.len(), 4, "ring retains exactly its capacity");
        // the three Forward spans were the oldest: all evicted
        assert!(spans.iter().all(|s| s.phase == Phase::OptStep));
        // chronological (end-time) order survives the wrap
        for w in spans.windows(2) {
            assert!(w[0].start_ns <= w[1].start_ns);
        }
        let st = tel.stats();
        assert_eq!(st.spans, 7);
        assert_eq!(st.dropped, 3);
        // aggregates keep counting past the ring: nothing dropped there
        let fwd = &st.phases[Phase::Forward as usize];
        assert_eq!(fwd.count, 3);
    }

    /// Self time is an exact subtraction of nested child durations.
    #[test]
    fn nested_spans_split_self_time_exactly() {
        let tel = Telemetry::from_config(&enabled_cfg(16));
        tel.register_thread("nest");
        {
            let _outer = tel.span(Phase::Backward);
            std::thread::sleep(std::time::Duration::from_millis(2));
            {
                let _inner = tel.span(Phase::OptStep);
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
        }
        let st = tel.stats();
        let outer = &st.phases[Phase::Backward as usize];
        let inner = &st.phases[Phase::OptStep as usize];
        assert_eq!(outer.count, 1);
        assert_eq!(inner.count, 1);
        assert!(inner.total_s > 0.0);
        // child span's full duration was subtracted from the parent's self
        let expect_self = outer.total_s - inner.total_s;
        assert!((outer.self_s - expect_self).abs() < 1e-9);
        assert!(outer.total_s >= inner.total_s);
        // spans nest within the parent's interval
        let spans = tel.tracks()[0].spans();
        let (inner_s, outer_s) = (&spans[0], &spans[1]); // inner ends first
        assert_eq!(outer_s.phase, Phase::Backward);
        assert!(inner_s.start_ns >= outer_s.start_ns);
        assert!(
            inner_s.start_ns + inner_s.dur_ns <= outer_s.start_ns + outer_s.dur_ns,
            "child interval contained in parent interval"
        );
    }

    #[test]
    fn each_thread_gets_its_own_track() {
        let tel = Telemetry::from_config(&enabled_cfg(16));
        tel.register_thread("main-thread");
        {
            let _sp = tel.span(Phase::Forward);
        }
        std::thread::scope(|s| {
            s.spawn(|| {
                tel.register_thread("helper-thread");
                let _sp = tel.span(Phase::Backward);
            });
        });
        let tracks = tel.tracks();
        assert_eq!(tracks.len(), 2);
        let names: Vec<&str> = tracks.iter().map(|t| t.name()).collect();
        assert!(names.contains(&"main-thread"));
        assert!(names.contains(&"helper-thread"));
    }

    #[test]
    fn gauges_accumulate_when_enabled() {
        let tel = Telemetry::from_config(&enabled_cfg(16));
        tel.queue_push();
        tel.queue_push();
        tel.queue_pop();
        assert_eq!(tel.queue_depth(), 1);
        tel.add_flops(500);
        tel.add_flops(1500);
        assert_eq!(tel.flops_total(), 2000);
    }

    #[test]
    fn sample_series_is_bounded_drop_oldest() {
        let tel = Telemetry::from_config(&enabled_cfg(16));
        for i in 0..(MAX_SAMPLES + 10) {
            tel.push_sample(sampler::Sample { t_s: i as f64, ..sampler::Sample::default() });
        }
        let samples = tel.samples();
        assert_eq!(samples.len(), MAX_SAMPLES);
        assert_eq!(samples[0].t_s, 10.0, "oldest samples were dropped");
    }

    #[test]
    fn config_validation_rejects_zero_ring() {
        assert!(TelemetryConfig::default().validate().is_ok());
        let bad = TelemetryConfig { enabled: true, ring_capacity: 0, ..TelemetryConfig::default() };
        assert!(bad.validate().is_err());
    }

    #[test]
    fn phase_names_roundtrip_their_index() {
        for (i, &p) in PHASES.iter().enumerate() {
            assert_eq!(p as usize, i);
            assert_eq!(Phase::from_index(i), Some(p));
        }
        assert_eq!(Phase::from_index(Phase::COUNT), None);
    }
}
