//! Background time-series sampler: a `telemetry-sampler` thread snapshots
//! run gauges — queue depth, live compute occupancy (MFU), FLOP/s, τ means,
//! push-sum weight and per-link wire bytes/s — into the recorder's bounded
//! in-memory series at a configurable period. Rates are finite differences
//! between consecutive snapshots, so a sample reads a handful of relaxed
//! atomics plus one `CommStats` snapshot and never touches a hot path.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use crate::coordinator::Shared;
use crate::telemetry::{Phase, Telemetry};

/// One directed link's instantaneous wire rate.
#[derive(Clone, Copy, Debug)]
pub struct LinkRate {
    /// Sending worker.
    pub from: usize,
    /// Receiving worker.
    pub to: usize,
    /// Encoded wire bytes per second over the last sampler period.
    pub bytes_per_s: f64,
}

/// One sampler reading. `t_s` is seconds since the recorder's epoch — the
/// same time origin the span rings use, so counter tracks line up with the
/// span tracks in the exported trace.
#[derive(Clone, Debug, Default)]
pub struct Sample {
    /// Sample time, seconds since the recorder epoch.
    pub t_s: f64,
    /// Decoupled pass-queue depth (sum over workers) at sample time.
    pub queue_depth: i64,
    /// Live model-flops-utilization proxy: fraction of the period the
    /// compute lanes spent inside `Forward`/`Backward` spans (the same
    /// occupancy definition `RunSummary.mfu` reports end-of-run).
    pub mfu: f64,
    /// Model FLOPs retired per second over the period.
    pub flops_per_s: f64,
    /// Mean observed per-layer staleness τ so far (cumulative).
    pub tau_mean: f64,
    /// Total push-sum weight currently held by the workers.
    pub push_weight: f64,
    /// Encoded wire bytes per second over the period (all links).
    pub bytes_per_s: f64,
    /// Per-link wire rates (links with traffic this period only).
    pub links: Vec<LinkRate>,
}

/// Handle to the running sampler thread; [`SamplerHandle::stop`] takes a
/// final sample, stops the thread and joins it.
pub struct SamplerHandle {
    stop: Arc<AtomicBool>,
    join: Option<JoinHandle<()>>,
}

impl SamplerHandle {
    /// Signal the sampler to finish and wait for it.
    pub fn stop(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.join.take() {
            let _ = h.join();
        }
    }
}

impl Drop for SamplerHandle {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.join.take() {
            let _ = h.join();
        }
    }
}

/// Spawn the sampler thread. `lanes` is the number of compute lanes the MFU
/// normalizes over (trainers × threads-per-worker — the denominator
/// `RunSummary`'s occupancy uses). Returns `None` when telemetry is
/// disabled or the period is zero.
pub fn spawn(
    tel: &Arc<Telemetry>,
    shared: &Arc<Shared>,
    period_ms: u64,
    lanes: f64,
) -> Option<SamplerHandle> {
    if !tel.enabled() || period_ms == 0 {
        return None;
    }
    let stop = Arc::new(AtomicBool::new(false));
    let join = {
        let tel = Arc::clone(tel);
        let shared = Arc::clone(shared);
        let stop = Arc::clone(&stop);
        std::thread::Builder::new()
            .name("telemetry-sampler".to_string())
            .spawn(move || run(&tel, &shared, period_ms, lanes.max(1.0), &stop))
            .expect("spawn telemetry sampler")
    };
    Some(SamplerHandle { stop, join: Some(join) })
}

fn run(tel: &Telemetry, shared: &Shared, period_ms: u64, lanes: f64, stop: &AtomicBool) {
    let mut cursor = Cursor::default();
    cursor.t_s = tel.elapsed_s();
    loop {
        // chunked sleep: the handle's stop/join stays responsive even with
        // a long sampling period
        let mut slept = 0u64;
        while slept < period_ms && !stop.load(Ordering::Relaxed) {
            let chunk = (period_ms - slept).min(20);
            std::thread::sleep(Duration::from_millis(chunk));
            slept += chunk;
        }
        let done = stop.load(Ordering::Relaxed);
        tel.push_sample(sample(tel, shared, lanes, &mut cursor));
        if done {
            break; // one final sample so short runs always have a series
        }
    }
}

/// Finite-difference state carried between samples.
#[derive(Default)]
struct Cursor {
    t_s: f64,
    compute_ns: u64,
    flops: u64,
    bytes: u64,
    link_bytes: BTreeMap<(usize, usize), u64>,
}

fn sample(tel: &Telemetry, shared: &Shared, lanes: f64, prev: &mut Cursor) -> Sample {
    let t_s = tel.elapsed_s();
    let dt = (t_s - prev.t_s).max(1e-9);

    let compute_ns = tel.phase_total_ns(Phase::Forward) + tel.phase_total_ns(Phase::Backward);
    let mfu = (compute_ns.saturating_sub(prev.compute_ns)) as f64 * 1e-9 / (dt * lanes);

    let flops = tel.flops_total();
    let flops_per_s = flops.saturating_sub(prev.flops) as f64 / dt;

    let comm = shared.fabric.core().snapshot();
    let bytes_per_s = comm.bytes_sent.saturating_sub(prev.bytes) as f64 / dt;
    let mut links = Vec::new();
    let mut link_bytes = BTreeMap::new();
    for l in &comm.links {
        let key = (l.from, l.to);
        let before = prev.link_bytes.get(&key).copied().unwrap_or(0);
        let delta = l.bytes.saturating_sub(before);
        if delta > 0 {
            links.push(LinkRate { from: l.from, to: l.to, bytes_per_s: delta as f64 / dt });
        }
        link_bytes.insert(key, l.bytes);
    }

    let push_weight = shared.weights.iter().map(|w| w.get() as f64).sum();
    let tau_mean = shared.staleness.snapshot().mean_tau();

    *prev = Cursor { t_s, compute_ns, flops, bytes: comm.bytes_sent, link_bytes };
    Sample {
        t_s,
        queue_depth: tel.queue_depth(),
        mfu,
        flops_per_s,
        tau_mean,
        push_weight,
        bytes_per_s,
        links,
    }
}
