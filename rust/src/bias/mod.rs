//! Empirical validation of the paper's theory (Section 6, Appendix C):
//!
//! * **Elastic consistency** (Assumption 6): `E‖x̄_t − x_t^i‖² ≤ η²B²` with
//!   `B = B'τ_max`, `B' = (M−1)S/M` — we measure the LHS during a live run
//!   and compare against the bound with `S` estimated from observed gradient
//!   norms.
//! * **Lemma 6.1** (gradient-bias bound): `E‖b(x)‖² ≤ 4K_b²η²B²` — we
//!   measure the bias as the squared distance between gradients evaluated at
//!   a worker's snapshot and at the consensus mean (the definition used in
//!   the proof of C.4), with `K_b` estimated as an empirical Lipschitz
//!   constant of the stochastic gradient field.
//!
//! These checks are what Figure A1 ("model disagreement is bounded and goes
//! to zero") and the Lemma-6.1 bench rely on.

use anyhow::Result;

use crate::coordinator::Shared;
use crate::data::Dataset;
use crate::model::{ModelExec, ModelParams};
use crate::tensor::Tensor;

/// One sample of the theory diagnostics at some step.
#[derive(Clone, Debug)]
pub struct BiasSample {
    pub step: usize,
    /// measured max_i ‖x̄ − x_i‖²
    pub consistency_sq: f64,
    /// measured ‖g(x_i) − g(x̄)‖² (the bias second moment proxy)
    pub bias_sq: f64,
    /// measured ‖g(x_i) − g(x̄)‖ / ‖x_i − x̄‖  (local Lipschitz estimate)
    pub lipschitz_est: f64,
    /// measured ‖g(x̄)‖ (stochastic gradient norm, feeds S)
    pub grad_norm: f64,
}

/// Accumulates samples plus the constants needed to evaluate the bounds.
#[derive(Clone, Debug, Default)]
pub struct BiasTracker {
    pub samples: Vec<BiasSample>,
}

impl BiasTracker {
    /// Evaluate the diagnostics for worker `wid` against the consensus of
    /// all replicas. Runs two extra gradient evaluations on a probe batch
    /// (expensive — call sparsely).
    pub fn measure(
        &mut self,
        step: usize,
        exec: &mut ModelExec,
        shared: &Shared,
        wid: usize,
        data: &dyn Dataset,
    ) -> Result<()> {
        // consensus parameters x̄
        let flats: Vec<Vec<f32>> = shared.params.iter().map(|p| p.flatten()).collect();
        let d = flats[0].len();
        let mut mean = vec![0.0f32; d];
        for f in &flats {
            for (m, &x) in mean.iter_mut().zip(f.iter()) {
                *m += x;
            }
        }
        for m in &mut mean {
            *m /= flats.len() as f32;
        }
        let consistency_sq = flats
            .iter()
            .map(|f| sq_dist(f, &mean))
            .fold(0.0f64, f64::max);

        // probe gradients at x_i and at x̄ on the SAME batch
        let probe = data.eval_batch(0);
        let scratch = ModelParams::init(&exec.manifest, 0);

        scratch.store_flat(&flats[wid], wid, step);
        let g_i = full_gradient(exec, &scratch, &probe)?;
        scratch.store_flat(&mean, wid, step);
        let g_bar = full_gradient(exec, &scratch, &probe)?;

        let bias_sq = sq_dist(&g_i, &g_bar);
        let param_dist = sq_dist(&flats[wid], &mean).sqrt();
        let lipschitz_est = if param_dist > 1e-12 {
            bias_sq.sqrt() / param_dist
        } else {
            0.0
        };
        let grad_norm = g_bar.iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt();

        self.samples.push(BiasSample { step, consistency_sq, bias_sq, lipschitz_est, grad_norm });
        Ok(())
    }

    /// Check Lemma 6.1 on the collected samples: every measured bias second
    /// moment must sit below `4 K² η² B²` with empirical K, S and the given
    /// (η, M, τ_max). Returns (worst measured bias, worst bound) — callers
    /// assert `bias <= bound * slack`.
    pub fn lemma61_check(&self, eta: f64, m: usize, tau_max: f64) -> (f64, f64) {
        let k = self
            .samples
            .iter()
            .map(|s| s.lipschitz_est)
            .fold(0.0f64, f64::max);
        let s_max = self.samples.iter().map(|s| s.grad_norm).fold(0.0f64, f64::max);
        let b_prime = (m as f64 - 1.0) / m as f64 * s_max;
        let b = b_prime * tau_max;
        let bound = 4.0 * k * k * eta * eta * b * b;
        let worst = self.samples.iter().map(|s| s.bias_sq).fold(0.0f64, f64::max);
        (worst, bound)
    }

    /// Check elastic consistency: worst measured ‖x̄−x_i‖² vs η²B².
    pub fn elastic_check(&self, eta: f64, m: usize, tau_max: f64) -> (f64, f64) {
        let s_max = self.samples.iter().map(|s| s.grad_norm).fold(0.0f64, f64::max);
        let b = (m as f64 - 1.0) / m as f64 * s_max * tau_max;
        let bound = eta * eta * b * b;
        let worst = self
            .samples
            .iter()
            .map(|s| s.consistency_sq)
            .fold(0.0f64, f64::max);
        (worst, bound)
    }

    pub fn to_csv(&self) -> String {
        let mut out = String::from("step,consistency_sq,bias_sq,lipschitz_est,grad_norm\n");
        for s in &self.samples {
            out.push_str(&format!(
                "{},{:.6e},{:.6e},{:.6e},{:.6e}\n",
                s.step, s.consistency_sq, s.bias_sq, s.lipschitz_est, s.grad_norm
            ));
        }
        out
    }
}

/// Full flat gradient of the model at `params` on `batch`.
pub fn full_gradient(
    exec: &mut ModelExec,
    params: &ModelParams,
    batch: &crate::data::Batch,
) -> Result<Vec<f32>> {
    let pass = exec.forward(params, batch)?;
    let n_layers = exec.manifest.layers.len();
    let mut per_layer: Vec<Option<Vec<Tensor>>> = (0..n_layers).map(|_| None).collect();
    {
        let mut sink = |li: usize, grads: Vec<Tensor>| {
            per_layer[li] = Some(grads);
        };
        exec.backward(params, &pass, &mut sink)?;
    }
    let mut flat = Vec::new();
    for g in per_layer.into_iter() {
        for t in g.expect("missing layer gradient") {
            flat.extend_from_slice(&t.data);
        }
    }
    Ok(flat)
}

fn sq_dist(a: &[f32], b: &[f32]) -> f64 {
    a.iter()
        .zip(b.iter())
        .map(|(&x, &y)| {
            let d = (x - y) as f64;
            d * d
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sq_dist_basic() {
        assert_eq!(sq_dist(&[0.0, 3.0], &[4.0, 0.0]), 25.0);
    }

    #[test]
    fn lemma61_bound_uses_worst_case_constants() {
        let mut t = BiasTracker::default();
        t.samples.push(BiasSample {
            step: 0,
            consistency_sq: 0.01,
            bias_sq: 0.001,
            lipschitz_est: 2.0,
            grad_norm: 5.0,
        });
        t.samples.push(BiasSample {
            step: 1,
            consistency_sq: 0.02,
            bias_sq: 0.004,
            lipschitz_est: 1.0,
            grad_norm: 3.0,
        });
        let (worst, bound) = t.lemma61_check(0.1, 4, 2.0);
        assert_eq!(worst, 0.004);
        // K=2, S=5, B' = 3.75, B = 7.5, bound = 4*4*0.01*56.25 = 9.0
        assert!((bound - 9.0).abs() < 1e-9);
        let (ec_worst, ec_bound) = t.elastic_check(0.1, 4, 2.0);
        assert_eq!(ec_worst, 0.02);
        assert!((ec_bound - 0.5625).abs() < 1e-9);
    }
}
