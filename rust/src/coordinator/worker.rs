//! Per-device drivers: the serial "computation thread" of Figure 1 and the
//! decoupled forward/backward pools of the PD-ASGD regime. Both open one
//! engine-owned [`StepState`] per forward pass and thread it through the
//! algorithm hooks — the contract that makes interleaved steps
//! (`bwd_threads > 1`) safe for every stash-based algorithm.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::algorithms::{self, StepState, WorkerAlgo};
use crate::comm::Fabric;
use crate::config::{Algorithm, Compensation, TrainConfig};
use crate::coordinator::queue::{BoundedQueue, PassPool};
use crate::coordinator::{CheckpointRendezvous, Shared, WorkerSlot, WorkerStats};
use crate::data::{self, Dataset};
use crate::manifest::Manifest;
use crate::metrics::{CurvePoint, QueueStats};
use crate::model::{HostPass, ModelExec, ModelParams};
use crate::tensor::clock::ClockStamp;
use crate::resilience::checkpoint::{self, Checkpoint, WorkerState, FORMAT_VERSION};
use crate::resilience::AlgoState;
use crate::runtime::Runtime;
use crate::session::events::TrainEvent;
use crate::telemetry::Phase;

/// Where a (re)spawned worker starts: the first step it runs, its
/// data-loader cursor, and optionally a checkpointed algorithm state. A
/// fresh run boots at zeros; a resume boots at the snapshot; a chaos respawn
/// boots at the crash point with a fresh algorithm state (the device died —
/// its optimizer moments died with it).
#[derive(Clone, Debug, Default)]
pub(crate) struct WorkerBoot {
    pub start_step: usize,
    pub cursor: u64,
    pub algo: Option<AlgoState>,
}

/// How a worker's thread ended.
pub(crate) enum WorkerExit {
    /// Ran to the end of its step budget (or the run-wide stop flag).
    Completed(WorkerStats),
    /// A scheduled chaos fault fired: the worker tore down at `next_step`
    /// (that step not executed). The supervisor decides about a respawn.
    Crashed {
        next_step: usize,
        cursor: u64,
        stats: WorkerStats,
    },
}

/// The paper's "computation thread" for one device.
pub(crate) fn worker_main(
    cfg: &TrainConfig,
    wid: usize,
    shared: &Arc<Shared>,
    manifest: &Manifest,
    boot: WorkerBoot,
) -> Result<WorkerExit> {
    let mut rt = Runtime::new().context("worker runtime")?;
    let mut exec = ModelExec::load(&mut rt, manifest, &cfg.model)
        .with_context(|| format!("worker {wid}: loading model"))?;
    let model = manifest.model(&cfg.model)?;
    let n_layers = model.layers.len();
    let mut dataset = data::build(model, wid, cfg.workers, cfg.seed)?;
    if boot.cursor > 0 {
        dataset.skip(boot.cursor);
    }
    let mut algo = algorithms::build(cfg, wid, Arc::clone(shared), &exec.manifest)?;
    if let Some(state) = boot.algo {
        algo.load_state_dict(state)
            .with_context(|| format!("worker {wid}: restoring algorithm state"))?;
    }

    let my_params = Arc::clone(&shared.params[wid]);
    shared.telemetry.register_thread(&format!("worker-{wid}"));
    let is_straggler = cfg.straggler.map(|(w, _)| w == wid).unwrap_or(false);
    let delay_iters = cfg.straggler.map(|(_, d)| d).unwrap_or(0.0);
    let mut baseline_step_s = 0.0f64;
    let mut drift_scratch = DriftScratch::new(shared.m);
    let mut completed = 0usize;
    let mut flops_seen = 0u64;
    let mut fwd_s = 0.0f64;
    let mut bwd_s = 0.0f64;

    for step in boot.start_step..cfg.steps {
        if shared.should_stop() {
            break;
        }
        // Chaos injection: a scheduled fault kills this device at the top of
        // its crash step. Helper threads are torn down cleanly (we simulate
        // a dead device, not a wedged harness); the supervisor reclaims the
        // worker's push-sum weight and decides about a respawn.
        if shared.chaos.as_ref().is_some_and(|c| c.due(wid, step)) {
            algo.finish()?;
            return Ok(WorkerExit::Crashed {
                next_step: step,
                cursor: dataset.cursor(),
                stats: WorkerStats {
                    compute_s: exec.compute_s,
                    fwd_compute_s: fwd_s,
                    bwd_compute_s: bwd_s,
                    flops: exec.flops_retired,
                    steps: completed,
                    upload_hits: exec.upload_hits,
                    upload_misses: exec.upload_misses,
                    queue: QueueStats::default(),
                },
            });
        }
        // Straggler injection (Section 5.4): idle for a multiple of the
        // measured fwd+bwd time.
        if is_straggler && delay_iters > 0.0 && baseline_step_s > 0.0 {
            let delay_s = baseline_step_s * delay_iters;
            shared
                .events
                .emit(TrainEvent::StragglerInjected { worker: wid, step, delay_s });
            std::thread::sleep(std::time::Duration::from_secs_f64(delay_s));
        }
        let step_t0 = Instant::now();

        let compute_before_fwd = exec.compute_s;
        let batch = dataset.next_batch();
        // the pass's parameter provenance is what the forward is about to
        // read: snapshot the staleness clocks (and, under DC compensation,
        // the parameter values) BEFORE the first upload
        let mut ctx = open_step(cfg, &my_params, step, n_layers);
        let pass = {
            let _sp = shared.telemetry.span(Phase::Forward);
            exec.forward(&my_params, &batch)?
        };
        if !pass.loss.is_finite() {
            anyhow::bail!("worker {wid}: loss diverged (step {step})");
        }
        let compute_after_fwd = exec.compute_s;
        fwd_s += compute_after_fwd - compute_before_fwd;
        {
            let _sp = shared.telemetry.span(Phase::Backward);
            let mut err: Option<anyhow::Error> = None;
            let mut sink = |li: usize, grads: Vec<crate::tensor::Tensor>| {
                if err.is_none() {
                    if let Err(e) = algo.on_layer_grads(&mut ctx, li, grads) {
                        err = Some(e);
                    }
                }
            };
            exec.backward(&my_params, &pass, &mut sink)?;
            if let Some(e) = err {
                return Err(e);
            }
        }
        bwd_s += exec.compute_s - compute_after_fwd;
        algo.on_step_end(ctx)?;
        completed += 1;
        shared.steps_done[wid].fetch_add(1, Ordering::Relaxed);
        if shared.telemetry.enabled() {
            shared.telemetry.add_flops(exec.flops_retired - flops_seen);
            flops_seen = exec.flops_retired;
        }
        // step boundary: apply queued fabric traffic addressed to this
        // worker (no-op on the instant shared-memory transport)
        shared.fabric.deliver_due(shared, wid, step);
        shared
            .events
            .emit(TrainEvent::StepCompleted { worker: wid, step, loss: pass.loss as f64 });
        if shared.events.has_observers() && (step % cfg.eval_every == 0 || step + 1 == cfg.steps) {
            shared.events.emit(TrainEvent::Utilization {
                worker: wid,
                lane: 0,
                step,
                compute_s: exec.compute_s,
                flops: exec.flops_retired,
            });
        }

        if completed <= 3 {
            // calibrate the straggler delay unit on undelayed steps
            let dt = step_t0.elapsed().as_secs_f64();
            baseline_step_s = if completed == 1 { dt } else { 0.5 * (baseline_step_s + dt) };
        }

        // Evaluation + drift tracking (worker 0 evaluates its replica;
        // compute/flop counters are excluded from training accounting).
        if wid == 0 && (step % cfg.eval_every == 0 || step + 1 == cfg.steps) {
            let flops_before = exec.flops_retired;
            let compute_before = exec.compute_s;
            let (loss, acc) = exec.evaluate(&my_params, dataset.as_ref(), 4)?;
            exec.flops_retired = flops_before;
            exec.compute_s = compute_before;
            let time_s = shared.elapsed_s();
            shared.curve.lock().unwrap().push(CurvePoint {
                step,
                time_s,
                loss,
                accuracy: acc,
            });
            shared
                .events
                .emit(TrainEvent::EvalPoint { step, time_s, loss, accuracy: acc });
        }
        if wid == 0
            && cfg.track_drift_every > 0
            && step % cfg.track_drift_every == 0
        {
            let v = sample_drift(&shared.params, &mut drift_scratch);
            shared.drift.lock().unwrap().push_sample(step, v);
        }

        // Periodic checkpoint rendezvous (the last action of a step body, so
        // the snapshot point is identical wherever the run is driven from).
        maybe_checkpoint(cfg, wid, shared, step, algo.as_mut(), dataset.as_ref())?;
    }

    algo.finish()?;
    Ok(WorkerExit::Completed(WorkerStats {
        compute_s: exec.compute_s,
        fwd_compute_s: fwd_s,
        bwd_compute_s: bwd_s,
        flops: exec.flops_retired,
        steps: completed,
        upload_hits: exec.upload_hits,
        upload_misses: exec.upload_misses,
        queue: QueueStats::default(),
    }))
}

/// Decoupled worker: forward pool -> bounded pass queue -> backward pool,
/// all for ONE simulated device.
///
/// * Every pool thread owns its own `Runtime`/`ModelExec` (`xla` wrappers are
///   `!Send`); passes cross threads as host-side [`HostPass`] buffers that
///   are recycled through a [`PassPool`] — no per-step allocation.
/// * Forward threads claim step indices from a shared counter and block on
///   the queue once `queue_depth` passes await backward (backpressure bounds
///   activation memory and staleness).
/// * Backward threads pop passes (possibly out of step order), run backward,
///   and drive the algorithm hooks under a per-worker mutex, each pass
///   carrying its own engine-owned [`StepState`] — see the
///   [`crate::algorithms`] threading contract.
/// * The last forward thread out closes the queue, so the backward pool
///   drains the tail and exits; any pool error raises the run-wide `stop`
///   flag, which unblocks every queue waiter (no deadlock on wind-down).
pub(crate) fn worker_decoupled(
    cfg: &TrainConfig,
    wid: usize,
    shared: &Arc<Shared>,
    manifest: &Manifest,
) -> Result<WorkerStats> {
    let model = manifest.model(&cfg.model)?;
    let pass_queue: BoundedQueue<HostPass> = BoundedQueue::new(cfg.queue_depth);
    let pool: PassPool<HostPass> = PassPool::new();
    let next_step = AtomicUsize::new(0);
    let live_producers = AtomicUsize::new(cfg.fwd_threads);
    let algo: Mutex<Box<dyn WorkerAlgo>> =
        Mutex::new(algorithms::build(cfg, wid, Arc::clone(shared), model)?);

    let results: Vec<Result<WorkerStats>> = std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for ft in 0..cfg.fwd_threads {
            let (pass_queue, pool, next_step, live_producers) =
                (&pass_queue, &pool, &next_step, &live_producers);
            handles.push(scope.spawn(move || {
                let r = forward_pool_main(cfg, wid, ft, shared, manifest, pass_queue, pool, next_step);
                if r.is_err() {
                    shared.stop.store(true, Ordering::Relaxed);
                }
                // last producer out closes the queue -> backward pool drains
                if live_producers.fetch_sub(1, Ordering::AcqRel) == 1 {
                    pass_queue.close();
                }
                r
            }));
        }
        for bt in 0..cfg.bwd_threads {
            let (pass_queue, pool, algo) = (&pass_queue, &pool, &algo);
            handles.push(scope.spawn(move || {
                let r = backward_pool_main(cfg, wid, bt, shared, manifest, pass_queue, pool, algo);
                if r.is_err() {
                    shared.stop.store(true, Ordering::Relaxed);
                }
                r
            }));
        }
        handles
            .into_iter()
            .map(|h| h.join().expect("pool thread panicked"))
            .collect()
    });

    let mut ws = WorkerStats::default();
    for r in results {
        ws.absorb(&r?);
    }
    ws.queue = pass_queue.stats();
    algo.into_inner().unwrap().finish()?;
    Ok(ws)
}

/// One forward-pool thread: claim a step, produce a [`HostPass`], push it
/// into the bounded queue (blocking at `queue_depth` — the backpressure the
/// tests pin down).
#[allow(clippy::too_many_arguments)]
fn forward_pool_main(
    cfg: &TrainConfig,
    wid: usize,
    ft: usize,
    shared: &Arc<Shared>,
    manifest: &Manifest,
    pass_queue: &BoundedQueue<HostPass>,
    pool: &PassPool<HostPass>,
    next_step: &AtomicUsize,
) -> Result<WorkerStats> {
    let mut rt = Runtime::new().context("forward-pool runtime")?;
    let mut exec = ModelExec::load(&mut rt, manifest, &cfg.model)
        .with_context(|| format!("worker {wid} fwd {ft}: loading model"))?;
    let model = manifest.model(&cfg.model)?;
    // Thread 0 keeps the worker's serial batch stream (a 1:1 ratio consumes
    // exactly the data the serial loop would); extra forward threads get
    // decorrelated shards of the same worker slice.
    let seed = cfg.seed ^ ((ft as u64) << 32);
    let mut dataset = data::build(model, wid, cfg.workers, seed)?;
    let my_params = Arc::clone(&shared.params[wid]);
    shared.telemetry.register_thread(&format!("fwd-{wid}-{ft}"));

    let is_straggler = cfg.straggler.map(|(w, _)| w == wid).unwrap_or(false);
    let delay_iters = cfg.straggler.map(|(_, d)| d).unwrap_or(0.0);
    let mut baseline_fwd_s = 0.0f64;
    let mut produced = 0usize;
    let mut flops_seen = 0u64;

    loop {
        if shared.should_stop() {
            break;
        }
        let step = next_step.fetch_add(1, Ordering::Relaxed);
        if step >= cfg.steps {
            break;
        }
        // Straggler injection (Section 5.4) lives in the FORWARD pool: pass
        // production gates the whole pipeline, so idling here slows the
        // device end-to-end. The delay unit is the measured forward latency
        // (the backward pool's time is not observable from this side).
        if is_straggler && delay_iters > 0.0 && baseline_fwd_s > 0.0 {
            let delay_s = baseline_fwd_s * delay_iters;
            shared
                .events
                .emit(TrainEvent::StragglerInjected { worker: wid, step, delay_s });
            std::thread::sleep(Duration::from_secs_f64(delay_s));
        }
        let t0 = Instant::now();
        let batch = dataset.next_batch();
        let mut pass = pool.take();
        pass.step = step;
        capture_pass_provenance(cfg, &my_params, &mut pass);
        {
            let _sp = shared.telemetry.span(Phase::Forward);
            exec.forward_host(&my_params, &batch, &mut pass)?;
        }
        if !pass.loss.is_finite() {
            anyhow::bail!("worker {wid}: loss diverged (step {step})");
        }
        if shared.telemetry.enabled() {
            shared.telemetry.add_flops(exec.flops_retired - flops_seen);
            flops_seen = exec.flops_retired;
        }
        if produced < 3 {
            // calibrate the straggler delay unit on undelayed passes
            let dt = t0.elapsed().as_secs_f64();
            baseline_fwd_s = if produced == 0 { dt } else { 0.5 * (baseline_fwd_s + dt) };
        }
        produced += 1;
        let pushed = {
            let _sp = shared.telemetry.span(Phase::QueueWait);
            pass_queue.push(pass, &shared.stop)
        };
        if pushed.is_err() {
            break; // run is stopping (or queue closed early)
        }
        shared.telemetry.queue_push();
        if shared.events.has_observers() {
            // depth right after insertion (len() takes the queue lock, so
            // only pay for it when someone is listening)
            shared
                .events
                .emit(TrainEvent::QueueDepth { worker: wid, step, depth: pass_queue.len() });
            if step % cfg.eval_every == 0 || step + 1 == cfg.steps {
                shared.events.emit(TrainEvent::Utilization {
                    worker: wid,
                    lane: ft,
                    step,
                    compute_s: exec.compute_s,
                    flops: exec.flops_retired,
                });
            }
        }
    }
    Ok(WorkerStats {
        compute_s: exec.compute_s,
        fwd_compute_s: exec.compute_s,
        // steps are counted where passes COMPLETE (the backward pool)
        steps: 0,
        flops: exec.flops_retired,
        upload_hits: exec.upload_hits,
        upload_misses: exec.upload_misses,
        ..Default::default()
    })
}

/// One backward-pool thread: drain the pass queue, run backward, feed the
/// algorithm hooks (serialized per worker, one engine-owned [`StepState`]
/// per pass), recycle the pass buffer. Worker 0's backward threads also own
/// evaluation and drift sampling (an eval-eligible step is evaluated by
/// whichever of them pops its pass), mirroring the serial loop's worker-0
/// duties.
#[allow(clippy::too_many_arguments)]
fn backward_pool_main(
    cfg: &TrainConfig,
    wid: usize,
    bt: usize,
    shared: &Arc<Shared>,
    manifest: &Manifest,
    pass_queue: &BoundedQueue<HostPass>,
    pool: &PassPool<HostPass>,
    algo: &Mutex<Box<dyn WorkerAlgo>>,
) -> Result<WorkerStats> {
    let mut rt = Runtime::new().context("backward-pool runtime")?;
    let mut exec = ModelExec::load(&mut rt, manifest, &cfg.model)
        .with_context(|| format!("worker {wid} bwd {bt}: loading model"))?;
    let model = manifest.model(&cfg.model)?;
    let n_layers = model.layers.len();
    let my_params = Arc::clone(&shared.params[wid]);
    // Worker 0 owns evaluation + drift duty (as in the serial loop). EVERY
    // backward thread of worker 0 carries an eval stream: an eval-eligible
    // step is evaluated by whichever thread pops its pass, so no eval point
    // is dropped when bwd_threads > 1. Eval batches are deterministic, so
    // the streams are identical across threads.
    let eval_ds = if wid == 0 {
        Some(data::build(model, wid, cfg.workers, cfg.seed)?)
    } else {
        None
    };
    let mut drift_scratch = DriftScratch::new(shared.m);
    let mut completed = 0usize;
    let mut flops_seen = 0u64;
    shared.telemetry.register_thread(&format!("bwd-{wid}-{bt}"));

    loop {
        let popped = {
            let _sp = shared.telemetry.span(Phase::QueueWait);
            pass_queue.pop(&shared.stop)
        };
        let Some(mut pass) = popped else { break };
        shared.telemetry.queue_pop();
        let step = pass.step;
        let loss = pass.loss as f64;
        let mut ctx = StepState::new(step, n_layers)
            .with_clocks(std::mem::take(&mut pass.clocks));
        if !pass.x_then.is_empty() {
            // hand the forward-time values to the apply sites (the pooled
            // buffers are rebuilt by the next capture)
            ctx = ctx.with_x_then(std::mem::take(&mut pass.x_then));
        }
        {
            let _sp = shared.telemetry.span(Phase::Backward);
            let mut err: Option<anyhow::Error> = None;
            let mut sink = |li: usize, grads: Vec<crate::tensor::Tensor>| {
                if err.is_none() {
                    if let Err(e) = algo.lock().unwrap().on_layer_grads(&mut ctx, li, grads) {
                        err = Some(e);
                    }
                }
            };
            exec.backward_host(&my_params, &pass, &mut sink)?;
            if let Some(e) = err {
                return Err(e);
            }
        }
        algo.lock().unwrap().on_step_end(ctx)?;
        completed += 1;
        shared.steps_done[wid].fetch_add(1, Ordering::Relaxed);
        if shared.telemetry.enabled() {
            shared.telemetry.add_flops(exec.flops_retired - flops_seen);
            flops_seen = exec.flops_retired;
        }
        // step boundary: apply queued fabric traffic (outside the hook
        // mutex — deliveries use the same lock-free stores the updaters do)
        shared.fabric.deliver_due(shared, wid, step);
        pool.put(pass);
        shared
            .events
            .emit(TrainEvent::StepCompleted { worker: wid, step, loss });
        if shared.events.has_observers() && (step % cfg.eval_every == 0 || step + 1 == cfg.steps) {
            shared.events.emit(TrainEvent::Utilization {
                worker: wid,
                lane: cfg.fwd_threads + bt,
                step,
                compute_s: exec.compute_s,
                flops: exec.flops_retired,
            });
        }

        if let Some(ds) = eval_ds.as_deref() {
            // compute/flop counters are excluded, exactly as in the serial loop
            if step % cfg.eval_every == 0 || step + 1 == cfg.steps {
                let flops_before = exec.flops_retired;
                let compute_before = exec.compute_s;
                let (loss, acc) = exec.evaluate(&my_params, ds, 4)?;
                exec.flops_retired = flops_before;
                exec.compute_s = compute_before;
                let time_s = shared.elapsed_s();
                shared.curve.lock().unwrap().push(CurvePoint {
                    step,
                    time_s,
                    loss,
                    accuracy: acc,
                });
                shared
                    .events
                    .emit(TrainEvent::EvalPoint { step, time_s, loss, accuracy: acc });
            }
            if cfg.track_drift_every > 0 && step % cfg.track_drift_every == 0 {
                let v = sample_drift(&shared.params, &mut drift_scratch);
                shared.drift.lock().unwrap().push_sample(step, v);
            }
        }
    }
    Ok(WorkerStats {
        compute_s: exec.compute_s,
        bwd_compute_s: exec.compute_s,
        steps: completed,
        flops: exec.flops_retired,
        upload_hits: exec.upload_hits,
        upload_misses: exec.upload_misses,
        ..Default::default()
    })
}

/// Open the engine-owned context for one pass: capture every layer's
/// staleness-clock snapshot — and, when DC compensation is on, the
/// forward-time parameter values `x_then` — BEFORE the forward pass reads
/// the stores. Serial and lockstep drivers share this.
pub(crate) fn open_step(
    cfg: &TrainConfig,
    params: &ModelParams,
    step: usize,
    n_layers: usize,
) -> StepState {
    let mut ctx = StepState::new(step, n_layers).with_clocks(params.clock_snapshot());
    if wants_x_then(cfg) {
        ctx = ctx.with_x_then(params.layers.iter().map(|l| l.snapshot()).collect());
    }
    ctx
}

/// Whether passes must carry forward-time parameter values: local DC
/// compensation, or DC-ASGD-PS (the *shard* compensates with the trainer's
/// forward-time values shipped inside the gradient push).
fn wants_x_then(cfg: &TrainConfig) -> bool {
    cfg.staleness.compensation == Compensation::Dc || cfg.algorithm == Algorithm::DcAsgdPs
}

/// Decoupled-mode counterpart of [`open_step`]: fill the pooled
/// [`HostPass`]'s provenance fields on the forward-pool thread, right
/// before the forward reads the stores.
fn capture_pass_provenance(cfg: &TrainConfig, params: &ModelParams, pass: &mut HostPass) {
    pass.clocks.clear();
    pass.clocks.extend(params.clock_snapshot());
    pass.x_then.clear();
    if wants_x_then(cfg) {
        pass.x_then = params.layers.iter().map(|l| l.snapshot()).collect();
    }
}

/// Driver of a parameter-server shard (role topologies): no model execution
/// at all — the shard pumps its fabric inbox, applying trainer gradient
/// pushes to the layers it owns (via [`crate::comm`]'s `GradPush` arm) and
/// replying with fresh parameters. Exits when every trainer has finished (or
/// died) and the inbox is dry, so late in-flight pushes are never stranded.
pub(crate) fn shard_main(
    cfg: &TrainConfig,
    wid: usize,
    shared: &Arc<Shared>,
) -> Result<WorkerExit> {
    let trainers = cfg.cluster.n_trainers(cfg.workers);
    shared.telemetry.register_thread(&format!("shard-{wid}"));
    loop {
        // a shard has no step counter of its own: chaos faults and delivery
        // stamps run on the fastest trainer's clock
        let global = (0..trainers)
            .map(|w| shared.steps_done[w].load(Ordering::Relaxed) as usize)
            .max()
            .unwrap_or(0);
        if shared.chaos.as_ref().is_some_and(|c| c.due(wid, global)) {
            return Ok(WorkerExit::Crashed {
                next_step: global,
                cursor: 0,
                stats: WorkerStats::default(),
            });
        }
        if shared.should_stop() {
            break;
        }
        let pending = shared.fabric.pending_to(wid);
        if pending > 0 {
            if let Some(ps) = shared.ps.as_ref() {
                ps.queue_depth_max.fetch_max(pending as u64, Ordering::Relaxed);
            }
        }
        let applied = shared.fabric.deliver_due(shared, wid, global);
        let trainers_done = (0..trainers).all(|w| {
            shared.steps_done[w].load(Ordering::Relaxed) >= cfg.steps as u64
                || !shared.membership.alive(w)
        });
        if trainers_done && shared.fabric.pending_to(wid) == 0 {
            break;
        }
        if applied == 0 {
            std::thread::sleep(Duration::from_micros(200));
        }
    }
    Ok(WorkerExit::Completed(WorkerStats::default()))
}

/// Periodic checkpoint rendezvous, called at the end of every step body.
/// Three phases over the live-counted barrier (reused across phases —
/// generations make that safe):
///
/// 1. every live worker quiesces its async updates, then meets — after the
///    release, all pre-boundary writes are applied and every live worker is
///    paused here, so the shared stores are stable;
/// 2. every worker deposits its thread-owned state ([`WorkerSlot`]), meets
///    again;
/// 3. the lowest-id live worker writes the snapshot, everyone meets once
///    more and resumes training.
///
/// A write failure is recorded on the rendezvous and fails the run on every
/// worker (a checkpoint you asked for but did not get is an error, not a
/// log line).
pub(crate) fn maybe_checkpoint(
    cfg: &TrainConfig,
    wid: usize,
    shared: &Arc<Shared>,
    step: usize,
    algo: &mut dyn WorkerAlgo,
    dataset: &dyn Dataset,
) -> Result<()> {
    let Some(ck) = shared.ckpt.as_ref() else {
        return Ok(());
    };
    if (step + 1) % ck.every != 0 || step + 1 >= cfg.steps {
        return Ok(());
    }
    algo.quiesce()?;
    if !ck.barrier.wait(&shared.stop) {
        return Ok(()); // run is stopping
    }
    let slot = WorkerSlot { cursor: dataset.cursor(), algo: algo.state_dict()? };
    ck.slots.lock().unwrap()[wid] = Some(slot);
    if !ck.barrier.wait(&shared.stop) {
        return Ok(());
    }
    if shared.membership.first_live() == Some(wid) {
        if let Err(e) = write_checkpoint(cfg, shared, ck, step + 1) {
            *ck.failure.lock().unwrap() = Some(format!("{e:#}"));
            shared.stop.store(true, Ordering::Relaxed);
        }
    }
    let _ = ck.barrier.wait(&shared.stop);
    if let Some(msg) = ck.failure.lock().unwrap().clone() {
        anyhow::bail!("checkpoint at step {} failed: {msg}", step + 1);
    }
    Ok(())
}

/// Assemble and write one snapshot into `<dir>/step-XXXXXX`. Caller
/// guarantees quiescence (every live worker is paused at the boundary with
/// its slot deposited). Shared with the lockstep driver.
pub(crate) fn write_checkpoint(
    cfg: &TrainConfig,
    shared: &Arc<Shared>,
    ck: &CheckpointRendezvous,
    next_step: usize,
) -> Result<()> {
    let _sp = shared.telemetry.span(Phase::Checkpoint);
    let workers_state: Vec<WorkerState> = {
        let mut slots = ck.slots.lock().unwrap();
        (0..shared.m)
            .map(|w| {
                let steps_done = shared.steps_done[w].load(Ordering::Relaxed);
                match slots[w].take() {
                    Some(slot) => WorkerState {
                        alive: true,
                        steps_done,
                        cursor: slot.cursor,
                        weight: shared.weights[w].get(),
                        algo: slot.algo,
                    },
                    // a chaos-dead worker has no thread to deposit a slot:
                    // record it dead with a fresh algorithm state (its
                    // optimizer moments died with the device)
                    None => WorkerState {
                        alive: shared.membership.alive(w),
                        steps_done,
                        cursor: steps_done,
                        weight: shared.weights[w].get(),
                        algo: AlgoState::default(),
                    },
                }
            })
            .collect()
    };
    let params = shared.params.iter().map(|p| p.state_dict()).collect();
    let clocks: Vec<Vec<ClockStamp>> = shared.params.iter().map(|p| p.clock_state()).collect();
    // quiesce the links: drain serializes the in-flight messages, restore
    // puts the very same messages back (their send-time dice stay rolled)
    let mut in_flight = Vec::new();
    for w in 0..shared.m {
        in_flight.extend(shared.fabric.drain(w));
    }
    shared.fabric.restore(shared, in_flight.clone());
    let mut curve = shared.curve.lock().unwrap().clone();
    curve.sort_by_step();
    let drift = shared.drift.lock().unwrap().clone();
    let snapshot = Checkpoint {
        version: FORMAT_VERSION,
        model: cfg.model.clone(),
        algorithm: cfg.algorithm.name().to_string(),
        workers: cfg.workers,
        seed: cfg.seed,
        step: next_step,
        elapsed_s: shared.elapsed_s(),
        epoch: shared.membership.epoch(),
        params,
        clocks,
        workers_state,
        in_flight,
        // codec error-feedback residuals: gradient mass the sparsifier is
        // still holding sender-side belongs to the snapshot too
        residuals: shared.fabric.core().codec().residual_state(),
        curve: curve.points,
        drift: drift.samples.iter().map(|&(s, v)| (s as u64, v)).collect(),
    };
    let dir = checkpoint::step_dir(&ck.dir, next_step);
    checkpoint::save(&dir, &snapshot)?;
    ck.saved.fetch_add(1, Ordering::Relaxed);
    shared.events.emit(TrainEvent::CheckpointSaved {
        step: next_step,
        path: dir.display().to_string(),
    });
    Ok(())
}

/// Reusable buffers for streamed drift sampling (§Perf: `flatten()`
/// materialized every replica's full parameter vector per sample; these
/// buffers are sized to the largest single tensor instead).
pub(crate) struct DriftScratch {
    /// per-worker snapshot of the tensor currently being swept
    snaps: Vec<Vec<f32>>,
    /// per-element mean of that tensor (f64 accumulation)
    mean: Vec<f64>,
    /// per-worker running Σ‖x_w − x̄‖² across tensors
    sq: Vec<f64>,
}

impl DriftScratch {
    pub(crate) fn new(m: usize) -> DriftScratch {
        DriftScratch { snaps: vec![Vec::new(); m], mean: Vec::new(), sq: vec![0.0; m] }
    }
}

/// Disagreement sample (Fig A1) computed tensor-by-tensor into reusable
/// buffers: mean over workers of ‖x_w − x̄‖/√d, with
/// ‖x_w − x̄‖² = Σ_tensors ‖chunk_w − chunk_mean‖² — numerically identical to
/// `DriftTracker::record` on full flattened vectors, without the per-sample
/// full-model allocations.
pub(crate) fn sample_drift(params: &[Arc<ModelParams>], scratch: &mut DriftScratch) -> f64 {
    let m = params.len();
    if m == 0 {
        return 0.0;
    }
    let d = params[0].numel();
    scratch.sq.iter_mut().for_each(|v| *v = 0.0);
    for li in 0..params[0].layers.len() {
        for ti in 0..params[0].layers[li].tensors.len() {
            let n = params[0].layers[li].tensors[ti].numel();
            scratch.mean.clear();
            scratch.mean.resize(n, 0.0);
            for (w, p) in params.iter().enumerate() {
                let snap = &mut scratch.snaps[w];
                snap.resize(n, 0.0);
                p.layers[li].tensors[ti].load_into(snap);
                for (mu, &x) in scratch.mean.iter_mut().zip(snap.iter()) {
                    *mu += x as f64;
                }
            }
            for mu in &mut scratch.mean {
                *mu /= m as f64;
            }
            for (w, sq) in scratch.sq.iter_mut().enumerate() {
                for (&x, &mu) in scratch.snaps[w].iter().zip(scratch.mean.iter()) {
                    let dd = x as f64 - mu;
                    *sq += dd * dd;
                }
            }
        }
    }
    scratch.sq.iter().map(|&s| (s / d as f64).sqrt()).sum::<f64>() / m as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::DriftTracker;
    use crate::tensor::{AtomicTensor, LayerParams, Tensor};
    use crate::util::rng::Pcg32;

    fn random_store(rng: &mut Pcg32, shape: &[usize]) -> AtomicTensor {
        let mut t = Tensor::zeros(shape);
        for v in &mut t.data {
            *v = rng.normal();
        }
        AtomicTensor::from_tensor(&t)
    }

    /// Pins the invariant the §Perf streamed drift path relies on: the
    /// tensor-by-tensor sweep must produce the SAME number as
    /// `DriftTracker::record` on fully flattened parameter vectors.
    #[test]
    fn streamed_drift_matches_record_on_flattened_vectors() {
        let mut rng = Pcg32::new(7);
        let m = 3;
        let params: Vec<Arc<ModelParams>> = (0..m)
            .map(|_| {
                Arc::new(ModelParams {
                    layers: vec![
                        LayerParams::new(vec![
                            random_store(&mut rng, &[4, 3]),
                            random_store(&mut rng, &[3]),
                        ]),
                        LayerParams::new(vec![random_store(&mut rng, &[5])]),
                    ],
                })
            })
            .collect();

        let flats: Vec<Vec<f32>> = params.iter().map(|p| p.flatten()).collect();
        let mut tracker = DriftTracker::default();
        tracker.record(0, &flats);
        let reference = tracker.samples[0].1;
        assert!(reference > 0.0, "random replicas must disagree");

        let mut scratch = DriftScratch::new(m);
        let streamed = sample_drift(&params, &mut scratch);
        assert!(
            (streamed - reference).abs() < 1e-12,
            "streamed {streamed} != record {reference}"
        );
        // scratch buffers are reusable across samples
        let again = sample_drift(&params, &mut scratch);
        assert!((again - reference).abs() < 1e-12);
    }
}
