//! The run engine: a supervising loop that spawns one driver per simulated
//! device (serial loop or decoupled forward/backward pools — see
//! [`super::worker`]), executes the chaos fault schedule (tear down /
//! respawn with per-algorithm recovery), propagates the cooperative stop
//! flag on error, and joins everything back into per-worker
//! [`WorkerStats`]. Summary assembly lives in [`crate::session`].
//!
//! # Crash / recovery protocol
//!
//! A worker whose scheduled fault fires exits its thread with
//! `WorkerExit::Crashed`. The supervisor then:
//!
//! 1. marks the slot dead (membership epoch bumps) and emits
//!    [`TrainEvent::WorkerCrashed`];
//! 2. drains the dead worker's fabric inbox, reclaiming any shipped
//!    push-sum weight to its senders — **mass is never destroyed**;
//! 3. for gossip algorithms, folds the dead worker's own push-sum weight
//!    into the lowest-id live peer (same invariant);
//! 4. if the fault schedules a restart, respawns the worker after the
//!    downtime: gossip workers re-enter from that peer's *current*
//!    parameters with half the donor's weight (conserved), barrier workers
//!    keep their own (still-current) replica; either way the optimizer
//!    moments died with the device. [`TrainEvent::WorkerJoined`] fires with
//!    the new membership epoch.
//!
//! Under the `Stall` recovery policy a *permanent* loss leaves barrier
//! algorithms waiting forever; after `TrainConfig::stall_timeout_s` the
//! supervisor marks the run stalled (`RunStats::recovery.stalled`) and stops
//! it — the fault-tolerance bench's DDP rows are exactly this path.

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::config::TrainConfig;
use crate::coordinator::worker::{WorkerBoot, WorkerExit};
use crate::coordinator::{lockstep, worker, Shared, WorkerStats};
use crate::manifest::Manifest;
use crate::resilience::{Checkpoint, RecoveryPolicy};
use crate::session::events::TrainEvent;

/// Supervisor's view of one worker slot.
enum Slot<'scope> {
    Running(std::thread::ScopedJoinHandle<'scope, Result<WorkerExit>>),
    /// crashed with a scheduled restart: respawn once `at` passes
    Waiting { at: Instant, boot: WorkerBoot },
    Done,
}

/// Drive the configured run to completion on the thread cluster.
pub(crate) fn execute(
    cfg: &TrainConfig,
    manifest: &Manifest,
    shared: &Arc<Shared>,
    resume: Option<&Checkpoint>,
) -> Result<Vec<WorkerStats>> {
    if cfg.lockstep {
        return lockstep::run(cfg, manifest, shared, resume);
    }
    let start_step = resume.map(|c| c.step).unwrap_or(0);
    let boot_for = |wid: usize| -> WorkerBoot {
        match resume {
            Some(ck) => WorkerBoot {
                start_step,
                cursor: ck.workers_state[wid].cursor,
                algo: Some(ck.workers_state[wid].algo.clone()),
            },
            None => WorkerBoot::default(),
        }
    };

    std::thread::scope(|scope| -> Result<Vec<WorkerStats>> {
        let spawn_worker = |wid: usize, boot: WorkerBoot| {
            let shared = Arc::clone(shared);
            let cfg = cfg.clone();
            scope.spawn(move || {
                let r = if cfg.cluster.is_shard(wid, cfg.workers) {
                    // role topology: the last wids run the PS shard pump, no
                    // model execution (config validation keeps shards out of
                    // decoupled mode)
                    worker::shard_main(&cfg, wid, &shared)
                } else if cfg.decoupled {
                    worker::worker_decoupled(&cfg, wid, &shared, manifest)
                        .map(WorkerExit::Completed)
                } else {
                    worker::worker_main(&cfg, wid, &shared, manifest, boot)
                };
                if r.is_err() {
                    shared.stop.store(true, Ordering::Relaxed);
                }
                r
            })
        };

        let mut slots: Vec<Slot> = (0..cfg.workers)
            .map(|wid| Slot::Running(spawn_worker(wid, boot_for(wid))))
            .collect();
        let mut stats: Vec<WorkerStats> = vec![WorkerStats::default(); cfg.workers];
        let mut first_err: Option<anyhow::Error> = None;
        let mut permanent_crash_at: Option<Instant> = None;
        let mut permanent_shard_dead = false;

        loop {
            let mut all_done = true;
            for wid in 0..cfg.workers {
                let slot = &mut slots[wid];
                match slot {
                    Slot::Done => {}
                    Slot::Running(h) if h.is_finished() => {
                        let h = match std::mem::replace(slot, Slot::Done) {
                            Slot::Running(h) => h,
                            _ => unreachable!(),
                        };
                        match h.join().expect("worker thread panicked") {
                            Ok(WorkerExit::Completed(ws)) => stats[wid].absorb(&ws),
                            Ok(WorkerExit::Crashed { next_step, cursor, stats: ws }) => {
                                stats[wid].absorb(&ws);
                                handle_crash(cfg, shared, wid, next_step);
                                let restart = shared
                                    .chaos
                                    .as_ref()
                                    .and_then(|c| c.restart_after(wid, next_step));
                                match restart {
                                    Some(secs) => {
                                        *slot = Slot::Waiting {
                                            at: Instant::now() + Duration::from_secs_f64(secs),
                                            boot: WorkerBoot {
                                                start_step: next_step,
                                                cursor,
                                                algo: None,
                                            },
                                        };
                                        all_done = false;
                                    }
                                    None => {
                                        permanent_crash_at.get_or_insert_with(Instant::now);
                                        if cfg.cluster.is_shard(wid, cfg.workers) {
                                            permanent_shard_dead = true;
                                        }
                                    }
                                }
                            }
                            Err(e) => {
                                shared.stop.store(true, Ordering::Relaxed);
                                if first_err.is_none() {
                                    first_err = Some(e);
                                }
                            }
                        }
                    }
                    Slot::Running(_) => all_done = false,
                    Slot::Waiting { at, .. } => {
                        if shared.should_stop() {
                            *slot = Slot::Done;
                        } else if Instant::now() >= *at {
                            let boot = match std::mem::replace(slot, Slot::Done) {
                                Slot::Waiting { boot, .. } => boot,
                                _ => unreachable!(),
                            };
                            recover_worker(cfg, shared, wid, boot.start_step);
                            *slot = Slot::Running(spawn_worker(wid, boot));
                            all_done = false;
                        } else {
                            all_done = false;
                        }
                    }
                }
            }
            if all_done {
                break;
            }
            // Stall detection: a permanently lost worker under the Stall
            // policy leaves barrier collectives waiting for a peer that is
            // never coming back — and a permanently lost PS shard leaves its
            // layer partition frozen (route_layer yields None, trainers make
            // no progress on those layers). Report and stop instead of
            // hanging; under Shrink, route_layer re-partitions on the bumped
            // membership epoch instead and the run continues.
            if let Some(t0) = permanent_crash_at {
                if (cfg.algorithm.uses_barrier() || permanent_shard_dead)
                    && shared.membership.policy() == RecoveryPolicy::Stall
                    && !shared.membership.stalled()
                    && t0.elapsed().as_secs_f64() > cfg.stall_timeout_s
                {
                    shared.membership.mark_stalled();
                    shared.stop.store(true, Ordering::Relaxed);
                }
            }
            // Dead-slot weight sweep: a gossip peer that read alive==true an
            // instant before mark_dead can still deposit push-sum weight
            // into the dead slot (lock-free stores, no global quiesce).
            // Re-fold any residue into a live peer every supervisor pass —
            // try_drain claims the accept slot so a deposit mid-flight is
            // never lost to a read-zero-write race; on contention we simply
            // retry next pass. Mass can park for a poll interval, never
            // strand — the conservation invariant holds under chaos.
            if !cfg.algorithm.uses_barrier() {
                for w in 0..cfg.workers {
                    if !shared.membership.alive(w) {
                        if let Some(donor) = shared.membership.first_live() {
                            match shared.weights[w].try_drain() {
                                Some(residue) if residue > 0.0 => {
                                    shared.weights[donor].reclaim(residue);
                                }
                                _ => {}
                            }
                        }
                    }
                }
            }
            std::thread::sleep(Duration::from_millis(2));
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(stats),
        }
    })
}

/// Supervisor-side teardown of a crashed worker (see module docs, steps
/// 1–3). The worker's thread has already exited cleanly.
fn handle_crash(cfg: &TrainConfig, shared: &Arc<Shared>, wid: usize, step: usize) {
    shared.membership.mark_dead(wid);
    shared.events.emit(TrainEvent::WorkerCrashed { worker: wid, step });
    // In-flight traffic addressed to the dead worker: gossip payloads are
    // lost with the device (delayed information) and their shipped push-sum
    // weight is reclaimed at the senders — mass is never destroyed. Reliable
    // collective shares (GradShare/ParamShare) are NOT discarded: they stay
    // queued like bytes in a TCP buffer waiting for the host to come back,
    // so a respawned worker can still complete the step-tagged collect its
    // peers are blocked on.
    let (reliable, gossip): (Vec<_>, Vec<_>) = shared
        .fabric
        .drain(wid)
        .into_iter()
        .partition(|m| !m.payload.droppable());
    for msg in gossip {
        let w = msg.payload.shipped_weight();
        if w > 0.0 {
            shared.weights[msg.from].reclaim(w);
        }
    }
    shared.fabric.restore(shared, reliable);
    // the dead worker's own weight folds into a surviving peer; gossip
    // consensus keeps total mass 1 (barrier algorithms don't use weights).
    // try_drain claims the accept slot so a racing deposit isn't lost; if a
    // peer is mid-deposit right now, the supervisor's per-pass sweep picks
    // the slot up a poll interval later.
    if !cfg.algorithm.uses_barrier() {
        if let Some(donor) = shared.membership.first_live() {
            if let Some(w) = shared.weights[wid].try_drain() {
                shared.weights[donor].reclaim(w);
            }
        }
    }
}

/// Supervisor-side recovery right before a respawn (module docs, step 4).
fn recover_worker(cfg: &TrainConfig, shared: &Arc<Shared>, wid: usize, step: usize) {
    if !cfg.algorithm.uses_barrier() {
        if let Some(donor) = shared.membership.first_live() {
            // re-enter gossip from the donor's CURRENT parameters (the
            // joiner's own replica is stale by the downtime) with half the
            // donor's push-sum weight — mass conserved
            shared.params[wid].copy_from(&shared.params[donor], donor, step);
            let w = shared.weights[donor].halve();
            shared.weights[wid].reclaim(w);
        }
    }
    shared.membership.mark_alive(wid);
    shared.events.emit(TrainEvent::WorkerJoined {
        worker: wid,
        step,
        epoch: shared.membership.epoch(),
    });
}
