//! The run engine: spawns one driver per simulated device (serial loop or
//! decoupled forward/backward pools — see [`super::worker`]), propagates the
//! cooperative stop flag on error, and joins everything back into per-worker
//! [`WorkerStats`]. Summary assembly lives in [`crate::session`].

use std::sync::atomic::Ordering;
use std::sync::Arc;

use anyhow::Result;

use crate::config::TrainConfig;
use crate::coordinator::{worker, Shared, WorkerStats};
use crate::manifest::Manifest;

/// Drive the configured run to completion on the thread cluster.
pub(crate) fn execute(
    cfg: &TrainConfig,
    manifest: &Manifest,
    shared: &Arc<Shared>,
) -> Result<Vec<WorkerStats>> {
    std::thread::scope(|scope| -> Result<Vec<WorkerStats>> {
        let mut handles = Vec::new();
        for wid in 0..cfg.workers {
            let shared = Arc::clone(shared);
            let cfg = cfg.clone();
            handles.push(scope.spawn(move || {
                let r = if cfg.decoupled {
                    worker::worker_decoupled(&cfg, wid, &shared, manifest)
                } else {
                    worker::worker_main(&cfg, wid, &shared, manifest)
                };
                if r.is_err() {
                    shared.stop.store(true, Ordering::Relaxed);
                }
                r
            }));
        }
        handles
            .into_iter()
            .map(|h| h.join().expect("worker thread panicked"))
            .collect()
    })
}
