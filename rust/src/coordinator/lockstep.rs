//! Deterministic lockstep driver: every simulated device of the run is
//! driven round-robin by ONE thread, with the algorithms' asynchronous
//! updates quiesced after each hook.
//!
//! The threaded engine is intentionally racy — gossip writes land in peers'
//! stores whenever the OS schedules the updater threads, exactly as the
//! paper describes. That realism makes gossip runs non-reproducible
//! run-to-run, which is fatal for one specific job: proving that a
//! checkpoint resume is **bit-identical** to an uninterrupted run. Lockstep
//! mode (`TrainConfig::lockstep`) removes the scheduler from the picture:
//!
//! * phase A — for each worker in id order: forward, backward (streaming
//!   `on_layer_grads`), then [`crate::algorithms::WorkerAlgo::quiesce`], so
//!   LayUp's updater has applied every local update *and* peer push before
//!   the next worker computes;
//! * phase B — for each worker in id order: `on_step_end` + quiesce, then
//!   the fabric's step-boundary deliveries.
//!
//! Same seed → same floats, every run. Barrier algorithms (which would
//! deadlock a single driving thread at their collectives), decoupled pools,
//! chaos schedules, stragglers and the simulated fabric (wall-clock
//! deliveries) are rejected by `TrainConfig::validate` for this mode;
//! checkpointing works and is how the resume-parity tests pin the
//! save→load→continue invariant for the gossip algorithms.

use std::sync::atomic::Ordering;
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::algorithms::{self, StepState, WorkerAlgo};
use crate::comm::Fabric;
use crate::config::TrainConfig;
use crate::coordinator::worker::{self, DriftScratch, WorkerBoot};
use crate::coordinator::{Shared, WorkerSlot, WorkerStats};
use crate::data::{self, Dataset};
use crate::manifest::Manifest;
use crate::metrics::{CurvePoint, QueueStats};
use crate::model::ModelExec;
use crate::resilience::Checkpoint;
use crate::runtime::Runtime;
use crate::session::events::TrainEvent;
use crate::telemetry::Phase;

/// Per-worker execution context owned by the driving thread. The runtime
/// must outlive its executables, so it rides along.
struct Wctx {
    _rt: Runtime,
    exec: ModelExec,
    dataset: Box<dyn Dataset>,
    algo: Box<dyn WorkerAlgo>,
    completed: usize,
    fwd_s: f64,
    bwd_s: f64,
}

/// Drive the whole run on the calling thread (see module docs).
pub(crate) fn run(
    cfg: &TrainConfig,
    manifest: &Manifest,
    shared: &Arc<Shared>,
    resume: Option<&Checkpoint>,
) -> Result<Vec<WorkerStats>> {
    let model = manifest.model(&cfg.model)?;
    let n_layers = model.layers.len();
    let m = cfg.workers;
    // role topologies: only the trainer wids compute; the shard wids are
    // driven implicitly — a GradPush on the instant fabric (the only one
    // lockstep allows) applies at the trainer's push and replies
    // synchronously, so the schedule stays single-threaded deterministic
    let trainers = cfg.cluster.n_trainers(m);
    let start_step = resume.map(|c| c.step).unwrap_or(0);

    let mut ctxs: Vec<Wctx> = Vec::with_capacity(trainers);
    for wid in 0..trainers {
        let boot = match resume {
            Some(ck) => WorkerBoot {
                start_step,
                cursor: ck.workers_state[wid].cursor,
                algo: Some(ck.workers_state[wid].algo.clone()),
            },
            None => WorkerBoot::default(),
        };
        let mut rt = Runtime::new().context("lockstep runtime")?;
        let exec = ModelExec::load(&mut rt, manifest, &cfg.model)
            .with_context(|| format!("lockstep worker {wid}: loading model"))?;
        let mut dataset = data::build(model, wid, cfg.workers, cfg.seed)?;
        if boot.cursor > 0 {
            dataset.skip(boot.cursor);
        }
        let mut algo = algorithms::build(cfg, wid, Arc::clone(shared), model)?;
        if let Some(state) = boot.algo {
            algo.load_state_dict(state)
                .with_context(|| format!("lockstep worker {wid}: restoring state"))?;
        }
        ctxs.push(Wctx {
            _rt: rt,
            exec,
            dataset,
            algo,
            completed: 0,
            fwd_s: 0.0,
            bwd_s: 0.0,
        });
    }
    // shard wids get only their checkpoint proxy (`algorithms::build`
    // returns the PS shard algo for them): no runtime, no dataset
    let mut shard_algos: Vec<Box<dyn WorkerAlgo>> = Vec::with_capacity(m - trainers);
    for wid in trainers..m {
        let mut algo = algorithms::build(cfg, wid, Arc::clone(shared), model)?;
        if let Some(ck) = resume {
            algo.load_state_dict(ck.workers_state[wid].algo.clone())
                .with_context(|| format!("lockstep shard {wid}: restoring state"))?;
        }
        shard_algos.push(algo);
    }

    shared.telemetry.register_thread("lockstep");
    let mut drift_scratch = DriftScratch::new(m);
    let mut states: Vec<Option<(StepState, f64)>> = (0..trainers).map(|_| None).collect();
    'steps: for step in start_step..cfg.steps {
        // phase A: compute, serialized in worker-id order — THE schedule
        for wid in 0..trainers {
            if shared.should_stop() {
                break 'steps;
            }
            let c = &mut ctxs[wid];
            let batch = c.dataset.next_batch();
            let fwd_before = c.exec.compute_s;
            // clock snapshot (and DC x_then) before the forward reads
            let mut ctx = worker::open_step(cfg, &shared.params[wid], step, n_layers);
            let pass = {
                let _sp = shared.telemetry.span(Phase::Forward);
                c.exec.forward(&shared.params[wid], &batch)?
            };
            if !pass.loss.is_finite() {
                anyhow::bail!("lockstep worker {wid}: loss diverged (step {step})");
            }
            let fwd_after = c.exec.compute_s;
            c.fwd_s += fwd_after - fwd_before;
            {
                let _sp = shared.telemetry.span(Phase::Backward);
                let exec = &mut c.exec;
                let algo = &mut c.algo;
                let mut err: Option<anyhow::Error> = None;
                let mut sink = |li: usize, grads: Vec<crate::tensor::Tensor>| {
                    if err.is_none() {
                        if let Err(e) = algo.on_layer_grads(&mut ctx, li, grads) {
                            err = Some(e);
                        }
                    }
                };
                exec.backward(&shared.params[wid], &pass, &mut sink)?;
                if let Some(e) = err {
                    return Err(e);
                }
            }
            c.bwd_s += c.exec.compute_s - fwd_after;
            // every streamed update of this worker lands before the next
            // worker computes — the determinism guarantee
            c.algo.quiesce()?;
            states[wid] = Some((ctx, pass.loss as f64));
        }
        // phase B: step ends, same order
        for wid in 0..trainers {
            let Some((ctx, loss)) = states[wid].take() else {
                break 'steps; // stopped mid-phase-A
            };
            let c = &mut ctxs[wid];
            c.algo.on_step_end(ctx)?;
            c.algo.quiesce()?;
            c.completed += 1;
            shared.steps_done[wid].fetch_add(1, Ordering::Relaxed);
            shared.fabric.deliver_due(shared, wid, step);
            shared
                .events
                .emit(TrainEvent::StepCompleted { worker: wid, step, loss });
        }
        // worker-0 duties: evaluation + drift sampling, same cadence as the
        // threaded serial loop (compute/flop counters excluded)
        if step % cfg.eval_every == 0 || step + 1 == cfg.steps {
            let c = &mut ctxs[0];
            let flops_before = c.exec.flops_retired;
            let compute_before = c.exec.compute_s;
            let (loss, acc) = c.exec.evaluate(&shared.params[0], c.dataset.as_ref(), 4)?;
            c.exec.flops_retired = flops_before;
            c.exec.compute_s = compute_before;
            let time_s = shared.elapsed_s();
            shared.curve.lock().unwrap().push(CurvePoint {
                step,
                time_s,
                loss,
                accuracy: acc,
            });
            shared
                .events
                .emit(TrainEvent::EvalPoint { step, time_s, loss, accuracy: acc });
        }
        if cfg.track_drift_every > 0 && step % cfg.track_drift_every == 0 {
            let v = worker::sample_drift(&shared.params, &mut drift_scratch);
            shared.drift.lock().unwrap().push_sample(step, v);
        }
        // checkpoint boundary — single-threaded, so no rendezvous barrier:
        // quiesce everyone, deposit every slot, write
        if let Some(ck) = shared.ckpt.as_ref() {
            if (step + 1) % ck.every == 0 && step + 1 < cfg.steps {
                for (wid, c) in ctxs.iter_mut().enumerate() {
                    c.algo.quiesce()?;
                    ck.slots.lock().unwrap()[wid] = Some(WorkerSlot {
                        cursor: c.dataset.cursor(),
                        algo: c.algo.state_dict()?,
                    });
                }
                // shard slots: no data cursor, just the optimizer moments
                for (k, algo) in shard_algos.iter_mut().enumerate() {
                    ck.slots.lock().unwrap()[trainers + k] = Some(WorkerSlot {
                        cursor: 0,
                        algo: algo.state_dict()?,
                    });
                }
                worker::write_checkpoint(cfg, shared, ck, step + 1)?;
            }
        }
    }

    let mut stats = Vec::with_capacity(m);
    for mut c in ctxs {
        c.algo.finish()?;
        stats.push(WorkerStats {
            compute_s: c.exec.compute_s,
            fwd_compute_s: c.fwd_s,
            bwd_compute_s: c.bwd_s,
            flops: c.exec.flops_retired,
            steps: c.completed,
            upload_hits: c.exec.upload_hits,
            upload_misses: c.exec.upload_misses,
            queue: QueueStats::default(),
        });
    }
    for mut algo in shard_algos {
        algo.finish()?;
        stats.push(WorkerStats::default()); // shards run no compute
    }
    Ok(stats)
}
