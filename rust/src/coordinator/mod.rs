//! Cluster coordinator: the shared state and thread plumbing of every
//! simulated device (plus whatever helper threads the algorithm needs, e.g.
//! LayUp's updaters), wired to the shared lock-free parameter stores.
//!
//! This is the L3 runtime of the paper, split in three layers:
//!
//! * [`crate::session`] — the public facade: build a session from a
//!   `TrainConfig` + `Manifest`, attach typed-event observers, run, get a
//!   `RunSummary`;
//! * `engine` — spawns the per-device drivers and aggregates their stats;
//! * `worker` — the per-device drivers themselves. Two execution modes:
//!   **serial** (`decoupled = false`, default): one thread runs
//!   forward -> backward -> hooks per step — the "computation thread" of
//!   Figure 1, unchanged, so all historical benches stay comparable;
//!   **decoupled** (`decoupled = true`): a *forward pool* of `fwd_threads`
//!   threads produces host-side passes ([`crate::model::HostPass`]) into a
//!   bounded, backpressured [`queue::BoundedQueue`]; a *backward pool* of
//!   `bwd_threads` threads consumes them, runs backward and feeds the
//!   algorithm hooks. This is the PD-ASGD regime (forward:backward thread
//!   ratios above 1:1) whose extra gradient staleness Lemma 6.1's bias bound
//!   covers; the queue depth bounds both activation memory and staleness.
//!
//! Algorithms hook both modes via [`crate::algorithms::WorkerAlgo`] — see
//! that module's threading contract for decoupled-mode semantics.
//!
//! All inter-worker traffic flows through the run's communication fabric
//! ([`crate::comm::Fabric`], held on [`Shared`]): collective shares land in
//! the fabric's mailboxes and gossip payloads mix into the receiving store —
//! instantly on the shared-memory transport, at the receiver's step
//! boundaries on the simulated one (the per-step `deliver_due` call in
//! `worker`).
//!
//! This module keeps the shared state ([`Shared`], [`StopBarrier`],
//! [`WorkerStats`]); the public run entry is `layup::session`.

pub(crate) mod engine;
pub(crate) mod lockstep;
pub mod queue;
pub(crate) mod worker;

use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::comm::Fabric;
use crate::config::{StalenessConfig, TrainConfig};
use crate::manifest::Manifest;
use crate::metrics::{Curve, DriftTracker, QueueStats, StalenessTracker};
use crate::model::ModelParams;
use crate::resilience::{AlgoState, ChaosRuntime, Checkpoint, Membership, RecoveryPolicy};
use crate::session::events::EventBus;
use crate::topology::PushSumWeight;

/// A barrier that can be abandoned when the run is stopping (a plain
/// `std::sync::Barrier` would deadlock the surviving workers if one worker
/// errors out mid-run).
///
/// Membership-aware ([`crate::resilience::membership`]): with a membership
/// attached, the release target follows the live worker count — always for
/// `live_counted` barriers (the checkpoint rendezvous must not wait for a
/// dead worker), and under the `Shrink` recovery policy for the run barrier
/// (a shrunken collective synchronizes among survivors; under `Stall` the
/// target stays fixed, which is exactly the stall the fault-tolerance bench
/// measures). Liveness is re-read every wake-up, so a membership change
/// mid-wait releases waiters within the poll interval.
pub struct StopBarrier {
    n: usize,
    state: Mutex<(usize, u64)>, // (arrived count, generation)
    cv: Condvar,
    membership: Option<Arc<Membership>>,
    /// live-count the target regardless of recovery policy
    always_live: bool,
}

impl StopBarrier {
    pub fn new(n: usize) -> Self {
        StopBarrier {
            n,
            state: Mutex::new((0, 0)),
            cv: Condvar::new(),
            membership: None,
            always_live: false,
        }
    }

    /// A run barrier whose target follows membership under the `Shrink`
    /// policy (and stays fixed under `Stall`).
    pub fn with_membership(n: usize, membership: Arc<Membership>) -> Self {
        StopBarrier { membership: Some(membership), ..StopBarrier::new(n) }
    }

    /// A barrier that always counts live workers only (checkpoint
    /// rendezvous).
    pub fn live_counted(n: usize, membership: Arc<Membership>) -> Self {
        StopBarrier { membership: Some(membership), always_live: true, ..StopBarrier::new(n) }
    }

    fn target(&self) -> usize {
        match &self.membership {
            Some(m) if self.always_live || m.policy() == RecoveryPolicy::Shrink => {
                m.live_count().clamp(1, self.n)
            }
            _ => self.n,
        }
    }

    /// Returns `true` when the collective arrived, `false` if `stop` was
    /// raised while waiting (caller should wind down).
    pub fn wait(&self, stop: &AtomicBool) -> bool {
        let mut st = self.state.lock().unwrap();
        let gen = st.1;
        st.0 += 1;
        if st.0 >= self.target() {
            st.0 = 0;
            st.1 += 1;
            self.cv.notify_all();
            return true;
        }
        loop {
            let (guard, _timeout) = self
                .cv
                .wait_timeout(st, Duration::from_millis(20))
                .unwrap();
            st = guard;
            if st.1 != gen {
                return true;
            }
            if st.0 >= self.target() {
                // the membership shrank while we waited: release the round
                st.0 = 0;
                st.1 += 1;
                self.cv.notify_all();
                return true;
            }
            if stop.load(Ordering::Relaxed) {
                // undo our arrival so a later generation isn't corrupted
                st.0 = st.0.saturating_sub(1);
                return false;
            }
        }
    }
}

/// Per-worker snapshot deposited during a checkpoint rendezvous (the
/// worker-thread-owned state the writer cannot reach itself; the resume
/// step is the rendezvous boundary, tracked by the writer).
pub struct WorkerSlot {
    /// data-loader cursor
    pub cursor: u64,
    /// algorithm state (optimizer moments, gossip RNG, outer momentum)
    pub algo: AlgoState,
}

/// Rendezvous state for periodic checkpoints: every live worker quiesces,
/// deposits a [`WorkerSlot`], and the lowest-id live worker writes the
/// snapshot (see `worker::maybe_checkpoint` for the three-phase protocol).
pub struct CheckpointRendezvous {
    /// checkpoint every k steps (validated > 0)
    pub every: usize,
    /// parent directory; snapshots land in `step-XXXXXX` subdirectories
    pub dir: PathBuf,
    /// live-counted phase barrier (reused across the three phases —
    /// generations make reuse safe)
    pub barrier: StopBarrier,
    pub slots: Mutex<Vec<Option<WorkerSlot>>>,
    /// checkpoints written so far (surfaced in `RunStats::recovery`)
    pub saved: AtomicU64,
    /// a failed write is recorded here and fails the run on every worker
    pub failure: Mutex<Option<String>>,
}

impl CheckpointRendezvous {
    fn new(every: usize, dir: PathBuf, m: usize, membership: Arc<Membership>) -> Self {
        CheckpointRendezvous {
            every,
            dir,
            barrier: StopBarrier::live_counted(m, membership),
            slots: Mutex::new((0..m).map(|_| None).collect()),
            saved: AtomicU64::new(0),
            failure: Mutex::new(None),
        }
    }
}

/// Parameter-server runtime of a `ps:N` role topology (`None` on flat and
/// hierarchical clusters): the per-shard optimizer stacks plus the PS
/// traffic counters surfaced in `RunStats`. Each shard's stack is locked
/// per gradient delivery — shards own disjoint layer ranges, so contention
/// exists only between deliveries to the *same* shard, never across shards.
pub struct PsState {
    /// worker id of shard 0 (shards are the last `shards.len()` ids)
    pub first_shard_wid: usize,
    /// one [`crate::algorithms::PerLayerOpt`] per shard, stamping the
    /// shard's own wid into every applied layer's staleness clock
    pub shards: Vec<Mutex<crate::algorithms::PerLayerOpt>>,
    /// gradient pushes applied by shards
    pub grad_pushes: AtomicU64,
    /// parameter replies shipped back to trainers
    pub param_pulls: AtomicU64,
    /// deepest per-pump delivery batch any shard observed (queue pressure)
    pub queue_depth_max: AtomicU64,
}

impl PsState {
    /// Shard index of worker `wid` (`None` for trainers).
    pub fn shard_of(&self, wid: usize) -> Option<usize> {
        wid.checked_sub(self.first_shard_wid).filter(|&k| k < self.shards.len())
    }
}

/// State shared by all worker + updater threads of one run.
pub struct Shared {
    pub m: usize,
    /// per-worker model replicas (lock-free stores)
    pub params: Vec<Arc<ModelParams>>,
    /// push-sum weights (gossip algorithms)
    pub weights: Vec<PushSumWeight>,
    /// synchronization barrier (DDP / LocalSGD family); membership-aware
    pub barrier: StopBarrier,
    /// the run's communication fabric: every inter-worker byte (gossip
    /// pushes, all-reduce shares, snapshot exchanges) goes through it
    pub fabric: Arc<dyn Fabric>,
    /// elastic worker membership (shared with the fabric core; epochs bump
    /// on every crash/join)
    pub membership: Arc<Membership>,
    /// chaos fault schedule runtime (`None`: no faults planned)
    pub chaos: Option<Arc<ChaosRuntime>>,
    /// periodic-checkpoint rendezvous (`None`: checkpointing off)
    pub ckpt: Option<CheckpointRendezvous>,
    /// cooperative shutdown (set on worker error)
    pub stop: AtomicBool,
    /// eval learning curve (written by worker 0)
    pub curve: Mutex<Curve>,
    /// model disagreement samples (Fig A1)
    pub drift: Mutex<DriftTracker>,
    /// per-worker completed step counters (straggler visibility)
    pub steps_done: Vec<AtomicU64>,
    /// typed-event fan-out (observers attached by the session builder)
    pub events: EventBus,
    /// per-layer observed-staleness counters (τ at gradient apply),
    /// recorded by every apply site against the pass's clock snapshot
    pub staleness: StalenessTracker,
    /// staleness update policies of the run (compensation / mixing knobs)
    pub staleness_cfg: StalenessConfig,
    pub start: Instant,
    /// wall seconds of training that happened before this process
    /// (checkpoint resume; keeps loss-vs-wallclock curves continuous)
    pub start_offset_s: f64,
    /// shard pool for the parameter hot path (§Perf): sized by
    /// `cfg.update_threads`, shared by every optimizer stack and gossip
    /// apply site of this run. `update_threads = 1` ⇒ serial, bit-identical
    /// to the unsharded path.
    pub update_pool: Arc<crate::tensor::shard::ShardPool>,
    /// parameter-server runtime (`Some` only under a `ps:N` topology)
    pub ps: Option<PsState>,
    /// run telemetry recorder (span rings, gauges, sampled series);
    /// disabled by default — every span site then pays one relaxed load
    pub telemetry: Arc<crate::telemetry::Telemetry>,
}

impl Shared {
    /// Shared state with no observers attached (tests and benches that poke
    /// the internals directly).
    pub fn new(cfg: &TrainConfig, manifest: &Manifest) -> Result<Arc<Shared>> {
        Shared::with_events(cfg, manifest, EventBus::new(), None)
    }

    /// Shared state carrying the session's event bus, optionally restored
    /// from a checkpoint (replica values, push-sum weights, step counters,
    /// recorded curve/drift and in-flight fabric traffic).
    pub fn with_events(
        cfg: &TrainConfig,
        manifest: &Manifest,
        events: EventBus,
        resume: Option<&Checkpoint>,
    ) -> Result<Arc<Shared>> {
        let model = manifest.model(&cfg.model)?;
        let m = cfg.workers;
        // All replicas start identical (same init seed): the paper's methods
        // assume a common initial consensus. The RNG init runs ONCE on the
        // prototype; every other replica is a value copy of it.
        let proto = ModelParams::init(model, cfg.seed);
        let params: Vec<Arc<ModelParams>> = std::iter::once(Arc::clone(&proto))
            .chain((1..m).map(|_| proto.replica()))
            .collect();
        let fabric = crate::comm::build_fabric(
            &cfg.fabric,
            &cfg.codec,
            cfg.coalesce,
            m,
            cfg.seed ^ 0xfab41c,
        );
        let membership = Arc::clone(fabric.core().membership());
        membership.set_policy(cfg.recovery);
        let weights: Vec<PushSumWeight> =
            (0..m).map(|_| PushSumWeight::new(1.0 / m as f32)).collect();
        let mut curve = Curve::default();
        let mut drift = DriftTracker::default();
        let mut steps_done: Vec<AtomicU64> = (0..m).map(|_| AtomicU64::new(0)).collect();
        let mut start_offset_s = 0.0;
        if let Some(ck) = resume {
            for (p, state) in params.iter().zip(&ck.params) {
                p.load_state_dict(state)?;
            }
            // restore each replica's staleness clocks bit-identically (the
            // plain load above must not double-stamp them)
            for (p, stamps) in params.iter().zip(&ck.clocks) {
                p.load_clocks(stamps)?;
            }
            for (w, ws) in ck.workers_state.iter().enumerate() {
                weights[w].set(ws.weight);
                steps_done[w] = AtomicU64::new(ws.steps_done);
            }
            curve.points = ck.curve.clone();
            for &(step, v) in &ck.drift {
                drift.push_sample(step as usize, v);
            }
            start_offset_s = ck.elapsed_s;
            // membership starts all-alive: resuming revives every slot, like
            // restarting the job (a mid-downtime respawn is not persisted)
        }
        let chaos = if cfg.faults.is_empty() {
            None
        } else {
            Some(Arc::new(ChaosRuntime::new(cfg.faults.clone())))
        };
        let ckpt = if cfg.checkpoint_every > 0 {
            Some(CheckpointRendezvous::new(
                cfg.checkpoint_every,
                cfg.checkpoint_dir.clone(),
                m,
                Arc::clone(&membership),
            ))
        } else {
            None
        };
        let n_layers = model.layers.len();
        let update_pool = crate::tensor::shard::ShardPool::new(cfg.update_threads);
        let telemetry = crate::telemetry::Telemetry::from_config(&cfg.telemetry);
        update_pool.install_telemetry(&telemetry);
        let ps = if cfg.cluster.n_shards() > 0 {
            // Role topology: install the routing table on the fabric core and
            // stand up one optimizer stack per server shard. Shard wids come
            // after every trainer wid, so shard k's stack stamps wid
            // `trainers + k` into the clocks of the layers it owns.
            fabric
                .core()
                .install_roles(crate::topology::roles::RoleTable::new(cfg.cluster, m, n_layers));
            let trainers = cfg.cluster.n_trainers(m);
            Some(PsState {
                first_shard_wid: trainers,
                shards: (0..cfg.cluster.n_shards())
                    .map(|k| {
                        Mutex::new(crate::algorithms::PerLayerOpt::new(
                            &cfg.optim,
                            &cfg.schedule,
                            model,
                            trainers + k,
                            Arc::clone(&update_pool),
                        ))
                    })
                    .collect(),
                grad_pushes: AtomicU64::new(0),
                param_pulls: AtomicU64::new(0),
                queue_depth_max: AtomicU64::new(0),
            })
        } else {
            None
        };
        if let Some((ps, ck)) = ps.as_ref().zip(resume) {
            // shard optimizer moments ride in the shard wid's worker slot
            for (k, slot) in ps.shards.iter().enumerate() {
                if let Some(opt) = &ck.workers_state[ps.first_shard_wid + k].algo.opt {
                    slot.lock().unwrap().load_state_dict(opt)?;
                }
            }
        }
        let shared = Arc::new(Shared {
            m,
            params,
            weights,
            barrier: StopBarrier::with_membership(m, Arc::clone(&membership)),
            fabric,
            membership,
            chaos,
            ckpt,
            stop: AtomicBool::new(false),
            curve: Mutex::new(curve),
            drift: Mutex::new(drift),
            steps_done,
            events,
            staleness: StalenessTracker::new(n_layers),
            staleness_cfg: cfg.staleness,
            start: Instant::now(),
            start_offset_s,
            update_pool,
            ps,
            telemetry,
        });
        if let Some(ck) = resume {
            // codec error-feedback residuals first (a restored compressed
            // message that drops must reclaim into the restored state, not
            // an empty one), then the in-flight messages back on the links
            shared.fabric.core().codec().load_residual_state(&ck.residuals);
            shared.fabric.restore(&shared, ck.in_flight.clone());
        }
        Ok(shared)
    }

    /// Minimal shared state for unit and property tests that drive a fabric
    /// directly against hand-built parameter replicas (no manifest, no
    /// runtime). Weights start at `1/m`, as in a real run.
    pub fn for_tests(params: Vec<Arc<ModelParams>>, fabric: Arc<dyn Fabric>) -> Arc<Shared> {
        let m = params.len();
        let n_layers = params.first().map(|p| p.layers.len()).unwrap_or(0);
        let membership = Arc::clone(fabric.core().membership());
        Arc::new(Shared {
            m,
            params,
            weights: (0..m).map(|_| PushSumWeight::new(1.0 / m as f32)).collect(),
            barrier: StopBarrier::with_membership(m, Arc::clone(&membership)),
            fabric,
            membership,
            chaos: None,
            ckpt: None,
            stop: AtomicBool::new(false),
            curve: Mutex::new(Curve::default()),
            drift: Mutex::new(DriftTracker::default()),
            steps_done: (0..m).map(|_| AtomicU64::new(0)).collect(),
            events: EventBus::new(),
            staleness: StalenessTracker::new(n_layers),
            staleness_cfg: StalenessConfig::default(),
            start: Instant::now(),
            start_offset_s: 0.0,
            update_pool: crate::tensor::shard::ShardPool::serial(),
            ps: None,
            telemetry: crate::telemetry::Telemetry::disabled(),
        })
    }

    pub fn should_stop(&self) -> bool {
        self.stop.load(Ordering::Relaxed)
    }

    /// Wall seconds of training including any checkpointed history (the
    /// time axis of eval points and summaries).
    pub fn elapsed_s(&self) -> f64 {
        self.start_offset_s + self.start.elapsed().as_secs_f64()
    }

    /// Sum of gossip (applied, skipped) counters.
    pub fn gossip_counts(&self) -> (u64, u64) {
        let applied = self.weights.iter().map(|w| w.applied.load(Ordering::Relaxed)).sum();
        let skipped = self.weights.iter().map(|w| w.skipped.load(Ordering::Relaxed)).sum();
        (applied, skipped)
    }
}

/// Per-worker accounting returned from the worker thread (or aggregated over
/// a worker's forward/backward pools in decoupled mode).
#[derive(Clone, Debug, Default)]
pub struct WorkerStats {
    /// total time inside compute, fwd + bwd
    pub compute_s: f64,
    /// forward-side share of `compute_s` (per-pool occupancy split)
    pub fwd_compute_s: f64,
    /// backward-side share of `compute_s`
    pub bwd_compute_s: f64,
    pub flops: u64,
    /// steps actually COMPLETED — not `cfg.steps`: a run that breaks early on
    /// `should_stop()` reports what really happened, and occupancy/MFU
    /// denominators use this
    pub steps: usize,
    pub upload_hits: u64,
    pub upload_misses: u64,
    /// pass-queue counters (decoupled mode; zeros for the serial loop)
    pub queue: QueueStats,
}

impl WorkerStats {
    /// Fold a pool thread's stats into the worker total.
    pub(crate) fn absorb(&mut self, other: &WorkerStats) {
        self.compute_s += other.compute_s;
        self.fwd_compute_s += other.fwd_compute_s;
        self.bwd_compute_s += other.bwd_compute_s;
        self.flops += other.flops;
        self.steps += other.steps;
        self.upload_hits += other.upload_hits;
        self.upload_misses += other.upload_misses;
        self.queue.merge(&other.queue);
    }
}
