//! Cluster coordinator: spawns the compute threads of every simulated device
//! (plus whatever helper threads the algorithm needs, e.g. LayUp's updaters),
//! wires them to the shared lock-free parameter stores, injects stragglers,
//! and collects metrics.
//!
//! This is the L3 runtime of the paper. Two execution modes per worker:
//!
//! * **serial** (`decoupled = false`, default): one thread runs
//!   forward -> backward -> hooks per step — the "computation thread" of
//!   Figure 1, unchanged, so all historical benches stay comparable;
//! * **decoupled** (`decoupled = true`): a *forward pool* of
//!   `fwd_threads` threads produces host-side passes ([`crate::model::HostPass`])
//!   into a bounded, backpressured [`queue::BoundedQueue`]; a *backward pool*
//!   of `bwd_threads` threads consumes them, runs backward and feeds the
//!   algorithm hooks. This is the PD-ASGD regime (forward:backward thread
//!   ratios above 1:1) whose extra gradient staleness Lemma 6.1's bias bound
//!   covers; the queue depth bounds both activation memory and staleness.
//!
//! Algorithms hook both modes via [`crate::algorithms::WorkerAlgo`] — see
//! that trait's threading contract for decoupled-mode caveats.

pub mod queue;

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::algorithms::{self, GradSet, WorkerAlgo};
use crate::config::{Algorithm, TrainConfig};
use crate::data;
use crate::manifest::Manifest;
use crate::metrics::{Curve, CurvePoint, DriftTracker, QueueStats, RunSummary};
use crate::model::{HostPass, ModelExec, ModelParams};
use crate::runtime::Runtime;
use crate::topology::PushSumWeight;
use queue::{BoundedQueue, PassPool};

/// A barrier that can be abandoned when the run is stopping (a plain
/// `std::sync::Barrier` would deadlock the surviving workers if one worker
/// errors out mid-run).
pub struct StopBarrier {
    n: usize,
    state: Mutex<(usize, u64)>, // (arrived count, generation)
    cv: Condvar,
}

impl StopBarrier {
    pub fn new(n: usize) -> Self {
        StopBarrier { n, state: Mutex::new((0, 0)), cv: Condvar::new() }
    }

    /// Returns `true` when all workers arrived, `false` if `stop` was raised
    /// while waiting (caller should wind down).
    pub fn wait(&self, stop: &AtomicBool) -> bool {
        let mut st = self.state.lock().unwrap();
        let gen = st.1;
        st.0 += 1;
        if st.0 == self.n {
            st.0 = 0;
            st.1 += 1;
            self.cv.notify_all();
            return true;
        }
        loop {
            let (guard, _timeout) = self
                .cv
                .wait_timeout(st, Duration::from_millis(20))
                .unwrap();
            st = guard;
            if st.1 != gen {
                return true;
            }
            if stop.load(Ordering::Relaxed) {
                // undo our arrival so a later generation isn't corrupted
                st.0 = st.0.saturating_sub(1);
                return false;
            }
        }
    }
}

/// State shared by all worker + updater threads of one run.
pub struct Shared {
    pub m: usize,
    /// per-worker model replicas (lock-free stores)
    pub params: Vec<Arc<ModelParams>>,
    /// push-sum weights (gossip algorithms)
    pub weights: Vec<PushSumWeight>,
    /// synchronization barrier (DDP / LocalSGD family)
    pub barrier: StopBarrier,
    /// gradient exchange slots (DDP all-reduce)
    pub grad_slots: Vec<Mutex<Option<GradSet>>>,
    /// flat parameter exchange slots (LocalSGD / SlowMo / CO2)
    pub param_slots: Vec<Mutex<Option<Vec<f32>>>>,
    /// cooperative shutdown (set on worker error)
    pub stop: AtomicBool,
    /// eval learning curve (written by worker 0)
    pub curve: Mutex<Curve>,
    /// model disagreement samples (Fig A1)
    pub drift: Mutex<DriftTracker>,
    /// per-worker completed step counters (straggler visibility)
    pub steps_done: Vec<AtomicU64>,
    pub start: Instant,
}

impl Shared {
    pub fn new(cfg: &TrainConfig, manifest: &Manifest) -> Result<Arc<Shared>> {
        let model = manifest.model(&cfg.model)?;
        let m = cfg.workers;
        // All replicas start identical (same init seed): the paper's methods
        // assume a common initial consensus. The RNG init runs ONCE on the
        // prototype; every other replica is a value copy of it.
        let proto = ModelParams::init(model, cfg.seed);
        let params: Vec<Arc<ModelParams>> = std::iter::once(Arc::clone(&proto))
            .chain((1..m).map(|_| proto.replica()))
            .collect();
        Ok(Arc::new(Shared {
            m,
            params,
            weights: (0..m).map(|_| PushSumWeight::new(1.0 / m as f32)).collect(),
            barrier: StopBarrier::new(m),
            grad_slots: (0..m).map(|_| Mutex::new(None)).collect(),
            param_slots: (0..m).map(|_| Mutex::new(None)).collect(),
            stop: AtomicBool::new(false),
            curve: Mutex::new(Curve::default()),
            drift: Mutex::new(DriftTracker::default()),
            steps_done: (0..m).map(|_| AtomicU64::new(0)).collect(),
            start: Instant::now(),
        }))
    }

    pub fn should_stop(&self) -> bool {
        self.stop.load(Ordering::Relaxed)
    }

    /// Sum of gossip (applied, skipped) counters.
    pub fn gossip_counts(&self) -> (u64, u64) {
        let applied = self.weights.iter().map(|w| w.applied.load(Ordering::Relaxed)).sum();
        let skipped = self.weights.iter().map(|w| w.skipped.load(Ordering::Relaxed)).sum();
        (applied, skipped)
    }
}

/// Per-worker accounting returned from the worker thread (or aggregated over
/// a worker's forward/backward pools in decoupled mode).
#[derive(Clone, Debug, Default)]
pub struct WorkerStats {
    /// total time inside compute, fwd + bwd
    pub compute_s: f64,
    /// forward-side share of `compute_s` (per-pool occupancy split)
    pub fwd_compute_s: f64,
    /// backward-side share of `compute_s`
    pub bwd_compute_s: f64,
    pub flops: u64,
    /// steps actually COMPLETED — not `cfg.steps`: a run that breaks early on
    /// `should_stop()` reports what really happened, and occupancy/MFU
    /// denominators use this
    pub steps: usize,
    pub upload_hits: u64,
    pub upload_misses: u64,
    /// pass-queue counters (decoupled mode; zeros for the serial loop)
    pub queue: QueueStats,
}

impl WorkerStats {
    /// Fold a pool thread's stats into the worker total.
    fn absorb(&mut self, other: &WorkerStats) {
        self.compute_s += other.compute_s;
        self.fwd_compute_s += other.fwd_compute_s;
        self.bwd_compute_s += other.bwd_compute_s;
        self.flops += other.flops;
        self.steps += other.steps;
        self.upload_hits += other.upload_hits;
        self.upload_misses += other.upload_misses;
        self.queue.merge(&other.queue);
    }
}

/// Run one full training job on the thread cluster. Returns the learning
/// curve, MFU/occupancy, drift samples and gossip counters.
pub fn run(cfg: &TrainConfig, manifest: &Manifest) -> Result<RunSummary> {
    cfg.validate()?;
    let shared = Shared::new(cfg, manifest)?;
    let t0 = Instant::now();

    let stats: Vec<WorkerStats> = std::thread::scope(|scope| -> Result<Vec<WorkerStats>> {
        let mut handles = Vec::new();
        for wid in 0..cfg.workers {
            let shared = Arc::clone(&shared);
            let cfg = cfg.clone();
            handles.push(scope.spawn(move || {
                let r = if cfg.decoupled {
                    worker_decoupled(&cfg, wid, &shared, manifest)
                } else {
                    worker_main(&cfg, wid, &shared, manifest)
                };
                if r.is_err() {
                    shared.stop.store(true, Ordering::Relaxed);
                }
                r
            }));
        }
        handles
            .into_iter()
            .map(|h| h.join().expect("worker thread panicked"))
            .collect()
    })?;

    let wall = t0.elapsed().as_secs_f64();
    let total_compute: f64 = stats.iter().map(|s| s.compute_s).sum();
    let total_flops: u64 = stats.iter().map(|s| s.flops).sum();
    let total_steps: usize = stats.iter().map(|s| s.steps).sum();
    // Occupancy denominators count the threads that could have computed:
    // one per worker serially, fwd_threads + bwd_threads per worker decoupled.
    let (fwd_pool, bwd_pool) = if cfg.decoupled {
        (cfg.fwd_threads, cfg.bwd_threads)
    } else {
        (1, 1)
    };
    let threads = if cfg.decoupled { fwd_pool + bwd_pool } else { 1 };
    let occupancy = (total_compute / (wall * (cfg.workers * threads) as f64)).min(1.0);
    let (applied, skipped) = shared.gossip_counts();

    let model = manifest.model(&cfg.model)?;
    let mut data0 = data::build(model, 0, cfg.workers, cfg.seed);
    let batches_per_epoch = data0.batches_per_epoch();
    let _ = data0.next_batch();

    let mut curve = shared.curve.lock().unwrap().clone();
    curve.sort_by_step(); // decoupled passes complete out of step order
    let mut drift = shared.drift.lock().unwrap().clone();
    drift.sort_by_step();
    let mut queue_stats = QueueStats::default();
    for s in &stats {
        queue_stats.merge(&s.queue);
    }
    let mut extras = std::collections::BTreeMap::new();
    extras.insert("achieved_flops_per_s".into(), total_flops as f64 / wall);
    extras.insert("max_disagreement".into(), drift.max_disagreement());
    extras.insert("final_disagreement".into(), drift.final_disagreement());
    extras.insert(
        "upload_hit_rate".into(),
        stats.iter().map(|s| s.upload_hits).sum::<u64>() as f64
            / (stats.iter().map(|s| s.upload_hits + s.upload_misses).sum::<u64>() as f64).max(1.0),
    );
    // Per-pool occupancy split (§Perf): is the pipeline fwd- or bwd-bound?
    extras.insert(
        "fwd_occupancy".into(),
        (stats.iter().map(|s| s.fwd_compute_s).sum::<f64>()
            / (wall * (cfg.workers * fwd_pool) as f64))
            .min(1.0),
    );
    extras.insert(
        "bwd_occupancy".into(),
        (stats.iter().map(|s| s.bwd_compute_s).sum::<f64>()
            / (wall * (cfg.workers * bwd_pool) as f64))
            .min(1.0),
    );
    extras.insert("queue_depth_mean".into(), queue_stats.mean_depth());
    extras.insert("queue_depth_max".into(), queue_stats.max_depth as f64);
    extras.insert("queue_blocked_frac".into(), queue_stats.blocked_frac());

    Ok(RunSummary {
        algorithm: cfg.algorithm.name().to_string(),
        curve,
        mfu: occupancy, // benches calibrate against single-worker peak
        compute_occupancy: occupancy,
        total_time_s: wall,
        total_steps,
        epochs: stats.first().map(|s| s.steps).unwrap_or(0) / batches_per_epoch.max(1),
        gossip_skipped: skipped,
        gossip_applied: applied,
        extras,
    })
}

/// The paper's "computation thread" for one device.
fn worker_main(
    cfg: &TrainConfig,
    wid: usize,
    shared: &Arc<Shared>,
    manifest: &Manifest,
) -> Result<WorkerStats> {
    let mut rt = Runtime::new().context("worker runtime")?;
    let mut exec = ModelExec::load(&mut rt, manifest, &cfg.model)
        .with_context(|| format!("worker {wid}: loading model"))?;
    let model = manifest.model(&cfg.model)?;
    let mut dataset = data::build(model, wid, cfg.workers, cfg.seed);
    let mut algo = algorithms::build(cfg, wid, Arc::clone(shared), &exec.manifest)?;

    let my_params = Arc::clone(&shared.params[wid]);
    let is_straggler = cfg.straggler.map(|(w, _)| w == wid).unwrap_or(false);
    let delay_iters = cfg.straggler.map(|(_, d)| d).unwrap_or(0.0);
    let mut baseline_step_s = 0.0f64;
    let mut drift_scratch = DriftScratch::new(shared.m);
    let mut completed = 0usize;
    let mut fwd_s = 0.0f64;
    let mut bwd_s = 0.0f64;

    for step in 0..cfg.steps {
        if shared.should_stop() {
            break;
        }
        // Straggler injection (Section 5.4): idle for a multiple of the
        // measured fwd+bwd time.
        if is_straggler && delay_iters > 0.0 && baseline_step_s > 0.0 {
            std::thread::sleep(std::time::Duration::from_secs_f64(
                baseline_step_s * delay_iters,
            ));
        }
        let step_t0 = Instant::now();

        let compute_before_fwd = exec.compute_s;
        let batch = dataset.next_batch();
        let pass = exec.forward(&my_params, &batch)?;
        if !pass.loss.is_finite() {
            anyhow::bail!("worker {wid}: loss diverged (step {step})");
        }
        let compute_after_fwd = exec.compute_s;
        fwd_s += compute_after_fwd - compute_before_fwd;
        {
            let mut err: Option<anyhow::Error> = None;
            let mut sink = |li: usize, grads: Vec<crate::tensor::Tensor>| {
                if err.is_none() {
                    if let Err(e) = algo.on_layer_grads(step, li, grads) {
                        err = Some(e);
                    }
                }
            };
            exec.backward(&my_params, &pass, &mut sink)?;
            if let Some(e) = err {
                return Err(e);
            }
        }
        bwd_s += exec.compute_s - compute_after_fwd;
        algo.on_step_end(step)?;
        completed += 1;
        shared.steps_done[wid].fetch_add(1, Ordering::Relaxed);

        if step < 3 {
            // calibrate the straggler delay unit on undelayed steps
            let dt = step_t0.elapsed().as_secs_f64();
            baseline_step_s = if step == 0 { dt } else { 0.5 * (baseline_step_s + dt) };
        }

        // Evaluation + drift tracking (worker 0 evaluates its replica;
        // compute/flop counters are excluded from training accounting).
        if wid == 0 && (step % cfg.eval_every == 0 || step + 1 == cfg.steps) {
            let flops_before = exec.flops_retired;
            let compute_before = exec.compute_s;
            let (loss, acc) = exec.evaluate(&my_params, dataset.as_ref(), 4)?;
            exec.flops_retired = flops_before;
            exec.compute_s = compute_before;
            shared.curve.lock().unwrap().push(CurvePoint {
                step,
                time_s: shared.start.elapsed().as_secs_f64(),
                loss,
                accuracy: acc,
            });
        }
        if wid == 0
            && cfg.track_drift_every > 0
            && step % cfg.track_drift_every == 0
        {
            let v = sample_drift(&shared.params, &mut drift_scratch);
            shared.drift.lock().unwrap().push_sample(step, v);
        }
    }

    algo.finish()?;
    Ok(WorkerStats {
        compute_s: exec.compute_s,
        fwd_compute_s: fwd_s,
        bwd_compute_s: bwd_s,
        flops: exec.flops_retired,
        steps: completed,
        upload_hits: exec.upload_hits,
        upload_misses: exec.upload_misses,
        queue: QueueStats::default(),
    })
}

/// Decoupled worker (the tentpole): forward pool -> bounded pass queue ->
/// backward pool, all for ONE simulated device.
///
/// * Every pool thread owns its own `Runtime`/`ModelExec` (`xla` wrappers are
///   `!Send`); passes cross threads as host-side [`HostPass`] buffers that
///   are recycled through a [`PassPool`] — no per-step allocation.
/// * Forward threads claim step indices from a shared counter and block on
///   the queue once `queue_depth` passes await backward (backpressure bounds
///   activation memory and staleness).
/// * Backward threads pop passes (possibly out of step order), run backward,
///   and drive the algorithm hooks under a per-worker mutex — see
///   [`WorkerAlgo`]'s threading contract.
/// * The last forward thread out closes the queue, so the backward pool
///   drains the tail and exits; any pool error raises the run-wide `stop`
///   flag, which unblocks every queue waiter (no deadlock on wind-down).
fn worker_decoupled(
    cfg: &TrainConfig,
    wid: usize,
    shared: &Arc<Shared>,
    manifest: &Manifest,
) -> Result<WorkerStats> {
    let model = manifest.model(&cfg.model)?;
    let pass_queue: BoundedQueue<HostPass> = BoundedQueue::new(cfg.queue_depth);
    let pool: PassPool<HostPass> = PassPool::new();
    let next_step = AtomicUsize::new(0);
    let live_producers = AtomicUsize::new(cfg.fwd_threads);
    let algo: Mutex<Box<dyn WorkerAlgo>> =
        Mutex::new(algorithms::build(cfg, wid, Arc::clone(shared), model)?);

    let results: Vec<Result<WorkerStats>> = std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for ft in 0..cfg.fwd_threads {
            let (pass_queue, pool, next_step, live_producers) =
                (&pass_queue, &pool, &next_step, &live_producers);
            handles.push(scope.spawn(move || {
                let r = forward_pool_main(cfg, wid, ft, shared, manifest, pass_queue, pool, next_step);
                if r.is_err() {
                    shared.stop.store(true, Ordering::Relaxed);
                }
                // last producer out closes the queue -> backward pool drains
                if live_producers.fetch_sub(1, Ordering::AcqRel) == 1 {
                    pass_queue.close();
                }
                r
            }));
        }
        for bt in 0..cfg.bwd_threads {
            let (pass_queue, pool, algo) = (&pass_queue, &pool, &algo);
            handles.push(scope.spawn(move || {
                let r = backward_pool_main(cfg, wid, bt, shared, manifest, pass_queue, pool, algo);
                if r.is_err() {
                    shared.stop.store(true, Ordering::Relaxed);
                }
                r
            }));
        }
        handles
            .into_iter()
            .map(|h| h.join().expect("pool thread panicked"))
            .collect()
    });

    let mut ws = WorkerStats::default();
    for r in results {
        ws.absorb(&r?);
    }
    ws.queue = pass_queue.stats();
    algo.into_inner().unwrap().finish()?;
    Ok(ws)
}

/// One forward-pool thread: claim a step, produce a [`HostPass`], push it
/// into the bounded queue (blocking at `queue_depth` — the backpressure the
/// tests pin down).
#[allow(clippy::too_many_arguments)]
fn forward_pool_main(
    cfg: &TrainConfig,
    wid: usize,
    ft: usize,
    shared: &Arc<Shared>,
    manifest: &Manifest,
    pass_queue: &BoundedQueue<HostPass>,
    pool: &PassPool<HostPass>,
    next_step: &AtomicUsize,
) -> Result<WorkerStats> {
    let mut rt = Runtime::new().context("forward-pool runtime")?;
    let mut exec = ModelExec::load(&mut rt, manifest, &cfg.model)
        .with_context(|| format!("worker {wid} fwd {ft}: loading model"))?;
    let model = manifest.model(&cfg.model)?;
    // Thread 0 keeps the worker's serial batch stream (a 1:1 ratio consumes
    // exactly the data the serial loop would); extra forward threads get
    // decorrelated shards of the same worker slice.
    let seed = cfg.seed ^ ((ft as u64) << 32);
    let mut dataset = data::build(model, wid, cfg.workers, seed);
    let my_params = Arc::clone(&shared.params[wid]);

    let is_straggler = cfg.straggler.map(|(w, _)| w == wid).unwrap_or(false);
    let delay_iters = cfg.straggler.map(|(_, d)| d).unwrap_or(0.0);
    let mut baseline_fwd_s = 0.0f64;
    let mut produced = 0usize;

    loop {
        if shared.should_stop() {
            break;
        }
        let step = next_step.fetch_add(1, Ordering::Relaxed);
        if step >= cfg.steps {
            break;
        }
        // Straggler injection (Section 5.4) lives in the FORWARD pool: pass
        // production gates the whole pipeline, so idling here slows the
        // device end-to-end. The delay unit is the measured forward latency
        // (the backward pool's time is not observable from this side).
        if is_straggler && delay_iters > 0.0 && baseline_fwd_s > 0.0 {
            std::thread::sleep(Duration::from_secs_f64(baseline_fwd_s * delay_iters));
        }
        let t0 = Instant::now();
        let batch = dataset.next_batch();
        let mut pass = pool.take();
        pass.step = step;
        exec.forward_host(&my_params, &batch, &mut pass)?;
        if !pass.loss.is_finite() {
            anyhow::bail!("worker {wid}: loss diverged (step {step})");
        }
        if produced < 3 {
            // calibrate the straggler delay unit on undelayed passes
            let dt = t0.elapsed().as_secs_f64();
            baseline_fwd_s = if produced == 0 { dt } else { 0.5 * (baseline_fwd_s + dt) };
        }
        produced += 1;
        if pass_queue.push(pass, &shared.stop).is_err() {
            break; // run is stopping (or queue closed early)
        }
    }
    Ok(WorkerStats {
        compute_s: exec.compute_s,
        fwd_compute_s: exec.compute_s,
        // steps are counted where passes COMPLETE (the backward pool)
        steps: 0,
        flops: exec.flops_retired,
        upload_hits: exec.upload_hits,
        upload_misses: exec.upload_misses,
        ..Default::default()
    })
}

/// One backward-pool thread: drain the pass queue, run backward, feed the
/// algorithm hooks (serialized per worker), recycle the pass buffer. The
/// designated thread (worker 0, backward thread 0) also owns evaluation and
/// drift sampling, mirroring the serial loop's worker-0 duties.
#[allow(clippy::too_many_arguments)]
fn backward_pool_main(
    cfg: &TrainConfig,
    wid: usize,
    bt: usize,
    shared: &Arc<Shared>,
    manifest: &Manifest,
    pass_queue: &BoundedQueue<HostPass>,
    pool: &PassPool<HostPass>,
    algo: &Mutex<Box<dyn WorkerAlgo>>,
) -> Result<WorkerStats> {
    let mut rt = Runtime::new().context("backward-pool runtime")?;
    let mut exec = ModelExec::load(&mut rt, manifest, &cfg.model)
        .with_context(|| format!("worker {wid} bwd {bt}: loading model"))?;
    let model = manifest.model(&cfg.model)?;
    let my_params = Arc::clone(&shared.params[wid]);
    // Worker 0 owns evaluation + drift duty (as in the serial loop). EVERY
    // backward thread of worker 0 carries an eval stream: an eval-eligible
    // step is evaluated by whichever thread pops its pass, so no eval point
    // is dropped when bwd_threads > 1. Eval batches are deterministic, so
    // the streams are identical across threads.
    let eval_ds = if wid == 0 {
        Some(data::build(model, wid, cfg.workers, cfg.seed))
    } else {
        None
    };
    let mut drift_scratch = DriftScratch::new(shared.m);
    let mut completed = 0usize;

    while let Some(pass) = pass_queue.pop(&shared.stop) {
        let step = pass.step;
        {
            let mut err: Option<anyhow::Error> = None;
            let mut sink = |li: usize, grads: Vec<crate::tensor::Tensor>| {
                if err.is_none() {
                    if let Err(e) = algo.lock().unwrap().on_layer_grads(step, li, grads) {
                        err = Some(e);
                    }
                }
            };
            exec.backward_host(&my_params, &pass, &mut sink)?;
            if let Some(e) = err {
                return Err(e);
            }
        }
        algo.lock().unwrap().on_step_end(step)?;
        completed += 1;
        shared.steps_done[wid].fetch_add(1, Ordering::Relaxed);
        pool.put(pass);

        if let Some(ds) = eval_ds.as_deref() {
            // compute/flop counters are excluded, exactly as in the serial loop
            if step % cfg.eval_every == 0 || step + 1 == cfg.steps {
                let flops_before = exec.flops_retired;
                let compute_before = exec.compute_s;
                let (loss, acc) = exec.evaluate(&my_params, ds, 4)?;
                exec.flops_retired = flops_before;
                exec.compute_s = compute_before;
                shared.curve.lock().unwrap().push(CurvePoint {
                    step,
                    time_s: shared.start.elapsed().as_secs_f64(),
                    loss,
                    accuracy: acc,
                });
            }
            if cfg.track_drift_every > 0 && step % cfg.track_drift_every == 0 {
                let v = sample_drift(&shared.params, &mut drift_scratch);
                shared.drift.lock().unwrap().push_sample(step, v);
            }
        }
    }
    Ok(WorkerStats {
        compute_s: exec.compute_s,
        bwd_compute_s: exec.compute_s,
        steps: completed,
        flops: exec.flops_retired,
        upload_hits: exec.upload_hits,
        upload_misses: exec.upload_misses,
        ..Default::default()
    })
}

/// Reusable buffers for streamed drift sampling (§Perf: `flatten()`
/// materialized every replica's full parameter vector per sample; these
/// buffers are sized to the largest single tensor instead).
struct DriftScratch {
    /// per-worker snapshot of the tensor currently being swept
    snaps: Vec<Vec<f32>>,
    /// per-element mean of that tensor (f64 accumulation)
    mean: Vec<f64>,
    /// per-worker running Σ‖x_w − x̄‖² across tensors
    sq: Vec<f64>,
}

impl DriftScratch {
    fn new(m: usize) -> DriftScratch {
        DriftScratch { snaps: vec![Vec::new(); m], mean: Vec::new(), sq: vec![0.0; m] }
    }
}

/// Disagreement sample (Fig A1) computed tensor-by-tensor into reusable
/// buffers: mean over workers of ‖x_w − x̄‖/√d, with
/// ‖x_w − x̄‖² = Σ_tensors ‖chunk_w − chunk_mean‖² — numerically identical to
/// `DriftTracker::record` on full flattened vectors, without the per-sample
/// full-model allocations.
fn sample_drift(params: &[Arc<ModelParams>], scratch: &mut DriftScratch) -> f64 {
    let m = params.len();
    if m == 0 {
        return 0.0;
    }
    let d = params[0].numel();
    scratch.sq.iter_mut().for_each(|v| *v = 0.0);
    for li in 0..params[0].layers.len() {
        for ti in 0..params[0].layers[li].tensors.len() {
            let n = params[0].layers[li].tensors[ti].numel();
            scratch.mean.clear();
            scratch.mean.resize(n, 0.0);
            for (w, p) in params.iter().enumerate() {
                let snap = &mut scratch.snaps[w];
                snap.resize(n, 0.0);
                p.layers[li].tensors[ti].load_into(snap);
                for (mu, &x) in scratch.mean.iter_mut().zip(snap.iter()) {
                    *mu += x as f64;
                }
            }
            for mu in &mut scratch.mean {
                *mu /= m as f64;
            }
            for (w, sq) in scratch.sq.iter_mut().enumerate() {
                for (&x, &mu) in scratch.snaps[w].iter().zip(scratch.mean.iter()) {
                    let dd = x as f64 - mu;
                    *sq += dd * dd;
                }
            }
        }
    }
    scratch.sq.iter().map(|&s| (s / d as f64).sqrt()).sum::<f64>() / m as f64
}

/// Convenience: run every paper algorithm on the same config, returning
/// summaries in paper-table order (used by the bench harness).
pub fn run_all(base: &TrainConfig, manifest: &Manifest) -> Result<Vec<RunSummary>> {
    Algorithm::all_paper()
        .iter()
        .map(|&a| {
            let mut cfg = base.clone();
            cfg.algorithm = a;
            run(&cfg, manifest)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{AtomicTensor, LayerParams, Tensor};
    use crate::util::rng::Pcg32;

    fn random_store(rng: &mut Pcg32, shape: &[usize]) -> AtomicTensor {
        let mut t = Tensor::zeros(shape);
        for v in &mut t.data {
            *v = rng.normal();
        }
        AtomicTensor::from_tensor(&t)
    }

    /// Pins the invariant the §Perf streamed drift path relies on: the
    /// tensor-by-tensor sweep must produce the SAME number as
    /// `DriftTracker::record` on fully flattened parameter vectors.
    #[test]
    fn streamed_drift_matches_record_on_flattened_vectors() {
        let mut rng = Pcg32::new(7);
        let m = 3;
        let params: Vec<Arc<ModelParams>> = (0..m)
            .map(|_| {
                Arc::new(ModelParams {
                    layers: vec![
                        LayerParams {
                            tensors: vec![
                                random_store(&mut rng, &[4, 3]),
                                random_store(&mut rng, &[3]),
                            ],
                        },
                        LayerParams { tensors: vec![random_store(&mut rng, &[5])] },
                    ],
                })
            })
            .collect();

        let flats: Vec<Vec<f32>> = params.iter().map(|p| p.flatten()).collect();
        let mut tracker = DriftTracker::default();
        tracker.record(0, &flats);
        let reference = tracker.samples[0].1;
        assert!(reference > 0.0, "random replicas must disagree");

        let mut scratch = DriftScratch::new(m);
        let streamed = sample_drift(&params, &mut scratch);
        assert!(
            (streamed - reference).abs() < 1e-12,
            "streamed {streamed} != record {reference}"
        );
        // scratch buffers are reusable across samples
        let again = sample_drift(&params, &mut scratch);
        assert!((again - reference).abs() < 1e-12);
    }
}
