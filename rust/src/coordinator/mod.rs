//! Cluster coordinator: spawns one worker thread per simulated device (plus
//! whatever helper threads the algorithm needs, e.g. LayUp's updaters), wires
//! them to the shared lock-free parameter stores, injects stragglers, and
//! collects metrics.
//!
//! This is the L3 runtime of the paper: the training loop below is the
//! "computation thread" of Figure 1; algorithms hook it via
//! [`crate::algorithms::WorkerAlgo`].

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Condvar, Mutex};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::algorithms::{self, GradSet};
use crate::config::{Algorithm, TrainConfig};
use crate::data;
use crate::manifest::Manifest;
use crate::metrics::{Curve, CurvePoint, DriftTracker, RunSummary};
use crate::model::{ModelExec, ModelParams};
use crate::runtime::Runtime;
use crate::topology::PushSumWeight;

/// A barrier that can be abandoned when the run is stopping (a plain
/// `std::sync::Barrier` would deadlock the surviving workers if one worker
/// errors out mid-run).
pub struct StopBarrier {
    n: usize,
    state: Mutex<(usize, u64)>, // (arrived count, generation)
    cv: Condvar,
}

impl StopBarrier {
    pub fn new(n: usize) -> Self {
        StopBarrier { n, state: Mutex::new((0, 0)), cv: Condvar::new() }
    }

    /// Returns `true` when all workers arrived, `false` if `stop` was raised
    /// while waiting (caller should wind down).
    pub fn wait(&self, stop: &AtomicBool) -> bool {
        let mut st = self.state.lock().unwrap();
        let gen = st.1;
        st.0 += 1;
        if st.0 == self.n {
            st.0 = 0;
            st.1 += 1;
            self.cv.notify_all();
            return true;
        }
        loop {
            let (guard, _timeout) = self
                .cv
                .wait_timeout(st, Duration::from_millis(20))
                .unwrap();
            st = guard;
            if st.1 != gen {
                return true;
            }
            if stop.load(Ordering::Relaxed) {
                // undo our arrival so a later generation isn't corrupted
                st.0 = st.0.saturating_sub(1);
                return false;
            }
        }
    }
}

/// State shared by all worker + updater threads of one run.
pub struct Shared {
    pub m: usize,
    /// per-worker model replicas (lock-free stores)
    pub params: Vec<Arc<ModelParams>>,
    /// push-sum weights (gossip algorithms)
    pub weights: Vec<PushSumWeight>,
    /// synchronization barrier (DDP / LocalSGD family)
    pub barrier: StopBarrier,
    /// gradient exchange slots (DDP all-reduce)
    pub grad_slots: Vec<Mutex<Option<GradSet>>>,
    /// flat parameter exchange slots (LocalSGD / SlowMo / CO2)
    pub param_slots: Vec<Mutex<Option<Vec<f32>>>>,
    /// cooperative shutdown (set on worker error)
    pub stop: AtomicBool,
    /// eval learning curve (written by worker 0)
    pub curve: Mutex<Curve>,
    /// model disagreement samples (Fig A1)
    pub drift: Mutex<DriftTracker>,
    /// per-worker completed step counters (straggler visibility)
    pub steps_done: Vec<AtomicU64>,
    pub start: Instant,
}

impl Shared {
    pub fn new(cfg: &TrainConfig, manifest: &Manifest) -> Result<Arc<Shared>> {
        let model = manifest.model(&cfg.model)?;
        let m = cfg.workers;
        // All replicas start identical (same init seed): the paper's methods
        // assume a common initial consensus.
        let proto = ModelParams::init(model, cfg.seed);
        let params: Vec<Arc<ModelParams>> = (0..m)
            .map(|_| {
                let p = ModelParams::init(model, cfg.seed);
                p.copy_from(&proto);
                p
            })
            .collect();
        Ok(Arc::new(Shared {
            m,
            params,
            weights: (0..m).map(|_| PushSumWeight::new(1.0 / m as f32)).collect(),
            barrier: StopBarrier::new(m),
            grad_slots: (0..m).map(|_| Mutex::new(None)).collect(),
            param_slots: (0..m).map(|_| Mutex::new(None)).collect(),
            stop: AtomicBool::new(false),
            curve: Mutex::new(Curve::default()),
            drift: Mutex::new(DriftTracker::default()),
            steps_done: (0..m).map(|_| AtomicU64::new(0)).collect(),
            start: Instant::now(),
        }))
    }

    pub fn should_stop(&self) -> bool {
        self.stop.load(Ordering::Relaxed)
    }

    /// Sum of gossip (applied, skipped) counters.
    pub fn gossip_counts(&self) -> (u64, u64) {
        let applied = self.weights.iter().map(|w| w.applied.load(Ordering::Relaxed)).sum();
        let skipped = self.weights.iter().map(|w| w.skipped.load(Ordering::Relaxed)).sum();
        (applied, skipped)
    }
}

/// Per-worker accounting returned from the worker thread.
#[derive(Clone, Debug, Default)]
pub struct WorkerStats {
    pub compute_s: f64,
    pub flops: u64,
    pub steps: usize,
    pub upload_hits: u64,
    pub upload_misses: u64,
}

/// Run one full training job on the thread cluster. Returns the learning
/// curve, MFU/occupancy, drift samples and gossip counters.
pub fn run(cfg: &TrainConfig, manifest: &Manifest) -> Result<RunSummary> {
    let shared = Shared::new(cfg, manifest)?;
    let t0 = Instant::now();

    let stats: Vec<WorkerStats> = std::thread::scope(|scope| -> Result<Vec<WorkerStats>> {
        let mut handles = Vec::new();
        for wid in 0..cfg.workers {
            let shared = Arc::clone(&shared);
            let cfg = cfg.clone();
            handles.push(scope.spawn(move || {
                let r = worker_main(&cfg, wid, &shared, manifest);
                if r.is_err() {
                    shared.stop.store(true, Ordering::Relaxed);
                }
                r
            }));
        }
        handles
            .into_iter()
            .map(|h| h.join().expect("worker thread panicked"))
            .collect()
    })?;

    let wall = t0.elapsed().as_secs_f64();
    let total_compute: f64 = stats.iter().map(|s| s.compute_s).sum();
    let total_flops: u64 = stats.iter().map(|s| s.flops).sum();
    let occupancy = (total_compute / (wall * cfg.workers as f64)).min(1.0);
    let (applied, skipped) = shared.gossip_counts();

    let model = manifest.model(&cfg.model)?;
    let mut data0 = data::build(model, 0, cfg.workers, cfg.seed);
    let batches_per_epoch = data0.batches_per_epoch();
    let _ = data0.next_batch();

    let curve = shared.curve.lock().unwrap().clone();
    let drift = shared.drift.lock().unwrap().clone();
    let mut extras = std::collections::BTreeMap::new();
    extras.insert("achieved_flops_per_s".into(), total_flops as f64 / wall);
    extras.insert("max_disagreement".into(), drift.max_disagreement());
    extras.insert("final_disagreement".into(), drift.final_disagreement());
    extras.insert(
        "upload_hit_rate".into(),
        stats.iter().map(|s| s.upload_hits).sum::<u64>() as f64
            / (stats.iter().map(|s| s.upload_hits + s.upload_misses).sum::<u64>() as f64).max(1.0),
    );

    Ok(RunSummary {
        algorithm: cfg.algorithm.name().to_string(),
        curve,
        mfu: occupancy, // benches calibrate against single-worker peak
        compute_occupancy: occupancy,
        total_time_s: wall,
        total_steps: cfg.steps * cfg.workers,
        epochs: cfg.steps / batches_per_epoch.max(1),
        gossip_skipped: skipped,
        gossip_applied: applied,
        extras,
    })
}

/// The paper's "computation thread" for one device.
fn worker_main(
    cfg: &TrainConfig,
    wid: usize,
    shared: &Arc<Shared>,
    manifest: &Manifest,
) -> Result<WorkerStats> {
    let mut rt = Runtime::new().context("worker runtime")?;
    let mut exec = ModelExec::load(&mut rt, manifest, &cfg.model)
        .with_context(|| format!("worker {wid}: loading model"))?;
    let model = manifest.model(&cfg.model)?;
    let mut dataset = data::build(model, wid, cfg.workers, cfg.seed);
    let mut algo = algorithms::build(cfg, wid, Arc::clone(shared), &exec.manifest)?;

    let my_params = Arc::clone(&shared.params[wid]);
    let is_straggler = cfg.straggler.map(|(w, _)| w == wid).unwrap_or(false);
    let delay_iters = cfg.straggler.map(|(_, d)| d).unwrap_or(0.0);
    let mut baseline_step_s = 0.0f64;

    for step in 0..cfg.steps {
        if shared.should_stop() {
            break;
        }
        // Straggler injection (Section 5.4): idle for a multiple of the
        // measured fwd+bwd time.
        if is_straggler && delay_iters > 0.0 && baseline_step_s > 0.0 {
            std::thread::sleep(std::time::Duration::from_secs_f64(
                baseline_step_s * delay_iters,
            ));
        }
        let step_t0 = Instant::now();

        let batch = dataset.next_batch();
        let pass = exec.forward(&my_params, &batch)?;
        if !pass.loss.is_finite() {
            anyhow::bail!("worker {wid}: loss diverged (step {step})");
        }
        {
            let mut err: Option<anyhow::Error> = None;
            let mut sink = |li: usize, grads: Vec<crate::tensor::Tensor>| {
                if err.is_none() {
                    if let Err(e) = algo.on_layer_grads(step, li, grads) {
                        err = Some(e);
                    }
                }
            };
            exec.backward(&my_params, &pass, &mut sink)?;
            if let Some(e) = err {
                return Err(e);
            }
        }
        algo.on_step_end(step)?;
        shared.steps_done[wid].fetch_add(1, Ordering::Relaxed);

        if step < 3 {
            // calibrate the straggler delay unit on undelayed steps
            let dt = step_t0.elapsed().as_secs_f64();
            baseline_step_s = if step == 0 { dt } else { 0.5 * (baseline_step_s + dt) };
        }

        // Evaluation + drift tracking (worker 0 evaluates its replica;
        // compute/flop counters are excluded from training accounting).
        if wid == 0 && (step % cfg.eval_every == 0 || step + 1 == cfg.steps) {
            let flops_before = exec.flops_retired;
            let compute_before = exec.compute_s;
            let (loss, acc) = exec.evaluate(&my_params, dataset.as_ref(), 4)?;
            exec.flops_retired = flops_before;
            exec.compute_s = compute_before;
            shared.curve.lock().unwrap().push(CurvePoint {
                step,
                time_s: shared.start.elapsed().as_secs_f64(),
                loss,
                accuracy: acc,
            });
        }
        if wid == 0
            && cfg.track_drift_every > 0
            && step % cfg.track_drift_every == 0
        {
            let flats: Vec<Vec<f32>> = shared.params.iter().map(|p| p.flatten()).collect();
            shared.drift.lock().unwrap().record(step, &flats);
        }
    }

    algo.finish()?;
    Ok(WorkerStats {
        compute_s: exec.compute_s,
        flops: exec.flops_retired,
        steps: cfg.steps,
        upload_hits: exec.upload_hits,
        upload_misses: exec.upload_misses,
    })
}

/// Convenience: run every paper algorithm on the same config, returning
/// summaries in paper-table order (used by the bench harness).
pub fn run_all(base: &TrainConfig, manifest: &Manifest) -> Result<Vec<RunSummary>> {
    Algorithm::all_paper()
        .iter()
        .map(|&a| {
            let mut cfg = base.clone();
            cfg.algorithm = a;
            run(&cfg, manifest)
        })
        .collect()
}
