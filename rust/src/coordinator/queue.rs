//! The bounded, backpressured, stop-aware pass queue connecting a worker's
//! decoupled forward and backward pools, plus the recycling pool that keeps
//! `HostPass` buffers alive across steps.
//!
//! Semantics:
//!
//! * `push` blocks while the queue holds `cap` items (backpressure: the
//!   forward pool cannot run more than `queue_depth` passes ahead of the
//!   backward pool, which bounds activation memory AND gradient staleness);
//! * `pop` blocks while the queue is empty, returning `None` once the queue
//!   is closed and drained;
//! * raising `stop` unblocks every waiter promptly (20 ms poll, like
//!   [`super::StopBarrier`]): blocked pushers get their item back, blocked
//!   poppers get `None` — so a run winds down without deadlock even with the
//!   forward pool pinned at capacity.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::Duration;

use crate::metrics::QueueStats;

struct Inner<T> {
    q: VecDeque<T>,
    closed: bool,
    stats: QueueStats,
}

/// Multi-producer multi-consumer bounded queue (see module docs).
pub struct BoundedQueue<T> {
    cap: usize,
    inner: Mutex<Inner<T>>,
    cv: Condvar,
}

impl<T> BoundedQueue<T> {
    pub fn new(cap: usize) -> Self {
        BoundedQueue {
            cap: cap.max(1),
            inner: Mutex::new(Inner {
                q: VecDeque::with_capacity(cap.max(1)),
                closed: false,
                stats: QueueStats::default(),
            }),
            cv: Condvar::new(),
        }
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().q.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Enqueue `item`, blocking while the queue is full. Returns the item
    /// back as `Err` if the queue was closed or `stop` was raised while
    /// waiting (caller should wind down).
    pub fn push(&self, item: T, stop: &AtomicBool) -> Result<(), T> {
        let mut inner = self.inner.lock().unwrap();
        let mut blocked = false;
        while inner.q.len() >= self.cap && !inner.closed {
            if stop.load(Ordering::Relaxed) {
                return Err(item);
            }
            blocked = true;
            let (guard, _timeout) = self.cv.wait_timeout(inner, Duration::from_millis(20)).unwrap();
            inner = guard;
        }
        if inner.closed {
            return Err(item);
        }
        inner.q.push_back(item);
        let depth = inner.q.len();
        inner.stats.pushes += 1;
        inner.stats.depth_sum += depth as u64;
        inner.stats.max_depth = inner.stats.max_depth.max(depth);
        if blocked {
            inner.stats.blocked_pushes += 1;
        }
        drop(inner);
        self.cv.notify_all();
        Ok(())
    }

    /// Dequeue one item, blocking while the queue is empty. Returns `None`
    /// once the queue is closed and drained, or when `stop` is raised.
    pub fn pop(&self, stop: &AtomicBool) -> Option<T> {
        let mut inner = self.inner.lock().unwrap();
        loop {
            if let Some(item) = inner.q.pop_front() {
                inner.stats.pops += 1;
                drop(inner);
                self.cv.notify_all();
                return Some(item);
            }
            if inner.closed || stop.load(Ordering::Relaxed) {
                return None;
            }
            let (guard, _timeout) = self.cv.wait_timeout(inner, Duration::from_millis(20)).unwrap();
            inner = guard;
        }
    }

    /// Producer side is done: wake consumers so they can drain and exit.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.cv.notify_all();
    }

    /// Snapshot of the depth/backpressure counters.
    pub fn stats(&self) -> QueueStats {
        self.inner.lock().unwrap().stats.clone()
    }
}

/// Free-list recycling pool: backward threads return drained passes, forward
/// threads pick them up for the next step — steady-state training allocates
/// no pass buffers (§Perf).
pub struct PassPool<T> {
    free: Mutex<Vec<T>>,
}

impl<T: Default> PassPool<T> {
    pub fn new() -> Self {
        PassPool { free: Mutex::new(Vec::new()) }
    }

    /// A recycled buffer if one is free, else a fresh default.
    pub fn take(&self) -> T {
        self.free.lock().unwrap().pop().unwrap_or_default()
    }

    pub fn put(&self, item: T) {
        self.free.lock().unwrap().push(item);
    }
}

impl<T: Default> Default for PassPool<T> {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;
    use std::sync::Arc;
    use std::time::Instant;

    #[test]
    fn fifo_roundtrip_and_stats() {
        let q = BoundedQueue::new(4);
        let stop = AtomicBool::new(false);
        for i in 0..3 {
            q.push(i, &stop).unwrap();
        }
        assert_eq!(q.len(), 3);
        assert_eq!(q.pop(&stop), Some(0));
        assert_eq!(q.pop(&stop), Some(1));
        let st = q.stats();
        assert_eq!(st.pushes, 3);
        assert_eq!(st.pops, 2);
        assert_eq!(st.max_depth, 3);
        assert_eq!(st.blocked_pushes, 0);
    }

    #[test]
    fn push_blocks_at_capacity_until_pop() {
        let q = Arc::new(BoundedQueue::new(2));
        let stop = Arc::new(AtomicBool::new(false));
        q.push(0, &stop).unwrap();
        q.push(1, &stop).unwrap();

        let pushed = Arc::new(AtomicUsize::new(0));
        let producer = {
            let (q, stop, pushed) = (Arc::clone(&q), Arc::clone(&stop), Arc::clone(&pushed));
            std::thread::spawn(move || {
                q.push(2, &stop).unwrap();
                pushed.store(1, Ordering::SeqCst);
            })
        };
        // producer must be backpressured: the item does not land while full
        std::thread::sleep(Duration::from_millis(80));
        assert_eq!(pushed.load(Ordering::SeqCst), 0, "push must block at queue_depth");
        assert_eq!(q.len(), 2);

        assert_eq!(q.pop(&stop), Some(0));
        producer.join().unwrap();
        assert_eq!(pushed.load(Ordering::SeqCst), 1);
        assert!(q.stats().blocked_pushes >= 1);
    }

    #[test]
    fn stop_unblocks_full_queue_without_deadlock() {
        let q = Arc::new(BoundedQueue::new(1));
        let stop = Arc::new(AtomicBool::new(false));
        q.push(7usize, &stop).unwrap();

        let producer = {
            let (q, stop) = (Arc::clone(&q), Arc::clone(&stop));
            std::thread::spawn(move || q.push(8, &stop))
        };
        let consumer = {
            let (q, stop) = (Arc::clone(&q), Arc::clone(&stop));
            // consumer that never pops fast enough: waits on an empty queue
            std::thread::spawn(move || {
                let first = q.pop(&stop);
                let second = q.pop(&stop); // queue now empty -> blocks until stop
                (first, second)
            })
        };
        std::thread::sleep(Duration::from_millis(50));
        stop.store(true, Ordering::SeqCst);

        let t0 = Instant::now();
        let push_result = producer.join().unwrap();
        let (first, second) = consumer.join().unwrap();
        assert!(t0.elapsed() < Duration::from_secs(2), "stop must unblock promptly");
        // the producer either squeezed its item in before stop or got it back
        if push_result.is_err() {
            assert_eq!(push_result, Err(8));
        }
        assert_eq!(first, Some(7));
        if let Some(x) = second {
            assert_eq!(x, 8);
        }
    }

    #[test]
    fn close_drains_then_none() {
        let q = BoundedQueue::new(8);
        let stop = AtomicBool::new(false);
        q.push('a', &stop).unwrap();
        q.push('b', &stop).unwrap();
        q.close();
        assert_eq!(q.push('c', &stop), Err('c'), "closed queue rejects pushes");
        assert_eq!(q.pop(&stop), Some('a'));
        assert_eq!(q.pop(&stop), Some('b'));
        assert_eq!(q.pop(&stop), None);
    }

    #[test]
    fn producers_consumers_move_everything_once() {
        let q = Arc::new(BoundedQueue::new(3));
        let stop = Arc::new(AtomicBool::new(false));
        let n_per = 200usize;
        let producers: Vec<_> = (0..3)
            .map(|p| {
                let (q, stop) = (Arc::clone(&q), Arc::clone(&stop));
                std::thread::spawn(move || {
                    for i in 0..n_per {
                        q.push(p * n_per + i, &stop).unwrap();
                    }
                })
            })
            .collect();
        let consumers: Vec<_> = (0..2)
            .map(|_| {
                let (q, stop) = (Arc::clone(&q), Arc::clone(&stop));
                std::thread::spawn(move || {
                    let mut got = Vec::new();
                    while let Some(x) = q.pop(&stop) {
                        got.push(x);
                    }
                    got
                })
            })
            .collect();
        for p in producers {
            p.join().unwrap();
        }
        q.close();
        let mut all: Vec<usize> = consumers.into_iter().flat_map(|c| c.join().unwrap()).collect();
        all.sort_unstable();
        assert_eq!(all, (0..3 * n_per).collect::<Vec<_>>());
        let st = q.stats();
        assert_eq!(st.pushes, 3 * n_per as u64);
        assert_eq!(st.pops, 3 * n_per as u64);
        assert!(st.max_depth <= 3);
    }

    #[test]
    fn pass_pool_recycles() {
        let pool: PassPool<Vec<f32>> = PassPool::new();
        let mut a = pool.take();
        assert!(a.is_empty());
        a.resize(64, 1.0);
        let cap = a.capacity();
        pool.put(a);
        let b = pool.take();
        assert_eq!(b.capacity(), cap, "pooled buffer keeps its allocation");
    }
}
