//! Typed view of `artifacts/manifest.json` (written by `python/compile/aot.py`).
//!
//! The manifest is the contract between the Python compile path and the Rust
//! runtime: per model, an ordered list of layers, each pointing at a fwd/bwd
//! HLO-text artifact plus parameter shapes, init specs and FLOP counts.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LayerKind {
    /// Consumes raw data (tokens or features); backward emits no `gx`.
    First,
    /// Activation in, activation out.
    Mid,
    /// Consumes activations + targets; forward returns (loss, metric).
    Loss,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

#[derive(Clone, Debug)]
pub struct ParamInit {
    pub name: String,
    pub shape: Vec<usize>,
    /// "normal" | "zeros" | "ones" | "uniform"
    pub init: String,
    pub scale: f32,
}

impl ParamInit {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Clone, Debug)]
pub struct LayerManifest {
    pub name: String,
    pub kind: LayerKind,
    /// Layers with equal share_key execute the same compiled artifact.
    pub share_key: String,
    pub fwd_file: String,
    pub bwd_file: String,
    /// Indices of the flat fwd inputs jax kept after DCE (see aot.py).
    pub fwd_kept: Vec<usize>,
    /// Indices of the flat bwd inputs jax kept after DCE.
    pub bwd_kept: Vec<usize>,
    pub params: Vec<ParamInit>,
    pub x_shape: Vec<usize>,
    pub x_dtype: DType,
    pub y_shape: Option<Vec<usize>>,
    pub targets_shape: Option<Vec<usize>>,
    pub fwd_flops: u64,
    pub bwd_flops: u64,
}

impl LayerManifest {
    pub fn param_numel(&self) -> usize {
        self.params.iter().map(|p| p.numel()).sum()
    }
}

#[derive(Clone, Debug)]
pub struct DataSpec {
    pub kind: String, // "vision" | "lm" | "sentiment"
    pub fields: BTreeMap<String, f64>,
}

impl DataSpec {
    pub fn get(&self, k: &str) -> Option<usize> {
        self.fields.get(k).map(|v| *v as usize)
    }
}

#[derive(Clone, Debug)]
pub struct ModelManifest {
    pub name: String,
    pub batch: usize,
    /// "classification" | "lm"
    pub task: String,
    pub n_valid_classes: usize,
    pub metric: String,
    pub data: DataSpec,
    pub param_count: usize,
    pub layers: Vec<LayerManifest>,
}

impl ModelManifest {
    pub fn total_fwd_flops(&self) -> u64 {
        self.layers.iter().map(|l| l.fwd_flops).sum()
    }

    pub fn total_bwd_flops(&self) -> u64 {
        self.layers.iter().map(|l| l.bwd_flops).sum()
    }

    pub fn step_flops(&self) -> u64 {
        self.total_fwd_flops() + self.total_bwd_flops()
    }
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub scale: String,
    pub models: BTreeMap<String, ModelManifest>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        Self::parse(dir, &text)
    }

    pub fn parse(dir: &Path, text: &str) -> Result<Manifest> {
        let j = Json::parse(text).context("parsing manifest.json")?;
        if j.get("format")?.as_usize()? != 1 {
            bail!("unsupported manifest format");
        }
        let scale = j
            .opt("scale")
            .and_then(|v| v.as_str().ok())
            .unwrap_or("default")
            .to_string();
        let mut models = BTreeMap::new();
        for (mname, mj) in j.get("models")?.as_obj()? {
            models.insert(mname.clone(), parse_model(mname, mj)?);
        }
        Ok(Manifest { dir: dir.to_path_buf(), scale, models })
    }

    pub fn model(&self, name: &str) -> Result<&ModelManifest> {
        self.models
            .get(name)
            .with_context(|| format!("model {name:?} not in manifest ({:?})",
                self.models.keys().collect::<Vec<_>>()))
    }

    pub fn artifact_path(&self, file: &str) -> PathBuf {
        self.dir.join(file)
    }
}

fn parse_model(name: &str, j: &Json) -> Result<ModelManifest> {
    let data_j = j.get("data")?;
    let mut fields = BTreeMap::new();
    for (k, v) in data_j.as_obj()? {
        if let Ok(n) = v.as_f64() {
            fields.insert(k.clone(), n);
        }
    }
    let data = DataSpec {
        kind: data_j.get("kind")?.as_str()?.to_string(),
        fields,
    };
    let mut layers = Vec::new();
    for lj in j.get("layers")?.as_arr()? {
        layers.push(parse_layer(lj)?);
    }
    if layers.is_empty() {
        bail!("model {name} has no layers");
    }
    if layers[0].kind != LayerKind::First || layers.last().unwrap().kind != LayerKind::Loss {
        bail!("model {name}: layer chain must be first .. mid .. loss");
    }
    Ok(ModelManifest {
        name: name.to_string(),
        batch: j.get("batch")?.as_usize()?,
        task: j.get("task")?.as_str()?.to_string(),
        n_valid_classes: j.get("n_valid_classes")?.as_usize()?,
        metric: j.get("metric")?.as_str()?.to_string(),
        data,
        param_count: j.get("param_count")?.as_usize()?,
        layers,
    })
}

fn parse_layer(j: &Json) -> Result<LayerManifest> {
    let kind = match j.get("kind")?.as_str()? {
        "first" => LayerKind::First,
        "mid" => LayerKind::Mid,
        "loss" => LayerKind::Loss,
        k => bail!("unknown layer kind {k:?}"),
    };
    let x_dtype = match j.get("x_dtype")?.as_str()? {
        "f32" => DType::F32,
        "i32" => DType::I32,
        d => bail!("unknown dtype {d:?}"),
    };
    let mut params = Vec::new();
    for pj in j.get("params")?.as_arr()? {
        params.push(ParamInit {
            name: pj.get("name")?.as_str()?.to_string(),
            shape: pj.get("shape")?.shape_vec()?,
            init: pj.get("init")?.as_str()?.to_string(),
            scale: pj.get("scale")?.as_f64()? as f32,
        });
    }
    // number of flat inputs: params + x (+ targets or gy)
    let n_inputs = params.len() + 2;
    let kept_or_all = |key: &str| -> Result<Vec<usize>> {
        match j.opt(key) {
            Some(v) => Ok(v.shape_vec()?),
            None => Ok((0..n_inputs).collect()),
        }
    };
    let fwd_kept = match j.opt("fwd_kept") {
        Some(v) => v.shape_vec()?,
        // fwd of first/mid layers has params+x inputs; loss has +targets
        None => (0..n_inputs - usize::from(kind != LayerKind::Loss)).collect(),
    };
    let bwd_kept = kept_or_all("bwd_kept")?;
    Ok(LayerManifest {
        name: j.get("name")?.as_str()?.to_string(),
        kind,
        share_key: j.get("share_key")?.as_str()?.to_string(),
        fwd_file: j.get("fwd")?.as_str()?.to_string(),
        bwd_file: j.get("bwd")?.as_str()?.to_string(),
        fwd_kept,
        bwd_kept,
        params,
        x_shape: j.get("x_shape")?.shape_vec()?,
        x_dtype,
        y_shape: j.opt("y_shape").map(|v| v.shape_vec()).transpose()?,
        targets_shape: j.opt("targets_shape").map(|v| v.shape_vec()).transpose()?,
        fwd_flops: j.get("fwd_flops")?.as_f64()? as u64,
        bwd_flops: j.get("bwd_flops")?.as_f64()? as u64,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "format": 1, "scale": "smoke",
      "models": {
        "m": {
          "batch": 4, "task": "classification", "n_valid_classes": 10,
          "metric": "acc_count", "param_count": 100,
          "data": {"kind": "vision", "n_in": 8, "n_classes": 10},
          "layers": [
            {"name": "stem", "kind": "first", "share_key": "s",
             "fwd": "s.fwd.hlo.txt", "bwd": "s.bwd.hlo.txt",
             "params": [{"name": "w", "shape": [8, 4], "init": "normal", "scale": 0.1}],
             "x_shape": [4, 8], "x_dtype": "f32", "y_shape": [4, 4],
             "targets_shape": null, "fwd_flops": 256, "bwd_flops": 512},
            {"name": "cls", "kind": "loss", "share_key": "c",
             "fwd": "c.fwd.hlo.txt", "bwd": "c.bwd.hlo.txt",
             "params": [{"name": "w", "shape": [4, 10], "init": "zeros", "scale": 0.0}],
             "x_shape": [4, 4], "x_dtype": "f32", "y_shape": null,
             "targets_shape": [4], "fwd_flops": 320, "bwd_flops": 640}
          ]
        }
      }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(Path::new("/tmp/art"), SAMPLE).unwrap();
        let model = m.model("m").unwrap();
        assert_eq!(model.batch, 4);
        assert_eq!(model.layers.len(), 2);
        assert_eq!(model.layers[0].kind, LayerKind::First);
        assert_eq!(model.layers[1].kind, LayerKind::Loss);
        assert_eq!(model.layers[1].targets_shape, Some(vec![4]));
        assert_eq!(model.step_flops(), 256 + 512 + 320 + 640);
        assert_eq!(model.data.get("n_classes"), Some(10));
        assert!(m.model("nope").is_err());
    }

    #[test]
    fn rejects_bad_chain() {
        let bad = SAMPLE.replace("\"kind\": \"first\"", "\"kind\": \"mid\"");
        assert!(Manifest::parse(Path::new("/tmp"), &bad).is_err());
    }
}
