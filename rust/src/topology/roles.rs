//! Heterogeneous worker roles and declarative traffic topologies.
//!
//! The seed-era cluster is *flat*: every worker is a trainer and traffic is
//! peer-to-peer gossip or a collective. This module adds a declarative
//! [`TopologySpec`] on top:
//!
//! * [`TopologySpec::Flat`] — every worker trains, gossip/collective traffic
//!   exactly as before (the default; bit-identical to the flat-era runs).
//! * [`TopologySpec::Ps`] — star/parameter-server: the **last** `shards`
//!   worker ids become server shards that partition the model's layers
//!   contiguously; the remaining ids stay trainers (worker 0 keeps its
//!   eval/drift duties). Trainers push per-layer gradients
//!   (`Payload::GradPush`) to the owning shard and receive fresh parameters
//!   back (`Payload::ParamPull`).
//! * [`TopologySpec::Hier`] — hierarchical two-tier: all workers train, but
//!   they are split into `groups` contiguous groups (exact
//!   [`super::group_bounds`] partition). Push-sum gossip stays *inside* the
//!   group on instant shared-memory semantics; once per sync period each
//!   group's leader exchanges whole models with the next group's leader over
//!   the configured fabric — on `SimFabric` that inter-group hop pays the
//!   link's latency/bandwidth model while intra-group traffic stays free,
//!   the classic intra-node/inter-node split.
//!
//! Layer→shard routing is elastic: [`RoleTable`] caches the owner map per
//! membership epoch, so a crashed shard (under `RecoveryPolicy::Shrink`)
//! re-partitions its layers across the survivors, with a handover record per
//! moved layer so the fabric can copy the freshest parameter values across.
//! Under `RecoveryPolicy::Stall` the static owner map is kept and routing to
//! a dead shard returns `None` — trainers freeze that layer until the shard
//! rejoins (or the engine declares the run stalled).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use anyhow::{bail, Result};

/// What a worker id *is* under a [`TopologySpec`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Role {
    /// Runs forward/backward passes and pushes gradients.
    Trainer,
    /// Parameter-server shard `shard` (0-based), owning a contiguous slice
    /// of the model's layers. Never computes passes.
    PsShard {
        /// 0-based shard index (`wid = m - n_shards + shard`)
        shard: usize,
    },
}

/// Declarative cluster topology: how roles are assigned and traffic routed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TopologySpec {
    /// Flat peer-to-peer cluster (seed-era behavior; the default).
    Flat,
    /// Star/parameter-server with `shards` server shards (the last `shards`
    /// worker ids) partitioning the model's layers.
    Ps {
        /// number of parameter-server shards (>= 1, < workers)
        shards: usize,
    },
    /// Hierarchical two-tier cluster: `groups` contiguous trainer groups,
    /// instant push-sum inside a group, leader-to-leader fabric exchange
    /// across groups.
    Hier {
        /// number of intra-node groups (>= 2, <= workers)
        groups: usize,
    },
}

impl Default for TopologySpec {
    fn default() -> Self {
        TopologySpec::Flat
    }
}

impl TopologySpec {
    /// Parse the CLI/TOML spelling: `flat`, `ps:N`, `hier:G`.
    pub fn parse(text: &str) -> Result<TopologySpec> {
        let t = text.trim();
        if t.eq_ignore_ascii_case("flat") {
            return Ok(TopologySpec::Flat);
        }
        if let Some(n) = t.strip_prefix("ps:") {
            let shards: usize = n
                .trim()
                .parse()
                .map_err(|_| anyhow::anyhow!("topology: bad shard count in {t:?}"))?;
            return Ok(TopologySpec::Ps { shards });
        }
        if let Some(g) = t.strip_prefix("hier:") {
            let groups: usize = g
                .trim()
                .parse()
                .map_err(|_| anyhow::anyhow!("topology: bad group count in {t:?}"))?;
            return Ok(TopologySpec::Hier { groups });
        }
        bail!("unknown topology {t:?} (expected flat, ps:N or hier:G)")
    }

    /// Canonical spelling (round-trips through [`TopologySpec::parse`]).
    pub fn name(&self) -> String {
        match self {
            TopologySpec::Flat => "flat".into(),
            TopologySpec::Ps { shards } => format!("ps:{shards}"),
            TopologySpec::Hier { groups } => format!("hier:{groups}"),
        }
    }

    /// Structural validation against the worker count.
    pub fn validate(&self, workers: usize) -> Result<()> {
        match *self {
            TopologySpec::Flat => Ok(()),
            TopologySpec::Ps { shards } => {
                if shards == 0 {
                    bail!("topology ps:N needs at least one shard");
                }
                if shards >= workers {
                    bail!(
                        "topology ps:{shards} leaves no trainers with {workers} workers \
                         (need shards < workers)"
                    );
                }
                Ok(())
            }
            TopologySpec::Hier { groups } => {
                if groups < 2 {
                    bail!("topology hier:G needs at least 2 groups (1 group is flat)");
                }
                if groups > workers {
                    bail!(
                        "topology hier:{groups} cannot split {workers} workers into \
                         more groups than workers"
                    );
                }
                Ok(())
            }
        }
    }

    /// Number of parameter-server shards (0 for non-PS topologies).
    pub fn n_shards(&self) -> usize {
        match *self {
            TopologySpec::Ps { shards } => shards,
            _ => 0,
        }
    }

    /// Number of workers that run training passes.
    pub fn n_trainers(&self, m: usize) -> usize {
        m - self.n_shards().min(m)
    }

    /// The role of worker `wid` in an `m`-worker cluster.
    pub fn role_of(&self, wid: usize, m: usize) -> Role {
        let trainers = self.n_trainers(m);
        if wid >= trainers {
            Role::PsShard { shard: wid - trainers }
        } else {
            Role::Trainer
        }
    }

    /// True when `wid` is a parameter-server shard.
    pub fn is_shard(&self, wid: usize, m: usize) -> bool {
        matches!(self.role_of(wid, m), Role::PsShard { .. })
    }

    /// Worker id of shard `k` (panics when `k` is out of range).
    pub fn shard_wid(&self, k: usize, m: usize) -> usize {
        assert!(k < self.n_shards(), "shard {k} out of range");
        self.n_trainers(m) + k
    }
}

/// One layer handed from a (dead) shard to a surviving one during an elastic
/// re-partition: the fabric copies `layer`'s parameters from `from_wid`'s
/// replica (which holds the freshest values even after the crash) into
/// `to_wid`'s before routing resumes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Handover {
    /// model layer being re-homed
    pub layer: usize,
    /// previous owner's worker id
    pub from_wid: usize,
    /// new owner's worker id
    pub to_wid: usize,
}

/// Owner (worker id, picked from `live`) of `layer` when `n_layers` layers
/// are partitioned contiguously across the `live` shard ids. With more live
/// shards than layers the tail shards own nothing.
pub fn layer_owner(layer: usize, n_layers: usize, live: &[usize]) -> Option<usize> {
    if live.is_empty() || layer >= n_layers {
        return None;
    }
    let g = live.len().min(n_layers);
    Some(live[super::group_of(layer, n_layers, g)])
}

/// Epoch-cached layer→shard owner map for a PS topology. `route` is called
/// on every gradient push; the owner map is only recomputed when the
/// membership epoch moves (crash/rejoin), and each recompute reports the
/// parameter handovers the caller must perform.
pub struct RoleTable {
    spec: TopologySpec,
    m: usize,
    n_layers: usize,
    cache: Mutex<RouteCache>,
    /// elastic re-partitions performed (shard crash/rejoin epochs)
    pub repartitions: AtomicU64,
}

struct RouteCache {
    /// membership epoch the owner map was computed at (`None` = never)
    epoch: Option<u64>,
    /// per-layer owner wid (`None` = owner dead under Stall policy)
    owners: Vec<Option<usize>>,
}

impl RoleTable {
    /// A routing table for `m` workers over `n_layers` model layers.
    pub fn new(spec: TopologySpec, m: usize, n_layers: usize) -> RoleTable {
        RoleTable {
            spec,
            m,
            n_layers,
            cache: Mutex::new(RouteCache { epoch: None, owners: vec![None; n_layers] }),
            repartitions: AtomicU64::new(0),
        }
    }

    pub fn spec(&self) -> &TopologySpec {
        &self.spec
    }

    /// Owner of `layer` at membership `epoch`, where `alive[wid]` flags the
    /// live workers and `shrink` selects the elastic policy: `true`
    /// re-partitions layers across the surviving shards (returning the
    /// parameter handovers to apply), `false` keeps the static map and
    /// returns `None` for layers whose owner is dead.
    pub fn route(
        &self,
        epoch: u64,
        alive: &[bool],
        shrink: bool,
        layer: usize,
    ) -> (Option<usize>, Vec<Handover>) {
        let mut cache = self.cache.lock().unwrap();
        let mut handovers = Vec::new();
        if cache.epoch != Some(epoch) {
            let all: Vec<usize> =
                (0..self.spec.n_shards()).map(|k| self.spec.shard_wid(k, self.m)).collect();
            let live: Vec<usize> =
                all.iter().copied().filter(|&w| alive.get(w).copied().unwrap_or(false)).collect();
            let fresh: Vec<Option<usize>> = (0..self.n_layers)
                .map(|l| {
                    if shrink {
                        layer_owner(l, self.n_layers, &live)
                    } else {
                        // static map; dead owner routes to None (stall)
                        layer_owner(l, self.n_layers, &all)
                            .filter(|&w| alive.get(w).copied().unwrap_or(false))
                    }
                })
                .collect();
            let first = cache.epoch.is_none();
            let mut moved = false;
            for (l, (&old, &new)) in cache.owners.iter().zip(fresh.iter()).enumerate() {
                if let (Some(old), Some(new)) = (old, new) {
                    if old != new {
                        moved = true;
                        handovers.push(Handover { layer: l, from_wid: old, to_wid: new });
                    }
                }
            }
            if !first && moved {
                self.repartitions.fetch_add(1, Ordering::Relaxed);
            }
            cache.owners = fresh;
            cache.epoch = Some(epoch);
        }
        (cache.owners.get(layer).copied().flatten(), handovers)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrips_and_rejects_garbage() {
        for text in ["flat", "ps:1", "ps:3", "hier:2", "hier:8"] {
            let spec = TopologySpec::parse(text).unwrap();
            assert_eq!(spec.name(), text);
            assert_eq!(TopologySpec::parse(&spec.name()).unwrap(), spec);
        }
        assert_eq!(TopologySpec::parse(" Flat ").unwrap(), TopologySpec::Flat);
        for bad in ["star", "ps:", "ps:x", "hier:", "ring:3", ""] {
            assert!(TopologySpec::parse(bad).is_err(), "{bad:?} should not parse");
        }
    }

    #[test]
    fn validation_bounds_shards_and_groups() {
        assert!(TopologySpec::Flat.validate(1).is_ok());
        assert!(TopologySpec::Ps { shards: 1 }.validate(2).is_ok());
        assert!(TopologySpec::Ps { shards: 0 }.validate(4).is_err());
        assert!(TopologySpec::Ps { shards: 4 }.validate(4).is_err(), "no trainers left");
        assert!(TopologySpec::Hier { groups: 2 }.validate(4).is_ok());
        assert!(TopologySpec::Hier { groups: 1 }.validate(4).is_err());
        assert!(TopologySpec::Hier { groups: 5 }.validate(4).is_err(), "groups > workers");
    }

    #[test]
    fn roles_put_shards_at_the_tail() {
        let spec = TopologySpec::Ps { shards: 2 };
        let m = 5;
        assert_eq!(spec.n_trainers(m), 3);
        for wid in 0..3 {
            assert_eq!(spec.role_of(wid, m), Role::Trainer);
        }
        assert_eq!(spec.role_of(3, m), Role::PsShard { shard: 0 });
        assert_eq!(spec.role_of(4, m), Role::PsShard { shard: 1 });
        assert_eq!(spec.shard_wid(0, m), 3);
        assert_eq!(spec.shard_wid(1, m), 4);
        assert!(spec.is_shard(4, m) && !spec.is_shard(0, m));
        // flat and hier topologies have no shards
        assert_eq!(TopologySpec::Flat.role_of(4, m), Role::Trainer);
        assert_eq!(TopologySpec::Hier { groups: 2 }.role_of(4, m), Role::Trainer);
    }

    #[test]
    fn layer_owner_partitions_and_handles_edge_counts() {
        // 7 layers over live shards {3, 4}: contiguous non-empty halves
        let live = [3usize, 4];
        let owners: Vec<usize> = (0..7).map(|l| layer_owner(l, 7, &live).unwrap()).collect();
        assert_eq!(owners, vec![3, 3, 3, 3, 4, 4, 4]);
        // more shards than layers: tail shard owns nothing but lookups work
        let live = [2usize, 3, 4];
        for l in 0..2 {
            assert!(layer_owner(l, 2, &live).is_some());
        }
        assert_eq!(layer_owner(5, 2, &live), None, "out-of-range layer");
        assert_eq!(layer_owner(0, 2, &[]), None, "no survivors");
    }

    #[test]
    fn role_table_repartitions_on_epoch_change_with_handover() {
        let spec = TopologySpec::Ps { shards: 2 };
        let (m, n_layers) = (4usize, 4usize);
        let rt = RoleTable::new(spec, m, n_layers);
        let alive = vec![true; m];
        // epoch 0: layers 0-1 on shard wid 2, layers 2-3 on shard wid 3
        let (owner, hand) = rt.route(0, &alive, true, 0);
        assert_eq!(owner, Some(2));
        assert!(hand.is_empty(), "first map is not a repartition");
        assert_eq!(rt.route(0, &alive, true, 3).0, Some(3));
        assert_eq!(rt.repartitions.load(Ordering::Relaxed), 0);

        // shard wid 3 dies; shrink moves its layers onto wid 2 with handover
        let mut alive2 = alive.clone();
        alive2[3] = false;
        let (owner, hand) = rt.route(1, &alive2, true, 2);
        assert_eq!(owner, Some(2));
        assert_eq!(
            hand,
            vec![
                Handover { layer: 2, from_wid: 3, to_wid: 2 },
                Handover { layer: 3, from_wid: 3, to_wid: 2 }
            ]
        );
        assert_eq!(rt.repartitions.load(Ordering::Relaxed), 1);

        // stall policy instead: static map, dead owner routes to None
        let rt = RoleTable::new(spec, m, n_layers);
        rt.route(0, &alive, false, 0);
        let (owner, hand) = rt.route(1, &alive2, false, 3);
        assert_eq!(owner, None, "dead owner must stall the layer");
        assert!(hand.is_empty());
        assert_eq!(rt.route(1, &alive2, false, 0).0, Some(2), "live shard keeps its layers");
    }
}
